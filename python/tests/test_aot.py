"""AOT export pipeline: HLO text round-trips and manifest consistency.

These run the lowering path (not the trained 400-step pipeline) so the suite
stays fast; the full pipeline is exercised by `make artifacts`.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import approx_matmul as am
from compile.kernels import ref

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_to_hlo_text_emits_entry_computation():
    lowered = jax.jit(lambda a, b: (ref.exact_matmul_ref(a, b),)).lower(
        jax.ShapeDtypeStruct((8, 8), jnp.float32), jax.ShapeDtypeStruct((8, 8), jnp.float32)
    )
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text and "f32[8,8]" in text


def test_to_hlo_text_pallas_lowering():
    """The pallas kernel (interpret=True) must lower to plain HLO — no
    custom-calls that the CPU PJRT client can't run."""
    lowered = jax.jit(lambda a, b, l: (am.approx_matmul(a, b, l),)).lower(
        jax.ShapeDtypeStruct((32, 32), jnp.float32),
        jax.ShapeDtypeStruct((32, 32), jnp.float32),
        jax.ShapeDtypeStruct((128, 128), jnp.float32),
    )
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    assert "custom-call" not in text.lower()


def test_export_writes_file(tmp_path):
    path = str(tmp_path / "m.hlo.txt")
    n = aot.export(
        lambda a: (a + 1.0,), (jax.ShapeDtypeStruct((4,), jnp.float32),), path
    )
    assert n > 0 and os.path.getsize(path) == n


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")),
                    reason="artifacts not built (run `make artifacts`)")
class TestBuiltArtifacts:
    @pytest.fixture(scope="class")
    def manifest(self):
        with open(os.path.join(ART, "manifest.json")) as f:
            return json.load(f)

    def test_manifest_fields(self, manifest):
        assert manifest["batch"] == 64
        assert manifest["num_classes"] == 5
        assert manifest["exact_test_accuracy"] > 0.85

    def test_all_hlo_files_exist(self, manifest):
        for name in ("matmul_approx", "matmul_exact", "cnn_approx", "cnn_exact"):
            p = os.path.join(ART, f"{name}.hlo.txt")
            assert os.path.exists(p), name
            assert os.path.getsize(p) == manifest["hlo_chars"][name]

    def test_weights_size_matches_specs(self, manifest):
        n_params = sum(int(np.prod(shape)) for _, shape in manifest["params"])
        assert os.path.getsize(os.path.join(ART, "weights.f32")) == 4 * n_params

    def test_testset_sizes(self, manifest):
        n = manifest["n_test"]
        assert os.path.getsize(os.path.join(ART, "testset_images.f32")) == 4 * n * 16 * 16
        assert os.path.getsize(os.path.join(ART, "testset_labels.u8")) == n

    def test_weights_reload_reproduce_accuracy(self, manifest):
        """Rebuild params from the flat file and check exact accuracy matches
        the manifest (this is exactly what the Rust native evaluator does)."""
        flat = np.fromfile(os.path.join(ART, "weights.f32"), dtype="<f4")
        params, off = {}, 0
        for name, shape in manifest["params"]:
            size = int(np.prod(shape))
            params[name] = jnp.asarray(flat[off : off + size].reshape(shape))
            off += size
        assert off == flat.size
        imgs = np.fromfile(os.path.join(ART, "testset_images.f32"), dtype="<f4").reshape(
            manifest["n_test"], 16, 16, 1
        )
        labels = np.fromfile(os.path.join(ART, "testset_labels.u8"), dtype=np.uint8)
        acc = model.accuracy(params, jnp.asarray(imgs), jnp.asarray(labels.astype(np.int32)))
        assert abs(acc - manifest["exact_test_accuracy"]) < 1e-6
