//! Bench campaign: grid throughput (jobs/sec) and campaign-global eval
//! cache-hit rate for the worker-pool scheduler vs a serial loop of
//! `ga_appx_cdp` calls over the same scenarios.

use carbon3d::approx::library;
use carbon3d::area::node::ALL_NODES;
use carbon3d::campaign::{run_campaign, CampaignSpec, ResultStore, SurrogateBackend};
use carbon3d::coordinator::ga_appx_cdp;
use carbon3d::dataflow::workloads::workload;
use carbon3d::ga::GaParams;
use carbon3d::runtime::EvalService;
use carbon3d::util::timer::time_once;

/// 2 models x 3 nodes x 2 deltas = 12 jobs at a reduced GA budget.
fn spec() -> CampaignSpec {
    let mut s = CampaignSpec::new(
        vec!["vgg16".to_string(), "resnet50".to_string()],
        ALL_NODES.to_vec(),
        vec![1.0, 3.0],
    );
    s.ga = GaParams { population: 16, generations: 8, patience: 4, ..Default::default() };
    s
}

fn main() {
    println!("== campaign benches ==");
    let s = spec();
    let n = s.n_jobs();
    let lib = library();

    // Serial baseline: one GA-APPX-CDP invocation per scenario, nothing
    // shared across runs (the pre-campaign workflow).
    let (_, serial_t) = time_once(|| {
        for job in s.jobs() {
            let w = workload(&job.model).unwrap();
            std::hint::black_box(ga_appx_cdp(
                &w,
                job.node,
                &lib,
                job.delta_pct,
                job.fps_floor,
                GaParams { seed: job.seed, ..s.ga },
            ));
        }
    });
    println!(
        "serial ga_appx_cdp loop                      {n} jobs in {serial_t:.2}s = {:.2} jobs/s",
        n as f64 / serial_t
    );

    for workers in [1usize, 2, 4, 8] {
        let path = std::env::temp_dir().join(format!(
            "carbon3d-bench-campaign-{}-{workers}.jsonl",
            std::process::id()
        ));
        let _ = std::fs::remove_file(&path);
        let mut store = ResultStore::open(&path).unwrap();
        let svc = EvalService::start(SurrogateBackend::default());
        let (report, t) =
            time_once(|| run_campaign(&s, workers, &mut store, &svc).unwrap());
        svc.shutdown();
        println!(
            "campaign {workers} worker{}                           \
             {n} jobs in {t:.2}s = {:.2} jobs/s | cache-hit {:.0}% | {:.2}x vs serial",
            if workers == 1 { " " } else { "s" },
            report.jobs_per_sec(),
            report.stats.hit_rate() * 100.0,
            serial_t / t
        );
        let _ = std::fs::remove_file(&path);
    }
}
