//! Crash-at-every-site **chaos harness** (`carbon3d campaign chaos`).
//!
//! Proves the crash-anywhere recovery invariant (DESIGN.md §11) end to
//! end: for every fault site in [`super::fault::SITES`], run the same
//! small campaign grid in a child process with
//! `CARBON3D_FAULTS=<site>:1:crash` armed, let the child abort
//! mid-operation, resume fault-free, and byte-compare the final store
//! and its durable sidecars against a fault-free reference run — across
//! all three executor shapes (thread pool, two lease-coordinated shards
//! plus merge, adaptive sampler).
//!
//! The harness drives the real binary (`std::env::current_exe()`), not
//! an in-process simulation: the abort kills the whole process exactly
//! like a power cut would, and recovery goes through the same CLI paths
//! an operator would run. Compared artifacts are the store itself, the
//! `.front.json` checkpoint, and the `.mapcache.json` sidecar;
//! `.status.json` is deliberately excluded — it is pure observability
//! (pids, timestamps) and carries no recovery state.

use std::path::{Path, PathBuf};
use std::process::Command;

use anyhow::{bail, ensure, Context, Result};

use super::checkpoint::CampaignArchive;
use super::fault::SITES;
use super::mapcache::mapcache_path;

/// The stderr marker the fault layer's process-terminating kinds print
/// before aborting. The harness uses it to tell an injected crash from
/// a genuine child failure (which must surface as an error, not a
/// recovery scenario).
pub const CRASH_MARKER: &str = "fault: injected";

/// Lease TTL (seconds) the sharded steps run with: short, so an orphan
/// lease left by a crash between claim and done expires within the
/// harness's [`LEASE_LAPSE_MS`] pause instead of the production default
/// of 900 s. Safe here because the harness runs shard steps
/// sequentially — nothing races the short TTL.
pub const CHAOS_LEASE_TTL_S: u64 = 1;

/// How long the sharded recovery pass waits before resuming, so any
/// lease the crashed child still held has visibly expired (timestamps
/// are second-resolution and a lease becomes stealable at age ttl+1).
pub const LEASE_LAPSE_MS: u64 = 2_500;

/// One executor shape the harness replays the grid under.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChaosMode {
    /// Single process, in-process thread pool (the default executor).
    Threads,
    /// Two lease-coordinated shard processes, then `campaign merge`.
    Sharded,
    /// Single process, `--sampler adaptive`.
    Adaptive,
}

impl ChaosMode {
    /// Every mode, in probe order.
    pub const ALL: [ChaosMode; 3] =
        [ChaosMode::Threads, ChaosMode::Sharded, ChaosMode::Adaptive];

    /// CLI name (`--modes threads,sharded,adaptive`).
    pub fn name(self) -> &'static str {
        match self {
            ChaosMode::Threads => "threads",
            ChaosMode::Sharded => "sharded",
            ChaosMode::Adaptive => "adaptive",
        }
    }

    /// Parse a CLI mode name, inverse of [`ChaosMode::name`].
    pub fn parse(s: &str) -> Result<Self> {
        match s.trim() {
            "threads" => Ok(ChaosMode::Threads),
            "sharded" => Ok(ChaosMode::Sharded),
            "adaptive" => Ok(ChaosMode::Adaptive),
            other => bail!("unknown chaos mode {other:?} (threads|sharded|adaptive)"),
        }
    }

    /// The child invocations (argv after the binary) that run one full
    /// campaign of this shape into `store`. `grid` is the passthrough
    /// grid/GA flag list; every step receives it verbatim so reference,
    /// fault, and recovery passes all describe the identical campaign.
    fn steps(self, grid: &[String], store: &Path) -> Vec<Vec<String>> {
        let store = store.display().to_string();
        let campaign = |extra: &[&str]| -> Vec<String> {
            let mut v = vec!["campaign".to_string()];
            v.extend(grid.iter().cloned());
            v.extend(["--out".to_string(), store.clone()]);
            v.extend(extra.iter().map(|s| s.to_string()));
            v
        };
        let ttl = CHAOS_LEASE_TTL_S.to_string();
        match self {
            ChaosMode::Threads => vec![campaign(&[])],
            ChaosMode::Sharded => vec![
                campaign(&["--shard", "0/2", "--lease-ttl", &ttl]),
                campaign(&["--shard", "1/2", "--lease-ttl", &ttl]),
                {
                    let mut v =
                        vec!["campaign".to_string(), "merge".to_string(), "--shards".to_string(), "2".to_string()];
                    v.extend(grid.iter().cloned());
                    v.extend(["--out".to_string(), store.clone()]);
                    v
                },
            ],
            ChaosMode::Adaptive => vec![campaign(&["--sampler", "adaptive"])],
        }
    }
}

/// What a single (mode, site) probe established.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SiteOutcome {
    /// Crash injected, recovery resumed, every artifact byte-identical.
    Identical,
    /// The site was never reached under this mode (e.g. lease sites in a
    /// single-process run): no crash fired and the campaign simply
    /// completed. Not a failure per se — but a site no mode hits fails
    /// the coverage check ([`uncovered_sites`]).
    NotHit,
    /// Recovery produced different bytes; the message names the
    /// artifact(s).
    Diverged(String),
}

impl SiteOutcome {
    /// Short human verdict for progress lines and the summary table.
    pub fn describe(&self) -> String {
        match self {
            SiteOutcome::Identical => "crash + resume -> byte-identical".to_string(),
            SiteOutcome::NotHit => "site not hit under this mode (skipped)".to_string(),
            SiteOutcome::Diverged(d) => format!("DIVERGED: {d}"),
        }
    }
}

/// Per-(mode, site) verdict.
#[derive(Debug)]
pub struct SiteReport {
    /// Mode name ([`ChaosMode::name`]).
    pub mode: &'static str,
    /// Fault site probed (one of [`SITES`]).
    pub site: &'static str,
    /// What happened.
    pub outcome: SiteOutcome,
}

/// The chaos harness: a binary to re-invoke, the grid flags every child
/// receives, and a scratch directory (one subdirectory per probe, kept
/// for post-mortem inspection).
pub struct ChaosHarness {
    /// Binary to drive (normally `std::env::current_exe()`).
    pub exe: PathBuf,
    /// Passthrough grid/GA flags (`--models …`, `--quick`, …).
    pub grid: Vec<String>,
    /// Working directory for reference and probe campaign stores.
    pub dir: PathBuf,
}

impl ChaosHarness {
    /// Run the probes for `modes` and return every per-site verdict.
    /// Errors are harness malfunctions (a child failed for a reason
    /// other than the injected crash); recovery divergence is reported
    /// in the verdicts, not as an `Err`.
    pub fn run(&self, modes: &[ChaosMode]) -> Result<Vec<SiteReport>> {
        let mut reports = Vec::new();
        for &mode in modes {
            reports.extend(self.run_mode(mode)?);
        }
        Ok(reports)
    }

    fn run_mode(&self, mode: ChaosMode) -> Result<Vec<SiteReport>> {
        let ref_dir = self.dir.join(format!("{}-reference", mode.name()));
        std::fs::create_dir_all(&ref_dir)
            .with_context(|| format!("creating {}", ref_dir.display()))?;
        let ref_store = ref_dir.join("campaign.jsonl");
        println!("chaos[{}]: fault-free reference run", mode.name());
        for step in mode.steps(&self.grid, &ref_store) {
            let crashed = self.child(&step, None)?;
            ensure!(!crashed, "reference run aborted with no fault armed");
        }
        let mut reports = Vec::new();
        for &site in SITES {
            let outcome = self.probe(mode, site, &ref_store)?;
            println!("chaos[{}] {site}: {}", mode.name(), outcome.describe());
            reports.push(SiteReport { mode: mode.name(), site, outcome });
        }
        Ok(reports)
    }

    /// One probe: crash the campaign at the first hit of `site`, resume
    /// fault-free, compare against the reference.
    fn probe(&self, mode: ChaosMode, site: &str, ref_store: &Path) -> Result<SiteOutcome> {
        let dir = self.dir.join(format!("{}-{}", mode.name(), site.replace('.', "-")));
        std::fs::create_dir_all(&dir).with_context(|| format!("creating {}", dir.display()))?;
        let store = dir.join("campaign.jsonl");
        let steps = mode.steps(&self.grid, &store);
        let plan = format!("{site}:1:crash");

        // Fault pass: run the steps with the plan armed until one aborts.
        // Multi-step modes keep the plan armed on every step (each child
        // process counts its own hits), so the crash lands in whichever
        // step reaches the site first.
        let mut crashed_at = None;
        for (i, step) in steps.iter().enumerate() {
            if self.child(step, Some(&plan))? {
                crashed_at = Some(i);
                break;
            }
        }
        let Some(first) = crashed_at else {
            return Ok(SiteOutcome::NotHit);
        };

        if mode == ChaosMode::Sharded {
            // Let any lease the dead child still held expire, so the
            // recovery shards can reclaim or steal its jobs.
            std::thread::sleep(std::time::Duration::from_millis(LEASE_LAPSE_MS));
        }
        // Recovery pass, fault-free, from the step that died. Steps that
        // completed before it are not re-run; the crashed step resumes
        // its partial store, and later steps never ran at all (they
        // tolerate the redundant --resume on their empty stores).
        for step in &steps[first..] {
            let mut step = step.clone();
            step.push("--resume".to_string());
            let crashed = self.child(&step, None)?;
            ensure!(!crashed, "recovery step aborted with no fault armed");
        }
        compare_artifacts(ref_store, &store)
    }

    /// Run one child invocation to completion. `Ok(true)` means the
    /// fault plan fired a crash (non-success exit plus [`CRASH_MARKER`]
    /// on stderr); `Ok(false)` is a clean exit; anything else — a child
    /// failing on its own — is a harness error.
    fn child(&self, args: &[String], fault: Option<&str>) -> Result<bool> {
        let mut cmd = Command::new(&self.exe);
        cmd.args(args);
        // The harness's own environment must not leak into the children:
        // reference and recovery runs stay fault-free even if the
        // operator has CARBON3D_FAULTS exported, and tracing would only
        // slow the probes down.
        cmd.env_remove("CARBON3D_FAULTS");
        cmd.env_remove("CARBON3D_TRACE");
        if let Some(plan) = fault {
            cmd.env("CARBON3D_FAULTS", plan);
        }
        let out = cmd
            .output()
            .with_context(|| format!("spawning {} {}", self.exe.display(), args.join(" ")))?;
        if out.status.success() {
            return Ok(false);
        }
        let stderr = String::from_utf8_lossy(&out.stderr);
        if fault.is_some() && stderr.contains(CRASH_MARKER) {
            return Ok(true);
        }
        bail!(
            "chaos child `{}` failed ({}) without an injected crash:\n{}",
            args.join(" "),
            out.status,
            stderr.trim_end()
        );
    }
}

/// Byte-compare the recovered campaign's durable artifacts against the
/// fault-free reference: the store itself, the `.front.json`
/// checkpoint, and the `.mapcache.json` sidecar. Missing on both sides
/// is equal (sidecar disabled); missing on one side is a divergence.
fn compare_artifacts(reference: &Path, recovered: &Path) -> Result<SiteOutcome> {
    let pairs = [
        ("store", reference.to_path_buf(), recovered.to_path_buf()),
        (
            "front checkpoint",
            CampaignArchive::checkpoint_path(reference),
            CampaignArchive::checkpoint_path(recovered),
        ),
        ("mapcache sidecar", mapcache_path(reference), mapcache_path(recovered)),
    ];
    let mut diverged = Vec::new();
    for (what, a, b) in &pairs {
        match (std::fs::read(a).ok(), std::fs::read(b).ok()) {
            (None, None) => {}
            (Some(x), Some(y)) if x == y => {}
            (Some(_), None) => diverged.push(format!("{what} missing after recovery")),
            (None, Some(_)) => diverged.push(format!("{what} missing in the reference")),
            (Some(x), Some(y)) => {
                diverged.push(format!("{what}: {} vs {} bytes differ", x.len(), y.len()));
            }
        }
    }
    if diverged.is_empty() {
        Ok(SiteOutcome::Identical)
    } else {
        Ok(SiteOutcome::Diverged(diverged.join("; ")))
    }
}

/// The probes whose recovery diverged — the harness's failure set.
pub fn failures(reports: &[SiteReport]) -> Vec<&SiteReport> {
    reports.iter().filter(|r| matches!(r.outcome, SiteOutcome::Diverged(_))).collect()
}

/// Sites that fired in no probed mode. When all three modes were probed
/// this means a [`SITES`] entry went dead — the registry is stale or a
/// call site lost its fault hook — which the harness treats as a
/// failure (a dead site would silently stop being chaos-tested).
pub fn uncovered_sites(reports: &[SiteReport]) -> Vec<&'static str> {
    SITES
        .iter()
        .copied()
        .filter(|s| {
            let probes: Vec<_> = reports.iter().filter(|r| r.site == *s).collect();
            !probes.is_empty() && probes.iter().all(|r| r.outcome == SiteOutcome::NotHit)
        })
        .collect()
}

/// One-line-per-probe summary table, modes grouped in probe order.
pub fn render_reports(reports: &[SiteReport]) -> String {
    let site_w = SITES.iter().map(|s| s.len()).max().unwrap_or(0);
    let mut out = String::new();
    for r in reports {
        out.push_str(&format!(
            "{:<10} {:<site_w$}  {}\n",
            r.mode,
            r.site,
            r.outcome.describe()
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("carbon3d-chaos-{}-{name}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn mode_names_round_trip() {
        for mode in ChaosMode::ALL {
            assert_eq!(ChaosMode::parse(mode.name()).unwrap(), mode);
        }
        assert!(ChaosMode::parse("exhaustive").is_err());
    }

    #[test]
    fn steps_share_the_grid_and_the_store() {
        let grid = vec!["--models".to_string(), "vgg16".to_string(), "--quick".to_string()];
        let store = Path::new("/tmp/x/campaign.jsonl");
        for mode in ChaosMode::ALL {
            let steps = mode.steps(&grid, store);
            let expect = if mode == ChaosMode::Sharded { 3 } else { 1 };
            assert_eq!(steps.len(), expect, "{}", mode.name());
            for step in &steps {
                assert_eq!(step[0], "campaign");
                assert!(step.contains(&"--models".to_string()), "{step:?}");
                assert!(step.contains(&"/tmp/x/campaign.jsonl".to_string()), "{step:?}");
            }
        }
        let merge = &ChaosMode::Sharded.steps(&grid, store)[2];
        assert_eq!(merge[1], "merge");
        let adaptive = &ChaosMode::Adaptive.steps(&grid, store)[0];
        assert!(adaptive.contains(&"--sampler".to_string()));
    }

    #[test]
    fn compare_flags_each_divergent_artifact() {
        let d = tmp("cmp");
        let a = d.join("a.jsonl");
        let b = d.join("b.jsonl");
        std::fs::write(&a, "row\n").unwrap();
        std::fs::write(&b, "row\n").unwrap();
        // Stores equal, no sidecars on either side: identical.
        assert_eq!(compare_artifacts(&a, &b).unwrap(), SiteOutcome::Identical);
        // A sidecar present on one side only is a divergence.
        std::fs::write(CampaignArchive::checkpoint_path(&a), "{}").unwrap();
        let SiteOutcome::Diverged(msg) = compare_artifacts(&a, &b).unwrap() else {
            panic!("one-sided sidecar must diverge");
        };
        assert!(msg.contains("front checkpoint"), "{msg}");
        // Different store bytes name the store.
        std::fs::write(CampaignArchive::checkpoint_path(&b), "{}").unwrap();
        std::fs::write(&b, "row2\n").unwrap();
        let SiteOutcome::Diverged(msg) = compare_artifacts(&a, &b).unwrap() else {
            panic!("different stores must diverge");
        };
        assert!(msg.contains("store"), "{msg}");
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn coverage_check_only_flags_sites_every_mode_skipped() {
        let reports = vec![
            SiteReport { mode: "threads", site: "lease.claim", outcome: SiteOutcome::NotHit },
            SiteReport { mode: "sharded", site: "lease.claim", outcome: SiteOutcome::Identical },
            SiteReport { mode: "threads", site: "surrogate.fit", outcome: SiteOutcome::NotHit },
            SiteReport { mode: "sharded", site: "surrogate.fit", outcome: SiteOutcome::NotHit },
        ];
        assert_eq!(uncovered_sites(&reports), vec!["surrogate.fit"]);
        // Sites with no probes at all (mode subset runs) are not flagged.
        assert!(uncovered_sites(&[]).is_empty());
        assert!(failures(&reports).is_empty());
    }
}
