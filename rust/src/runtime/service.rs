//! Accuracy-evaluation service: a threaded request loop over the evaluation
//! engine (the vLLM-router-shaped slice of L3).
//!
//! Clients submit `EvalRequest`s (multiplier id, or a raw LUT) on a channel;
//! a worker owns the evaluator and serves requests FIFO with *result
//! caching* and *request coalescing* (duplicate in-flight multiplier ids
//! collapse onto one evaluation — the GA hammers the same feasible set
//! repeatedly). The worker is generic over the evaluation backend so tests
//! run on the fast native path and production on PJRT.

use std::collections::HashMap;
use std::sync::mpsc::{self, Receiver, Sender};
use std::thread::JoinHandle;

use anyhow::Result;

use crate::accuracy::native::{ApproxDatapath, NativeEvaluator};
use crate::approx::{lut_f32, Multiplier};

/// Evaluation backend: maps a multiplier LUT to a test-set accuracy.
pub trait EvalBackend: Send + 'static {
    fn accuracy_of_lut(&self, lut: &[f32]) -> Result<f64>;
}

/// Native bit-faithful backend (no PJRT; used in tests and as fallback).
pub struct NativeBackend(pub NativeEvaluator);

impl EvalBackend for NativeBackend {
    fn accuracy_of_lut(&self, lut: &[f32]) -> Result<f64> {
        Ok(self.0.accuracy(&ApproxDatapath::from_lut(lut.to_vec())))
    }
}

/// A request to evaluate one multiplier.
pub struct EvalRequest {
    pub mult_id: usize,
    pub lut: Vec<f32>,
    pub reply: Sender<Result<f64, String>>,
}

/// Worker mailbox message. `Stop` is sent by `shutdown` so the worker exits
/// deterministically even while client handles (sender clones) are alive.
enum Msg {
    Eval(EvalRequest),
    Stop,
}

/// Handle to the running service.
pub struct EvalService {
    tx: Sender<Msg>,
    worker: Option<JoinHandle<ServiceStats>>,
}

/// Counters the worker reports on shutdown.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ServiceStats {
    pub served: usize,
    pub evaluated: usize,
    pub cache_hits: usize,
    pub coalesced: usize,
}

impl EvalService {
    /// Spawn the worker thread over a backend.
    pub fn start<B: EvalBackend>(backend: B) -> Self {
        let (tx, rx) = mpsc::channel::<Msg>();
        let worker = std::thread::spawn(move || worker_loop(backend, rx));
        Self { tx, worker: Some(worker) }
    }

    /// Client handle for submitting requests.
    pub fn client(&self) -> EvalClient {
        EvalClient { tx: self.tx.clone() }
    }

    /// Shut down (poison message + join) and return stats. Outstanding
    /// queued requests ahead of the Stop are still served; later submits
    /// from surviving client clones get a "service stopped" error.
    pub fn shutdown(mut self) -> ServiceStats {
        let _ = self.tx.send(Msg::Stop);
        self.worker
            .take()
            .expect("shutdown called once")
            .join()
            .expect("worker panicked")
    }
}

/// Cloneable client handle.
#[derive(Clone)]
pub struct EvalClient {
    tx: Sender<Msg>,
}

impl EvalClient {
    /// Blocking evaluation of one multiplier.
    pub fn eval(&self, m: &Multiplier) -> Result<f64, String> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Msg::Eval(EvalRequest { mult_id: m.id, lut: lut_f32(m), reply }))
            .map_err(|_| "service stopped".to_string())?;
        rx.recv().map_err(|_| "service dropped request".to_string())?
    }

    /// Fire-and-collect: submit all multipliers, then gather accuracies in
    /// submission order. Coalescing in the worker dedupes repeats.
    pub fn eval_all(&self, mults: &[&Multiplier]) -> Result<Vec<f64>, String> {
        let mut replies = Vec::with_capacity(mults.len());
        for m in mults {
            let (reply, rx) = mpsc::channel();
            self.tx
                .send(Msg::Eval(EvalRequest { mult_id: m.id, lut: lut_f32(m), reply }))
                .map_err(|_| "service stopped".to_string())?;
            replies.push(rx);
        }
        replies
            .into_iter()
            .map(|rx| rx.recv().map_err(|_| "service dropped request".to_string())?)
            .collect()
    }
}

fn worker_loop<B: EvalBackend>(backend: B, rx: Receiver<Msg>) -> ServiceStats {
    let mut stats = ServiceStats::default();
    let mut cache: HashMap<usize, f64> = HashMap::new();
    // Drain-and-batch: pull everything queued, coalesce by mult_id, then
    // evaluate unique ids once and fan results back out.
    'outer: while let Ok(first) = rx.recv() {
        let first = match first {
            Msg::Stop => break 'outer,
            Msg::Eval(r) => r,
        };
        let mut batch: Vec<EvalRequest> = vec![first];
        let mut stop_after = false;
        while let Ok(more) = rx.try_recv() {
            match more {
                Msg::Stop => {
                    stop_after = true;
                    break;
                }
                Msg::Eval(r) => batch.push(r),
            }
        }
        // Group replies by multiplier id.
        let mut groups: HashMap<usize, Vec<EvalRequest>> = HashMap::new();
        for req in batch {
            groups.entry(req.mult_id).or_default().push(req);
        }
        let mut ids: Vec<usize> = groups.keys().copied().collect();
        ids.sort_unstable(); // deterministic service order
        for id in ids {
            let reqs = groups.remove(&id).unwrap();
            stats.served += reqs.len();
            stats.coalesced += reqs.len() - 1;
            let acc = if let Some(&hit) = cache.get(&id) {
                stats.cache_hits += reqs.len();
                Ok(hit)
            } else {
                stats.evaluated += 1;
                match backend.accuracy_of_lut(&reqs[0].lut) {
                    Ok(a) => {
                        cache.insert(id, a);
                        Ok(a)
                    }
                    Err(e) => Err(format!("{e:#}")),
                }
            };
            for req in reqs {
                let _ = req.reply.send(acc.clone());
            }
        }
        if stop_after {
            break 'outer;
        }
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    /// Counting stub backend: accuracy = f(lut[255*255 entry]) so results
    /// are checkable and differ across designs (the (128,128) entry is the
    /// same for most families — no low bits to approximate).
    struct Stub(Arc<AtomicUsize>);

    impl EvalBackend for Stub {
        fn accuracy_of_lut(&self, lut: &[f32]) -> Result<f64> {
            self.0.fetch_add(1, Ordering::SeqCst);
            Ok(f64::from(lut[127 * 128 + 127]) / 100_000.0)
        }
    }

    fn mults() -> Vec<crate::approx::Multiplier> {
        crate::approx::library()
    }

    #[test]
    fn serves_and_caches() {
        let count = Arc::new(AtomicUsize::new(0));
        let svc = EvalService::start(Stub(count.clone()));
        let client = svc.client();
        let lib = mults();
        let a1 = client.eval(&lib[0]).unwrap();
        let a2 = client.eval(&lib[0]).unwrap(); // cached
        let a3 = client.eval(&lib[5]).unwrap();
        assert_eq!(a1, a2);
        assert_ne!(a1, a3);
        let stats = svc.shutdown();
        assert_eq!(stats.served, 3);
        assert_eq!(stats.evaluated, 2);
        assert_eq!(stats.cache_hits, 1);
        assert_eq!(count.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn eval_all_returns_in_submission_order() {
        let svc = EvalService::start(Stub(Arc::new(AtomicUsize::new(0))));
        let client = svc.client();
        let lib = mults();
        let sel: Vec<&crate::approx::Multiplier> = vec![&lib[3], &lib[1], &lib[3], &lib[7]];
        let out = client.eval_all(&sel).unwrap();
        assert_eq!(out.len(), 4);
        assert_eq!(out[0], out[2]); // same multiplier, same answer
        let stats = svc.shutdown();
        assert_eq!(stats.served, 4);
        // The duplicate either coalesced in-batch or hit the cache; both
        // save one evaluation.
        assert_eq!(stats.evaluated, 3);
        assert_eq!(stats.coalesced + stats.cache_hits, 1);
    }

    #[test]
    fn concurrent_clients_all_get_answers() {
        let svc = EvalService::start(Stub(Arc::new(AtomicUsize::new(0))));
        let lib = Arc::new(mults());
        let mut handles = Vec::new();
        for t in 0..4 {
            let client = svc.client();
            let lib = lib.clone();
            handles.push(std::thread::spawn(move || {
                (0..8).map(|i| client.eval(&lib[(t * 3 + i) % lib.len()]).unwrap()).collect::<Vec<_>>()
            }));
        }
        for h in handles {
            let results = h.join().unwrap();
            assert_eq!(results.len(), 8);
        }
        let stats = svc.shutdown();
        assert_eq!(stats.served, 32);
        // At most one evaluation per distinct multiplier id.
        assert!(stats.evaluated <= 32 - stats.cache_hits - stats.coalesced);
    }

    #[test]
    fn shutdown_returns_stats_once() {
        let svc = EvalService::start(Stub(Arc::new(AtomicUsize::new(0))));
        let stats = svc.shutdown();
        assert_eq!(stats, ServiceStats::default());
    }

    #[test]
    fn native_backend_end_to_end_if_artifacts_exist() {
        if !std::path::Path::new("artifacts/manifest.json").exists() {
            eprintln!("SKIP: artifacts not built");
            return;
        }
        let artifacts = crate::runtime::Artifacts::load(std::path::Path::new("artifacts")).unwrap();
        let native = NativeEvaluator::load(&artifacts).unwrap();
        let exact_expected = artifacts.exact_test_accuracy;
        let svc = EvalService::start(NativeBackend(native));
        let client = svc.client();
        let lib = mults();
        let acc = client.eval(&lib[crate::approx::EXACT_ID]).unwrap();
        assert!((acc - exact_expected).abs() < 1e-9);
        svc.shutdown();
    }
}
