//! Aligned text tables + CSV emission for experiment reports.

/// A simple column-aligned text table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Self { header: header.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render as an aligned text table.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        let line = |cells: &[String], out: &mut String| {
            for (i, cell) in cells.iter().enumerate() {
                out.push_str(&format!("{:<w$}", cell, w = widths[i]));
                if i + 1 < ncol {
                    out.push_str("  ");
                }
            }
            out.push('\n');
        };
        line(&self.header, &mut out);
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncol - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            line(row, &mut out);
        }
        out
    }

    /// Render as CSV (naive quoting: fields with commas/quotes are quoted).
    pub fn to_csv(&self) -> String {
        let esc = |s: &String| {
            if s.contains(',') || s.contains('"') || s.contains('\n') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.clone()
            }
        };
        let mut out = String::new();
        out.push_str(&self.header.iter().map(esc).collect::<Vec<_>>().join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(esc).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Format a float with engineering-friendly precision.
pub fn fmt(x: f64) -> String {
    if x == 0.0 {
        "0".to_string()
    } else if x.abs() >= 1000.0 {
        format!("{x:.0}")
    } else if x.abs() >= 10.0 {
        format!("{x:.2}")
    } else if x.abs() >= 0.01 {
        format!("{x:.4}")
    } else {
        format!("{x:.3e}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(vec!["name", "val"]);
        t.row(vec!["a", "1"]).row(vec!["longer", "22"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("name"));
        assert!(lines[3].starts_with("longer"));
    }

    #[test]
    #[should_panic]
    fn wrong_width_panics() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["only-one"]);
    }

    #[test]
    fn csv_quotes_commas() {
        let mut t = Table::new(vec!["k", "v"]);
        t.row(vec!["x,y", "a\"b"]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"a\"\"b\""));
    }

    #[test]
    fn fmt_ranges() {
        assert_eq!(fmt(0.0), "0");
        assert_eq!(fmt(1234.4), "1234");
        assert_eq!(fmt(12.345), "12.35");
        assert_eq!(fmt(0.5), "0.5000");
        assert!(fmt(1e-5).contains('e'));
    }
}
