"""L1 correctness: Pallas kernel vs pure-jnp oracle (the CORE signal).

hypothesis sweeps shapes/dtypes/LUTs; numpy oracle checks are bit-level.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import approx_matmul as am
from compile.kernels import ref

RNG = np.random.default_rng(1234)


def rand(m, n, scale=1.0, rng=RNG):
    return (rng.normal(size=(m, n)) * scale).astype(np.float32)


# ---------------------------------------------------------------- bf16 round
def test_bf16_round_matches_numpy_cast():
    x = rand(64, 64, scale=10.0)
    ours = np.asarray(ref.bf16_round(jnp.asarray(x)))
    want = x.astype(jnp.bfloat16).astype(np.float32)
    np.testing.assert_array_equal(ours, want)


def test_bf16_round_is_idempotent():
    x = rand(32, 32)
    once = ref.bf16_round(jnp.asarray(x))
    np.testing.assert_array_equal(np.asarray(once), np.asarray(ref.bf16_round(once)))


@given(st.floats(min_value=-1.0000000150474662e30, max_value=1.0000000150474662e30,
                 allow_nan=False, width=32))
@settings(max_examples=200, deadline=None)
def test_bf16_round_scalar_property(v):
    got = float(np.asarray(ref.bf16_round(jnp.float32(v))))
    want = float(np.float32(v).astype(jnp.bfloat16).astype(np.float32))
    assert got == want or (np.isinf(got) and np.isinf(want))


def test_decompose_roundtrip():
    x = rand(16, 16, scale=3.0)
    s, e, m = ref.decompose(jnp.asarray(x))
    s, e, m = np.asarray(s), np.asarray(e), np.asarray(m)
    sig = (128 + m).astype(np.float64)
    recon = s * sig * np.exp2(e.astype(np.float64) - 134.0)
    recon[e == 0] = 0.0
    want = np.asarray(ref.bf16_round(jnp.asarray(x)), dtype=np.float64)
    np.testing.assert_allclose(recon, want, rtol=0, atol=0)


# ---------------------------------------------------------------- LUT builders
def test_exact_lut_values():
    lut = ref.exact_lut()
    assert lut.shape == (128, 128)
    assert lut[0, 0] == 128 * 128
    assert lut[127, 127] == 255 * 255
    assert lut[5, 9] == 133 * 137


def test_truncated_lut_is_lower_bound_of_exact():
    ex = ref.exact_lut()
    for k in (1, 2, 3, 4, 5):
        tl = ref.truncated_lut(k)
        assert np.all(tl <= ex)
        assert np.all(tl >= 0)


def test_perforated_lut_is_lower_bound_of_exact():
    ex = ref.exact_lut()
    for p in (1, 3, 5, 7):
        pf = ref.perforated_lut(p)
        assert np.all(pf <= ex)


def test_truncated_lut0_is_exact():
    np.testing.assert_array_equal(ref.truncated_lut(0), ref.exact_lut())


# ------------------------------------------------------- oracle-level checks
def test_exact_lut_oracle_equals_bf16_matmul():
    a, b = rand(24, 40), rand(40, 16)
    got = ref.approx_matmul_ref(jnp.asarray(a), jnp.asarray(b), jnp.asarray(ref.exact_lut()))
    want = ref.exact_matmul_ref(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)


def test_elementwise_exact_lut_is_bitexact():
    """Single products (no accumulation) must match bf16*bf16 exactly."""
    a, b = rand(64, 64, 5.0), rand(64, 64, 5.0)
    got = ref.approx_mul_elementwise(jnp.asarray(a), jnp.asarray(b), jnp.asarray(ref.exact_lut()))
    abf = a.astype(jnp.bfloat16).astype(np.float32)
    bbf = b.astype(jnp.bfloat16).astype(np.float32)
    np.testing.assert_array_equal(np.asarray(got), abf * bbf)


def test_zero_inputs_flush_to_zero():
    a = np.zeros((8, 8), np.float32)
    b = rand(8, 8)
    got = ref.approx_matmul_ref(jnp.asarray(a), jnp.asarray(b), jnp.asarray(ref.exact_lut()))
    np.testing.assert_array_equal(np.asarray(got), np.zeros((8, 8), np.float32))


def test_denormals_flush_to_zero():
    a = np.full((4, 4), 1e-40, np.float32)  # denormal in f32 and bf16
    b = rand(4, 4)
    got = ref.approx_mul_elementwise(jnp.asarray(a), jnp.asarray(b), jnp.asarray(ref.exact_lut()))
    np.testing.assert_array_equal(np.asarray(got), np.zeros((4, 4), np.float32))


def test_negative_signs():
    a, b = -rand(8, 8, 2.0), rand(8, 8, 2.0)
    got = ref.approx_mul_elementwise(jnp.asarray(np.abs(a) * -1), jnp.asarray(b), jnp.asarray(ref.exact_lut()))
    assert np.all(np.sign(np.asarray(got)) == -np.sign(np.abs(a.astype(jnp.bfloat16).astype(np.float32)) * b.astype(jnp.bfloat16).astype(np.float32)).clip(-1, 1) * -1) or True
    # stronger: matches elementwise bf16 product
    abf = (np.abs(a) * -1).astype(jnp.bfloat16).astype(np.float32)
    bbf = b.astype(jnp.bfloat16).astype(np.float32)
    np.testing.assert_array_equal(np.asarray(got), abf * bbf)


# ------------------------------------------------------- kernel vs oracle
@pytest.mark.parametrize("m,k,n", [(32, 32, 32), (64, 32, 32), (32, 64, 96), (96, 96, 64)])
@pytest.mark.parametrize("lut_fn", [ref.exact_lut, lambda: ref.truncated_lut(3), lambda: ref.perforated_lut(5)])
def test_kernel_matches_oracle_divisible(m, k, n, lut_fn):
    a, b, lut = rand(m, k), rand(k, n), lut_fn()
    got = am.approx_matmul(jnp.asarray(a), jnp.asarray(b), jnp.asarray(lut))
    want = ref.approx_matmul_ref(jnp.asarray(a), jnp.asarray(b), jnp.asarray(lut))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@given(
    m=st.integers(1, 70),
    k=st.integers(1, 70),
    n=st.integers(1, 70),
    scale=st.sampled_from([0.1, 1.0, 30.0]),
    kind=st.sampled_from(["exact", "trunc2", "trunc4", "perf3", "perf6"]),
)
@settings(max_examples=25, deadline=None)
def test_kernel_padded_matches_oracle_any_shape(m, k, n, scale, kind):
    rng = np.random.default_rng(m * 10007 + k * 101 + n)
    a = (rng.normal(size=(m, k)) * scale).astype(np.float32)
    b = (rng.normal(size=(k, n)) * scale).astype(np.float32)
    lut = {
        "exact": ref.exact_lut,
        "trunc2": lambda: ref.truncated_lut(2),
        "trunc4": lambda: ref.truncated_lut(4),
        "perf3": lambda: ref.perforated_lut(3),
        "perf6": lambda: ref.perforated_lut(6),
    }[kind]()
    got = am.approx_matmul_padded(
        jnp.asarray(a), jnp.asarray(b), jnp.asarray(lut), block_m=16, block_n=16, block_k=16
    )
    want = ref.approx_matmul_ref(jnp.asarray(a), jnp.asarray(b), jnp.asarray(lut))
    # Kernel and oracle sum over K in different block orders; with
    # cancelling terms the difference is bounded by ulps of the *summand*
    # magnitude (~scale^2 per product, k products), not of the result.
    atol = 3e-6 * scale * scale * k
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=atol)


@pytest.mark.parametrize("bm,bn,bk", [(8, 8, 8), (16, 32, 8), (32, 16, 64), (64, 64, 64)])
def test_kernel_block_shape_invariance(bm, bn, bk):
    """Result must not depend on the tiling (up to f32 summation order)."""
    a, b = rand(64, 64), rand(64, 64)
    lut = ref.truncated_lut(2)
    got = am.approx_matmul(jnp.asarray(a), jnp.asarray(b), jnp.asarray(lut), block_m=bm, block_n=bn, block_k=bk)
    want = ref.approx_matmul_ref(jnp.asarray(a), jnp.asarray(b), jnp.asarray(lut))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_kernel_exact_lut_vs_f32_matmul_close():
    """bf16 quantization error only — sanity on overall numerics."""
    a, b = rand(64, 64), rand(64, 64)
    got = am.approx_matmul(jnp.asarray(a), jnp.asarray(b), jnp.asarray(ref.exact_lut()))
    want = a @ b
    err = np.abs(np.asarray(got) - want).max() / (np.abs(want).max() + 1e-9)
    assert err < 0.05


def test_kernel_rejects_bad_shapes():
    a = jnp.zeros((33, 32))
    b = jnp.zeros((32, 32))
    with pytest.raises(AssertionError):
        am.approx_matmul(a, b, jnp.asarray(ref.exact_lut()))


def test_pad_to_roundtrip():
    x = jnp.asarray(rand(10, 13))
    p = am.pad_to(x, 16, 16)
    assert p.shape == (16, 16)
    np.testing.assert_array_equal(np.asarray(p[:10, :13]), np.asarray(x))
    np.testing.assert_array_equal(np.asarray(p[10:, :]), 0)
