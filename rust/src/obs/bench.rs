//! Bench timing harness (criterion is unavailable offline).
//!
//! `bench(name, warmup, iters, f)` runs a warmup, then `iters` timed
//! invocations and summarizes mean/p50/p95 — the shared harness for
//! everything in rust/benches/. Lives in `obs` so the bench path shares
//! one timing/formatting stack with the tracer (see [`super::fmt`]).

use std::time::Instant;

use super::fmt::human_time;
use crate::util::stats::Summary;

/// Result of a timed benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Label printed in front of the timing columns.
    pub name: String,
    /// Number of timed (post-warmup) iterations behind `summary`.
    pub iters: usize,
    /// Per-iteration wall time in seconds.
    pub summary: Summary,
}

impl BenchResult {
    /// One aligned human-readable result line (mean / p50 / p95 / iters).
    pub fn line(&self) -> String {
        format!(
            "{:<44} {:>10}/iter  p50 {:>10}  p95 {:>10}  ({} iters)",
            self.name,
            human_time(self.summary.mean),
            human_time(self.summary.p50),
            human_time(self.summary.p95),
            self.iters
        )
    }
}

/// Time `f` for `iters` iterations after `warmup` untimed ones.
/// Returns per-iteration statistics. `f`'s return value is black-boxed.
pub fn bench<T, F: FnMut() -> T>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    assert!(iters > 0);
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
    }
    BenchResult { name: name.to_string(), iters, summary: Summary::of(&samples) }
}

/// Time a single invocation (for long end-to-end pipelines).
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_iterations() {
        let counter = std::cell::Cell::new(0usize);
        let r = bench("count", 2, 5, || counter.set(counter.get() + 1));
        assert_eq!(counter.get(), 7); // 2 warmup + 5 timed
        assert_eq!(r.iters, 5);
        assert!(r.summary.mean >= 0.0);
    }

    #[test]
    fn time_once_returns_value() {
        let (v, dt) = time_once(|| 40 + 2);
        assert_eq!(v, 42);
        assert!(dt >= 0.0);
    }
}
