//! **Executor** — the layer that decides *who evaluates jobs*. The
//! [`JobSource`](super::source::JobSource) fixes what runs and in which
//! order, the [`CommitPipeline`](super::commit::CommitPipeline) fixes how
//! results land; an `Executor` only moves jobs between the two:
//!
//! - [`threads::ThreadPoolExecutor`] — the classic in-process pool: N
//!   std-threads drain the schedule, results reorder through the pipeline.
//! - [`sharded::ShardedExecutor`] — one of N cooperating processes: walks
//!   the schedule sequentially, claims jobs through a file-based lease
//!   protocol, commits to a per-shard store.
//! - [`sharded::MergeExecutor`] — resolves jobs from already-written shard
//!   stores instead of running the GA; folding shard stores through the
//!   same pipeline is what makes the merged store byte-identical to a
//!   single-process run.
//!
//! Every executor shares ONE [`EvalService`] per process, so the
//! multiplier-accuracy cache stays campaign-global: after the first job
//! primes the cache, every later job's accuracy table is pure cache hits.

pub mod adaptive;
pub mod sharded;
pub mod threads;

use std::path::Path;
use std::time::Instant;

use anyhow::{ensure, Result};

use crate::accuracy::model::{
    drop_pct_from_error, feasible_multipliers, predicted_drop_pct, DEFAULT_K, MEAN_SIG_PRODUCT,
};
use crate::accuracy::native::NativeEvaluator;
use crate::coordinator::ga_appx_with_feasible_objective_shared;
use crate::dataflow::cache::CacheCounts;
use crate::dataflow::workloads::{workload, Workload};
use crate::ga::GaParams;
use crate::obs::{Merge, MetricsSnapshot};
use crate::runtime::{Artifacts, EvalBackend, EvalClient, EvalService, NativeBackend, ServiceStats};
use crate::util::json::{obj, Json};
use crate::obs::fmt::human_time;

use super::commit::{CommitPipeline, FrontCell, PruneMode};
use super::source::{JobCtx, JobSource};
use super::spec::{integration_name, CampaignSpec, JobSpec, SamplerMode};
use super::store::ResultStore;
use super::surrogate::{prune_rule, CostSurrogate};

pub use adaptive::AdaptiveExecutor;
pub use threads::ThreadPoolExecutor;

/// Who evaluates the scheduled jobs. Implementations read the schedule
/// from the source (in any order, with any concurrency) and must `offer`
/// exactly one [`super::commit::JobOutcome`] per scheduled job.
pub trait Executor {
    /// Short human description for the campaign banner.
    fn describe(&self) -> String;

    /// Which prune rules this executor's runs may apply, before the spec's
    /// `prune` gate collapses them to [`PruneMode::Off`]. Single-process
    /// runs and the merge use the full rule set; shard processes restrict
    /// themselves to [`PruneMode::FloorOnly`] — see its docs for why.
    fn prune_mode(&self) -> PruneMode {
        PruneMode::Full
    }

    /// Lane label for this run's status snapshot (`<store>.status.json`):
    /// `None` for single-process runs, the shard label for shard workers,
    /// `"merge"` for the merge pass. Purely observational — never feeds
    /// back into scheduling or commits.
    fn status_shard(&self) -> Option<String> {
        None
    }

    /// Which sampler this executor implements. Checked against the spec by
    /// [`run_campaign_with`] so an adaptive spec can never silently drain
    /// through a schedule-order executor (or vice versa) — the two produce
    /// different store byte sequences by design.
    fn sampler(&self) -> SamplerMode {
        SamplerMode::Exhaustive
    }

    /// Drain the schedule into the pipeline.
    fn drain(
        &self,
        ctx: &JobCtx,
        source: &JobSource,
        service: &EvalService,
        pipeline: &mut CommitPipeline<'_>,
    ) -> Result<()>;
}

/// Reference exact-path accuracy when no measured artifacts exist (the
/// trained tiny CNN's manifest value).
const SURROGATE_EXACT_ACC: f64 = 0.9355;

/// Accuracy backend for artifact-less environments: measures the effective
/// arithmetic error of the submitted LUT against exact significand products
/// and applies the calibrated ΔA drop model at tiny-CNN depth. Monotone in
/// the LUT's error, so feasibility ordering matches the measured path.
pub struct SurrogateBackend {
    exact_accuracy: f64,
    k: f64,
    tiny: Workload,
}

impl Default for SurrogateBackend {
    fn default() -> Self {
        Self {
            exact_accuracy: SURROGATE_EXACT_ACC,
            k: DEFAULT_K,
            tiny: workload("tinycnn").expect("tinycnn workload exists"),
        }
    }
}

impl EvalBackend for SurrogateBackend {
    fn accuracy_of_lut(&self, lut: &[f32]) -> Result<f64> {
        ensure!(lut.len() == 128 * 128, "LUT must be 128x128");
        let (mut mred, mut bias) = (0.0f64, 0.0f64);
        for i in 0..128usize {
            for j in 0..128usize {
                let exact = ((128 + i) * (128 + j)) as f64;
                let got = f64::from(lut[i * 128 + j]);
                mred += (got - exact).abs() / exact;
                bias += got - exact;
            }
        }
        let n = (128 * 128) as f64;
        let e_eff = mred / n + (bias / n).abs() / MEAN_SIG_PRODUCT;
        let drop_pct = drop_pct_from_error(e_eff, &self.tiny, self.k);
        Ok(self.exact_accuracy - drop_pct / 100.0)
    }
}

/// Start the campaign-global accuracy service: measured native evaluation
/// when artifacts are built, the surrogate error model otherwise. Returns
/// the service and the backend's name (for reporting).
pub fn start_service(artifacts_dir: &Path) -> Result<(EvalService, &'static str)> {
    if artifacts_dir.join("manifest.json").exists() {
        let artifacts = Artifacts::load(artifacts_dir)?;
        let native = NativeEvaluator::load(&artifacts)?;
        Ok((EvalService::start(NativeBackend(native)), "native"))
    } else {
        Ok((EvalService::start(SurrogateBackend::default()), "surrogate"))
    }
}

/// What a finished campaign reports.
#[derive(Debug, Clone)]
pub struct CampaignReport {
    pub jobs_total: usize,
    /// Jobs that ran and committed a row.
    pub jobs_run: usize,
    /// Jobs whose evaluation panicked and was quarantined as a `failed`
    /// row (DESIGN.md §11). Deterministic — a pure function of the
    /// committed rows — so it lives in `deterministic_json` too.
    pub jobs_failed: usize,
    /// Jobs skipped because the store already had their row (resume).
    pub jobs_skipped: usize,
    /// Jobs skipped because their optimistic bound provably cannot beat
    /// the committed front (deterministic prune; no row written).
    pub jobs_pruned: usize,
    /// The subset of `jobs_pruned` the adaptive planner pruned on the
    /// learned surrogate bound (0 for exhaustive runs).
    pub jobs_pruned_surrogate: usize,
    /// Jobs left to other shards (always 0 for single-process runs).
    pub jobs_deferred: usize,
    pub elapsed_s: f64,
    /// Eval-service counter deltas attributable to this campaign.
    pub stats: ServiceStats,
    /// Geometry-mapping-cache hits/misses across every GA evaluation
    /// (DESIGN.md §7.6). Concurrency-dependent like `stats` — racing
    /// threads can both miss one key — so it stays out of
    /// [`CampaignReport::deterministic_json`].
    pub mapping: CacheCounts,
    /// Chromosome-memo hits/misses aggregated over all jobs' GA runs.
    pub memo: CacheCounts,
    /// Process-metrics delta over the run (queue-wait and per-phase
    /// histograms feed [`CampaignReport::line`]; benches embed the whole
    /// snapshot). Timing-dependent, so excluded from `deterministic_json`.
    pub metrics: MetricsSnapshot,
}

impl CampaignReport {
    pub fn jobs_per_sec(&self) -> f64 {
        if self.elapsed_s > 0.0 {
            self.jobs_run as f64 / self.elapsed_s
        } else {
            0.0
        }
    }

    pub fn line(&self) -> String {
        let deferred = if self.jobs_deferred > 0 {
            format!(", {} on other shards", self.jobs_deferred)
        } else {
            String::new()
        };
        // Quarantined jobs are loud in the summary line: a failed row is
        // replayable (`--retry-failed`) but never silent.
        let failed = if self.jobs_failed > 0 {
            format!(", {} failed", self.jobs_failed)
        } else {
            String::new()
        };
        // Surrogate attribution inside the prune share: how many of the
        // pruned jobs the learned bound (not an analytic rule) removed.
        let surrogate = if self.jobs_pruned_surrogate > 0 {
            format!(", {} by surrogate", self.jobs_pruned_surrogate)
        } else {
            String::new()
        };
        // Adaptive-planner activity: batch re-rank count, from the
        // metrics delta (0 and silent for exhaustive runs).
        let reranks = self.metrics.counter("sampler_reranks");
        let sampler = if reranks > 0 {
            format!(" | sampler: {reranks} reranks")
        } else {
            String::new()
        };
        // Sidecar attribution: how many hits were served by entries the
        // mapcache sidecar preloaded (0 and silent when no sidecar fed
        // this run).
        let persisted = if self.mapping.persisted_hits > 0 {
            format!(", {} persisted", self.mapping.persisted_hits)
        } else {
            String::new()
        };
        format!(
            "{} jobs ({} run, {} resumed{failed}, pruned {}/{} ({:.0}%){surrogate}{deferred}) \
             in {:.2}s = {:.2} jobs/s | \
             eval service: {} served, {} evaluated, {} cache hits, {} coalesced \
             ({:.0}% hit rate) | mapping cache: {}/{} hits ({:.0}%{persisted}) | \
             GA memo: {}/{} hits ({:.0}%){sampler}",
            self.jobs_total,
            self.jobs_run,
            self.jobs_skipped,
            self.jobs_pruned,
            self.jobs_total,
            if self.jobs_total > 0 {
                self.jobs_pruned as f64 / self.jobs_total as f64 * 100.0
            } else {
                0.0
            },
            self.elapsed_s,
            self.jobs_per_sec(),
            self.stats.served,
            self.stats.evaluated,
            self.stats.cache_hits,
            self.stats.coalesced,
            self.stats.hit_rate() * 100.0,
            self.mapping.hits,
            self.mapping.lookups(),
            self.mapping.hit_rate() * 100.0,
            self.memo.hits,
            self.memo.lookups(),
            self.memo.hit_rate() * 100.0,
        ) + &self.timing_suffix()
    }

    /// Queue-wait percentiles and per-phase time shares from the metrics
    /// snapshot. Empty when the snapshot carries no timing data (e.g.
    /// hand-built reports in tests), leaving `line()` as before.
    fn timing_suffix(&self) -> String {
        let mut out = String::new();
        if let Some(h) = self.metrics.histogram("service.queue_wait") {
            out.push_str(&format!(
                " | queue wait p50 {} p95 {}",
                human_time(h.p50() / 1e6),
                human_time(h.p95() / 1e6),
            ));
        }
        // Shares are of the summed phase time, not wall-clock: phases run
        // concurrently across workers, so wall-relative shares would not
        // add up to anything readable.
        let sums: Vec<(&str, f64)> = crate::obs::status::PHASES
            .iter()
            .filter_map(|n| self.metrics.histogram(n).map(|h| (*n, h.sum as f64)))
            .collect();
        let total: f64 = sums.iter().map(|(_, s)| s).sum();
        if total > 0.0 {
            out.push_str(" | phases:");
            for (i, (name, sum)) in sums.iter().enumerate() {
                let sep = if i > 0 { "," } else { "" };
                out.push_str(&format!("{sep} {name} {:.0}%", sum / total * 100.0));
            }
        }
        out
    }

    /// The timing-free view of the report: job counters only, so an
    /// N-shard merge and a single-process run of the same grid serialize
    /// byte-identically (elapsed time and service stats legitimately
    /// differ between the two; the counters must not).
    pub fn deterministic_json(&self) -> Json {
        obj([
            ("jobs_total", Json::from(self.jobs_total)),
            ("jobs_run", Json::from(self.jobs_run)),
            ("jobs_failed", Json::from(self.jobs_failed)),
            ("jobs_skipped", Json::from(self.jobs_skipped)),
            ("jobs_pruned", Json::from(self.jobs_pruned)),
            ("jobs_deferred", Json::from(self.jobs_deferred)),
        ])
    }
}

/// Drain the campaign grid with `workers` threads — the classic
/// single-process entry point, kept as the stable public API. Dispatches
/// on the spec's sampler: exhaustive grids drain through the thread pool,
/// adaptive specs through the batch planner.
pub fn run_campaign(
    spec: &CampaignSpec,
    workers: usize,
    store: &mut ResultStore,
    service: &EvalService,
) -> Result<CampaignReport> {
    match spec.sampler {
        SamplerMode::Exhaustive => {
            run_campaign_with(spec, &ThreadPoolExecutor::new(workers), store, service)
        }
        SamplerMode::Adaptive { batch } => {
            run_campaign_with(spec, &AdaptiveExecutor::new(workers, batch), store, service)
        }
    }
}

/// Run a campaign through an explicit executor: build the deterministic
/// job source, restore the committed front, and let the executor drain the
/// schedule through the commit pipeline. Everything about the committed
/// store — including which jobs get pruned — is deterministic in the spec,
/// whatever the executor.
pub fn run_campaign_with(
    spec: &CampaignSpec,
    executor: &dyn Executor,
    store: &mut ResultStore,
    service: &EvalService,
) -> Result<CampaignReport> {
    spec.validate()?;
    ensure!(
        executor.sampler() == spec.sampler,
        "spec sampler '{}' does not match executor sampler '{}'",
        spec.sampler.name(),
        executor.sampler().name()
    );
    // Stamp (or verify) the store's sampler header before any row lands:
    // adaptive stores are self-describing, so a later resume — or a
    // `campaign merge` fed a shard store — can refuse a mode mismatch
    // instead of silently mixing byte-incompatible orderings.
    store.ensure_sampler(spec.sampler)?;
    let _campaign_span = crate::obs::span("campaign.run");
    let ctx = JobCtx::new(spec)?;
    // Warm the geometry-mapping cache from the store's sidecar before any
    // job runs. Strictly a performance hint: mappings are pure functions
    // of their geometry key, so a present, absent, or corrupt sidecar all
    // produce byte-identical stores/fronts/reports (corrupt = quiet
    // rebuild, see `mapcache`).
    let mapcache_on = super::mapcache::enabled();
    let mapcache_path = super::mapcache::mapcache_path(store.path());
    if mapcache_on {
        super::mapcache::load_into(&mapcache_path, &ctx.shares.mapping);
    }
    let before = service.stats();
    let before_metrics = MetricsSnapshot::collect();
    let t0 = Instant::now();
    let source = {
        let _span = crate::obs::span("source.build");
        JobSource::build(spec, &ctx, store, service)?
    };
    let front = FrontCell::restore(store, spec.objective.carbon_axis())?;
    let mode = executor.prune_mode().gated(spec.prune);
    // Status snapshots are pure observability: the writer is built before
    // the pipeline mutably borrows the store, and dropped errors inside
    // the pipeline never fail the campaign.
    let status = crate::obs::StatusWriter::create(store.path(), executor.status_shard());
    let mut pipeline = CommitPipeline::new(store, &front, &source, mode);
    pipeline.set_status(status);
    if mapcache_on {
        pipeline.set_mapcache(Some(super::mapcache::MapCachePersist::new(
            mapcache_path,
            ctx.shares.mapping.clone(),
        )));
    }
    executor.drain(&ctx, &source, service, &mut pipeline)?;
    let totals = pipeline.finish()?;
    Ok(CampaignReport {
        jobs_total: source.jobs_total(),
        jobs_run: totals.jobs_run,
        jobs_failed: totals.jobs_failed,
        jobs_skipped: source.jobs_skipped(),
        jobs_pruned: totals.jobs_pruned,
        jobs_pruned_surrogate: totals.jobs_pruned_surrogate,
        jobs_deferred: totals.jobs_deferred,
        elapsed_s: t0.elapsed().as_secs_f64(),
        // One shared counter-delta definition (obs::Merge) for every
        // stats type — the old hand-written `stats_delta` is gone.
        stats: service.stats().diff(&before),
        mapping: ctx.shares.mapping.counts(),
        memo: ctx.shares.memo.counts(),
        metrics: MetricsSnapshot::collect().diff(&before_metrics),
    })
}

/// Post-hoc prune diagnosis for a store (`carbon3d campaign
/// --explain-prune`): rebuild the analytic bounds, fit the surrogate on
/// every committed row — the state the adaptive planner would hold at the
/// end of the run — and report, per grid job, the analytic vs. surrogate
/// vs. tightened bound, the family incumbent, and which rule fires (or
/// why the job stands). Read-only: never mutates the store.
pub fn explain_prune(
    spec: &CampaignSpec,
    store: &ResultStore,
    service: &EvalService,
) -> Result<String> {
    spec.validate()?;
    let ctx = JobCtx::new(spec)?;
    let source = JobSource::build_with_all_bounds(spec, &ctx, store, service)?;
    let stored: std::collections::HashMap<String, f64> = store
        .rows()
        .iter()
        .filter_map(|row| {
            let key = row.get("key").ok()?.as_str().ok()?.to_string();
            let obj = row.get("obj_value").ok()?.as_f64().ok()?;
            Some((key, obj))
        })
        .collect();
    let mut surrogate = CostSurrogate::new();
    let mut incumbents: std::collections::HashMap<String, f64> =
        std::collections::HashMap::new();
    for job in source.grid() {
        if let Some(&v) = stored.get(&job.key()) {
            surrogate.observe(job, v);
            let e = incumbents.entry(job.family()).or_insert(v);
            if v < *e {
                *e = v;
            }
        }
    }
    surrogate.fit();
    let opt = |v: Option<f64>| match v {
        Some(v) => format!("{v:.6}"),
        None => "-".to_string(),
    };
    let mut out = format!(
        "{} grid jobs, {} committed rows, surrogate: {} points, margin {}\n",
        source.grid().len(),
        stored.len(),
        surrogate.len(),
        opt(surrogate.margin()),
    );
    for job in source.grid() {
        let key = job.key();
        let bound = source.bound(job.id).expect("every grid job has a bound");
        let lo = surrogate.lower_estimate(job);
        let tight = surrogate.tightened_lb(job, bound.objective_lb);
        let inc = incumbents.get(&job.family()).copied();
        let verdict = if stored.contains_key(&key) {
            "committed".to_string()
        } else {
            match prune_rule(job, bound, inc, &surrogate) {
                Some(rule) => format!("pruned: {}", rule.name()),
                None => "runnable".to_string(),
            }
        };
        out.push_str(&format!(
            "{key}: analytic {:.6} | surrogate {} | tightened {tight:.6} | \
             incumbent {} | {verdict}\n",
            bound.objective_lb,
            opt(lo),
            opt(inc),
        ));
    }
    Ok(out)
}

/// Execute one scenario: measured/surrogate accuracy table through the
/// shared service, δ-feasible set, objective-aware GA run, result row.
/// Shared by every executor — a row is a pure function of the job spec,
/// which is what makes shard stores mergeable byte-identically.
pub(crate) fn run_job(job: &JobSpec, ctx: &JobCtx, client: &EvalClient) -> Result<Json> {
    // Per-job phase span: attributes every nested span (ga.run,
    // mapper.search, ...) on this thread to the job key.
    let _job_scope = crate::obs::job_scope(&job.key());
    let _span = crate::obs::span("job.eval");
    super::fault::point("job.eval")?;
    let w = ctx.workload(&job.model)?;

    // Calibrated K through the campaign-global service, memoized once per
    // process in the job context (`JobCtx::k`): the value is a pure
    // function of the library and the accuracy backend, so the bound
    // pre-pass and every job agree by construction — without per-job
    // service round-trips or LUT rebuilds.
    let k = ctx.k(client)?;
    let feasible = feasible_multipliers(&ctx.lib, w, job.delta_pct, k);
    ensure!(!feasible.is_empty(), "no multiplier satisfies δ={}%", job.delta_pct);
    let n_feasible = feasible.len();

    let params = GaParams { seed: job.seed, ..ctx.ga };
    let r = ga_appx_with_feasible_objective_shared(
        w,
        job.node,
        job.integration,
        &ctx.lib,
        feasible,
        job.fps_floor,
        ctx.objective,
        params,
        &ctx.shares,
    );

    let best = &r.best;
    let e = &r.best_eval;
    let mult = &ctx.lib[best.mult_id];
    Ok(obj([
        ("key", Json::from(job.key())),
        ("model", Json::from(job.model.clone())),
        ("node", Json::from(job.node.name())),
        ("integration", Json::from(integration_name(job.integration))),
        ("delta_pct", Json::from(job.delta_pct)),
        (
            "fps_floor",
            match job.fps_floor {
                Some(f) => Json::from(f),
                None => Json::Null,
            },
        ),
        ("objective", Json::from(job.objective.name())),
        ("seed", Json::from(format!("{:#018x}", job.seed))),
        ("px", Json::from(best.px)),
        ("py", Json::from(best.py)),
        ("rf_bytes", Json::from(best.rf_bytes)),
        ("sram_bytes", Json::from(best.sram_bytes)),
        ("mult_id", Json::from(best.mult_id)),
        ("mult", Json::from(mult.name())),
        ("carbon_g", Json::from(e.carbon_g)),
        ("delay_s", Json::from(e.delay_s)),
        ("fps", Json::from(e.fps)),
        ("cdp", Json::from(e.cdp)),
        ("energy_per_inf_j", Json::from(e.energy_per_inference_j)),
        ("op_gco2", Json::from(e.operational_gco2)),
        ("lifetime_gco2", Json::from(e.lifetime_gco2)),
        ("lifetime_cdp", Json::from(e.lifetime_cdp)),
        ("obj_value", Json::from(ctx.objective.value(e))),
        ("carbon_per_mm2", Json::from(e.carbon_per_mm2)),
        ("silicon_mm2", Json::from(e.silicon_mm2)),
        ("feasible", Json::from(e.feasible)),
        ("drop_pct", Json::from(predicted_drop_pct(mult, w, k))),
        ("k", Json::from(k)),
        ("n_feasible", Json::from(n_feasible)),
        ("evaluations", Json::from(r.evaluations)),
        ("generations", Json::from(r.generations_run)),
    ]))
}

/// Context string for a failed job, shared by the executors.
pub(crate) fn job_context(job: &JobSpec) -> String {
    format!("job {}", job.key())
}

/// The quarantine row for a job whose evaluation panicked: the job's
/// identity (key, scenario axes, seed — enough for `campaign merge` to
/// verify provenance and for `--retry-failed` to replay it) plus the
/// panic message, flagged `"failed": true` so every archive build path
/// skips it (DESIGN.md §11).
pub(crate) fn failed_row(job: &JobSpec, error: &str) -> Json {
    obj([
        ("key", Json::from(job.key())),
        ("model", Json::from(job.model.clone())),
        ("node", Json::from(job.node.name())),
        ("integration", Json::from(integration_name(job.integration))),
        ("delta_pct", Json::from(job.delta_pct)),
        ("objective", Json::from(job.objective.name())),
        ("seed", Json::from(format!("{:#018x}", job.seed))),
        (super::store::FAILED_FIELD, Json::from(true)),
        ("error", Json::from(error)),
    ])
}

/// [`run_job`] with panic quarantine: a panicking evaluation is caught,
/// reported loudly (`job.quarantined`), and converted into a
/// [`failed_row`] instead of unwinding into the executor — one poison
/// job must never kill a campaign or strand its shard peers. Genuine
/// `Err` results still propagate; they describe infrastructure
/// problems, not job-local poison.
pub(crate) fn run_job_quarantined(
    job: &JobSpec,
    ctx: &JobCtx,
    client: &EvalClient,
) -> Result<Json> {
    match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| run_job(job, ctx, client))) {
        Ok(result) => result,
        Err(payload) => {
            let msg = super::fault::panic_message(payload.as_ref());
            crate::obs::warn_event(
                "job.quarantined",
                &format!("job {}: evaluation panicked — quarantined: {msg}", job.key()),
                &[
                    ("job", Json::from(job.key())),
                    ("error", Json::from(msg.as_str())),
                ],
            );
            Ok(failed_row(job, &msg))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::{library, lut_f32, EXACT_ID};

    #[test]
    fn surrogate_exact_lut_has_zero_drop() {
        let lib = library();
        let b = SurrogateBackend::default();
        let acc = b.accuracy_of_lut(&lut_f32(&lib[EXACT_ID])).unwrap();
        assert!((acc - SURROGATE_EXACT_ACC).abs() < 1e-12);
    }

    #[test]
    fn surrogate_orders_designs_by_error() {
        let lib = library();
        let b = SurrogateBackend::default();
        // A mild truncation should keep more accuracy than an aggressive one.
        let mild = lib.iter().find(|m| m.name() == "TRUNC1").unwrap();
        let harsh = lib.iter().find(|m| m.name() == "TRUNC5").unwrap();
        let a_mild = b.accuracy_of_lut(&lut_f32(mild)).unwrap();
        let a_harsh = b.accuracy_of_lut(&lut_f32(harsh)).unwrap();
        assert!(a_mild > a_harsh, "{a_mild} !> {a_harsh}");
    }

    #[test]
    fn surrogate_rejects_bad_lut() {
        assert!(SurrogateBackend::default().accuracy_of_lut(&[1.0; 7]).is_err());
    }

    #[test]
    fn report_line_mentions_throughput_hits_and_prunes() {
        let r = CampaignReport {
            jobs_total: 10,
            jobs_run: 8,
            jobs_failed: 0,
            jobs_skipped: 1,
            jobs_pruned: 1,
            jobs_pruned_surrogate: 0,
            jobs_deferred: 0,
            elapsed_s: 4.0,
            stats: ServiceStats { served: 100, evaluated: 20, cache_hits: 70, coalesced: 10 },
            mapping: CacheCounts { hits: 90, misses: 30, ..Default::default() },
            memo: CacheCounts { hits: 25, misses: 75, ..Default::default() },
            metrics: MetricsSnapshot::default(),
        };
        assert!((r.jobs_per_sec() - 2.0).abs() < 1e-12);
        let line = r.line();
        assert!(line.contains("2.00 jobs/s"), "{line}");
        assert!(line.contains("80% hit rate"), "{line}");
        // Prunes report their share of the grid, not just a bare count.
        assert!(line.contains("pruned 1/10 (10%)"), "{line}");
        assert!(!line.contains("surrogate"), "{line}");
        assert!(!line.contains("sampler"), "{line}");
        assert!(line.contains("mapping cache: 90/120 hits (75%)"), "{line}");
        assert!(!line.contains("persisted"), "{line}");
        assert!(line.contains("GA memo: 25/100 hits (25%)"), "{line}");
        assert!(!line.contains("other shards"), "{line}");
        // Shard runs additionally report the jobs other shards own.
        let sharded = CampaignReport { jobs_deferred: 5, ..r.clone() };
        assert!(sharded.line().contains("5 on other shards"), "{}", sharded.line());
        // Adaptive runs attribute surrogate prunes and re-rank activity.
        let mut snap = MetricsSnapshot::default();
        snap.counters.insert("sampler_reranks".into(), 3);
        let adaptive = CampaignReport {
            jobs_pruned: 4,
            jobs_pruned_surrogate: 3,
            metrics: snap,
            ..r.clone()
        };
        let line = adaptive.line();
        assert!(line.contains("pruned 4/10 (40%), 3 by surrogate"), "{line}");
        assert!(line.contains("sampler: 3 reranks"), "{line}");
        // Sidecar-served hits are attributed inside the mapping segment.
        let warmed = CampaignReport {
            mapping: CacheCounts { hits: 90, misses: 30, persisted_hits: 12, preloaded: 40 },
            ..r
        };
        let line = warmed.line();
        assert!(line.contains("mapping cache: 90/120 hits (75%, 12 persisted)"), "{line}");
    }

    #[test]
    fn report_line_gains_queue_wait_and_phase_shares_when_measured() {
        let mut snap = MetricsSnapshot::default();
        let hist = |v: u64| {
            let h = crate::obs::Histogram::default();
            h.record(v);
            h.counts()
        };
        snap.histograms.insert("service.queue_wait".into(), hist(100));
        snap.histograms.insert("ga.run".into(), hist(3_000_000));
        snap.histograms.insert("mapper.search".into(), hist(1_000_000));
        let r = CampaignReport {
            jobs_total: 1,
            jobs_run: 1,
            jobs_failed: 0,
            jobs_skipped: 0,
            jobs_pruned: 0,
            jobs_pruned_surrogate: 0,
            jobs_deferred: 0,
            elapsed_s: 1.0,
            stats: ServiceStats::default(),
            mapping: CacheCounts::default(),
            memo: CacheCounts::default(),
            metrics: snap,
        };
        let line = r.line();
        assert!(line.contains("queue wait p50 100.000us p95 100.000us"), "{line}");
        assert!(line.contains("phases: ga.run 75%, mapper.search 25%"), "{line}");
    }

    #[test]
    fn deterministic_json_excludes_timing_and_stats() {
        let r = CampaignReport {
            jobs_total: 4,
            jobs_run: 3,
            jobs_failed: 0,
            jobs_skipped: 0,
            jobs_pruned: 1,
            jobs_pruned_surrogate: 1,
            jobs_deferred: 0,
            elapsed_s: 123.0,
            stats: ServiceStats { served: 9, evaluated: 9, cache_hits: 0, coalesced: 0 },
            mapping: CacheCounts { hits: 7, misses: 3, ..Default::default() },
            memo: CacheCounts { hits: 2, misses: 8, ..Default::default() },
            metrics: MetricsSnapshot::default(),
        };
        let text = r.deterministic_json().dumps();
        assert!(text.contains("\"jobs_run\":3"), "{text}");
        assert!(!text.contains("elapsed"), "{text}");
        assert!(!text.contains("served"), "{text}");
        // Cache counters are concurrency-dependent, so they must stay out
        // of the byte-compared report too.
        assert!(!text.contains("mapping"), "{text}");
        assert!(!text.contains("memo"), "{text}");
        // Sampler instrumentation (surrogate prune share, re-rank count)
        // follows the same convention: line() only, never the bytes an
        // N-shard merge is compared against.
        assert!(!text.contains("surrogate"), "{text}");
        assert!(!text.contains("rerank"), "{text}");
        // Equal counters serialize equally whatever the timing or caching.
        let slower = CampaignReport {
            elapsed_s: 999.0,
            mapping: CacheCounts::default(),
            ..r
        };
        assert_eq!(text, slower.deterministic_json().dumps());
    }
}
