"""AOT pipeline: train the tiny CNN, export HLO-text artifacts + data files.

Runs ONCE at build time (`make artifacts`); Python is never on the request
path. Interchange format is HLO **text**, not a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version the published `xla` 0.1.6 crate links) rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Artifacts (all consumed by rust/src/runtime + rust/src/accuracy):
  matmul_approx.hlo.txt  (a[64,64], b[64,64], lut[128,128]) -> (c[64,64],)
  matmul_exact.hlo.txt   (a[64,64], b[64,64])               -> (c[64,64],)
  cnn_approx.hlo.txt     (images[64,16,16,1], lut[128,128]) -> (logits[64,5],)
  cnn_exact.hlo.txt      (images[64,16,16,1])               -> (logits[64,5],)
  weights.f32            trained parameters, flat f32 LE, PARAM_SPECS order
  testset_images.f32     [512,16,16,1] f32 LE
  testset_labels.u8      [512] u8
  trainset_*.f32/u8      training split (for rust-side experiments)
  manifest.json          shapes, counts, exact-path accuracy, provenance
"""

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import dataset, model
from .kernels import approx_matmul as am
from .kernels import ref

BATCH = 64
N_TRAIN = 2048
N_TEST = 512
SEED = 7


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so the rust
    side unwraps with to_tuple1)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export(fn, args, path: str) -> int:
    text = to_hlo_text(jax.jit(fn).lower(*args))
    # Guards against the two known HLO-text round-trip corruptions in the
    # xla_extension 0.5.1 parser the Rust runtime links (see DESIGN.md
    # §AOT-gotchas):
    #  1. jnp.pad lowers through a `_pad` HLO call whose routed parameters
    #     silently read as zeros -> use lax.pad (model._pad_same).
    #  2. Large array constants are elided by the printer as `{...}` and
    #     parse as garbage -> keep weights as runtime parameters.
    # The functional check is `carbon3d selfcheck`, which compares PJRT
    # accuracy against this manifest.
    assert "to_apply=_pad" not in text, (
        f"{path}: lowered HLO pads via a `call` — use model._pad_same "
        "(lax.pad) instead of jnp.pad"
    )
    assert "{..." not in text, (
        f"{path}: lowered HLO contains an elided large constant — pass big "
        "arrays as runtime parameters instead of baking them in"
    )
    with open(path, "w") as f:
        f.write(text)
    return len(text)


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts")
    p.add_argument("--steps", type=int, default=400)
    p.add_argument("--quick", action="store_true", help="fewer train steps (CI)")
    args = p.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    t0 = time.time()
    steps = 120 if args.quick else args.steps

    # ---- data + training (exact path) ------------------------------------
    train_x, train_y = dataset.generate(N_TRAIN, seed=SEED)
    test_x, test_y = dataset.generate(N_TEST, seed=SEED + 1)
    params = model.init_params(seed=SEED)
    params, hist = model.train(
        params, jnp.asarray(train_x), jnp.asarray(train_y), steps=steps, log=print
    )
    acc_exact = model.accuracy(params, jnp.asarray(test_x), jnp.asarray(test_y))
    print(f"exact-path test accuracy: {acc_exact:.4f}  (loss {hist[0]:.3f} -> {hist[-1]:.3f})")

    # sanity: the exact LUT through the approximate datapath must not move
    # accuracy (bf16 rounding only).
    lut = jnp.asarray(ref.exact_lut())
    acc_lut = model.accuracy(
        params, jnp.asarray(test_x[:128]), jnp.asarray(test_y[:128]), lut=lut
    )
    print(f"exact-LUT approximate-datapath accuracy (128 imgs): {acc_lut:.4f}")

    # ---- HLO exports ------------------------------------------------------
    f32 = jnp.float32
    spec = jax.ShapeDtypeStruct
    sizes = {}
    sizes["matmul_approx"] = export(
        lambda a, b, l: (am.approx_matmul(a, b, l),),
        (spec((64, 64), f32), spec((64, 64), f32), spec((128, 128), f32)),
        os.path.join(args.out_dir, "matmul_approx.hlo.txt"),
    )
    sizes["matmul_exact"] = export(
        lambda a, b: (ref.exact_matmul_ref(a, b),),
        (spec((64, 64), f32), spec((64, 64), f32)),
        os.path.join(args.out_dir, "matmul_exact.hlo.txt"),
    )
    # CNN artifacts take the *trained* weights as runtime parameters in
    # PARAM_SPECS order (baking them as constants trips the large-constant
    # elision in the HLO-text round-trip — see `export`); the Rust engine
    # feeds them from weights.f32. The LUT stays a runtime input so one
    # artifact serves all multipliers.
    wspecs = [spec(shape, f32) for _, shape in model.PARAM_SPECS]

    def rebuild(ws):
        return {name: w for (name, _), w in zip(model.PARAM_SPECS, ws)}

    sizes["cnn_approx"] = export(
        lambda imgs, l, *ws: (model.forward(rebuild(ws), imgs, lut=l),),
        (spec((BATCH, 16, 16, 1), f32), spec((128, 128), f32), *wspecs),
        os.path.join(args.out_dir, "cnn_approx.hlo.txt"),
    )
    sizes["cnn_exact"] = export(
        lambda imgs, *ws: (model.forward(rebuild(ws), imgs),),
        (spec((BATCH, 16, 16, 1), f32), *wspecs),
        os.path.join(args.out_dir, "cnn_exact.hlo.txt"),
    )

    # ---- binary data ------------------------------------------------------
    flat = np.concatenate(
        [np.asarray(params[name], np.float32).reshape(-1) for name, _ in model.PARAM_SPECS]
    )
    flat.astype("<f4").tofile(os.path.join(args.out_dir, "weights.f32"))
    test_x.astype("<f4").tofile(os.path.join(args.out_dir, "testset_images.f32"))
    test_y.astype(np.uint8).tofile(os.path.join(args.out_dir, "testset_labels.u8"))
    train_x.astype("<f4").tofile(os.path.join(args.out_dir, "trainset_images.f32"))
    train_y.astype(np.uint8).tofile(os.path.join(args.out_dir, "trainset_labels.u8"))

    manifest = {
        "batch": BATCH,
        "img": model.IMG,
        "num_classes": model.NUM_CLASSES,
        "n_train": N_TRAIN,
        "n_test": N_TEST,
        "seed": SEED,
        "train_steps": steps,
        "final_train_loss": hist[-1],
        "exact_test_accuracy": acc_exact,
        "exact_lut_accuracy_128": acc_lut,
        "params": [[name, list(shape)] for name, shape in model.PARAM_SPECS],
        "hlo_chars": sizes,
        "jax_version": jax.__version__,
    }
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"artifacts written to {args.out_dir} in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
