//! **CommitPipeline** — the single-writer back half of a campaign: a
//! reorder buffer that restores schedule order, the writer-authoritative
//! prune decision, the JSONL append, and the incremental Pareto archive
//! with its atomically-written sidecar checkpoint.
//!
//! Executors produce `(job id, JobOutcome)` pairs in *any* order; the
//! pipeline commits them strictly in schedule-slot order, so the committed
//! store — including which jobs get pruned — is a pure function of the
//! spec and the rows committed before each slot, never of worker timing.

use std::collections::{BTreeMap, HashMap};
use std::path::PathBuf;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Result};

use crate::util::Json;

use super::checkpoint::write_atomic;
use super::clock::Clock;
use super::fault;
use super::pareto::{CampaignArchive, CarbonAxis};
use super::source::{prune_reason, JobBound, JobSource};
use super::spec::JobSpec;
use super::store::{row_is_failed, ResultStore};

/// Which prune rules apply — the ONE predicate shared by every executor's
/// dispatch-side early-out and the pipeline's authoritative commit-slot
/// decision, so the two can never drift apart.
///
/// `FloorOnly` exists for shard processes: the FPS-floor rule is a pure
/// function of the job and its bound, so every process agrees on it — but
/// the incumbent rule is only sound against incumbents committed at
/// *earlier schedule slots*, and a **resumed** shard store is not a slot
/// prefix (skipped-lease gaps mean stored rows can sit at later slots than
/// a still-pending job). A shard that incumbent-pruned against such rows
/// could starve the merge of a row it needs; restricting shards to the
/// floor rule removes that class entirely, at the cost of occasionally
/// evaluating a job the merge will discard.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PruneMode {
    /// Floor rule + incumbent rule (single-process runs and the merge,
    /// whose commit order makes incumbent pruning sound).
    Full,
    /// Floor rule only (shard processes).
    FloorOnly,
    /// Never prune (`--no-prune`).
    Off,
}

impl PruneMode {
    /// Collapse to `Off` when the spec disables pruning.
    pub fn gated(self, enabled: bool) -> Self {
        if enabled {
            self
        } else {
            PruneMode::Off
        }
    }

    /// Does this mode prune the job? `incumbent` is consulted lazily and
    /// only under `Full` (callers pass a closure so e.g. no lock is taken
    /// when the mode ignores incumbents).
    pub fn fires(
        self,
        job: &JobSpec,
        bound: Option<&JobBound>,
        incumbent: impl FnOnce() -> Option<f64>,
    ) -> bool {
        let inc = match self {
            PruneMode::Full => incumbent(),
            PruneMode::FloorOnly | PruneMode::Off => None,
        };
        match self {
            PruneMode::Off => false,
            PruneMode::Full | PruneMode::FloorOnly => {
                bound.is_some_and(|b| prune_reason(job, b, inc).is_some())
            }
        }
    }
}

/// Committed-front state: the incremental archive plus the best committed
/// objective value per job family.
struct FrontState {
    archive: CampaignArchive,
    incumbents: HashMap<String, f64>,
}

/// Shared committed-front cell: the writer updates it at each commit, the
/// executors read it for the dispatch-side prune early-out. Lives outside
/// the pipeline so workers can hold a reference while the writer drives
/// the pipeline mutably.
pub struct FrontCell {
    inner: Mutex<FrontState>,
}

/// Lock a front mutex, tolerating poison: the lock only guards
/// in-memory archive/incumbent state that is rebuilt from the store on
/// resume, so a panicking peer (now quarantined, never fatal) must not
/// cascade into every later commit.
fn front_lock(m: &Mutex<FrontState>) -> std::sync::MutexGuard<'_, FrontState> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

impl FrontCell {
    /// Restore the archive from its sidecar checkpoint (or rebuild from
    /// the rows) and seed the per-family incumbents from the rows already
    /// committed to `store`.
    pub fn restore(store: &ResultStore, axis: CarbonAxis) -> Result<Self> {
        let ckpt_path = CampaignArchive::checkpoint_path(store.path());
        let archive = CampaignArchive::load_or_rebuild(store.rows(), axis, &ckpt_path)?;
        let mut incumbents: HashMap<String, f64> = HashMap::new();
        for row in store.rows() {
            update_incumbent(&mut incumbents, row);
        }
        Ok(Self { inner: Mutex::new(FrontState { archive, incumbents }) })
    }

    /// Best committed objective value in a job family, if any. This is the
    /// executors' dispatch-side prune input — sound as an early-out because
    /// incumbents only ever improve as rows commit, so a prune visible at
    /// dispatch still holds when the writer re-checks at commit time.
    pub fn incumbent(&self, family: &str) -> Option<f64> {
        front_lock(&self.inner).incumbents.get(family).copied()
    }

    /// Current committed Pareto-front size (for the status snapshot).
    pub fn front_size(&self) -> usize {
        front_lock(&self.inner).archive.front.len()
    }
}

/// Family + objective value of a committed row, if it carries the
/// objective-era fields (legacy rows simply never become incumbents).
fn row_incumbent(row: &Json) -> Option<(String, f64)> {
    let s = |k: &str| row.get(k).ok().and_then(|v| v.as_str().ok().map(str::to_string));
    let fam = super::spec::family_of(
        &s("model")?,
        &s("node")?,
        &s("integration")?,
        &s("objective")?,
    );
    let v = row.get("obj_value").ok()?.as_f64().ok()?;
    Some((fam, v))
}

fn update_incumbent(incumbents: &mut HashMap<String, f64>, row: &Json) {
    if let Some((fam, v)) = row_incumbent(row) {
        let e = incumbents.entry(fam).or_insert(v);
        if v < *e {
            *e = v;
        }
    }
}

/// An executor's verdict on one scheduled job.
pub enum JobOutcome {
    /// The job ran and produced this result row.
    Row(Json),
    /// The executor's dispatch-side check found the job provably hopeless.
    /// The writer re-decides authoritatively at the commit slot.
    Pruned,
    /// The adaptive planner pruned the job on the surrogate-tightened
    /// bound. Planner-authoritative — only meaningful through
    /// [`CommitPipeline::offer_decided`]; the schedule-order path treats
    /// it as [`JobOutcome::Pruned`].
    PrunedSurrogate,
    /// The job belongs to another process (sharded runs): commit nothing,
    /// just advance past its slot.
    Skipped,
}

/// What the pipeline counted by the time it finished.
#[derive(Debug, Clone, Copy)]
pub struct CommitTotals {
    /// Jobs that committed a row.
    pub jobs_run: usize,
    /// Jobs pruned with no row written (authoritative commit-slot rule,
    /// or the adaptive planner's batch decision).
    pub jobs_pruned: usize,
    /// The subset of `jobs_pruned` pruned by the learned surrogate bound
    /// rather than an analytic rule (always 0 outside adaptive runs).
    pub jobs_pruned_surrogate: usize,
    /// Jobs deferred to other shards (always 0 for single-process runs).
    pub jobs_deferred: usize,
    /// Jobs whose evaluation panicked and were quarantined as failed
    /// rows (never enter the archive; retryable via `--retry-failed`).
    pub jobs_failed: usize,
}

/// The single-writer commit pipeline. `offer` accepts outcomes in any
/// order; commits happen strictly in schedule order.
pub struct CommitPipeline<'a> {
    store: &'a mut ResultStore,
    front: &'a FrontCell,
    source: &'a JobSource,
    mode: PruneMode,
    ckpt_path: PathBuf,
    buffer: BTreeMap<usize, JobOutcome>,
    cursor: usize,
    totals: CommitTotals,
    t0: Instant,
    clock: Clock,
    last_heartbeat_ms: u64,
    heartbeat_every_ms: u64,
    status: Option<crate::obs::StatusWriter>,
    mapcache: Option<super::mapcache::MapCachePersist>,
}

/// Heartbeat cadence: `CARBON3D_HEARTBEAT_SECS` (fractional seconds; 0
/// means every commit), default 5s. Consulted while tracing is on and
/// for the status-snapshot tick (`<store>.status.json`).
fn heartbeat_interval() -> Duration {
    std::env::var("CARBON3D_HEARTBEAT_SECS")
        .ok()
        .and_then(|v| v.parse::<f64>().ok())
        .filter(|s| s.is_finite() && *s >= 0.0)
        .map(Duration::from_secs_f64)
        .unwrap_or(Duration::from_secs(5))
}

/// Whether the heartbeat cadence elapsed on `clock`; advances `last_ms`
/// to now when due. Clock-injected so cadence behavior is testable with
/// a fake clock instead of sleeps.
fn cadence_due(clock: &Clock, last_ms: &mut u64, every_ms: u64) -> bool {
    let now = clock.now_ms();
    if now.saturating_sub(*last_ms) < every_ms {
        return false;
    }
    *last_ms = now;
    true
}

impl<'a> CommitPipeline<'a> {
    pub fn new(
        store: &'a mut ResultStore,
        front: &'a FrontCell,
        source: &'a JobSource,
        mode: PruneMode,
    ) -> Self {
        let ckpt_path = CampaignArchive::checkpoint_path(store.path());
        let clock = Clock::default();
        let now_ms = clock.now_ms();
        Self {
            store,
            front,
            source,
            mode,
            ckpt_path,
            buffer: BTreeMap::new(),
            cursor: 0,
            totals: CommitTotals {
                jobs_run: 0,
                jobs_pruned: 0,
                jobs_pruned_surrogate: 0,
                jobs_deferred: 0,
                jobs_failed: 0,
            },
            t0: Instant::now(),
            clock,
            last_heartbeat_ms: now_ms,
            heartbeat_every_ms: heartbeat_interval().as_millis() as u64,
            status: None,
            mapcache: None,
        }
    }

    /// Swap the heartbeat-cadence clock (tests inject a
    /// [`crate::campaign::clock::FakeClock`] so cadence behavior is
    /// deterministic without sleeping).
    pub fn set_clock(&mut self, clock: Clock) {
        self.last_heartbeat_ms = clock.now_ms();
        self.clock = clock;
    }

    /// Attach the live status-snapshot writer (built by the executor
    /// core from the store path + the executor's shard label).
    pub fn set_status(&mut self, status: Option<crate::obs::StatusWriter>) {
        self.status = status;
    }

    /// Attach the mapping-cache persist handle: the pipeline rewrites the
    /// sidecar at the same archive-checkpoint boundary as the front
    /// sidecar (plus once at [`CommitPipeline::finish`]), so everything a
    /// crashed run learned is on disk for its resume. Pure observability:
    /// the sidecar never feeds back into this run's results.
    pub fn set_mapcache(&mut self, persist: Option<super::mapcache::MapCachePersist>) {
        self.mapcache = persist;
    }

    /// The shared front cell, borrowed for the pipeline's full lifetime —
    /// executors keep this reference while the writer drives `offer`.
    pub fn front(&self) -> &'a FrontCell {
        self.front
    }

    /// The prune mode this pipeline commits under. Executors use the same
    /// mode for their dispatch-side early-out, so dispatch and commit can
    /// never apply different rules.
    pub fn mode(&self) -> PruneMode {
        self.mode
    }

    /// Accept one job's outcome. If it completes the prefix at the commit
    /// cursor, every ready slot is committed immediately.
    pub fn offer(&mut self, job_id: usize, outcome: JobOutcome) -> Result<()> {
        self.buffer.insert(job_id, outcome);
        // Reorder-buffer occupancy right after insert: how far ahead of the
        // commit cursor the executors have run.
        crate::obs::metrics().gauge_set("commit_reorder_depth", self.buffer.len() as u64);
        let schedule = self.source.schedule();
        while self.cursor < schedule.len() {
            let Some(out) = self.buffer.remove(&schedule[self.cursor].id) else {
                break;
            };
            self.commit_slot(&schedule[self.cursor], out)?;
            self.cursor += 1;
            self.maybe_heartbeat();
        }
        Ok(())
    }

    /// The current progress snapshot — one definition feeds the trace
    /// heartbeat and the status sidecar, so they always agree.
    fn progress(&self) -> crate::obs::Heartbeat {
        crate::obs::Heartbeat {
            done: self.totals.jobs_run,
            pruned: self.totals.jobs_pruned,
            deferred: self.totals.jobs_deferred,
            committed: self.cursor,
            scheduled: self.source.schedule().len(),
            elapsed_s: self.t0.elapsed().as_secs_f64(),
        }
    }

    /// Emit a live-progress heartbeat (trace sidecar + stderr, when
    /// tracing is on) and refresh the status snapshot, if the cadence
    /// elapsed. Purely observational: never stdout or the store.
    fn maybe_heartbeat(&mut self) {
        let traced = crate::obs::enabled();
        if !traced && self.status.is_none() {
            return;
        }
        if !cadence_due(&self.clock, &mut self.last_heartbeat_ms, self.heartbeat_every_ms) {
            return;
        }
        let h = self.progress();
        if traced {
            crate::obs::heartbeat(&h);
        }
        if let Some(status) = &self.status {
            // Status write failures must never kill a campaign.
            let _ = status.write("running", &h, self.front.front_size());
        }
    }

    /// Commit the job at the current cursor slot: apply the authoritative
    /// prune rule against the rows committed at earlier slots, then append
    /// the row and checkpoint the archive. Shared-state update happens
    /// under the lock; file I/O (row append + checkpoint) outside it, so
    /// executors' dispatch-side prune reads never stall behind disk writes.
    fn commit_slot(&mut self, job: &JobSpec, out: JobOutcome) -> Result<()> {
        if matches!(out, JobOutcome::Skipped) {
            self.totals.jobs_deferred += 1;
            return Ok(());
        }
        let prune = {
            let st = front_lock(&self.front.inner);
            self.mode.fires(job, self.source.bound(job.id), || {
                st.incumbents.get(&job.family()).copied()
            })
        };
        if prune {
            self.totals.jobs_pruned += 1;
            return Ok(());
        }
        let JobOutcome::Row(row) = out else {
            bail!(
                "job {} was marked pruned by its executor but is runnable at its \
                 commit slot",
                job.key()
            );
        };
        self.commit_row(row)
    }

    /// Append one committed row: incumbent + archive update under the
    /// lock, file I/O (row append + checkpoint) outside it. Shared by the
    /// schedule-order path ([`Self::offer`]) and the planner-authoritative
    /// path ([`Self::offer_decided`]).
    fn commit_row(&mut self, row: Json) -> Result<()> {
        let _span = crate::obs::span("commit.row");
        fault::point("commit.row")?;
        let failed = row_is_failed(&row);
        let ckpt = {
            let mut st = front_lock(&self.front.inner);
            // A quarantined-failure row occupies its store slot but never
            // becomes an incumbent; the archive skips it internally while
            // keeping row indices aligned.
            if !failed {
                update_incumbent(&mut st.incumbents, &row);
            }
            st.archive.insert_row(&row)?;
            st.archive.checkpoint()
        };
        self.store.append(row)?;
        // The front checkpoint is atomic (temp + rename), so a retry
        // after a transient write failure is safe; a crash here leaves a
        // stale sidecar that the resume detects and rebuilds.
        fault::retry_io("checkpoint.write", || -> Result<()> {
            fault::point("checkpoint.write")?;
            write_atomic(&self.ckpt_path, &ckpt.dumps())
        })?;
        // The archive checkpoint is the durability boundary; keep the
        // trace sidecar, status snapshot, and mapcache sidecar no staler
        // than it.
        crate::obs::flush();
        if let Some(mc) = &mut self.mapcache {
            mc.persist_if_grown();
        }
        if failed {
            self.totals.jobs_failed += 1;
        } else {
            self.totals.jobs_run += 1;
        }
        if let Some(status) = &self.status {
            let _ = status.write(
                "running",
                &self.progress_at(self.cursor + 1),
                self.front.front_size(),
            );
        }
        Ok(())
    }

    /// Planner-authoritative ordered commit — the adaptive sampler's entry
    /// point. The single-threaded planner has already decided this job's
    /// fate at a deterministic batch boundary (against *virtual* incumbents
    /// replayed from the committed rows), so the pipeline trusts the
    /// outcome instead of re-deriving it from schedule order: surrogate
    /// decisions are not monotone the way analytic incumbent prunes are,
    /// and re-checking them here against different state would break the
    /// replay contract. Commits land in call order. A drain must use this
    /// entry point or [`Self::offer`] exclusively, never both.
    pub fn offer_decided(&mut self, job: &JobSpec, outcome: JobOutcome) -> Result<()> {
        ensure!(
            self.buffer.is_empty(),
            "offer_decided cannot interleave with buffered offer outcomes"
        );
        match outcome {
            JobOutcome::Skipped => {
                bail!("adaptive campaigns cannot defer job {}", job.key())
            }
            JobOutcome::Pruned => self.totals.jobs_pruned += 1,
            JobOutcome::PrunedSurrogate => {
                self.totals.jobs_pruned += 1;
                self.totals.jobs_pruned_surrogate += 1;
                crate::obs::metrics().incr("jobs_pruned_surrogate", 1);
            }
            JobOutcome::Row(row) => self.commit_row(row)?,
        }
        self.cursor += 1;
        self.maybe_heartbeat();
        Ok(())
    }

    /// Rows already committed to the store (the resume prefix), exposed so
    /// the adaptive planner can replay them through its virtual state
    /// without re-offering them.
    pub fn stored_rows(&self) -> &[Json] {
        self.store.rows()
    }

    /// [`Self::progress`] with an explicit committed count — `commit_slot`
    /// runs before `offer` advances the cursor past the slot.
    fn progress_at(&self, committed: usize) -> crate::obs::Heartbeat {
        crate::obs::Heartbeat { committed, ..self.progress() }
    }

    /// Verify every scheduled slot was committed and return the counters.
    pub fn finish(mut self) -> Result<CommitTotals> {
        ensure!(
            self.cursor == self.source.schedule().len(),
            "campaign incomplete: committed {} of {} scheduled jobs",
            self.cursor,
            self.source.schedule().len()
        );
        if let Some(mc) = &mut self.mapcache {
            mc.persist_if_grown();
        }
        if let Some(status) = &self.status {
            let _ = status.write("done", &self.progress(), self.front.front_size());
        }
        Ok(self.totals)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::area::die::Integration;
    use crate::area::TechNode;
    use crate::campaign::spec::CampaignObjective;
    use crate::util::json::obj;

    fn job(fps_floor: Option<f64>) -> JobSpec {
        JobSpec {
            id: 0,
            model: "vgg16".to_string(),
            node: TechNode::N14,
            integration: Integration::ThreeD,
            delta_pct: 3.0,
            fps_floor,
            objective: CampaignObjective::EmbodiedCdp,
            seed: 1,
        }
    }

    #[test]
    fn prune_modes_gate_exactly_the_rules_they_claim() {
        let bound = JobBound {
            carbon_lb_g: 1.0,
            delay_lb_s: 0.5,
            energy_lb_j: 0.01,
            fps_ub: 2.0,
            objective_lb: 5.0,
        };
        // Incumbent rule: Full only — shards must not apply it (their
        // stores are not slot prefixes), the merge must.
        assert!(PruneMode::Full.fires(&job(None), Some(&bound), || Some(4.0)));
        assert!(!PruneMode::FloorOnly.fires(&job(None), Some(&bound), || Some(4.0)));
        assert!(!PruneMode::Off.fires(&job(None), Some(&bound), || Some(4.0)));
        // Floor rule: every pruning mode (it is a pure function of the job).
        assert!(PruneMode::Full.fires(&job(Some(3.0)), Some(&bound), || None));
        assert!(PruneMode::FloorOnly.fires(&job(Some(3.0)), Some(&bound), || None));
        assert!(!PruneMode::Off.fires(&job(Some(3.0)), Some(&bound), || None));
        // Non-incumbent modes never even consult the incumbent closure.
        assert!(!PruneMode::FloorOnly.fires(&job(None), Some(&bound), || unreachable!()));
        assert!(!PruneMode::Off.fires(&job(Some(3.0)), Some(&bound), || unreachable!()));
        // A job without a bound is never pruned.
        assert!(!PruneMode::Full.fires(&job(Some(3.0)), None, || None));
        // The spec's prune gate collapses any mode to Off.
        assert_eq!(PruneMode::Full.gated(false), PruneMode::Off);
        assert_eq!(PruneMode::FloorOnly.gated(false), PruneMode::Off);
        assert_eq!(PruneMode::FloorOnly.gated(true), PruneMode::FloorOnly);
    }

    #[test]
    fn row_incumbent_requires_objective_fields() {
        let legacy = obj([("key", Json::from("a")), ("carbon_g", Json::from(1.0))]);
        assert!(row_incumbent(&legacy).is_none());
        let modern = obj([
            ("model", Json::from("vgg16")),
            ("node", Json::from("14nm")),
            ("integration", Json::from("3D")),
            ("objective", Json::from("embodied-cdp")),
            ("obj_value", Json::from(2.5)),
        ]);
        let (fam, v) = row_incumbent(&modern).unwrap();
        assert_eq!(fam, "vgg16@14nm/3D/embodied-cdp");
        // The row-derived family and the job-derived family share one
        // definition; pin that they agree on the same scenario.
        assert_eq!(fam, job(None).family());
        assert_eq!(v, 2.5);
    }

    #[test]
    fn update_incumbent_keeps_the_minimum() {
        let row = |v: f64| {
            obj([
                ("model", Json::from("m")),
                ("node", Json::from("7nm")),
                ("integration", Json::from("3D")),
                ("objective", Json::from("embodied-cdp")),
                ("obj_value", Json::from(v)),
            ])
        };
        let mut inc = HashMap::new();
        update_incumbent(&mut inc, &row(5.0));
        update_incumbent(&mut inc, &row(7.0));
        update_incumbent(&mut inc, &row(3.0));
        assert_eq!(inc["m@7nm/3D/embodied-cdp"], 3.0);
    }
}
