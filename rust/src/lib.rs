//! # carbon3d
//!
//! Reproduction of *"Carbon-Efficient 3D DNN Acceleration: Optimizing
//! Performance and Sustainability"* (CS.AR 2025): a carbon-aware
//! design-space-exploration framework for 3D memory-on-logic DNN
//! accelerators that swaps exact bf16 mantissa multipliers for approximate
//! ones and searches accelerator configurations minimizing the
//! Carbon-Delay-Product (CDP) under accuracy and FPS constraints.
//!
//! ## Layers
//! - **L3 (this crate)**: the DSE framework — approximate-multiplier
//!   library, area/carbon/dataflow models, genetic algorithm, baselines,
//!   experiment pipelines — plus a PJRT runtime that executes the AOT-
//!   compiled accuracy-evaluation workload.
//! - **L2/L1 (python/, build-time only)**: JAX CNN + Pallas LUT-matmul
//!   kernel, lowered once to `artifacts/*.hlo.txt`.
//! - **campaign**: the production layer on top — runs entire scenario grids
//!   ({workload} x {node} x {integration} x {δ} x {FPS floor}) through
//!   three explicit layers (JobSource / Executor / CommitPipeline) with a
//!   campaign-global accuracy cache, a resumable JSONL result store, an
//!   incremental checkpointed cross-scenario Pareto archive, selectable
//!   objectives (embodied CDP / operational / lifetime CDP) with
//!   deterministic bound-based job pruning, and sharded multi-process
//!   execution (`--shard i/N` + `campaign merge`) whose merged output is
//!   byte-identical to a single-process run. The evaluation hot path is
//!   memoized by what actually varies (DESIGN.md §7.6): a geometry-keyed
//!   mapping cache shared across the GA/islands/jobs and a table-driven
//!   bit-faithful native datapath — both bit-identical to their direct
//!   counterparts and CI-gated against perf regressions. On top of those
//!   (DESIGN.md §9): an 8-wide lane matmul kernel with a runtime-selected
//!   scalar fallback (`CARBON3D_SIMD=0`), a batched evaluator entry point
//!   over a preallocated buffer pool, and a persistent mapping-cache
//!   sidecar (`<store>.mapcache.json`) that warm-starts resumed, re-run,
//!   and merged campaigns without changing a byte of their output.
//!
//! See DESIGN.md (repo root) for the system inventory; measured-vs-paper
//! numbers are printed by `carbon3d report`.

pub mod accuracy;
pub mod approx;
pub mod area;
pub mod campaign;
pub mod carbon;
pub mod coordinator;
pub mod dataflow;
pub mod ga;
pub mod obs;
pub mod runtime;
pub mod util;

pub use area::TechNode;
pub use dataflow::AccelConfig;
