//! Bench FIG3: regenerates the paper's Figure 3 series (gCO2/mm^2 vs FPS,
//! VGG16, three nodes, four approaches) and times sweep vs GA-point cost.
//!
//! Run: `cargo bench --bench fig3 [-- --full]`

use carbon3d::approx::library;
use carbon3d::area::node::ALL_NODES;
use carbon3d::area::TechNode;
use carbon3d::coordinator::baselines::{sweep_nvdla, Approach};
use carbon3d::coordinator::fig3::run_fig3;
use carbon3d::dataflow::workloads::workload;
use carbon3d::ga::GaParams;
use carbon3d::util::stats::pct_change;
use carbon3d::obs::bench::{bench, time_once};

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let params = if full {
        GaParams::default()
    } else {
        GaParams { population: 32, generations: 20, patience: 8, ..Default::default() }
    };
    let lib = library();

    let (r, secs) = time_once(|| run_fig3(&lib, "vgg16", params));
    println!("== FIG3 ({} points in {:.2}s) ==", r.points.len(), secs);
    println!("{}", r.render());

    // Headline §IV-B numbers.
    for &node in &ALL_NODES {
        if let (Some(ga), Some(e3)) = (
            r.best_meeting_fps(node, Approach::GaAppxCdp, 20.0),
            r.best_meeting_fps(node, Approach::ThreeDExact, 20.0),
        ) {
            println!(
                "{} @20FPS: GA vs 3D-Exact carbon cut {:.1}%",
                node.name(),
                -pct_change(e3.carbon_g, ga.carbon_g)
            );
        }
    }

    // Timing units.
    let w = workload("vgg16").unwrap();
    let res = bench("fig3: one NVDLA sweep (6 points, 3D-Exact@7nm)", 1, 10, || {
        sweep_nvdla(Approach::ThreeDExact, &w, TechNode::N7, &lib)
    });
    println!("{}", res.line());
}
