//! Mini property-based testing harness (proptest is unavailable offline).
//!
//! `check(name, cases, |rng| ...)` runs the closure with `cases` independent
//! seeded RNG streams; a panic in any case is re-raised together with the
//! case seed so failures reproduce with `case_with_seed`.

use super::rng::Rng;

/// Run `cases` randomized checks; on failure, report the offending seed.
pub fn check<F: Fn(&mut Rng)>(name: &str, cases: usize, f: F) {
    for i in 0..cases {
        let seed = 0xC0FFEE ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut rng = Rng::new(seed);
            f(&mut rng);
        }));
        if let Err(panic) = result {
            let msg = panic
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| panic.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!(
                "property {name:?} failed on case {i} (seed {seed:#x}): {msg}\n\
                 reproduce with util::prop::case_with_seed({seed:#x}, ...)"
            );
        }
    }
}

/// Re-run a single failing case by seed.
pub fn case_with_seed<F: Fn(&mut Rng)>(seed: u64, f: F) {
    let mut rng = Rng::new(seed);
    f(&mut rng);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        // Captured mutation via cell to count invocations.
        let counter = std::cell::Cell::new(0);
        check("trivial", 25, |rng| {
            let _ = rng.f64();
            counter.set(counter.get() + 1);
        });
        count += counter.get();
        assert_eq!(count, 25);
    }

    #[test]
    fn failing_property_reports_seed() {
        let result = std::panic::catch_unwind(|| {
            check("always-fails", 3, |_rng| panic!("boom"));
        });
        let err = result.unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("seed"), "{msg}");
        assert!(msg.contains("boom"), "{msg}");
    }

    #[test]
    fn cases_see_distinct_randomness() {
        let seen = std::cell::RefCell::new(Vec::new());
        check("distinct", 10, |rng| {
            seen.borrow_mut().push(rng.next_u64());
        });
        let v = seen.borrow();
        let mut dedup = v.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), v.len());
    }
}
