//! The accuracy-evaluation engine: artifacts + compiled executables +
//! batched test-set inference. This is the rust-side "ApproxTrain": the GA
//! asks it for measured ΔA per multiplier LUT; Python is never involved.

use std::collections::HashMap;

use anyhow::{ensure, Result};

use super::artifacts::Artifacts;
use super::pjrt::{Executable, PjrtClient};
use crate::accuracy::native::{NativeEvaluator, IMG, NUM_CLASSES};
use crate::accuracy::AccuracyTable;
use crate::approx::{lut_f32, Multiplier};

/// Engine owning the PJRT client and compiled executables (compiled once,
/// executed many times — one compiled executable per model variant).
pub struct Engine {
    pub artifacts: Artifacts,
    client: PjrtClient,
    executables: HashMap<String, Executable>,
    /// Test data (shared with the native evaluator's loader).
    native: NativeEvaluator,
}

impl Engine {
    /// Create from an artifacts directory; compiles the CNN executables.
    pub fn new(artifacts: Artifacts) -> Result<Self> {
        artifacts.verify()?;
        let client = PjrtClient::cpu()?;
        let mut executables = HashMap::new();
        for name in ["cnn_approx", "cnn_exact", "matmul_approx", "matmul_exact"] {
            let exe = client.compile_hlo_text(name, &artifacts.hlo_path(name))?;
            executables.insert(name.to_string(), exe);
        }
        let native = NativeEvaluator::load(&artifacts)?;
        Ok(Self { artifacts, client, executables, native })
    }

    pub fn platform(&self) -> String {
        self.client.platform()
    }

    pub fn executable(&self, name: &str) -> Option<&Executable> {
        self.executables.get(name)
    }

    /// The trained weights as (data, shape) pairs in PARAM_SPECS order —
    /// the CNN artifacts take them as runtime parameters (baked constants
    /// trip the HLO-text large-constant elision; see python/compile/aot.py).
    fn weight_inputs(&self) -> [(&[f32], [usize; 4]); 6] {
        let w = &self.native.weights;
        // Shapes padded to 4 entries; the used prefix length is in .1[3].
        [
            (&w.conv1_w, [3, 3, 1, 8]),
            (&w.conv1_b, [8, 0, 0, 1]),
            (&w.conv2_w, [3, 3, 8, 16]),
            (&w.conv2_b, [16, 0, 0, 1]),
            (&w.fc_w, [256, NUM_CLASSES, 0, 2]),
            (&w.fc_b, [NUM_CLASSES, 0, 0, 1]),
        ]
    }

    fn push_weights<'a>(&'a self, inputs: &mut Vec<(&'a [f32], Vec<usize>)>) {
        for (data, shape) in self.weight_inputs() {
            let rank = match shape {
                [_, _, _, 1] => 1,
                [_, _, _, 2] => 2,
                _ => 4,
            };
            let dims: Vec<usize> = match rank {
                1 => vec![shape[0]],
                2 => vec![shape[0], shape[1]],
                _ => shape.to_vec(),
            };
            inputs.push((data, dims));
        }
    }

    /// Run the approximate CNN on one batch (len = batch*16*16) with a LUT.
    pub fn cnn_logits_approx(&self, images: &[f32], lut: &[f32]) -> Result<Vec<f32>> {
        let b = self.artifacts.batch;
        ensure!(images.len() == b * IMG * IMG, "batch must be exactly {b} images");
        ensure!(lut.len() == 128 * 128, "LUT must be 128x128");
        let exe = self.executables.get("cnn_approx").unwrap();
        let mut inputs: Vec<(&[f32], Vec<usize>)> =
            vec![(images, vec![b, IMG, IMG, 1]), (lut, vec![128, 128])];
        self.push_weights(&mut inputs);
        let refs: Vec<(&[f32], &[usize])> =
            inputs.iter().map(|(d, s)| (*d, s.as_slice())).collect();
        exe.run_f32(&refs)
    }

    /// Run the exact CNN on one batch.
    pub fn cnn_logits_exact(&self, images: &[f32]) -> Result<Vec<f32>> {
        let b = self.artifacts.batch;
        ensure!(images.len() == b * IMG * IMG, "batch must be exactly {b} images");
        let exe = self.executables.get("cnn_exact").unwrap();
        let mut inputs: Vec<(&[f32], Vec<usize>)> = vec![(images, vec![b, IMG, IMG, 1])];
        self.push_weights(&mut inputs);
        let refs: Vec<(&[f32], &[usize])> =
            inputs.iter().map(|(d, s)| (*d, s.as_slice())).collect();
        exe.run_f32(&refs)
    }

    /// Top-1 accuracy over the artifact test set through the PJRT path.
    /// `lut = None` runs the exact executable.
    pub fn accuracy_pjrt(&self, lut: Option<&[f32]>) -> Result<f64> {
        let b = self.artifacts.batch;
        let n = self.native.testset.n;
        ensure!(n % b == 0, "test set ({n}) not a multiple of batch ({b})");
        let mut correct = 0usize;
        for start in (0..n).step_by(b) {
            let imgs = &self.native.testset.images[start * IMG * IMG..(start + b) * IMG * IMG];
            let logits = match lut {
                Some(l) => self.cnn_logits_approx(imgs, l)?,
                None => self.cnn_logits_exact(imgs)?,
            };
            for i in 0..b {
                let row = &logits[i * NUM_CLASSES..(i + 1) * NUM_CLASSES];
                let pred = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0;
                if pred == self.native.testset.labels[start + i] as usize {
                    correct += 1;
                }
            }
        }
        Ok(correct as f64 / n as f64)
    }

    /// Measure the full accuracy table for a set of multipliers via PJRT.
    pub fn measure_table(&self, mults: &[&Multiplier]) -> Result<AccuracyTable> {
        let exact = self.accuracy_pjrt(None)?;
        let mut table = AccuracyTable { exact, ..Default::default() };
        for m in mults {
            let lut = lut_f32(m);
            table.accuracy.insert(m.id, self.accuracy_pjrt(Some(&lut))?);
        }
        Ok(table)
    }

    /// Native (non-PJRT) evaluator view for cross-checking.
    pub fn native(&self) -> &NativeEvaluator {
        &self.native
    }
}
