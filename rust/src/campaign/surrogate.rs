//! **Learned job-cost surrogate** — an incremental, deterministic
//! distance-weighted regressor over job-key features, fitted online from
//! committed rows, that *tightens* the analytic optimistic bound
//! ([`super::source::JobBound`]) with what the campaign has already
//! learned about its design space.
//!
//! The model is inverse-distance-weighted (IDW) regression in log space:
//! each committed row contributes a point `(features(job), ln obj_value)`,
//! and a prediction is the similarity-weighted mean of the stored targets.
//! Features are exactly the axes a job key encodes — workload, node,
//! integration, δ, FPS floor (numeric axes in their canonical 3-decimal
//! form) — so two jobs are near iff their key axes are near, with
//! categorical mismatches priced as fixed penalties.
//!
//! **Soundness guard.** A surrogate prediction is *not* a bound; it only
//! becomes one after subtracting a calibrated residual margin. [`fit`]
//! recomputes the leave-one-out residual quantile over the stored points
//! and [`CostSurrogate::tightened_lb`] returns
//! `max(analytic_lb, exp(pred − K_MARGIN·q))` — the analytic bound is the
//! floor, so a tightened bound can never be *looser* than the proof the
//! bound pre-pass already has, and the margin makes it pessimistic about
//! its own accuracy. The adaptive sampler only prunes on the tightened
//! bound when the committed incumbent already beats it (same shape as the
//! analytic incumbent rule in [`super::source::prune_reason`]); the CI
//! smoke gate pins that each family's best objective survives pruning
//! bit-identically (DESIGN.md §10.4 spells out the front contract).
//!
//! **Determinism.** Points live in a `BTreeMap` keyed by job key and every
//! summation — predictions, leave-one-out residuals — iterates in key
//! order, so predictions are *bit-identical* whatever order rows were
//! observed in (worker interleaving, resume boundaries, shard merges).
//! Pinned by the property tests below.
//!
//! [`fit`]: CostSurrogate::fit

use std::collections::BTreeMap;

use crate::area::TechNode;

use super::source::{prune_reason, JobBound};
use super::spec::JobSpec;

/// Minimum observed points before the surrogate offers predictions —
/// below this the leave-one-out residuals say nothing about accuracy.
pub const MIN_FIT: usize = 6;

/// How many residual quantiles of safety margin the tightened bound
/// subtracts from a prediction (in log space). One full upper-quantile of
/// leave-one-out error is already pessimistic — the planner evaluates
/// each family's best-ranked jobs long before [`MIN_FIT`] is reached, so
/// the margin guards prune decisions about the *tail* of a family, not
/// its winner.
pub const K_MARGIN: f64 = 1.0;

/// Which leave-one-out residual quantile calibrates the margin.
const RESIDUAL_Q: f64 = 0.9;

/// IDW smoothing: weight = 1 / (distance² + TAU). Keeps exact-match
/// weights finite and far points non-zero.
const TAU: f64 = 0.25;

/// Squared distance added per mismatched categorical axis (model,
/// integration, objective, FPS-floor presence).
const CAT2: f64 = 9.0;

/// The feature embedding of one job key.
#[derive(Debug, Clone, PartialEq)]
struct JobFeatures {
    model: String,
    integration: &'static str,
    objective: &'static str,
    ln_node_nm: f64,
    delta_pct: f64,
    /// `ln fps_floor` when the job has a floor.
    ln_fps: Option<f64>,
}

/// Feature-space value of a node: its drawn dimension in nm, logged so the
/// 45 → 14 and 14 → 7 steps are comparably sized.
fn node_nm(node: TechNode) -> f64 {
    match node {
        TechNode::N45 => 45.0,
        TechNode::N14 => 14.0,
        TechNode::N7 => 7.0,
    }
}

fn features(job: &JobSpec) -> JobFeatures {
    JobFeatures {
        model: job.model.clone(),
        integration: super::spec::integration_name(job.integration),
        objective: job.objective.name(),
        ln_node_nm: node_nm(job.node).ln(),
        delta_pct: job.delta_pct,
        ln_fps: job.fps_floor.map(f64::ln),
    }
}

/// Squared feature-space distance between two jobs.
fn dist2(a: &JobFeatures, b: &JobFeatures) -> f64 {
    let mut d2 = 0.0;
    if a.model != b.model {
        d2 += CAT2;
    }
    if a.integration != b.integration {
        d2 += CAT2;
    }
    if a.objective != b.objective {
        d2 += CAT2;
    }
    let dn = a.ln_node_nm - b.ln_node_nm;
    d2 += dn * dn;
    let dd = a.delta_pct - b.delta_pct;
    d2 += dd * dd;
    match (a.ln_fps, b.ln_fps) {
        (None, None) => {}
        (Some(fa), Some(fb)) => {
            let df = fa - fb;
            d2 += df * df;
        }
        _ => d2 += CAT2,
    }
    d2
}

struct Point {
    feat: JobFeatures,
    /// `ln obj_value` of the committed row.
    y: f64,
}

/// The incremental IDW cost model. See the module docs for the contract.
#[derive(Default)]
pub struct CostSurrogate {
    /// Committed observations, keyed by job key: iteration order — and
    /// therefore every floating-point summation — is independent of
    /// observation order.
    points: BTreeMap<String, Point>,
    /// `K_MARGIN ·` leave-one-out residual quantile, in log space.
    /// `None` until [`CostSurrogate::fit`] has seen [`MIN_FIT`] points.
    margin: Option<f64>,
}

impl CostSurrogate {
    pub fn new() -> Self {
        Self::default()
    }

    /// Observed points so far.
    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// The calibrated log-space margin, once fitted.
    pub fn margin(&self) -> Option<f64> {
        self.margin
    }

    /// Record one committed evaluation. Non-positive or non-finite
    /// objective values carry no information for a log-space model and are
    /// ignored. Re-observing a key (a merge replaying a duplicate row)
    /// overwrites with identical data, so it cannot skew anything.
    pub fn observe(&mut self, job: &JobSpec, obj_value: f64) {
        if !obj_value.is_finite() || obj_value <= 0.0 {
            return;
        }
        self.points
            .insert(job.key(), Point { feat: features(job), y: obj_value.ln() });
    }

    /// Recalibrate the residual margin from the stored points
    /// (leave-one-out, quantile [`RESIDUAL_Q`]). O(n²) — called at batch
    /// boundaries by the adaptive planner, not per prediction.
    pub fn fit(&mut self) {
        let _span = crate::obs::span("surrogate.fit");
        if self.points.len() < MIN_FIT {
            self.margin = None;
            return;
        }
        let pts: Vec<&Point> = self.points.values().collect();
        let mut residuals: Vec<f64> = Vec::with_capacity(pts.len());
        for (j, held_out) in pts.iter().enumerate() {
            let (mut num, mut den) = (0.0, 0.0);
            for (i, p) in pts.iter().enumerate() {
                if i == j {
                    continue;
                }
                let w = 1.0 / (dist2(&held_out.feat, &p.feat) + TAU);
                num += w * p.y;
                den += w;
            }
            residuals.push((held_out.y - num / den).abs());
        }
        residuals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        // Deterministic upper-quantile index (ceil form): for n = 10 and
        // q = 0.9 this is residuals[8].
        let idx = ((RESIDUAL_Q * residuals.len() as f64).ceil() as usize)
            .clamp(1, residuals.len())
            - 1;
        self.margin = Some(K_MARGIN * residuals[idx]);
        crate::obs::metrics().gauge_set("surrogate_points", self.points.len() as u64);
    }

    /// Predicted `ln obj_value` for a job. `None` until fitted.
    pub fn predict(&self, job: &JobSpec) -> Option<f64> {
        self.margin?;
        let _span = crate::obs::span("surrogate.predict");
        let feat = features(job);
        let (mut num, mut den) = (0.0, 0.0);
        for p in self.points.values() {
            let w = 1.0 / (dist2(&feat, &p.feat) + TAU);
            num += w * p.y;
            den += w;
        }
        Some(num / den)
    }

    /// The surrogate's margin-discounted lower estimate of a job's
    /// objective value (linear space). `None` until fitted.
    pub fn lower_estimate(&self, job: &JobSpec) -> Option<f64> {
        let pred = self.predict(job)?;
        Some((pred - self.margin?).exp())
    }

    /// The tightened objective lower bound:
    /// `max(analytic_lb, surrogate lower estimate)`. Falling back to the
    /// analytic bound keeps the guarantee one-sided — tightening can only
    /// raise the bound, never undercut the analytic proof.
    pub fn tightened_lb(&self, job: &JobSpec, analytic_lb: f64) -> f64 {
        match self.lower_estimate(job) {
            Some(lo) if lo > analytic_lb => lo,
            _ => analytic_lb,
        }
    }
}

/// Which rule the adaptive planner pruned a job under (reported by
/// `campaign --explain-prune` and counted separately: surrogate prunes
/// feed the `jobs_pruned_surrogate` counter).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PruneRule {
    /// The analytic FPS-floor rule (pure function of the job and bound).
    FpsFloor,
    /// The analytic incumbent rule: the optimistic bound already loses to
    /// a committed result in the job's family.
    AnalyticIncumbent,
    /// The learned rule: the surrogate's margin-discounted lower estimate
    /// already loses to the committed family incumbent.
    Surrogate,
}

impl PruneRule {
    pub fn name(&self) -> &'static str {
        match self {
            PruneRule::FpsFloor => "fps-floor",
            PruneRule::AnalyticIncumbent => "analytic-incumbent",
            PruneRule::Surrogate => "surrogate",
        }
    }
}

/// The adaptive planner's prune decision for one job: analytic rules first
/// (delegated to [`prune_reason`], the single shared definition), then the
/// surrogate-tightened incumbent rule. `incumbent` is the best committed
/// objective value in the job's family.
pub fn prune_rule(
    job: &JobSpec,
    bound: &JobBound,
    incumbent: Option<f64>,
    surrogate: &CostSurrogate,
) -> Option<PruneRule> {
    if prune_reason(job, bound, None).is_some() {
        return Some(PruneRule::FpsFloor);
    }
    if prune_reason(job, bound, incumbent).is_some() {
        return Some(PruneRule::AnalyticIncumbent);
    }
    let inc = incumbent?;
    let lo = surrogate.lower_estimate(job)?;
    if lo >= inc {
        return Some(PruneRule::Surrogate);
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::area::die::Integration;
    use crate::campaign::spec::{job_seed, CampaignObjective, CampaignSpec};

    fn job(model: &str, node: TechNode, delta: f64, fps: Option<f64>) -> JobSpec {
        let mut j = JobSpec {
            id: 0,
            model: model.to_string(),
            node,
            integration: Integration::ThreeD,
            delta_pct: delta,
            fps_floor: fps,
            objective: CampaignObjective::EmbodiedCdp,
            seed: 0,
        };
        j.seed = job_seed(7, &j.key());
        j
    }

    /// A small synthetic grid with a smooth target: obj = model_scale *
    /// node_nm * (4 - δ). Spread wide enough that near-neighbor structure
    /// matters.
    fn observations() -> Vec<(JobSpec, f64)> {
        let mut out = Vec::new();
        for (mi, model) in ["vgg16", "resnet50"].iter().enumerate() {
            for node in [TechNode::N45, TechNode::N14, TechNode::N7] {
                for delta in [1.0, 2.0, 3.0] {
                    let j = job(model, node, delta, None);
                    let v = (1.0 + mi as f64) * node_nm(node) * (4.0 - delta);
                    out.push((j, v));
                }
            }
        }
        out
    }

    fn fitted(order: impl Iterator<Item = usize>) -> CostSurrogate {
        let obs = observations();
        let mut s = CostSurrogate::new();
        for i in order {
            let (j, v) = &obs[i];
            s.observe(j, *v);
        }
        s.fit();
        s
    }

    #[test]
    fn predictions_are_bit_identical_across_observation_orders() {
        // Property: the same observation *set* — in commit order, reversed,
        // or any sharded interleaving — yields bit-identical predictions
        // and margins. This is what makes the adaptive replay exact.
        let n = observations().len();
        let fwd = fitted(0..n);
        let rev = fitted((0..n).rev());
        let shuffled = fitted((0..n).map(|i| (i * 7 + 3) % n));
        assert_eq!(fwd.margin().unwrap().to_bits(), rev.margin().unwrap().to_bits());
        assert_eq!(fwd.margin().unwrap().to_bits(), shuffled.margin().unwrap().to_bits());
        for probe in [
            job("vgg16", TechNode::N45, 2.0, None),
            job("resnet50", TechNode::N7, 1.0, Some(30.0)),
            job("alexnet", TechNode::N14, 3.0, None),
        ] {
            let p = fwd.predict(&probe).unwrap();
            assert_eq!(p.to_bits(), rev.predict(&probe).unwrap().to_bits(), "{}", probe.key());
            assert_eq!(
                p.to_bits(),
                shuffled.predict(&probe).unwrap().to_bits(),
                "{}",
                probe.key()
            );
        }
    }

    #[test]
    fn surrogate_stays_silent_below_min_fit() {
        let obs = observations();
        let mut s = CostSurrogate::new();
        for (j, v) in obs.iter().take(MIN_FIT - 1) {
            s.observe(j, *v);
        }
        s.fit();
        assert_eq!(s.margin(), None);
        assert_eq!(s.predict(&obs[0].0), None);
        assert_eq!(s.lower_estimate(&obs[0].0), None);
        // Tightening without a fit falls back to the analytic bound.
        assert_eq!(s.tightened_lb(&obs[0].0, 3.25), 3.25);
        // One more observation crosses the threshold.
        s.observe(&obs[MIN_FIT - 1].0, obs[MIN_FIT - 1].1);
        s.fit();
        assert!(s.margin().is_some());
        assert!(s.predict(&obs[0].0).is_some());
    }

    #[test]
    fn non_positive_observations_are_ignored() {
        let mut s = CostSurrogate::new();
        s.observe(&job("vgg16", TechNode::N7, 1.0, None), 0.0);
        s.observe(&job("vgg16", TechNode::N7, 2.0, None), -4.0);
        s.observe(&job("vgg16", TechNode::N7, 3.0, None), f64::NAN);
        assert!(s.is_empty());
    }

    #[test]
    fn tightened_bound_never_undercuts_the_analytic_bound() {
        // Property: for any job, tightened_lb >= analytic_lb — the
        // surrogate can only tighten, never loosen, the proof.
        let s = fitted(0..observations().len());
        for (j, _) in observations() {
            for analytic in [1e-6, 1.0, 1e9] {
                assert!(s.tightened_lb(&j, analytic) >= analytic, "{}", j.key());
            }
        }
    }

    #[test]
    fn interpolation_tracks_the_smooth_target_within_margin() {
        // The model should reconstruct held-out points of a smooth target
        // to within its own claimed margin: remove one observation,
        // predict it, and compare in log space.
        let obs = observations();
        for hold in 0..obs.len() {
            let mut s = CostSurrogate::new();
            for (i, (j, v)) in obs.iter().enumerate() {
                if i != hold {
                    s.observe(j, *v);
                }
            }
            s.fit();
            let (j, truth) = &obs[hold];
            let pred = s.predict(j).unwrap();
            let err = (pred - truth.ln()).abs();
            // The margin is calibrated on the training set; held-out error
            // stays within a small multiple of it for the smooth target.
            assert!(
                err <= 2.0 * s.margin().unwrap() / K_MARGIN + 0.75,
                "{}: err {err:.3}, margin {:.3}",
                j.key(),
                s.margin().unwrap()
            );
        }
    }

    #[test]
    fn prune_rule_orders_analytic_before_surrogate() {
        let s = fitted(0..observations().len());
        let bound = JobBound {
            carbon_lb_g: 1.0,
            delay_lb_s: 0.5,
            energy_lb_j: 0.01,
            fps_ub: 2.0,
            objective_lb: 5.0,
        };
        let free = job("vgg16", TechNode::N45, 2.0, None);
        // No incumbent: never pruned (the surrogate rule needs a target).
        assert_eq!(prune_rule(&free, &bound, None, &s), None);
        // Analytic incumbent rule fires before the surrogate is consulted.
        assert_eq!(
            prune_rule(&free, &bound, Some(4.0), &s),
            Some(PruneRule::AnalyticIncumbent)
        );
        // FPS floor beats everything.
        let floored = job("vgg16", TechNode::N45, 2.0, Some(3.0));
        assert_eq!(prune_rule(&floored, &bound, Some(4.0), &s), Some(PruneRule::FpsFloor));
        // Surrogate rule: analytic bound permits, learned estimate forbids.
        // vgg16@45nm/d2.0 truth is 90; an incumbent of 6 (just above the
        // analytic bound of 5) is far below the learned estimate.
        let lo = s.lower_estimate(&free).unwrap();
        assert!(lo > 6.0, "learned lower estimate {lo} too weak for this test");
        assert_eq!(prune_rule(&free, &bound, Some(6.0), &s), Some(PruneRule::Surrogate));
        // And a surrogate prune can never fire when the incumbent is
        // above the learned estimate.
        assert_eq!(prune_rule(&free, &bound, Some(lo * 10.0), &s), None);
    }

    #[test]
    fn observing_a_grid_twice_changes_nothing() {
        // Merge-style duplicate replay: identical rows overwrite in place.
        let obs = observations();
        let mut once = CostSurrogate::new();
        let mut twice = CostSurrogate::new();
        for (j, v) in &obs {
            once.observe(j, *v);
            twice.observe(j, *v);
        }
        for (j, v) in &obs {
            twice.observe(j, *v);
        }
        once.fit();
        twice.fit();
        assert_eq!(once.len(), twice.len());
        let probe = job("vgg16", TechNode::N14, 1.5, None);
        assert_eq!(
            once.predict(&probe).unwrap().to_bits(),
            twice.predict(&probe).unwrap().to_bits()
        );
    }

    #[test]
    fn distance_prices_categorical_and_numeric_axes() {
        let a = features(&job("vgg16", TechNode::N45, 1.0, None));
        assert_eq!(dist2(&a, &a), 0.0);
        // Other model: one categorical penalty.
        let b = features(&job("resnet50", TechNode::N45, 1.0, None));
        assert_eq!(dist2(&a, &b), CAT2);
        // δ moves quadratically.
        let c = features(&job("vgg16", TechNode::N45, 3.0, None));
        assert_eq!(dist2(&a, &c), 4.0);
        // FPS presence mismatch is categorical.
        let d = features(&job("vgg16", TechNode::N45, 1.0, Some(30.0)));
        assert_eq!(dist2(&a, &d), CAT2);
        // Node distance is log-scaled and symmetric.
        let e = features(&job("vgg16", TechNode::N7, 1.0, None));
        assert!((dist2(&a, &e) - (45.0f64 / 7.0).ln().powi(2)).abs() < 1e-12);
        assert_eq!(dist2(&a, &e).to_bits(), dist2(&e, &a).to_bits());
    }

    #[test]
    fn campaign_grid_keys_are_the_point_identity() {
        // Observing through real grid jobs lands one point per key.
        let spec = CampaignSpec::new(
            vec!["vgg16".to_string()],
            vec![TechNode::N45, TechNode::N7],
            vec![1.0, 3.0],
        );
        let mut s = CostSurrogate::new();
        for j in spec.jobs() {
            s.observe(&j, 2.0 + j.id as f64);
        }
        assert_eq!(s.len(), spec.n_jobs());
    }
}
