//! Geometry-keyed mapping cache — the central memo of the evaluation hot
//! path (DESIGN.md §7.6).
//!
//! `map_network`, task delay, and the memory-side area inputs depend only
//! on the *geometry* of a configuration — `(px, py, rf_bytes, sram_bytes,
//! node, integration)` plus the workload — and never on the multiplier
//! gene (`approx_multiplier_lowers_carbon_same_delay` pins `delay_s`
//! equality across multipliers). The GA, its islands, and every campaign
//! job therefore re-ran the same mapper search once per multiplier for
//! each geometry they visited. [`MappingCache`] memoizes the mapping by
//! workload name + [`GeometryDims`], turning those ~|library|-fold
//! redundant searches into one; the cached [`NetworkMapping`] is the very
//! value a direct `map_network` call computes (`Arc`-shared, never
//! mutated), so evaluations are bit-identical with and without the cache.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, RwLock};

use super::arch::AccelConfig;
use super::mapper::{map_network, NetworkMapping};
use super::workloads::Workload;
use crate::area::die::Integration;
use crate::area::TechNode;

/// Everything the mapper's output depends on, minus the workload (which
/// keys the outer map by name so lookups borrow instead of allocating).
/// Deliberately excludes `mult_id`: the multiplier changes area, energy,
/// and accuracy — never the tiling, traffic, or delay.
pub type GeometryDims = (usize, usize, usize, usize, TechNode, Integration);

/// The geometry half of a configuration.
pub fn geometry_dims(cfg: &AccelConfig) -> GeometryDims {
    (cfg.px, cfg.py, cfg.rf_bytes, cfg.sram_bytes, cfg.node, cfg.integration)
}

/// Shared hit/miss counters (relaxed atomics: observability, not
/// synchronization). Also used for the fitness contexts' chromosome-memo
/// counters, so one type serves every cache the reports surface.
#[derive(Debug, Default)]
pub struct CacheStats {
    hits: AtomicUsize,
    misses: AtomicUsize,
}

impl CacheStats {
    pub fn hit(&self) {
        self.hits.fetch_add(1, Ordering::Relaxed);
    }

    pub fn miss(&self) {
        self.misses.fetch_add(1, Ordering::Relaxed);
    }

    pub fn counts(&self) -> CacheCounts {
        CacheCounts {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
        }
    }
}

/// A point-in-time snapshot of [`CacheStats`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheCounts {
    pub hits: usize,
    pub misses: usize,
}

impl CacheCounts {
    pub fn lookups(&self) -> usize {
        self.hits + self.misses
    }

    /// Fraction of lookups served from the cache; 0.0 before any lookup.
    pub fn hit_rate(&self) -> f64 {
        if self.lookups() == 0 {
            0.0
        } else {
            self.hits as f64 / self.lookups() as f64
        }
    }
}

/// Thread-safe memo of `map_network` results keyed by geometry. Cheap to
/// share (`Arc<MappingCache>` inside `ga::EvalShares`) across the GA
/// population, island threads, and every job a campaign process runs.
/// Two-level: workload name (probed borrowed — no allocation per lookup)
/// over the all-`Copy` [`GeometryDims`].
pub struct MappingCache {
    map: RwLock<HashMap<String, HashMap<GeometryDims, Arc<NetworkMapping>>>>,
    stats: CacheStats,
    enabled: bool,
}

impl Default for MappingCache {
    fn default() -> Self {
        Self::new()
    }
}

impl MappingCache {
    pub fn new() -> Self {
        Self { map: RwLock::new(HashMap::new()), stats: CacheStats::default(), enabled: true }
    }

    /// A cache that never stores: every lookup recomputes, reproducing the
    /// pre-cache evaluation path. Exists so `benches/native.rs` can measure
    /// the cache's wall-clock win on a like-for-like grid.
    pub fn disabled() -> Self {
        Self { enabled: false, ..Self::new() }
    }

    /// The mapping for a configuration's geometry, computed at most once
    /// per key. Two threads racing on a fresh key may both compute (both
    /// counted as misses; the first insert wins) — harmless, because the
    /// value is a pure function of the key.
    pub fn mapping(&self, w: &Workload, cfg: &AccelConfig) -> Arc<NetworkMapping> {
        if !self.enabled {
            self.stats.miss();
            crate::obs::metrics().incr("mapper_cache_misses", 1);
            let _span = crate::obs::span("mapper.search");
            return Arc::new(map_network(w, cfg));
        }
        let dims = geometry_dims(cfg);
        if let Some(hit) = self
            .map
            .read()
            .expect("mapping cache poisoned")
            .get(&w.name)
            .and_then(|per| per.get(&dims))
        {
            self.stats.hit();
            crate::obs::metrics().incr("mapper_cache_hits", 1);
            return hit.clone();
        }
        self.stats.miss();
        crate::obs::metrics().incr("mapper_cache_misses", 1);
        let fresh = {
            let _span = crate::obs::span("mapper.search");
            Arc::new(map_network(w, cfg))
        };
        let mut map = self.map.write().expect("mapping cache poisoned");
        map.entry(w.name.clone()).or_default().entry(dims).or_insert(fresh).clone()
    }

    /// Hit/miss counters since construction.
    pub fn counts(&self) -> CacheCounts {
        self.stats.counts()
    }

    /// Distinct (workload, geometry) entries cached so far.
    pub fn len(&self) -> usize {
        self.map
            .read()
            .expect("mapping cache poisoned")
            .values()
            .map(|per| per.len())
            .sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::EXACT_ID;
    use crate::dataflow::workloads::workload;

    fn cfg(mult_id: usize) -> AccelConfig {
        AccelConfig {
            px: 16,
            py: 16,
            rf_bytes: 512,
            sram_bytes: 1 << 20,
            node: TechNode::N14,
            integration: Integration::ThreeD,
            mult_id,
        }
    }

    #[test]
    fn key_ignores_multiplier_gene() {
        assert_eq!(geometry_dims(&cfg(EXACT_ID)), geometry_dims(&cfg(7)));
    }

    #[test]
    fn same_geometry_different_multiplier_is_one_mapper_run() {
        let cache = MappingCache::new();
        let w = workload("resnet50").unwrap();
        let a = cache.mapping(&w, &cfg(EXACT_ID));
        let b = cache.mapping(&w, &cfg(9));
        assert!(Arc::ptr_eq(&a, &b), "distinct mappings for one geometry");
        let c = cache.counts();
        assert_eq!((c.hits, c.misses), (1, 1));
        assert_eq!(cache.len(), 1);
        assert!((c.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn cached_mapping_equals_direct_call() {
        let cache = MappingCache::new();
        let w = workload("vgg16").unwrap();
        let c = cfg(3);
        let cached = cache.mapping(&w, &c);
        let direct = map_network(&w, &c);
        assert_eq!(cached.total_cycles, direct.total_cycles);
        assert_eq!(cached.layers, direct.layers);
        assert_eq!(cached.delay_s(&c).to_bits(), direct.delay_s(&c).to_bits());
    }

    #[test]
    fn different_geometry_or_workload_is_a_fresh_entry() {
        let cache = MappingCache::new();
        let w1 = workload("vgg16").unwrap();
        let w2 = workload("resnet50").unwrap();
        let mut big = cfg(EXACT_ID);
        big.px = 32;
        cache.mapping(&w1, &cfg(EXACT_ID));
        cache.mapping(&w1, &big);
        cache.mapping(&w2, &cfg(EXACT_ID));
        assert_eq!(cache.len(), 3);
        assert_eq!(cache.counts().hits, 0);
    }

    #[test]
    fn disabled_cache_always_recomputes_but_stays_correct() {
        let cache = MappingCache::disabled();
        let w = workload("tinycnn").unwrap();
        let a = cache.mapping(&w, &cfg(EXACT_ID));
        let b = cache.mapping(&w, &cfg(EXACT_ID));
        assert!(!Arc::ptr_eq(&a, &b));
        assert_eq!(a.total_cycles, b.total_cycles);
        assert_eq!(cache.counts(), CacheCounts { hits: 0, misses: 2 });
        assert!(cache.is_empty());
    }

    #[test]
    fn shared_across_threads() {
        let cache = Arc::new(MappingCache::new());
        let w = workload("tinycnn").unwrap();
        std::thread::scope(|s| {
            for _ in 0..4 {
                let cache = cache.clone();
                let w = &w;
                s.spawn(move || {
                    for mult_id in 0..8 {
                        let m = cache.mapping(w, &cfg(mult_id));
                        assert!(m.total_cycles > 0);
                    }
                });
            }
        });
        assert_eq!(cache.len(), 1);
        let c = cache.counts();
        assert_eq!(c.lookups(), 32);
        // At least the strictly-later lookups hit; racing first lookups may
        // each count a miss, so only the sum is exact.
        assert!(c.hits >= 32 - 4, "{c:?}");
    }
}
