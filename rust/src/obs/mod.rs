//! Structured observability: spans, a process-wide metrics registry, and
//! a JSONL trace sidecar (DESIGN.md §8).
//!
//! Three layers with different costs and different gates:
//!
//! 1. **Metrics** ([`metrics`], [`MetricsSnapshot`]) — always on. Named
//!    atomic counters/gauges/histograms; recording is lock-free on the
//!    steady state and never touches deterministic outputs.
//! 2. **Spans** ([`span`], [`job_scope`]) — the timing histogram feed is
//!    always on; nesting bookkeeping and sidecar lines only happen while
//!    a sink is installed. Disabled spans allocate nothing.
//! 3. **Sink** ([`install`], [`uninstall`], [`flush`]) — opt-in via
//!    `carbon3d campaign --trace` / `CARBON3D_TRACE=1`; writes the
//!    `<store>.trace.jsonl` sidecar read back by `carbon3d trace report`.
//!
//! On top of the per-process core sits the campaign observatory
//! (DESIGN.md §8.5): [`merge`] folds shard sidecars into one stream with
//! per-shard lanes, [`diff`] attributes run-to-run regressions to
//! phases, [`export`] emits Chrome/Perfetto timelines, and [`status`]
//! keeps an atomically-updated `<store>.status.json` live snapshot
//! (always on, `CARBON3D_STATUS=0` / `--no-status` to disable).
//!
//! Determinism contract: nothing in this module writes to the result
//! store, the `.front.json` checkpoint, or `deterministic_json()`; the
//! sidecar is a separate file keyed off the store path. CI's
//! `trace-smoke` job byte-compares traced vs. untraced runs.

#![deny(missing_docs)]

pub mod bench;
pub mod diff;
pub mod export;
pub mod fmt;
pub mod merge;
pub mod metrics;
pub mod report;
pub mod sink;
pub mod span;
pub mod status;

pub use diff::ObsRecord;
pub use fmt::human_time;
pub use merge::merge_traces;
pub use metrics::{merged, metrics, Histogram, HistogramCounts, Merge, Metrics, MetricsSnapshot};
pub use report::TraceReport;
pub use sink::{enabled, flush, heartbeat, install, uninstall, Heartbeat, TraceSummary};
pub use span::{job_scope, span, JobScope, Span};
pub use status::StatusWriter;

use crate::util::json::Json;

/// Record a point event: always bumps the counter named `name` in the
/// metrics registry (so events are countable with tracing off — e.g.
/// `store.torn_append` in tests), and writes a sidecar `event` line when
/// a sink is installed.
pub fn event(name: &'static str, fields: &[(&str, Json)]) {
    metrics().incr(name, 1);
    sink::write_event(name, fields);
}

/// [`event`] plus an unconditional human-readable warning on stderr —
/// for recovery paths that must stay visible on untraced runs (the
/// store's torn-append warning).
pub fn warn_event(name: &'static str, human: &str, fields: &[(&str, Json)]) {
    eprintln!("{human}");
    event(name, fields);
}

/// Serializes tests that install the process-global trace sink (cargo
/// runs tests of one binary concurrently in one process).
#[cfg(test)]
pub(crate) fn test_sink_guard() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::obj;
    use std::path::{Path, PathBuf};

    fn tmp(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("carbon3d-obs-{tag}-{}.trace.jsonl", std::process::id()))
    }

    #[test]
    fn sink_schema_round_trips_through_the_report_loader() {
        let _guard = test_sink_guard();
        let path = tmp("roundtrip");
        install(&path, Path::new("/tmp/demo.jsonl"), Some("0/2")).unwrap();
        {
            let _job = job_scope("vgg16|7nm|d3");
            let _outer = span("job.eval");
            {
                let _inner = span("ga.run");
            }
        }
        event("lease.claim", &[("key", Json::from("vgg16|7nm|d3"))]);
        heartbeat(&Heartbeat {
            done: 3,
            pruned: 1,
            deferred: 0,
            committed: 4,
            scheduled: 8,
            elapsed_s: 2.0,
        });
        let summary = uninstall().unwrap();
        assert_eq!(summary.path, path);

        let r = TraceReport::load(&path).unwrap();
        assert_eq!(r.schema, sink::SCHEMA);
        assert_eq!(r.store, "/tmp/demo.jsonl");
        assert_eq!(r.shard.as_deref(), Some("0/2"));
        assert_eq!(r.beats.len(), 1);
        assert_eq!(r.beats[0].done, 3);
        assert_eq!(r.metrics_lines, 1);
        assert!(r.final_metrics.is_some());
        assert!(r.epoch_ms.is_some(), "header must carry the wall-clock epoch");
        assert_eq!(r.events.len(), 1);
        assert_eq!(r.events[0].name, "lease.claim");
        assert_eq!(r.events[0].fields.get("key").unwrap().as_str().unwrap(), "vgg16|7nm|d3");
        // header + 2 spans + event + heartbeat + metrics
        assert_eq!(r.lines, 6);
        assert_eq!(summary.lines, 6);

        // Nesting: ga.run closed under job.eval, both attributed to the job.
        let inner = r.spans.iter().find(|s| s.name == "ga.run").unwrap();
        assert_eq!(inner.parent.as_deref(), Some("job.eval"));
        assert_eq!(inner.depth, 1);
        assert_eq!(inner.job.as_deref(), Some("vgg16|7nm|d3"));
        let outer = r.spans.iter().find(|s| s.name == "job.eval").unwrap();
        assert_eq!(outer.parent, None);
        assert_eq!(outer.depth, 0);

        // Render paths don't panic and mention the phases.
        let text = r.render(5);
        assert!(text.contains("job.eval"));
        assert!(text.contains("slowest jobs"));
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn loader_rejects_bad_lines() {
        let _guard = test_sink_guard();
        let path = tmp("invalid");
        // No header.
        std::fs::write(&path, "{\"kind\":\"span\"}\n").unwrap();
        assert!(TraceReport::load(&path).is_err());
        // Wrong schema version.
        std::fs::write(
            &path,
            "{\"kind\":\"header\",\"schema\":\"carbon3d-trace/999\",\"pid\":1,\
             \"store\":\"s\",\"shard\":null}\n",
        )
        .unwrap();
        assert!(TraceReport::load(&path).is_err());
        // Valid header, span missing dur_us.
        let header = obj([
            ("kind", Json::from("header")),
            ("schema", Json::from(sink::SCHEMA)),
            ("pid", Json::from(1.0)),
            ("store", Json::from("s")),
            ("shard", Json::Null),
        ]);
        std::fs::write(
            &path,
            format!("{}\n{{\"kind\":\"span\",\"name\":\"x\",\"t_us\":0}}\n", header.dumps()),
        )
        .unwrap();
        let err = TraceReport::load(&path).unwrap_err();
        assert!(format!("{err:#}").contains(":2"), "error should cite the line: {err:#}");
        // Unknown kind.
        std::fs::write(&path, format!("{}\n{{\"kind\":\"mystery\"}}\n", header.dumps())).unwrap();
        assert!(TraceReport::load(&path).is_err());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn events_count_with_tracing_off_but_write_no_sidecar() {
        let _guard = test_sink_guard();
        assert!(!enabled());
        let before = metrics().snapshot();
        event("obs.test.event", &[("k", Json::from(1.0))]);
        event("obs.test.event", &[]);
        let delta = metrics().snapshot().diff(&before);
        assert_eq!(delta.counter("obs.test.event"), 2);
    }

    #[test]
    fn job_span_coverage_merges_overlaps() {
        let _guard = test_sink_guard();
        let path = tmp("coverage");
        let header = obj([
            ("kind", Json::from("header")),
            ("schema", Json::from(sink::SCHEMA)),
            ("pid", Json::from(1.0)),
            ("store", Json::from("s")),
            ("shard", Json::Null),
        ]);
        let span_line = |t: f64, d: f64| {
            obj([
                ("kind", Json::from("span")),
                ("name", Json::from("job.eval")),
                ("t_us", Json::from(t)),
                ("dur_us", Json::from(d)),
                ("depth", Json::from(0.0)),
                ("parent", Json::Null),
                ("job", Json::from("j")),
                ("thread", Json::from(0.0)),
            ])
            .dumps()
        };
        // Two overlapping worker spans [0,60] + [40,100] and a gap to 200.
        std::fs::write(
            &path,
            format!(
                "{}\n{}\n{}\n{}\n",
                header.dumps(),
                span_line(0.0, 60.0),
                span_line(40.0, 60.0),
                span_line(150.0, 50.0)
            ),
        )
        .unwrap();
        let r = TraceReport::load(&path).unwrap();
        assert_eq!(r.wall_us(), 200);
        assert!((r.job_span_coverage() - 0.75).abs() < 1e-9);
        std::fs::remove_file(&path).unwrap();
    }
}
