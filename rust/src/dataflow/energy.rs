//! Operational-energy model (used by ablations and the [6]-style baseline
//! comparisons; the paper's objective is embodied carbon x delay, but the
//! energy roll-up validates the 3D interconnect advantage).

use super::arch::AccelConfig;
use super::mapper::NetworkMapping;
use crate::area::die::Integration;
use crate::area::mac::mac_power_uw;
use crate::approx::Multiplier;

/// Per-event energies in picojoules at a given configuration.
#[derive(Debug, Clone, Copy)]
pub struct EnergyModel {
    pub mac_pj: f64,
    pub sram_word_pj: f64,
    pub dram_byte_pj: f64,
    /// Per-word SRAM->PE transport (NoC hop chain for 2D, vertical link for 3D).
    pub transport_word_pj: f64,
}

impl EnergyModel {
    /// Build from the configuration (node scaling + integration style).
    pub fn for_config(cfg: &AccelConfig, mult: &Multiplier) -> Self {
        // MAC energy from the gate model: power (uW) at f -> energy/cycle.
        let mac_uw = mac_power_uw(mult, cfg.node);
        let mac_pj = mac_uw / cfg.node.freq_mhz(); // uW / MHz = pJ
        // SRAM read ~ node-scaled; classic 45nm value ~5pJ/word for a
        // megabyte-class array.
        let sram_word_pj = 5.0 * cfg.node.sram_bitcell_um2() / 0.36;
        // LPDDR access ~ 20-40 pJ/byte at the device, node-independent-ish.
        let dram_byte_pj = 30.0;
        // 2D NoC: ~0.6pJ/word/hop x avg hops (~(px+py)/3); 3D hybrid bond:
        // ~0.05pJ/word (the ISSCC'24 prototype reports ~40% energy cut at
        // iso-area; the vertical hop is over 10x cheaper than a mesh path).
        let transport_word_pj = match cfg.integration {
            Integration::TwoD => 0.6 * ((cfg.px + cfg.py) as f64 / 3.0),
            Integration::ThreeD => 0.05,
        };
        Self { mac_pj, sram_word_pj, dram_byte_pj, transport_word_pj }
    }

    /// Total inference energy (joules) for a mapped network.
    pub fn network_energy_j(&self, m: &NetworkMapping) -> f64 {
        let mut pj = 0.0;
        for l in &m.layers {
            pj += l.macs as f64 * self.mac_pj;
            pj += l.sram_words as f64 * (self.sram_word_pj + self.transport_word_pj);
            pj += l.dram_bytes as f64 * self.dram_byte_pj;
        }
        pj * 1e-12
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::area::TechNode;
    use crate::approx::{library, EXACT_ID};
    use crate::dataflow::mapper::map_network;
    use crate::dataflow::workloads::workload;

    fn cfg(integration: Integration) -> AccelConfig {
        AccelConfig {
            px: 16,
            py: 16,
            rf_bytes: 512,
            sram_bytes: 2 << 20,
            node: TechNode::N14,
            integration,
            mult_id: EXACT_ID,
        }
    }

    #[test]
    fn three_d_transport_cheaper_than_2d() {
        let lib = library();
        let e2 = EnergyModel::for_config(&cfg(Integration::TwoD), &lib[EXACT_ID]);
        let e3 = EnergyModel::for_config(&cfg(Integration::ThreeD), &lib[EXACT_ID]);
        assert!(e3.transport_word_pj < e2.transport_word_pj / 10.0);
    }

    #[test]
    fn three_d_network_energy_lower() {
        let lib = library();
        let w = workload("resnet50").unwrap();
        let c2 = cfg(Integration::TwoD);
        let c3 = cfg(Integration::ThreeD);
        let e2 = EnergyModel::for_config(&c2, &lib[EXACT_ID]).network_energy_j(&map_network(&w, &c2));
        let e3 = EnergyModel::for_config(&c3, &lib[EXACT_ID]).network_energy_j(&map_network(&w, &c3));
        assert!(e3 < e2, "3D {e3} !< 2D {e2}");
    }

    #[test]
    fn vgg16_inference_energy_ballpark() {
        // Edge accelerator at 14nm: O(10-500) mJ per VGG16 inference.
        let lib = library();
        let c = cfg(Integration::ThreeD);
        let e = EnergyModel::for_config(&c, &lib[EXACT_ID])
            .network_energy_j(&map_network(&workload("vgg16").unwrap(), &c));
        assert!((0.005..1.0).contains(&e), "energy {e} J");
    }

    #[test]
    fn approx_mult_cuts_mac_energy() {
        let lib = library();
        let c = cfg(Integration::ThreeD);
        let exact = EnergyModel::for_config(&c, &lib[EXACT_ID]).mac_pj;
        let best = lib
            .iter()
            .map(|m| EnergyModel::for_config(&c, m).mac_pj)
            .fold(f64::INFINITY, f64::min);
        assert!(best < exact);
    }
}
