//! Figure 2 pipeline: normalized inference delay and embodied carbon across
//! technology nodes (45/14/7nm), accuracy thresholds (1/2/3%) and the five
//! CNNs, GA-APPX-CDP vs the GA-CDP-EXACT baseline [6].

use crate::approx::Multiplier;
use crate::area::node::ALL_NODES;
use crate::area::TechNode;
use crate::dataflow::workloads::workload;
use crate::ga::{GaParams, GaResult};
use crate::util::{table, Table};

use super::{ga_appx_min_carbon, ga_cdp_exact};

/// One cell of Fig. 2: a (node, model, δ) GA result normalized to baseline.
#[derive(Debug, Clone)]
pub struct Fig2Cell {
    pub node: TechNode,
    pub model: String,
    pub delta_pct: f64,
    pub norm_delay: f64,
    pub norm_carbon: f64,
    pub mult_name: String,
    pub best: GaResult,
}

/// Full Fig. 2 data.
#[derive(Debug, Clone)]
pub struct Fig2Result {
    pub cells: Vec<Fig2Cell>,
    /// (node, model) -> baseline absolute (delay_s, carbon_g).
    pub baselines: Vec<(TechNode, String, f64, f64)>,
}

pub const FIG2_MODELS: [&str; 5] = ["vgg16", "vgg19", "resnet50", "resnet50v2", "densenet121"];
pub const FIG2_DELTAS: [f64; 3] = [1.0, 2.0, 3.0];

/// Run the full Fig. 2 grid. `models` defaults to the paper's five CNNs.
pub fn run_fig2(
    library: &[Multiplier],
    models: &[&str],
    params: GaParams,
) -> Fig2Result {
    let mut cells = Vec::new();
    let mut baselines = Vec::new();
    for &node in &ALL_NODES {
        for &model in models {
            let w = workload(model).unwrap_or_else(|| panic!("unknown workload {model}"));
            // Baseline: [6]-style CDP GA without approximation.
            let base = ga_cdp_exact(&w, node, library, None, params);
            let (bd, bc) = (base.best_eval.delay_s, base.best_eval.carbon_g);
            baselines.push((node, model.to_string(), bd, bc));
            // GA-APPX-CDP constrained to the baseline's performance, then
            // polished to the minimum-carbon feasible design (the paper's
            // "lower embodied carbon while maintaining competitive
            // performance" — the same constrained methodology §IV-B makes
            // explicit with FPS targets). Without the floor the CDP optimum
            // may legally trade carbon *up* for delay, which is not the
            // comparison Fig. 2 reports.
            let fps_floor = base.best_eval.fps * 0.999;
            for &delta in &FIG2_DELTAS {
                // Seed varies per cell for independent searches.
                let cell_params = GaParams {
                    seed: params
                        .seed
                        .wrapping_add((delta as u64) << 8)
                        .wrapping_add(node as u64)
                        .wrapping_add(model.len() as u64),
                    ..params
                };
                let r = ga_appx_min_carbon(
                    &w,
                    node,
                    library,
                    delta,
                    fps_floor,
                    cell_params,
                    Some(&base.best),
                );
                cells.push(Fig2Cell {
                    node,
                    model: model.to_string(),
                    delta_pct: delta,
                    norm_delay: r.best_eval.delay_s / bd,
                    norm_carbon: r.best_eval.carbon_g / bc,
                    mult_name: library[r.best.mult_id].name(),
                    best: r,
                });
            }
        }
    }
    Fig2Result { cells, baselines }
}

impl Fig2Result {
    /// Render the figure as a table (rows = the paper's bar groups).
    pub fn render(&self) -> String {
        let mut t = Table::new(vec![
            "node", "model", "delta", "norm_delay", "norm_carbon", "carbon_cut_%", "mult",
        ]);
        for c in &self.cells {
            t.row(vec![
                c.node.name().to_string(),
                c.model.clone(),
                format!("{}%", c.delta_pct),
                table::fmt(c.norm_delay),
                table::fmt(c.norm_carbon),
                format!("{:.1}", (1.0 - c.norm_carbon) * 100.0),
                c.mult_name.clone(),
            ]);
        }
        t.render()
    }

    /// Max carbon reduction (%) at a node across models/deltas.
    pub fn max_carbon_cut_pct(&self, node: TechNode) -> f64 {
        self.cells
            .iter()
            .filter(|c| c.node == node)
            .map(|c| (1.0 - c.norm_carbon) * 100.0)
            .fold(f64::NEG_INFINITY, f64::max)
    }

    /// Mean carbon reduction (%) at a node and δ.
    pub fn mean_carbon_cut_pct(&self, node: TechNode, delta: f64) -> f64 {
        let xs: Vec<f64> = self
            .cells
            .iter()
            .filter(|c| c.node == node && c.delta_pct == delta)
            .map(|c| (1.0 - c.norm_carbon) * 100.0)
            .collect();
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::library;

    /// Small GA budget keeps the test minutes-fast while preserving the
    /// paper's qualitative shape.
    fn quick_params() -> GaParams {
        GaParams { population: 20, generations: 12, patience: 6, seed: 42, ..Default::default() }
    }

    #[test]
    fn fig2_single_model_shape() {
        let lib = library();
        let r = run_fig2(&lib, &["resnet50"], quick_params());
        assert_eq!(r.cells.len(), 3 * 3); // 3 nodes x 3 deltas
        for c in &r.cells {
            // GA-APPX-CDP must never exceed baseline carbon (the exact
            // multiplier is in its gene pool).
            assert!(
                c.norm_carbon <= 1.02,
                "{} {} δ{}: norm carbon {}",
                c.node.name(),
                c.model,
                c.delta_pct,
                c.norm_carbon
            );
            assert!(c.norm_delay > 0.0 && c.norm_delay < 3.0);
        }
    }

    #[test]
    fn looser_delta_never_hurts_carbon() {
        let lib = library();
        let r = run_fig2(&lib, &["vgg16"], quick_params());
        for &node in &ALL_NODES {
            let cut1 = r.mean_carbon_cut_pct(node, 1.0);
            let cut3 = r.mean_carbon_cut_pct(node, 3.0);
            // δ=3% has a superset gene pool; allow small GA noise.
            assert!(cut3 >= cut1 - 3.0, "{}: cut1 {cut1} cut3 {cut3}", node.name());
        }
    }
}
