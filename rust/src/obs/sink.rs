//! The JSONL trace sink: a process-global, mutex-guarded buffered writer
//! producing the `<store>.trace.jsonl` sidecar.
//!
//! Layout per line (schema `carbon3d-trace/1`, one JSON object per line):
//!
//! - `header` — first line; schema version, pid, store path, shard
//!   label, and the wall-clock epoch (`epoch_ms`, Unix ms) that anchors
//!   every monotonic `t_us` offset — `trace merge` reconciles shard
//!   sidecars onto one time base from it.
//! - `span` — a closed timed span: name, start offset + duration (µs),
//!   nesting depth, parent span name, owning job key, thread ordinal.
//! - `event` — a point event (lease claim, torn-append recovery, ...).
//! - `heartbeat` — periodic live progress (jobs done/pruned/deferred,
//!   jobs/s, cache hit-rates, ETA).
//! - `metrics` — final [`MetricsSnapshot`] written at uninstall.
//!
//! Install/uninstall bracket one campaign run; `enabled()` is a single
//! relaxed atomic load, which is what keeps the disabled hot path free.
//! The sidecar is a separate file from the store and is never read back
//! by the campaign engine, so tracing cannot perturb deterministic
//! outputs.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::{ensure, Context, Result};

use crate::util::json::{obj, Json};

use super::metrics::{metrics, MetricsSnapshot};

/// Sidecar schema identifier; bump the suffix on breaking line-format
/// changes so `trace report --check` can refuse mismatched files.
pub const SCHEMA: &str = "carbon3d-trace/1";

static ENABLED: AtomicBool = AtomicBool::new(false);
static STATE: Mutex<Option<SinkState>> = Mutex::new(None);

struct SinkState {
    out: BufWriter<File>,
    epoch: Instant,
    path: PathBuf,
    lines: u64,
}

/// Whether a trace sink is currently installed. One relaxed load — this
/// is the gate every span/event site checks first.
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Summary returned by [`uninstall`] for the CLI's closing message.
#[derive(Debug, Clone)]
pub struct TraceSummary {
    /// Sidecar path the sink was writing to.
    pub path: PathBuf,
    /// Total lines written, header and final metrics included.
    pub lines: u64,
}

/// Install the process trace sink, truncating `path` and writing the
/// schema header. Fails if a sink is already installed (one campaign per
/// process; tests serialize on a shared lock).
pub fn install(path: &Path, store: &Path, shard: Option<&str>) -> Result<()> {
    let mut st = STATE.lock().expect("trace sink poisoned");
    ensure!(st.is_none(), "trace sink already installed");
    let file = File::create(path)
        .with_context(|| format!("creating trace sidecar {}", path.display()))?;
    let mut state = SinkState {
        out: BufWriter::new(file),
        epoch: Instant::now(),
        path: path.to_path_buf(),
        lines: 0,
    };
    let epoch_ms = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_millis() as u64)
        .unwrap_or(0);
    let header = obj([
        ("kind", Json::from("header")),
        ("schema", Json::from(SCHEMA)),
        ("pid", Json::from(std::process::id() as f64)),
        ("store", Json::from(store.display().to_string())),
        ("shard", shard.map(Json::from).unwrap_or(Json::Null)),
        ("epoch_ms", Json::from(epoch_ms as f64)),
    ]);
    state.write_line(&header)?;
    *st = Some(state);
    ENABLED.store(true, Ordering::Release);
    Ok(())
}

/// Tear down the sink: write the final `metrics` line (a full registry
/// snapshot), flush, and close. Returns `None` if no sink was installed.
pub fn uninstall() -> Option<TraceSummary> {
    // Drop the gate first so concurrently-finishing spans stop enqueueing.
    ENABLED.store(false, Ordering::Release);
    let mut st = STATE.lock().expect("trace sink poisoned");
    let mut state = st.take()?;
    let line = obj([
        ("kind", Json::from("metrics")),
        ("t_us", Json::from(state.epoch.elapsed().as_micros() as f64)),
        ("snapshot", MetricsSnapshot::collect().to_json()),
    ]);
    let _ = state.write_line(&line);
    let _ = state.out.flush();
    Some(TraceSummary { path: state.path.clone(), lines: state.lines })
}

/// Flush buffered trace lines to disk. Called by the commit pipeline on
/// every archive checkpoint so the sidecar never trails the store by
/// more than one commit.
pub fn flush() {
    if !enabled() {
        return;
    }
    if let Some(state) = STATE.lock().expect("trace sink poisoned").as_mut() {
        let _ = state.out.flush();
    }
}

impl SinkState {
    fn write_line(&mut self, line: &Json) -> Result<()> {
        writeln!(self.out, "{}", line.dumps())?;
        self.lines += 1;
        Ok(())
    }
}

/// Small monotone ordinal for the current thread (ThreadId has no stable
/// numeric form); only consulted on traced span close.
fn thread_ordinal() -> u64 {
    static NEXT: AtomicU64 = AtomicU64::new(0);
    thread_local! {
        static ID: u64 = NEXT.fetch_add(1, Ordering::Relaxed);
    }
    ID.with(|id| *id)
}

/// Record a closed span. Called from `Span::drop` only when the span was
/// created with tracing enabled.
pub(super) fn write_span(
    name: &'static str,
    parent: Option<&'static str>,
    depth: usize,
    job: Option<&str>,
    t0: Instant,
    dur: Duration,
) {
    let mut st = STATE.lock().expect("trace sink poisoned");
    let Some(state) = st.as_mut() else { return };
    let t_us = t0.saturating_duration_since(state.epoch).as_micros() as f64;
    let line = obj([
        ("kind", Json::from("span")),
        ("name", Json::from(name)),
        ("t_us", Json::from(t_us)),
        ("dur_us", Json::from(dur.as_micros() as f64)),
        ("depth", Json::from(depth as f64)),
        ("parent", parent.map(Json::from).unwrap_or(Json::Null)),
        ("job", job.map(Json::from).unwrap_or(Json::Null)),
        ("thread", Json::from(thread_ordinal() as f64)),
    ]);
    let _ = state.write_line(&line);
}

/// Write a point event line (no-op when tracing is off — the companion
/// counter in the metrics registry is what stays always-on).
pub(super) fn write_event(name: &'static str, fields: &[(&str, Json)]) {
    if !enabled() {
        return;
    }
    let mut st = STATE.lock().expect("trace sink poisoned");
    let Some(state) = st.as_mut() else { return };
    let mut f = std::collections::BTreeMap::new();
    for (k, v) in fields {
        f.insert((*k).to_string(), v.clone());
    }
    let line = obj([
        ("kind", Json::from("event")),
        ("name", Json::from(name)),
        ("t_us", Json::from(state.epoch.elapsed().as_micros() as f64)),
        ("fields", Json::Obj(f)),
    ]);
    let _ = state.write_line(&line);
}

/// Live-progress snapshot emitted periodically by the commit pipeline.
#[derive(Debug, Clone, Copy)]
pub struct Heartbeat {
    /// Rows committed so far.
    pub done: usize,
    /// Jobs pruned by the bound rule instead of evaluated.
    pub pruned: usize,
    /// Jobs deferred past this pass (sharded runs: lease unavailable).
    pub deferred: usize,
    /// Schedule slots committed (done + pruned + deferred + skipped).
    pub committed: usize,
    /// Total schedule slots.
    pub scheduled: usize,
    /// Campaign wall clock behind the rates, in seconds.
    pub elapsed_s: f64,
}

impl Heartbeat {
    /// Committed schedule slots per second of campaign wall clock.
    pub fn jobs_per_s(&self) -> f64 {
        if self.elapsed_s > 0.0 {
            self.committed as f64 / self.elapsed_s
        } else {
            0.0
        }
    }

    /// Remaining-slot ETA in seconds at the current commit rate.
    pub fn eta_s(&self) -> f64 {
        let rate = self.jobs_per_s();
        if rate > 0.0 {
            self.scheduled.saturating_sub(self.committed) as f64 / rate
        } else {
            0.0
        }
    }
}

/// `hits / total`, 0 when nothing happened — shared by the heartbeat
/// line and the status snapshot so both report identical rates.
pub fn hit_rate(hits: u64, total: u64) -> f64 {
    if total > 0 {
        hits as f64 / total as f64
    } else {
        0.0
    }
}

/// Emit a heartbeat: one sidecar line plus a human line on stderr
/// (stdout carries the report and stays clean). Cache hit-rates come
/// from the process metrics registry.
pub fn heartbeat(h: &Heartbeat) {
    if !enabled() {
        return;
    }
    let rate = h.jobs_per_s();
    let eta_s = h.eta_s();
    let m = metrics();
    let mapper_hits = m.counter("mapper_cache_hits");
    let mapper_rate = hit_rate(mapper_hits, mapper_hits + m.counter("mapper_cache_misses"));
    let service_rate = hit_rate(m.counter("service_cache_hits"), m.counter("service_served"));
    {
        let mut st = STATE.lock().expect("trace sink poisoned");
        let Some(state) = st.as_mut() else { return };
        let line = obj([
            ("kind", Json::from("heartbeat")),
            ("t_us", Json::from(state.epoch.elapsed().as_micros() as f64)),
            ("done", Json::from(h.done)),
            ("pruned", Json::from(h.pruned)),
            ("deferred", Json::from(h.deferred)),
            ("committed", Json::from(h.committed)),
            ("scheduled", Json::from(h.scheduled)),
            ("jobs_per_s", Json::from(rate)),
            ("eta_s", Json::from(eta_s)),
            ("mapper_hit_rate", Json::from(mapper_rate)),
            ("service_hit_rate", Json::from(service_rate)),
        ]);
        let _ = state.write_line(&line);
        let _ = state.out.flush();
    }
    eprintln!(
        "[trace] {}/{} slots ({} rows, {} pruned, {} deferred) | {:.2} jobs/s | \
         mapper {:.0}% hits | eval svc {:.0}% hits | ETA {}",
        h.committed,
        h.scheduled,
        h.done,
        h.pruned,
        h.deferred,
        rate,
        mapper_rate * 100.0,
        service_rate * 100.0,
        super::fmt::human_time(eta_s),
    );
}
