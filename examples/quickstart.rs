//! Quickstart: the whole stack in one file.
//!
//! 1. Load the AOT-compiled approximate-matmul artifact (Pallas kernel,
//!    lowered by `make artifacts`) on the PJRT CPU client.
//! 2. Run it with the exact LUT and with an approximate multiplier's LUT;
//!    compare numerics.
//! 3. Price a small 3D accelerator in embodied carbon with both multipliers.
//!
//! Run: `cargo run --release --example quickstart`

use std::path::Path;

use carbon3d::approx::{library, lut_f32, EXACT_ID};
use carbon3d::area::die::Integration;
use carbon3d::area::TechNode;
use carbon3d::carbon::embodied_carbon;
use carbon3d::dataflow::arch::AccelConfig;
use carbon3d::runtime::pjrt::PjrtClient;
use carbon3d::runtime::Artifacts;

fn main() -> anyhow::Result<()> {
    // --- 1. PJRT + artifact -------------------------------------------------
    let artifacts = Artifacts::load(Path::new("artifacts"))?;
    let client = PjrtClient::cpu()?;
    let exe = client.compile_hlo_text("matmul_approx", &artifacts.hlo_path("matmul_approx"))?;
    println!("loaded matmul_approx on {}", client.platform());

    // --- 2. exact vs approximate LUT ---------------------------------------
    let lib = library();
    let trunc3 = lib.iter().find(|m| m.name() == "TRUNC3").unwrap();
    let mut a = vec![0f32; 64 * 64];
    let mut b = vec![0f32; 64 * 64];
    for i in 0..64 * 64 {
        a[i] = ((i % 53) as f32 - 26.0) * 0.09;
        b[i] = ((i % 47) as f32 - 23.0) * 0.06;
    }
    let lut_exact = lut_f32(&lib[EXACT_ID]);
    let lut_appx = lut_f32(trunc3);
    let exact = exe.run_f32(&[(&a, &[64, 64]), (&b, &[64, 64]), (&lut_exact, &[128, 128])])?;
    let appx = exe.run_f32(&[(&a, &[64, 64]), (&b, &[64, 64]), (&lut_appx, &[128, 128])])?;
    let mean_abs: f32 = exact.iter().map(|x| x.abs()).sum::<f32>() / exact.len() as f32;
    let mean_err: f32 =
        exact.iter().zip(&appx).map(|(x, y)| (x - y).abs()).sum::<f32>() / exact.len() as f32;
    println!(
        "TRUNC3 vs EXACT over a 64x64x64 matmul: mean |err| = {:.4} ({:.2}% of mean |value|)",
        mean_err,
        mean_err / mean_abs * 100.0
    );

    // --- 3. embodied carbon of a small 3D accelerator ----------------------
    for mult in [&lib[EXACT_ID], trunc3] {
        let cfg = AccelConfig {
            px: 16,
            py: 16,
            rf_bytes: 128,
            sram_bytes: 512 << 10,
            node: TechNode::N14,
            integration: Integration::ThreeD,
            mult_id: mult.id,
        };
        let areas = cfg.die_areas(mult);
        let carbon = embodied_carbon(&areas, cfg.node, cfg.integration);
        println!(
            "{:<22} logic {:.3} mm^2, memory {:.3} mm^2 -> {:.2} gCO2 embodied",
            cfg.describe(mult),
            areas.logic_mm2,
            areas.memory_mm2,
            carbon.total_g()
        );
    }
    println!("quickstart OK");
    Ok(())
}
