//! Native bit-faithful evaluator: the trained tiny CNN through the
//! approximate bf16 MAC datapath, entirely in Rust.
//!
//! Semantics mirror python/compile/kernels/ref.py exactly:
//!   bf16 RNE rounding -> sign/exp/mant decompose -> LUT significand product
//!   -> exact power-of-two scale -> f32 accumulation; zeros/denormals flush.
//! Layer plumbing mirrors python/compile/model.py (im2col patch order
//! (dy,dx,c), 'same' padding, maxpool2, fc).

use std::path::Path;

use anyhow::{ensure, Context, Result};

use crate::approx::Multiplier;
use crate::runtime::artifacts::Artifacts;

/// bf16 round-to-nearest-even, result as f32 with low 16 bits zero.
#[inline]
pub fn bf16_round(x: f32) -> f32 {
    let bits = x.to_bits();
    let lsb = (bits >> 16) & 1;
    f32::from_bits(bits.wrapping_add(0x7FFF + lsb) & 0xFFFF_0000)
}

/// Exact f32 2^e for integer e (3-factor clamped chain; matches
/// ref.pow2_exact).
#[inline]
fn pow2_exact(e: i32) -> f32 {
    let factor = |ei: i32| f32::from_bits(((ei + 127) as u32) << 23);
    let e1 = e.clamp(-126, 127);
    let r = e - e1;
    let e2 = r.clamp(-126, 127);
    let e3 = (r - e2).clamp(-126, 127);
    factor(e1) * factor(e2) * factor(e3)
}

/// The approximate MAC datapath for one multiplier LUT.
pub struct ApproxDatapath {
    /// 128x128 significand products (u16 range), f32 for parity with the
    /// AOT kernel input.
    lut: Vec<f32>,
}

impl ApproxDatapath {
    pub fn new(mult: &Multiplier) -> Self {
        Self { lut: crate::approx::lut_f32(mult) }
    }

    pub fn from_lut(lut: Vec<f32>) -> Self {
        assert_eq!(lut.len(), 128 * 128);
        Self { lut }
    }

    /// One approximate product (ref.approx_mul_elementwise semantics).
    #[inline]
    pub fn mul(&self, a: f32, b: f32) -> f32 {
        let ab = bf16_round(a).to_bits();
        let bb = bf16_round(b).to_bits();
        let ea = (ab >> 23) & 0xFF;
        let eb = (bb >> 23) & 0xFF;
        if ea == 0 || eb == 0 {
            return 0.0;
        }
        let ma = (ab >> 16) & 0x7F;
        let mb = (bb >> 16) & 0x7F;
        let sig = self.lut[(ma * 128 + mb) as usize];
        let scale = pow2_exact(ea as i32 + eb as i32 - 268);
        let sign = if (ab ^ bb) & 0x8000_0000 != 0 { -1.0f32 } else { 1.0f32 };
        sign * (sig * scale)
    }

    /// [M,K] x [K,N] matmul with f32 accumulation over ascending k.
    ///
    /// Hot path of the native evaluator (EXPERIMENTS.md §Perf): operands are
    /// decomposed to (sign|mant, exp) *once* up front instead of re-rounding
    /// + re-decoding both scalars on every one of the M*K*N products.
    pub fn matmul(&self, a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        assert_eq!(a.len(), m * k);
        assert_eq!(b.len(), k * n);
        // Pre-decode: pack (mant<<1 | signbit) and keep exp separately;
        // exp == 0 marks zero/denormal (flushed).
        #[inline]
        fn decode(x: f32) -> (u32, i32) {
            let bits = bf16_round(x).to_bits();
            let exp = ((bits >> 23) & 0xFF) as i32;
            let key = ((bits >> 16) & 0x7F) << 1 | (bits >> 31);
            (key, exp)
        }
        let da: Vec<(u32, i32)> = a.iter().map(|&x| decode(x)).collect();
        let db: Vec<(u32, i32)> = b.iter().map(|&x| decode(x)).collect();
        let mut out = vec![0f32; m * n];
        for i in 0..m {
            for kk in 0..k {
                let (ka, ea) = da[i * k + kk];
                if ea == 0 {
                    continue;
                }
                let row_a_base = ((ka >> 1) * 128) as usize;
                let sign_a = ka & 1;
                let out_row = &mut out[i * n..(i + 1) * n];
                let b_row = &db[kk * n..(kk + 1) * n];
                for (o, &(kb, eb)) in out_row.iter_mut().zip(b_row) {
                    if eb == 0 {
                        continue;
                    }
                    let sig = self.lut[row_a_base + (kb >> 1) as usize];
                    let scale = pow2_exact(ea + eb - 268);
                    let v = sig * scale;
                    *o += if (sign_a ^ (kb & 1)) != 0 { -v } else { v };
                }
            }
        }
        out
    }
}

/// Trained tiny-CNN weights (PARAM_SPECS order, see python/compile/model.py).
#[derive(Debug, Clone)]
pub struct Weights {
    pub conv1_w: Vec<f32>, // [3,3,1,8]
    pub conv1_b: Vec<f32>, // [8]
    pub conv2_w: Vec<f32>, // [3,3,8,16]
    pub conv2_b: Vec<f32>, // [16]
    pub fc_w: Vec<f32>,    // [256,5]
    pub fc_b: Vec<f32>,    // [5]
}

/// Test-set images + labels.
#[derive(Debug, Clone)]
pub struct TestSet {
    pub images: Vec<f32>, // [n,16,16,1]
    pub labels: Vec<u8>,
    pub n: usize,
}

/// The native evaluator: weights + test set + forward pass.
pub struct NativeEvaluator {
    pub weights: Weights,
    pub testset: TestSet,
    pub exact_accuracy: f64,
}

pub const IMG: usize = 16;
pub const NUM_CLASSES: usize = 5;

impl NativeEvaluator {
    /// Load from the artifacts directory (weights.f32, testset_*, manifest).
    pub fn load(artifacts: &Artifacts) -> Result<Self> {
        let dir = &artifacts.dir;
        let w = read_f32(&dir.join("weights.f32"))?;
        let sizes = [3 * 3 * 8, 8, 3 * 3 * 8 * 16, 16, 256 * 5, 5];
        ensure!(
            w.len() == sizes.iter().sum::<usize>(),
            "weights.f32 has {} floats, want {}",
            w.len(),
            sizes.iter().sum::<usize>()
        );
        let mut off = 0;
        let mut take = |n: usize| {
            let v = w[off..off + n].to_vec();
            off += n;
            v
        };
        let weights = Weights {
            conv1_w: take(sizes[0]),
            conv1_b: take(sizes[1]),
            conv2_w: take(sizes[2]),
            conv2_b: take(sizes[3]),
            fc_w: take(sizes[4]),
            fc_b: take(sizes[5]),
        };
        let images = read_f32(&dir.join("testset_images.f32"))?;
        let labels = std::fs::read(dir.join("testset_labels.u8"))
            .context("read testset_labels.u8")?;
        let n = labels.len();
        ensure!(images.len() == n * IMG * IMG, "testset images/labels mismatch");
        Ok(Self {
            weights,
            testset: TestSet { images, labels, n },
            exact_accuracy: artifacts.exact_test_accuracy,
        })
    }

    /// Forward pass for a batch of images through the approximate datapath.
    /// `images` is [b,16,16,1] row-major. Returns logits [b,NUM_CLASSES].
    pub fn forward(&self, dp: &ApproxDatapath, images: &[f32], b: usize) -> Vec<f32> {
        let w = &self.weights;
        // conv1: 16x16x1 -> 16x16x8, relu, pool -> 8x8x8
        let c1 = conv2d_same(dp, images, b, IMG, IMG, 1, &w.conv1_w, &w.conv1_b, 8);
        let p1 = maxpool2(&relu(c1), b, IMG, IMG, 8);
        // conv2: 8x8x8 -> 8x8x16, relu, pool -> 4x4x16
        let c2 = conv2d_same(dp, &p1, b, 8, 8, 8, &w.conv2_w, &w.conv2_b, 16);
        let p2 = maxpool2(&relu(c2), b, 8, 8, 16);
        // fc: 256 -> 5
        let mut logits = dp.matmul(&p2, &w.fc_w, b, 256, NUM_CLASSES);
        for row in logits.chunks_mut(NUM_CLASSES) {
            for (x, bias) in row.iter_mut().zip(&w.fc_b) {
                *x += bias;
            }
        }
        logits
    }

    /// Top-1 accuracy of a multiplier datapath over the whole test set.
    pub fn accuracy(&self, dp: &ApproxDatapath) -> f64 {
        let n = self.testset.n;
        let mut correct = 0usize;
        // Batch to keep im2col buffers small.
        let bs = 64;
        for start in (0..n).step_by(bs) {
            let b = bs.min(n - start);
            let imgs = &self.testset.images[start * IMG * IMG..(start + b) * IMG * IMG];
            let logits = self.forward(dp, imgs, b);
            for i in 0..b {
                let row = &logits[i * NUM_CLASSES..(i + 1) * NUM_CLASSES];
                let pred = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0;
                if pred == self.testset.labels[start + i] as usize {
                    correct += 1;
                }
            }
        }
        correct as f64 / n as f64
    }
}

fn relu(mut v: Vec<f32>) -> Vec<f32> {
    for x in &mut v {
        if *x < 0.0 {
            *x = 0.0;
        }
    }
    v
}

/// 'same' 3x3 conv via im2col + approx matmul; patch order (dy,dx,c) matches
/// model.im2col.
#[allow(clippy::too_many_arguments)]
fn conv2d_same(
    dp: &ApproxDatapath,
    x: &[f32],
    b: usize,
    h: usize,
    wd: usize,
    cin: usize,
    weights: &[f32], // [3,3,cin,cout]
    bias: &[f32],
    cout: usize,
) -> Vec<f32> {
    let k = 3usize;
    let pad = 1usize;
    let patch = k * k * cin;
    let mut cols = vec![0f32; b * h * wd * patch];
    for bi in 0..b {
        for y in 0..h {
            for xx in 0..wd {
                let row = ((bi * h + y) * wd + xx) * patch;
                let mut p = 0usize;
                for dy in 0..k {
                    for dx in 0..k {
                        let sy = y as isize + dy as isize - pad as isize;
                        let sx = xx as isize + dx as isize - pad as isize;
                        for c in 0..cin {
                            cols[row + p] = if sy >= 0
                                && sy < h as isize
                                && sx >= 0
                                && sx < wd as isize
                            {
                                x[((bi * h + sy as usize) * wd + sx as usize) * cin + c]
                            } else {
                                0.0
                            };
                            p += 1;
                        }
                    }
                }
            }
        }
    }
    // weights [3,3,cin,cout] flatten to [patch, cout] in the same (dy,dx,c)
    // order — the natural row-major flattening.
    let mut out = dp.matmul(&cols, weights, b * h * wd, patch, cout);
    for row in out.chunks_mut(cout) {
        for (v, bb) in row.iter_mut().zip(bias) {
            *v += bb;
        }
    }
    out
}

/// 2x2 max pooling, NHWC.
fn maxpool2(x: &[f32], b: usize, h: usize, w: usize, c: usize) -> Vec<f32> {
    let (oh, ow) = (h / 2, w / 2);
    let mut out = vec![f32::NEG_INFINITY; b * oh * ow * c];
    for bi in 0..b {
        for y in 0..oh {
            for xx in 0..ow {
                for ch in 0..c {
                    let mut m = f32::NEG_INFINITY;
                    for dy in 0..2 {
                        for dx in 0..2 {
                            let v = x[((bi * h + 2 * y + dy) * w + 2 * xx + dx) * c + ch];
                            if v > m {
                                m = v;
                            }
                        }
                    }
                    out[((bi * oh + y) * ow + xx) * c + ch] = m;
                }
            }
        }
    }
    out
}

fn read_f32(path: &Path) -> Result<Vec<f32>> {
    let bytes = std::fs::read(path).with_context(|| format!("read {}", path.display()))?;
    ensure!(bytes.len() % 4 == 0, "{}: not a multiple of 4 bytes", path.display());
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::{library, EXACT_ID};

    #[test]
    fn bf16_round_known_values() {
        assert_eq!(bf16_round(1.0), 1.0);
        assert_eq!(bf16_round(0.0), 0.0);
        // 1.00390625 = 1 + 2^-8 rounds to 1.0 in bf16 (RNE ties-to-even).
        assert_eq!(bf16_round(1.00390625), 1.0);
        // 1.0078125 = 1 + 2^-7 is exactly representable.
        assert_eq!(bf16_round(1.0078125), 1.0078125);
        assert_eq!(bf16_round(-2.5), -2.5);
    }

    #[test]
    fn pow2_exact_matches_f64() {
        for e in -250..=250 {
            let got = pow2_exact(e) as f64;
            let want = 2f64.powi(e);
            // Representable range of f32 (incl. denormals handled by chain).
            if (-126..=127).contains(&e) {
                assert_eq!(got, want, "e={e}");
            }
        }
    }

    #[test]
    fn exact_datapath_matches_bf16_product() {
        let lib = library();
        let dp = ApproxDatapath::new(&lib[EXACT_ID]);
        let vals = [0.0f32, 1.0, -1.5, 0.3, 7.25, -100.0, 3.1415926, 1e-3];
        for &a in &vals {
            for &b in &vals {
                let want = bf16_round(a) * bf16_round(b);
                let got = dp.mul(a, b);
                assert_eq!(got, want, "mul({a},{b})");
            }
        }
    }

    #[test]
    fn matmul_exact_lut_matches_naive() {
        let lib = library();
        let dp = ApproxDatapath::new(&lib[EXACT_ID]);
        let a: Vec<f32> = (0..6).map(|i| i as f32 * 0.5 - 1.0).collect(); // 2x3
        let b: Vec<f32> = (0..12).map(|i| (i as f32).sin()).collect(); // 3x4
        let got = dp.matmul(&a, &b, 2, 3, 4);
        for i in 0..2 {
            for j in 0..4 {
                let mut want = 0f32;
                for k in 0..3 {
                    want += bf16_round(a[i * 3 + k]) * bf16_round(b[k * 4 + j]);
                }
                assert!((got[i * 4 + j] - want).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn truncated_datapath_underestimates_magnitude() {
        let lib = library();
        let trunc = lib.iter().find(|m| m.name() == "TRUNC4").unwrap();
        let dp_t = ApproxDatapath::new(trunc);
        let dp_e = ApproxDatapath::new(&lib[EXACT_ID]);
        for (a, b) in [(1.7f32, 2.3f32), (0.9, -0.4), (-3.3, -1.1)] {
            assert!(dp_t.mul(a, b).abs() <= dp_e.mul(a, b).abs() + 1e-9);
        }
    }

    #[test]
    fn maxpool_hand_case() {
        // 1x4x4x1 ascending values.
        let x: Vec<f32> = (0..16).map(|i| i as f32).collect();
        let out = maxpool2(&x, 1, 4, 4, 1);
        assert_eq!(out, vec![5.0, 7.0, 13.0, 15.0]);
    }

    #[test]
    fn conv_identity_kernel_preserves_input() {
        // 3x3 kernel with only the center tap = 1 reproduces the input.
        let lib = library();
        let dp = ApproxDatapath::new(&lib[EXACT_ID]);
        let x: Vec<f32> = (0..16).map(|i| (i as f32) * 0.125).collect(); // 1x4x4x1
        let mut w = vec![0f32; 9];
        w[4] = 1.0; // center (dy=1,dx=1)
        let out = conv2d_same(&dp, &x, 1, 4, 4, 1, &w, &[0.0], 1);
        for (got, want) in out.iter().zip(&x) {
            assert!((got - bf16_round(*want)).abs() < 1e-6);
        }
    }
}
