//! Front **presentation and cross-campaign merging**: the printable
//! Pareto/aggregate tables for one archive, and the `carbon3d front merge`
//! view that folds the fronts of several stores — possibly run under
//! different objectives or deployments — into one non-dominated set, each
//! point tagged with its source store and objective.

use std::collections::BTreeMap;

use std::path::Path;

use anyhow::{ensure, Result};

use crate::util::{table, Table};

use super::pareto::{dominates, ArchivePoint, CampaignArchive, CarbonAxis, GroupBy};
use super::store::ResultStore;

impl CampaignArchive {
    /// The cross-scenario Pareto front as a printable table.
    pub fn pareto_table(&self) -> Table {
        let mut t = Table::new(vec![
            "scenario", "mult", "carbon_g", "lifetime_g", "delay_ms", "drop_pp", "cdp",
        ]);
        for &i in &self.front {
            let p = &self.points[i];
            t.row(vec![
                p.key.clone(),
                p.mult.clone(),
                table::fmt(p.carbon_g),
                table::fmt(p.lifetime_gco2),
                format!("{:.3}", p.delay_s * 1e3),
                format!("{:.2}", p.drop_pct),
                format!("{:.4}", p.cdp),
            ]);
        }
        t
    }

    /// Aggregate summary per node or per workload: scenario count, how many
    /// sit on the cross-scenario front, carbon/cdp extremes and means.
    pub fn aggregate_table(&self, by: GroupBy) -> Table {
        let label = match by {
            GroupBy::Node => "node",
            GroupBy::Model => "model",
        };
        let mut groups: BTreeMap<String, Vec<usize>> = BTreeMap::new();
        for (i, p) in self.points.iter().enumerate() {
            let g = match by {
                GroupBy::Node => p.node.clone(),
                GroupBy::Model => p.model.clone(),
            };
            groups.entry(g).or_default().push(i);
        }
        let mut t = Table::new(vec![
            label, "jobs", "on_front", "min_carbon_g", "mean_carbon_g", "best_cdp", "min_delay_ms",
        ]);
        for (g, idxs) in &groups {
            let carbons: Vec<f64> = idxs.iter().map(|&i| self.points[i].carbon_g).collect();
            let min_c = carbons.iter().cloned().fold(f64::INFINITY, f64::min);
            let mean_c = carbons.iter().sum::<f64>() / carbons.len() as f64;
            let best_cdp =
                idxs.iter().map(|&i| self.points[i].cdp).fold(f64::INFINITY, f64::min);
            let min_delay =
                idxs.iter().map(|&i| self.points[i].delay_s).fold(f64::INFINITY, f64::min);
            let on_front = idxs.iter().filter(|&&i| self.front.contains(&i)).count();
            t.row(vec![
                g.clone(),
                idxs.len().to_string(),
                on_front.to_string(),
                table::fmt(min_c),
                table::fmt(mean_c),
                format!("{:.4}", best_cdp),
                format!("{:.3}", min_delay * 1e3),
            ]);
        }
        t
    }
}

/// One point of a merged cross-campaign front, tagged with the store it
/// came from (its objective travels inside the [`ArchivePoint`]).
#[derive(Debug, Clone)]
pub struct MergedPoint {
    pub point: ArchivePoint,
    pub store: String,
}

/// The cross-campaign front: the union of several stores' fronts with
/// dominance re-resolved on one shared carbon axis.
#[derive(Debug, Clone)]
pub struct MergedFront {
    pub axis: CarbonAxis,
    /// Union of the source fronts (every candidate, tagged by store).
    pub points: Vec<MergedPoint>,
    /// Indices into `points` that survive cross-campaign dominance.
    pub front: Vec<usize>,
}

/// Merge the fronts of several archives into one non-dominated set on
/// `axis`. Each source archive's front must already be computed on the
/// same axis (use [`CampaignArchive::from_rows_on`]; a mismatch is a loud
/// error): a point dominated within its own store on that axis can never
/// resurface in the union, so merging fronts — rather than full stores —
/// loses nothing.
pub fn merge_fronts(
    sources: &[(String, CampaignArchive)],
    axis: CarbonAxis,
) -> Result<MergedFront> {
    let mut points: Vec<MergedPoint> = Vec::new();
    for (label, arch) in sources {
        ensure!(
            arch.axis == axis,
            "front of {label} was computed on the {} carbon axis, not {} — rebuild it \
             with CampaignArchive::from_rows_on",
            arch.axis.name(),
            axis.name()
        );
        for &i in &arch.front {
            points.push(MergedPoint { point: arch.points[i].clone(), store: label.clone() });
        }
    }
    let front = (0..points.len())
        .filter(|&i| {
            points
                .iter()
                .enumerate()
                .all(|(j, other)| j == i || !dominates(axis, &other.point, &points[i].point))
        })
        .collect();
    Ok(MergedFront { axis, points, front })
}

/// Load each store's rows and merge their fronts on `axis` — the
/// `carbon3d front merge` entry point. Store labels are the file names.
pub fn merge_store_fronts(paths: &[String], axis: CarbonAxis) -> Result<MergedFront> {
    let mut sources = Vec::new();
    for path in paths {
        ensure!(Path::new(path).exists(), "store {path} does not exist");
        let store = ResultStore::open(Path::new(path))?;
        let arch = CampaignArchive::from_rows_on(store.rows(), axis)?;
        sources.push((path.clone(), arch));
    }
    merge_fronts(&sources, axis)
}

impl MergedFront {
    /// The merged front as a printable table, one row per surviving point,
    /// tagged with source store and objective.
    pub fn table(&self) -> Table {
        let mut t = Table::new(vec![
            "scenario", "store", "objective", "mult", "carbon_g", "lifetime_g", "delay_ms",
            "drop_pp",
        ]);
        for &i in &self.front {
            let mp = &self.points[i];
            let p = &mp.point;
            t.row(vec![
                p.key.clone(),
                mp.store.clone(),
                p.objective.clone(),
                p.mult.clone(),
                table::fmt(p.carbon_g),
                table::fmt(p.lifetime_gco2),
                format!("{:.3}", p.delay_s * 1e3),
                format!("{:.2}", p.drop_pct),
            ]);
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::campaign::pareto::tests::row;
    use crate::util::Json;

    fn tagged(key: &str, objective: &str, c: f64, life: f64, d: f64, a: f64) -> Json {
        let mut r = row(key, "m", "14nm", c, d, a);
        if let Json::Obj(map) = &mut r {
            map.insert("objective".to_string(), Json::from(objective));
            map.insert("lifetime_gco2".to_string(), Json::from(life));
        }
        r
    }

    #[test]
    fn aggregates_group_and_count() {
        let rows = vec![
            row("a", "vgg16", "14nm", 10.0, 1.0, 1.0),
            row("b", "resnet50", "14nm", 20.0, 2.0, 1.0),
            row("c", "vgg16", "7nm", 8.0, 3.0, 1.0),
        ];
        let arch = CampaignArchive::from_rows(&rows).unwrap();
        let t = arch.aggregate_table(GroupBy::Node);
        assert_eq!(t.n_rows(), 2); // 14nm, 7nm
        let t = arch.aggregate_table(GroupBy::Model);
        assert_eq!(t.n_rows(), 2); // vgg16, resnet50
    }

    #[test]
    fn merged_front_resolves_dominance_across_stores() {
        // Store A (embodied campaign): one strong, one weak point.
        let a = vec![
            tagged("a1", "embodied-cdp", 5.0, 50.0, 1.0, 1.0),
            tagged("a2", "embodied-cdp", 9.0, 90.0, 3.0, 3.0),
        ];
        // Store B (lifetime campaign): trades against a1 on the lifetime
        // axis (a2 is already dominated by a1 inside store A).
        let b = vec![tagged("b1", "lifetime-cdp", 6.0, 40.0, 2.0, 0.5)];
        let axis = CarbonAxis::Lifetime;
        let sources = vec![
            ("a.jsonl".to_string(), CampaignArchive::from_rows_on(&a, axis).unwrap()),
            ("b.jsonl".to_string(), CampaignArchive::from_rows_on(&b, axis).unwrap()),
        ];
        // A source front computed on the wrong axis is refused loudly.
        let e = merge_fronts(&sources, CarbonAxis::Embodied).unwrap_err();
        assert!(format!("{e:#}").contains("carbon axis"), "{e:#}");
        let merged = merge_fronts(&sources, axis).unwrap();
        let mut keys: Vec<&str> =
            merged.front.iter().map(|&i| merged.points[i].point.key.as_str()).collect();
        keys.sort();
        // a2 fell inside store A's own front; a1 and b1 trade across stores.
        assert_eq!(keys, vec!["a1", "b1"]);
        // Tags survive the merge: each survivor knows its store+objective.
        for &i in &merged.front {
            let mp = &merged.points[i];
            match mp.point.key.as_str() {
                "a1" => {
                    assert_eq!(mp.store, "a.jsonl");
                    assert_eq!(mp.point.objective, "embodied-cdp");
                }
                "b1" => {
                    assert_eq!(mp.store, "b.jsonl");
                    assert_eq!(mp.point.objective, "lifetime-cdp");
                }
                other => panic!("unexpected survivor {other}"),
            }
        }
        let rendered = merged.table().render();
        assert!(rendered.contains("lifetime-cdp"), "{rendered}");
        assert!(rendered.contains("a.jsonl"), "{rendered}");
    }

    #[test]
    fn merge_store_fronts_reads_stores_from_disk() {
        let dir = std::env::temp_dir();
        let pa = dir.join(format!("carbon3d-front-merge-a-{}.jsonl", std::process::id()));
        let pb = dir.join(format!("carbon3d-front-merge-b-{}.jsonl", std::process::id()));
        for (p, rows) in [
            (&pa, vec![tagged("a1", "embodied-cdp", 5.0, 50.0, 1.0, 1.0)]),
            (&pb, vec![tagged("b1", "lifetime-cdp", 6.0, 40.0, 2.0, 0.5)]),
        ] {
            let _ = std::fs::remove_file(p);
            let text: String =
                rows.iter().map(|r| format!("{}\n", r.dumps())).collect();
            std::fs::write(p, text).unwrap();
        }
        let merged = merge_store_fronts(
            &[pa.display().to_string(), pb.display().to_string()],
            CarbonAxis::Lifetime,
        )
        .unwrap();
        assert_eq!(merged.front.len(), 2);
        let _ = std::fs::remove_file(&pa);
        let _ = std::fs::remove_file(&pb);
    }
}
