//! Live campaign status snapshot: `<store>.status.json`
//! (DESIGN.md §8.5).
//!
//! On every heartbeat tick and archive checkpoint the commit pipeline
//! rewrites one small JSON document — jobs done/pruned/total, commit
//! rate and ETA, current Pareto-front size, per-phase time shares, and
//! cache/lease counters — atomically (temp + rename, the same
//! [`crate::campaign::checkpoint::write_atomic`] discipline as the
//! front sidecar), so an operator can `cat`/poll it mid-run without
//! ever seeing a torn file. It is on by default (pure observability:
//! the store, front, and report stay byte-identical — CI-gated) and
//! disabled with `CARBON3D_STATUS=0` or `--no-status`.
//!
//! `carbon3d trace metrics <status.json>` renders the same document in
//! Prometheus text exposition format — the designed seam for the
//! ROADMAP's future `carbon3d serve /status` endpoint, which will serve
//! exactly this payload.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};

use anyhow::{Context, Result};

use crate::util::json::{obj, Json};

use super::metrics::metrics;
use super::sink::{hit_rate, Heartbeat};

/// Status document schema identifier.
pub const STATUS_SCHEMA: &str = "carbon3d-status/1";

/// The campaign phases broken out as time shares in the status document
/// and `CampaignReport::line()` — the layers a job's wall clock divides
/// into, plus the adaptive planner's surrogate refits.
pub const PHASES: [&str; 5] =
    ["ga.run", "mapper.search", "service.eval", "commit.row", "surrogate.fit"];

static FORCE_OFF: AtomicBool = AtomicBool::new(false);

/// Programmatic kill switch (`--no-status`); composes with the
/// `CARBON3D_STATUS=0` environment override.
pub fn set_enabled(on: bool) {
    FORCE_OFF.store(!on, Ordering::Relaxed);
}

/// Whether status snapshots are enabled for this process.
pub fn enabled() -> bool {
    !FORCE_OFF.load(Ordering::Relaxed)
        && std::env::var("CARBON3D_STATUS").map(|v| v != "0").unwrap_or(true)
}

/// The sidecar path for a store: `campaign.jsonl` -> `campaign.status.json`
/// (shard stores get their own, e.g. `campaign.shard0of2.status.json`).
pub fn status_path(store: &Path) -> PathBuf {
    store.with_extension("status.json")
}

/// Writes `<store>.status.json` snapshots. Constructed once per campaign
/// by the executor core; the commit pipeline drives it.
#[derive(Debug, Clone)]
pub struct StatusWriter {
    path: PathBuf,
    store: String,
    shard: Option<String>,
}

impl StatusWriter {
    /// Build a writer unconditionally (tests, tooling).
    pub fn new(store: &Path, shard: Option<String>) -> Self {
        Self { path: status_path(store), store: store.display().to_string(), shard }
    }

    /// Build a writer iff status snapshots are enabled.
    pub fn create(store: &Path, shard: Option<String>) -> Option<Self> {
        enabled().then(|| Self::new(store, shard))
    }

    /// The status-document path this writer rewrites.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Atomically rewrite the snapshot. `state` is `"running"` or
    /// `"done"`. Errors are reported, not fatal — callers drop them:
    /// status is pure observability and must never kill a campaign.
    pub fn write(&self, state: &str, h: &Heartbeat, front_size: usize) -> Result<()> {
        crate::campaign::fault::point("status.write")?;
        let doc = self.document(state, h, front_size);
        crate::campaign::checkpoint::write_atomic(&self.path, &format!("{}\n", doc.pretty(2)))
            .with_context(|| format!("writing status {}", self.path.display()))
    }

    /// Assemble the status document from a progress heartbeat plus the
    /// process metrics registry (same sources as the stderr heartbeat,
    /// so both always agree).
    pub fn document(&self, state: &str, h: &Heartbeat, front_size: usize) -> Json {
        let m = metrics();
        let mapper = (m.counter("mapper_cache_hits"), m.counter("mapper_cache_misses"));
        let memo = (m.counter("ga_memo_hits"), m.counter("ga_memo_misses"));
        let (svc_hits, svc_served) =
            (m.counter("service_cache_hits"), m.counter("service_served"));
        let snap = m.snapshot();
        let phase_total: u64 =
            PHASES.iter().filter_map(|p| snap.histogram(p)).map(|h| h.sum).sum();
        let shares = PHASES
            .iter()
            .map(|&p| {
                let sum = snap.histogram(p).map(|h| h.sum).unwrap_or(0);
                let share =
                    if phase_total > 0 { sum as f64 / phase_total as f64 } else { 0.0 };
                (p.to_string(), Json::from(share))
            })
            .collect();
        let cache = |hits: u64, total: u64, total_key: &str, total_v: u64| {
            obj([
                ("hits", Json::from(hits as f64)),
                (total_key, Json::from(total_v as f64)),
                ("hit_rate", Json::from(hit_rate(hits, total))),
            ])
        };
        obj([
            ("schema", Json::from(STATUS_SCHEMA)),
            ("state", Json::from(state)),
            ("pid", Json::from(std::process::id() as f64)),
            ("store", Json::from(self.store.as_str())),
            ("shard", self.shard.as_deref().map(Json::from).unwrap_or(Json::Null)),
            ("jobs_done", Json::from(h.done)),
            ("jobs_pruned", Json::from(h.pruned)),
            ("jobs_deferred", Json::from(h.deferred)),
            ("slots_committed", Json::from(h.committed)),
            ("slots_total", Json::from(h.scheduled)),
            ("jobs_per_s", Json::from(h.jobs_per_s())),
            ("eta_s", Json::from(h.eta_s())),
            ("elapsed_s", Json::from(h.elapsed_s)),
            ("front_size", Json::from(front_size)),
            ("phase_shares", Json::Obj(shares)),
            (
                "caches",
                obj([
                    ("mapper", cache(mapper.0, mapper.0 + mapper.1, "misses", mapper.1)),
                    ("service", cache(svc_hits, svc_served, "served", svc_served)),
                    ("ga_memo", cache(memo.0, memo.0 + memo.1, "misses", memo.1)),
                ]),
            ),
            (
                "lease",
                obj([
                    ("reclaims", Json::from(m.counter("lease_reclaims") as f64)),
                    ("unavailable", Json::from(m.counter("lease_unavailable") as f64)),
                ]),
            ),
        ])
    }
}

/// Render a status document in Prometheus text exposition format
/// (`carbon3d trace metrics <status.json>`).
pub fn prometheus_text(doc: &Json) -> Result<String> {
    let schema = doc.get("schema")?.as_str()?;
    anyhow::ensure!(
        schema == STATUS_SCHEMA,
        "status schema {schema:?} != expected {STATUS_SCHEMA:?}"
    );
    let num = |key: &str| -> Result<String> { Ok(doc.get(key)?.dumps()) };
    let mut out = String::new();
    let state = doc.get("state")?.as_str()?.to_string();
    let shard = match doc.get("shard")? {
        Json::Str(s) => s.clone(),
        _ => String::new(),
    };
    out.push_str("# TYPE carbon3d_status_info gauge\n");
    out.push_str(&format!(
        "carbon3d_status_info{{state=\"{state}\",shard=\"{shard}\",pid=\"{}\"}} 1\n",
        num("pid")?
    ));
    for (key, metric) in [
        ("jobs_done", "carbon3d_jobs_done"),
        ("jobs_pruned", "carbon3d_jobs_pruned"),
        ("jobs_deferred", "carbon3d_jobs_deferred"),
        ("slots_committed", "carbon3d_slots_committed"),
        ("slots_total", "carbon3d_slots_total"),
        ("jobs_per_s", "carbon3d_jobs_per_second"),
        ("eta_s", "carbon3d_eta_seconds"),
        ("elapsed_s", "carbon3d_elapsed_seconds"),
        ("front_size", "carbon3d_front_size"),
    ] {
        out.push_str(&format!("# TYPE {metric} gauge\n{metric} {}\n", num(key)?));
    }
    out.push_str("# TYPE carbon3d_phase_share gauge\n");
    for (phase, share) in doc.get("phase_shares")?.as_obj()? {
        out.push_str(&format!(
            "carbon3d_phase_share{{phase=\"{phase}\"}} {}\n",
            share.dumps()
        ));
    }
    out.push_str("# TYPE carbon3d_cache_hit_rate gauge\n");
    for (cache, counts) in doc.get("caches")?.as_obj()? {
        out.push_str(&format!(
            "carbon3d_cache_hit_rate{{cache=\"{cache}\"}} {}\n",
            counts.get("hit_rate")?.dumps()
        ));
    }
    for (key, metric) in
        [("reclaims", "carbon3d_lease_reclaims"), ("unavailable", "carbon3d_lease_unavailable")]
    {
        out.push_str(&format!(
            "# TYPE {metric} counter\n{metric} {}\n",
            doc.get("lease")?.get(key)?.dumps()
        ));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn beat() -> Heartbeat {
        Heartbeat { done: 3, pruned: 1, deferred: 0, committed: 4, scheduled: 8, elapsed_s: 2.0 }
    }

    #[test]
    fn snapshot_writes_atomically_and_round_trips() {
        let store = std::env::temp_dir()
            .join(format!("carbon3d-status-{}.jsonl", std::process::id()));
        let w = StatusWriter::new(&store, Some("0/2".into()));
        assert_eq!(w.path(), status_path(&store));
        w.write("running", &beat(), 5).unwrap();
        let doc = Json::parse(&std::fs::read_to_string(w.path()).unwrap()).unwrap();
        assert_eq!(doc.get("schema").unwrap().as_str().unwrap(), STATUS_SCHEMA);
        assert_eq!(doc.get("state").unwrap().as_str().unwrap(), "running");
        assert_eq!(doc.get("shard").unwrap().as_str().unwrap(), "0/2");
        assert_eq!(doc.get("jobs_done").unwrap().as_usize().unwrap(), 3);
        assert_eq!(doc.get("slots_total").unwrap().as_usize().unwrap(), 8);
        assert_eq!(doc.get("front_size").unwrap().as_usize().unwrap(), 5);
        // jobs_per_s = 4 committed / 2s; eta = 4 remaining / 2 per s.
        assert_eq!(doc.get("jobs_per_s").unwrap().as_f64().unwrap(), 2.0);
        assert_eq!(doc.get("eta_s").unwrap().as_f64().unwrap(), 2.0);
        let shares = doc.get("phase_shares").unwrap().as_obj().unwrap();
        assert_eq!(shares.len(), PHASES.len());
        std::fs::remove_file(w.path()).unwrap();
    }

    #[test]
    fn prometheus_rendering_carries_the_headline_series() {
        let w = StatusWriter::new(Path::new("/tmp/x.jsonl"), None);
        let doc = w.document("done", &beat(), 2);
        let text = prometheus_text(&doc).unwrap();
        assert!(text.contains("carbon3d_jobs_done 3\n"), "{text}");
        assert!(text.contains("carbon3d_front_size 2\n"), "{text}");
        assert!(text.contains("carbon3d_status_info{state=\"done\""), "{text}");
        assert!(text.contains("carbon3d_phase_share{phase=\"ga.run\"}"), "{text}");
        assert!(text.contains("carbon3d_cache_hit_rate{cache=\"mapper\"}"), "{text}");
        // Wrong schema is refused.
        assert!(prometheus_text(&obj([("schema", Json::from("nope/1"))])).is_err());
    }
}
