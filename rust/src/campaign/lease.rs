//! File-based **claim/lease protocol** for sharded campaigns: a
//! `<store>.leases/` directory shared by every `--shard i/N` process, one
//! small JSON file per claimed job.
//!
//! Protocol:
//! - **Claim** — atomic `create_new` of the job's lease file. Exactly one
//!   process can win; everyone else sees the file and moves on.
//! - **Done** — after committing the row, the holder rewrites the lease
//!   with `done: true` (temp file + rename). Done leases are permanent:
//!   they are never reclaimed, so finished work is never redone.
//! - **Expiry** — a lease that is not done and older than the TTL marks a
//!   crashed holder. Reclaim renames the stale file away (rename is
//!   atomic, so exactly one contender wins) and re-claims fresh.
//!
//! Correctness never rests on the leases alone: jobs are idempotent (GA
//! seeds derive from the job *key*), so if a presumed-dead holder was
//! merely slow and finishes anyway, both processes commit byte-identical
//! rows to their own shard stores and the merge step deduplicates them.
//! Leases only prevent *systematic* duplicate work; the TTL should exceed
//! the worst-case single-job time.

use std::fs::OpenOptions;
use std::io::Write as _;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::{obj, Json};

use super::clock::Clock;
use super::fault;
use super::spec::fnv1a64;

/// Outcome of a claim attempt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Claim {
    /// This process now holds the lease and must evaluate the job.
    Acquired,
    /// Another holder has it (live or done) — skip the job.
    Unavailable,
}

/// Handle to a shared lease directory.
pub struct LeaseDir {
    dir: PathBuf,
    holder: String,
    ttl_s: u64,
    clock: Clock,
}

impl LeaseDir {
    /// The lease directory companion of a canonical store path
    /// (`campaign.jsonl` -> `campaign.jsonl.leases/`).
    pub fn for_store(canonical: &Path) -> PathBuf {
        let mut os = canonical.as_os_str().to_os_string();
        os.push(".leases");
        PathBuf::from(os)
    }

    /// Open (creating if needed) a lease directory as `holder`. Holder ids
    /// should be unique per process (e.g. include the pid): expiry tells
    /// crashed incarnations apart by age, not by name.
    pub fn open(dir: PathBuf, holder: String, ttl_s: u64) -> Result<Self> {
        Self::open_with_clock(dir, holder, ttl_s, Clock::default())
    }

    /// [`LeaseDir::open`] with an injected clock, so TTL-expiry and
    /// reclaim tests run against a fake clock instead of sleeping or
    /// back-dating lease files.
    pub fn open_with_clock(dir: PathBuf, holder: String, ttl_s: u64, clock: Clock) -> Result<Self> {
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("create lease directory {}", dir.display()))?;
        Ok(Self { dir, holder, ttl_s, clock })
    }

    /// Lease file for a job key. The key is hashed — keys contain path
    /// separators — and stored inside the file for human inspection.
    fn lease_path(&self, key: &str) -> PathBuf {
        self.dir.join(format!("{:016x}.lease", fnv1a64(key.as_bytes())))
    }

    fn lease_doc(&self, key: &str, done: bool) -> Json {
        obj([
            ("key", Json::from(key)),
            ("holder", Json::from(self.holder.clone())),
            ("created_s", Json::from(self.clock.now_s() as usize)),
            ("done", Json::from(done)),
        ])
    }

    /// Try to claim `key`: atomic create wins; an existing lease blocks
    /// unless it is expired (not done + older than the TTL), in which case
    /// it is evicted and re-claimed — exactly one contender can win the
    /// eviction because it goes through an atomic rename.
    pub fn try_claim(&self, key: &str) -> Result<Claim> {
        fault::point("lease.claim")?;
        let path = self.lease_path(key);
        // Two attempts: the second runs only after this process evicted an
        // expired lease; losing the re-create race then means another
        // claimant got in first, which is a valid Unavailable.
        let mut reclaimed = false;
        for _ in 0..2 {
            match OpenOptions::new().write(true).create_new(true).open(&path) {
                Ok(mut f) => {
                    f.write_all(self.lease_doc(key, false).dumps().as_bytes())
                        .with_context(|| format!("write lease {}", path.display()))?;
                    self.note_claim(key, reclaimed);
                    return Ok(Claim::Acquired);
                }
                Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
                    if !self.expired(&path)? || !self.evict(&path) {
                        crate::obs::metrics().incr("lease_unavailable", 1);
                        return Ok(Claim::Unavailable);
                    }
                    reclaimed = true;
                }
                Err(e) => {
                    return Err(e).with_context(|| format!("claim lease {}", path.display()))
                }
            }
        }
        crate::obs::metrics().incr("lease_unavailable", 1);
        Ok(Claim::Unavailable)
    }

    /// Observability for a won claim: the `lease.claim` event (with the
    /// job key) and, when it went through an expired-lease eviction, the
    /// `lease_reclaims` counter.
    fn note_claim(&self, key: &str, reclaimed: bool) {
        if reclaimed {
            crate::obs::metrics().incr("lease_reclaims", 1);
        }
        crate::obs::event(
            "lease.claim",
            &[("key", Json::from(key)), ("reclaimed", Json::from(reclaimed))],
        );
    }

    /// Steal `key` only if an *expired* lease exists — the recovery path
    /// for jobs abandoned by a killed shard. A missing lease means the job
    /// belongs to a shard that has not reached it yet: not stealable.
    pub fn steal_expired(&self, key: &str) -> Result<Claim> {
        let path = self.lease_path(key);
        if !path.exists() || !self.expired(&path)? || !self.evict(&path) {
            return Ok(Claim::Unavailable);
        }
        match OpenOptions::new().write(true).create_new(true).open(&path) {
            Ok(mut f) => {
                f.write_all(self.lease_doc(key, false).dumps().as_bytes())
                    .with_context(|| format!("write lease {}", path.display()))?;
                self.note_claim(key, true);
                Ok(Claim::Acquired)
            }
            Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => Ok(Claim::Unavailable),
            Err(e) => Err(e).with_context(|| format!("steal lease {}", path.display())),
        }
    }

    /// Mark a held lease done (called after the row is committed). Done
    /// leases are permanent — no later process will redo the job. Written
    /// via temp file + atomic rename so a reader never sees a torn flag.
    pub fn mark_done(&self, key: &str) -> Result<()> {
        let path = self.lease_path(key);
        let tmp = PathBuf::from(format!("{}.tmp-{}", path.display(), std::process::id()));
        // Temp + rename is atomic, so a transient failure is retryable
        // without a reader ever seeing a torn done flag.
        fault::retry_io("lease.done", || -> Result<()> {
            fault::point("lease.done")?;
            std::fs::write(&tmp, self.lease_doc(key, true).dumps())
                .with_context(|| format!("write {}", tmp.display()))?;
            std::fs::rename(&tmp, &path)
                .with_context(|| format!("finalize lease {}", path.display()))
        })?;
        crate::obs::event("lease.done", &[("key", Json::from(key))]);
        Ok(())
    }

    /// Is the lease at `path` expired? Done leases never expire. A lease
    /// whose content is unreadable (a claimant crashed inside the initial
    /// write, or a concurrent reader caught it torn) ages by file mtime
    /// instead of the recorded timestamp.
    fn expired(&self, path: &Path) -> Result<bool> {
        if let Ok(text) = std::fs::read_to_string(path) {
            if let Ok(doc) = Json::parse(&text) {
                if matches!(doc.get("done"), Ok(Json::Bool(true))) {
                    return Ok(false);
                }
                if let Ok(created) = doc.get("created_s").and_then(|v| v.as_usize()) {
                    return Ok(self.clock.now_s().saturating_sub(created as u64) > self.ttl_s);
                }
            }
        }
        // Torn or vanished: fall back to mtime; a vanished file (eviction
        // race) reads as fresh, which safely resolves to Unavailable.
        let age = std::fs::metadata(path)
            .and_then(|m| m.modified())
            .ok()
            .and_then(|t| t.elapsed().ok())
            .map(|d| d.as_secs())
            .unwrap_or(0);
        Ok(age > self.ttl_s)
    }

    /// Test hook: plant a lease as a (possibly long-dead) foreign holder
    /// would have left it — `age_s` seconds old, done or not.
    #[cfg(test)]
    pub(crate) fn plant_for_test(&self, key: &str, age_s: u64, done: bool) {
        let doc = obj([
            ("key", Json::from(key)),
            ("holder", Json::from("dead-shard")),
            ("created_s", Json::from((self.clock.now_s().saturating_sub(age_s)) as usize)),
            ("done", Json::from(done)),
        ]);
        std::fs::write(self.lease_path(key), doc.dumps()).unwrap();
    }

    /// Atomically move an expired lease out of the way. Exactly one
    /// contender's rename succeeds; losers report `false` and back off.
    fn evict(&self, path: &Path) -> bool {
        let stale = PathBuf::from(format!("{}.stale-{}", path.display(), std::process::id()));
        if std::fs::rename(path, &stale).is_ok() {
            let _ = std::fs::remove_file(&stale);
            true
        } else {
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(name: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("carbon3d-leases-{}-{name}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn open(dir: &Path, holder: &str, ttl_s: u64) -> LeaseDir {
        LeaseDir::open(dir.to_path_buf(), holder.to_string(), ttl_s).unwrap()
    }

    /// Plant a lease file as a dead holder would have left it.
    fn plant(dir: &LeaseDir, key: &str, age_s: u64, done: bool) {
        dir.plant_for_test(key, age_s, done);
    }

    #[test]
    fn claim_is_exclusive() {
        let d = tmp_dir("exclusive");
        let a = open(&d, "a", 600);
        let b = open(&d, "b", 600);
        assert_eq!(a.try_claim("job1").unwrap(), Claim::Acquired);
        assert_eq!(b.try_claim("job1").unwrap(), Claim::Unavailable);
        assert_eq!(b.try_claim("job2").unwrap(), Claim::Acquired);
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn expired_lease_is_reclaimed_exactly_once() {
        let d = tmp_dir("reclaim");
        let a = open(&d, "a", 600);
        let b = open(&d, "b", 600);
        plant(&a, "job", 9_999, false);
        let m = crate::obs::metrics();
        let (reclaims0, unavail0) =
            (m.counter("lease_reclaims"), m.counter("lease_unavailable"));
        // First claimant wins the reclaim; the second sees a fresh lease.
        assert_eq!(a.try_claim("job").unwrap(), Claim::Acquired);
        assert_eq!(b.try_claim("job").unwrap(), Claim::Unavailable);
        assert_eq!(b.steal_expired("job").unwrap(), Claim::Unavailable);
        // The reclaim and the lost contention both land in the registry.
        assert!(m.counter("lease_reclaims") > reclaims0);
        assert!(m.counter("lease_unavailable") > unavail0);
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn done_leases_are_permanent() {
        let d = tmp_dir("done");
        let a = open(&d, "a", 600);
        assert_eq!(a.try_claim("job").unwrap(), Claim::Acquired);
        a.mark_done("job").unwrap();
        // Even a holder whose clock says everything expired cannot reclaim
        // a done lease (planting an ancient done lease proves the same).
        let b = open(&d, "b", 0);
        assert_eq!(b.try_claim("job").unwrap(), Claim::Unavailable);
        assert_eq!(b.steal_expired("job").unwrap(), Claim::Unavailable);
        plant(&b, "old", 9_999, true);
        assert_eq!(b.steal_expired("old").unwrap(), Claim::Unavailable);
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn steal_requires_an_existing_expired_lease() {
        let d = tmp_dir("steal");
        let a = open(&d, "a", 600);
        // No lease: the owning shard has not reached the job — not ours.
        assert_eq!(a.steal_expired("job").unwrap(), Claim::Unavailable);
        // Fresh lease: holder presumed alive.
        let b = open(&d, "b", 600);
        assert_eq!(b.try_claim("job").unwrap(), Claim::Acquired);
        assert_eq!(a.steal_expired("job").unwrap(), Claim::Unavailable);
        // Expired lease: stolen.
        plant(&a, "crashed", 9_999, false);
        assert_eq!(a.steal_expired("crashed").unwrap(), Claim::Acquired);
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn torn_lease_content_ages_by_mtime() {
        let d = tmp_dir("torn");
        let a = open(&d, "a", 600);
        std::fs::write(a.lease_path("job"), "{\"key\": \"job\", \"hold").unwrap();
        // Freshly torn: treated as live (mtime age ~0), not reclaimable.
        assert_eq!(a.try_claim("job").unwrap(), Claim::Unavailable);
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn ttl_expiry_is_deterministic_under_a_fake_clock() {
        use crate::campaign::clock::FakeClock;
        let d = tmp_dir("fake-clock");
        let fake = FakeClock::new(1_000_000);
        let a = LeaseDir::open_with_clock(d.clone(), "a".into(), 600, fake.clock()).unwrap();
        let b = LeaseDir::open_with_clock(d.clone(), "b".into(), 600, fake.clock()).unwrap();
        assert_eq!(a.try_claim("job").unwrap(), Claim::Acquired);
        // Inside the TTL: the holder is presumed alive, whole window long.
        assert_eq!(b.try_claim("job").unwrap(), Claim::Unavailable);
        fake.advance_s(600);
        assert_eq!(b.try_claim("job").unwrap(), Claim::Unavailable, "age == ttl is not expired");
        // One tick past the TTL: reclaimable, exactly once.
        fake.advance_s(1);
        assert_eq!(b.steal_expired("job").unwrap(), Claim::Acquired);
        assert_eq!(a.try_claim("job").unwrap(), Claim::Unavailable, "b's fresh lease blocks a");
        // Done leases stay permanent no matter how far time advances.
        b.mark_done("job").unwrap();
        fake.advance_s(1_000_000);
        assert_eq!(a.try_claim("job").unwrap(), Claim::Unavailable);
        assert_eq!(a.steal_expired("job").unwrap(), Claim::Unavailable);
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn injected_io_error_on_mark_done_is_retried() {
        use crate::campaign::fault;
        let _guard = fault::test_guard();
        let d = tmp_dir("fault-done");
        let a = open(&d, "a", 600);
        assert_eq!(a.try_claim("job").unwrap(), Claim::Acquired);
        fault::arm(vec![fault::FaultRule {
            site: "lease.done".into(),
            nth: 1,
            kind: fault::FaultKind::IoError,
        }]);
        let r = a.mark_done("job");
        fault::disarm();
        r.unwrap();
        // The done flag landed despite the injected first-attempt failure.
        let b = open(&d, "b", 0);
        assert_eq!(b.steal_expired("job").unwrap(), Claim::Unavailable);
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn lease_dir_path_derives_from_store() {
        let p = LeaseDir::for_store(Path::new("results/campaign.jsonl"));
        assert_eq!(p, Path::new("results/campaign.jsonl.leases"));
    }
}
