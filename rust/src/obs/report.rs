//! Trace sidecar reader: strict schema validation (`trace report
//! --check`) plus the per-phase breakdown, per-shard lane, and
//! top-K-slowest-jobs tables behind `carbon3d trace report`.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::obs::fmt::human_time;
use crate::util::json::Json;
use crate::util::table::Table;

use super::metrics::MetricsSnapshot;
use super::sink::SCHEMA;

/// One closed span parsed from a sidecar line.
#[derive(Debug, Clone)]
pub struct SpanRec {
    /// Span (and duration-histogram) name, dotted `layer.verb`.
    pub name: String,
    /// Name of the enclosing span on the same thread, if nested.
    pub parent: Option<String>,
    /// Nesting depth at close (0 = top-level).
    pub depth: usize,
    /// Owning job key when closed under an `obs::job_scope`.
    pub job: Option<String>,
    /// Start offset from the trace epoch, µs.
    pub t_us: u64,
    /// Duration, µs.
    pub dur_us: u64,
    /// Small per-process thread ordinal (not an OS tid).
    pub thread: u64,
    /// Lane tag stamped by `trace merge` (single-process sidecars carry
    /// the lane on the header instead).
    pub shard: Option<String>,
}

/// One point event parsed from a sidecar line.
#[derive(Debug, Clone)]
pub struct EventRec {
    /// Event (and counter) name, e.g. `lease.claim`.
    pub name: String,
    /// Offset from the trace epoch, µs.
    pub t_us: u64,
    /// Lane tag stamped by `trace merge`.
    pub shard: Option<String>,
    /// Free-form event payload (always a JSON object).
    pub fields: Json,
}

impl EventRec {
    /// Whether a boolean event field is present and true.
    pub fn flag(&self, key: &str) -> bool {
        matches!(self.fields.get(key), Ok(Json::Bool(true)))
    }
}

/// One live-progress heartbeat parsed from a sidecar line.
#[derive(Debug, Clone)]
pub struct HeartbeatRec {
    /// Offset from the trace epoch, µs.
    pub t_us: u64,
    /// Rows committed at emission time.
    pub done: u64,
    /// Jobs pruned at emission time.
    pub pruned: u64,
    /// Schedule slots committed at emission time.
    pub committed: u64,
    /// Total schedule slots.
    pub scheduled: u64,
    /// Lane tag stamped by `trace merge`.
    pub shard: Option<String>,
}

/// Per-lane aggregation of a (merged) trace — one row per shard worker.
#[derive(Debug, Clone, Default)]
pub struct LaneStats {
    /// Lane label: the shard tag, header shard, or `main`.
    pub label: String,
    /// Spans attributed to this lane.
    pub spans: usize,
    /// `job.eval` spans attributed to this lane.
    pub jobs: usize,
    /// Interval-merged `job.eval` wall clock for this lane, in µs.
    pub busy_us: u64,
    /// `lease.claim` events on this lane.
    pub claims: u64,
    /// Claims that reclaimed an expired lease (contention signal).
    pub reclaims: u64,
}

/// A fully parsed + validated trace sidecar.
#[derive(Debug, Clone)]
pub struct TraceReport {
    /// Schema identifier from the header (`carbon3d-trace/1`).
    pub schema: String,
    /// Result-store path the trace belongs to.
    pub store: String,
    /// Shard label from the header (`0/2`, `merge`), if sharded.
    pub shard: Option<String>,
    /// Recording process id (0 for a merged stream).
    pub pid: u64,
    /// Wall-clock anchor of `t_us` offsets (Unix ms). Optional: sidecars
    /// predating the observatory lack it; `trace merge` requires it.
    pub epoch_ms: Option<u64>,
    /// All closed spans, in file order.
    pub spans: Vec<SpanRec>,
    /// All point events, in file order.
    pub events: Vec<EventRec>,
    /// All live-progress heartbeats, in file order.
    pub beats: Vec<HeartbeatRec>,
    /// Number of `metrics` lines seen (one per contributing process).
    pub metrics_lines: usize,
    /// All `metrics` lines folded through [`super::Merge`] — the
    /// campaign-wide counter totals for a merged trace.
    pub final_metrics: Option<MetricsSnapshot>,
    /// Total sidecar line count.
    pub lines: usize,
}

fn req_num(v: &Json, key: &str) -> Result<f64> {
    v.get(key).with_context(|| format!("field {key:?}"))?.as_f64()
}

fn req_str(v: &Json, key: &str) -> Result<String> {
    Ok(v.get(key).with_context(|| format!("field {key:?}"))?.as_str()?.to_string())
}

fn opt_str(v: &Json, key: &str) -> Result<Option<String>> {
    match v.get(key).with_context(|| format!("field {key:?}"))? {
        Json::Null => Ok(None),
        Json::Str(s) => Ok(Some(s.clone())),
        other => bail!("field {key:?}: expected string or null, got {other:?}"),
    }
}

/// Like [`opt_str`], but the field may also be absent entirely (lane
/// tags only exist on merged sidecars, epoch only on current ones).
fn absent_ok_str(v: &Json, key: &str) -> Result<Option<String>> {
    match v.get(key) {
        Err(_) => Ok(None),
        Ok(Json::Null) => Ok(None),
        Ok(Json::Str(s)) => Ok(Some(s.clone())),
        Ok(other) => bail!("field {key:?}: expected string or null, got {other:?}"),
    }
}

/// Merged total length of a set of `(start, end)` intervals in µs —
/// overlaps (concurrent worker threads) count once.
pub(super) fn merged_interval_us(mut ivals: Vec<(u64, u64)>) -> u64 {
    ivals.sort_unstable();
    let mut covered = 0u64;
    let mut cur: Option<(u64, u64)> = None;
    for (a, b) in ivals {
        match &mut cur {
            Some((_, e)) if a <= *e => *e = (*e).max(b),
            _ => {
                if let Some((s, e)) = cur {
                    covered += e - s;
                }
                cur = Some((a, b));
            }
        }
    }
    if let Some((s, e)) = cur {
        covered += e - s;
    }
    covered
}

impl TraceReport {
    /// Parse and strictly validate a sidecar. Every line must be a JSON
    /// object of a known `kind` with all required fields; the first line
    /// must be a `header` carrying the expected schema version.
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading trace {}", path.display()))?;
        let mut report: Option<TraceReport> = None;
        for (i, line) in text.lines().enumerate() {
            let lineno = i + 1;
            let v = Json::parse(line)
                .with_context(|| format!("{}:{lineno}: invalid JSON", path.display()))?;
            (|| -> Result<()> {
                let kind = req_str(&v, "kind")?;
                match (kind.as_str(), &mut report) {
                    ("header", r @ None) => {
                        let schema = req_str(&v, "schema")?;
                        if schema != SCHEMA {
                            bail!("schema {schema:?} != expected {SCHEMA:?}");
                        }
                        *r = Some(TraceReport {
                            schema,
                            store: req_str(&v, "store")?,
                            shard: opt_str(&v, "shard")?,
                            pid: req_num(&v, "pid")? as u64,
                            epoch_ms: match v.get("epoch_ms") {
                                Ok(e) => Some(e.as_f64()? as u64),
                                Err(_) => None,
                            },
                            spans: Vec::new(),
                            events: Vec::new(),
                            beats: Vec::new(),
                            metrics_lines: 0,
                            final_metrics: None,
                            lines: 0,
                        });
                    }
                    ("header", Some(_)) => bail!("duplicate header line"),
                    (_, None) => bail!("first line must be a header"),
                    ("span", Some(r)) => r.spans.push(SpanRec {
                        name: req_str(&v, "name")?,
                        parent: opt_str(&v, "parent")?,
                        depth: req_num(&v, "depth")? as usize,
                        job: opt_str(&v, "job")?,
                        t_us: req_num(&v, "t_us")? as u64,
                        dur_us: req_num(&v, "dur_us")? as u64,
                        thread: req_num(&v, "thread")? as u64,
                        shard: absent_ok_str(&v, "shard")?,
                    }),
                    ("event", Some(r)) => r.events.push(EventRec {
                        name: req_str(&v, "name")?,
                        t_us: req_num(&v, "t_us")? as u64,
                        shard: absent_ok_str(&v, "shard")?,
                        fields: {
                            let f = v.get("fields")?;
                            f.as_obj()?;
                            f.clone()
                        },
                    }),
                    ("heartbeat", Some(r)) => {
                        for k in ["deferred", "jobs_per_s", "eta_s", "mapper_hit_rate",
                            "service_hit_rate"]
                        {
                            req_num(&v, k)?;
                        }
                        r.beats.push(HeartbeatRec {
                            t_us: req_num(&v, "t_us")? as u64,
                            done: req_num(&v, "done")? as u64,
                            pruned: req_num(&v, "pruned")? as u64,
                            committed: req_num(&v, "committed")? as u64,
                            scheduled: req_num(&v, "scheduled")? as u64,
                            shard: absent_ok_str(&v, "shard")?,
                        });
                    }
                    ("metrics", Some(r)) => {
                        req_num(&v, "t_us")?;
                        let snap = MetricsSnapshot::from_json(v.get("snapshot")?)?;
                        match &mut r.final_metrics {
                            Some(m) => super::Merge::merge(m, &snap),
                            none => *none = Some(snap),
                        }
                        r.metrics_lines += 1;
                    }
                    (k, Some(_)) => bail!("unknown line kind {k:?}"),
                }
                Ok(())
            })()
            .with_context(|| format!("{}:{lineno}", path.display()))?;
        }
        let mut r = match report {
            Some(r) => r,
            None => bail!("{}: empty trace (no header line)", path.display()),
        };
        r.lines = text.lines().count();
        Ok(r)
    }

    /// Wall clock covered by the trace in microseconds: the latest span
    /// end offset.
    pub fn wall_us(&self) -> u64 {
        self.spans.iter().map(|s| s.t_us + s.dur_us).max().unwrap_or(0)
    }

    /// The lane a record belongs to: its own tag (merged sidecars), else
    /// the header shard, else the single implicit lane.
    fn lane_label(&self, rec_shard: &Option<String>) -> String {
        rec_shard
            .clone()
            .or_else(|| self.shard.clone())
            .unwrap_or_else(|| "main".to_string())
    }

    /// Per-lane aggregation, one row per shard worker, sorted by label:
    /// span/job counts, interval-merged busy time, and lease claim /
    /// reclaim contention from the `lease.claim` events.
    pub fn lanes(&self) -> Vec<LaneStats> {
        let mut lanes: BTreeMap<String, (LaneStats, Vec<(u64, u64)>)> = BTreeMap::new();
        for s in &self.spans {
            let (stats, ivals) = lanes.entry(self.lane_label(&s.shard)).or_default();
            stats.spans += 1;
            if s.name == "job.eval" {
                stats.jobs += 1;
                ivals.push((s.t_us, s.t_us + s.dur_us));
            }
        }
        for e in &self.events {
            let (stats, _) = lanes.entry(self.lane_label(&e.shard)).or_default();
            if e.name == "lease.claim" {
                stats.claims += 1;
                if e.flag("reclaimed") {
                    stats.reclaims += 1;
                }
            }
        }
        lanes
            .into_iter()
            .map(|(label, (mut stats, ivals))| {
                stats.label = label;
                stats.busy_us = merged_interval_us(ivals);
                stats
            })
            .collect()
    }

    /// Per-phase aggregation (by span name, sorted by total time desc):
    /// `(name, count, total_us, p50_us, p95_us)`.
    pub fn phases(&self) -> Vec<(String, usize, u64, f64, f64)> {
        let mut by_name: std::collections::BTreeMap<&str, Vec<f64>> = Default::default();
        for s in &self.spans {
            by_name.entry(&s.name).or_default().push(s.dur_us as f64);
        }
        let mut out: Vec<_> = by_name
            .into_iter()
            .map(|(name, durs)| {
                let total = durs.iter().sum::<f64>() as u64;
                let s = crate::util::stats::Summary::of(&durs);
                (name.to_string(), durs.len(), total, s.p50, s.p95)
            })
            .collect();
        out.sort_by(|a, b| b.2.cmp(&a.2).then(a.0.cmp(&b.0)));
        out
    }

    /// The `k` slowest per-job spans (`job.eval`), slowest first:
    /// `(job key, dur_us)`. Fully deterministic under duration ties:
    /// ordered by duration desc, then start offset, then job key.
    pub fn slowest_jobs(&self, k: usize) -> Vec<(String, u64)> {
        let mut jobs: Vec<(String, u64, u64)> = self
            .spans
            .iter()
            .filter(|s| s.name == "job.eval")
            .map(|s| (s.job.clone().unwrap_or_else(|| "<unattributed>".into()), s.dur_us, s.t_us))
            .collect();
        jobs.sort_by(|a, b| b.1.cmp(&a.1).then(a.2.cmp(&b.2)).then(a.0.cmp(&b.0)));
        jobs.truncate(k);
        jobs.into_iter().map(|(job, dur_us, _)| (job, dur_us)).collect()
    }

    /// Fraction of trace wall-clock covered by per-job `job.eval` spans,
    /// merging overlaps across worker threads (the acceptance gate's
    /// ">= 95% of campaign wall-clock" number).
    pub fn job_span_coverage(&self) -> f64 {
        let wall = self.wall_us();
        if wall == 0 {
            return 0.0;
        }
        let covered = merged_interval_us(
            self.spans
                .iter()
                .filter(|s| s.name == "job.eval")
                .map(|s| (s.t_us, s.t_us + s.dur_us))
                .collect(),
        );
        covered as f64 / wall as f64
    }

    /// Fault-layer activity in file order: every `fault.injected`,
    /// `io_retries`, and `io_gave_up` event the trace recorded. Empty for
    /// a healthy, fault-free run.
    pub fn fault_events(&self) -> Vec<&EventRec> {
        self.events
            .iter()
            .filter(|e| {
                matches!(e.name.as_str(), "fault.injected" | "io_retries" | "io_gave_up")
            })
            .collect()
    }

    /// Render the human report: summary line, per-shard lane table (when
    /// the trace is merged), per-phase table, fault/retry activity (when
    /// any), top-K slowest jobs.
    pub fn render(&self, top: usize) -> String {
        let wall_us = self.wall_us();
        let wall_s = wall_us as f64 / 1e6;
        let mut out = format!(
            "trace of {} ({}schema {})\nwall clock {} | {} spans, {} events, {} heartbeats | \
             job span coverage {:.0}%\n\n",
            self.store,
            match &self.shard {
                Some(s) => format!("shard {s}, "),
                None => String::new(),
            },
            self.schema,
            human_time(wall_s),
            self.spans.len(),
            self.events.len(),
            self.beats.len(),
            self.job_span_coverage() * 100.0,
        );
        let lanes = self.lanes();
        if lanes.len() > 1 {
            let mut t =
                Table::new(vec!["lane", "spans", "jobs", "busy", "util%", "claims", "reclaims"]);
            for l in &lanes {
                let util = if wall_us > 0 {
                    100.0 * l.busy_us as f64 / wall_us as f64
                } else {
                    0.0
                };
                t.row(vec![
                    l.label.clone(),
                    l.spans.to_string(),
                    l.jobs.to_string(),
                    human_time(l.busy_us as f64 / 1e6),
                    format!("{util:.0}"),
                    l.claims.to_string(),
                    l.reclaims.to_string(),
                ]);
            }
            out.push_str(&t.render());
            out.push('\n');
        }
        let mut t = Table::new(vec!["phase", "count", "total", "p50", "p95", "% wall"]);
        for (name, count, total_us, p50, p95) in self.phases() {
            let pct = if wall_us > 0 { 100.0 * total_us as f64 / wall_us as f64 } else { 0.0 };
            t.row(vec![
                name,
                count.to_string(),
                human_time(total_us as f64 / 1e6),
                human_time(p50 / 1e6),
                human_time(p95 / 1e6),
                // Can exceed 100%: phase totals sum across worker threads.
                format!("{pct:.1}"),
            ]);
        }
        out.push_str(&t.render());
        let faults = self.fault_events();
        if !faults.is_empty() {
            let str_field = |e: &EventRec, key: &str| -> String {
                e.fields
                    .get(key)
                    .ok()
                    .and_then(|v| v.as_str().ok().map(str::to_string))
                    .unwrap_or_default()
            };
            out.push_str(&format!(
                "\nfault injection / io retries ({} events):\n",
                faults.len()
            ));
            let mut t = Table::new(vec!["t", "event", "site", "detail"]);
            for e in &faults {
                let detail = match e.name.as_str() {
                    "fault.injected" => {
                        let nth = e
                            .fields
                            .get("nth")
                            .ok()
                            .and_then(|v| v.as_f64().ok())
                            .unwrap_or(0.0);
                        format!("kind {} (hit {nth:.0})", str_field(e, "kind"))
                    }
                    _ => str_field(e, "error"),
                };
                t.row(vec![
                    human_time(e.t_us as f64 / 1e6),
                    e.name.clone(),
                    str_field(e, "site"),
                    detail,
                ]);
            }
            out.push_str(&t.render());
        }
        let slow = self.slowest_jobs(top);
        if !slow.is_empty() {
            out.push_str(&format!("\ntop {} slowest jobs:\n", slow.len()));
            let mut t = Table::new(vec!["job", "time"]);
            for (job, dur_us) in slow {
                t.row(vec![job, human_time(dur_us as f64 / 1e6)]);
            }
            out.push_str(&t.render());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::obj;
    use std::path::PathBuf;

    fn tmp(tag: &str) -> PathBuf {
        std::env::temp_dir()
            .join(format!("carbon3d-report-{tag}-{}.trace.jsonl", std::process::id()))
    }

    fn header(shard: Option<&str>) -> String {
        obj([
            ("kind", Json::from("header")),
            ("schema", Json::from(SCHEMA)),
            ("pid", Json::from(1.0)),
            ("store", Json::from("s")),
            ("shard", shard.map(Json::from).unwrap_or(Json::Null)),
            ("epoch_ms", Json::from(1_000.0)),
        ])
        .dumps()
    }

    fn job_span(job: &str, t: f64, d: f64, shard: Option<&str>) -> String {
        let mut o = obj([
            ("kind", Json::from("span")),
            ("name", Json::from("job.eval")),
            ("t_us", Json::from(t)),
            ("dur_us", Json::from(d)),
            ("depth", Json::from(0.0)),
            ("parent", Json::Null),
            ("job", Json::from(job)),
            ("thread", Json::from(0.0)),
        ]);
        if let (Json::Obj(m), Some(s)) = (&mut o, shard) {
            m.insert("shard".into(), Json::from(s));
        }
        o.dumps()
    }

    #[test]
    fn top_k_ordering_is_deterministic_under_duration_ties() {
        let path = tmp("ties");
        // Three equal-duration jobs: order must fall back to start offset,
        // then name — never file order.
        let lines = [
            header(None),
            job_span("zz-late", 300.0, 50.0, None),
            job_span("bb-early", 100.0, 50.0, None),
            job_span("aa-same-start", 300.0, 50.0, None),
            job_span("slowest", 0.0, 90.0, None),
        ];
        std::fs::write(&path, lines.join("\n") + "\n").unwrap();
        let r = TraceReport::load(&path).unwrap();
        let top = r.slowest_jobs(10);
        assert_eq!(
            top,
            vec![
                ("slowest".to_string(), 90),
                ("bb-early".to_string(), 50),
                ("aa-same-start".to_string(), 50),
                ("zz-late".to_string(), 50),
            ]
        );
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn fault_and_retry_events_surface_in_the_report() {
        let path = tmp("faults");
        let event = |name: &str, fields: Json| {
            obj([
                ("kind", Json::from("event")),
                ("name", Json::from(name)),
                ("t_us", Json::from(10.0)),
                ("fields", fields),
            ])
            .dumps()
        };
        let lines = [
            header(None),
            job_span("a", 0.0, 50.0, None),
            event(
                "fault.injected",
                obj([
                    ("site", Json::from("store.append")),
                    ("nth", Json::from(2.0)),
                    ("kind", Json::from("io-error")),
                ]),
            ),
            event(
                "io_retries",
                obj([
                    ("site", Json::from("store.append")),
                    ("error", Json::from("injected io-error")),
                ]),
            ),
            // Unrelated events stay out of the fault section.
            event("mapcache.rebuild", obj([("path", Json::from("x"))])),
        ];
        std::fs::write(&path, lines.join("\n") + "\n").unwrap();
        let r = TraceReport::load(&path).unwrap();
        assert_eq!(r.fault_events().len(), 2);
        let rendered = r.render(3);
        assert!(rendered.contains("fault injection / io retries (2 events)"), "{rendered}");
        assert!(rendered.contains("store.append"), "{rendered}");
        assert!(rendered.contains("kind io-error (hit 2)"), "{rendered}");
        assert!(rendered.contains("injected io-error"), "{rendered}");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn lanes_aggregate_per_shard_busy_time_and_lease_contention() {
        let path = tmp("lanes");
        let claim = |shard: &str, reclaimed: bool| {
            obj([
                ("kind", Json::from("event")),
                ("name", Json::from("lease.claim")),
                ("t_us", Json::from(5.0)),
                ("shard", Json::from(shard)),
                (
                    "fields",
                    obj([("key", Json::from("j")), ("reclaimed", Json::from(reclaimed))]),
                ),
            ])
            .dumps()
        };
        let lines = [
            header(None),
            // Lane 0/2: overlapping spans [0,60] + [40,100] -> busy 100.
            job_span("a", 0.0, 60.0, Some("0/2")),
            job_span("b", 40.0, 60.0, Some("0/2")),
            // Lane 1/2: disjoint [0,30] + [50,80] -> busy 60.
            job_span("c", 0.0, 30.0, Some("1/2")),
            job_span("d", 50.0, 30.0, Some("1/2")),
            claim("0/2", false),
            claim("1/2", true),
        ];
        std::fs::write(&path, lines.join("\n") + "\n").unwrap();
        let r = TraceReport::load(&path).unwrap();
        let lanes = r.lanes();
        assert_eq!(lanes.len(), 2);
        assert_eq!((lanes[0].label.as_str(), lanes[0].jobs, lanes[0].busy_us), ("0/2", 2, 100));
        assert_eq!((lanes[0].claims, lanes[0].reclaims), (1, 0));
        assert_eq!((lanes[1].label.as_str(), lanes[1].jobs, lanes[1].busy_us), ("1/2", 2, 60));
        assert_eq!((lanes[1].claims, lanes[1].reclaims), (1, 1));
        // The merged render shows the lane table.
        assert!(r.render(3).contains("reclaims"));
        std::fs::remove_file(&path).unwrap();
    }
}
