"""L2: JAX CNN forward pass (exact + approximate-MAC variants) and training.

The CNN is the accuracy-evaluation workload of the ApproxTrain stand-in
(DESIGN.md §6.3): a small conv net over the synthetic-shapes dataset. Every
multiply in conv/fc layers runs through the approximate bf16 MAC datapath
(kernels.approx_matmul) when a LUT is supplied; the exact path uses plain f32
matmul. Training always uses the exact path (the paper evaluates *inference*
accuracy drop of post-trained networks).

Architecture (16x16x1 input, 5 classes):
  conv 3x3x1->8 (same) + ReLU + maxpool2   -> 8x8x8
  conv 3x3x8->16 (same) + ReLU + maxpool2  -> 4x4x16
  fc 256->5
"""

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import approx_matmul as am

IMG = 16
NUM_CLASSES = 5

# (name, shape) in canonical flattening order — mirrored by the Rust native
# evaluator (rust/src/accuracy/native.rs) and artifacts/weights.manifest.json.
PARAM_SPECS = [
    ("conv1_w", (3, 3, 1, 8)),
    ("conv1_b", (8,)),
    ("conv2_w", (3, 3, 8, 16)),
    ("conv2_b", (16,)),
    ("fc_w", (256, NUM_CLASSES)),
    ("fc_b", (NUM_CLASSES,)),
]


def init_params(seed: int) -> dict:
    rng = np.random.default_rng(seed)
    params = {}
    for name, shape in PARAM_SPECS:
        if name.endswith("_b"):
            params[name] = jnp.zeros(shape, jnp.float32)
        else:
            fan_in = int(np.prod(shape[:-1]))
            std = float(np.sqrt(2.0 / fan_in))
            params[name] = jnp.asarray(
                rng.normal(0.0, std, size=shape).astype(np.float32)
            )
    return params


def _pad_same(x: jnp.ndarray, ph: int, pw: int) -> jnp.ndarray:
    """Zero-pad H/W via the lax.pad primitive. Deliberately NOT jnp.pad and
    NOT concatenate-with-zeros: jnp.pad lowers through an HLO `call` and
    zero-concat materializes large zero constants — both of which the
    xla_extension 0.5.1 HLO-text round-trip (used by the Rust runtime)
    corrupts (the printer elides big constants as `{...}`). lax.pad lowers
    to a single `pad` op with a scalar. See DESIGN.md §AOT-gotchas."""
    cfg = [(0, 0, 0), (ph, ph, 0), (pw, pw, 0), (0, 0, 0)]
    return jax.lax.pad(x, jnp.float32(0), cfg)


def im2col(x: jnp.ndarray, kh: int, kw: int) -> jnp.ndarray:
    """NHWC 'same'-padded patch extraction.

    [B,H,W,C] -> [B*H*W, kh*kw*C], patch order (dy, dx, c) — matched exactly
    by the Rust native evaluator.
    """
    b, h, w, c = x.shape
    ph, pw = kh // 2, kw // 2
    xp = _pad_same(x, ph, pw)
    cols = []
    for dy in range(kh):
        for dx in range(kw):
            cols.append(xp[:, dy : dy + h, dx : dx + w, :])
    patches = jnp.concatenate(cols, axis=-1)  # [B,H,W,kh*kw*C]
    return patches.reshape(b * h * w, kh * kw * c)


def _mm(a, b, lut, interpret_blocks):
    """Matmul through the approximate datapath when a LUT is given."""
    if lut is None:
        return jnp.matmul(a, b, preferred_element_type=jnp.float32)
    bm, bn, bk = interpret_blocks
    return am.approx_matmul_padded(a, b, lut, block_m=bm, block_n=bn, block_k=bk)


def conv2d(x, w, bias, lut=None, blocks=(256, 16, 8)):
    # Block shapes from the measured interpret-mode sweep (EXPERIMENTS.md
    # §Perf): large M tiles amortize the grid loop for im2col matmuls whose
    # M = B*H*W is huge while K,N are small; bk=8 avoids padding K=9 4x.
    """'same' 3x3 conv via im2col + (approximate) matmul."""
    kh, kw, cin, cout = w.shape
    b, h, wd, _ = x.shape
    cols = im2col(x, kh, kw)                      # [B*H*W, kh*kw*cin]
    wmat = w.reshape(kh * kw * cin, cout)
    out = _mm(cols, wmat, lut, blocks)            # [B*H*W, cout]
    return out.reshape(b, h, wd, cout) + bias


def maxpool2(x):
    b, h, w, c = x.shape
    return jnp.max(x.reshape(b, h // 2, 2, w // 2, 2, c), axis=(2, 4))


def forward(params: dict, images: jnp.ndarray, lut=None) -> jnp.ndarray:
    """Logits [B, NUM_CLASSES]. `lut=None` -> exact f32; else approx MAC."""
    x = conv2d(images, params["conv1_w"], params["conv1_b"], lut)
    x = maxpool2(jax.nn.relu(x))
    x = conv2d(x, params["conv2_w"], params["conv2_b"], lut)
    x = maxpool2(jax.nn.relu(x))
    x = x.reshape(x.shape[0], -1)                 # [B, 256]
    return _mm(x, params["fc_w"], lut, (32, 8, 32)) + params["fc_b"]


def loss_fn(params, images, labels):
    logits = forward(params, images)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


@jax.jit
def train_step(params, images, labels, lr):
    loss, grads = jax.value_and_grad(loss_fn)(params, images, labels)
    new = {k: v - lr * grads[k] for k, v in params.items()}
    return new, loss


def train(params, images, labels, *, steps=400, batch=64, lr=0.08, seed=1, log=None):
    """Plain SGD on the exact path. Returns (params, loss_history)."""
    rng = np.random.default_rng(seed)
    n = images.shape[0]
    hist = []
    for step in range(steps):
        idx = rng.integers(0, n, size=batch)
        params, loss = train_step(
            params, images[idx], labels[idx], jnp.float32(lr)
        )
        hist.append(float(loss))
        if log and step % 50 == 0:
            log(f"step {step:4d} loss {float(loss):.4f}")
    return params, hist


def accuracy(params, images, labels, lut=None, batch=64) -> float:
    """Top-1 accuracy, batched to bound interpret-mode memory."""
    n = images.shape[0]
    correct = 0
    for s in range(0, n, batch):
        logits = forward(params, images[s : s + batch], lut)
        correct += int(jnp.sum(jnp.argmax(logits, axis=1) == labels[s : s + batch]))
    return correct / n
