//! Append-only JSONL result store with checkpoint/resume.
//!
//! One line per completed job, written in schedule order by the commit
//! pipeline's single writer. On open, existing rows are parsed and their
//! job keys indexed, so a restarted campaign skips completed scenarios. A
//! torn final line (interrupted mid-write, so no trailing newline) is
//! dropped and its job redone; corruption anywhere else — including an
//! unparseable but newline-*terminated* final line, which an interrupted
//! append can never produce — is a loud error rather than silent data
//! loss. Sharded campaigns coordinate through the sibling
//! [`crate::campaign::lease`] directory; each shard writes its own store
//! of this same format.

use std::collections::HashSet;
use std::fs::{File, OpenOptions};
use std::io::Write as _;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::Json;

/// Field every row carries to identify its scenario.
pub const KEY_FIELD: &str = "key";

/// The JSONL store.
pub struct ResultStore {
    path: PathBuf,
    rows: Vec<Json>,
    keys: HashSet<String>,
    file: File,
}

impl ResultStore {
    /// Open (creating parent directories and the file if needed) and index
    /// any rows already present.
    pub fn open(path: &Path) -> Result<Self> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("create store directory {}", dir.display()))?;
            }
        }
        let existing = match std::fs::read_to_string(path) {
            Ok(text) => text,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => String::new(),
            Err(e) => return Err(e).with_context(|| format!("read store {}", path.display())),
        };
        let mut rows = Vec::new();
        let mut keys = HashSet::new();
        let mut torn = false;
        // Only a *final* line with no trailing newline can be a torn append
        // (the writer always emits `row\n` in one call). Anything else that
        // fails to parse is corruption and must error loudly — quietly
        // dropping it would silently truncate committed results.
        let ends_with_newline = existing.ends_with('\n');
        let lines: Vec<&str> = existing.lines().filter(|l| !l.trim().is_empty()).collect();
        for (i, line) in lines.iter().enumerate() {
            match Json::parse(line) {
                Ok(row) => {
                    let key = row
                        .get(KEY_FIELD)
                        .and_then(|k| k.as_str().map(str::to_string))
                        .with_context(|| format!("store row {} has no string `key`", i + 1))?;
                    if !keys.insert(key.clone()) {
                        bail!("store {} has duplicate key {key:?}", path.display());
                    }
                    rows.push(row);
                }
                Err(e) if i + 1 == lines.len() && !ends_with_newline => {
                    // Torn tail from an interrupted append: drop it; the
                    // campaign will redo that job. Routed through the obs
                    // event API: warns on stderr, bumps the
                    // `store.torn_append` counter (countable in tests), and
                    // lands in the trace sidecar when tracing is on.
                    crate::obs::warn_event(
                        "store.torn_append",
                        &format!("store {}: ignoring torn final line ({e:#})", path.display()),
                        &[
                            ("store", Json::from(path.display().to_string())),
                            ("error", Json::from(format!("{e:#}"))),
                        ],
                    );
                    torn = true;
                }
                Err(e) => {
                    return Err(e).with_context(|| {
                        format!(
                            "store {} row {} corrupt (not a torn append tail); \
                             refusing to resume over damaged results",
                            path.display(),
                            i + 1
                        )
                    })
                }
            }
        }
        if torn {
            // Drop the torn bytes without risking the committed prefix:
            // write the good rows to a sibling temp file, then atomically
            // rename it over the store. The common (untorn) path never
            // rewrites anything.
            let tmp = path.with_extension("jsonl.tmp");
            let mut f = File::create(&tmp)
                .with_context(|| format!("create {}", tmp.display()))?;
            for row in &rows {
                writeln!(f, "{}", row.dumps())
                    .with_context(|| format!("rewrite store {}", tmp.display()))?;
            }
            f.flush()?;
            drop(f);
            std::fs::rename(&tmp, path)
                .with_context(|| format!("replace store {}", path.display()))?;
        }
        let file = OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)
            .with_context(|| format!("open store {}", path.display()))?;
        Ok(Self { path: path.to_path_buf(), rows, keys, file })
    }

    /// Has a row for this job key already been committed?
    pub fn contains(&self, key: &str) -> bool {
        self.keys.contains(key)
    }

    /// Append one result row (must carry a unique `key`) and flush.
    pub fn append(&mut self, row: Json) -> Result<()> {
        let key = row
            .get(KEY_FIELD)
            .and_then(|k| k.as_str().map(str::to_string))
            .context("result row has no string `key`")?;
        if !self.keys.insert(key.clone()) {
            bail!("duplicate result for job {key:?}");
        }
        writeln!(self.file, "{}", row.dumps())
            .with_context(|| format!("append to store {}", self.path.display()))?;
        self.file.flush()?;
        self.rows.push(row);
        Ok(())
    }

    /// All committed rows, in file order.
    pub fn rows(&self) -> &[Json] {
        &self.rows
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::obj;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!(
            "carbon3d-store-{}-{name}.jsonl",
            std::process::id()
        ))
    }

    fn row(key: &str, x: f64) -> Json {
        obj([("key", Json::from(key)), ("x", Json::from(x))])
    }

    #[test]
    fn append_then_reopen_indexes_keys() {
        let path = tmp("roundtrip");
        let _ = std::fs::remove_file(&path);
        {
            let mut s = ResultStore::open(&path).unwrap();
            assert!(s.is_empty());
            s.append(row("a", 1.0)).unwrap();
            s.append(row("b", 2.0)).unwrap();
        }
        let s = ResultStore::open(&path).unwrap();
        assert_eq!(s.len(), 2);
        assert!(s.contains("a") && s.contains("b") && !s.contains("c"));
        assert_eq!(s.rows()[1].get("x").unwrap().as_f64().unwrap(), 2.0);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn duplicate_key_rejected() {
        let path = tmp("dup");
        let _ = std::fs::remove_file(&path);
        let mut s = ResultStore::open(&path).unwrap();
        s.append(row("a", 1.0)).unwrap();
        assert!(s.append(row("a", 9.0)).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn torn_final_line_is_dropped_and_redone() {
        let path = tmp("torn");
        let _ = std::fs::remove_file(&path);
        {
            let mut s = ResultStore::open(&path).unwrap();
            s.append(row("a", 1.0)).unwrap();
        }
        // Simulate a crash mid-append.
        let mut f = OpenOptions::new().append(true).open(&path).unwrap();
        write!(f, "{{\"key\": \"b\", \"x\":").unwrap();
        drop(f);
        let torn_before = crate::obs::metrics().counter("store.torn_append");
        let s = ResultStore::open(&path).unwrap();
        assert_eq!(s.len(), 1);
        assert!(!s.contains("b"));
        // The recovery is an obs event now: countable with tracing off.
        assert!(crate::obs::metrics().counter("store.torn_append") > torn_before);
        // The torn bytes are gone from disk after reopen.
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 1);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn mid_file_corruption_is_an_error() {
        let path = tmp("corrupt");
        let _ = std::fs::remove_file(&path);
        std::fs::write(&path, "not json\n{\"key\": \"a\", \"x\": 1}\n").unwrap();
        assert!(ResultStore::open(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn newline_terminated_garbage_tail_is_an_error_not_a_truncation() {
        // A final line that fails to parse but IS newline-terminated cannot
        // be a torn append (appends write `row\n` atomically from the
        // store's perspective) — treat it as corruption, never drop it.
        let path = tmp("garbage-tail");
        let _ = std::fs::remove_file(&path);
        std::fs::write(&path, "{\"key\": \"a\", \"x\": 1}\nnot json\n").unwrap();
        let err = ResultStore::open(&path).err().expect("open must refuse garbage tail");
        assert!(format!("{err:#}").contains("row 2"), "{err:#}");
        // The damaged file is left untouched for inspection.
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 2);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn rows_without_keys_are_rejected() {
        let path = tmp("nokey");
        let _ = std::fs::remove_file(&path);
        let mut s = ResultStore::open(&path).unwrap();
        assert!(s.append(obj([("x", Json::from(1.0))])).is_err());
        let _ = std::fs::remove_file(&path);
    }
}
