//! Approximate-multiplier library (EvoApprox8b stand-in — DESIGN.md §6.1).
//!
//! EvoApprox's role in the paper is a Pareto set of 8x8 unsigned multipliers
//! over (silicon area, arithmetic error): the GA picks the most area-efficient
//! design whose measured DNN accuracy drop fits the threshold δ (Eq. 7).
//!
//! We reproduce that role with bit-exact *behavioral* models spanning the
//! same design families the library catalogs:
//!   - partial-product perforation          (`Perforate`)
//!   - operand truncation                   (`Truncate`)
//!   - broken-array multipliers             (`BrokenArray`)
//!   - OR-based lower-column compression    (`OrCompress`)
//!   - log-domain: Mitchell and DRUM        (`Mitchell`, `Drum`)
//!   - exact baseline                       (`Exact`)
//!
//! Hardware costs come from a gate-level cost model (`cost.rs`): each design
//! reports the adder/AND cells its structure eliminates relative to the full
//! 8x8 array, and per-node standard-cell parameters turn gate counts into
//! area/power/delay at 45/14/7nm. Error metrics are computed *exhaustively*
//! over the full 256x256 input space and over the bf16-significand domain
//! [128,255]^2 actually exercised by the MAC (`error.rs`).

pub mod cost;
pub mod error;
pub mod models;
pub mod netlist;

pub use cost::{GateCounts, HwCost};
pub use error::ErrorMetrics;
pub use models::{ApproxKind, Multiplier};

use crate::area::TechNode;

/// The full multiplier library (36 designs incl. the exact baseline).
/// Deterministic order; `id` indexes into this vector.
pub fn library() -> Vec<Multiplier> {
    let mut designs: Vec<ApproxKind> = vec![ApproxKind::Exact];
    for p in 1..=7 {
        designs.push(ApproxKind::Perforate(p));
    }
    for k in 1..=5 {
        designs.push(ApproxKind::Truncate(k));
    }
    for d in 2..=9 {
        designs.push(ApproxKind::BrokenArray(d));
    }
    for t in 2..=8 {
        designs.push(ApproxKind::OrCompress(t));
    }
    designs.push(ApproxKind::Mitchell);
    for k in 3..=6 {
        designs.push(ApproxKind::Drum(k));
    }
    // Hybrids: truncate + perforate (EvoApprox's evolved designs often
    // combine independent simplifications).
    designs.push(ApproxKind::TruncPerf(2, 3));
    designs.push(ApproxKind::TruncPerf(3, 4));
    designs.push(ApproxKind::TruncPerf(1, 5));

    designs
        .into_iter()
        .enumerate()
        .map(|(id, kind)| Multiplier::new(id, kind))
        .collect()
}

/// Library entries that satisfy a mean-relative-error bound on the
/// significand domain (coarse pre-filter before accuracy simulation).
pub fn filter_by_mred(lib: &[Multiplier], max_mred: f64) -> Vec<usize> {
    lib.iter()
        .filter(|m| m.error.sig_mred <= max_mred)
        .map(|m| m.id)
        .collect()
}

/// The exact multiplier's id in `library()` (always 0).
pub const EXACT_ID: usize = 0;

/// Significand-product LUT (128x128, f32) for feeding the AOT kernel and the
/// native evaluator: entry (i, j) = design(128+i, 128+j).
pub fn lut_f32(m: &Multiplier) -> Vec<f32> {
    let mut lut = Vec::with_capacity(128 * 128);
    for i in 0..128u32 {
        for j in 0..128u32 {
            lut.push(m.mul((128 + i) as u8, (128 + j) as u8) as f32);
        }
    }
    lut
}

/// Area of a multiplier at a node, in um^2 (convenience wrapper).
pub fn area_um2(m: &Multiplier, node: TechNode) -> f64 {
    m.hw_cost(node).area_um2
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn library_is_deterministic_and_ids_sequential() {
        let a = library();
        let b = library();
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            assert_eq!(x.id, i);
            assert_eq!(x.kind, y.kind);
            assert_eq!(x.error.sig_mred, y.error.sig_mred);
        }
    }

    #[test]
    fn exact_is_first_and_error_free() {
        let lib = library();
        assert_eq!(lib[EXACT_ID].kind, ApproxKind::Exact);
        assert_eq!(lib[EXACT_ID].error.sig_mred, 0.0);
        assert_eq!(lib[EXACT_ID].error.full_wce, 0);
    }

    #[test]
    fn all_approx_designs_are_smaller_than_exact() {
        let lib = library();
        let exact_area = area_um2(&lib[EXACT_ID], TechNode::N45);
        for m in &lib[1..] {
            let a = area_um2(m, TechNode::N45);
            assert!(
                a < exact_area,
                "{} area {a} !< exact {exact_area}",
                m.name()
            );
        }
    }

    #[test]
    fn mred_filter_monotone() {
        let lib = library();
        let strict = filter_by_mred(&lib, 0.001);
        let loose = filter_by_mred(&lib, 0.1);
        assert!(strict.len() <= loose.len());
        for id in &strict {
            assert!(loose.contains(id));
        }
        // The exact multiplier always qualifies.
        assert!(strict.contains(&EXACT_ID));
    }

    #[test]
    fn lut_matches_behavioral_model() {
        let lib = library();
        for m in [&lib[0], &lib[3], lib.last().unwrap()] {
            let lut = lut_f32(m);
            assert_eq!(lut.len(), 128 * 128);
            for (i, j) in [(0u32, 0u32), (5, 9), (127, 127), (64, 1)] {
                let want = m.mul((128 + i) as u8, (128 + j) as u8) as f32;
                assert_eq!(lut[(i * 128 + j) as usize], want);
            }
        }
    }

    #[test]
    fn exact_lut_values() {
        let lib = library();
        let lut = lut_f32(&lib[EXACT_ID]);
        assert_eq!(lut[0], (128.0 * 128.0) as f32);
        assert_eq!(lut[128 * 128 - 1], (255.0 * 255.0) as f32);
    }
}
