//! Campaign scheduler: a pool of std-thread workers draining the job grid,
//! all sharing ONE `EvalService` so the multiplier-accuracy cache is
//! campaign-global. The δ-feasible sets of neighboring scenarios overlap
//! heavily, so after the first job primes the cache every later job's
//! accuracy table is pure cache hits — the dominant cross-run saving.
//!
//! The queue is **objective-aware**: each pending job gets an analytic
//! optimistic bound ([`JobBound`]) on the best objective value any design in
//! its search space could reach, jobs are dispatched most-promising-first by
//! that bound, and jobs whose bound provably cannot beat the committed
//! front are skipped ([`prune_reason`]). Pruning is deterministic by
//! construction: the commit-time decision for the job at schedule slot *i*
//! is a pure function of the rows committed at slots `< i` (the dispatch-
//! time check is merely a sound early-out — incumbents only improve as rows
//! commit, so a prune visible at dispatch still holds at commit).
//!
//! Results flow through a reorder buffer and are committed to the JSONL
//! store in schedule order, which (with key-derived per-job GA seeds) makes
//! the store byte-identical for any worker count or interleaving, fresh or
//! resumed. The cross-scenario Pareto archive is maintained incrementally
//! as rows commit and checkpointed beside the store.

use std::collections::{BTreeMap, HashMap};
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, bail, ensure, Context as _, Result};

use crate::accuracy::model::{
    calibrate_k, drop_pct_from_error, feasible_multipliers, predicted_drop_pct, DEFAULT_K,
    MEAN_SIG_PRODUCT,
};
use crate::accuracy::native::NativeEvaluator;
use crate::accuracy::AccuracyTable;
use crate::approx::{library, Multiplier, EXACT_ID};
use crate::area::mac::mac_power_uw;
use crate::carbon::embodied_carbon;
use crate::coordinator::ga_appx_with_feasible_objective;
use crate::dataflow::arch::AccelConfig;
use crate::dataflow::workloads::{workload, Workload};
use crate::ga::{GaParams, Objective, SearchSpace};
use crate::runtime::{Artifacts, EvalBackend, EvalClient, EvalService, NativeBackend, ServiceStats};
use crate::util::json::{obj, Json};

use super::pareto::CampaignArchive;
use super::spec::{integration_name, CampaignSpec, JobSpec};
use super::store::ResultStore;

/// Reference exact-path accuracy when no measured artifacts exist (the
/// trained tiny CNN's manifest value).
const SURROGATE_EXACT_ACC: f64 = 0.9355;

/// Accuracy backend for artifact-less environments: measures the effective
/// arithmetic error of the submitted LUT against exact significand products
/// and applies the calibrated ΔA drop model at tiny-CNN depth. Monotone in
/// the LUT's error, so feasibility ordering matches the measured path.
pub struct SurrogateBackend {
    exact_accuracy: f64,
    k: f64,
    tiny: Workload,
}

impl Default for SurrogateBackend {
    fn default() -> Self {
        Self {
            exact_accuracy: SURROGATE_EXACT_ACC,
            k: DEFAULT_K,
            tiny: workload("tinycnn").expect("tinycnn workload exists"),
        }
    }
}

impl EvalBackend for SurrogateBackend {
    fn accuracy_of_lut(&self, lut: &[f32]) -> Result<f64> {
        ensure!(lut.len() == 128 * 128, "LUT must be 128x128");
        let (mut mred, mut bias) = (0.0f64, 0.0f64);
        for i in 0..128usize {
            for j in 0..128usize {
                let exact = ((128 + i) * (128 + j)) as f64;
                let got = f64::from(lut[i * 128 + j]);
                mred += (got - exact).abs() / exact;
                bias += got - exact;
            }
        }
        let n = (128 * 128) as f64;
        let e_eff = mred / n + (bias / n).abs() / MEAN_SIG_PRODUCT;
        let drop_pct = drop_pct_from_error(e_eff, &self.tiny, self.k);
        Ok(self.exact_accuracy - drop_pct / 100.0)
    }
}

/// Start the campaign-global accuracy service: measured native evaluation
/// when artifacts are built, the surrogate error model otherwise. Returns
/// the service and the backend's name (for reporting).
pub fn start_service(artifacts_dir: &Path) -> Result<(EvalService, &'static str)> {
    if artifacts_dir.join("manifest.json").exists() {
        let artifacts = Artifacts::load(artifacts_dir)?;
        let native = NativeEvaluator::load(&artifacts)?;
        Ok((EvalService::start(NativeBackend(native)), "native"))
    } else {
        Ok((EvalService::start(SurrogateBackend::default()), "surrogate"))
    }
}

/// Fetch the campaign-global accuracy table through the shared service and
/// calibrate the ΔA model's K against it. Used identically by the bound
/// pre-pass and by every job — a single definition is what guarantees the
/// pre-pass δ-feasible sets (and therefore the prune bounds) agree exactly
/// with the sets the GA searches.
fn calibrated_k(client: &EvalClient, lib: &[Multiplier], tiny: &Workload) -> Result<f64> {
    let mult_refs: Vec<&Multiplier> = lib.iter().collect();
    let accs = client
        .eval_all(&mult_refs)
        .map_err(|e| anyhow!("accuracy service: {e}"))?;
    let mut table = AccuracyTable { exact: accs[EXACT_ID], ..Default::default() };
    for (m, &a) in lib.iter().zip(&accs) {
        table.accuracy.insert(m.id, a);
    }
    Ok(calibrate_k(lib, tiny, &table))
}

/// Analytic optimistic bounds for one pending job: component-wise lower
/// bounds over the job's *entire* search space, so no achievable design can
/// beat them. Used to order the queue (most promising first) and to prune
/// jobs that provably cannot improve the committed front.
#[derive(Debug, Clone, Copy)]
pub struct JobBound {
    /// Lower bound on embodied carbon (g): the min-area corner of the
    /// search space with the cheapest δ-feasible multiplier.
    pub carbon_lb_g: f64,
    /// Lower bound on task delay (s): compute-bound at the largest array.
    pub delay_lb_s: f64,
    /// Lower bound on energy/inference (J): MAC energy only, at the most
    /// frugal δ-feasible multiplier (memory traffic ignored).
    pub energy_lb_j: f64,
    /// Upper bound on achievable FPS (`1 / delay_lb_s`).
    pub fps_ub: f64,
    /// Lower bound on the campaign objective value.
    pub objective_lb: f64,
}

/// Compute the optimistic bound for a job over its δ-feasible multiplier
/// set. Every component combines best-cases that no single design attains
/// simultaneously, which is exactly what makes it a valid lower bound.
pub fn job_bound(
    job: &JobSpec,
    w: &Workload,
    lib: &[Multiplier],
    feasible: &[usize],
    objective: &Objective,
) -> JobBound {
    let space = SearchSpace::standard(feasible.to_vec());
    let (px_min, py_min) = (space.px[0], space.py[0]);
    let (px_max, py_max) = (*space.px.last().unwrap(), *space.py.last().unwrap());
    let (rf_min, sram_min) = (space.rf_bytes[0], space.sram_bytes[0]);
    let mut carbon_lb_g = f64::INFINITY;
    let mut mac_pj_min = f64::INFINITY;
    for &mid in feasible {
        let cfg = AccelConfig {
            px: px_min,
            py: py_min,
            rf_bytes: rf_min,
            sram_bytes: sram_min,
            node: job.node,
            integration: job.integration,
            mult_id: mid,
        };
        let areas = cfg.die_areas(&lib[mid]);
        let c = embodied_carbon(&areas, job.node, job.integration).total_g();
        carbon_lb_g = carbon_lb_g.min(c);
        mac_pj_min = mac_pj_min.min(mac_power_uw(&lib[mid], job.node) / job.node.freq_mhz());
    }
    let macs = w.total_macs() as f64;
    let freq_hz = job.node.freq_mhz() * 1e6;
    let delay_lb_s = macs / ((px_max * py_max) as f64 * freq_hz);
    let energy_lb_j = macs * mac_pj_min * 1e-12;
    let objective_lb = match objective {
        Objective::EmbodiedCdp(_) => carbon_lb_g * delay_lb_s,
        Objective::OperationalCarbon(d) => d.lifetime_gco2(energy_lb_j),
        Objective::LifetimeCdp(d) => (carbon_lb_g + d.lifetime_gco2(energy_lb_j)) * delay_lb_s,
    };
    JobBound { carbon_lb_g, delay_lb_s, energy_lb_j, fps_ub: 1.0 / delay_lb_s, objective_lb }
}

/// Why a job may be skipped without running, given its bound and the best
/// committed objective value in its family (None = no incumbent yet).
/// Returns `None` when the job must run.
///
/// Note the exact semantics: rule (b) prunes on the *scalar objective*
/// projected per (model, node, integration) family — a pruned scenario can
/// never improve the family's best objective value, but its row might have
/// contributed to the 3-axis (carbon, delay, drop) archive through a lower
/// accuracy drop alone. Pruning trades that per-scenario completeness for
/// speed; campaigns that need every grid point exhaustively set
/// `CampaignSpec::prune = false` (CLI `--no-prune`).
pub fn prune_reason(
    job: &JobSpec,
    bound: &JobBound,
    incumbent: Option<f64>,
) -> Option<&'static str> {
    if let Some(floor) = job.fps_floor {
        if bound.fps_ub < floor {
            // Even the compute-bound best case misses the floor: every
            // design in the space is infeasible.
            return Some("fps floor exceeds the reachable bound");
        }
    }
    if let Some(best) = incumbent {
        if bound.objective_lb >= best {
            // The optimistic bound already loses to a committed result in
            // this (model, node, integration) family.
            return Some("objective bound cannot beat the committed front");
        }
    }
    None
}

/// What a finished campaign reports.
#[derive(Debug, Clone, Copy)]
pub struct CampaignReport {
    pub jobs_total: usize,
    /// Jobs that ran and committed a row.
    pub jobs_run: usize,
    /// Jobs skipped because the store already had their row (resume).
    pub jobs_skipped: usize,
    /// Jobs skipped because their optimistic bound provably cannot beat
    /// the committed front (deterministic prune; no row written).
    pub jobs_pruned: usize,
    pub elapsed_s: f64,
    /// Eval-service counter deltas attributable to this campaign.
    pub stats: ServiceStats,
}

impl CampaignReport {
    pub fn jobs_per_sec(&self) -> f64 {
        if self.elapsed_s > 0.0 {
            self.jobs_run as f64 / self.elapsed_s
        } else {
            0.0
        }
    }

    pub fn line(&self) -> String {
        format!(
            "{} jobs ({} run, {} resumed, {} pruned) in {:.2}s = {:.2} jobs/s | eval service: \
             {} served, {} evaluated, {} cache hits, {} coalesced ({:.0}% hit rate)",
            self.jobs_total,
            self.jobs_run,
            self.jobs_skipped,
            self.jobs_pruned,
            self.elapsed_s,
            self.jobs_per_sec(),
            self.stats.served,
            self.stats.evaluated,
            self.stats.cache_hits,
            self.stats.coalesced,
            self.stats.hit_rate() * 100.0,
        )
    }
}

fn stats_delta(after: ServiceStats, before: ServiceStats) -> ServiceStats {
    ServiceStats {
        served: after.served - before.served,
        evaluated: after.evaluated - before.evaluated,
        cache_hits: after.cache_hits - before.cache_hits,
        coalesced: after.coalesced - before.coalesced,
    }
}

/// Committed-front state shared between the writer (updates on commit) and
/// the workers (read for the dispatch-side prune early-out).
struct FrontState {
    archive: CampaignArchive,
    /// Best committed objective value per job family.
    incumbents: HashMap<String, f64>,
}

/// Family + objective value of a committed row, if it carries the
/// objective-era fields (legacy rows simply never become incumbents).
fn row_incumbent(row: &Json) -> Option<(String, f64)> {
    let s = |k: &str| row.get(k).ok().and_then(|v| v.as_str().ok().map(str::to_string));
    let fam =
        format!("{}@{}/{}/{}", s("model")?, s("node")?, s("integration")?, s("objective")?);
    let v = row.get("obj_value").ok()?.as_f64().ok()?;
    Some((fam, v))
}

fn update_incumbent(incumbents: &mut HashMap<String, f64>, row: &Json) {
    if let Some((fam, v)) = row_incumbent(row) {
        let e = incumbents.entry(fam).or_insert(v);
        if v < *e {
            *e = v;
        }
    }
}

/// A worker's verdict on one job.
enum JobOutcome {
    Row(Json),
    Pruned,
}

/// Drain the campaign grid with `workers` threads, committing one JSONL row
/// per runnable job to `store` in schedule order (ascending optimistic
/// objective bound, ties by grid id). Jobs whose key is already in the
/// store are skipped (checkpoint/resume); jobs whose bound cannot beat the
/// committed front are pruned; everything else about the run — including
/// which jobs get pruned — is deterministic in the campaign seed.
pub fn run_campaign(
    spec: &CampaignSpec,
    workers: usize,
    store: &mut ResultStore,
    service: &EvalService,
) -> Result<CampaignReport> {
    let jobs = spec.jobs();
    let mut pending: Vec<JobSpec> =
        jobs.iter().filter(|j| !store.contains(&j.key())).cloned().collect();
    let jobs_skipped = jobs.len() - pending.len();
    let lib = library();
    let mut workloads: HashMap<String, Workload> = HashMap::new();
    for m in &spec.models {
        workloads
            .insert(m.clone(), workload(m).ok_or_else(|| anyhow!("unknown model {m}"))?);
    }
    let tiny = workload("tinycnn").expect("tinycnn workload exists");
    let objective = spec.objective.to_fitness(spec.deployment);
    let axis = spec.objective.carbon_axis();

    let before = service.stats();
    let t0 = Instant::now();

    // Bound pre-pass: one accuracy-table fetch (shared with the jobs via
    // the service cache), then an analytic bound per pending job. The queue
    // is then ordered most-promising-first; commits follow this schedule
    // order, so the ordering itself is part of the deterministic contract.
    let mut bounds: HashMap<usize, JobBound> = HashMap::new();
    if !pending.is_empty() {
        let client = service.client();
        let k = calibrated_k(&client, &lib, &tiny)?;
        let mut feasible_sets: HashMap<(String, u64), Vec<usize>> = HashMap::new();
        for job in &pending {
            let w = workloads.get(&job.model).expect("workload preloaded");
            let f = feasible_sets
                .entry((job.model.clone(), job.delta_pct.to_bits()))
                .or_insert_with(|| feasible_multipliers(&lib, w, job.delta_pct, k));
            ensure!(
                !f.is_empty(),
                "no multiplier satisfies δ={}% for {}",
                job.delta_pct,
                job.model
            );
            bounds.insert(job.id, job_bound(job, w, &lib, f, &objective));
        }
        pending.sort_by(|a, b| {
            bounds[&a.id]
                .objective_lb
                .partial_cmp(&bounds[&b.id].objective_lb)
                .unwrap()
                .then(a.id.cmp(&b.id))
        });
    }

    // Committed-front state: restore the incremental Pareto archive from
    // its sidecar checkpoint (or rebuild from the rows) and seed the
    // per-family incumbents from the already-committed rows.
    let ckpt_path = CampaignArchive::checkpoint_path(store.path());
    let archive = CampaignArchive::load_or_rebuild(store.rows(), axis, &ckpt_path)?;
    let mut incumbents: HashMap<String, f64> = HashMap::new();
    for row in store.rows() {
        update_incumbent(&mut incumbents, row);
    }
    let shared = Mutex::new(FrontState { archive, incumbents });

    let n_workers = workers.max(1).min(pending.len().max(1));
    let next = AtomicUsize::new(0);
    let (tx, rx) = mpsc::channel::<Result<(usize, JobOutcome)>>();
    let mut jobs_run = 0usize;
    let mut jobs_pruned = 0usize;

    std::thread::scope(|scope| -> Result<()> {
        for _ in 0..n_workers {
            let tx = tx.clone();
            let client = service.client();
            let (pending, lib, workloads, tiny, next, ga) =
                (&pending, &lib, &workloads, &tiny, &next, spec.ga);
            let (bounds, shared, objective, prune_on) =
                (&bounds, &shared, &objective, spec.prune);
            scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= pending.len() {
                    break;
                }
                let job = &pending[i];
                // Dispatch-side prune early-out: sound, because commits only
                // ever improve the incumbents, so a prune visible now still
                // holds when the writer re-checks at commit time.
                let pruned = prune_on
                    && bounds.get(&job.id).is_some_and(|b| {
                        let inc =
                            shared.lock().unwrap().incumbents.get(&job.family()).copied();
                        prune_reason(job, b, inc).is_some()
                    });
                let out = if pruned {
                    Ok((job.id, JobOutcome::Pruned))
                } else {
                    run_job(job, ga, lib, workloads, tiny, &client, objective)
                        .with_context(|| format!("job {}", job.key()))
                        .map(|row| (job.id, JobOutcome::Row(row)))
                };
                if tx.send(out).is_err() {
                    break;
                }
            });
        }
        drop(tx);

        // Single writer: reorder results into schedule order and apply the
        // authoritative prune rule at commit time, so the committed store —
        // including which jobs were pruned — is a pure function of the spec
        // and the rows committed before each slot.
        let expected: Vec<usize> = pending.iter().map(|j| j.id).collect();
        let mut buffer: BTreeMap<usize, JobOutcome> = BTreeMap::new();
        let mut cursor = 0usize;
        for msg in rx {
            let (id, out) = msg?;
            buffer.insert(id, out);
            while cursor < expected.len() {
                let Some(out) = buffer.remove(&expected[cursor]) else {
                    break;
                };
                let job = &pending[cursor];
                // Shared-state update under the lock; file I/O (row append +
                // checkpoint) outside it, so workers' dispatch-side prune
                // reads never stall behind disk writes.
                let mut st = shared.lock().unwrap();
                let prune = spec.prune
                    && bounds.get(&job.id).is_some_and(|b| {
                        prune_reason(job, b, st.incumbents.get(&job.family()).copied())
                            .is_some()
                    });
                let commit = if prune {
                    None
                } else {
                    let JobOutcome::Row(row) = out else {
                        bail!(
                            "job {} pruned by a worker but runnable at commit time",
                            job.key()
                        );
                    };
                    update_incumbent(&mut st.incumbents, &row);
                    st.archive.insert_row(&row)?;
                    Some((row, st.archive.checkpoint()))
                };
                drop(st);
                match commit {
                    None => jobs_pruned += 1,
                    Some((row, ckpt)) => {
                        store.append(row)?;
                        std::fs::write(&ckpt_path, ckpt.dumps()).with_context(|| {
                            format!("write archive checkpoint {}", ckpt_path.display())
                        })?;
                        jobs_run += 1;
                    }
                }
                cursor += 1;
            }
        }
        ensure!(
            cursor == expected.len(),
            "campaign incomplete: committed {cursor} of {} pending jobs",
            expected.len()
        );
        Ok(())
    })?;

    Ok(CampaignReport {
        jobs_total: jobs.len(),
        jobs_run,
        jobs_skipped,
        jobs_pruned,
        elapsed_s: t0.elapsed().as_secs_f64(),
        stats: stats_delta(service.stats(), before),
    })
}

/// Execute one scenario: measured/surrogate accuracy table through the
/// shared service, δ-feasible set, objective-aware GA run, result row.
fn run_job(
    job: &JobSpec,
    ga: GaParams,
    lib: &[Multiplier],
    workloads: &HashMap<String, Workload>,
    tiny: &Workload,
    client: &EvalClient,
    objective: &Objective,
) -> Result<Json> {
    let w = workloads
        .get(&job.model)
        .ok_or_else(|| anyhow!("workload {} not preloaded", job.model))?;

    // Accuracy table via the campaign-global service. Deliberately
    // re-derived per job rather than threaded in from the bound pre-pass:
    // jobs stay self-contained (runnable without a pre-pass), and the
    // shared `calibrated_k` definition + the service's result cache
    // guarantee the values agree — the redundancy costs only cached
    // round-trips, never re-evaluation.
    let k = calibrated_k(client, lib, tiny)?;
    let feasible = feasible_multipliers(lib, w, job.delta_pct, k);
    ensure!(!feasible.is_empty(), "no multiplier satisfies δ={}%", job.delta_pct);
    let n_feasible = feasible.len();

    let params = GaParams { seed: job.seed, ..ga };
    let r = ga_appx_with_feasible_objective(
        w,
        job.node,
        job.integration,
        lib,
        feasible,
        job.fps_floor,
        *objective,
        params,
    );

    let best = &r.best;
    let e = &r.best_eval;
    let mult = &lib[best.mult_id];
    Ok(obj([
        ("key", Json::from(job.key())),
        ("model", Json::from(job.model.clone())),
        ("node", Json::from(job.node.name())),
        ("integration", Json::from(integration_name(job.integration))),
        ("delta_pct", Json::from(job.delta_pct)),
        (
            "fps_floor",
            match job.fps_floor {
                Some(f) => Json::from(f),
                None => Json::Null,
            },
        ),
        ("objective", Json::from(job.objective.name())),
        ("seed", Json::from(format!("{:#018x}", job.seed))),
        ("px", Json::from(best.px)),
        ("py", Json::from(best.py)),
        ("rf_bytes", Json::from(best.rf_bytes)),
        ("sram_bytes", Json::from(best.sram_bytes)),
        ("mult_id", Json::from(best.mult_id)),
        ("mult", Json::from(mult.name())),
        ("carbon_g", Json::from(e.carbon_g)),
        ("delay_s", Json::from(e.delay_s)),
        ("fps", Json::from(e.fps)),
        ("cdp", Json::from(e.cdp)),
        ("energy_per_inf_j", Json::from(e.energy_per_inference_j)),
        ("op_gco2", Json::from(e.operational_gco2)),
        ("lifetime_gco2", Json::from(e.lifetime_gco2)),
        ("lifetime_cdp", Json::from(e.lifetime_cdp)),
        ("obj_value", Json::from(objective.value(e))),
        ("carbon_per_mm2", Json::from(e.carbon_per_mm2)),
        ("silicon_mm2", Json::from(e.silicon_mm2)),
        ("feasible", Json::from(e.feasible)),
        ("drop_pct", Json::from(predicted_drop_pct(mult, w, k))),
        ("k", Json::from(k)),
        ("n_feasible", Json::from(n_feasible)),
        ("evaluations", Json::from(r.evaluations)),
        ("generations", Json::from(r.generations_run)),
    ]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::area::die::Integration;
    use crate::area::TechNode;
    use crate::campaign::spec::CampaignObjective;
    use crate::ga::evaluate_objective;
    use crate::util::Rng;

    #[test]
    fn surrogate_exact_lut_has_zero_drop() {
        let lib = library();
        let b = SurrogateBackend::default();
        let acc = b.accuracy_of_lut(&crate::approx::lut_f32(&lib[EXACT_ID])).unwrap();
        assert!((acc - SURROGATE_EXACT_ACC).abs() < 1e-12);
    }

    #[test]
    fn surrogate_orders_designs_by_error() {
        let lib = library();
        let b = SurrogateBackend::default();
        // A mild truncation should keep more accuracy than an aggressive one.
        let mild = lib.iter().find(|m| m.name() == "TRUNC1").unwrap();
        let harsh = lib.iter().find(|m| m.name() == "TRUNC5").unwrap();
        let a_mild = b.accuracy_of_lut(&crate::approx::lut_f32(mild)).unwrap();
        let a_harsh = b.accuracy_of_lut(&crate::approx::lut_f32(harsh)).unwrap();
        assert!(a_mild > a_harsh, "{a_mild} !> {a_harsh}");
    }

    #[test]
    fn surrogate_rejects_bad_lut() {
        assert!(SurrogateBackend::default().accuracy_of_lut(&[1.0; 7]).is_err());
    }

    #[test]
    fn report_line_mentions_throughput_hits_and_prunes() {
        let r = CampaignReport {
            jobs_total: 10,
            jobs_run: 8,
            jobs_skipped: 1,
            jobs_pruned: 1,
            elapsed_s: 4.0,
            stats: ServiceStats { served: 100, evaluated: 20, cache_hits: 70, coalesced: 10 },
        };
        assert!((r.jobs_per_sec() - 2.0).abs() < 1e-12);
        let line = r.line();
        assert!(line.contains("2.00 jobs/s"), "{line}");
        assert!(line.contains("80% hit rate"), "{line}");
        assert!(line.contains("1 pruned"), "{line}");
    }

    fn test_job(fps_floor: Option<f64>) -> JobSpec {
        let mut j = JobSpec {
            id: 0,
            model: "vgg16".to_string(),
            node: TechNode::N14,
            integration: Integration::ThreeD,
            delta_pct: 3.0,
            fps_floor,
            objective: CampaignObjective::EmbodiedCdp,
            seed: 0,
        };
        j.seed = super::super::spec::job_seed(1, &j.key());
        j
    }

    #[test]
    fn prune_rules_fire_on_bound_violations_only() {
        let bound = JobBound {
            carbon_lb_g: 1.0,
            delay_lb_s: 0.5,
            energy_lb_j: 0.01,
            fps_ub: 2.0,
            objective_lb: 5.0,
        };
        let free = test_job(None);
        // No incumbent, no floor: must run.
        assert_eq!(prune_reason(&free, &bound, None), None);
        // Incumbent worse than the bound: still must run (could beat it).
        assert_eq!(prune_reason(&free, &bound, Some(6.0)), None);
        // Incumbent at/below the bound: provably cannot beat it.
        assert!(prune_reason(&free, &bound, Some(5.0)).is_some());
        assert!(prune_reason(&free, &bound, Some(4.0)).is_some());
        // FPS floor above the compute-bound best case: infeasible.
        assert!(prune_reason(&test_job(Some(3.0)), &bound, None).is_some());
        assert_eq!(prune_reason(&test_job(Some(1.0)), &bound, None), None);
    }

    #[test]
    fn job_bound_is_a_true_lower_bound_on_sampled_designs() {
        // Property: the analytic bound never exceeds any achievable design's
        // metrics, across objectives and random chromosomes.
        let lib = library();
        let w = workload("resnet50").unwrap();
        let feasible: Vec<usize> = (0..lib.len()).collect();
        let dep = crate::carbon::operational::Deployment::default();
        for objective in [
            Objective::EmbodiedCdp(dep),
            Objective::OperationalCarbon(dep),
            Objective::LifetimeCdp(dep),
        ] {
            let job = test_job(None);
            let b = job_bound(&job, &w, &lib, &feasible, &objective);
            let space = SearchSpace::standard(feasible.clone());
            let mut rng = Rng::new(42);
            for _ in 0..25 {
                let c = space.sample(&mut rng);
                let e = evaluate_objective(
                    &c,
                    &w,
                    job.node,
                    job.integration,
                    &lib,
                    None,
                    &objective,
                );
                assert!(b.carbon_lb_g <= e.carbon_g + 1e-9, "{objective:?}");
                assert!(b.delay_lb_s <= e.delay_s + 1e-12, "{objective:?}");
                assert!(b.energy_lb_j <= e.energy_per_inference_j + 1e-15, "{objective:?}");
                assert!(b.fps_ub >= e.fps - 1e-9, "{objective:?}");
                assert!(
                    b.objective_lb <= objective.value(&e) * (1.0 + 1e-9),
                    "{objective:?}: bound {} vs value {}",
                    b.objective_lb,
                    objective.value(&e)
                );
            }
        }
    }

    #[test]
    fn row_incumbent_requires_objective_fields() {
        let legacy = obj([("key", Json::from("a")), ("carbon_g", Json::from(1.0))]);
        assert!(row_incumbent(&legacy).is_none());
        let modern = obj([
            ("model", Json::from("vgg16")),
            ("node", Json::from("14nm")),
            ("integration", Json::from("3D")),
            ("objective", Json::from("embodied-cdp")),
            ("obj_value", Json::from(2.5)),
        ]);
        let (fam, v) = row_incumbent(&modern).unwrap();
        assert_eq!(fam, "vgg16@14nm/3D/embodied-cdp");
        assert_eq!(v, 2.5);
        // And the family string matches JobSpec::family for the same scenario.
        assert_eq!(fam, test_job(None).family());
    }
}
