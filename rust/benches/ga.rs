//! Bench GA: full GA-run cost at the paper's budget, fitness-eval cost with
//! cold/warm cache, and convergence statistics over seeds.

use carbon3d::approx::library;
use carbon3d::area::die::Integration;
use carbon3d::area::TechNode;
use carbon3d::coordinator::ga_appx_cdp;
use carbon3d::dataflow::workloads::workload;
use carbon3d::ga::fitness::FitnessCtx;
use carbon3d::ga::{GaParams, SearchSpace};
use carbon3d::obs::bench::bench;
use carbon3d::util::Rng;

fn main() {
    println!("== GA benches ==");
    let lib = library();
    let w = workload("resnet50").unwrap();

    // Cold fitness evaluations (cache thrash via fresh ctx each iter).
    let space = SearchSpace::standard((0..lib.len()).collect());
    let mut rng = Rng::new(1);
    let samples: Vec<_> = (0..64).map(|_| space.sample(&mut rng)).collect();
    let res = bench("64 cold fitness evals (resnet50@14nm)", 2, 20, || {
        let mut ctx = FitnessCtx::new(&w, TechNode::N14, Integration::ThreeD, &lib, None);
        for c in &samples {
            std::hint::black_box(ctx.eval(c));
        }
    });
    println!("{}", res.line());

    // Full paper-budget GA run.
    let res = bench("GA-APPX-CDP full run (pop 64, <=48 gens)", 0, 5, || {
        ga_appx_cdp(&w, TechNode::N14, &lib, 3.0, None, GaParams::default())
    });
    println!("{}", res.line());

    // Convergence robustness over seeds.
    let mut finals = Vec::new();
    for seed in 0..10u64 {
        let r = ga_appx_cdp(
            &w,
            TechNode::N14,
            &lib,
            3.0,
            None,
            GaParams { seed, ..Default::default() },
        );
        finals.push(r.best_eval.cdp);
    }
    let s = carbon3d::util::Summary::of(&finals);
    println!(
        "CDP across 10 seeds: mean {:.5}, spread (max-min)/mean {:.2}%",
        s.mean,
        (s.max - s.min) / s.mean * 100.0
    );
}
