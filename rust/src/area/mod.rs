//! Silicon-area models: technology nodes, CACTI-lite SRAM/RF, MAC and die
//! composition (DESIGN.md §6.2/§6.4).
//!
//! The chip area is the dominant factor in embodied carbon (paper §III-C);
//! everything in `carbon/` consumes areas produced here.

pub mod die;
pub mod mac;
pub mod node;
pub mod sram;

pub use die::{logic_die_area_mm2, memory_die_area_mm2, DieAreas};
pub use mac::mac_area_um2;
pub use node::TechNode;
pub use sram::{rf_area_um2, sram_area_mm2};
