//! **Sharded campaigns**: N cooperating `carbon3d campaign --shard i/N`
//! processes drain one grid concurrently, then `carbon3d campaign merge`
//! folds their shard stores into the canonical schedule-order store.
//!
//! Division of labor: every shard builds the same deterministic
//! [`JobSource`] and walks the full schedule sequentially. Jobs it *owns*
//! (a pure hash of the job key — see [`super::super::source::shard_owner`])
//! are claimed through the [`LeaseDir`] protocol and evaluated into the
//! shard's own store; jobs owned by other shards are skipped, unless their
//! lease has expired (the owner died mid-job), in which case the walker
//! steals and evaluates them — that is the crash-recovery path.
//!
//! Why the merge is byte-identical to a single-process run: rows are pure
//! functions of the job spec (key-derived GA seeds), the schedule order is
//! a pure function of the spec, and the merge replays the authoritative
//! commit-slot prune rule through the same [`CommitPipeline`]. The one
//! subtle obligation is that a shard must never skip a job the merge turns
//! out to need. That is why shards run under
//! [`PruneMode::FloorOnly`](super::super::commit::PruneMode): the FPS-floor
//! rule is a pure function of the job and its bound, so every process
//! agrees on it — but the incumbent rule is only sound against rows
//! committed at *earlier* schedule slots, and a resumed shard store is not
//! a slot prefix (lease-unavailable gaps leave stored rows at later slots
//! than a still-pending job). Incumbent pruning is left to the merge, which
//! replays commits in schedule order and so applies it soundly; a shard at
//! worst evaluates a job the merge then discards.

use std::collections::HashMap;
use std::fmt;
use std::path::{Path, PathBuf};

use anyhow::{ensure, Context as _, Result};

use crate::runtime::EvalService;
use crate::util::Json;

use super::super::commit::{CommitPipeline, JobOutcome, PruneMode};
use super::super::lease::{Claim, LeaseDir};
use super::super::source::{shard_owner, JobCtx, JobSource};
use super::super::store::{ResultStore, KEY_FIELD};
use super::{job_context, run_job_quarantined, Executor};

/// Which shard of how many this process is.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardId {
    pub index: usize,
    pub count: usize,
}

impl ShardId {
    /// Parse the CLI form `i/N` (0-based index).
    pub fn parse(s: &str) -> Result<Self> {
        let (i, n) = s
            .split_once('/')
            .with_context(|| format!("--shard expects i/N (e.g. 0/3), got {s:?}"))?;
        let index: usize =
            i.trim().parse().with_context(|| format!("bad shard index in {s:?}"))?;
        let count: usize =
            n.trim().parse().with_context(|| format!("bad shard count in {s:?}"))?;
        ensure!(count >= 1, "shard count must be >= 1, got {count}");
        ensure!(index < count, "shard index {index} out of range for count {count}");
        Ok(Self { index, count })
    }

    /// Does this shard primarily own a job (by key hash)?
    pub fn owns(&self, key: &str) -> bool {
        shard_owner(key, self.count) == self.index
    }
}

impl fmt::Display for ShardId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}/{}", self.index, self.count)
    }
}

/// The per-shard store beside the canonical one
/// (`campaign.jsonl` -> `campaign.shard0of3.jsonl`).
pub fn shard_store_path(canonical: &Path, shard: ShardId) -> PathBuf {
    canonical.with_extension(format!("shard{}of{}.jsonl", shard.index, shard.count))
}

/// One of N cooperating shard processes: sequential (parallelism comes
/// from running N processes), lease-claimed, writing its own shard store.
pub struct ShardedExecutor {
    pub shard: ShardId,
    pub leases: LeaseDir,
}

impl Executor for ShardedExecutor {
    fn describe(&self) -> String {
        format!("shard {} (lease-claimed, sequential)", self.shard)
    }

    fn prune_mode(&self) -> PruneMode {
        // Incumbent pruning against a shard store is unsound once the store
        // stops being a slot prefix (module docs): floor rule only.
        PruneMode::FloorOnly
    }

    fn status_shard(&self) -> Option<String> {
        Some(self.shard.to_string())
    }

    fn drain(
        &self,
        ctx: &JobCtx,
        source: &JobSource,
        service: &EvalService,
        pipeline: &mut CommitPipeline<'_>,
    ) -> Result<()> {
        let client = service.client();
        let front = pipeline.front();
        let mode = pipeline.mode();
        for job in source.schedule() {
            // Dispatch-side prune (floor rule — a pure function of the job
            // and its bound, so every shard agrees without coordination).
            // No lease is taken: other shards decide identically.
            let pruned = mode.fires(job, source.bound(job.id), || front.incumbent(&job.family()));
            if pruned {
                pipeline.offer(job.id, JobOutcome::Pruned)?;
                continue;
            }
            let key = job.key();
            let claim = if self.shard.owns(&key) {
                self.leases.try_claim(&key)?
            } else {
                // Not ours — only steal it if its owner abandoned it.
                self.leases.steal_expired(&key)?
            };
            match claim {
                Claim::Acquired => {
                    // Quarantined: a poison job becomes a `failed` row in
                    // this shard's store (and flows through the merge like
                    // any other row) instead of stranding the lease for
                    // peers to re-hit.
                    let row = run_job_quarantined(job, ctx, &client)
                        .with_context(|| job_context(job))?;
                    pipeline.offer(job.id, JobOutcome::Row(row))?;
                    self.leases.mark_done(&key)?;
                }
                Claim::Unavailable => pipeline.offer(job.id, JobOutcome::Skipped)?,
            }
        }
        Ok(())
    }
}

/// Resolves jobs from already-written shard stores instead of running the
/// GA. Replaying the lookup through the shared commit pipeline is what
/// produces the canonical store: schedule order, authoritative prune
/// decisions, archive and sidecar — all byte-identical to a single-process
/// run of the same spec.
pub struct MergeExecutor {
    rows: HashMap<String, Json>,
}

impl MergeExecutor {
    /// Load every shard store beside `canonical`. Duplicate keys across
    /// shard stores (a presumed-dead shard that finished anyway) must be
    /// byte-identical — anything else means the shards ran different specs
    /// and the merge refuses.
    pub fn from_shard_stores(canonical: &Path, count: usize) -> Result<Self> {
        ensure!(count >= 1, "shard count must be >= 1, got {count}");
        let mut rows: HashMap<String, Json> = HashMap::new();
        for index in 0..count {
            let shard = ShardId { index, count };
            let path = shard_store_path(canonical, shard);
            ensure!(
                path.exists(),
                "missing shard store {} — run `carbon3d campaign --shard {shard}` \
                 to completion first",
                path.display()
            );
            let store = ResultStore::open(&path)
                .with_context(|| format!("open shard store {}", path.display()))?;
            // Sharding is an exhaustive-sampler protocol: an adaptive
            // store's row order follows its planner's batch decisions, so
            // folding one into a schedule-order merge would silently mix
            // byte-incompatible orderings. Refuse loudly instead.
            if let Some(mode) = store.sampler_header() {
                ensure!(
                    mode == crate::campaign::spec::SamplerMode::Exhaustive,
                    "shard store {} was written by a '{}' sampler — `campaign merge` \
                     only accepts exhaustive shard stores (re-run the shards without \
                     `--sampler adaptive`)",
                    path.display(),
                    mode.name()
                );
            }
            for row in store.rows() {
                let key = row
                    .get(KEY_FIELD)
                    .and_then(|k| k.as_str())
                    .with_context(|| format!("shard store {} row without key", path.display()))?
                    .to_string();
                match rows.get(&key) {
                    None => {
                        rows.insert(key, row.clone());
                    }
                    Some(prev) => ensure!(
                        prev.dumps() == row.dumps(),
                        "shard stores disagree on job {key:?}: rows are seeded by key and \
                         must be byte-identical — were the shards run with different specs?"
                    ),
                }
            }
        }
        Ok(Self { rows })
    }

    /// Number of distinct rows collected from the shard stores.
    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }
}

impl Executor for MergeExecutor {
    fn describe(&self) -> String {
        format!("merge of {} shard-store rows", self.rows.len())
    }

    fn status_shard(&self) -> Option<String> {
        Some("merge".to_string())
    }

    fn drain(
        &self,
        _ctx: &JobCtx,
        source: &JobSource,
        _service: &EvalService,
        pipeline: &mut CommitPipeline<'_>,
    ) -> Result<()> {
        for job in source.schedule() {
            match self.rows.get(&job.key()) {
                Some(row) => {
                    // The campaign seed is not part of job keys, so only the
                    // row's recorded seed can catch a merge invoked with a
                    // different --seed than the shards ran under.
                    let got = row.get("seed").ok().and_then(|s| s.as_str().ok());
                    let want = format!("{:#018x}", job.seed);
                    ensure!(
                        got == Some(want.as_str()),
                        "shard row for {} was evaluated with seed {} but this spec \
                         derives {want} — were the shards run with a different --seed \
                         or GA flags?",
                        job.key(),
                        got.unwrap_or("<missing>"),
                    );
                    pipeline.offer(job.id, JobOutcome::Row(row.clone()))?
                }
                // No shard evaluated it: legitimate only if the
                // authoritative rule prunes this slot — the pipeline
                // errors loudly otherwise.
                None => pipeline.offer(job.id, JobOutcome::Pruned).with_context(|| {
                    format!(
                        "no shard store has a row for {} — was every shard run to \
                         completion?",
                        job.key()
                    )
                })?,
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::area::TechNode;
    use crate::campaign::exec::{run_campaign, run_campaign_with, SurrogateBackend};
    use crate::campaign::pareto::CampaignArchive;
    use crate::campaign::spec::CampaignSpec;
    use crate::ga::GaParams;

    #[test]
    fn shard_executors_restrict_themselves_to_floor_pruning() {
        let d = std::env::temp_dir()
            .join(format!("carbon3d-sharded-mode-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        let leases = LeaseDir::open(d.clone(), "t".to_string(), 600).unwrap();
        let ex = ShardedExecutor { shard: ShardId { index: 0, count: 2 }, leases };
        // Incumbent pruning against a shard store is unsound (module docs):
        // only the merge — which commits in schedule order — may apply it.
        assert_eq!(ex.prune_mode(), PruneMode::FloorOnly);
        assert_eq!(MergeExecutor { rows: HashMap::new() }.prune_mode(), PruneMode::Full);
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn shard_id_parses_and_rejects() {
        let s = ShardId::parse("1/3").unwrap();
        assert_eq!((s.index, s.count), (1, 3));
        assert_eq!(s.to_string(), "1/3");
        assert!(ShardId::parse("3/3").is_err());
        assert!(ShardId::parse("0/0").is_err());
        assert!(ShardId::parse("nope").is_err());
        assert!(ShardId::parse("1").is_err());
    }

    #[test]
    fn shard_store_paths_are_distinct_siblings() {
        let canonical = Path::new("results/campaign.jsonl");
        let p0 = shard_store_path(canonical, ShardId { index: 0, count: 2 });
        let p1 = shard_store_path(canonical, ShardId { index: 1, count: 2 });
        assert_eq!(p0, Path::new("results/campaign.shard0of2.jsonl"));
        assert_ne!(p0, p1);
    }

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir()
            .join(format!("carbon3d-sharded-{}-{name}.jsonl", std::process::id()))
    }

    /// 2 models x 2 nodes x 2 deltas x 2 fps floors = 16 jobs, half of
    /// them prunable (absurd FPS floor), tiny GA budget.
    fn shard_spec() -> CampaignSpec {
        let mut s = CampaignSpec::new(
            vec!["vgg16".to_string(), "resnet50".to_string()],
            vec![TechNode::N45, TechNode::N7],
            vec![1.0, 3.0],
        );
        s.fps_floors = vec![None, Some(1e9)];
        s.ga = GaParams {
            population: 8,
            generations: 4,
            patience: 2,
            elites: 1,
            ..Default::default()
        };
        s
    }

    fn cleanup_campaign(canonical: &Path, count: usize) {
        let _ = std::fs::remove_file(canonical);
        let _ = std::fs::remove_file(CampaignArchive::checkpoint_path(canonical));
        let _ = std::fs::remove_file(crate::obs::status::status_path(canonical));
        let _ = std::fs::remove_dir_all(LeaseDir::for_store(canonical));
        for index in 0..count {
            let p = shard_store_path(canonical, ShardId { index, count });
            let _ = std::fs::remove_file(&p);
            let _ = std::fs::remove_file(CampaignArchive::checkpoint_path(&p));
            let _ = std::fs::remove_file(crate::obs::status::status_path(&p));
        }
    }

    fn run_shard(spec: &CampaignSpec, canonical: &Path, shard: ShardId) -> ResultStore {
        let mut store = ResultStore::open(&shard_store_path(canonical, shard)).unwrap();
        let leases = LeaseDir::open(
            LeaseDir::for_store(canonical),
            format!("test-shard-{shard}"),
            600,
        )
        .unwrap();
        let svc = EvalService::start(SurrogateBackend::default());
        run_campaign_with(spec, &ShardedExecutor { shard, leases }, &mut store, &svc).unwrap();
        svc.shutdown();
        store
    }

    #[test]
    fn three_shard_run_plus_merge_matches_single_process_byte_for_byte() {
        let spec = shard_spec();
        let (single, canonical) = (tmp("single"), tmp("merged"));
        let _ = std::fs::remove_file(&single);
        let _ = std::fs::remove_file(CampaignArchive::checkpoint_path(&single));
        cleanup_campaign(&canonical, 3);

        // Reference: one process, 4 worker threads.
        let mut ref_store = ResultStore::open(&single).unwrap();
        let svc = EvalService::start(SurrogateBackend::default());
        let ref_report = run_campaign(&spec, 4, &mut ref_store, &svc).unwrap();
        svc.shutdown();
        assert_eq!(ref_report.jobs_pruned, 8, "{}", ref_report.line());

        // Three shards drain the same grid (sequentially here; processes
        // in production — the lease protocol is the same either way).
        for index in 0..3 {
            run_shard(&spec, &canonical, ShardId { index, count: 3 });
        }

        // Merge the shard stores into the canonical store.
        let merge = MergeExecutor::from_shard_stores(&canonical, 3).unwrap();
        assert_eq!(merge.n_rows(), 8, "every runnable job evaluated exactly once");
        let mut merged_store = ResultStore::open(&canonical).unwrap();
        let svc = EvalService::start(SurrogateBackend::default());
        let merged_report =
            run_campaign_with(&spec, &merge, &mut merged_store, &svc).unwrap();
        svc.shutdown();

        // Store, front sidecar, and report counters: byte-identical.
        let bytes = |p: &Path| std::fs::read_to_string(p).unwrap();
        assert_eq!(bytes(&single), bytes(&canonical), "merged store diverged");
        assert_eq!(
            bytes(&CampaignArchive::checkpoint_path(&single)),
            bytes(&CampaignArchive::checkpoint_path(&canonical)),
            "merged front sidecar diverged"
        );
        assert_eq!(
            ref_report.deterministic_json().dumps(),
            merged_report.deterministic_json().dumps(),
            "merged report counters diverged"
        );

        let _ = std::fs::remove_file(&single);
        let _ = std::fs::remove_file(CampaignArchive::checkpoint_path(&single));
        cleanup_campaign(&canonical, 3);
    }

    #[test]
    fn abandoned_lease_is_stolen_and_the_job_runs_exactly_once() {
        let mut spec = shard_spec();
        spec.fps_floors = vec![None]; // 8 jobs
        spec.prune = false; // lease mechanics only — keep every job runnable
        let canonical = tmp("steal");
        cleanup_campaign(&canonical, 2);

        // A shard-1 job was claimed by a now-dead incarnation: plant its
        // expired lease before any shard runs.
        let leases =
            LeaseDir::open(LeaseDir::for_store(&canonical), "planter".to_string(), 600)
                .unwrap();
        let victim = spec
            .jobs()
            .into_iter()
            .map(|j| j.key())
            .find(|k| shard_owner(k, 2) == 1)
            .expect("some job hashes to shard 1");
        leases.plant_for_test(&victim, 9_999, false);

        // Shard 0 walks the schedule: it owns its own half and steals the
        // abandoned job.
        let store0 = run_shard(&spec, &canonical, ShardId { index: 0, count: 2 });
        assert!(store0.contains(&victim), "expired lease was not stolen");

        // Shard 1 then runs: the stolen job is done — not re-evaluated.
        let store1 = run_shard(&spec, &canonical, ShardId { index: 1, count: 2 });
        assert!(!store1.contains(&victim), "stolen job was re-evaluated");

        // Between them the shards cover the full grid exactly once, and
        // the merge accepts the result.
        assert_eq!(store0.len() + store1.len(), 8);
        let merge = MergeExecutor::from_shard_stores(&canonical, 2).unwrap();
        assert_eq!(merge.n_rows(), 8);

        cleanup_campaign(&canonical, 2);
    }

    #[test]
    fn merge_refuses_shard_rows_from_a_different_seed() {
        let mut spec = shard_spec();
        spec.fps_floors = vec![None];
        spec.models.truncate(1);
        spec.deltas.truncate(1); // 1 model x 2 nodes x 1 delta = 2 jobs
        let canonical = tmp("seed-mismatch");
        cleanup_campaign(&canonical, 1);
        run_shard(&spec, &canonical, ShardId { index: 0, count: 1 });
        let merge = MergeExecutor::from_shard_stores(&canonical, 1).unwrap();
        let mut merged_store = ResultStore::open(&canonical).unwrap();
        let svc = EvalService::start(SurrogateBackend::default());
        let mut reseeded = spec.clone();
        reseeded.seed ^= 1;
        let err =
            run_campaign_with(&reseeded, &merge, &mut merged_store, &svc).unwrap_err();
        svc.shutdown();
        assert!(format!("{err:#}").contains("--seed"), "{err:#}");
        cleanup_campaign(&canonical, 1);
    }

    #[test]
    fn merge_refuses_missing_shard_stores() {
        let canonical = tmp("missing");
        cleanup_campaign(&canonical, 2);
        let err = MergeExecutor::from_shard_stores(&canonical, 2).unwrap_err();
        assert!(format!("{err:#}").contains("missing shard store"), "{err:#}");
        cleanup_campaign(&canonical, 2);
    }
}
