//! Deterministic xorshift64*-based PRNG.
//!
//! All stochastic components (GA, workload generators, property tests) take
//! an explicit seed so every experiment — including whole campaign stores —
//! is reproducible bit-for-bit.

/// xorshift64* PRNG with splitmix64 seeding.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create from a seed; any seed (including 0) is valid.
    pub fn new(seed: u64) -> Self {
        // splitmix64 step guarantees a non-zero xorshift state.
        let mut z = seed.wrapping_add(0x9E37_79B9_7F4A_7C15);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        Self { state: (z ^ (z >> 31)) | 1 }
    }

    /// Next raw u64.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform u64 in [0, n) without modulo bias (Lemire reduction).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in [lo, hi] inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as usize
    }

    /// Uniform f64 in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick a random element of a slice.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty(), "choice on empty slice");
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Derive an independent child stream (for parallel substreams).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn zero_seed_works() {
        let mut r = Rng::new(0);
        assert_ne!(r.next_u64(), 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let x = r.below(7) as usize;
            assert!(x < 7);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn range_inclusive() {
        let mut r = Rng::new(11);
        let (mut lo_seen, mut hi_seen) = (false, false);
        for _ in 0..2000 {
            let x = r.range(3, 8);
            assert!((3..=8).contains(&x));
            lo_seen |= x == 3;
            hi_seen |= x == 8;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut r = Rng::new(23);
        let mut a = r.fork();
        let mut b = r.fork();
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
