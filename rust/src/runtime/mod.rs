//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `make artifacts` and executes them on the xla crate's CPU client.
//!
//! Python never runs here — the rust binary is self-contained once
//! artifacts exist. Interchange is HLO *text* (xla_extension 0.5.1 rejects
//! jax>=0.5's 64-bit-id serialized protos; the text parser reassigns ids).

pub mod artifacts;
pub mod engine;
pub mod pjrt;
pub mod service;

pub use artifacts::Artifacts;
pub use engine::Engine;
pub use service::{EvalBackend, EvalClient, EvalService, NativeBackend, ServiceStats};
