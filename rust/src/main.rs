//! carbon3d CLI — the L3 coordinator entrypoint.
//!
//! Subcommands (hand-rolled parser; clap is unavailable offline):
//!   multipliers            print the approximate-multiplier library
//!   workloads              print the DNN workload inventory
//!   map --model M ...      map one workload onto a configuration
//!   carbon ...             carbon breakdown of a configuration
//!   dse --model M ...      one GA-APPX-CDP run
//!   fig2 [--quick]         reproduce Fig. 2
//!   fig3 [--quick]         reproduce Fig. 3
//!   report [--quick]       headline paper-vs-measured report
//!   accuracy [--pjrt]      ΔA table on the trained tiny CNN
//!   selfcheck              PJRT runtime smoke test (matmul artifacts)

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, bail, ensure, Context, Result};

use carbon3d::accuracy::model::{calibrate_k, feasible_multipliers, predicted_drop_pct, DEFAULT_K};
use carbon3d::accuracy::native::{ApproxDatapath, NativeEvaluator};
use carbon3d::approx::{library, lut_f32, EXACT_ID};
use carbon3d::area::die::Integration;
use carbon3d::area::node::ALL_NODES;
use carbon3d::area::TechNode;
use carbon3d::carbon::embodied_carbon;
use carbon3d::coordinator::{
    ga_appx_with_feasible_objective_shared, ga_cdp_exact, headline_report, run_fig2, run_fig3,
};
use carbon3d::coordinator::fig2::FIG2_MODELS;
use carbon3d::dataflow::arch::AccelConfig;
use carbon3d::dataflow::mapper::map_network;
use carbon3d::dataflow::workloads::{workload, workload_names};
use carbon3d::ga::GaParams;
use carbon3d::runtime::{Artifacts, Engine};
use carbon3d::util::{table, Table};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Minimal flag parser: positional subcommand + `--key value` / `--flag`.
struct Opts {
    flags: HashMap<String, String>,
    /// Non-flag tokens in order, excluding tokens consumed as flag values
    /// (the rule "a token after `--flag` is its value unless it starts
    /// with `--`" lives only here).
    positionals: Vec<String>,
}

impl Opts {
    fn parse(args: &[String]) -> Self {
        let mut flags = HashMap::new();
        let mut positionals = Vec::new();
        let mut i = 0;
        while i < args.len() {
            if let Some(key) = args[i].strip_prefix("--") {
                let has_val = i + 1 < args.len() && !args[i + 1].starts_with("--");
                if has_val {
                    flags.insert(key.to_string(), args[i + 1].clone());
                    i += 2;
                } else {
                    flags.insert(key.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                positionals.push(args[i].clone());
                i += 1;
            }
        }
        Self { flags, positionals }
    }

    fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    fn get(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    fn usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} expects an integer, got {v}")),
        }
    }

    fn f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} expects a number, got {v}")),
        }
    }

    fn node(&self) -> Result<TechNode> {
        let s = self.get("node", "14nm");
        TechNode::from_name(&s).ok_or_else(|| anyhow!("unknown node {s} (45nm|14nm|7nm)"))
    }
}

/// Deployment knobs shared by `campaign` and `lifetime`: `--lifetime-years`,
/// `--ipd` (inferences/day), `--grid-gco2-kwh`. The CLI speaks gCO2/kWh to
/// match the carbon tables; the model keeps kgCO2/kWh.
fn deployment_from_opts(o: &Opts) -> Result<carbon3d::carbon::operational::Deployment> {
    use carbon3d::carbon::operational::Deployment;
    let d = Deployment::default();
    Ok(Deployment {
        lifetime_years: o.f64("lifetime-years", d.lifetime_years)?,
        inferences_per_day: o.f64("ipd", d.inferences_per_day)?,
        grid_kgco2_per_kwh: o.f64("grid-gco2-kwh", d.grid_kgco2_per_kwh * 1000.0)? / 1000.0,
    })
}

fn ga_params(o: &Opts) -> Result<GaParams> {
    let quick = o.has("quick");
    Ok(GaParams {
        population: o.usize("pop", if quick { 32 } else { 64 })?,
        generations: o.usize("gens", if quick { 20 } else { 48 })?,
        patience: if quick { 8 } else { 14 },
        seed: o.usize("seed", 0xCAFE)? as u64,
        ..Default::default()
    })
}

fn run(args: &[String]) -> Result<()> {
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let o = Opts::parse(&args[1.min(args.len())..]);
    match cmd {
        "multipliers" => cmd_multipliers(&o),
        "workloads" => cmd_workloads(),
        "map" => cmd_map(&o),
        "carbon" => cmd_carbon(&o),
        "dse" => cmd_dse(&o),
        "campaign" => match args.get(1).map(String::as_str) {
            Some("merge") => cmd_campaign_merge(&Opts::parse(&args[2..])),
            Some("chaos") => cmd_campaign_chaos(&Opts::parse(&args[2..])),
            _ => cmd_campaign(&o),
        },
        "front" => cmd_front(&args[1..]),
        "trace" => cmd_trace(&args[1..]),
        "fig2" => cmd_fig2(&o),
        "fig3" => cmd_fig3(&o),
        "report" => cmd_report(&o),
        "accuracy" => cmd_accuracy(&o),
        "verilog" => cmd_verilog(&o),
        "pipeline" => cmd_pipeline(&o),
        "lifetime" => cmd_lifetime(&o),
        "selfcheck" => cmd_selfcheck(),
        "help" | "--help" | "-h" => {
            println!("{}", HELP);
            Ok(())
        }
        other => bail!("unknown subcommand {other:?}; try `carbon3d help`"),
    }
}

const HELP: &str = "carbon3d — carbon-efficient 3D DNN accelerator DSE
USAGE: carbon3d <subcommand> [--flags]
  multipliers [--node N]        approximate-multiplier library + HW costs
  workloads                     DNN workload inventory
  map --model M [--node N] [--px P --py P --sram KB --rf B] [--twod]
  carbon [--node N] [--px ..]   embodied-carbon breakdown of a config
  dse --model M [--node N] [--delta PCT] [--fps F] [--quick]
  campaign [--models a,b|all] [--nodes 45nm,14nm|all] [--delta 1,2,3]
           [--integrations 3d,2d] [--fps F1,F2] [--workers N] [--quick]
           [--out FILE.jsonl] [--resume] [--seed S]
           [--objective embodied-cdp|operational|lifetime-cdp]
           [--lifetime-years Y] [--ipd N] [--grid-gco2-kwh G] [--no-prune]
           [--shard i/N] [--lease-ttl SECS] [--report-json FILE] [--trace]
           [--no-status] [--no-mapcache]
           [--sampler exhaustive|adaptive] [--sampler-batch N]
           [--explain-prune FILE.jsonl]
           [--fault-plan FILE.json] [--retry-failed]
                                run the whole scenario grid on a worker pool
                                with a campaign-global accuracy cache, an
                                objective-aware bound-ordered queue (jobs
                                that cannot beat the committed front are
                                pruned), an incremental checkpointed Pareto
                                archive, and a resumable JSONL result store.
                                --shard i/N makes this process one of N
                                lease-coordinated shards writing its own
                                shard store beside --out. Every run keeps an
                                atomically-updated live snapshot at
                                `<store>.status.json` (disable with
                                --no-status or CARBON3D_STATUS=0) and a
                                persistent mapping-cache sidecar at
                                `<store>.mapcache.json` that warm-starts
                                resumes and re-runs (disable with
                                --no-mapcache or CARBON3D_MAPCACHE=0; a
                                corrupt sidecar is quietly rebuilt — store
                                bytes never depend on it).
                                --sampler adaptive re-ranks the grid in
                                deterministic batches (--sampler-batch,
                                default 16) by expected improvement over a
                                learned job-cost surrogate and prunes on
                                its margin-tightened bound; the store's
                                header line records the mode, and resume /
                                merge refuse a mode mix. --explain-prune
                                FILE prints per-job analytic vs surrogate
                                bounds for this grid against FILE's rows
                                and which prune rule fires (read-only)
                                A job that panics is quarantined as a
                                `failed` row (counted in the report) instead
                                of killing the campaign; --retry-failed (with
                                --resume) purges those rows so their jobs
                                re-run. --fault-plan FILE (or the compact
                                CARBON3D_FAULTS=site:nth:kind syntax) arms
                                the deterministic fault-injection layer for
                                crash/torn-write/io-error/delay/panic drills
  campaign merge --shards N [--out FILE.jsonl] <same grid flags>
                                fold N shard stores into the canonical
                                store — byte-identical (rows, front sidecar,
                                report counters) to a single-process run —
                                and union the shards' mapcache sidecars
                                into the canonical one
  campaign chaos [--modes threads,sharded,adaptive] [--dir D] <small grid flags>
                                crash-at-every-site recovery proof: per fault
                                site, re-run the grid in a child process with
                                CARBON3D_FAULTS=<site>:1:crash, let it abort
                                mid-operation, resume fault-free, and byte-
                                compare store + front + mapcache sidecars
                                against a fault-free reference — for each
                                executor shape (thread pool, 2 shards +
                                merge, adaptive sampler)
  trace report <trace.jsonl> [--top K] [--check]
                                per-phase breakdown, per-shard lanes, and
                                top-K slowest jobs from a `<store>.trace.jsonl`
                                sidecar; --check only validates the schema and
                                prints a summary. Sidecars come from
                                `campaign --trace` (or CARBON3D_TRACE=1);
                                tracing never changes the store/front bytes.
                                CARBON3D_HEARTBEAT_SECS tunes live-progress
                                cadence (default 5)
  trace merge <shard.trace.jsonl>... --out MERGED.trace.jsonl
                                fold N shard sidecars into one stream on a
                                unified time base, one lane per shard; the
                                output re-validates under `trace report`
  trace diff <old> <new> [--json [FILE]] [--gate PCT]
                                phase-by-phase attribution of wall-clock and
                                counter deltas between two records (trace
                                sidecars or bench --json files); --gate exits
                                non-zero naming the worst regressed phase
  trace export <trace.jsonl> --chrome OUT.json
                                Chrome trace-event JSON for ui.perfetto.dev /
                                chrome://tracing (lanes -> processes, worker
                                threads -> threads, heartbeats -> counters)
  trace metrics <status.json>   render a `<store>.status.json` snapshot in
                                Prometheus text exposition format
  front merge <store.jsonl>... [--axis embodied|lifetime]
                                merge the Pareto fronts of several stores
                                (any objectives/deployments) into one
                                cross-campaign front, each point tagged
                                with its source store and objective
  fig2 [--quick] [--models a,b] reproduce Fig. 2 (normalized delay/carbon)
  fig3 [--quick] [--model M]    reproduce Fig. 3 (gCO2/mm^2 vs FPS)
  report [--quick]              headline paper-vs-measured claims
  accuracy [--pjrt] [--limit N] measured ΔA table on the tiny CNN
  verilog [--out-dir D]         emit structural Verilog for the multiplier library
  pipeline --model M [--segments N]  inter-layer pipelined schedule (Tangram-style)
  lifetime --model M [--ipd N] [--lifetime-years Y] [--grid-gco2-kwh G]
                                embodied vs operational carbon over device lifetime
  selfcheck                     PJRT runtime smoke test

dse also accepts --islands N (island-model GA with ring migration).";

fn cmd_multipliers(o: &Opts) -> Result<()> {
    let node = o.node()?;
    let lib = library();
    let mut t = Table::new(vec![
        "id", "name", "area_um2", "power_uW", "delay_ns", "sig_MRED", "sig_bias", "full_WCE",
    ]);
    for m in &lib {
        let hw = m.hw_cost(node);
        t.row(vec![
            m.id.to_string(),
            m.name(),
            format!("{:.1}", hw.area_um2),
            format!("{:.1}", hw.power_uw),
            format!("{:.2}", hw.delay_ns),
            format!("{:.5}", m.error.sig_mred),
            format!("{:.1}", m.error.sig_bias),
            m.error.full_wce.to_string(),
        ]);
    }
    println!("approximate-multiplier library at {} ({} designs)", node.name(), lib.len());
    println!("{}", t.render());
    Ok(())
}

fn cmd_workloads() -> Result<()> {
    let mut t = Table::new(vec!["name", "layers", "MAC layers", "GMACs", "params(M)"]);
    for name in workload_names() {
        let w = workload(name).unwrap();
        t.row(vec![
            name.to_string(),
            w.layers.len().to_string(),
            w.n_conv_fc().to_string(),
            format!("{:.2}", w.total_macs() as f64 / 1e9),
            format!("{:.1}", w.total_weight_bytes() as f64 / 2e6),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

fn config_from_opts(o: &Opts) -> Result<(AccelConfig, usize)> {
    let node = o.node()?;
    let mult_id = o.usize("mult", EXACT_ID)?;
    let lib_len = library().len();
    if mult_id >= lib_len {
        bail!("--mult {mult_id} out of range (library has {lib_len})");
    }
    Ok((
        AccelConfig {
            px: o.usize("px", 16)?,
            py: o.usize("py", 16)?,
            rf_bytes: o.usize("rf", 512)?,
            sram_bytes: o.usize("sram", 1024)? * 1024,
            node,
            integration: if o.has("twod") { Integration::TwoD } else { Integration::ThreeD },
            mult_id,
        },
        mult_id,
    ))
}

fn cmd_map(o: &Opts) -> Result<()> {
    let model = o.get("model", "vgg16");
    let w = workload(&model).ok_or_else(|| anyhow!("unknown model {model}"))?;
    let (cfg, mult_id) = config_from_opts(o)?;
    let lib = library();
    let m = map_network(&w, &cfg);
    println!("{} on {}", model, cfg.describe(&lib[mult_id]));
    let mut t = Table::new(vec!["layer", "cycles", "compute", "sram", "dram", "util"]);
    for l in m.layers.iter().take(o.usize("limit", 1000)?) {
        t.row(vec![
            l.name.clone(),
            l.cycles.to_string(),
            l.compute_cycles.to_string(),
            l.sram_cycles.to_string(),
            l.dram_cycles.to_string(),
            format!("{:.2}", l.utilization),
        ]);
    }
    println!("{}", t.render());
    println!(
        "total: {} cycles = {:.3} ms  ({:.2} fps, mean util {:.2})",
        m.total_cycles,
        m.delay_s(&cfg) * 1e3,
        m.fps(&cfg),
        m.mean_utilization()
    );
    Ok(())
}

fn cmd_carbon(o: &Opts) -> Result<()> {
    let (cfg, mult_id) = config_from_opts(o)?;
    let lib = library();
    let areas = cfg.die_areas(&lib[mult_id]);
    let b = embodied_carbon(&areas, cfg.node, cfg.integration);
    println!("config: {}", cfg.describe(&lib[mult_id]));
    println!(
        "areas : logic {:.2} mm^2, memory {:.2} mm^2, package {:.2} mm^2",
        areas.logic_mm2, areas.memory_mm2, areas.package_mm2
    );
    let mut t = Table::new(vec!["component", "gCO2", "share_%"]);
    let total = b.total_g();
    for (name, v) in [
        ("logic die", b.logic_die_g),
        ("memory die", b.memory_die_g),
        ("bonding", b.bonding_g),
        ("packaging", b.packaging_g),
    ] {
        t.row(vec![name.to_string(), table::fmt(v), format!("{:.1}", v / total * 100.0)]);
    }
    println!("{}", t.render());
    println!("total embodied carbon: {:.1} gCO2  ({:.2} gCO2/mm^2 of package)", total, total / areas.package_mm2);
    Ok(())
}

fn cmd_dse(o: &Opts) -> Result<()> {
    let model = o.get("model", "vgg16");
    let w = workload(&model).ok_or_else(|| anyhow!("unknown model {model}"))?;
    let node = o.node()?;
    let delta = o.f64("delta", 3.0)?;
    let fps_floor = if o.has("fps") { Some(o.f64("fps", 0.0)?) } else { None };
    let params = ga_params(o)?;
    let lib = library();

    println!(
        "GA-APPX-CDP: {model} @ {}, δ={delta}%, fps_floor={fps_floor:?}, pop={} gens={}",
        node.name(),
        params.population,
        params.generations
    );
    let base = ga_cdp_exact(&w, node, &lib, fps_floor, params);
    let islands = o.usize("islands", 0)?;
    let feasible = feasible_multipliers(&lib, &w, delta, DEFAULT_K);
    ensure!(!feasible.is_empty(), "no multiplier satisfies δ={delta}%");
    // One set of shared evaluation caches for the whole search, so the
    // cache-efficacy line below reflects the run that was just printed.
    let shares = carbon3d::ga::EvalShares::default();
    let r = if islands > 1 {
        use carbon3d::ga::{run_islands_shared, IslandParams, SearchSpace};
        let space = SearchSpace::standard(feasible);
        let ip = IslandParams {
            islands,
            epoch_generations: params.generations / 4 + 1,
            epochs: 4,
            migrants: 2,
            base: params,
        };
        println!("island-model GA: {islands} islands x {} epochs", ip.epochs);
        run_islands_shared(&space, ip, &w, node, Integration::ThreeD, &lib, fps_floor, &shares)
    } else {
        ga_appx_with_feasible_objective_shared(
            &w,
            node,
            Integration::ThreeD,
            &lib,
            feasible,
            fps_floor,
            carbon3d::ga::Objective::embodied(),
            params,
            &shares,
        )
    };
    println!(
        "baseline (GA-CDP-EXACT): {}  carbon {:.1} g, delay {:.2} ms, CDP {:.3}",
        carbon3d::ga::fitness::to_config(&base.best, node, Integration::ThreeD)
            .describe(&lib[base.best.mult_id]),
        base.best_eval.carbon_g,
        base.best_eval.delay_s * 1e3,
        base.best_eval.cdp
    );
    println!(
        "GA-APPX-CDP            : {}  carbon {:.1} g, delay {:.2} ms, CDP {:.3}",
        carbon3d::ga::fitness::to_config(&r.best, node, Integration::ThreeD)
            .describe(&lib[r.best.mult_id]),
        r.best_eval.carbon_g,
        r.best_eval.delay_s * 1e3,
        r.best_eval.cdp
    );
    println!(
        "carbon cut {:.1}%  | delay change {:+.1}%  | {} evals, {} gens",
        (1.0 - r.best_eval.carbon_g / base.best_eval.carbon_g) * 100.0,
        (r.best_eval.delay_s / base.best_eval.delay_s - 1.0) * 100.0,
        r.evaluations,
        r.generations_run
    );
    let (mc, gm) = (shares.mapping.counts(), shares.memo.counts());
    println!(
        "eval caches: {} unique geometries, mapping {}/{} hits ({:.0}%) | \
         GA memo {}/{} hits ({:.0}%)",
        shares.mapping.len(),
        mc.hits,
        mc.lookups(),
        mc.hit_rate() * 100.0,
        gm.hits,
        gm.lookups(),
        gm.hit_rate() * 100.0,
    );
    Ok(())
}

/// Build the campaign spec from CLI flags — shared by `campaign`,
/// `campaign --shard i/N`, and `campaign merge`, which must agree on the
/// spec for shard stores to merge byte-identically.
fn campaign_spec_from_opts(o: &Opts) -> Result<carbon3d::campaign::CampaignSpec> {
    use carbon3d::campaign::spec::integration_from_name;
    use carbon3d::campaign::{CampaignObjective, CampaignSpec};

    let models_arg = o.get("models", "all");
    let models: Vec<String> = if models_arg == "all" {
        FIG2_MODELS.iter().map(|s| s.to_string()).collect()
    } else {
        models_arg.split(',').map(|s| s.trim().to_string()).collect()
    };
    for m in &models {
        workload(m).ok_or_else(|| anyhow!("unknown model {m}"))?;
    }
    let nodes_arg = o.get("nodes", "all");
    let nodes: Vec<TechNode> = if nodes_arg == "all" {
        ALL_NODES.to_vec()
    } else {
        nodes_arg
            .split(',')
            .map(|s| {
                TechNode::from_name(s.trim())
                    .ok_or_else(|| anyhow!("unknown node {s} (45nm|14nm|7nm)"))
            })
            .collect::<Result<_>>()?
    };
    let deltas: Vec<f64> = o
        .get("delta", "1,2,3")
        .split(',')
        .map(|s| {
            s.trim()
                .parse::<f64>()
                .with_context(|| format!("--delta expects numbers, got {s}"))
        })
        .collect::<Result<_>>()?;
    let integrations: Vec<Integration> = o
        .get("integrations", "3d")
        .split(',')
        .map(|s| {
            integration_from_name(s.trim())
                .ok_or_else(|| anyhow!("unknown integration {s} (2d|3d)"))
        })
        .collect::<Result<_>>()?;
    let fps_floors: Vec<Option<f64>> = match o.flags.get("fps") {
        None => vec![None],
        Some(s) => s
            .split(',')
            .map(|v| {
                v.trim()
                    .parse::<f64>()
                    .map(Some)
                    .with_context(|| format!("--fps expects numbers, got {v}"))
            })
            .collect::<Result<_>>()?,
    };

    let obj_arg = o.get("objective", "embodied-cdp");
    let objective = CampaignObjective::from_name(&obj_arg).ok_or_else(|| {
        anyhow!("unknown objective {obj_arg} (embodied-cdp|operational|lifetime-cdp)")
    })?;

    let sampler = match o.get("sampler", "exhaustive").as_str() {
        "exhaustive" => carbon3d::campaign::SamplerMode::Exhaustive,
        "adaptive" => carbon3d::campaign::SamplerMode::Adaptive {
            batch: o.usize("sampler-batch", 16)?,
        },
        other => bail!("unknown sampler {other:?} (exhaustive|adaptive)"),
    };

    let mut spec = CampaignSpec::new(models, nodes, deltas);
    spec.integrations = integrations;
    spec.fps_floors = fps_floors;
    spec.ga = ga_params(o)?;
    spec.seed = o.usize("seed", 0xCA4B07)? as u64;
    spec.objective = objective;
    spec.deployment = deployment_from_opts(o)?;
    spec.prune = !o.has("no-prune");
    spec.sampler = sampler;
    spec.validate()?;
    Ok(spec)
}

/// `--report-json FILE`: persist the timing-free report counters (used by
/// CI to byte-compare a sharded merge against a single-process run).
fn write_report_json(o: &Opts, report: &carbon3d::campaign::CampaignReport) -> Result<()> {
    if let Some(path) = o.flags.get("report-json") {
        std::fs::write(path, report.deterministic_json().dumps())
            .with_context(|| format!("write report counters {path}"))?;
    }
    Ok(())
}

fn print_campaign_summary(
    store: &carbon3d::campaign::ResultStore,
    axis: carbon3d::campaign::CarbonAxis,
) -> Result<()> {
    use carbon3d::campaign::{CampaignArchive, GroupBy};
    let arch = CampaignArchive::load_or_rebuild(
        store.rows(),
        axis,
        &CampaignArchive::checkpoint_path(store.path()),
    )?;
    println!("\n== per-node summary ==");
    println!("{}", arch.aggregate_table(GroupBy::Node).render());
    println!("== per-workload summary ==");
    println!("{}", arch.aggregate_table(GroupBy::Model).render());
    println!(
        "== cross-scenario Pareto front ({} carbon / delay / accuracy-drop, {} of {} points) ==",
        axis.name(),
        arch.front.len(),
        arch.points.len()
    );
    println!("{}", arch.pareto_table().render());
    Ok(())
}

/// Tracing is requested by `--trace` or a non-empty, non-"0"
/// `CARBON3D_TRACE` environment variable.
fn trace_enabled(o: &Opts) -> bool {
    o.has("trace")
        || matches!(std::env::var("CARBON3D_TRACE"), Ok(v) if !v.is_empty() && v != "0")
}

/// Install the trace sidecar writer next to `store_path` (so the sidecar
/// of `campaign.shard0of3.jsonl` is `campaign.shard0of3.trace.jsonl`).
/// Installed *before* the store opens so recovery events land in the
/// trace too. Returns the sidecar path for the closing message.
fn install_tracer(store_path: &Path, shard: Option<&str>) -> Result<std::path::PathBuf> {
    let trace_path = store_path.with_extension("trace.jsonl");
    carbon3d::obs::install(&trace_path, store_path, shard)?;
    eprintln!("[trace] writing sidecar {}", trace_path.display());
    Ok(trace_path)
}

/// Close the sidecar (final metrics snapshot, flush) and tell the user
/// where it went and how to read it.
fn finish_tracer() {
    if let Some(s) = carbon3d::obs::uninstall() {
        println!(
            "trace: {} lines -> {} (inspect with `carbon3d trace report {}`)",
            s.lines,
            s.path.display(),
            s.path.display()
        );
    }
}

fn cmd_trace(args: &[String]) -> Result<()> {
    const USAGE: &str = "usage: carbon3d trace <report|merge|diff|export|metrics> ...";
    match args.first().map(String::as_str) {
        Some("report") => cmd_trace_report(&args[1..]),
        Some("merge") => cmd_trace_merge(&args[1..]),
        Some("diff") => cmd_trace_diff(&args[1..]),
        Some("export") => cmd_trace_export(&args[1..]),
        Some("metrics") => cmd_trace_metrics(&args[1..]),
        Some(other) => bail!("unknown trace subcommand {other:?}; {USAGE}"),
        None => bail!("{USAGE}"),
    }
}

fn cmd_trace_report(args: &[String]) -> Result<()> {
    use carbon3d::obs::TraceReport;

    const USAGE: &str = "usage: carbon3d trace report <trace.jsonl> [--top K] [--check]";
    let o = Opts::parse(args);
    let path = o
        .positionals
        .first()
        .ok_or_else(|| anyhow!("trace report needs a sidecar path; {USAGE}"))?;
    let r = TraceReport::load(Path::new(path))?;
    if o.has("check") {
        println!(
            "{path}: OK ({}, {} lines: {} spans, {} events, {} heartbeats, {} metrics, \
             {} lanes)",
            r.schema,
            r.lines,
            r.spans.len(),
            r.events.len(),
            r.beats.len(),
            r.metrics_lines,
            r.lanes().len()
        );
    } else {
        println!("{}", r.render(o.usize("top", 5)?));
    }
    Ok(())
}

fn cmd_trace_merge(args: &[String]) -> Result<()> {
    const USAGE: &str =
        "usage: carbon3d trace merge <shard.trace.jsonl>... --out MERGED.trace.jsonl";
    let o = Opts::parse(args);
    let inputs: Vec<std::path::PathBuf> =
        o.positionals.iter().map(std::path::PathBuf::from).collect();
    if inputs.is_empty() {
        bail!("trace merge needs at least one input sidecar; {USAGE}");
    }
    let out = o
        .flags
        .get("out")
        .ok_or_else(|| anyhow!("trace merge needs --out FILE; {USAGE}"))?;
    let s = carbon3d::obs::merge_traces(&inputs, Path::new(out))?;
    println!(
        "merged {} sidecars ({} lanes: {}) -> {} ({} lines, epoch {} ms; inspect with \
         `carbon3d trace report {}`)",
        s.inputs,
        s.lanes.len(),
        s.lanes.join(", "),
        s.path.display(),
        s.lines,
        s.epoch_ms,
        s.path.display()
    );
    Ok(())
}

fn cmd_trace_diff(args: &[String]) -> Result<()> {
    use carbon3d::obs::diff::DiffReport;
    use carbon3d::obs::ObsRecord;

    const USAGE: &str =
        "usage: carbon3d trace diff <old> <new> [--json [FILE]] [--gate PCT]";
    let o = Opts::parse(args);
    let [old_path, new_path] = o.positionals.as_slice() else {
        bail!("trace diff needs exactly two records (trace sidecars or bench --json files); {USAGE}");
    };
    let d = DiffReport::new(
        ObsRecord::load(Path::new(old_path))?,
        ObsRecord::load(Path::new(new_path))?,
    );
    let gate = match o.flags.get("gate") {
        Some(_) => Some(o.f64("gate", 0.0)?),
        None => None,
    };
    match o.flags.get("json") {
        // Bare `--json` prints to stdout; `--json FILE` writes the file.
        Some(v) if v == "true" => println!("{}", d.to_json(gate).pretty(2)),
        Some(path) => std::fs::write(path, format!("{}\n", d.to_json(gate).pretty(2)))
            .with_context(|| format!("write diff json {path}"))?,
        None => print!("{}", d.render()),
    }
    if let Some(gate_pct) = gate {
        let regressions = d.regressions(gate_pct);
        if let Some(worst) = regressions.first() {
            bail!(
                "{} phase(s) regressed past the {gate_pct}% gate; worst: {} \
                 ({:+.1}% total, p50 {} -> {})",
                regressions.len(),
                worst.name,
                worst.total_pct().unwrap_or(0.0),
                carbon3d::obs::human_time(worst.old.p50 / 1e6),
                carbon3d::obs::human_time(worst.new.p50 / 1e6),
            );
        }
        println!("gate: no phase regressed past {gate_pct}%");
    }
    Ok(())
}

fn cmd_trace_export(args: &[String]) -> Result<()> {
    const USAGE: &str = "usage: carbon3d trace export <trace.jsonl> --chrome OUT.json";
    let o = Opts::parse(args);
    let trace = o
        .positionals
        .first()
        .ok_or_else(|| anyhow!("trace export needs a sidecar path; {USAGE}"))?;
    let out = o
        .flags
        .get("chrome")
        .ok_or_else(|| anyhow!("trace export needs --chrome OUT.json; {USAGE}"))?;
    let n = carbon3d::obs::export::export_chrome(Path::new(trace), Path::new(out))?;
    println!(
        "wrote {n} trace events -> {out} (open in ui.perfetto.dev or chrome://tracing)"
    );
    Ok(())
}

fn cmd_trace_metrics(args: &[String]) -> Result<()> {
    const USAGE: &str = "usage: carbon3d trace metrics <status.json>";
    let o = Opts::parse(args);
    let path = o
        .positionals
        .first()
        .ok_or_else(|| anyhow!("trace metrics needs a status snapshot path; {USAGE}"))?;
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let doc = carbon3d::util::Json::parse(&text)
        .with_context(|| format!("{path}: not a JSON document"))?;
    print!("{}", carbon3d::obs::status::prometheus_text(&doc)?);
    Ok(())
}

/// Arm the deterministic fault-injection plan from `--fault-plan FILE`
/// or the `CARBON3D_FAULTS` environment variable (how the chaos harness
/// arms its children). No-op when neither is present — fault sites then
/// cost a single relaxed atomic load.
fn arm_faults(o: &Opts) -> Result<()> {
    use carbon3d::campaign::fault;
    if let Some(path) = o.flags.get("fault-plan") {
        let rules = fault::load_plan_file(Path::new(path))?;
        eprintln!("fault: armed {} rule(s) from {path}", rules.len());
        fault::arm(rules);
    } else if fault::arm_from_env()? {
        eprintln!("fault: armed plan from CARBON3D_FAULTS");
    }
    Ok(())
}

fn cmd_campaign(o: &Opts) -> Result<()> {
    use carbon3d::campaign::{
        explain_prune, run_campaign_with, shard_store_path, start_service, AdaptiveExecutor,
        Executor, LeaseDir, ResultStore, SamplerMode, ShardId, ShardedExecutor,
        ThreadPoolExecutor,
    };

    arm_faults(o)?;
    let spec = campaign_spec_from_opts(o)?;

    // `--explain-prune <store>`: read-only prune diagnosis — rebuild the
    // analytic bounds and the end-of-run surrogate state for this grid and
    // print, per job, which rule fires (or why the job stands). No rows
    // are written and no GA runs.
    if let Some(store_arg) = o.flags.get("explain-prune") {
        let store = ResultStore::open(Path::new(store_arg))?;
        let (svc, backend) = start_service(Path::new(&o.get("artifacts", "artifacts")))?;
        println!(
            "explain-prune: {} ({} rows, {backend} accuracy backend)",
            store_arg,
            store.len()
        );
        let explained = explain_prune(&spec, &store, &svc);
        svc.shutdown();
        print!("{}", explained?);
        return Ok(());
    }

    let out = o.get("out", "results/campaign.jsonl");
    let canonical = Path::new(&out);
    let shard = match o.flags.get("shard") {
        Some(s) => Some(ShardId::parse(s)?),
        None => None,
    };
    if shard.is_some() && spec.sampler != SamplerMode::Exhaustive {
        bail!(
            "--shard cannot combine with --sampler adaptive: the adaptive planner's \
             batch replay needs the whole grid in one process (run it unsharded, or \
             drop --sampler for lease-coordinated shards)"
        );
    }
    let store_path = match shard {
        Some(s) => shard_store_path(canonical, s),
        None => canonical.to_path_buf(),
    };
    if o.has("no-status") {
        carbon3d::obs::status::set_enabled(false);
    }
    if o.has("no-mapcache") {
        carbon3d::campaign::mapcache::set_enabled(false);
    }
    if trace_enabled(o) {
        let label = shard.map(|s| s.to_string());
        install_tracer(&store_path, label.as_deref())?;
    }
    let mut store = ResultStore::open(&store_path)?;
    if !store.is_empty() && !o.has("resume") {
        bail!(
            "store {} already has {} rows; pass --resume to continue it or remove the file",
            store_path.display(),
            store.len()
        );
    }
    if o.has("retry-failed") {
        // Drop quarantined rows so the resume re-runs their jobs (the
        // guard above means this only happens under --resume).
        let purged = store.purge_failed()?;
        println!("retry-failed: purged {purged} quarantined row(s); their jobs will re-run");
    }
    let executor: Box<dyn Executor> = match shard {
        Some(s) => {
            let leases = LeaseDir::open(
                LeaseDir::for_store(canonical),
                format!("shard{}of{}-pid{}", s.index, s.count, std::process::id()),
                o.usize("lease-ttl", 900)? as u64,
            )?;
            Box::new(ShardedExecutor { shard: s, leases })
        }
        None => match spec.sampler {
            SamplerMode::Exhaustive => {
                Box::new(ThreadPoolExecutor::new(o.usize("workers", 4)?))
            }
            SamplerMode::Adaptive { batch } => {
                Box::new(AdaptiveExecutor::new(o.usize("workers", 4)?, batch))
            }
        },
    };
    let (svc, backend) = start_service(Path::new(&o.get("artifacts", "artifacts")))?;
    println!(
        "campaign: {} jobs = {} models x {} nodes x {} integrations x {} deltas x {} fps | \
         objective {} ({}y, {:.0} inf/day, {:.0} gCO2/kWh) | {} | \
         {backend} accuracy backend | store {}",
        spec.n_jobs(),
        spec.models.len(),
        spec.nodes.len(),
        spec.integrations.len(),
        spec.deltas.len(),
        spec.fps_floors.len(),
        spec.objective.name(),
        spec.deployment.lifetime_years,
        spec.deployment.inferences_per_day,
        spec.deployment.grid_kgco2_per_kwh * 1000.0,
        executor.describe(),
        store_path.display(),
    );
    let report = run_campaign_with(&spec, executor.as_ref(), &mut store, &svc)?;
    svc.shutdown();
    write_report_json(o, &report)?;
    match shard {
        Some(s) => {
            // A shard store is a partial view: skip the archive tables and
            // point at the merge step instead.
            println!("{}", report.line());
            println!(
                "shard {} done; once every shard finishes, fold the stores with \
                 `carbon3d campaign merge --shards {} --out {out} <same grid flags>`",
                s, s.count
            );
        }
        None => {
            print_campaign_summary(&store, spec.objective.carbon_axis())?;
            println!("{}", report.line());
        }
    }
    finish_tracer();
    Ok(())
}

fn cmd_campaign_merge(o: &Opts) -> Result<()> {
    use carbon3d::campaign::{
        mapcache, run_campaign_with, shard_store_path, start_service, MergeExecutor,
        ResultStore, ShardId,
    };

    arm_faults(o)?;
    let spec = campaign_spec_from_opts(o)?;
    if spec.sampler != carbon3d::campaign::SamplerMode::Exhaustive {
        bail!(
            "campaign merge only folds exhaustive shard stores — adaptive campaigns \
             run in one process and need no merge (drop --sampler adaptive)"
        );
    }
    let shards = o.usize("shards", 0)?;
    if shards == 0 {
        bail!("campaign merge requires --shards N (the count the shards ran with)");
    }
    let out = o.get("out", "results/campaign.jsonl");
    let canonical = Path::new(&out);
    if o.has("no-status") {
        carbon3d::obs::status::set_enabled(false);
    }
    if o.has("no-mapcache") {
        mapcache::set_enabled(false);
    }
    if trace_enabled(o) {
        install_tracer(canonical, Some("merge"))?;
    }
    // Union the shards' mapcache sidecars into the canonical one before the
    // merge runs, so the merge itself (and every later resume) starts from
    // everything any shard learned. A hint, not a dependency: unreadable
    // shard sidecars are skipped quietly.
    if mapcache::enabled() {
        let shard_sidecars: Vec<std::path::PathBuf> = (0..shards)
            .map(|i| {
                mapcache::mapcache_path(&shard_store_path(
                    canonical,
                    ShardId { index: i, count: shards },
                ))
            })
            .collect();
        let n = mapcache::merge_sidecars(&mapcache::mapcache_path(canonical), &shard_sidecars)?;
        if n > 0 {
            println!("mapcache: {n} entries unioned from {shards} shard sidecars");
        }
    }
    let mut store = ResultStore::open(canonical)?;
    if !store.is_empty() && !o.has("resume") {
        bail!(
            "store {out} already has {} rows; pass --resume to continue it or remove the file",
            store.len()
        );
    }
    let merge = MergeExecutor::from_shard_stores(canonical, shards)?;
    let (svc, backend) = start_service(Path::new(&o.get("artifacts", "artifacts")))?;
    println!(
        "campaign merge: folding {shards} shard stores ({} rows) into {out} | \
         {backend} accuracy backend",
        merge.n_rows()
    );
    let report = run_campaign_with(&spec, &merge, &mut store, &svc)?;
    svc.shutdown();
    write_report_json(o, &report)?;
    print_campaign_summary(&store, spec.objective.carbon_axis())?;
    println!("{}", report.line());
    finish_tracer();
    Ok(())
}

fn cmd_campaign_chaos(o: &Opts) -> Result<()> {
    use carbon3d::campaign::chaos::{
        failures, render_reports, uncovered_sites, ChaosHarness, ChaosMode,
    };

    // Grid/GA flags forwarded verbatim to every child campaign; the
    // harness itself owns --out, --shard, --lease-ttl, --sampler and
    // --resume.
    let mut grid: Vec<String> = Vec::new();
    for key in [
        "models", "nodes", "delta", "integrations", "fps", "objective", "lifetime-years",
        "ipd", "grid-gco2-kwh", "seed", "pop", "gens", "workers", "sampler-batch", "artifacts",
    ] {
        if let Some(v) = o.flags.get(key) {
            grid.push(format!("--{key}"));
            grid.push(v.clone());
        }
    }
    if o.has("quick") {
        grid.push("--quick".to_string());
    }
    if o.has("no-prune") {
        grid.push("--no-prune".to_string());
    }
    let modes: Vec<ChaosMode> = match o.flags.get("modes") {
        None => ChaosMode::ALL.to_vec(),
        Some(s) => s.split(',').map(ChaosMode::parse).collect::<Result<_>>()?,
    };
    let all_modes = ChaosMode::ALL.iter().all(|m| modes.contains(m));
    let dir = match o.flags.get("dir") {
        Some(d) => std::path::PathBuf::from(d),
        None => std::env::temp_dir().join(format!("carbon3d-chaos-{}", std::process::id())),
    };
    std::fs::create_dir_all(&dir).with_context(|| format!("creating {}", dir.display()))?;
    let harness =
        ChaosHarness { exe: std::env::current_exe()?, grid, dir: dir.clone() };
    println!(
        "chaos: probing {} fault sites x {} mode(s); campaign stores under {}",
        carbon3d::campaign::fault::SITES.len(),
        modes.len(),
        dir.display()
    );
    let reports = harness.run(&modes)?;
    println!();
    print!("{}", render_reports(&reports));
    let bad = failures(&reports);
    if !bad.is_empty() {
        bail!(
            "chaos: {} probe(s) diverged after crash + resume (stores kept under {})",
            bad.len(),
            dir.display()
        );
    }
    if all_modes {
        let dead = uncovered_sites(&reports);
        if !dead.is_empty() {
            bail!(
                "chaos: fault site(s) never hit by any mode: {} — stale SITES registry \
                 or a call site lost its fault hook",
                dead.join(", ")
            );
        }
    }
    println!("chaos: every hit site recovered to byte-identical artifacts");
    Ok(())
}

fn cmd_front(args: &[String]) -> Result<()> {
    use carbon3d::campaign::{merge_store_fronts, CarbonAxis};

    const USAGE: &str =
        "usage: carbon3d front merge <store.jsonl>... [--axis embodied|lifetime]";
    match args.first().map(String::as_str) {
        Some("merge") => {}
        Some(other) => bail!("unknown front subcommand {other:?}; {USAGE}"),
        None => bail!("{USAGE}"),
    }
    let o = Opts::parse(&args[1..]);
    let stores = &o.positionals;
    if stores.is_empty() {
        bail!("front merge needs at least one store path; {USAGE}");
    }
    let axis_name = o.get("axis", "lifetime");
    let axis = CarbonAxis::from_name(&axis_name)
        .ok_or_else(|| anyhow!("unknown axis {axis_name} (embodied|lifetime)"))?;
    let merged = merge_store_fronts(stores, axis)?;
    println!(
        "== cross-campaign Pareto front ({} carbon / delay / accuracy-drop; {} of {} \
         front candidates from {} stores) ==",
        axis.name(),
        merged.front.len(),
        merged.points.len(),
        stores.len()
    );
    println!("{}", merged.table().render());
    Ok(())
}

fn cmd_fig2(o: &Opts) -> Result<()> {
    let lib = library();
    let params = ga_params(o)?;
    let models_arg = o.get("models", &FIG2_MODELS.join(","));
    let models: Vec<&str> = models_arg.split(',').collect();
    let r = run_fig2(&lib, &models, params);
    println!("{}", r.render());
    for &node in &ALL_NODES {
        println!(
            "{}: max carbon cut {:.1}%",
            node.name(),
            r.max_carbon_cut_pct(node)
        );
    }
    Ok(())
}

fn cmd_fig3(o: &Opts) -> Result<()> {
    let lib = library();
    let params = ga_params(o)?;
    let model = o.get("model", "vgg16");
    let r = run_fig3(&lib, &model, params);
    println!("{}", r.render());
    Ok(())
}

fn cmd_report(o: &Opts) -> Result<()> {
    let lib = library();
    let params = ga_params(o)?;
    println!("running Fig.2 grid...");
    let fig2 = run_fig2(&lib, &FIG2_MODELS, params);
    println!("running Fig.3 sweeps...");
    let fig3 = run_fig3(&lib, "vgg16", params);
    println!("\n== headline claims (paper vs measured) ==");
    for c in headline_report(&fig2, &fig3) {
        println!("{}", c.line());
    }
    Ok(())
}

fn cmd_accuracy(o: &Opts) -> Result<()> {
    let artifacts = Artifacts::load(Path::new(&o.get("artifacts", "artifacts")))?;
    let lib = library();
    let limit = o.usize("limit", lib.len())?;
    let tiny = workload("tinycnn").unwrap();

    if o.has("pjrt") {
        let engine = Engine::new(artifacts)?;
        println!("PJRT platform: {}", engine.platform());
        let mults: Vec<&carbon3d::approx::Multiplier> = lib.iter().take(limit).collect();
        let t = engine.measure_table(&mults)?;
        let k = calibrate_k(&lib, &tiny, &t);
        print_accuracy_table(&lib[..limit.min(lib.len())], &t, &tiny, k);
    } else {
        let native = NativeEvaluator::load(&Artifacts::load(Path::new(
            &o.get("artifacts", "artifacts"),
        ))?)?;
        let mut t = carbon3d::accuracy::AccuracyTable {
            exact: native.accuracy(&ApproxDatapath::new(&lib[EXACT_ID])),
            ..Default::default()
        };
        for m in lib.iter().take(limit) {
            t.accuracy.insert(m.id, native.accuracy(&ApproxDatapath::new(m)));
        }
        let k = calibrate_k(&lib, &tiny, &t);
        print_accuracy_table(&lib[..limit.min(lib.len())], &t, &tiny, k);
    }
    Ok(())
}

fn print_accuracy_table(
    mults: &[carbon3d::approx::Multiplier],
    t: &carbon3d::accuracy::AccuracyTable,
    tiny: &carbon3d::dataflow::workloads::Workload,
    k: f64,
) {
    let mut tab = Table::new(vec!["id", "mult", "accuracy", "drop_pp", "model_pred_pp"]);
    for m in mults {
        let acc = t.accuracy[&m.id];
        tab.row(vec![
            m.id.to_string(),
            m.name(),
            format!("{:.4}", acc),
            format!("{:+.2}", (t.exact - acc) * 100.0),
            format!("{:.2}", predicted_drop_pct(m, tiny, k)),
        ]);
    }
    println!("exact-path accuracy: {:.4}   calibrated K = {:.2} (default {DEFAULT_K})", t.exact, k);
    println!("{}", tab.render());
}

fn cmd_verilog(o: &Opts) -> Result<()> {
    let out_dir = o.get("out-dir", "results/verilog");
    std::fs::create_dir_all(&out_dir)?;
    let all = carbon3d::approx::netlist::export_all_verilog();
    let lib = library();
    let mut t = Table::new(vec!["mult", "gates", "depth", "file"]);
    for m in &lib {
        if let Some(nl) = m.kind.netlist() {
            let file = format!("{out_dir}/{}.v", m.name().to_lowercase());
            std::fs::write(&file, &all[&m.name()])?;
            t.row(vec![
                m.name(),
                nl.gate_count().to_string(),
                nl.depth().to_string(),
                file,
            ]);
        }
    }
    println!("{}", t.render());
    println!("wrote {} structural netlists to {out_dir}/", all.len());
    println!("(log-domain designs MITCH/DRUM* use macro blocks — no flat netlist)");
    Ok(())
}

fn cmd_pipeline(o: &Opts) -> Result<()> {
    use carbon3d::dataflow::pipeline::{best_pipeline, schedule_pipeline};
    let model = o.get("model", "vgg16");
    let w = workload(&model).ok_or_else(|| anyhow!("unknown model {model}"))?;
    let (cfg, mult_id) = config_from_opts(o)?;
    let lib = library();
    println!("{} on {}", model, cfg.describe(&lib[mult_id]));
    let max_segments = o.usize("segments", 6)?;
    let single = schedule_pipeline(&w, &cfg, 1);
    let best = best_pipeline(&w, &cfg, max_segments);
    let mut t = Table::new(vec!["segment", "layers", "pe_share", "cycles"]);
    for (i, s) in best.segments.iter().enumerate() {
        t.row(vec![
            i.to_string(),
            format!("{}..{}", s.layer_range.0, s.layer_range.1),
            format!("{:.2}", s.pe_share),
            s.cycles.to_string(),
        ]);
    }
    println!("{}", t.render());
    println!(
        "layer-by-layer: {:.2} fps | pipelined ({} segments): {:.2} fps throughput, {:.2} ms latency",
        single.throughput_fps(&cfg),
        best.segments.len(),
        best.throughput_fps(&cfg),
        best.latency_s(&cfg) * 1e3,
    );
    Ok(())
}

fn cmd_lifetime(o: &Opts) -> Result<()> {
    use carbon3d::carbon::operational::{embodied_share, operational_carbon_with};
    use carbon3d::dataflow::mapper::map_network;
    let model = o.get("model", "resnet50");
    let w = workload(&model).ok_or_else(|| anyhow!("unknown model {model}"))?;
    let (cfg, mult_id) = config_from_opts(o)?;
    let lib = library();
    let dep = deployment_from_opts(o)?;
    let mapping = map_network(&w, &cfg);
    let areas = cfg.die_areas(&lib[mult_id]);
    let emb = embodied_carbon(&areas, cfg.node, cfg.integration).total_g();
    let op = operational_carbon_with(&cfg, &lib[mult_id], &mapping, &dep);
    println!("{} on {}", model, cfg.describe(&lib[mult_id]));
    println!(
        "energy/inference {:.2} mJ | {:.0} inferences/day | lifetime {:.1} kWh @ {:.0} gCO2/kWh",
        op.energy_per_inference_j * 1e3,
        op.inferences_per_day,
        op.lifetime_kwh,
        dep.grid_kgco2_per_kwh * 1000.0
    );
    println!(
        "embodied {:.1} gCO2 vs operational {:.1} gCO2 over {} years -> embodied share {:.0}%",
        emb,
        op.lifetime_gco2,
        dep.lifetime_years,
        embodied_share(emb, &op) * 100.0
    );
    Ok(())
}

fn cmd_selfcheck() -> Result<()> {
    let artifacts = Artifacts::load(Path::new("artifacts"))?;
    artifacts.verify()?;
    println!("artifacts OK ({} files)", Artifacts::hlo_names().len());
    let engine = Engine::new(artifacts)?;
    println!("PJRT platform: {} ({} devices)", engine.platform(), 1);

    // matmul artifacts: exact LUT through the approx kernel == exact kernel.
    let lib = library();
    let lut = lut_f32(&lib[EXACT_ID]);
    let mut a = vec![0f32; 64 * 64];
    let mut b = vec![0f32; 64 * 64];
    for i in 0..64 * 64 {
        a[i] = ((i % 97) as f32 - 48.0) * 0.11;
        b[i] = ((i % 89) as f32 - 44.0) * 0.07;
    }
    let approx = engine
        .executable("matmul_approx")
        .unwrap()
        .run_f32(&[(&a, &[64, 64]), (&b, &[64, 64]), (&lut, &[128, 128])])?;
    let exact = engine
        .executable("matmul_exact")
        .unwrap()
        .run_f32(&[(&a, &[64, 64]), (&b, &[64, 64])])?;
    let max_err = approx
        .iter()
        .zip(&exact)
        .map(|(x, y)| (x - y).abs())
        .fold(0f32, f32::max);
    println!("matmul exact-LUT max |err| vs exact path: {max_err:.2e}");
    anyhow::ensure!(max_err < 1e-3, "kernel mismatch: {max_err}");

    // CNN artifacts: PJRT exact accuracy matches the manifest.
    let acc = engine.accuracy_pjrt(None)?;
    println!(
        "PJRT exact accuracy {:.4} (manifest {:.4})",
        acc, engine.artifacts.exact_test_accuracy
    );
    anyhow::ensure!((acc - engine.artifacts.exact_test_accuracy).abs() < 1e-6);
    println!("selfcheck OK");
    Ok(())
}
