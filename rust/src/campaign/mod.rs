//! Design-space-exploration **campaign engine**: run an entire scenario
//! grid — {workload} x {TechNode} x {Integration} x {δ} x {FPS floor} —
//! as a job queue, instead of one GA invocation at a time.
//!
//! The engine is three explicit layers (DESIGN.md §6):
//! - [`source`] — **JobSource**: deterministic grid enumeration, per-job
//!   optimistic bounds ([`source::JobBound`]), and the schedule order
//!   (ascending bound; commits follow it). Pure function of the spec and
//!   the rows already committed — identical for any worker count, shard
//!   count, or resume boundary.
//! - [`exec`] — **Executor**: who evaluates jobs. The in-process
//!   [`exec::ThreadPoolExecutor`], the multi-process
//!   [`exec::sharded::ShardedExecutor`] (file-based [`lease`] claims, one
//!   store per shard), and [`exec::sharded::MergeExecutor`] (folds shard
//!   stores into the canonical store). All executors in a process share
//!   ONE [`crate::runtime::EvalService`], so accuracy evaluations are
//!   cached campaign-globally.
//! - [`commit`] — **CommitPipeline**: reorder buffer, the writer-
//!   authoritative prune decision ([`source::prune_reason`]; `--no-prune`
//!   for exhaustive grids), the JSONL append, and the incremental Pareto
//!   archive with its atomically-written sidecar checkpoint.
//!
//! Around them: [`spec`] (grid + [`CampaignObjective`] + key-derived
//! per-job seeds), [`store`] (append-only JSONL with checkpoint/resume;
//! torn final lines dropped, anything else loud), [`pareto`] +
//! [`checkpoint`] + [`front`] (archive core, sidecar I/O, presentation and
//! cross-campaign front merging), [`mapcache`] (the persistent
//! mapping-cache sidecar: a pure performance hint that must never change
//! store bytes), and [`surrogate`] (the learned job-cost model behind the
//! `--sampler adaptive` planner in [`exec::AdaptiveExecutor`]: tightened
//! bounds with a calibrated residual margin, batch re-ranking by expected
//! improvement, surrogate prunes counted separately).
//!
//! Invariant the tests pin down: for a fixed campaign seed and sampler,
//! the final store bytes are identical whether the campaign ran
//! uninterrupted with any number of workers, was killed and resumed, or
//! (exhaustive only) was sharded across N processes and merged. Adaptive
//! stores carry a header line recording their sampler mode, so resume and
//! `campaign merge` refuse mode mixes instead of corrupting the contract.

pub mod chaos;
pub mod checkpoint;
pub mod clock;
pub mod commit;
pub mod exec;
pub mod fault;
pub mod front;
pub mod lease;
pub mod mapcache;
pub mod pareto;
pub mod source;
pub mod spec;
pub mod store;
pub mod surrogate;

pub use commit::{CommitPipeline, CommitTotals, FrontCell, JobOutcome};
pub use mapcache::{mapcache_path, MapCachePersist};
pub use exec::sharded::{shard_store_path, MergeExecutor, ShardId, ShardedExecutor};
pub use exec::{
    explain_prune, run_campaign, run_campaign_with, start_service, AdaptiveExecutor,
    CampaignReport, Executor, SurrogateBackend, ThreadPoolExecutor,
};
pub use front::{merge_fronts, merge_store_fronts, MergedFront, MergedPoint};
pub use lease::{Claim, LeaseDir};
pub use pareto::{ArchivePoint, CampaignArchive, CarbonAxis, GroupBy};
pub use source::{job_bound, prune_reason, shard_owner, JobBound, JobCtx, JobSource};
pub use spec::{CampaignObjective, CampaignSpec, JobSpec, SamplerMode};
pub use store::ResultStore;
pub use surrogate::{prune_rule, CostSurrogate, PruneRule};

#[cfg(test)]
mod tests {
    use std::path::PathBuf;

    use super::*;
    use crate::area::TechNode;
    use crate::ga::GaParams;
    use crate::runtime::EvalService;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!(
            "carbon3d-campaign-{}-{name}.jsonl",
            std::process::id()
        ))
    }

    fn cleanup(path: &std::path::Path) {
        let _ = std::fs::remove_file(path);
        let _ = std::fs::remove_file(CampaignArchive::checkpoint_path(path));
        let _ = std::fs::remove_file(crate::obs::status::status_path(path));
        let _ = std::fs::remove_file(mapcache::mapcache_path(path));
    }

    /// 2 models x 2 nodes x 2 deltas = 8 jobs, tiny GA budget.
    fn quick_spec() -> CampaignSpec {
        let mut s = CampaignSpec::new(
            vec!["vgg16".to_string(), "resnet50".to_string()],
            vec![TechNode::N45, TechNode::N7],
            vec![1.0, 3.0],
        );
        s.ga = GaParams { population: 8, generations: 4, patience: 2, elites: 1, ..Default::default() };
        s
    }

    fn run_spec_to(
        spec: &CampaignSpec,
        path: &std::path::Path,
        workers: usize,
    ) -> (CampaignReport, String) {
        let mut store = ResultStore::open(path).unwrap();
        // Surrogate backend: deterministic and artifact-free.
        let svc = EvalService::start(SurrogateBackend::default());
        let report = run_campaign(spec, workers, &mut store, &svc).unwrap();
        svc.shutdown();
        (report, std::fs::read_to_string(path).unwrap())
    }

    fn run_to(path: &std::path::Path, workers: usize) -> (CampaignReport, String) {
        run_spec_to(&quick_spec(), path, workers)
    }

    #[test]
    fn campaign_resume_and_worker_count_are_invisible_in_the_store() {
        let (p4, p1, pr) = (tmp("w4"), tmp("w1"), tmp("resume"));
        for p in [&p4, &p1, &pr] {
            cleanup(p);
        }

        // Uninterrupted, 4 workers.
        let (report, bytes4) = run_to(&p4, 4);
        assert_eq!(report.jobs_total, 8);
        assert_eq!(report.jobs_run, 8);
        assert_eq!(report.jobs_skipped, 0);
        assert_eq!(report.jobs_pruned, 0);
        assert_eq!(bytes4.lines().count(), 8);

        // Campaign-global cache: the bound pre-pass plus all 8 jobs request
        // the full library, but only the first evaluates it — everything
        // later is cross-job hits.
        let lib_len = crate::approx::library().len();
        assert_eq!(report.stats.served, (8 + 1) * lib_len);
        assert!(report.stats.evaluated <= lib_len, "{:?}", report.stats);
        assert!(report.stats.cache_hits > 0, "{:?}", report.stats);
        assert!(report.stats.hit_rate() > 0.5, "{:?}", report.stats);

        // Same grid, 1 worker: byte-identical store.
        let (_, bytes1) = run_to(&p1, 1);
        assert_eq!(bytes4, bytes1, "store depends on worker interleaving");

        // Kill after 5 jobs (truncate), then resume: identical store again.
        let prefix: String =
            bytes4.lines().take(5).map(|l| format!("{l}\n")).collect();
        std::fs::write(&pr, prefix).unwrap();
        let (resumed, bytes_r) = run_to(&pr, 3);
        assert_eq!(resumed.jobs_skipped, 5);
        assert_eq!(resumed.jobs_run, 3);
        assert_eq!(bytes_r, bytes4, "resume diverged from uninterrupted run");

        // The archive reads the store back: 8 points, a nonempty front,
        // and aggregates grouped by the grid's 2 nodes / 2 models. The
        // incremental archive (checkpointed beside the store during the
        // run) must agree with a full recompute.
        let store = ResultStore::open(&p4).unwrap();
        let arch = CampaignArchive::from_rows(store.rows()).unwrap();
        assert_eq!(arch.points.len(), 8);
        assert!(!arch.front.is_empty());
        assert_eq!(arch.aggregate_table(GroupBy::Node).n_rows(), 2);
        assert_eq!(arch.aggregate_table(GroupBy::Model).n_rows(), 2);
        let restored = CampaignArchive::load_or_rebuild(
            store.rows(),
            CarbonAxis::Embodied,
            &CampaignArchive::checkpoint_path(&p4),
        )
        .unwrap();
        assert_eq!(restored.front, arch.front, "checkpointed archive diverged");

        for p in [&p4, &p1, &pr] {
            cleanup(p);
        }
    }

    #[test]
    fn rerun_of_complete_campaign_is_a_noop() {
        let p = tmp("noop");
        cleanup(&p);
        let (_, bytes) = run_to(&p, 2);
        let (report, bytes_again) = run_to(&p, 2);
        assert_eq!(report.jobs_run, 0);
        assert_eq!(report.jobs_skipped, 8);
        assert_eq!(report.stats.served, 0);
        assert_eq!(bytes, bytes_again);
        cleanup(&p);
    }

    #[test]
    fn unreachable_fps_floors_are_pruned_deterministically() {
        // Half the grid demands an absurd FPS floor; the bound proves those
        // jobs can never produce a feasible design, so they are pruned —
        // identically on fresh and resumed runs.
        let (pf, pr) = (tmp("prune-fresh"), tmp("prune-resume"));
        for p in [&pf, &pr] {
            cleanup(p);
        }
        let mut spec = quick_spec();
        spec.fps_floors = vec![None, Some(1e9)];

        let (report, bytes) = run_spec_to(&spec, &pf, 4);
        assert_eq!(report.jobs_total, 16);
        assert_eq!(report.jobs_pruned, 8, "{}", report.line());
        assert_eq!(report.jobs_run, 8);
        assert_eq!(bytes.lines().count(), 8);
        // Only the unconstrained jobs committed rows.
        for line in bytes.lines() {
            assert!(line.contains("\"fps_floor\":null"), "{line}");
        }

        // Resume from a 3-row prefix: pruned set and bytes unchanged.
        let prefix: String = bytes.lines().take(3).map(|l| format!("{l}\n")).collect();
        std::fs::write(&pr, prefix).unwrap();
        let (resumed, bytes_r) = run_spec_to(&spec, &pr, 2);
        assert_eq!(resumed.jobs_skipped, 3);
        assert_eq!(resumed.jobs_run, 5);
        assert_eq!(resumed.jobs_pruned, 8);
        assert_eq!(bytes_r, bytes, "pruned resume diverged");

        // With pruning disabled the floored jobs run (and report
        // infeasible rows) instead of being skipped.
        let pn = tmp("prune-off");
        cleanup(&pn);
        let mut spec_off = spec.clone();
        spec_off.prune = false;
        let (off, bytes_off) = run_spec_to(&spec_off, &pn, 4);
        assert_eq!(off.jobs_pruned, 0);
        assert_eq!(off.jobs_run, 16);
        assert_eq!(bytes_off.lines().count(), 16);

        for p in [&pf, &pr, &pn] {
            cleanup(p);
        }
    }

    #[test]
    fn tracing_never_changes_store_bytes_and_attributes_spans_to_jobs() {
        // Serialize against other tests that install the global sink; the
        // assertions below stay robust to spans leaking in from OTHER
        // campaign tests running concurrently in this process (the sink is
        // process-global) by matching on this spec's job keys.
        let _guard = crate::obs::test_sink_guard();
        let (pu, pt) = (tmp("untraced"), tmp("traced"));
        for p in [&pu, &pt] {
            cleanup(p);
        }
        let mut spec = quick_spec();
        spec.models.truncate(1);
        spec.deltas.truncate(1); // 2 jobs: vgg16 on 45nm and 7nm

        let (report_u, bytes_untraced) = run_spec_to(&spec, &pu, 3);

        let trace = pt.with_extension("trace.jsonl");
        crate::obs::install(&trace, &pt, None).unwrap();
        let (report_t, bytes_traced) = run_spec_to(&spec, &pt, 3);
        let summary = crate::obs::uninstall().unwrap();

        // The determinism contract: tracing must be invisible in the
        // store, the front checkpoint, and the deterministic report.
        assert_eq!(bytes_traced, bytes_untraced, "tracing perturbed the store bytes");
        let front_u = std::fs::read(CampaignArchive::checkpoint_path(&pu)).unwrap();
        let front_t = std::fs::read(CampaignArchive::checkpoint_path(&pt)).unwrap();
        assert_eq!(front_u, front_t, "tracing perturbed the front checkpoint");
        assert_eq!(
            report_t.deterministic_json().dumps(),
            report_u.deterministic_json().dumps()
        );

        // The always-on status snapshot landed beside the store, closed
        // out as "done", and agrees with the report's counters.
        let status = crate::util::Json::parse(
            &std::fs::read_to_string(crate::obs::status::status_path(&pt)).unwrap(),
        )
        .unwrap();
        assert_eq!(status.get("state").unwrap().as_str().unwrap(), "done");
        assert_eq!(
            status.get("jobs_done").unwrap().as_usize().unwrap(),
            report_t.jobs_run
        );
        assert!(status.get("front_size").unwrap().as_usize().unwrap() > 0);

        // The sidecar validates and attributes spans: every job key gets a
        // `job.eval` span, and GA runs nest under it even though workers
        // are ThreadPoolExecutor threads.
        let r = crate::obs::TraceReport::load(&trace).unwrap();
        assert_eq!(summary.lines as usize, r.lines);
        for job in spec.jobs() {
            let key = job.key();
            assert!(
                r.spans
                    .iter()
                    .any(|s| s.name == "job.eval" && s.job.as_deref() == Some(key.as_str())),
                "no job.eval span attributed to {key}"
            );
            assert!(
                r.spans.iter().any(|s| s.name == "ga.run"
                    && s.parent.as_deref() == Some("job.eval")
                    && s.job.as_deref() == Some(key.as_str())),
                "no ga.run span nested under job.eval for {key}"
            );
        }
        assert!(r.job_span_coverage() > 0.0);
        assert!(r.metrics_lines >= 1, "uninstall writes the final metrics snapshot");

        let _ = std::fs::remove_file(&trace);
        for p in [&pu, &pt] {
            cleanup(p);
        }
    }

    #[test]
    fn mapcache_sidecar_never_changes_bytes_and_warm_starts_reruns() {
        // Serialize against other obs tests: the corrupt-sidecar leg emits
        // a `mapcache.rebuild` warn event through the process-global sink.
        let _guard = crate::obs::test_sink_guard();
        let (pa, pb, pc) = (tmp("mc-fresh"), tmp("mc-warm"), tmp("mc-corrupt"));
        for p in [&pa, &pb, &pc] {
            cleanup(p);
        }
        let mut spec = quick_spec();
        spec.models.truncate(1);
        spec.deltas.truncate(1); // 2 jobs: vgg16 on 45nm and 7nm

        // A fresh run leaves a loadable sidecar beside the store and
        // attributes no hits to persistence (nothing was preloaded).
        let (report_a, bytes_a) = run_spec_to(&spec, &pa, 2);
        let side_a = mapcache::mapcache_path(&pa);
        assert!(side_a.exists(), "campaign did not write its mapcache sidecar");
        assert_eq!(report_a.mapping.persisted_hits, 0);
        assert_eq!(report_a.mapping.preloaded, 0);

        // Seed a second store's sidecar from the first run: the warm run
        // must be byte-identical in the store, the front checkpoint, and
        // the deterministic report — and the mapper searches it skipped
        // must show up as persisted hits.
        std::fs::copy(&side_a, mapcache::mapcache_path(&pb)).unwrap();
        let (report_b, bytes_b) = run_spec_to(&spec, &pb, 2);
        assert_eq!(bytes_b, bytes_a, "warm-started store diverged");
        assert_eq!(
            std::fs::read(CampaignArchive::checkpoint_path(&pb)).unwrap(),
            std::fs::read(CampaignArchive::checkpoint_path(&pa)).unwrap(),
            "warm-started front checkpoint diverged"
        );
        assert_eq!(
            report_b.deterministic_json().dumps(),
            report_a.deterministic_json().dumps()
        );
        assert!(report_b.mapping.preloaded > 0, "{:?}", report_b.mapping);
        assert!(report_b.mapping.persisted_hits > 0, "{:?}", report_b.mapping);
        assert!(report_b.line().contains("persisted"), "{}", report_b.line());

        // A corrupt sidecar is quietly dropped: bytes identical to the
        // fresh run, zero persisted attribution, and the run replaces the
        // garbage with a loadable sidecar.
        std::fs::write(mapcache::mapcache_path(&pc), "}{ not a sidecar").unwrap();
        let (report_c, bytes_c) = run_spec_to(&spec, &pc, 2);
        assert_eq!(bytes_c, bytes_a, "corrupt sidecar leaked into the store");
        assert_eq!(report_c.mapping.persisted_hits, 0);
        let reloaded = crate::dataflow::MappingCache::new();
        assert!(
            mapcache::load_into(&mapcache::mapcache_path(&pc), &reloaded) > 0,
            "run did not rebuild the corrupt sidecar"
        );

        for p in [&pa, &pb, &pc] {
            cleanup(p);
        }
    }

    #[test]
    fn simultaneous_sidecar_corruption_respects_each_policy() {
        // All three sidecars damaged before one resume (DESIGN.md §11):
        // the front checkpoint is the only source-of-truth sidecar, so it
        // alone is loud; the mapcache is a performance hint (quiet
        // rebuild) and the status snapshot is pure observability
        // (silently overwritten). Each policy must hold independently of
        // the other two being damaged in the same resume.
        let _guard = crate::obs::test_sink_guard();
        use crate::obs::Merge as _;
        let (pf, pr) = (tmp("corrupt-fresh"), tmp("corrupt-resume"));
        for p in [&pf, &pr] {
            cleanup(p);
        }
        let mut spec = quick_spec();
        spec.models.truncate(1);
        spec.deltas.truncate(1); // 2 jobs

        let (_, bytes) = run_spec_to(&spec, &pf, 2);

        // A 1-row prefix of the store, with every sidecar corrupted at once.
        let prefix: String = bytes.lines().take(1).map(|l| format!("{l}\n")).collect();
        std::fs::write(&pr, prefix).unwrap();
        let front = CampaignArchive::checkpoint_path(&pr);
        std::fs::write(&front, "}{ torn checkpoint").unwrap();
        std::fs::write(mapcache::mapcache_path(&pr), "}{ torn mapcache").unwrap();
        std::fs::write(crate::obs::status::status_path(&pr), "}{ torn status").unwrap();

        // The resume must refuse loudly: checkpoints are written
        // atomically, so a garbage document means external damage.
        let svc = EvalService::start(SurrogateBackend::default());
        let err = {
            let mut store = ResultStore::open(&pr).unwrap();
            run_campaign(&spec, 2, &mut store, &svc).unwrap_err()
        };
        assert!(format!("{err:#}").contains("corrupt"), "{err:#}");
        // The loud reject left the store rows untouched.
        assert_eq!(std::fs::read_to_string(&pr).unwrap().lines().count(), 1);

        // Apply the error message's remedy — delete the front sidecar —
        // and resume with the other two still damaged: the mapcache is
        // quietly rebuilt (one `mapcache.rebuild` warn), the status
        // snapshot is simply overwritten, and the final bytes match the
        // uninterrupted run.
        std::fs::remove_file(&front).unwrap();
        let before = crate::obs::metrics().snapshot();
        let mut store = ResultStore::open(&pr).unwrap();
        let report = run_campaign(&spec, 2, &mut store, &svc).unwrap();
        svc.shutdown();
        assert_eq!(report.jobs_skipped, 1);
        assert_eq!(report.jobs_run, 1);
        assert_eq!(
            std::fs::read_to_string(&pr).unwrap(),
            bytes,
            "recovery diverged from the uninterrupted run"
        );
        let delta = crate::obs::metrics().snapshot().diff(&before);
        assert!(
            delta.counter("mapcache.rebuild") >= 1,
            "quiet mapcache rebuild was not logged"
        );
        assert_eq!(
            std::fs::read(CampaignArchive::checkpoint_path(&pr)).unwrap(),
            std::fs::read(CampaignArchive::checkpoint_path(&pf)).unwrap(),
            "front checkpoint was not rebuilt"
        );
        let status = crate::util::Json::parse(
            &std::fs::read_to_string(crate::obs::status::status_path(&pr)).unwrap(),
        )
        .unwrap();
        assert_eq!(status.get("state").unwrap().as_str().unwrap(), "done");

        for p in [&pf, &pr] {
            cleanup(p);
        }
    }

    #[test]
    fn panicking_job_is_quarantined_and_retryable() {
        // A poison job must not kill the campaign: the panic is caught,
        // the job commits as a `failed` row counted in jobs_failed, and
        // purge_failed() + resume replaces it with the real row.
        let _guard = crate::obs::test_sink_guard();
        let _faults = fault::test_guard();
        let (pc, pq) = (tmp("quar-clean"), tmp("quar"));
        for p in [&pc, &pq] {
            cleanup(p);
        }
        let mut spec = quick_spec();
        spec.models.truncate(1);
        spec.deltas.truncate(1); // 2 jobs

        // Fault-free reference (1 worker, so evaluation order is the
        // schedule order and nth:1 below targets a fixed job).
        let (_, clean_bytes) = run_spec_to(&spec, &pc, 1);

        fault::arm(vec![fault::FaultRule {
            site: "job.eval".to_string(),
            nth: 1,
            kind: fault::FaultKind::Panic,
        }]);
        let (report, bytes) = run_spec_to(&spec, &pq, 1);
        fault::disarm();
        assert_eq!(report.jobs_failed, 1, "{}", report.line());
        assert_eq!(report.jobs_run, 1, "{}", report.line());
        assert!(report.line().contains("1 failed"), "{}", report.line());
        let failed_lines: Vec<&str> = bytes
            .lines()
            .filter(|l| {
                crate::util::Json::parse(l).is_ok_and(|row| store::row_is_failed(&row))
            })
            .collect();
        assert_eq!(failed_lines.len(), 1, "{bytes}");
        assert!(failed_lines[0].contains("injected panic"), "{}", failed_lines[0]);

        // Failed rows never enter the Pareto archive.
        let arch =
            CampaignArchive::from_rows(ResultStore::open(&pq).unwrap().rows()).unwrap();
        assert_eq!(arch.points.len(), 1);

        // Retry: purge the quarantined row, resume fault-free. The store
        // is no longer a prefix of the canonical sequence, so whole-file
        // byte identity is not the contract here — line-set identity is:
        // rows are pure functions of their job.
        let mut store = ResultStore::open(&pq).unwrap();
        assert_eq!(store.purge_failed().unwrap(), 1);
        drop(store);
        let (retried, retried_bytes) = run_spec_to(&spec, &pq, 1);
        assert_eq!(retried.jobs_failed, 0);
        assert_eq!(retried.jobs_run, 1);
        assert_eq!(retried.jobs_skipped, 1);
        let mut got: Vec<&str> = retried_bytes.lines().collect();
        let mut want: Vec<&str> = clean_bytes.lines().collect();
        got.sort_unstable();
        want.sort_unstable();
        assert_eq!(got, want, "retried rows diverged from the fault-free run");

        for p in [&pc, &pq] {
            cleanup(p);
        }
    }

    #[test]
    fn lifetime_objective_changes_keys_and_reports_lifetime_carbon() {
        let p = tmp("lifetime");
        cleanup(&p);
        let mut spec = quick_spec();
        spec.models.truncate(1);
        spec.deltas.truncate(1);
        spec.objective = CampaignObjective::LifetimeCdp;
        let (report, bytes) = run_spec_to(&spec, &p, 2);
        assert_eq!(report.jobs_run, 2);
        for line in bytes.lines() {
            assert!(line.contains("obj=lifetime-cdp"), "{line}");
            assert!(line.contains("\"objective\":\"lifetime-cdp\""), "{line}");
        }
        let store = ResultStore::open(&p).unwrap();
        for row in store.rows() {
            let carbon = row.get("carbon_g").unwrap().as_f64().unwrap();
            let lifetime = row.get("lifetime_gco2").unwrap().as_f64().unwrap();
            let op = row.get("op_gco2").unwrap().as_f64().unwrap();
            assert!(op > 0.0);
            assert!((lifetime - (carbon + op)).abs() < 1e-9);
            let obj = row.get("obj_value").unwrap().as_f64().unwrap();
            let delay = row.get("delay_s").unwrap().as_f64().unwrap();
            assert!((obj - lifetime * delay).abs() < 1e-9);
        }
        cleanup(&p);
    }

    #[test]
    fn adaptive_campaign_is_deterministic_and_self_describing() {
        // The adaptive-sampler determinism contract, in-process: for a
        // fixed spec, the store bytes — header line included — are
        // identical whatever the worker count and wherever a resume cuts
        // in, and a complete store reruns as a no-op.
        let (p4, p1, pr) = (tmp("ad-w4"), tmp("ad-w1"), tmp("ad-resume"));
        for p in [&p4, &p1, &pr] {
            cleanup(p);
        }
        let mut spec = quick_spec();
        // batch 3 over 8 jobs: the last planning round is ragged.
        spec.sampler = SamplerMode::Adaptive { batch: 3 };

        let (report, bytes4) = run_spec_to(&spec, &p4, 4);
        // Self-describing store: the sampler header is the first line and
        // is not a data row.
        let header = bytes4.lines().next().unwrap();
        assert!(header.contains("\"schema\":\"carbon3d-store/1\""), "{header}");
        assert!(header.contains("\"sampler\":\"adaptive\""), "{header}");
        assert!(header.contains("\"batch\":3"), "{header}");
        assert_eq!(bytes4.lines().count(), report.jobs_run + 1);
        // Planner bookkeeping: every grid job is run or pruned, and
        // surrogate prunes are a subset of all prunes.
        assert_eq!(report.jobs_run + report.jobs_pruned, report.jobs_total);
        assert_eq!(report.jobs_skipped, 0);
        assert!(report.jobs_pruned_surrogate <= report.jobs_pruned, "{}", report.line());

        // Worker count is invisible in the bytes: the planner decides at
        // batch boundaries, workers only evaluate.
        let (_, bytes1) = run_spec_to(&spec, &p1, 1);
        assert_eq!(bytes4, bytes1, "adaptive store depends on worker interleaving");

        // Kill after the header + 2 rows, resume: byte-identical replay.
        let cut = 1 + 2.min(report.jobs_run);
        let prefix: String =
            bytes4.lines().take(cut).map(|l| format!("{l}\n")).collect();
        std::fs::write(&pr, prefix).unwrap();
        let (resumed, bytes_r) = run_spec_to(&spec, &pr, 3);
        assert_eq!(resumed.jobs_skipped, cut - 1);
        assert_eq!(resumed.jobs_run, report.jobs_run - (cut - 1));
        assert_eq!(bytes_r, bytes4, "adaptive resume diverged from the fresh run");

        // Rerun of the complete store: no new rows, bytes untouched, and
        // the replay re-derives the same prune set.
        let (noop, bytes_again) = run_spec_to(&spec, &p4, 2);
        assert_eq!(noop.jobs_run, 0);
        assert_eq!(noop.jobs_skipped, report.jobs_run);
        assert_eq!(noop.jobs_pruned, report.jobs_pruned);
        assert_eq!(bytes_again, bytes4);

        for p in [&p4, &p1, &pr] {
            cleanup(p);
        }
    }

    #[test]
    fn adaptive_sampler_preserves_family_bests_against_exhaustive() {
        // A single-family δ ladder — the shape the surrogate is built for:
        // one workload/node, eight δ values, smooth objective-vs-δ
        // structure. The adaptive run may prune part of the tail; what it
        // must never do is lose a family's best objective value, and every
        // row it does commit must be byte-identical to the exhaustive
        // run's row for the same job (rows are pure functions of the job).
        let (pe, pa) = (tmp("ladder-ex"), tmp("ladder-ad"));
        for p in [&pe, &pa] {
            cleanup(p);
        }
        let mut spec = CampaignSpec::new(
            vec!["vgg16".to_string()],
            vec![TechNode::N7],
            vec![0.5, 1.0, 1.5, 2.0, 2.5, 3.0, 3.5, 4.0],
        );
        spec.ga = GaParams {
            population: 8,
            generations: 4,
            patience: 2,
            elites: 1,
            ..Default::default()
        };
        let (re, be) = run_spec_to(&spec, &pe, 4);
        assert_eq!(re.jobs_pruned_surrogate, 0, "exhaustive runs never consult the surrogate");

        let mut adaptive = spec.clone();
        adaptive.sampler = SamplerMode::Adaptive { batch: 2 };
        let (ra, ba) = run_spec_to(&adaptive, &pa, 4);

        // The sampler can only save work, never add it.
        assert!(ra.jobs_run <= re.jobs_run, "{} > {}", ra.jobs_run, re.jobs_run);
        assert_eq!(ra.jobs_run + ra.jobs_pruned, ra.jobs_total);

        // Every committed adaptive row is verbatim one of the exhaustive
        // run's rows (the exhaustive store is headerless; the adaptive
        // store's first line is its header).
        if re.jobs_pruned == 0 {
            for line in ba.lines().skip(1) {
                assert!(
                    be.lines().any(|l| l == line),
                    "adaptive row not in the exhaustive store: {line}"
                );
            }
        }

        // Family-best preservation: the best committed objective value is
        // bit-identical between the two stores. (The analytic incumbent
        // rule can only prune a family's argmin when the incumbent already
        // equals it; the surrogate margin guards the learned rule — this
        // assertion is the soundness contract of ISSUE 9.)
        let best = |bytes: &str| {
            bytes
                .lines()
                .filter_map(|l| {
                    crate::util::Json::parse(l)
                        .ok()?
                        .get("obj_value")
                        .ok()?
                        .as_f64()
                        .ok()
                })
                .fold(f64::INFINITY, f64::min)
        };
        let (b_ex, b_ad) = (best(&be), best(&ba));
        assert!(b_ex.is_finite() && b_ad.is_finite());
        assert_eq!(
            b_ex.to_bits(),
            b_ad.to_bits(),
            "adaptive pruning lost the family best: exhaustive {b_ex}, adaptive {b_ad}"
        );

        // --explain-prune replays the planner's end-of-run state: every
        // committed row reports "committed", every missing grid job gets a
        // rule or "runnable".
        let svc = EvalService::start(SurrogateBackend::default());
        let store = ResultStore::open(&pa).unwrap();
        let explained = explain_prune(&adaptive, &store, &svc).unwrap();
        svc.shutdown();
        assert!(explained.contains("8 grid jobs"), "{explained}");
        let committed =
            explained.lines().filter(|l| l.ends_with("| committed")).count();
        assert_eq!(committed, ra.jobs_run, "{explained}");
        for line in explained.lines().skip(1) {
            assert!(
                line.ends_with("| committed")
                    || line.contains("| pruned: ")
                    || line.ends_with("| runnable"),
                "{line}"
            );
        }

        for p in [&pe, &pa] {
            cleanup(p);
        }
    }

    #[test]
    fn sampler_mode_mixes_are_refused_loudly() {
        let (pe, pa, ps) = (tmp("mix-ex"), tmp("mix-ad"), tmp("mix-shard"));
        cleanup(&pe);
        cleanup(&pa);
        let mut spec = quick_spec();
        spec.models.truncate(1);
        spec.deltas.truncate(1); // 2 jobs
        let mut adaptive = spec.clone();
        adaptive.sampler = SamplerMode::Adaptive { batch: 2 };

        // An exhaustive (headerless) store with rows cannot be resumed
        // adaptively.
        let (_, bytes_e) = run_spec_to(&spec, &pe, 2);
        assert!(!bytes_e.lines().next().unwrap().contains("\"schema\""));
        let svc = EvalService::start(SurrogateBackend::default());
        {
            let mut store = ResultStore::open(&pe).unwrap();
            let err = run_campaign(&adaptive, 2, &mut store, &svc).unwrap_err();
            assert!(format!("{err:#}").contains("--sampler adaptive"), "{err:#}");
        }

        // An adaptive store refuses exhaustive resume and a different
        // batch size (the batch is part of the byte contract).
        let (_, _) = run_spec_to(&adaptive, &pa, 2);
        {
            let mut store = ResultStore::open(&pa).unwrap();
            let err = run_campaign(&spec, 2, &mut store, &svc).unwrap_err();
            assert!(format!("{err:#}").contains("exhaustive"), "{err:#}");
        }
        {
            let mut rebatched = adaptive.clone();
            rebatched.sampler = SamplerMode::Adaptive { batch: 3 };
            let mut store = ResultStore::open(&pa).unwrap();
            let err = run_campaign(&rebatched, 2, &mut store, &svc).unwrap_err();
            assert!(format!("{err:#}").contains("batch"), "{err:#}");
        }
        svc.shutdown();

        // `campaign merge` refuses shard stores written by an adaptive
        // sampler: copying the adaptive store into a shard slot must fail.
        let shard_path =
            shard_store_path(&ps, ShardId::parse("0/1").unwrap());
        let _ = std::fs::remove_file(&shard_path);
        std::fs::copy(&pa, &shard_path).unwrap();
        let err = MergeExecutor::from_shard_stores(&ps, 1).unwrap_err();
        assert!(
            format!("{err:#}").contains("only accepts exhaustive shard stores"),
            "{err:#}"
        );
        let _ = std::fs::remove_file(&shard_path);

        cleanup(&pe);
        cleanup(&pa);
    }
}
