//! Bench FIG2: regenerates the paper's Figure 2 rows (normalized delay +
//! embodied carbon per node/model/δ) and times the pipeline.
//!
//! Run: `cargo bench --bench fig2 [-- --full]`
//! Default uses a reduced GA budget per cell so the whole grid stays fast;
//! `--full` uses the paper-scale budget (same results shape).

use carbon3d::approx::library;
use carbon3d::area::node::ALL_NODES;
use carbon3d::coordinator::fig2::{run_fig2, FIG2_MODELS};
use carbon3d::ga::GaParams;
use carbon3d::obs::bench::{bench, time_once};

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let params = if full {
        GaParams::default()
    } else {
        GaParams { population: 32, generations: 20, patience: 8, ..Default::default() }
    };
    let lib = library();

    // One full-grid run: the figure itself.
    let (r, secs) = time_once(|| run_fig2(&lib, &FIG2_MODELS, params));
    println!("== FIG2 ({} cells in {:.2}s) ==", r.cells.len(), secs);
    println!("{}", r.render());
    for &node in &ALL_NODES {
        println!("{}: max carbon cut {:.1}%", node.name(), r.max_carbon_cut_pct(node));
    }

    // Timing: single (node, model) cell — the unit of GA work.
    let res = bench("fig2: one GA cell (vgg16@14nm, δ=3%)", 1, 5, || {
        carbon3d::coordinator::ga_appx_min_carbon(
            &carbon3d::dataflow::workloads::workload("vgg16").unwrap(),
            carbon3d::TechNode::N14,
            &lib,
            3.0,
            1.0, // fps floor far below reach: unconstrained-ish
            params,
            None,
        )
    });
    println!("{}", res.line());
}
