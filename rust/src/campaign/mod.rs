//! Design-space-exploration **campaign engine**: run an entire scenario
//! grid — {workload} x {TechNode} x {Integration} x {δ} x {FPS floor} —
//! as a job queue, instead of one GA invocation at a time.
//!
//! The engine is three explicit layers (DESIGN.md §6):
//! - [`source`] — **JobSource**: deterministic grid enumeration, per-job
//!   optimistic bounds ([`source::JobBound`]), and the schedule order
//!   (ascending bound; commits follow it). Pure function of the spec and
//!   the rows already committed — identical for any worker count, shard
//!   count, or resume boundary.
//! - [`exec`] — **Executor**: who evaluates jobs. The in-process
//!   [`exec::ThreadPoolExecutor`], the multi-process
//!   [`exec::sharded::ShardedExecutor`] (file-based [`lease`] claims, one
//!   store per shard), and [`exec::sharded::MergeExecutor`] (folds shard
//!   stores into the canonical store). All executors in a process share
//!   ONE [`crate::runtime::EvalService`], so accuracy evaluations are
//!   cached campaign-globally.
//! - [`commit`] — **CommitPipeline**: reorder buffer, the writer-
//!   authoritative prune decision ([`source::prune_reason`]; `--no-prune`
//!   for exhaustive grids), the JSONL append, and the incremental Pareto
//!   archive with its atomically-written sidecar checkpoint.
//!
//! Around them: [`spec`] (grid + [`CampaignObjective`] + key-derived
//! per-job seeds), [`store`] (append-only JSONL with checkpoint/resume;
//! torn final lines dropped, anything else loud), [`pareto`] +
//! [`checkpoint`] + [`front`] (archive core, sidecar I/O, presentation and
//! cross-campaign front merging), and [`mapcache`] (the persistent
//! mapping-cache sidecar: a pure performance hint that must never change
//! store bytes).
//!
//! Invariant the tests pin down: for a fixed campaign seed, the final
//! store bytes are identical whether the campaign ran uninterrupted with
//! any number of workers, was killed and resumed, or was sharded across N
//! processes and merged.

pub mod checkpoint;
pub mod commit;
pub mod exec;
pub mod front;
pub mod lease;
pub mod mapcache;
pub mod pareto;
pub mod source;
pub mod spec;
pub mod store;

pub use commit::{CommitPipeline, CommitTotals, FrontCell, JobOutcome};
pub use mapcache::{mapcache_path, MapCachePersist};
pub use exec::sharded::{shard_store_path, MergeExecutor, ShardId, ShardedExecutor};
pub use exec::{
    run_campaign, run_campaign_with, start_service, CampaignReport, Executor,
    SurrogateBackend, ThreadPoolExecutor,
};
pub use front::{merge_fronts, merge_store_fronts, MergedFront, MergedPoint};
pub use lease::{Claim, LeaseDir};
pub use pareto::{ArchivePoint, CampaignArchive, CarbonAxis, GroupBy};
pub use source::{job_bound, prune_reason, shard_owner, JobBound, JobCtx, JobSource};
pub use spec::{CampaignObjective, CampaignSpec, JobSpec};
pub use store::ResultStore;

#[cfg(test)]
mod tests {
    use std::path::PathBuf;

    use super::*;
    use crate::area::TechNode;
    use crate::ga::GaParams;
    use crate::runtime::EvalService;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!(
            "carbon3d-campaign-{}-{name}.jsonl",
            std::process::id()
        ))
    }

    fn cleanup(path: &std::path::Path) {
        let _ = std::fs::remove_file(path);
        let _ = std::fs::remove_file(CampaignArchive::checkpoint_path(path));
        let _ = std::fs::remove_file(crate::obs::status::status_path(path));
        let _ = std::fs::remove_file(mapcache::mapcache_path(path));
    }

    /// 2 models x 2 nodes x 2 deltas = 8 jobs, tiny GA budget.
    fn quick_spec() -> CampaignSpec {
        let mut s = CampaignSpec::new(
            vec!["vgg16".to_string(), "resnet50".to_string()],
            vec![TechNode::N45, TechNode::N7],
            vec![1.0, 3.0],
        );
        s.ga = GaParams { population: 8, generations: 4, patience: 2, elites: 1, ..Default::default() };
        s
    }

    fn run_spec_to(
        spec: &CampaignSpec,
        path: &std::path::Path,
        workers: usize,
    ) -> (CampaignReport, String) {
        let mut store = ResultStore::open(path).unwrap();
        // Surrogate backend: deterministic and artifact-free.
        let svc = EvalService::start(SurrogateBackend::default());
        let report = run_campaign(spec, workers, &mut store, &svc).unwrap();
        svc.shutdown();
        (report, std::fs::read_to_string(path).unwrap())
    }

    fn run_to(path: &std::path::Path, workers: usize) -> (CampaignReport, String) {
        run_spec_to(&quick_spec(), path, workers)
    }

    #[test]
    fn campaign_resume_and_worker_count_are_invisible_in_the_store() {
        let (p4, p1, pr) = (tmp("w4"), tmp("w1"), tmp("resume"));
        for p in [&p4, &p1, &pr] {
            cleanup(p);
        }

        // Uninterrupted, 4 workers.
        let (report, bytes4) = run_to(&p4, 4);
        assert_eq!(report.jobs_total, 8);
        assert_eq!(report.jobs_run, 8);
        assert_eq!(report.jobs_skipped, 0);
        assert_eq!(report.jobs_pruned, 0);
        assert_eq!(bytes4.lines().count(), 8);

        // Campaign-global cache: the bound pre-pass plus all 8 jobs request
        // the full library, but only the first evaluates it — everything
        // later is cross-job hits.
        let lib_len = crate::approx::library().len();
        assert_eq!(report.stats.served, (8 + 1) * lib_len);
        assert!(report.stats.evaluated <= lib_len, "{:?}", report.stats);
        assert!(report.stats.cache_hits > 0, "{:?}", report.stats);
        assert!(report.stats.hit_rate() > 0.5, "{:?}", report.stats);

        // Same grid, 1 worker: byte-identical store.
        let (_, bytes1) = run_to(&p1, 1);
        assert_eq!(bytes4, bytes1, "store depends on worker interleaving");

        // Kill after 5 jobs (truncate), then resume: identical store again.
        let prefix: String =
            bytes4.lines().take(5).map(|l| format!("{l}\n")).collect();
        std::fs::write(&pr, prefix).unwrap();
        let (resumed, bytes_r) = run_to(&pr, 3);
        assert_eq!(resumed.jobs_skipped, 5);
        assert_eq!(resumed.jobs_run, 3);
        assert_eq!(bytes_r, bytes4, "resume diverged from uninterrupted run");

        // The archive reads the store back: 8 points, a nonempty front,
        // and aggregates grouped by the grid's 2 nodes / 2 models. The
        // incremental archive (checkpointed beside the store during the
        // run) must agree with a full recompute.
        let store = ResultStore::open(&p4).unwrap();
        let arch = CampaignArchive::from_rows(store.rows()).unwrap();
        assert_eq!(arch.points.len(), 8);
        assert!(!arch.front.is_empty());
        assert_eq!(arch.aggregate_table(GroupBy::Node).n_rows(), 2);
        assert_eq!(arch.aggregate_table(GroupBy::Model).n_rows(), 2);
        let restored = CampaignArchive::load_or_rebuild(
            store.rows(),
            CarbonAxis::Embodied,
            &CampaignArchive::checkpoint_path(&p4),
        )
        .unwrap();
        assert_eq!(restored.front, arch.front, "checkpointed archive diverged");

        for p in [&p4, &p1, &pr] {
            cleanup(p);
        }
    }

    #[test]
    fn rerun_of_complete_campaign_is_a_noop() {
        let p = tmp("noop");
        cleanup(&p);
        let (_, bytes) = run_to(&p, 2);
        let (report, bytes_again) = run_to(&p, 2);
        assert_eq!(report.jobs_run, 0);
        assert_eq!(report.jobs_skipped, 8);
        assert_eq!(report.stats.served, 0);
        assert_eq!(bytes, bytes_again);
        cleanup(&p);
    }

    #[test]
    fn unreachable_fps_floors_are_pruned_deterministically() {
        // Half the grid demands an absurd FPS floor; the bound proves those
        // jobs can never produce a feasible design, so they are pruned —
        // identically on fresh and resumed runs.
        let (pf, pr) = (tmp("prune-fresh"), tmp("prune-resume"));
        for p in [&pf, &pr] {
            cleanup(p);
        }
        let mut spec = quick_spec();
        spec.fps_floors = vec![None, Some(1e9)];

        let (report, bytes) = run_spec_to(&spec, &pf, 4);
        assert_eq!(report.jobs_total, 16);
        assert_eq!(report.jobs_pruned, 8, "{}", report.line());
        assert_eq!(report.jobs_run, 8);
        assert_eq!(bytes.lines().count(), 8);
        // Only the unconstrained jobs committed rows.
        for line in bytes.lines() {
            assert!(line.contains("\"fps_floor\":null"), "{line}");
        }

        // Resume from a 3-row prefix: pruned set and bytes unchanged.
        let prefix: String = bytes.lines().take(3).map(|l| format!("{l}\n")).collect();
        std::fs::write(&pr, prefix).unwrap();
        let (resumed, bytes_r) = run_spec_to(&spec, &pr, 2);
        assert_eq!(resumed.jobs_skipped, 3);
        assert_eq!(resumed.jobs_run, 5);
        assert_eq!(resumed.jobs_pruned, 8);
        assert_eq!(bytes_r, bytes, "pruned resume diverged");

        // With pruning disabled the floored jobs run (and report
        // infeasible rows) instead of being skipped.
        let pn = tmp("prune-off");
        cleanup(&pn);
        let mut spec_off = spec.clone();
        spec_off.prune = false;
        let (off, bytes_off) = run_spec_to(&spec_off, &pn, 4);
        assert_eq!(off.jobs_pruned, 0);
        assert_eq!(off.jobs_run, 16);
        assert_eq!(bytes_off.lines().count(), 16);

        for p in [&pf, &pr, &pn] {
            cleanup(p);
        }
    }

    #[test]
    fn tracing_never_changes_store_bytes_and_attributes_spans_to_jobs() {
        // Serialize against other tests that install the global sink; the
        // assertions below stay robust to spans leaking in from OTHER
        // campaign tests running concurrently in this process (the sink is
        // process-global) by matching on this spec's job keys.
        let _guard = crate::obs::test_sink_guard();
        let (pu, pt) = (tmp("untraced"), tmp("traced"));
        for p in [&pu, &pt] {
            cleanup(p);
        }
        let mut spec = quick_spec();
        spec.models.truncate(1);
        spec.deltas.truncate(1); // 2 jobs: vgg16 on 45nm and 7nm

        let (report_u, bytes_untraced) = run_spec_to(&spec, &pu, 3);

        let trace = pt.with_extension("trace.jsonl");
        crate::obs::install(&trace, &pt, None).unwrap();
        let (report_t, bytes_traced) = run_spec_to(&spec, &pt, 3);
        let summary = crate::obs::uninstall().unwrap();

        // The determinism contract: tracing must be invisible in the
        // store, the front checkpoint, and the deterministic report.
        assert_eq!(bytes_traced, bytes_untraced, "tracing perturbed the store bytes");
        let front_u = std::fs::read(CampaignArchive::checkpoint_path(&pu)).unwrap();
        let front_t = std::fs::read(CampaignArchive::checkpoint_path(&pt)).unwrap();
        assert_eq!(front_u, front_t, "tracing perturbed the front checkpoint");
        assert_eq!(
            report_t.deterministic_json().dumps(),
            report_u.deterministic_json().dumps()
        );

        // The always-on status snapshot landed beside the store, closed
        // out as "done", and agrees with the report's counters.
        let status = crate::util::Json::parse(
            &std::fs::read_to_string(crate::obs::status::status_path(&pt)).unwrap(),
        )
        .unwrap();
        assert_eq!(status.get("state").unwrap().as_str().unwrap(), "done");
        assert_eq!(
            status.get("jobs_done").unwrap().as_usize().unwrap(),
            report_t.jobs_run
        );
        assert!(status.get("front_size").unwrap().as_usize().unwrap() > 0);

        // The sidecar validates and attributes spans: every job key gets a
        // `job.eval` span, and GA runs nest under it even though workers
        // are ThreadPoolExecutor threads.
        let r = crate::obs::TraceReport::load(&trace).unwrap();
        assert_eq!(summary.lines as usize, r.lines);
        for job in spec.jobs() {
            let key = job.key();
            assert!(
                r.spans
                    .iter()
                    .any(|s| s.name == "job.eval" && s.job.as_deref() == Some(key.as_str())),
                "no job.eval span attributed to {key}"
            );
            assert!(
                r.spans.iter().any(|s| s.name == "ga.run"
                    && s.parent.as_deref() == Some("job.eval")
                    && s.job.as_deref() == Some(key.as_str())),
                "no ga.run span nested under job.eval for {key}"
            );
        }
        assert!(r.job_span_coverage() > 0.0);
        assert!(r.metrics_lines >= 1, "uninstall writes the final metrics snapshot");

        let _ = std::fs::remove_file(&trace);
        for p in [&pu, &pt] {
            cleanup(p);
        }
    }

    #[test]
    fn mapcache_sidecar_never_changes_bytes_and_warm_starts_reruns() {
        // Serialize against other obs tests: the corrupt-sidecar leg emits
        // a `mapcache.rebuild` warn event through the process-global sink.
        let _guard = crate::obs::test_sink_guard();
        let (pa, pb, pc) = (tmp("mc-fresh"), tmp("mc-warm"), tmp("mc-corrupt"));
        for p in [&pa, &pb, &pc] {
            cleanup(p);
        }
        let mut spec = quick_spec();
        spec.models.truncate(1);
        spec.deltas.truncate(1); // 2 jobs: vgg16 on 45nm and 7nm

        // A fresh run leaves a loadable sidecar beside the store and
        // attributes no hits to persistence (nothing was preloaded).
        let (report_a, bytes_a) = run_spec_to(&spec, &pa, 2);
        let side_a = mapcache::mapcache_path(&pa);
        assert!(side_a.exists(), "campaign did not write its mapcache sidecar");
        assert_eq!(report_a.mapping.persisted_hits, 0);
        assert_eq!(report_a.mapping.preloaded, 0);

        // Seed a second store's sidecar from the first run: the warm run
        // must be byte-identical in the store, the front checkpoint, and
        // the deterministic report — and the mapper searches it skipped
        // must show up as persisted hits.
        std::fs::copy(&side_a, mapcache::mapcache_path(&pb)).unwrap();
        let (report_b, bytes_b) = run_spec_to(&spec, &pb, 2);
        assert_eq!(bytes_b, bytes_a, "warm-started store diverged");
        assert_eq!(
            std::fs::read(CampaignArchive::checkpoint_path(&pb)).unwrap(),
            std::fs::read(CampaignArchive::checkpoint_path(&pa)).unwrap(),
            "warm-started front checkpoint diverged"
        );
        assert_eq!(
            report_b.deterministic_json().dumps(),
            report_a.deterministic_json().dumps()
        );
        assert!(report_b.mapping.preloaded > 0, "{:?}", report_b.mapping);
        assert!(report_b.mapping.persisted_hits > 0, "{:?}", report_b.mapping);
        assert!(report_b.line().contains("persisted"), "{}", report_b.line());

        // A corrupt sidecar is quietly dropped: bytes identical to the
        // fresh run, zero persisted attribution, and the run replaces the
        // garbage with a loadable sidecar.
        std::fs::write(mapcache::mapcache_path(&pc), "}{ not a sidecar").unwrap();
        let (report_c, bytes_c) = run_spec_to(&spec, &pc, 2);
        assert_eq!(bytes_c, bytes_a, "corrupt sidecar leaked into the store");
        assert_eq!(report_c.mapping.persisted_hits, 0);
        let reloaded = crate::dataflow::MappingCache::new();
        assert!(
            mapcache::load_into(&mapcache::mapcache_path(&pc), &reloaded) > 0,
            "run did not rebuild the corrupt sidecar"
        );

        for p in [&pa, &pb, &pc] {
            cleanup(p);
        }
    }

    #[test]
    fn lifetime_objective_changes_keys_and_reports_lifetime_carbon() {
        let p = tmp("lifetime");
        cleanup(&p);
        let mut spec = quick_spec();
        spec.models.truncate(1);
        spec.deltas.truncate(1);
        spec.objective = CampaignObjective::LifetimeCdp;
        let (report, bytes) = run_spec_to(&spec, &p, 2);
        assert_eq!(report.jobs_run, 2);
        for line in bytes.lines() {
            assert!(line.contains("obj=lifetime-cdp"), "{line}");
            assert!(line.contains("\"objective\":\"lifetime-cdp\""), "{line}");
        }
        let store = ResultStore::open(&p).unwrap();
        for row in store.rows() {
            let carbon = row.get("carbon_g").unwrap().as_f64().unwrap();
            let lifetime = row.get("lifetime_gco2").unwrap().as_f64().unwrap();
            let op = row.get("op_gco2").unwrap().as_f64().unwrap();
            assert!(op > 0.0);
            assert!((lifetime - (carbon + op)).abs() < 1e-9);
            let obj = row.get("obj_value").unwrap().as_f64().unwrap();
            let delay = row.get("delay_s").unwrap().as_f64().unwrap();
            assert!((obj - lifetime * delay).abs() < 1e-9);
        }
        cleanup(&p);
    }
}
