//! Trace sidecar reader: strict schema validation (`trace report
//! --check`) plus the per-phase breakdown and top-K-slowest-jobs tables
//! behind `carbon3d trace report`.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;
use crate::util::table::Table;
use crate::util::timer::human_time;

use super::sink::SCHEMA;

/// One closed span parsed from a sidecar line.
#[derive(Debug, Clone)]
pub struct SpanRec {
    pub name: String,
    pub parent: Option<String>,
    pub depth: usize,
    pub job: Option<String>,
    pub t_us: u64,
    pub dur_us: u64,
    pub thread: u64,
}

/// A fully parsed + validated trace sidecar.
#[derive(Debug, Clone)]
pub struct TraceReport {
    pub schema: String,
    pub store: String,
    pub shard: Option<String>,
    pub spans: Vec<SpanRec>,
    pub events: Vec<String>,
    pub heartbeats: usize,
    pub metrics_lines: usize,
    pub lines: usize,
}

fn req_num(v: &Json, key: &str) -> Result<f64> {
    v.get(key).with_context(|| format!("field {key:?}"))?.as_f64()
}

fn req_str(v: &Json, key: &str) -> Result<String> {
    Ok(v.get(key).with_context(|| format!("field {key:?}"))?.as_str()?.to_string())
}

fn opt_str(v: &Json, key: &str) -> Result<Option<String>> {
    match v.get(key).with_context(|| format!("field {key:?}"))? {
        Json::Null => Ok(None),
        Json::Str(s) => Ok(Some(s.clone())),
        other => bail!("field {key:?}: expected string or null, got {other:?}"),
    }
}

impl TraceReport {
    /// Parse and strictly validate a sidecar. Every line must be a JSON
    /// object of a known `kind` with all required fields; the first line
    /// must be a `header` carrying the expected schema version.
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading trace {}", path.display()))?;
        let mut report: Option<TraceReport> = None;
        for (i, line) in text.lines().enumerate() {
            let lineno = i + 1;
            let v = Json::parse(line)
                .with_context(|| format!("{}:{lineno}: invalid JSON", path.display()))?;
            (|| -> Result<()> {
                let kind = req_str(&v, "kind")?;
                match (kind.as_str(), &mut report) {
                    ("header", r @ None) => {
                        let schema = req_str(&v, "schema")?;
                        if schema != SCHEMA {
                            bail!("schema {schema:?} != expected {SCHEMA:?}");
                        }
                        req_num(&v, "pid")?;
                        *r = Some(TraceReport {
                            schema,
                            store: req_str(&v, "store")?,
                            shard: opt_str(&v, "shard")?,
                            spans: Vec::new(),
                            events: Vec::new(),
                            heartbeats: 0,
                            metrics_lines: 0,
                            lines: 0,
                        });
                    }
                    ("header", Some(_)) => bail!("duplicate header line"),
                    (_, None) => bail!("first line must be a header"),
                    ("span", Some(r)) => r.spans.push(SpanRec {
                        name: req_str(&v, "name")?,
                        parent: opt_str(&v, "parent")?,
                        depth: req_num(&v, "depth")? as usize,
                        job: opt_str(&v, "job")?,
                        t_us: req_num(&v, "t_us")? as u64,
                        dur_us: req_num(&v, "dur_us")? as u64,
                        thread: req_num(&v, "thread")? as u64,
                    }),
                    ("event", Some(r)) => {
                        req_num(&v, "t_us")?;
                        v.get("fields")?.as_obj()?;
                        r.events.push(req_str(&v, "name")?);
                    }
                    ("heartbeat", Some(r)) => {
                        for k in [
                            "t_us",
                            "done",
                            "pruned",
                            "deferred",
                            "committed",
                            "scheduled",
                            "jobs_per_s",
                            "eta_s",
                            "mapper_hit_rate",
                            "service_hit_rate",
                        ] {
                            req_num(&v, k)?;
                        }
                        r.heartbeats += 1;
                    }
                    ("metrics", Some(r)) => {
                        req_num(&v, "t_us")?;
                        let snap = v.get("snapshot")?;
                        snap.get("counters")?.as_obj()?;
                        snap.get("gauges")?.as_obj()?;
                        snap.get("histograms")?.as_obj()?;
                        r.metrics_lines += 1;
                    }
                    (k, Some(_)) => bail!("unknown line kind {k:?}"),
                }
                Ok(())
            })()
            .with_context(|| format!("{}:{lineno}", path.display()))?;
        }
        let mut r = match report {
            Some(r) => r,
            None => bail!("{}: empty trace (no header line)", path.display()),
        };
        r.lines = text.lines().count();
        Ok(r)
    }

    /// Wall clock covered by the trace in microseconds: the latest span
    /// end offset.
    pub fn wall_us(&self) -> u64 {
        self.spans.iter().map(|s| s.t_us + s.dur_us).max().unwrap_or(0)
    }

    /// Per-phase aggregation (by span name, sorted by total time desc):
    /// `(name, count, total_us, p50_us, p95_us)`.
    pub fn phases(&self) -> Vec<(String, usize, u64, f64, f64)> {
        let mut by_name: std::collections::BTreeMap<&str, Vec<f64>> = Default::default();
        for s in &self.spans {
            by_name.entry(&s.name).or_default().push(s.dur_us as f64);
        }
        let mut out: Vec<_> = by_name
            .into_iter()
            .map(|(name, durs)| {
                let total = durs.iter().sum::<f64>() as u64;
                let s = crate::util::stats::Summary::of(&durs);
                (name.to_string(), durs.len(), total, s.p50, s.p95)
            })
            .collect();
        out.sort_by(|a, b| b.2.cmp(&a.2).then(a.0.cmp(&b.0)));
        out
    }

    /// The `k` slowest per-job spans (`job.eval`), slowest first:
    /// `(job key, dur_us)`.
    pub fn slowest_jobs(&self, k: usize) -> Vec<(String, u64)> {
        let mut jobs: Vec<(String, u64)> = self
            .spans
            .iter()
            .filter(|s| s.name == "job.eval")
            .map(|s| (s.job.clone().unwrap_or_else(|| "<unattributed>".into()), s.dur_us))
            .collect();
        jobs.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        jobs.truncate(k);
        jobs
    }

    /// Fraction of trace wall-clock covered by per-job `job.eval` spans,
    /// merging overlaps across worker threads (the acceptance gate's
    /// ">= 95% of campaign wall-clock" number).
    pub fn job_span_coverage(&self) -> f64 {
        let wall = self.wall_us();
        if wall == 0 {
            return 0.0;
        }
        let mut ivals: Vec<(u64, u64)> = self
            .spans
            .iter()
            .filter(|s| s.name == "job.eval")
            .map(|s| (s.t_us, s.t_us + s.dur_us))
            .collect();
        ivals.sort_unstable();
        let mut covered = 0u64;
        let mut cur: Option<(u64, u64)> = None;
        for (a, b) in ivals {
            match &mut cur {
                Some((_, e)) if a <= *e => *e = (*e).max(b),
                _ => {
                    if let Some((s, e)) = cur {
                        covered += e - s;
                    }
                    cur = Some((a, b));
                }
            }
        }
        if let Some((s, e)) = cur {
            covered += e - s;
        }
        covered as f64 / wall as f64
    }

    /// Render the human report: summary line, per-phase table, top-K
    /// slowest jobs.
    pub fn render(&self, top: usize) -> String {
        let wall_s = self.wall_us() as f64 / 1e6;
        let mut out = format!(
            "trace of {} ({}schema {})\nwall clock {} | {} spans, {} events, {} heartbeats | \
             job span coverage {:.0}%\n\n",
            self.store,
            match &self.shard {
                Some(s) => format!("shard {s}, "),
                None => String::new(),
            },
            self.schema,
            human_time(wall_s),
            self.spans.len(),
            self.events.len(),
            self.heartbeats,
            self.job_span_coverage() * 100.0,
        );
        let mut t = Table::new(vec!["phase", "count", "total", "p50", "p95", "% wall"]);
        for (name, count, total_us, p50, p95) in self.phases() {
            let pct = if self.wall_us() > 0 {
                100.0 * total_us as f64 / self.wall_us() as f64
            } else {
                0.0
            };
            t.row(vec![
                name,
                count.to_string(),
                human_time(total_us as f64 / 1e6),
                human_time(p50 / 1e6),
                human_time(p95 / 1e6),
                // Can exceed 100%: phase totals sum across worker threads.
                format!("{pct:.1}"),
            ]);
        }
        out.push_str(&t.render());
        let slow = self.slowest_jobs(top);
        if !slow.is_empty() {
            out.push_str(&format!("\ntop {} slowest jobs:\n", slow.len()));
            let mut t = Table::new(vec!["job", "time"]);
            for (job, dur_us) in slow {
                t.row(vec![job, human_time(dur_us as f64 / 1e6)]);
            }
            out.push_str(&t.render());
        }
        out
    }
}
