//! Embodied-carbon model for 2D/3D accelerators — paper §III-B, Eq. (1)-(5).
//!
//! C_embodied = C_die_logic + C_die_memory + C_bonding + C_packaging    (1)
//! C_die      = CFPA * A_die + CFPA_Si * A_wasted                        (2)
//! CFPA       = (CI_fab * EPA + C_gas + C_material) / Y                  (3)
//! C_bonding  = CFPA_bonding * A_die                                     (4)
//! C_packaging= CFPA_packaging * A_package                               (5)
//!
//! Fab parameters follow ACT [3] / ECO-CHIP [19] / 3D-Carbon [18] published
//! ranges and the ISSCC'24 3D SoC prototype [10]; all approaches in every
//! experiment share them, so comparisons are like-for-like (DESIGN.md §6.5).

pub mod operational;
pub mod wafer;
pub mod yield_model;

pub use wafer::{dies_per_wafer, wasted_area_per_die_mm2, WAFER_DIAMETER_MM};
pub use yield_model::die_yield;

use crate::area::die::{DieAreas, Integration};
use crate::area::TechNode;

/// Carbon intensity of the fab's electricity, kgCO2 per kWh.
/// (Taiwan-grid-like value used across ACT studies.)
pub const CI_FAB_KGCO2_PER_KWH: f64 = 0.5;

/// Carbon cost per area of *wasted* silicon (dicing loss): raw wafer
/// processing + material, amortized — gCO2/mm^2.
pub const CFPA_SI_G_PER_MM2: f64 = 0.6;

/// Hybrid-bonding carbon per bonded die area, gCO2/mm^2 (wafer thinning,
/// pad planarization, F2F bonding steps — 3D-Carbon ballpark).
pub const CFPA_BONDING_G_PER_MM2: f64 = 1.0;

/// Packaging carbon per package-substrate area, gCO2/mm^2.
/// TSV-based 3D packages pay extra etch/fill steps vs 2D flip-chip.
pub const CFPA_PKG_2D_G_PER_MM2: f64 = 0.6;
pub const CFPA_PKG_3D_G_PER_MM2: f64 = 1.0;

/// SRAM-only memory dies need fewer mask/metal layers than logic dies;
/// ECO-CHIP models them with a reduced per-area fab footprint.
pub const MEMORY_DIE_EPA_FACTOR: f64 = 0.7;

/// Hybrid-bonding stack yield: a failed bond scraps *both* known-good dies,
/// so 3D die carbon is amortized over successful stacks ([6]'s "lower
/// fabrication yields" of 3D integration).
pub const BOND_YIELD: f64 = 0.97;

/// Die process kind: logic dies pay the full per-area fab footprint; SRAM
/// memory dies a reduced one (fewer masks/metal layers).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DieKind {
    Logic,
    Memory,
}

/// Carbon footprint per unit *good* die area at a node — Eq. (3) — gCO2/mm^2.
/// `die_area_mm2` enters through yield Y(A).
pub fn cfpa_g_per_mm2(node: TechNode, die_area_mm2: f64, kind: DieKind) -> f64 {
    let epa_factor = match kind {
        DieKind::Logic => 1.0,
        DieKind::Memory => MEMORY_DIE_EPA_FACTOR,
    };
    // kgCO2/cm^2 terms.
    let energy = CI_FAB_KGCO2_PER_KWH * node.epa_kwh_per_cm2() * epa_factor;
    let raw_kg_per_cm2 = energy + node.gas_kgco2_per_cm2() * epa_factor + node.material_kgco2_per_cm2();
    let y = die_yield(node, die_area_mm2);
    // kg/cm^2 -> g/mm^2 : *1000 / 100
    raw_kg_per_cm2 * 10.0 / y
}

/// Eq. (2): carbon of fabricating one die, gCO2 (fabrication + dicing waste).
pub fn die_carbon_g(node: TechNode, die_area_mm2: f64, kind: DieKind) -> f64 {
    if die_area_mm2 <= 0.0 {
        return 0.0;
    }
    let fab = cfpa_g_per_mm2(node, die_area_mm2, kind) * die_area_mm2;
    let waste = CFPA_SI_G_PER_MM2 * wasted_area_per_die_mm2(die_area_mm2);
    fab + waste
}

/// Breakdown of the total embodied carbon, all in gCO2.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CarbonBreakdown {
    pub logic_die_g: f64,
    pub memory_die_g: f64,
    pub bonding_g: f64,
    pub packaging_g: f64,
}

impl CarbonBreakdown {
    /// Eq. (1): total embodied carbon, gCO2.
    pub fn total_g(&self) -> f64 {
        self.logic_die_g + self.memory_die_g + self.bonding_g + self.packaging_g
    }
}

/// Eq. (1)-(5) for a full accelerator.
pub fn embodied_carbon(
    areas: &DieAreas,
    node: TechNode,
    integration: Integration,
) -> CarbonBreakdown {
    // 3D stacks amortize die carbon over bond yield: a failed bond scraps
    // both known-good dies.
    let stack_yield = match integration {
        Integration::ThreeD => BOND_YIELD,
        Integration::TwoD => 1.0,
    };
    let logic_die_g = die_carbon_g(node, areas.logic_mm2, DieKind::Logic) / stack_yield;
    let memory_die_g = die_carbon_g(node, areas.memory_mm2, DieKind::Memory) / stack_yield;
    let (bonding_g, pkg_rate) = match integration {
        Integration::ThreeD => {
            // Both bonded interfaces are the stack footprint.
            (CFPA_BONDING_G_PER_MM2 * areas.footprint_mm2(), CFPA_PKG_3D_G_PER_MM2)
        }
        Integration::TwoD => (0.0, CFPA_PKG_2D_G_PER_MM2),
    };
    CarbonBreakdown {
        logic_die_g,
        memory_die_g,
        bonding_g,
        packaging_g: pkg_rate * areas.package_mm2,
    }
}

/// Carbon efficiency in gCO2 per mm^2 of *package* area (Fig. 3's y-axis).
pub fn carbon_per_mm2(breakdown: &CarbonBreakdown, areas: &DieAreas) -> f64 {
    breakdown.total_g() / areas.package_mm2
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn areas(logic: f64, memory: f64) -> DieAreas {
        DieAreas { logic_mm2: logic, memory_mm2: memory, package_mm2: logic.max(memory) * 1.35 + 4.0 }
    }

    #[test]
    fn cfpa_increases_at_advanced_nodes() {
        // Per-area fab carbon grows toward 7nm (more EUV/mask steps).
        let a = 20.0;
        assert!(cfpa_g_per_mm2(TechNode::N7, a, DieKind::Logic) > cfpa_g_per_mm2(TechNode::N14, a, DieKind::Logic));
        assert!(cfpa_g_per_mm2(TechNode::N14, a, DieKind::Logic) > cfpa_g_per_mm2(TechNode::N45, a, DieKind::Logic));
    }

    #[test]
    fn cfpa_grows_with_die_area_via_yield() {
        let node = TechNode::N7;
        assert!(cfpa_g_per_mm2(node, 200.0, DieKind::Logic) > cfpa_g_per_mm2(node, 10.0, DieKind::Logic));
    }

    #[test]
    fn die_carbon_superlinear_in_area() {
        // Doubling area more than doubles carbon (yield term).
        let node = TechNode::N7;
        let c1 = die_carbon_g(node, 50.0, DieKind::Logic);
        let c2 = die_carbon_g(node, 100.0, DieKind::Logic);
        assert!(c2 > 2.0 * c1);
    }

    #[test]
    fn three_d_carbon_exceeds_2d_at_iso_resources() {
        // The paper's core 3D sustainability challenge: for the same
        // accelerator resources (PEs + buffers), the 3D stack pays bonding
        // and TSV packaging, exceeding the 2D design's embodied carbon.
        // Checked through the real area pipeline (the memory die's reduced
        // fab footprint does not offset the 3D overheads).
        use crate::approx::{library, EXACT_ID};
        let lib = library();
        for node in crate::area::node::ALL_NODES {
            for n_pes in [256usize, 1024] {
                let px = (n_pes as f64).sqrt() as usize;
                let mk = |integration| {
                    crate::area::die::die_areas(
                        px,
                        n_pes / px,
                        128,
                        512 << 10,
                        &lib[EXACT_ID],
                        node,
                        integration,
                    )
                };
                let a2 = mk(Integration::TwoD);
                let a3 = mk(Integration::ThreeD);
                let c2 = embodied_carbon(&a2, node, Integration::TwoD).total_g();
                let c3 = embodied_carbon(&a3, node, Integration::ThreeD).total_g();
                assert!(c3 > c2, "{} {n_pes}PE: 3D {c3} !> 2D {c2}", node.name());
            }
        }
    }

    #[test]
    fn smaller_dies_help_yield_term() {
        // Splitting silicon into two smaller dies improves per-die yield —
        // the die-fab component alone must not grow.
        let node = TechNode::N7;
        let whole = die_carbon_g(node, 100.0, DieKind::Logic);
        let split = 2.0 * die_carbon_g(node, 50.0, DieKind::Logic);
        assert!(split < whole);
    }

    #[test]
    fn breakdown_total_is_sum() {
        let b = CarbonBreakdown { logic_die_g: 1.0, memory_die_g: 2.0, bonding_g: 3.0, packaging_g: 4.0 };
        assert_eq!(b.total_g(), 10.0);
    }

    #[test]
    fn carbon_positive_and_monotone_in_area_prop() {
        prop::check("carbon-monotone", 60, |rng| {
            let node = *rng.choice(&crate::area::node::ALL_NODES);
            let a = rng.uniform(1.0, 150.0);
            let delta = rng.uniform(0.5, 30.0);
            let c_small = embodied_carbon(&areas(a, a * 0.4), node, Integration::ThreeD).total_g();
            let c_big =
                embodied_carbon(&areas(a + delta, (a + delta) * 0.4), node, Integration::ThreeD)
                    .total_g();
            assert!(c_small > 0.0);
            assert!(c_big > c_small, "node {} a {a} delta {delta}", node.name());
        });
    }

    #[test]
    fn zero_memory_die_contributes_zero() {
        let b = embodied_carbon(&areas(25.0, 0.0), TechNode::N45, Integration::TwoD);
        assert_eq!(b.memory_die_g, 0.0);
        assert_eq!(b.bonding_g, 0.0);
    }
}
