//! END-TO-END driver: proves all three layers compose on a real small
//! workload.
//!
//!   L1 Pallas LUT-matmul kernel ──lowered into── L2 JAX CNN artifact
//!        └───────────── executed by ─────────── L3 rust PJRT runtime
//!
//! Pipeline:
//!   1. verify + compile the AOT artifacts (trained tiny CNN, 512-image
//!      held-out test set);
//!   2. MEASURE the accuracy drop ΔA of every multiplier in the library by
//!      running batched inference through the PJRT executable (the
//!      ApproxTrain stand-in — no Python anywhere on this path);
//!   3. cross-check a sample against the bit-faithful native evaluator;
//!   4. calibrate the analytical ΔA model's K on the measured table;
//!   5. build *measured* feasible sets for δ ∈ {1,2,3}% and run the GA DSE
//!      with them (tinycnn workload @14nm), reporting carbon vs the exact
//!      baseline.
//!
//! Writes results/e2e.json. Run:
//!   `cargo run --release --example e2e_accuracy [-- --limit N]`

use std::collections::BTreeMap;
use std::path::Path;

use carbon3d::accuracy::model::calibrate_k;
use carbon3d::accuracy::native::ApproxDatapath;
use carbon3d::accuracy::AccuracyTable;
use carbon3d::approx::{library, lut_f32, EXACT_ID};
use carbon3d::area::TechNode;
use carbon3d::coordinator::ga_cdp_exact;
use carbon3d::dataflow::workloads::workload;
use carbon3d::ga::GaParams;
use carbon3d::runtime::{Artifacts, Engine};
use carbon3d::util::json::{obj, Json};
use carbon3d::util::timer::{human_time, time_once};
use carbon3d::util::Table;

fn main() -> anyhow::Result<()> {
    let limit = std::env::args()
        .skip_while(|a| a != "--limit")
        .nth(1)
        .and_then(|v| v.parse::<usize>().ok())
        .unwrap_or(usize::MAX);

    // ---- 1. artifacts + engine -------------------------------------------
    let artifacts = Artifacts::load(Path::new("artifacts"))?;
    let (engine, t_compile) = time_once(|| Engine::new(artifacts));
    let engine = engine?;
    println!(
        "compiled {} executables on {} in {}",
        Artifacts::hlo_names().len(),
        engine.platform(),
        human_time(t_compile)
    );

    // ---- 2. measured ΔA per multiplier via PJRT ---------------------------
    let lib = library();
    let n_mults = lib.len().min(limit);
    let exact_acc = engine.accuracy_pjrt(None)?;
    println!(
        "exact-path accuracy (PJRT, {} images): {:.4} (manifest {:.4})",
        engine.artifacts.n_test, exact_acc, engine.artifacts.exact_test_accuracy
    );
    anyhow::ensure!((exact_acc - engine.artifacts.exact_test_accuracy).abs() < 1e-9);

    let mut measured = AccuracyTable { exact: exact_acc, ..Default::default() };
    let mut per_mult_secs = Vec::new();
    for m in lib.iter().take(n_mults) {
        let lut = lut_f32(m);
        let (acc, dt) = time_once(|| engine.accuracy_pjrt(Some(&lut)));
        measured.accuracy.insert(m.id, acc?);
        per_mult_secs.push(dt);
    }
    let total_eval: f64 = per_mult_secs.iter().sum();
    println!(
        "measured ΔA for {n_mults} multipliers x {} images in {} ({} per multiplier)",
        engine.artifacts.n_test,
        human_time(total_eval),
        human_time(total_eval / n_mults as f64)
    );

    // ---- 3. cross-check vs the native bit-faithful evaluator --------------
    let native = engine.native();
    for name in ["EXACT", "TRUNC3", "PERF5", "MITCH", "DRUM4"] {
        let m = lib.iter().find(|m| m.name() == name).unwrap();
        if m.id >= n_mults {
            continue;
        }
        let native_acc = native.accuracy(&ApproxDatapath::new(m));
        let pjrt_acc = measured.accuracy[&m.id];
        anyhow::ensure!(
            (native_acc - pjrt_acc).abs() < 0.005,
            "{name}: native {native_acc} vs pjrt {pjrt_acc}"
        );
    }
    println!("native evaluator cross-check OK (5 designs, |Δ| < 0.5pp)");

    // ---- 4. calibrate the analytical model --------------------------------
    let tiny = workload("tinycnn").unwrap();
    let k = calibrate_k(&lib, &tiny, &measured);
    println!("calibrated ΔA-model K = {k:.3}");

    let mut t = Table::new(vec!["mult", "area_um2@14nm", "measured_drop_pp"]);
    for m in lib.iter().take(n_mults) {
        t.row(vec![
            m.name(),
            format!("{:.1}", m.hw_cost(TechNode::N14).area_um2),
            format!("{:+.2}", measured.drop_pct(m.id).unwrap()),
        ]);
    }
    println!("{}", t.render());

    // ---- 5. GA DSE with *measured* feasible sets --------------------------
    let params = GaParams::default();
    let base = ga_cdp_exact(&tiny, TechNode::N14, &lib, None, params);
    println!(
        "baseline (exact): carbon {:.2} g, delay {:.3} ms",
        base.best_eval.carbon_g,
        base.best_eval.delay_s * 1e3
    );
    let mut deltas_json: BTreeMap<String, Json> = BTreeMap::new();
    for delta in [1.0, 2.0, 3.0] {
        let feasible = measured.feasible(delta);
        anyhow::ensure!(feasible.contains(&EXACT_ID));
        // Run the DSE restricted to the *measured* feasible set by pruning
        // the library view the GA sees.
        let r = ga_appx_min_carbon_measured(
            &tiny,
            TechNode::N14,
            &lib,
            &feasible,
            base.best_eval.fps * 0.999,
            params,
            &base.best,
        );
        let cut = (1.0 - r.best_eval.carbon_g / base.best_eval.carbon_g) * 100.0;
        println!(
            "δ={delta}%: {} feasible multipliers; best = {} -> carbon {:.2} g ({:+.1}% vs baseline)",
            feasible.len(),
            lib[r.best.mult_id].name(),
            r.best_eval.carbon_g,
            -cut
        );
        deltas_json.insert(
            format!("delta_{delta}"),
            obj([
                ("feasible", Json::from(feasible.len())),
                ("mult", Json::from(lib[r.best.mult_id].name())),
                ("carbon_g", Json::from(r.best_eval.carbon_g)),
                ("carbon_cut_pct", Json::from(cut)),
            ]),
        );
    }

    std::fs::create_dir_all("results")?;
    let out = obj([
        ("exact_accuracy", Json::from(exact_acc)),
        ("n_multipliers", Json::from(n_mults)),
        ("calibrated_k", Json::from(k)),
        ("eval_seconds_total", Json::from(total_eval)),
        ("baseline_carbon_g", Json::from(base.best_eval.carbon_g)),
        ("dse", Json::Obj(deltas_json)),
        (
            "measured_drops_pp",
            Json::Obj(
                lib.iter()
                    .take(n_mults)
                    .map(|m| (m.name(), Json::from(measured.drop_pct(m.id).unwrap())))
                    .collect(),
            ),
        ),
    ]);
    std::fs::write("results/e2e.json", out.pretty(2))?;
    println!("wrote results/e2e.json — end-to-end pipeline OK");
    Ok(())
}

/// GA constrained to an explicit measured feasible-multiplier set.
fn ga_appx_min_carbon_measured(
    w: &carbon3d::dataflow::workloads::Workload,
    node: TechNode,
    lib: &[carbon3d::approx::Multiplier],
    feasible: &[usize],
    fps_floor: f64,
    params: GaParams,
    baseline: &carbon3d::ga::Chromosome,
) -> carbon3d::ga::GaResult {
    use carbon3d::area::die::Integration;
    use carbon3d::coordinator::carbon_descend;
    use carbon3d::ga::fitness::FitnessCtx;
    use carbon3d::ga::{Ga, SearchSpace};

    let space = SearchSpace::standard(feasible.to_vec());
    let mut ctx = FitnessCtx::new(w, node, Integration::ThreeD, lib, Some(fps_floor));
    let mut r = Ga::new(space.clone(), params).run(&mut ctx);
    let mut seeds = vec![r.best.clone()];
    let mut b2 = baseline.clone();
    b2.mult_id = EXACT_ID;
    if space.contains(&b2) {
        seeds.push(b2);
    }
    let mut best: Option<(carbon3d::ga::Chromosome, carbon3d::ga::Evaluation)> = None;
    for s in seeds {
        let (c, e) = carbon_descend(&s, &space, &mut ctx);
        if e.feasible && best.as_ref().is_none_or(|(_, be)| e.carbon_g < be.carbon_g) {
            best = Some((c, e));
        }
    }
    if let Some((c, e)) = best {
        if e.carbon_g <= r.best_eval.carbon_g || !r.best_eval.feasible {
            r.best = c;
            r.best_eval = e;
        }
    }
    r
}
