//! Persistent mapping-cache sidecar: `campaign.jsonl` ->
//! `campaign.mapcache.json` (DESIGN.md §9.2).
//!
//! [`crate::dataflow::cache::MappingCache`] memoizes mapper searches
//! within one process; this module carries the memo *across* processes —
//! resumes, re-runs, shards, and `campaign merge` — by serializing the
//! cache to a schema-versioned, content-keyed JSON sidecar beside the
//! store. Every write goes through the same temp + rename discipline as
//! the front checkpoint ([`crate::campaign::checkpoint::write_atomic`]).
//!
//! The sidecar is a **performance hint, never a source of truth**: a
//! cached mapping is a pure function of its (workload, geometry) key, so
//! preloading can only skip recomputation, never change a result — the
//! store, front checkpoint, and deterministic report are byte-identical
//! with the sidecar present, absent, or corrupt (CI-gated). That is why,
//! in deliberate contrast to the front sidecar (whose corruption is loud:
//! external damage to a source of truth), a damaged or stale mapcache
//! sidecar is *quietly* dropped and rebuilt, logged through one
//! [`crate::obs::warn_event`] (`mapcache.rebuild`).
//!
//! Staleness is detected by content keying: the header carries a
//! fingerprint hashed from a canonical probe `map_network` result, so a
//! sidecar written by a binary whose mapper produces different mappings
//! is rejected as stale instead of silently poisoning results with
//! mappings the current mapper would not compute.
//!
//! Lossless by construction: `u64` cycle/traffic fields serialize as
//! decimal strings (the JSON layer's `f64` numbers lose integers above
//! 2^53) and `utilization` as bit-exact hex, so a round-trip through the
//! sidecar reproduces every mapping byte for byte.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, OnceLock};

use anyhow::{anyhow, ensure, Result};

use crate::area::die::Integration;
use crate::area::TechNode;
use crate::dataflow::arch::AccelConfig;
use crate::dataflow::cache::{GeometryDims, MappingCache};
use crate::dataflow::mapper::{map_network, LayerMapping, NetworkMapping};
use crate::dataflow::workloads::workload;
use crate::util::json::{obj, Json};

use super::checkpoint::write_atomic;
use super::spec::{fnv1a64, integration_from_name, integration_name};

/// Sidecar schema identifier; bump on any layout change.
pub const MAPCACHE_SCHEMA: &str = "carbon3d-mapcache/1";

static FORCE_OFF: AtomicBool = AtomicBool::new(false);

/// Programmatic kill switch (`--no-mapcache`); composes with the
/// `CARBON3D_MAPCACHE=0` environment override.
pub fn set_enabled(on: bool) {
    FORCE_OFF.store(!on, Ordering::Relaxed);
}

/// Whether mapcache sidecars are read/written by this process.
pub fn enabled() -> bool {
    !FORCE_OFF.load(Ordering::Relaxed)
        && std::env::var("CARBON3D_MAPCACHE").map(|v| v != "0").unwrap_or(true)
}

/// The sidecar path for a store: `campaign.jsonl` ->
/// `campaign.mapcache.json` (shard stores get their own, e.g.
/// `campaign.shard0of2.mapcache.json`).
pub fn mapcache_path(store: &Path) -> PathBuf {
    store.with_extension("mapcache.json")
}

/// The content key guarding sidecar reuse: an FNV-1a hash of the
/// serialized mapping the current mapper computes for one fixed probe
/// (tinycnn on a canonical mid-size geometry). Any change to mapper
/// semantics, the serialization layout, or the workload model changes
/// this value, invalidating every older sidecar. Computed once per
/// process — the probe is a single sub-millisecond `map_network` call.
pub fn mapper_fingerprint() -> &'static str {
    static FP: OnceLock<String> = OnceLock::new();
    FP.get_or_init(|| {
        let w = workload("tinycnn").expect("tinycnn workload exists");
        let cfg = AccelConfig {
            px: 8,
            py: 8,
            rf_bytes: 512,
            sram_bytes: 1 << 18,
            node: TechNode::N14,
            integration: Integration::ThreeD,
            mult_id: 0,
        };
        let probe = mapping_json(&map_network(&w, &cfg)).dumps();
        format!("{:#018x}", fnv1a64(probe.as_bytes()))
    })
}

/// Serialize the cache to `path` atomically: entries sorted by
/// (workload, geometry) so identical cache contents — however they were
/// accumulated — produce identical sidecar bytes.
pub fn save(path: &Path, cache: &MappingCache) -> Result<()> {
    let mut entries = cache.export();
    entries.sort_by(|a, b| entry_sort_key(&a.0, &a.1).cmp(&entry_sort_key(&b.0, &b.1)));
    let items: Vec<Json> =
        entries.iter().map(|(w, dims, m)| entry_json(w, dims, m)).collect();
    let doc = obj([
        ("schema", Json::from(MAPCACHE_SCHEMA)),
        ("fingerprint", Json::from(mapper_fingerprint())),
        ("entries", Json::from(items)),
    ]);
    let text = doc.dumps();
    // Atomic (temp + rename), so retrying a transient failure is safe; a
    // crash here at worst loses the hint, never store truth.
    super::fault::retry_io("mapcache.save", || -> Result<()> {
        super::fault::point("mapcache.save")?;
        write_atomic(path, &text)
    })
}

/// Preload `cache` from the sidecar at `path`. Missing file: a silent 0.
/// Unreadable, unparsable, schema-mismatched, or stale-fingerprint
/// sidecars are dropped with one `mapcache.rebuild` warn event and a 0 —
/// the cache simply rebuilds from scratch, exactly as if the file were
/// absent. Returns the number of entries actually injected.
pub fn load_into(path: &Path, cache: &MappingCache) -> usize {
    if !path.exists() {
        return 0;
    }
    match read_entries(path) {
        Ok(entries) => cache.preload(entries),
        Err(e) => {
            crate::obs::warn_event(
                "mapcache.rebuild",
                &format!("ignoring mapping-cache sidecar {}: {e}", path.display()),
                &[
                    ("path", Json::from(path.display().to_string())),
                    ("reason", Json::from(e.to_string())),
                ],
            );
            0
        }
    }
}

/// Union any readable sidecars among `sources` into one canonical sidecar
/// at `dest` (the `campaign merge` path: shard sidecars fold into the
/// canonical store's). Insert-if-absent per key makes the union
/// order-independent, and the sorted serializer makes the output bytes
/// independent of source order too. Unreadable sources are skipped via
/// the same quiet-rebuild rule as [`load_into`]. Returns the number of
/// entries in the merged sidecar.
pub fn merge_sidecars(dest: &Path, sources: &[PathBuf]) -> Result<usize> {
    let cache = MappingCache::new();
    load_into(dest, &cache);
    for src in sources {
        load_into(src, &cache);
    }
    let n = cache.len();
    if n > 0 {
        save(dest, &cache)?;
    }
    Ok(n)
}

/// The commit pipeline's persist handle: rewrites the sidecar at archive
/// checkpoints when (and only when) the cache grew since the last write,
/// so a steady-state campaign pays one `len()` probe per commit and an
/// interrupted one resumes with every mapping it had already discovered.
/// Write failures degrade to a warn event — the sidecar is a hint, and
/// losing it must never kill a campaign.
pub struct MapCachePersist {
    path: PathBuf,
    cache: Arc<MappingCache>,
    last_len: usize,
}

impl MapCachePersist {
    /// A handle that writes `cache` to `path`.
    pub fn new(path: PathBuf, cache: Arc<MappingCache>) -> Self {
        Self { path, cache, last_len: 0 }
    }

    /// Serialize the cache to the sidecar if its entry count changed
    /// since the last successful write.
    pub fn persist_if_grown(&mut self) {
        let len = self.cache.len();
        if len == self.last_len {
            return;
        }
        match save(&self.path, &self.cache) {
            Ok(()) => self.last_len = len,
            Err(e) => crate::obs::warn_event(
                "mapcache.write_failed",
                &format!(
                    "could not write mapping-cache sidecar {}: {e}",
                    self.path.display()
                ),
                &[("path", Json::from(self.path.display().to_string()))],
            ),
        }
    }
}

/// Parse and validate the sidecar, returning its entries.
fn read_entries(path: &Path) -> Result<Vec<(String, GeometryDims, NetworkMapping)>> {
    let text = std::fs::read_to_string(path)?;
    let doc = Json::parse(&text).map_err(|e| anyhow!("parse: {e}"))?;
    let schema = doc.get("schema")?.as_str()?;
    ensure!(schema == MAPCACHE_SCHEMA, "schema {schema}, want {MAPCACHE_SCHEMA}");
    let fp = doc.get("fingerprint")?.as_str()?;
    ensure!(
        fp == mapper_fingerprint(),
        "stale fingerprint {fp} (current {})",
        mapper_fingerprint()
    );
    let mut out = Vec::new();
    for e in doc.get("entries")?.as_arr()? {
        out.push(parse_entry(e)?);
    }
    Ok(out)
}

fn entry_sort_key(
    w: &str,
    dims: &GeometryDims,
) -> (String, usize, usize, usize, usize, &'static str, &'static str) {
    let (px, py, rf, sram, node, integ) = *dims;
    (w.to_string(), px, py, rf, sram, node.name(), integration_name(integ))
}

fn entry_json(w: &str, dims: &GeometryDims, m: &NetworkMapping) -> Json {
    let (px, py, rf, sram, node, integ) = *dims;
    obj([
        ("workload", Json::from(w)),
        ("px", Json::from(px)),
        ("py", Json::from(py)),
        ("rf_bytes", Json::from(rf)),
        ("sram_bytes", Json::from(sram)),
        ("node", Json::from(node.name())),
        ("integration", Json::from(integration_name(integ))),
        ("mapping", mapping_json(m)),
    ])
}

fn parse_entry(e: &Json) -> Result<(String, GeometryDims, NetworkMapping)> {
    let w = e.get("workload")?.as_str()?.to_string();
    let node_name = e.get("node")?.as_str()?;
    let node = TechNode::from_name(node_name)
        .ok_or_else(|| anyhow!("unknown node {node_name}"))?;
    let integ_name = e.get("integration")?.as_str()?;
    let integ = integration_from_name(integ_name)
        .ok_or_else(|| anyhow!("unknown integration {integ_name}"))?;
    let dims: GeometryDims = (
        e.get("px")?.as_usize()?,
        e.get("py")?.as_usize()?,
        e.get("rf_bytes")?.as_usize()?,
        e.get("sram_bytes")?.as_usize()?,
        node,
        integ,
    );
    Ok((w, dims, parse_mapping(e.get("mapping")?)?))
}

fn mapping_json(m: &NetworkMapping) -> Json {
    let layers: Vec<Json> = m
        .layers
        .iter()
        .map(|l| {
            obj([
                ("name", Json::from(l.name.as_str())),
                ("cycles", u64_json(l.cycles)),
                ("compute_cycles", u64_json(l.compute_cycles)),
                ("sram_cycles", u64_json(l.sram_cycles)),
                ("dram_cycles", u64_json(l.dram_cycles)),
                ("utilization", f64_bits_json(l.utilization)),
                ("macs", u64_json(l.macs)),
                ("sram_words", u64_json(l.sram_words)),
                ("dram_bytes", u64_json(l.dram_bytes)),
            ])
        })
        .collect();
    obj([
        ("workload", Json::from(m.workload.as_str())),
        ("total_cycles", u64_json(m.total_cycles)),
        ("layers", Json::from(layers)),
    ])
}

fn parse_mapping(j: &Json) -> Result<NetworkMapping> {
    let mut layers = Vec::new();
    for l in j.get("layers")?.as_arr()? {
        layers.push(LayerMapping {
            name: l.get("name")?.as_str()?.to_string(),
            cycles: parse_u64(l, "cycles")?,
            compute_cycles: parse_u64(l, "compute_cycles")?,
            sram_cycles: parse_u64(l, "sram_cycles")?,
            dram_cycles: parse_u64(l, "dram_cycles")?,
            utilization: parse_f64_bits(l, "utilization")?,
            macs: parse_u64(l, "macs")?,
            sram_words: parse_u64(l, "sram_words")?,
            dram_bytes: parse_u64(l, "dram_bytes")?,
        });
    }
    Ok(NetworkMapping {
        workload: j.get("workload")?.as_str()?.to_string(),
        layers,
        total_cycles: parse_u64(j, "total_cycles")?,
    })
}

/// `u64` as a decimal string: the JSON layer's numbers are `f64`, which
/// would silently round cycle counts above 2^53.
fn u64_json(v: u64) -> Json {
    Json::from(v.to_string())
}

fn parse_u64(j: &Json, field: &str) -> Result<u64> {
    let s = j.get(field)?.as_str()?;
    s.parse::<u64>().map_err(|e| anyhow!("field {field}: {e}"))
}

/// `f64` as bit-exact hex, so utilization round-trips byte-for-byte.
fn f64_bits_json(v: f64) -> Json {
    Json::from(format!("{:#018x}", v.to_bits()))
}

fn parse_f64_bits(j: &Json, field: &str) -> Result<f64> {
    let s = j.get(field)?.as_str()?;
    let hex = s
        .strip_prefix("0x")
        .ok_or_else(|| anyhow!("field {field}: want 0x-prefixed bits, got {s}"))?;
    let bits =
        u64::from_str_radix(hex, 16).map_err(|e| anyhow!("field {field}: {e}"))?;
    Ok(f64::from_bits(bits))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::cache::CacheCounts;
    use crate::dataflow::geometry_dims;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir()
            .join(format!("carbon3d-mapcache-{}-{name}.jsonl", std::process::id()))
    }

    fn cfg(px: usize) -> AccelConfig {
        AccelConfig {
            px,
            py: 8,
            rf_bytes: 512,
            sram_bytes: 1 << 18,
            node: TechNode::N45,
            integration: Integration::ThreeD,
            mult_id: 0,
        }
    }

    fn populated_cache(pxs: &[usize]) -> MappingCache {
        let cache = MappingCache::new();
        let w = workload("tinycnn").unwrap();
        for &px in pxs {
            cache.mapping(&w, &cfg(px));
        }
        cache
    }

    #[test]
    fn sidecar_roundtrip_is_lossless_and_deterministic() {
        let store = tmp("roundtrip");
        let side = mapcache_path(&store);
        let _ = std::fs::remove_file(&side);

        let cache = populated_cache(&[4, 8, 16]);
        save(&side, &cache).unwrap();
        let bytes = std::fs::read(&side).unwrap();

        // Reload into a fresh cache: every mapping identical, counters
        // attribute the preload.
        let fresh = MappingCache::new();
        assert_eq!(load_into(&side, &fresh), 3);
        assert_eq!(fresh.len(), 3);
        assert_eq!(
            fresh.counts(),
            CacheCounts { preloaded: 3, ..Default::default() }
        );
        let w = workload("tinycnn").unwrap();
        for &px in &[4usize, 8, 16] {
            let direct = map_network(&w, &cfg(px));
            let got = fresh.mapping(&w, &cfg(px));
            assert_eq!(got.total_cycles, direct.total_cycles);
            assert_eq!(got.layers, direct.layers);
            for (a, b) in got.layers.iter().zip(&direct.layers) {
                assert_eq!(a.utilization.to_bits(), b.utilization.to_bits());
            }
        }
        assert_eq!(fresh.counts().persisted_hits, 3);

        // Saving the reloaded cache reproduces the sidecar byte-for-byte:
        // serialization is canonical, independent of accumulation order.
        save(&side, &fresh).unwrap();
        assert_eq!(std::fs::read(&side).unwrap(), bytes);
        let _ = std::fs::remove_file(&side);
    }

    #[test]
    fn corrupt_stale_and_alien_sidecars_rebuild_quietly() {
        let _guard = crate::obs::test_sink_guard();
        let side = mapcache_path(&tmp("corrupt"));
        let fresh = || MappingCache::new();

        // Truncated JSON.
        std::fs::write(&side, "{\"schema\":\"carbon3d-mapc").unwrap();
        assert_eq!(load_into(&side, &fresh()), 0);
        // Valid JSON, wrong schema.
        std::fs::write(&side, "{\"schema\":\"carbon3d-trace/1\"}").unwrap();
        assert_eq!(load_into(&side, &fresh()), 0);
        // Right schema, stale fingerprint.
        let doc = obj([
            ("schema", Json::from(MAPCACHE_SCHEMA)),
            ("fingerprint", Json::from("0x0000000000000000")),
            ("entries", Json::from(Vec::<Json>::new())),
        ]);
        std::fs::write(&side, doc.dumps()).unwrap();
        assert_eq!(load_into(&side, &fresh()), 0);
        // Right header, mangled entry.
        let doc = obj([
            ("schema", Json::from(MAPCACHE_SCHEMA)),
            ("fingerprint", Json::from(mapper_fingerprint())),
            ("entries", Json::from(vec![obj([("workload", Json::from("x"))])])),
        ]);
        std::fs::write(&side, doc.dumps()).unwrap();
        assert_eq!(load_into(&side, &fresh()), 0);
        // Missing file: silent zero (no event).
        let _ = std::fs::remove_file(&side);
        assert_eq!(load_into(&side, &fresh()), 0);
    }

    #[test]
    fn merge_unions_shard_sidecars_order_independently() {
        let w = workload("tinycnn").unwrap();
        let shard_a = mapcache_path(&tmp("merge-a"));
        let shard_b = mapcache_path(&tmp("merge-b"));
        save(&shard_a, &populated_cache(&[4, 8])).unwrap();
        save(&shard_b, &populated_cache(&[8, 16])).unwrap();

        let merge_to = |name: &str, sources: &[PathBuf]| -> Vec<u8> {
            let dest = mapcache_path(&tmp(name));
            let _ = std::fs::remove_file(&dest);
            assert_eq!(merge_sidecars(&dest, sources).unwrap(), 3);
            let bytes = std::fs::read(&dest).unwrap();
            let _ = std::fs::remove_file(&dest);
            bytes
        };
        let ab = merge_to("merge-ab", &[shard_a.clone(), shard_b.clone()]);
        let ba = merge_to("merge-ba", &[shard_b.clone(), shard_a.clone()]);
        assert_eq!(ab, ba, "sidecar union depends on source order");

        // The union serves every geometry either shard saw.
        let dest = mapcache_path(&tmp("merge-load"));
        std::fs::write(&dest, &ab).unwrap();
        let cache = MappingCache::new();
        assert_eq!(load_into(&dest, &cache), 3);
        for &px in &[4usize, 8, 16] {
            let direct = map_network(&w, &cfg(px));
            assert_eq!(cache.mapping(&w, &cfg(px)).layers, direct.layers);
        }
        for p in [&shard_a, &shard_b, &dest] {
            let _ = std::fs::remove_file(p);
        }
    }

    #[test]
    fn geometry_dims_roundtrip_through_entry_json() {
        let w = workload("tinycnn").unwrap();
        let c = cfg(32);
        let dims = geometry_dims(&c);
        let m = map_network(&w, &c);
        let (w2, dims2, m2) = parse_entry(&entry_json(&w.name, &dims, &m)).unwrap();
        assert_eq!(w2, w.name);
        assert_eq!(dims2, dims);
        assert_eq!(m2.layers, m.layers);
        assert_eq!(m2.total_cycles, m.total_cycles);
    }
}
