//! Small self-contained utilities (offline build: no rand/serde/proptest/
//! criterion available, so we carry our own).

pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;

pub use json::Json;
pub use rng::Rng;
pub use stats::Summary;
pub use table::Table;
