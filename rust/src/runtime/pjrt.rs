//! Thin wrapper over the `xla` crate's PJRT CPU client.
//!
//! Pattern from /opt/xla-example/load_hlo: HLO text -> HloModuleProto ->
//! XlaComputation -> compile -> execute. Artifacts are lowered with
//! return_tuple=True, so results unwrap with `to_tuple1`.
//!
//! The `xla` crate is only reachable in environments with the PJRT toolchain
//! installed, so the real implementation is gated behind the `pjrt` cargo
//! feature. Without it this module compiles to an API-identical stub whose
//! client constructor returns an error — every native (non-PJRT) path,
//! including the campaign engine's surrogate accuracy backend, is unaffected.

#[cfg(all(feature = "pjrt", feature = "pjrt-stub"))]
compile_error!(
    "features `pjrt` and `pjrt-stub` are mutually exclusive: pick the real \
     PJRT runtime or the stub, not both"
);

#[cfg(feature = "pjrt")]
mod real {
    use std::path::Path;

    use anyhow::{ensure, Context, Result};

    /// A compiled executable plus its human name (for errors/metrics).
    pub struct Executable {
        pub name: String,
        exe: xla::PjRtLoadedExecutable,
    }

    /// The PJRT client wrapper.
    pub struct PjrtClient {
        client: xla::PjRtClient,
    }

    impl PjrtClient {
        /// Create the CPU client.
        pub fn cpu() -> Result<Self> {
            let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
            Ok(Self { client })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        pub fn device_count(&self) -> usize {
            self.client.device_count()
        }

        /// Load an HLO-text artifact and compile it.
        pub fn compile_hlo_text(&self, name: &str, path: &Path) -> Result<Executable> {
            ensure!(path.exists(), "HLO artifact {} missing (run `make artifacts`)", path.display());
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 artifact path")?,
            )
            .with_context(|| format!("parse HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compile artifact {name}"))?;
            Ok(Executable { name: name.to_string(), exe })
        }
    }

    impl Executable {
        /// Execute with f32 tensor inputs (shape per tensor), returning the
        /// flattened f32 output of the 1-tuple result.
        pub fn run_f32(&self, inputs: &[(&[f32], &[usize])]) -> Result<Vec<f32>> {
            let literals: Vec<xla::Literal> = inputs
                .iter()
                .map(|(data, shape)| -> Result<xla::Literal> {
                    let lit = xla::Literal::vec1(data);
                    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                    lit.reshape(&dims)
                        .with_context(|| format!("reshape input to {shape:?} for {}", self.name))
                })
                .collect::<Result<Vec<_>>>()?;
            let result = self
                .exe
                .execute::<xla::Literal>(&literals)
                .with_context(|| format!("execute {}", self.name))?;
            let lit = result[0][0]
                .to_literal_sync()
                .with_context(|| format!("fetch result of {}", self.name))?;
            let out = lit.to_tuple1().with_context(|| format!("untuple result of {}", self.name))?;
            out.to_vec::<f32>().with_context(|| format!("read f32 result of {}", self.name))
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod stub {
    use std::path::Path;

    use anyhow::{bail, Result};

    const UNAVAILABLE: &str =
        "PJRT unavailable: carbon3d was built without the `pjrt` feature \
         (enable with `--features pjrt` where the xla crate is installed)";

    /// Stub executable (never constructed without the `pjrt` feature).
    pub struct Executable {
        pub name: String,
    }

    /// Stub client: construction fails, so no stub method is ever reached
    /// at runtime.
    pub struct PjrtClient {
        _private: (),
    }

    impl PjrtClient {
        pub fn cpu() -> Result<Self> {
            bail!("{UNAVAILABLE}");
        }

        pub fn platform(&self) -> String {
            "unavailable".to_string()
        }

        pub fn device_count(&self) -> usize {
            0
        }

        pub fn compile_hlo_text(&self, _name: &str, _path: &Path) -> Result<Executable> {
            bail!("{UNAVAILABLE}");
        }
    }

    impl Executable {
        pub fn run_f32(&self, _inputs: &[(&[f32], &[usize])]) -> Result<Vec<f32>> {
            bail!("{UNAVAILABLE}");
        }
    }
}

#[cfg(feature = "pjrt")]
pub use real::{Executable, PjrtClient};

#[cfg(not(feature = "pjrt"))]
pub use stub::{Executable, PjrtClient};
