//! Accuracy-evaluation service: a threaded request loop over the evaluation
//! engine (the vLLM-router-shaped slice of L3).
//!
//! Clients submit `EvalRequest`s (multiplier id, or a raw LUT) on a channel;
//! a worker owns the evaluator and serves requests FIFO with *result
//! caching* and *request coalescing* (duplicate in-flight multiplier ids
//! collapse onto one evaluation — the GA hammers the same feasible set
//! repeatedly). The worker is generic over the evaluation backend so tests
//! run on the fast native path and production on PJRT.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::Result;

use crate::accuracy::native::{ApproxDatapath, NativeEvaluator};
use crate::approx::{lut_f32, Multiplier};

/// Evaluation backend: maps a multiplier LUT to a test-set accuracy.
pub trait EvalBackend: Send + 'static {
    fn accuracy_of_lut(&self, lut: &[f32]) -> Result<f64>;
}

/// Native bit-faithful backend (no PJRT; used in tests and as fallback).
///
/// Each request builds a table-driven [`ApproxDatapath`] (sign-folded
/// significand LUT + the process-global exponent-scale table, DESIGN.md
/// §7.6) whose matmul row-chunks across std threads — so the service's
/// single worker thread still saturates the machine during the one fresh
/// evaluation per multiplier its result cache admits.
pub struct NativeBackend(pub NativeEvaluator);

impl EvalBackend for NativeBackend {
    fn accuracy_of_lut(&self, lut: &[f32]) -> Result<f64> {
        Ok(self.0.accuracy(&ApproxDatapath::from_lut(lut.to_vec())))
    }
}

/// A request to evaluate one multiplier.
pub struct EvalRequest {
    pub mult_id: usize,
    pub lut: Vec<f32>,
    pub reply: Sender<Result<f64, String>>,
    /// Submission time — the worker records queue wait (`service.queue_wait`
    /// histogram) when it picks the request up.
    pub queued: std::time::Instant,
}

/// Worker mailbox message. `Stop` is sent by `shutdown` so the worker exits
/// deterministically even while client handles (sender clones) are alive.
enum Msg {
    Eval(EvalRequest),
    Stop,
}

/// Handle to the running service.
pub struct EvalService {
    tx: Sender<Msg>,
    worker: Option<JoinHandle<ServiceStats>>,
    counters: Arc<Counters>,
}

/// Counters the worker reports on shutdown — and, via [`EvalService::stats`],
/// *live* while serving: a campaign scheduler polls them to report
/// cross-job cache-hit/coalescing rates mid-run.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ServiceStats {
    pub served: usize,
    pub evaluated: usize,
    pub cache_hits: usize,
    pub coalesced: usize,
}

impl ServiceStats {
    /// Fraction of served requests answered without a fresh evaluation
    /// (cache hit or in-batch coalescing). 0.0 when nothing served yet.
    pub fn hit_rate(&self) -> f64 {
        if self.served == 0 {
            0.0
        } else {
            (self.cache_hits + self.coalesced) as f64 / self.served as f64
        }
    }
}

/// Shared atomic counters backing [`ServiceStats`] snapshots.
#[derive(Default)]
struct Counters {
    served: AtomicUsize,
    evaluated: AtomicUsize,
    cache_hits: AtomicUsize,
    coalesced: AtomicUsize,
}

impl Counters {
    fn snapshot(&self) -> ServiceStats {
        ServiceStats {
            served: self.served.load(Ordering::Acquire),
            evaluated: self.evaluated.load(Ordering::Acquire),
            cache_hits: self.cache_hits.load(Ordering::Acquire),
            coalesced: self.coalesced.load(Ordering::Acquire),
        }
    }
}

impl EvalService {
    /// Spawn the worker thread over a backend.
    pub fn start<B: EvalBackend>(backend: B) -> Self {
        let (tx, rx) = mpsc::channel::<Msg>();
        let counters = Arc::new(Counters::default());
        let worker_counters = counters.clone();
        let worker = std::thread::spawn(move || worker_loop(backend, rx, &worker_counters));
        Self { tx, worker: Some(worker), counters }
    }

    /// Client handle for submitting requests.
    pub fn client(&self) -> EvalClient {
        EvalClient { tx: self.tx.clone() }
    }

    /// Live counter snapshot (safe to call while the worker is serving).
    pub fn stats(&self) -> ServiceStats {
        self.counters.snapshot()
    }

    /// Shut down (poison message + join) and return stats. Outstanding
    /// queued requests ahead of the Stop are still served; requests already
    /// queued *behind* the Stop get an eager "service stopped" error reply
    /// (the worker drains and rejects them instead of dropping them), and
    /// later submits from surviving client clones fail at send time. One
    /// narrow race remains best-effort: a send landing between the worker's
    /// final drain and its channel teardown is reported as "service dropped
    /// request" — treat both errors as the service being gone.
    pub fn shutdown(mut self) -> ServiceStats {
        let _ = self.tx.send(Msg::Stop);
        self.worker
            .take()
            .expect("shutdown called once")
            .join()
            .expect("worker panicked")
    }
}

/// Cloneable client handle.
#[derive(Clone)]
pub struct EvalClient {
    tx: Sender<Msg>,
}

impl EvalClient {
    /// Blocking evaluation of one multiplier.
    pub fn eval(&self, m: &Multiplier) -> Result<f64, String> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Msg::Eval(EvalRequest {
                mult_id: m.id,
                lut: lut_f32(m),
                reply,
                queued: std::time::Instant::now(),
            }))
            .map_err(|_| "service stopped".to_string())?;
        rx.recv().map_err(|_| "service dropped request".to_string())?
    }

    /// Fire-and-collect: submit all multipliers, then gather accuracies in
    /// submission order. Coalescing in the worker dedupes repeats.
    pub fn eval_all(&self, mults: &[&Multiplier]) -> Result<Vec<f64>, String> {
        let mut replies = Vec::with_capacity(mults.len());
        for m in mults {
            let (reply, rx) = mpsc::channel();
            self.tx
                .send(Msg::Eval(EvalRequest {
                    mult_id: m.id,
                    lut: lut_f32(m),
                    reply,
                    queued: std::time::Instant::now(),
                }))
                .map_err(|_| "service stopped".to_string())?;
            replies.push(rx);
        }
        replies
            .into_iter()
            .map(|rx| rx.recv().map_err(|_| "service dropped request".to_string())?)
            .collect()
    }
}

fn worker_loop<B: EvalBackend>(backend: B, rx: Receiver<Msg>, counters: &Counters) -> ServiceStats {
    let mut cache: HashMap<usize, f64> = HashMap::new();
    let mut stopping = false;
    // Drain-and-batch: pull everything queued, coalesce by mult_id, then
    // evaluate unique ids once and fan results back out.
    while let Ok(first) = rx.recv() {
        let first = match first {
            Msg::Stop => {
                stopping = true;
                break;
            }
            Msg::Eval(r) => r,
        };
        let mut batch: Vec<EvalRequest> = vec![first];
        while let Ok(more) = rx.try_recv() {
            match more {
                Msg::Stop => {
                    stopping = true;
                    break;
                }
                Msg::Eval(r) => batch.push(r),
            }
        }
        // Group replies by multiplier id.
        let mut groups: HashMap<usize, Vec<EvalRequest>> = HashMap::new();
        for req in batch {
            groups.entry(req.mult_id).or_default().push(req);
        }
        let mut ids: Vec<usize> = groups.keys().copied().collect();
        ids.sort_unstable(); // deterministic service order
        for id in ids {
            let reqs = groups.remove(&id).unwrap();
            let m = crate::obs::metrics();
            for req in &reqs {
                m.record_duration("service.queue_wait", req.queued.elapsed());
            }
            counters.served.fetch_add(reqs.len(), Ordering::Release);
            counters.coalesced.fetch_add(reqs.len() - 1, Ordering::Release);
            m.incr("service_served", reqs.len() as u64);
            m.incr("service_coalesced", reqs.len() as u64 - 1);
            let acc = if let Some(&hit) = cache.get(&id) {
                counters.cache_hits.fetch_add(reqs.len(), Ordering::Release);
                m.incr("service_cache_hits", reqs.len() as u64);
                Ok(hit)
            } else {
                counters.evaluated.fetch_add(1, Ordering::Release);
                m.incr("service_evaluated", 1);
                let _span = crate::obs::span("service.eval");
                match backend.accuracy_of_lut(&reqs[0].lut) {
                    Ok(a) => {
                        cache.insert(id, a);
                        Ok(a)
                    }
                    Err(e) => Err(format!("{e:#}")),
                }
            };
            for req in reqs {
                let _ = req.reply.send(acc.clone());
            }
        }
        if stopping {
            break;
        }
    }
    if stopping {
        // Requests that raced in behind the Stop would otherwise be dropped
        // with the channel, leaving their reply senders dead and the client
        // mapping that to an opaque "service dropped request". Reject them
        // eagerly with the same error a post-shutdown submit gets.
        while let Ok(msg) = rx.try_recv() {
            if let Msg::Eval(req) = msg {
                let _ = req.reply.send(Err("service stopped".to_string()));
            }
        }
    }
    counters.snapshot()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    /// Counting stub backend: accuracy = f(lut[255*255 entry]) so results
    /// are checkable and differ across designs (the (128,128) entry is the
    /// same for most families — no low bits to approximate).
    struct Stub(Arc<AtomicUsize>);

    impl EvalBackend for Stub {
        fn accuracy_of_lut(&self, lut: &[f32]) -> Result<f64> {
            self.0.fetch_add(1, Ordering::SeqCst);
            Ok(f64::from(lut[127 * 128 + 127]) / 100_000.0)
        }
    }

    fn mults() -> Vec<crate::approx::Multiplier> {
        crate::approx::library()
    }

    #[test]
    fn serves_and_caches() {
        let count = Arc::new(AtomicUsize::new(0));
        let svc = EvalService::start(Stub(count.clone()));
        let client = svc.client();
        let lib = mults();
        let a1 = client.eval(&lib[0]).unwrap();
        let a2 = client.eval(&lib[0]).unwrap(); // cached
        let a3 = client.eval(&lib[5]).unwrap();
        assert_eq!(a1, a2);
        assert_ne!(a1, a3);
        let stats = svc.shutdown();
        assert_eq!(stats.served, 3);
        assert_eq!(stats.evaluated, 2);
        assert_eq!(stats.cache_hits, 1);
        assert_eq!(count.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn eval_all_returns_in_submission_order() {
        let svc = EvalService::start(Stub(Arc::new(AtomicUsize::new(0))));
        let client = svc.client();
        let lib = mults();
        let sel: Vec<&crate::approx::Multiplier> = vec![&lib[3], &lib[1], &lib[3], &lib[7]];
        let out = client.eval_all(&sel).unwrap();
        assert_eq!(out.len(), 4);
        assert_eq!(out[0], out[2]); // same multiplier, same answer
        let stats = svc.shutdown();
        assert_eq!(stats.served, 4);
        // The duplicate either coalesced in-batch or hit the cache; both
        // save one evaluation.
        assert_eq!(stats.evaluated, 3);
        assert_eq!(stats.coalesced + stats.cache_hits, 1);
    }

    #[test]
    fn concurrent_clients_all_get_answers() {
        let svc = EvalService::start(Stub(Arc::new(AtomicUsize::new(0))));
        let lib = Arc::new(mults());
        let mut handles = Vec::new();
        for t in 0..4 {
            let client = svc.client();
            let lib = lib.clone();
            handles.push(std::thread::spawn(move || {
                (0..8).map(|i| client.eval(&lib[(t * 3 + i) % lib.len()]).unwrap()).collect::<Vec<_>>()
            }));
        }
        for h in handles {
            let results = h.join().unwrap();
            assert_eq!(results.len(), 8);
        }
        let stats = svc.shutdown();
        assert_eq!(stats.served, 32);
        // At most one evaluation per distinct multiplier id.
        assert!(stats.evaluated <= 32 - stats.cache_hits - stats.coalesced);
    }

    #[test]
    fn requests_behind_stop_get_eager_error() {
        /// Slow backend: holds the worker long enough for a Stop plus a
        /// trailing request to queue up behind the in-flight batch.
        struct Slow;
        impl EvalBackend for Slow {
            fn accuracy_of_lut(&self, _lut: &[f32]) -> Result<f64> {
                std::thread::sleep(std::time::Duration::from_millis(100));
                Ok(0.5)
            }
        }
        let svc = EvalService::start(Slow);
        let client = svc.client();
        let lib = Arc::new(mults());
        let busy = {
            let c = svc.client();
            let lib = lib.clone();
            std::thread::spawn(move || c.eval(&lib[1]))
        };
        // Let the worker enter the slow evaluation, then queue Stop.
        std::thread::sleep(std::time::Duration::from_millis(20));
        let stopper = std::thread::spawn(move || svc.shutdown());
        std::thread::sleep(std::time::Duration::from_millis(20));
        // This lands behind the Stop (or after worker exit — either way the
        // surviving clone must get an eager, explicit error, not a dropped
        // reply channel).
        let err = client.eval(&lib[2]).unwrap_err();
        assert_eq!(err, "service stopped");
        // The busy request usually wins the race and is served; on a loaded
        // machine it may instead land behind the Stop — then it too must get
        // the explicit error, never an opaque dropped-reply one.
        let busy_res = busy.join().unwrap();
        assert!(
            busy_res == Ok(0.5) || busy_res == Err("service stopped".to_string()),
            "{busy_res:?}"
        );
        let stats = stopper.join().unwrap();
        assert!(stats.evaluated <= 1);
    }

    #[test]
    fn live_stats_visible_before_shutdown() {
        let svc = EvalService::start(Stub(Arc::new(AtomicUsize::new(0))));
        let client = svc.client();
        let lib = mults();
        client.eval(&lib[0]).unwrap();
        client.eval(&lib[0]).unwrap();
        let live = svc.stats();
        assert_eq!(live.served, 2);
        assert_eq!(live.evaluated, 1);
        assert_eq!(live.cache_hits, 1);
        assert!((live.hit_rate() - 0.5).abs() < 1e-12);
        assert_eq!(svc.shutdown(), live);
    }

    #[test]
    fn shutdown_returns_stats_once() {
        let svc = EvalService::start(Stub(Arc::new(AtomicUsize::new(0))));
        let stats = svc.shutdown();
        assert_eq!(stats, ServiceStats::default());
    }

    #[test]
    fn native_backend_datapath_parity() {
        // The backend's table-driven datapath must agree with the scalar
        // reference loop on the exact LUT it is handed — the service-level
        // view of the bit-identity invariant.
        let lib = mults();
        for m in [&lib[0], &lib[9], lib.last().unwrap()] {
            let dp = ApproxDatapath::from_lut(crate::approx::lut_f32(m));
            let a: Vec<f32> = (0..48).map(|i| (i as f32 * 0.37).sin() * 3.0).collect();
            let b: Vec<f32> = (0..60).map(|i| (i as f32 * 0.61).cos() * 2.0).collect();
            let got = dp.matmul(&a, &b, 4, 12, 5);
            let want = dp.matmul_reference(&a, &b, 4, 12, 5);
            assert_eq!(
                got.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                want.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            );
        }
    }

    #[test]
    fn native_backend_end_to_end_if_artifacts_exist() {
        if !std::path::Path::new("artifacts/manifest.json").exists() {
            eprintln!("SKIP: artifacts not built");
            return;
        }
        let artifacts = crate::runtime::Artifacts::load(std::path::Path::new("artifacts")).unwrap();
        let native = NativeEvaluator::load(&artifacts).unwrap();
        let exact_expected = artifacts.exact_test_accuracy;
        let svc = EvalService::start(NativeBackend(native));
        let client = svc.client();
        let lib = mults();
        let acc = client.eval(&lib[crate::approx::EXACT_ID]).unwrap();
        assert!((acc - exact_expected).abs() < 1e-9);
        svc.shutdown();
    }
}
