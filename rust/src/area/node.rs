//! Technology nodes evaluated by the paper: 45nm, 14nm, 7nm.
//!
//! Parameter sources (see DESIGN.md §6.5): 45nm open-cell-library era
//! numbers anchor the EvoApprox calibration; 14/7nm follow published
//! foundry density/FO4 trends and the ECO-CHIP / ACT carbon parameter
//! tables. Clock frequencies are the paper's: 500 / 940 / 1050 MHz.

use crate::approx::cost::CellParams;

/// A fabrication technology node.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum TechNode {
    N45,
    N14,
    N7,
}

pub const ALL_NODES: [TechNode; 3] = [TechNode::N45, TechNode::N14, TechNode::N7];

impl TechNode {
    pub fn name(&self) -> &'static str {
        match self {
            TechNode::N45 => "45nm",
            TechNode::N14 => "14nm",
            TechNode::N7 => "7nm",
        }
    }

    pub fn from_name(s: &str) -> Option<Self> {
        match s {
            "45" | "45nm" => Some(TechNode::N45),
            "14" | "14nm" => Some(TechNode::N14),
            "7" | "7nm" => Some(TechNode::N7),
            _ => None,
        }
    }

    /// MAC clock frequency (paper §IV): 500 / 940 / 1050 MHz.
    pub fn freq_mhz(&self) -> f64 {
        match self {
            TechNode::N45 => 500.0,
            TechNode::N14 => 940.0,
            TechNode::N7 => 1050.0,
        }
    }

    /// Standard-cell parameters (NAND2-equivalent).
    pub fn cell_params(&self) -> CellParams {
        match self {
            // 45nm: NAND2 ~ 1.06um x 1.7um with routing overhead -> ~1.6um^2.
            TechNode::N45 => CellParams {
                nand2_area_um2: 1.60,
                nand2_dyn_pw_per_mhz: 3.0,
                fo4_delay_ps: 125.0,
            },
            // 14nm FinFET: ~10x logic density over 45nm.
            TechNode::N14 => CellParams {
                nand2_area_um2: 0.160,
                nand2_dyn_pw_per_mhz: 0.9,
                fo4_delay_ps: 62.0,
            },
            // 7nm FinFET: ~3x density over 14nm.
            TechNode::N7 => CellParams {
                nand2_area_um2: 0.054,
                nand2_dyn_pw_per_mhz: 0.45,
                fo4_delay_ps: 53.0,
            },
        }
    }

    /// 6T SRAM bit-cell area in um^2 (published foundry values:
    /// 45nm ~0.35-0.37, 14nm ~0.064 (Intel 0.0588), 7nm ~0.027 (TSMC)).
    pub fn sram_bitcell_um2(&self) -> f64 {
        match self {
            TechNode::N45 => 0.36,
            TechNode::N14 => 0.064,
            TechNode::N7 => 0.027,
        }
    }

    /// Register-file bit-cell area (~1.2x the 6T cell for the small
    /// single-port scratchpads Eyeriss-style PEs use).
    pub fn rf_bitcell_um2(&self) -> f64 {
        self.sram_bitcell_um2() * 1.2
    }

    /// Defect density D0 (defects/mm^2) for the Poisson yield model.
    /// Advanced nodes have higher D0 (ECO-CHIP / industry ranges).
    pub fn defect_density_per_mm2(&self) -> f64 {
        match self {
            TechNode::N45 => 0.0007,
            TechNode::N14 => 0.0013,
            TechNode::N7 => 0.0020,
        }
    }

    /// Energy per unit area for wafer fabrication, kWh/cm^2 (ECO-CHIP/ACT
    /// trend: more masks/EUV steps at smaller nodes).
    pub fn epa_kwh_per_cm2(&self) -> f64 {
        match self {
            TechNode::N45 => 0.8,
            TechNode::N14 => 1.5,
            TechNode::N7 => 2.15,
        }
    }

    /// Direct greenhouse-gas emissions from fab chemistry, kgCO2/cm^2.
    pub fn gas_kgco2_per_cm2(&self) -> f64 {
        match self {
            TechNode::N45 => 0.10,
            TechNode::N14 => 0.15,
            TechNode::N7 => 0.20,
        }
    }

    /// Raw-material procurement carbon, kgCO2/cm^2.
    pub fn material_kgco2_per_cm2(&self) -> f64 {
        match self {
            TechNode::N45 => 0.28,
            TechNode::N14 => 0.39,
            TechNode::N7 => 0.50,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn name_roundtrip() {
        for n in ALL_NODES {
            assert_eq!(TechNode::from_name(n.name()), Some(n));
        }
        assert_eq!(TechNode::from_name("3nm"), None);
    }

    #[test]
    fn paper_clock_frequencies() {
        assert_eq!(TechNode::N45.freq_mhz(), 500.0);
        assert_eq!(TechNode::N14.freq_mhz(), 940.0);
        assert_eq!(TechNode::N7.freq_mhz(), 1050.0);
    }

    #[test]
    fn density_monotone_in_node() {
        assert!(TechNode::N45.cell_params().nand2_area_um2
            > TechNode::N14.cell_params().nand2_area_um2);
        assert!(TechNode::N14.cell_params().nand2_area_um2
            > TechNode::N7.cell_params().nand2_area_um2);
        assert!(TechNode::N45.sram_bitcell_um2() > TechNode::N7.sram_bitcell_um2());
    }

    #[test]
    fn carbon_intensity_of_fab_grows_at_advanced_nodes() {
        assert!(TechNode::N7.epa_kwh_per_cm2() > TechNode::N45.epa_kwh_per_cm2());
        assert!(TechNode::N7.defect_density_per_mm2() > TechNode::N45.defect_density_per_mm2());
    }
}
