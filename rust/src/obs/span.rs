//! Timed spans with thread-local nesting and per-job attribution.
//!
//! `span(name)` always feeds the duration histogram named after the span
//! (that is what `CampaignReport::line()`'s phase percentages read, so it
//! works on untraced runs too); the thread-local stack bookkeeping and the
//! sidecar line only happen when a trace sink is installed. With tracing
//! off the guard is inert: no allocation, no thread-local touch beyond
//! one atomic load — the property the `obs_alloc` test binary pins down.

use std::cell::RefCell;
use std::sync::Arc;
use std::time::Instant;

use super::metrics::metrics;
use super::sink;

thread_local! {
    /// Names of the open spans on this thread, outermost first.
    static STACK: RefCell<Vec<&'static str>> = const { RefCell::new(Vec::new()) };
    /// The job key the current thread is working on (set by executors).
    static JOB: RefCell<Option<Arc<str>>> = const { RefCell::new(None) };
}

/// RAII guard for one timed span. Closes (and records) on drop.
#[must_use = "a span measures the scope it is alive for"]
pub struct Span {
    name: &'static str,
    t0: Instant,
    /// Captured at open: whether this span participates in the sidecar.
    /// Keeps open/close symmetric even if the sink is (un)installed
    /// mid-span.
    traced: bool,
}

/// Open a timed span. The name doubles as the duration histogram name —
/// use the dotted `layer.verb` taxonomy from DESIGN.md §8.
pub fn span(name: &'static str) -> Span {
    let traced = sink::enabled();
    if traced {
        STACK.with(|s| s.borrow_mut().push(name));
    }
    Span { name, t0: Instant::now(), traced }
}

impl Drop for Span {
    fn drop(&mut self) {
        let dur = self.t0.elapsed();
        metrics().record_duration(self.name, dur);
        if self.traced {
            let (depth, parent) = STACK.with(|s| {
                let mut s = s.borrow_mut();
                s.pop();
                (s.len(), s.last().copied())
            });
            let job = JOB.with(|j| j.borrow().clone());
            sink::write_span(self.name, parent, depth, job.as_deref(), self.t0, dur);
        }
    }
}

/// RAII guard attributing spans on this thread to one job.
#[must_use = "a job scope attributes spans for the scope it is alive for"]
pub struct JobScope {
    prev: Option<Arc<str>>,
    active: bool,
}

/// Attribute subsequent spans on this thread to `key` until the guard
/// drops (restores the previous attribution, so scopes nest). Inert —
/// no allocation — when tracing is off.
pub fn job_scope(key: &str) -> JobScope {
    if !sink::enabled() {
        return JobScope { prev: None, active: false };
    }
    let prev = JOB.with(|j| j.borrow_mut().replace(Arc::from(key)));
    JobScope { prev, active: true }
}

impl Drop for JobScope {
    fn drop(&mut self) {
        if self.active {
            let prev = self.prev.take();
            JOB.with(|j| *j.borrow_mut() = prev);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_spans_skip_the_stack_but_feed_histograms() {
        let _guard = crate::obs::test_sink_guard();
        assert!(!sink::enabled());
        let before = metrics().snapshot();
        {
            let _outer = span("obs.test.outer");
            let _scope = job_scope("k");
            let _inner = span("obs.test.inner");
            STACK.with(|s| assert!(s.borrow().is_empty()));
            JOB.with(|j| assert!(j.borrow().is_none()));
        }
        let delta = metrics().snapshot().diff(&before);
        assert_eq!(delta.histogram("obs.test.outer").unwrap().count, 1);
        assert_eq!(delta.histogram("obs.test.inner").unwrap().count, 1);
    }

    #[test]
    fn job_scopes_nest_and_restore() {
        let _guard = crate::obs::test_sink_guard();
        let tmp = std::env::temp_dir()
            .join(format!("carbon3d-obs-scope-{}.trace.jsonl", std::process::id()));
        sink::install(&tmp, std::path::Path::new("test.jsonl"), None).unwrap();
        {
            let _a = job_scope("outer-job");
            JOB.with(|j| assert_eq!(j.borrow().as_deref(), Some("outer-job")));
            {
                let _b = job_scope("inner-job");
                JOB.with(|j| assert_eq!(j.borrow().as_deref(), Some("inner-job")));
            }
            JOB.with(|j| assert_eq!(j.borrow().as_deref(), Some("outer-job")));
        }
        JOB.with(|j| assert!(j.borrow().is_none()));
        sink::uninstall();
        let _ = std::fs::remove_file(&tmp);
    }
}
