//! Human-readable formatting for observability output.
//!
//! `human_time` is the single time-formatting path: trace reports, stderr
//! heartbeats, campaign report lines, and the bench harness all route
//! through it.

/// Format seconds in engineering units.
pub fn human_time(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3}s")
    } else if secs >= 1e-3 {
        format!("{:.3}ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.3}us", secs * 1e6)
    } else {
        format!("{:.1}ns", secs * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn human_time_units() {
        assert!(human_time(2.0).ends_with('s'));
        assert!(human_time(2e-3).ends_with("ms"));
        assert!(human_time(2e-6).ends_with("us"));
        assert!(human_time(2e-9).ends_with("ns"));
    }
}
