//! Layer-to-array mapper: delay-optimized dataflow scheduling (nn-dataflow
//! stand-in, extended for 3D memory-on-logic — paper §III-E).
//!
//! For every layer the mapper searches output-channel x output-pixel tilings
//! of the PE array, counts RF/SRAM/DRAM traffic under a weight-stationary
//! dataflow, and takes per-layer delay as the max of compute / SRAM / DRAM
//! pipelines (double-buffered overlap) plus a fixed launch overhead. The
//! 3D vertical links enter through `AccelConfig::sram_bw_words_per_cycle`.

use super::arch::{AccelConfig, LAYER_OVERHEAD_CYCLES};
use super::layer::{Layer, LayerKind, WORD_BYTES};
use super::workloads::Workload;

/// Mapping result for one layer.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerMapping {
    pub name: String,
    pub cycles: u64,
    pub compute_cycles: u64,
    pub sram_cycles: u64,
    pub dram_cycles: u64,
    /// PE-array utilization of the compute phase, 0..=1.
    pub utilization: f64,
    pub macs: u64,
    pub sram_words: u64,
    pub dram_bytes: u64,
}

/// Mapping result for a full network.
#[derive(Debug, Clone)]
pub struct NetworkMapping {
    pub workload: String,
    pub layers: Vec<LayerMapping>,
    pub total_cycles: u64,
}

impl NetworkMapping {
    /// End-to-end inference delay in seconds.
    pub fn delay_s(&self, cfg: &AccelConfig) -> f64 {
        self.total_cycles as f64 / cfg.freq_hz()
    }

    /// Frames per second.
    pub fn fps(&self, cfg: &AccelConfig) -> f64 {
        1.0 / self.delay_s(cfg)
    }

    /// MAC-array utilization aggregated over compute cycles.
    pub fn mean_utilization(&self) -> f64 {
        let total: u64 = self.layers.iter().map(|l| l.compute_cycles).sum();
        if total == 0 {
            return 0.0;
        }
        self.layers
            .iter()
            .map(|l| l.utilization * l.compute_cycles as f64)
            .sum::<f64>()
            / total as f64
    }
}

/// Reuse factor the per-PE register file supplies for SRAM traffic:
/// a weight parked in the RF serves all MACs of its output tile; activations
/// are broadcast. Bigger RFs park more weights -> fewer SRAM fetches.
fn rf_reuse_factor(rf_bytes: usize, kh: usize, kw: usize, in_c: usize) -> f64 {
    let slots = (rf_bytes / WORD_BYTES) as f64;
    // A PE needs kh*kw*tile_ic weights resident to avoid refetch; the
    // achievable reuse saturates at the filter footprint.
    let filter_words = (kh * kw * in_c) as f64;
    (slots / 2.0).clamp(1.0, filter_words.max(1.0)).min(256.0)
}

/// Map a single layer onto the array.
pub fn map_layer(layer: &Layer, cfg: &AccelConfig) -> LayerMapping {
    match layer.kind {
        LayerKind::Conv { in_c, out_c, kh, kw, .. } => {
            let (oh, ow, _) = layer.out_shape();
            map_gemm_like(
                &layer.name,
                cfg,
                oh * ow, // spatial work items
                out_c,   // output channels
                kh * kw * in_c,
                layer.weight_bytes() as u64,
                layer.ifmap_bytes() as u64,
                layer.ofmap_bytes() as u64,
                rf_reuse_factor(cfg.rf_bytes, kh, kw, in_c),
            )
        }
        LayerKind::Fc { in_f, out_f } => map_gemm_like(
            &layer.name,
            cfg,
            1,
            out_f,
            in_f,
            layer.weight_bytes() as u64,
            layer.ifmap_bytes() as u64,
            layer.ofmap_bytes() as u64,
            // FC weights have no reuse across a batch-1 inference.
            1.0,
        ),
        LayerKind::Pool { .. } | LayerKind::Eltwise { .. } => {
            // Memory-bound: stream ifmap in, ofmap out.
            let traffic_words = ((layer.ifmap_bytes() + layer.ofmap_bytes()) / WORD_BYTES) as u64;
            let sram_cycles =
                (traffic_words as f64 / cfg.sram_bw_words_per_cycle()).ceil() as u64;
            // Pool/eltwise operands usually stay on-chip; DRAM only if the
            // working set exceeds SRAM.
            let resident = layer.ifmap_bytes() + layer.ofmap_bytes();
            let dram_bytes =
                if resident > cfg.sram_bytes { (resident - cfg.sram_bytes) as u64 } else { 0 };
            let dram_cycles = (dram_bytes as f64 / cfg.dram_bytes_per_cycle()).ceil() as u64;
            let cycles = sram_cycles.max(dram_cycles) + LAYER_OVERHEAD_CYCLES;
            LayerMapping {
                name: layer.name.clone(),
                cycles,
                compute_cycles: 0,
                sram_cycles,
                dram_cycles,
                utilization: 0.0,
                macs: 0,
                sram_words: traffic_words,
                dram_bytes,
            }
        }
    }
}

/// Shared conv/FC mapping: `spatial` work items x `channels` outputs, each
/// output needing `depth` MACs.
#[allow(clippy::too_many_arguments)]
fn map_gemm_like(
    name: &str,
    cfg: &AccelConfig,
    spatial: usize,
    channels: usize,
    depth: usize,
    weight_bytes: u64,
    ifmap_bytes: u64,
    ofmap_bytes: u64,
    rf_reuse: f64,
) -> LayerMapping {
    let macs = (spatial * channels * depth) as u64;

    // --- compute: search the (channels->py, spatial->px) tiling and its
    // transpose, take the better utilization.
    let tiling = |wa: usize, wb: usize| -> u64 {
        // wa work mapped on px, wb on py.
        let ta = wa.div_ceil(cfg.px);
        let tb = wb.div_ceil(cfg.py);
        (ta * tb * depth) as u64
    };
    let compute_cycles = tiling(spatial, channels).min(tiling(channels, spatial)).max(1);
    let utilization = macs as f64 / (compute_cycles as f64 * cfg.n_pes() as f64);

    // --- SRAM->PE traffic: every MAC consumes a weight and an activation
    // word; RF reuse cuts weight traffic, spatial broadcast cuts activation
    // traffic (a fetched activation row feeds a whole PE row).
    let weight_words = macs as f64 / rf_reuse;
    let act_words = macs as f64 / (cfg.py as f64).max(1.0);
    let psum_words = (spatial * channels) as f64; // write-back: one word per output
    let sram_words = (weight_words + act_words + psum_words) as u64;
    let sram_cycles = (sram_words as f64 / cfg.sram_bw_words_per_cycle()).ceil() as u64;

    // --- DRAM traffic: weights stream once per output-channel tile pass;
    // if the layer working set exceeds SRAM, the ifmap is refetched per
    // weight tile (output-stationary tiling over channels).
    let working_set = weight_bytes + ifmap_bytes + ofmap_bytes;
    let refetches = if working_set as usize > cfg.sram_bytes {
        // number of channel tiles whose weights fit in half the SRAM
        (weight_bytes as f64 / (cfg.sram_bytes as f64 / 2.0)).ceil().max(1.0)
    } else {
        1.0
    };
    let dram_bytes = (weight_bytes as f64 + ifmap_bytes as f64 * refetches + ofmap_bytes as f64) as u64;
    let dram_cycles = (dram_bytes as f64 / cfg.dram_bytes_per_cycle()).ceil() as u64;

    let cycles = compute_cycles.max(sram_cycles).max(dram_cycles) + LAYER_OVERHEAD_CYCLES;
    LayerMapping {
        name: name.to_string(),
        cycles,
        compute_cycles,
        sram_cycles,
        dram_cycles,
        utilization,
        macs,
        sram_words,
        dram_bytes,
    }
}

/// Map every layer of a workload; delays add up (layer-by-layer execution,
/// as in the paper's latency-optimized nn-dataflow scheduling).
pub fn map_network(w: &Workload, cfg: &AccelConfig) -> NetworkMapping {
    let layers: Vec<LayerMapping> = w.layers.iter().map(|l| map_layer(l, cfg)).collect();
    let total_cycles = layers.iter().map(|l| l.cycles).sum();
    NetworkMapping { workload: w.name.clone(), layers, total_cycles }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::area::die::Integration;
    use crate::area::TechNode;
    use crate::approx::EXACT_ID;
    use crate::dataflow::workloads::workload;
    use crate::util::prop;

    fn cfg(px: usize, py: usize, integration: Integration) -> AccelConfig {
        AccelConfig {
            px,
            py,
            rf_bytes: 512,
            sram_bytes: 2 << 20,
            node: TechNode::N14,
            integration,
            mult_id: EXACT_ID,
        }
    }

    #[test]
    fn more_pes_reduce_delay_until_saturation() {
        let w = workload("vgg16").unwrap();
        let d16 = map_network(&w, &cfg(16, 16, Integration::ThreeD)).total_cycles;
        let d32 = map_network(&w, &cfg(32, 32, Integration::ThreeD)).total_cycles;
        assert!(d32 < d16, "{d32} !< {d16}");
        // Speedup bounded by PE ratio.
        assert!(d16 as f64 / d32 as f64 <= 4.05);
    }

    #[test]
    fn three_d_faster_than_2d_iso_resources() {
        // The paper's Fig. 3 claim: vertical integration wins on delay.
        let w = workload("vgg16").unwrap();
        let d3 = map_network(&w, &cfg(32, 32, Integration::ThreeD)).total_cycles;
        let d2 = map_network(&w, &cfg(32, 32, Integration::TwoD)).total_cycles;
        assert!(d3 < d2, "3D {d3} !< 2D {d2}");
    }

    #[test]
    fn vgg16_fps_plausible_range() {
        // 1024 PEs @ 940MHz on 15.5 GMACs: ideal ~62 fps; with util + mem
        // overheads expect O(10) fps — the paper's target band.
        let w = workload("vgg16").unwrap();
        let c = cfg(32, 32, Integration::ThreeD);
        let fps = map_network(&w, &c).fps(&c);
        assert!((5.0..70.0).contains(&fps), "fps {fps}");
    }

    #[test]
    fn utilization_in_unit_interval_all_layers() {
        let w = workload("resnet50").unwrap();
        let m = map_network(&w, &cfg(16, 16, Integration::ThreeD));
        for l in &m.layers {
            assert!((0.0..=1.0 + 1e-9).contains(&l.utilization), "{}: {}", l.name, l.utilization);
        }
    }

    #[test]
    fn compute_cycles_at_least_ideal() {
        let w = workload("densenet121").unwrap();
        let c = cfg(16, 16, Integration::ThreeD);
        let m = map_network(&w, &c);
        for l in m.layers.iter().filter(|l| l.macs > 0) {
            let ideal = l.macs.div_ceil(c.n_pes() as u64);
            assert!(l.compute_cycles >= ideal, "{}", l.name);
        }
    }

    #[test]
    fn bigger_rf_cuts_sram_traffic() {
        let w = workload("vgg16").unwrap();
        let mut small = cfg(16, 16, Integration::ThreeD);
        small.rf_bytes = 64;
        let mut big = small.clone();
        big.rf_bytes = 2048;
        let t_small: u64 = map_network(&w, &small).layers.iter().map(|l| l.sram_words).sum();
        let t_big: u64 = map_network(&w, &big).layers.iter().map(|l| l.sram_words).sum();
        assert!(t_big < t_small);
    }

    #[test]
    fn bigger_sram_cuts_dram_traffic() {
        let w = workload("vgg16").unwrap();
        let mut small = cfg(16, 16, Integration::ThreeD);
        small.sram_bytes = 256 << 10;
        let mut big = small.clone();
        big.sram_bytes = 8 << 20;
        let d_small: u64 = map_network(&w, &small).layers.iter().map(|l| l.dram_bytes).sum();
        let d_big: u64 = map_network(&w, &big).layers.iter().map(|l| l.dram_bytes).sum();
        assert!(d_big < d_small);
    }

    #[test]
    fn total_cycles_is_sum_of_layers() {
        let w = workload("tinycnn").unwrap();
        let m = map_network(&w, &cfg(8, 8, Integration::ThreeD));
        assert_eq!(m.total_cycles, m.layers.iter().map(|l| l.cycles).sum::<u64>());
    }

    #[test]
    fn delay_positive_and_finite_prop() {
        let w = workload("resnet50v2").unwrap();
        prop::check("mapper-sane", 30, |rng| {
            let c = AccelConfig {
                px: 1 << rng.range(2, 6),
                py: 1 << rng.range(2, 6),
                rf_bytes: 1 << rng.range(6, 12),
                sram_bytes: 1 << rng.range(17, 24),
                node: *rng.choice(&crate::area::node::ALL_NODES),
                integration: if rng.chance(0.5) { Integration::TwoD } else { Integration::ThreeD },
                mult_id: EXACT_ID,
            };
            let m = map_network(&w, &c);
            let d = m.delay_s(&c);
            assert!(d.is_finite() && d > 0.0);
            assert!(m.mean_utilization() <= 1.0 + 1e-9);
        });
    }

    #[test]
    fn fc_layers_are_dram_bound_on_big_arrays() {
        // VGG's fc6 (25088x4096 weights = 205MB) must be DRAM-bound.
        let w = workload("vgg16").unwrap();
        let c = cfg(32, 32, Integration::ThreeD);
        let m = map_network(&w, &c);
        let fc6 = m.layers.iter().find(|l| l.name == "fc6").unwrap();
        assert!(fc6.dram_cycles > fc6.compute_cycles);
    }
}
