//! Reproduce the paper's Figure 2: normalized inference delay and embodied
//! carbon across technology nodes (45/14/7nm) and accuracy-drop thresholds
//! (1/2/3%) for the five CNNs, GA-APPX-CDP vs the GA-CDP-EXACT baseline [6].
//!
//! Writes results/fig2.csv + results/fig2.txt and prints the table.
//!
//! Run: `cargo run --release --example fig2_repro [-- --quick]`

use carbon3d::approx::library;
use carbon3d::area::node::ALL_NODES;
use carbon3d::coordinator::fig2::{run_fig2, FIG2_DELTAS, FIG2_MODELS};
use carbon3d::ga::GaParams;
use carbon3d::util::{table, Table};

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let params = if quick {
        GaParams { population: 32, generations: 20, patience: 8, ..Default::default() }
    } else {
        GaParams::default()
    };
    let lib = library();
    let (r, secs) = carbon3d::util::timer::time_once(|| run_fig2(&lib, &FIG2_MODELS, params));
    println!("{}", r.render());

    // Per-node aggregates (the paper's headline "up to X%" values).
    let mut agg = Table::new(vec!["node", "delta", "mean_cut_%", "max_cut_%"]);
    for &node in &ALL_NODES {
        for &d in &FIG2_DELTAS {
            agg.row(vec![
                node.name().to_string(),
                format!("{d}%"),
                format!("{:.1}", r.mean_carbon_cut_pct(node, d)),
                format!(
                    "{:.1}",
                    r.cells
                        .iter()
                        .filter(|c| c.node == node && c.delta_pct == d)
                        .map(|c| (1.0 - c.norm_carbon) * 100.0)
                        .fold(f64::NEG_INFINITY, f64::max)
                ),
            ]);
        }
    }
    println!("{}", agg.render());
    println!("fig2 grid completed in {}", carbon3d::util::timer::human_time(secs));

    std::fs::create_dir_all("results")?;
    let mut csv = Table::new(vec![
        "node", "model", "delta_pct", "norm_delay", "norm_carbon", "mult",
    ]);
    for c in &r.cells {
        csv.row(vec![
            c.node.name().to_string(),
            c.model.clone(),
            format!("{}", c.delta_pct),
            table::fmt(c.norm_delay),
            table::fmt(c.norm_carbon),
            c.mult_name.clone(),
        ]);
    }
    std::fs::write("results/fig2.csv", csv.to_csv())?;
    std::fs::write("results/fig2.txt", r.render())?;
    println!("wrote results/fig2.csv, results/fig2.txt");
    Ok(())
}
