//! Baselines the paper compares against (§IV):
//!  - GA-CDP-EXACT: Byun et al. [6]-style CDP optimization of the 3D design
//!    *without* approximate computing (multiplier gene pinned to EXACT).
//!  - 2D-Exact / 3D-Exact / 3D-Appx: NVDLA-like fixed scalings, PE counts
//!    64..2048 in powers of two, buffers scaled per NVIDIA's published
//!    ratios [28].

use crate::accuracy::model::{feasible_multipliers, DEFAULT_K};
use crate::approx::{Multiplier, EXACT_ID};
use crate::area::die::Integration;
use crate::area::TechNode;
use crate::dataflow::arch::AccelConfig;
use crate::dataflow::workloads::Workload;
use crate::ga::fitness::{evaluate, Evaluation, FitnessCtx};
use crate::ga::{Chromosome, Ga, GaParams, GaResult, SearchSpace};

/// The four §IV-B design approaches.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Approach {
    TwoDExact,
    ThreeDExact,
    ThreeDAppx,
    GaAppxCdp,
}

impl Approach {
    pub fn name(&self) -> &'static str {
        match self {
            Approach::TwoDExact => "2D-Exact",
            Approach::ThreeDExact => "3D-Exact",
            Approach::ThreeDAppx => "3D-Appx",
            Approach::GaAppxCdp => "GA-APPX-CDP",
        }
    }

    pub fn integration(&self) -> Integration {
        match self {
            Approach::TwoDExact => Integration::TwoD,
            _ => Integration::ThreeD,
        }
    }
}

/// NVDLA-like configuration for a PE budget: square-ish array, local buffer
/// fixed at 512B/PE, global SRAM scaled with the MAC count (NVDLA's CBUF is
/// 512KB for the 2048-MAC full config -> 256B per MAC).
pub fn nvdla_like_config(
    n_pes: usize,
    node: TechNode,
    integration: Integration,
    mult_id: usize,
) -> AccelConfig {
    assert!(n_pes.is_power_of_two(), "NVDLA scaling uses power-of-two PE counts");
    let px = 1usize << (n_pes.trailing_zeros() / 2);
    let py = n_pes / px;
    AccelConfig {
        px,
        py,
        rf_bytes: 512,
        sram_bytes: (n_pes * 256).max(64 << 10),
        node,
        integration,
        mult_id,
    }
}

/// Evaluate an NVDLA-like sweep (64..=2048 PEs) for an approach.
/// For `ThreeDAppx`, the most area-efficient multiplier meeting δ=3% is used
/// (the paper's §IV-B setting).
pub fn sweep_nvdla(
    approach: Approach,
    workload: &Workload,
    node: TechNode,
    library: &[Multiplier],
) -> Vec<(AccelConfig, Evaluation)> {
    assert!(approach != Approach::GaAppxCdp, "GA points come from ga_appx_cdp_fps");
    let mult_id = match approach {
        Approach::ThreeDAppx => smallest_feasible_mult(library, workload, 3.0),
        _ => EXACT_ID,
    };
    let mut out = Vec::new();
    let mut n = 64usize;
    while n <= 2048 {
        let cfg = nvdla_like_config(n, node, approach.integration(), mult_id);
        let chrom = Chromosome {
            px: cfg.px,
            py: cfg.py,
            rf_bytes: cfg.rf_bytes,
            sram_bytes: cfg.sram_bytes,
            mult_id,
        };
        let eval = evaluate(&chrom, workload, node, approach.integration(), library, None);
        out.push((cfg, eval));
        n *= 2;
    }
    out
}

/// Most area-efficient multiplier whose predicted ΔA fits δ.
pub fn smallest_feasible_mult(library: &[Multiplier], workload: &Workload, delta_pct: f64) -> usize {
    let feasible = feasible_multipliers(library, workload, delta_pct, DEFAULT_K);
    feasible
        .into_iter()
        .min_by(|&a, &b| {
            library[a]
                .gates()
                .total_area_units()
                .partial_cmp(&library[b].gates().total_area_units())
                .unwrap()
        })
        .expect("feasible set is never empty (contains EXACT)")
}

/// The [6]-style baseline: same GA/space/objective, exact multiplier only.
pub fn ga_cdp_exact(
    workload: &Workload,
    node: TechNode,
    library: &[Multiplier],
    fps_floor: Option<f64>,
    params: GaParams,
) -> GaResult {
    let space = SearchSpace::standard(vec![EXACT_ID]);
    let mut ctx = FitnessCtx::new(workload, node, Integration::ThreeD, library, fps_floor);
    let mut r = Ga::new(space, params).run(&mut ctx);
    super::refine_to_min_carbon(&mut r, &ctx);
    r
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::library;
    use crate::dataflow::workloads::workload;

    #[test]
    fn nvdla_config_square_ish() {
        let c = nvdla_like_config(64, TechNode::N14, Integration::ThreeD, EXACT_ID);
        assert_eq!(c.px * c.py, 64);
        assert_eq!(c.px, 8);
        let c2 = nvdla_like_config(2048, TechNode::N14, Integration::ThreeD, EXACT_ID);
        assert_eq!(c2.px * c2.py, 2048);
        assert!(c2.px == 32 && c2.py == 64);
        assert_eq!(c2.sram_bytes, 2048 * 256);
    }

    #[test]
    fn sweep_covers_64_to_2048() {
        let lib = library();
        let w = workload("vgg16").unwrap();
        let pts = sweep_nvdla(Approach::ThreeDExact, &w, TechNode::N14, &lib);
        assert_eq!(pts.len(), 6); // 64,128,256,512,1024,2048
        // FPS grows with PE count.
        for pair in pts.windows(2) {
            assert!(pair[1].1.fps > pair[0].1.fps);
        }
    }

    #[test]
    fn three_d_exact_faster_but_dirtier_than_2d() {
        let lib = library();
        let w = workload("vgg16").unwrap();
        let p2 = sweep_nvdla(Approach::TwoDExact, &w, TechNode::N7, &lib);
        let p3 = sweep_nvdla(Approach::ThreeDExact, &w, TechNode::N7, &lib);
        for (a, b) in p2.iter().zip(&p3) {
            assert!(b.1.fps >= a.1.fps, "3D not faster at {} PEs", a.0.n_pes());
            assert!(
                b.1.carbon_per_mm2 > a.1.carbon_per_mm2,
                "3D not carbon-denser at {} PEs",
                a.0.n_pes()
            );
        }
    }

    #[test]
    fn appx_sweep_cuts_carbon_vs_exact_3d() {
        let lib = library();
        let w = workload("vgg16").unwrap();
        let pe = sweep_nvdla(Approach::ThreeDExact, &w, TechNode::N14, &lib);
        let pa = sweep_nvdla(Approach::ThreeDAppx, &w, TechNode::N14, &lib);
        for (e, a) in pe.iter().zip(&pa) {
            assert!(a.1.carbon_g < e.1.carbon_g, "at {} PEs", e.0.n_pes());
            assert_eq!(a.1.delay_s, e.1.delay_s); // same array & buffers
        }
    }

    #[test]
    fn smallest_feasible_is_not_exact_at_3pct() {
        let lib = library();
        let w = workload("vgg16").unwrap();
        let id = smallest_feasible_mult(&lib, &w, 3.0);
        assert_ne!(id, EXACT_ID, "3% should admit an approximate design");
        assert!(
            lib[id].gates().total_area_units() < lib[EXACT_ID].gates().total_area_units()
        );
    }

    #[test]
    #[should_panic]
    fn non_power_of_two_rejected() {
        nvdla_like_config(100, TechNode::N45, Integration::TwoD, EXACT_ID);
    }
}
