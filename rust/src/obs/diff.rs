//! `carbon3d trace diff`: phase-by-phase attribution of wall-clock and
//! counter deltas between two observability records (DESIGN.md §8.5).
//!
//! Both sides load through [`ObsRecord::load`], which accepts either a
//! trace sidecar (`<store>.trace.jsonl`, including `trace merge` output
//! — the folded final `metrics` line carries the campaign-wide totals)
//! or a bench `--json` artifact (`BENCH_campaign.json` /
//! `BENCH_eval.json`, which embed the same [`MetricsSnapshot`] delta
//! under a top-level `"metrics"` key) — so the CI bench trajectory files
//! double as diffable observability records.
//!
//! The comparison basis is the snapshot's phase histograms: per-phase
//! total/p50/p95 shifts, cache hit-rate drift, and queue-wait growth.
//! A phase counts as a regression under `--gate PCT` only when its
//! total grew past the gate *and* its p50 bucket moved — the 1-2-5
//! bucket ladder absorbs sub-bucket timing noise, and two identical
//! records trivially report zero regressions.

use std::collections::BTreeSet;
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::{obj, Json};
use crate::util::table::Table;

use super::fmt::human_time;
use super::metrics::{HistogramCounts, Merge, MetricsSnapshot};
use super::report::TraceReport;
use super::sink::hit_rate;

/// One observability record: where it came from, its counter snapshot,
/// and (for trace sidecars) the wall clock it covered.
#[derive(Debug, Clone)]
pub struct ObsRecord {
    /// Display path of the loaded file.
    pub source: String,
    /// Trace wall clock in µs; `None` for bench `--json` records.
    pub wall_us: Option<u64>,
    /// The counter/histogram snapshot the diff compares.
    pub metrics: MetricsSnapshot,
}

/// Per-phase timing stats lifted from a snapshot histogram.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PhaseStats {
    /// Spans recorded for this phase.
    pub count: u64,
    /// Summed duration, µs.
    pub total_us: u64,
    /// Median duration at bucket resolution, µs.
    pub p50: f64,
    /// 95th-percentile duration at bucket resolution, µs.
    pub p95: f64,
}

impl From<&HistogramCounts> for PhaseStats {
    fn from(h: &HistogramCounts) -> Self {
        Self { count: h.count, total_us: h.sum, p50: h.p50(), p95: h.p95() }
    }
}

/// One phase's old-vs-new comparison.
#[derive(Debug, Clone)]
pub struct PhaseDelta {
    /// Phase (span/histogram) name.
    pub name: String,
    /// Stats on the baseline side.
    pub old: PhaseStats,
    /// Stats on the candidate side.
    pub new: PhaseStats,
}

impl PhaseDelta {
    /// Total-time change in percent; `None` when the phase is new (no
    /// old baseline to compare against).
    pub fn total_pct(&self) -> Option<f64> {
        if self.old.total_us == 0 {
            return None;
        }
        Some(100.0 * (self.new.total_us as f64 - self.old.total_us as f64)
            / self.old.total_us as f64)
    }

    /// Regression under `gate_pct`: total grew past the gate AND the p50
    /// bucket moved up (bucket resolution absorbs timing noise).
    pub fn regressed(&self, gate_pct: f64) -> bool {
        match self.total_pct() {
            Some(pct) => pct > gate_pct && self.new.p50 > self.old.p50,
            None => false,
        }
    }
}

impl ObsRecord {
    /// Load a record, sniffing the format: a first line that is a trace
    /// `header` object means a JSONL sidecar; otherwise the whole file
    /// must be one bench `--json` document with a `"metrics"` key.
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let first = text.lines().next().unwrap_or("");
        let is_trace = Json::parse(first)
            .ok()
            .and_then(|v| v.get("kind").ok().map(|k| k == &Json::from("header")))
            .unwrap_or(false);
        if is_trace {
            let r = TraceReport::load(path)?;
            let metrics = r.final_metrics.clone().with_context(|| {
                format!("{}: trace carries no metrics line to diff", path.display())
            })?;
            return Ok(Self {
                source: path.display().to_string(),
                wall_us: Some(r.wall_us()),
                metrics,
            });
        }
        let doc = Json::parse(&text)
            .with_context(|| format!("{}: neither a trace sidecar nor JSON", path.display()))?;
        let metrics = MetricsSnapshot::from_json(
            doc.get("metrics")
                .with_context(|| format!("{}: no top-level \"metrics\" key", path.display()))?,
        )?;
        Ok(Self { source: path.display().to_string(), wall_us: None, metrics })
    }

    fn mapper_hit_rate(&self) -> f64 {
        let hits = self.metrics.counter("mapper_cache_hits");
        hit_rate(hits, hits + self.metrics.counter("mapper_cache_misses"))
    }

    fn service_hit_rate(&self) -> f64 {
        hit_rate(self.metrics.counter("service_cache_hits"), self.metrics.counter("service_served"))
    }

    fn memo_hit_rate(&self) -> f64 {
        let hits = self.metrics.counter("ga_memo_hits");
        hit_rate(hits, hits + self.metrics.counter("ga_memo_misses"))
    }
}

/// The old-vs-new comparison behind `carbon3d trace diff`.
#[derive(Debug, Clone)]
pub struct DiffReport {
    /// Baseline record.
    pub old: ObsRecord,
    /// Candidate record.
    pub new: ObsRecord,
}

impl DiffReport {
    /// Pair a baseline and a candidate record for comparison.
    pub fn new(old: ObsRecord, new: ObsRecord) -> Self {
        Self { old, new }
    }

    /// Old-vs-new stats for every phase histogram either side carries,
    /// sorted by new total desc then name (deterministic output order).
    pub fn phase_deltas(&self) -> Vec<PhaseDelta> {
        let names: BTreeSet<&String> =
            self.old.metrics.histograms.keys().chain(self.new.metrics.histograms.keys()).collect();
        let stats = |m: &MetricsSnapshot, name: &str| {
            m.histograms.get(name).map(PhaseStats::from).unwrap_or_default()
        };
        let mut out: Vec<PhaseDelta> = names
            .into_iter()
            .map(|name| PhaseDelta {
                name: name.clone(),
                old: stats(&self.old.metrics, name),
                new: stats(&self.new.metrics, name),
            })
            .collect();
        out.sort_by(|a, b| b.new.total_us.cmp(&a.new.total_us).then(a.name.cmp(&b.name)));
        out
    }

    /// Phases regressed past `gate_pct`, worst (largest growth) first.
    pub fn regressions(&self, gate_pct: f64) -> Vec<PhaseDelta> {
        let mut out: Vec<PhaseDelta> =
            self.phase_deltas().into_iter().filter(|d| d.regressed(gate_pct)).collect();
        out.sort_by(|a, b| {
            b.total_pct()
                .unwrap_or(0.0)
                .partial_cmp(&a.total_pct().unwrap_or(0.0))
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.name.cmp(&b.name))
        });
        out
    }

    /// Counter drift rows: `(name, old, new)` in displayable units.
    pub fn counter_drift(&self) -> Vec<(&'static str, f64, f64)> {
        vec![
            ("mapper cache hit rate", self.old.mapper_hit_rate(), self.new.mapper_hit_rate()),
            ("eval service hit rate", self.old.service_hit_rate(), self.new.service_hit_rate()),
            ("ga memo hit rate", self.old.memo_hit_rate(), self.new.memo_hit_rate()),
        ]
    }

    /// Reliability counter rows: `(name, old, new)` raw counts of the
    /// fault layer's activity — injected faults, IO retries, and retry
    /// exhaustions. Zero on both sides for a healthy run; surfacing them
    /// here makes a creeping retry rate visible in the same place as
    /// timing drift.
    pub fn reliability_drift(&self) -> Vec<(&'static str, u64, u64)> {
        ["fault.injected", "io_retries", "io_gave_up"]
            .into_iter()
            .map(|name| {
                (name, self.old.metrics.counter(name), self.new.metrics.counter(name))
            })
            .collect()
    }

    /// Machine-readable diff. `"metrics"` is `new - old` in the same
    /// serialized-snapshot format the benches embed, so diff outputs are
    /// themselves diffable records.
    pub fn to_json(&self, gate_pct: Option<f64>) -> Json {
        let phase_json = |d: &PhaseDelta| {
            obj([
                ("name", Json::from(d.name.as_str())),
                (
                    "old",
                    obj([
                        ("count", Json::from(d.old.count as f64)),
                        ("total_us", Json::from(d.old.total_us as f64)),
                        ("p50", Json::from(d.old.p50)),
                        ("p95", Json::from(d.old.p95)),
                    ]),
                ),
                (
                    "new",
                    obj([
                        ("count", Json::from(d.new.count as f64)),
                        ("total_us", Json::from(d.new.total_us as f64)),
                        ("p50", Json::from(d.new.p50)),
                        ("p95", Json::from(d.new.p95)),
                    ]),
                ),
                ("total_pct", d.total_pct().map(Json::from).unwrap_or(Json::Null)),
            ])
        };
        let side = |r: &ObsRecord| {
            obj([
                ("source", Json::from(r.source.as_str())),
                ("wall_us", r.wall_us.map(|w| Json::from(w as f64)).unwrap_or(Json::Null)),
            ])
        };
        let mut fields = vec![
            ("old", side(&self.old)),
            ("new", side(&self.new)),
            ("phases", Json::Arr(self.phase_deltas().iter().map(phase_json).collect())),
            (
                "counters",
                Json::Obj(
                    self.counter_drift()
                        .into_iter()
                        .map(|(name, old, new)| {
                            (
                                name.to_string(),
                                obj([("old", Json::from(old)), ("new", Json::from(new))]),
                            )
                        })
                        .collect(),
                ),
            ),
            (
                "reliability",
                Json::Obj(
                    self.reliability_drift()
                        .into_iter()
                        .map(|(name, old, new)| {
                            (
                                name.to_string(),
                                obj([
                                    ("old", Json::from(old as f64)),
                                    ("new", Json::from(new as f64)),
                                ]),
                            )
                        })
                        .collect(),
                ),
            ),
            ("metrics", self.new.metrics.diff(&self.old.metrics).to_json()),
        ];
        if let Some(gate) = gate_pct {
            fields.push(("gate_pct", Json::from(gate)));
            fields.push((
                "regressions",
                Json::Arr(
                    self.regressions(gate)
                        .iter()
                        .map(|d| Json::from(d.name.as_str()))
                        .collect(),
                ),
            ));
        }
        Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Render the human comparison: wall delta, phase table, counter
    /// drift lines.
    pub fn render(&self) -> String {
        let mut out = format!("trace diff: {} -> {}\n", self.old.source, self.new.source);
        if let (Some(a), Some(b)) = (self.old.wall_us, self.new.wall_us) {
            let pct = if a > 0 { 100.0 * (b as f64 - a as f64) / a as f64 } else { 0.0 };
            out.push_str(&format!(
                "wall clock {} -> {} ({:+.1}%)\n",
                human_time(a as f64 / 1e6),
                human_time(b as f64 / 1e6),
                pct
            ));
        }
        out.push('\n');
        let mut t = Table::new(vec![
            "phase", "old total", "new total", "delta%", "old p50", "new p50", "old p95", "new p95",
        ]);
        for d in self.phase_deltas() {
            t.row(vec![
                d.name.clone(),
                human_time(d.old.total_us as f64 / 1e6),
                human_time(d.new.total_us as f64 / 1e6),
                d.total_pct().map(|p| format!("{p:+.1}")).unwrap_or_else(|| "new".into()),
                human_time(d.old.p50 / 1e6),
                human_time(d.new.p50 / 1e6),
                human_time(d.old.p95 / 1e6),
                human_time(d.new.p95 / 1e6),
            ]);
        }
        out.push_str(&t.render());
        out.push('\n');
        for (name, old, new) in self.counter_drift() {
            out.push_str(&format!(
                "{name}: {:.1}% -> {:.1}% ({:+.1}pp)\n",
                old * 100.0,
                new * 100.0,
                (new - old) * 100.0
            ));
        }
        // Fault-layer activity only earns a line when either side saw any
        // — most diffs are between healthy runs.
        for (name, old, new) in self.reliability_drift() {
            if old > 0 || new > 0 {
                out.push_str(&format!(
                    "{name}: {old} -> {new} ({:+})\n",
                    new as i64 - old as i64
                ));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::metrics::Metrics;
    use std::path::PathBuf;

    fn tmp(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("carbon3d-diff-{tag}-{}.json", std::process::id()))
    }

    fn record(phase_values: &[(&'static str, &[u64])], mapper: (u64, u64)) -> ObsRecord {
        let m = Metrics::default();
        for (name, values) in phase_values {
            for &v in *values {
                m.record(name, v);
            }
        }
        m.incr("mapper_cache_hits", mapper.0);
        m.incr("mapper_cache_misses", mapper.1);
        ObsRecord { source: "test".into(), wall_us: None, metrics: m.snapshot() }
    }

    #[test]
    fn identical_records_report_zero_regressions() {
        let a = record(&[("ga.run", &[100, 200, 300])], (8, 2));
        let d = DiffReport::new(a.clone(), a);
        assert!(d.regressions(1.0).is_empty());
        let js = d.to_json(Some(1.0));
        assert_eq!(js.get("regressions").unwrap().as_arr().unwrap().len(), 0);
        // The embedded metrics delta is all zeros.
        let delta = js.get("metrics").unwrap();
        let hits =
            delta.get("counters").unwrap().get("mapper_cache_hits").unwrap().as_f64().unwrap();
        assert_eq!(hits, 0.0);
    }

    #[test]
    fn doubled_phase_is_attributed_as_the_culprit() {
        let old = record(&[("ga.run", &[100, 100]), ("mapper.search", &[50])], (5, 5));
        let new = record(&[("ga.run", &[1_000, 1_000]), ("mapper.search", &[50])], (2, 8));
        let d = DiffReport::new(old, new);
        let reg = d.regressions(10.0);
        assert_eq!(reg.len(), 1);
        assert_eq!(reg[0].name, "ga.run");
        assert!(reg[0].total_pct().unwrap() > 100.0);
        // Hit-rate drift surfaces in the counter rows.
        let drift = d.counter_drift();
        assert_eq!(drift[0].1, 0.5);
        assert_eq!(drift[0].2, 0.2);
        // Render paths don't panic and carry the table.
        assert!(d.render().contains("ga.run"));
    }

    #[test]
    fn sub_bucket_noise_does_not_regress() {
        // 100µs vs 101µs: same 1-2-5 bucket, p50 unchanged -> total grew
        // 1% but the gate only fires when the p50 bucket moves.
        let old = record(&[("service.eval", &[100, 100])], (0, 0));
        let new = record(&[("service.eval", &[101, 101])], (0, 0));
        let d = DiffReport::new(old, new);
        assert!(d.regressions(0.5).is_empty());
    }

    #[test]
    fn retry_counters_surface_as_raw_reliability_drift() {
        let old = record(&[("ga.run", &[100])], (1, 1));
        let m = Metrics::default();
        m.record("ga.run", 100);
        m.incr("io_retries", 3);
        m.incr("fault.injected", 1);
        let new = ObsRecord { source: "test".into(), wall_us: None, metrics: m.snapshot() };
        let d = DiffReport::new(old, new);
        let drift = d.reliability_drift();
        assert_eq!(drift.len(), 3);
        assert!(drift.contains(&("io_retries", 0, 3)), "{drift:?}");
        assert!(drift.contains(&("io_gave_up", 0, 0)), "{drift:?}");
        let rendered = d.render();
        assert!(rendered.contains("io_retries: 0 -> 3 (+3)"), "{rendered}");
        assert!(!rendered.contains("io_gave_up"), "zero rows stay hidden: {rendered}");
        let js = d.to_json(None);
        let rel = js.get("reliability").unwrap();
        assert_eq!(
            rel.get("io_retries").unwrap().get("new").unwrap().as_f64().unwrap(),
            3.0
        );
        assert_eq!(
            rel.get("io_gave_up").unwrap().get("new").unwrap().as_f64().unwrap(),
            0.0
        );
    }

    #[test]
    fn bench_json_documents_load_as_records() {
        let path = tmp("bench");
        let m = Metrics::default();
        m.record("ga.run", 500);
        m.incr("mapper_cache_hits", 3);
        let doc = crate::util::json::obj([
            ("bench", Json::from("campaign")),
            ("metrics", m.snapshot().to_json()),
        ]);
        std::fs::write(&path, doc.pretty(2)).unwrap();
        let r = ObsRecord::load(&path).unwrap();
        assert_eq!(r.wall_us, None);
        assert_eq!(r.metrics.counter("mapper_cache_hits"), 3);
        assert_eq!(r.metrics.histograms["ga.run"].count, 1);
        std::fs::remove_file(&path).unwrap();
    }
}
