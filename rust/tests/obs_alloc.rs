//! Property: with no trace sink installed, the span/metrics hot path
//! allocates nothing on the heap. This is the "near-zero-cost when off"
//! half of the observability contract (DESIGN.md §8) — counters and
//! histograms are pre-registered atomics, and disabled spans skip the
//! thread-local stack entirely.
//!
//! Lives in its own test binary because it swaps in a counting global
//! allocator, which would skew any other test sharing the process.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

struct CountingAlloc;

static ALLOCATIONS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

#[test]
fn disabled_tracing_hot_path_allocates_nothing() {
    assert!(!carbon3d::obs::enabled(), "no sink must be installed in this binary");

    // Warm-up: first use of each name registers its atomic in the registry
    // maps (one-time allocations by design), and the first span on this
    // thread initializes the thread-locals.
    {
        let _scope = carbon3d::obs::job_scope("warmup|job");
        let _span = carbon3d::obs::span("obs.alloc.test");
        carbon3d::obs::metrics().incr("obs_alloc_test_counter", 1);
        carbon3d::obs::metrics().gauge_set("obs_alloc_test_gauge", 1);
    }

    let before = ALLOCATIONS.load(Ordering::Relaxed);
    for i in 0..1000u64 {
        let _scope = carbon3d::obs::job_scope("steady|job");
        let _span = carbon3d::obs::span("obs.alloc.test");
        carbon3d::obs::metrics().incr("obs_alloc_test_counter", 1);
        carbon3d::obs::metrics().gauge_set("obs_alloc_test_gauge", i);
    }
    let after = ALLOCATIONS.load(Ordering::Relaxed);
    assert_eq!(
        after - before,
        0,
        "disabled spans/counters/gauges must not allocate on the steady state"
    );

    // Sanity: the instruments did record.
    let m = carbon3d::obs::metrics();
    assert_eq!(m.counter("obs_alloc_test_counter"), 1001);
    let snap = m.snapshot();
    let h = snap.histogram("obs.alloc.test").expect("span histogram fed");
    assert_eq!(h.count, 1001);
}
