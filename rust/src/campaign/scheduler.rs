//! Campaign scheduler: a pool of std-thread workers draining the job grid,
//! all sharing ONE `EvalService` so the multiplier-accuracy cache is
//! campaign-global. The δ-feasible sets of neighboring scenarios overlap
//! heavily, so after the first job primes the cache every later job's
//! accuracy table is pure cache hits — the dominant cross-run saving.
//!
//! Results flow through a reorder buffer and are committed to the JSONL
//! store in job-id order, which (with key-derived per-job GA seeds) makes
//! the store byte-identical for any worker count or interleaving.

use std::collections::{BTreeMap, HashMap};
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::time::Instant;

use anyhow::{anyhow, ensure, Context as _, Result};

use crate::accuracy::model::{
    calibrate_k, drop_pct_from_error, feasible_multipliers, predicted_drop_pct, DEFAULT_K,
    MEAN_SIG_PRODUCT,
};
use crate::accuracy::native::NativeEvaluator;
use crate::accuracy::AccuracyTable;
use crate::approx::{library, Multiplier, EXACT_ID};
use crate::coordinator::ga_appx_cdp_with_feasible;
use crate::dataflow::workloads::{workload, Workload};
use crate::ga::GaParams;
use crate::runtime::{Artifacts, EvalBackend, EvalClient, EvalService, NativeBackend, ServiceStats};
use crate::util::json::{obj, Json};

use super::spec::{integration_name, CampaignSpec, JobSpec};
use super::store::ResultStore;

/// Reference exact-path accuracy when no measured artifacts exist (the
/// trained tiny CNN's manifest value).
const SURROGATE_EXACT_ACC: f64 = 0.9355;

/// Accuracy backend for artifact-less environments: measures the effective
/// arithmetic error of the submitted LUT against exact significand products
/// and applies the calibrated ΔA drop model at tiny-CNN depth. Monotone in
/// the LUT's error, so feasibility ordering matches the measured path.
pub struct SurrogateBackend {
    exact_accuracy: f64,
    k: f64,
    tiny: Workload,
}

impl Default for SurrogateBackend {
    fn default() -> Self {
        Self {
            exact_accuracy: SURROGATE_EXACT_ACC,
            k: DEFAULT_K,
            tiny: workload("tinycnn").expect("tinycnn workload exists"),
        }
    }
}

impl EvalBackend for SurrogateBackend {
    fn accuracy_of_lut(&self, lut: &[f32]) -> Result<f64> {
        ensure!(lut.len() == 128 * 128, "LUT must be 128x128");
        let (mut mred, mut bias) = (0.0f64, 0.0f64);
        for i in 0..128usize {
            for j in 0..128usize {
                let exact = ((128 + i) * (128 + j)) as f64;
                let got = f64::from(lut[i * 128 + j]);
                mred += (got - exact).abs() / exact;
                bias += got - exact;
            }
        }
        let n = (128 * 128) as f64;
        let e_eff = mred / n + (bias / n).abs() / MEAN_SIG_PRODUCT;
        let drop_pct = drop_pct_from_error(e_eff, &self.tiny, self.k);
        Ok(self.exact_accuracy - drop_pct / 100.0)
    }
}

/// Start the campaign-global accuracy service: measured native evaluation
/// when artifacts are built, the surrogate error model otherwise. Returns
/// the service and the backend's name (for reporting).
pub fn start_service(artifacts_dir: &Path) -> Result<(EvalService, &'static str)> {
    if artifacts_dir.join("manifest.json").exists() {
        let artifacts = Artifacts::load(artifacts_dir)?;
        let native = NativeEvaluator::load(&artifacts)?;
        Ok((EvalService::start(NativeBackend(native)), "native"))
    } else {
        Ok((EvalService::start(SurrogateBackend::default()), "surrogate"))
    }
}

/// What a finished campaign reports.
#[derive(Debug, Clone, Copy)]
pub struct CampaignReport {
    pub jobs_total: usize,
    pub jobs_run: usize,
    /// Jobs skipped because the store already had their row (resume).
    pub jobs_skipped: usize,
    pub elapsed_s: f64,
    /// Eval-service counter deltas attributable to this campaign.
    pub stats: ServiceStats,
}

impl CampaignReport {
    pub fn jobs_per_sec(&self) -> f64 {
        if self.elapsed_s > 0.0 {
            self.jobs_run as f64 / self.elapsed_s
        } else {
            0.0
        }
    }

    pub fn line(&self) -> String {
        format!(
            "{} jobs ({} run, {} resumed) in {:.2}s = {:.2} jobs/s | eval service: \
             {} served, {} evaluated, {} cache hits, {} coalesced ({:.0}% hit rate)",
            self.jobs_total,
            self.jobs_run,
            self.jobs_skipped,
            self.elapsed_s,
            self.jobs_per_sec(),
            self.stats.served,
            self.stats.evaluated,
            self.stats.cache_hits,
            self.stats.coalesced,
            self.stats.hit_rate() * 100.0,
        )
    }
}

fn stats_delta(after: ServiceStats, before: ServiceStats) -> ServiceStats {
    ServiceStats {
        served: after.served - before.served,
        evaluated: after.evaluated - before.evaluated,
        cache_hits: after.cache_hits - before.cache_hits,
        coalesced: after.coalesced - before.coalesced,
    }
}

/// Drain the campaign grid with `workers` threads, committing one JSONL row
/// per job to `store` in job-id order. Jobs whose key is already in the
/// store are skipped (checkpoint/resume); everything else about the run is
/// deterministic in the campaign seed.
pub fn run_campaign(
    spec: &CampaignSpec,
    workers: usize,
    store: &mut ResultStore,
    service: &EvalService,
) -> Result<CampaignReport> {
    let jobs = spec.jobs();
    let pending: Vec<JobSpec> =
        jobs.iter().filter(|j| !store.contains(&j.key())).cloned().collect();
    let jobs_skipped = jobs.len() - pending.len();
    let lib = library();
    let mut workloads: HashMap<String, Workload> = HashMap::new();
    for m in &spec.models {
        workloads
            .insert(m.clone(), workload(m).ok_or_else(|| anyhow!("unknown model {m}"))?);
    }
    let tiny = workload("tinycnn").expect("tinycnn workload exists");

    let before = service.stats();
    let t0 = Instant::now();
    let n_workers = workers.max(1).min(pending.len().max(1));
    let next = AtomicUsize::new(0);
    let (tx, rx) = mpsc::channel::<Result<(usize, Json)>>();

    std::thread::scope(|scope| -> Result<()> {
        for _ in 0..n_workers {
            let tx = tx.clone();
            let client = service.client();
            let (pending, lib, workloads, tiny, next, ga) =
                (&pending, &lib, &workloads, &tiny, &next, spec.ga);
            scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= pending.len() {
                    break;
                }
                let job = &pending[i];
                let out = run_job(job, ga, lib, workloads, tiny, &client)
                    .with_context(|| format!("job {}", job.key()))
                    .map(|row| (job.id, row));
                if tx.send(out).is_err() {
                    break;
                }
            });
        }
        drop(tx);

        // Single writer: reorder results into job-id order so the store is
        // identical no matter how workers interleave.
        let expected: Vec<usize> = pending.iter().map(|j| j.id).collect();
        let mut buffer: BTreeMap<usize, Json> = BTreeMap::new();
        let mut cursor = 0usize;
        for msg in rx {
            let (id, row) = msg?;
            buffer.insert(id, row);
            while cursor < expected.len() {
                match buffer.remove(&expected[cursor]) {
                    Some(row) => {
                        store.append(row)?;
                        cursor += 1;
                    }
                    None => break,
                }
            }
        }
        ensure!(
            cursor == expected.len(),
            "campaign incomplete: committed {cursor} of {} pending jobs",
            expected.len()
        );
        Ok(())
    })?;

    Ok(CampaignReport {
        jobs_total: jobs.len(),
        jobs_run: pending.len(),
        jobs_skipped,
        elapsed_s: t0.elapsed().as_secs_f64(),
        stats: stats_delta(service.stats(), before),
    })
}

/// Execute one scenario: measured/surrogate accuracy table through the
/// shared service, δ-feasible set, GA-APPX-CDP run, result row.
fn run_job(
    job: &JobSpec,
    ga: GaParams,
    lib: &[Multiplier],
    workloads: &HashMap<String, Workload>,
    tiny: &Workload,
    client: &EvalClient,
) -> Result<Json> {
    let w = workloads
        .get(&job.model)
        .ok_or_else(|| anyhow!("workload {} not preloaded", job.model))?;

    // Accuracy table via the campaign-global service (cache-shared).
    let mult_refs: Vec<&Multiplier> = lib.iter().collect();
    let accs = client
        .eval_all(&mult_refs)
        .map_err(|e| anyhow!("accuracy service: {e}"))?;
    let mut table = AccuracyTable { exact: accs[EXACT_ID], ..Default::default() };
    for (m, &a) in lib.iter().zip(&accs) {
        table.accuracy.insert(m.id, a);
    }
    let k = calibrate_k(lib, tiny, &table);
    let feasible = feasible_multipliers(lib, w, job.delta_pct, k);
    ensure!(!feasible.is_empty(), "no multiplier satisfies δ={}%", job.delta_pct);
    let n_feasible = feasible.len();

    let params = GaParams { seed: job.seed, ..ga };
    let r = ga_appx_cdp_with_feasible(
        w,
        job.node,
        job.integration,
        lib,
        feasible,
        job.fps_floor,
        params,
    );

    let best = &r.best;
    let e = &r.best_eval;
    let mult = &lib[best.mult_id];
    Ok(obj([
        ("key", Json::from(job.key())),
        ("model", Json::from(job.model.clone())),
        ("node", Json::from(job.node.name())),
        ("integration", Json::from(integration_name(job.integration))),
        ("delta_pct", Json::from(job.delta_pct)),
        (
            "fps_floor",
            match job.fps_floor {
                Some(f) => Json::from(f),
                None => Json::Null,
            },
        ),
        ("seed", Json::from(format!("{:#018x}", job.seed))),
        ("px", Json::from(best.px)),
        ("py", Json::from(best.py)),
        ("rf_bytes", Json::from(best.rf_bytes)),
        ("sram_bytes", Json::from(best.sram_bytes)),
        ("mult_id", Json::from(best.mult_id)),
        ("mult", Json::from(mult.name())),
        ("carbon_g", Json::from(e.carbon_g)),
        ("delay_s", Json::from(e.delay_s)),
        ("fps", Json::from(e.fps)),
        ("cdp", Json::from(e.cdp)),
        ("carbon_per_mm2", Json::from(e.carbon_per_mm2)),
        ("silicon_mm2", Json::from(e.silicon_mm2)),
        ("feasible", Json::from(e.feasible)),
        ("drop_pct", Json::from(predicted_drop_pct(mult, w, k))),
        ("k", Json::from(k)),
        ("n_feasible", Json::from(n_feasible)),
        ("evaluations", Json::from(r.evaluations)),
        ("generations", Json::from(r.generations_run)),
    ]))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn surrogate_exact_lut_has_zero_drop() {
        let lib = library();
        let b = SurrogateBackend::default();
        let acc = b.accuracy_of_lut(&crate::approx::lut_f32(&lib[EXACT_ID])).unwrap();
        assert!((acc - SURROGATE_EXACT_ACC).abs() < 1e-12);
    }

    #[test]
    fn surrogate_orders_designs_by_error() {
        let lib = library();
        let b = SurrogateBackend::default();
        // A mild truncation should keep more accuracy than an aggressive one.
        let mild = lib.iter().find(|m| m.name() == "TRUNC1").unwrap();
        let harsh = lib.iter().find(|m| m.name() == "TRUNC5").unwrap();
        let a_mild = b.accuracy_of_lut(&crate::approx::lut_f32(mild)).unwrap();
        let a_harsh = b.accuracy_of_lut(&crate::approx::lut_f32(harsh)).unwrap();
        assert!(a_mild > a_harsh, "{a_mild} !> {a_harsh}");
    }

    #[test]
    fn surrogate_rejects_bad_lut() {
        assert!(SurrogateBackend::default().accuracy_of_lut(&[1.0; 7]).is_err());
    }

    #[test]
    fn report_line_mentions_throughput_and_hits() {
        let r = CampaignReport {
            jobs_total: 10,
            jobs_run: 8,
            jobs_skipped: 2,
            elapsed_s: 4.0,
            stats: ServiceStats { served: 100, evaluated: 20, cache_hits: 70, coalesced: 10 },
        };
        assert!((r.jobs_per_sec() - 2.0).abs() < 1e-12);
        let line = r.line();
        assert!(line.contains("2.00 jobs/s"), "{line}");
        assert!(line.contains("80% hit rate"), "{line}");
    }
}
