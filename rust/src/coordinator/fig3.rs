//! Figure 3 pipeline: embodied-carbon efficiency (gCO2/mm^2) vs performance
//! (FPS) for VGG16 across nodes; 2D-Exact / 3D-Exact / 3D-Appx NVDLA-like
//! sweeps (64..2048 PEs) plus GA-APPX-CDP points at the paper's FPS targets.

use crate::approx::Multiplier;
use crate::area::node::ALL_NODES;
use crate::area::TechNode;
use crate::dataflow::workloads::workload;
use crate::ga::GaParams;
use crate::util::{table, Table};

use super::baselines::{sweep_nvdla, Approach};
use super::ga_appx_cdp;

/// One point in Fig. 3's scatter.
#[derive(Debug, Clone)]
pub struct Fig3Point {
    pub node: TechNode,
    pub approach: Approach,
    pub n_pes: usize,
    pub fps: f64,
    pub carbon_per_mm2: f64,
    pub carbon_g: f64,
    pub feasible: bool,
    /// FPS target for GA points (None for sweep points).
    pub fps_target: Option<f64>,
}

/// Full Fig. 3 data.
#[derive(Debug, Clone)]
pub struct Fig3Result {
    pub points: Vec<Fig3Point>,
}

/// The paper's FPS targets (§IV-B), applied per node's reachable band.
pub const FPS_TARGETS: [f64; 5] = [10.0, 15.0, 20.0, 30.0, 40.0];

/// Run Fig. 3 for a model (the paper shows VGG16).
pub fn run_fig3(library: &[Multiplier], model: &str, params: GaParams) -> Fig3Result {
    let w = workload(model).unwrap_or_else(|| panic!("unknown workload {model}"));
    let mut points = Vec::new();
    for &node in &ALL_NODES {
        for approach in [Approach::TwoDExact, Approach::ThreeDExact, Approach::ThreeDAppx] {
            for (cfg, eval) in sweep_nvdla(approach, &w, node, library) {
                points.push(Fig3Point {
                    node,
                    approach,
                    n_pes: cfg.n_pes(),
                    fps: eval.fps,
                    carbon_per_mm2: eval.carbon_per_mm2,
                    carbon_g: eval.carbon_g,
                    feasible: true,
                    fps_target: None,
                });
            }
        }
        // GA-APPX-CDP at each FPS target (δ = 3%, the §IV-B setting).
        for (i, &target) in FPS_TARGETS.iter().enumerate() {
            let cell_params = GaParams {
                seed: params.seed.wrapping_add(node as u64 * 100 + i as u64),
                ..params
            };
            let r = ga_appx_cdp(&w, node, library, 3.0, Some(target), cell_params);
            points.push(Fig3Point {
                node,
                approach: Approach::GaAppxCdp,
                n_pes: r.best.px * r.best.py,
                fps: r.best_eval.fps,
                carbon_per_mm2: r.best_eval.carbon_per_mm2,
                carbon_g: r.best_eval.carbon_g,
                feasible: r.best_eval.feasible,
                fps_target: Some(target),
            });
        }
    }
    Fig3Result { points }
}

impl Fig3Result {
    pub fn render(&self) -> String {
        let mut t = Table::new(vec![
            "node", "approach", "PEs", "fps", "gCO2/mm2", "gCO2", "fps_target", "feasible",
        ]);
        for p in &self.points {
            t.row(vec![
                p.node.name().to_string(),
                p.approach.name().to_string(),
                p.n_pes.to_string(),
                table::fmt(p.fps),
                table::fmt(p.carbon_per_mm2),
                table::fmt(p.carbon_g),
                p.fps_target.map(|f| format!("{f}")).unwrap_or_else(|| "-".into()),
                if p.feasible { "y".into() } else { "VIOLATED".to_string() },
            ]);
        }
        t.render()
    }

    /// Sweep series for (node, approach), sorted by FPS.
    pub fn series(&self, node: TechNode, approach: Approach) -> Vec<&Fig3Point> {
        let mut v: Vec<&Fig3Point> = self
            .points
            .iter()
            .filter(|p| p.node == node && p.approach == approach)
            .collect();
        v.sort_by(|a, b| a.fps.partial_cmp(&b.fps).unwrap());
        v
    }

    /// Smallest-carbon point of an approach meeting an FPS target at a node
    /// (for the headline §IV-B comparisons).
    pub fn best_meeting_fps(
        &self,
        node: TechNode,
        approach: Approach,
        fps: f64,
    ) -> Option<&Fig3Point> {
        self.points
            .iter()
            .filter(|p| p.node == node && p.approach == approach && p.fps >= fps && p.feasible)
            .min_by(|a, b| a.carbon_g.partial_cmp(&b.carbon_g).unwrap())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::library;

    fn quick_params() -> GaParams {
        GaParams { population: 20, generations: 12, patience: 6, seed: 7, ..Default::default() }
    }

    #[test]
    fn fig3_point_counts() {
        let lib = library();
        let r = run_fig3(&lib, "vgg16", quick_params());
        // 3 nodes x (3 approaches x 6 sweep points + 5 GA points)
        assert_eq!(r.points.len(), 3 * (3 * 6 + 5));
    }

    #[test]
    fn three_d_dominates_2d_on_fps_in_sweeps() {
        let lib = library();
        let r = run_fig3(&lib, "vgg16", quick_params());
        for &node in &ALL_NODES {
            let s2 = r.series(node, Approach::TwoDExact);
            let s3 = r.series(node, Approach::ThreeDExact);
            for (a, b) in s2.iter().zip(&s3) {
                assert!(b.fps >= a.fps, "{}: {} PEs", node.name(), a.n_pes);
            }
        }
    }

    #[test]
    fn appx_3d_lowers_total_carbon_and_mean_density_vs_exact_3d() {
        // Approximate multipliers cut total carbon at every sweep point.
        // Carbon *density* (gCO2/mm^2) drops on geomean but not necessarily
        // pointwise: when the logic die sets the footprint, shrinking it
        // shrinks the package (denominator) too.
        let lib = library();
        let r = run_fig3(&lib, "vgg16", quick_params());
        for &node in &ALL_NODES {
            let se = r.series(node, Approach::ThreeDExact);
            let sa = r.series(node, Approach::ThreeDAppx);
            let mut dens_e = Vec::new();
            let mut dens_a = Vec::new();
            for (e, a) in se.iter().zip(&sa) {
                assert!(a.carbon_g < e.carbon_g, "{} {} PEs", node.name(), e.n_pes);
                dens_e.push(e.carbon_per_mm2);
                dens_a.push(a.carbon_per_mm2);
            }
            let ge = crate::util::stats::geomean(&dens_e);
            let ga = crate::util::stats::geomean(&dens_a);
            assert!(ga < ge * 1.001, "{}: appx density {ga} !< exact {ge}", node.name());
        }
    }
}
