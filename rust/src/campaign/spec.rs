//! Campaign grid definition: the cross product of
//! {workload} x {TechNode} x {Integration} x {δ} x {optional FPS floor},
//! flattened into a deterministic job list.
//!
//! Per-job seeds derive from the campaign seed *and the job key* (not the
//! job index), so results are reproducible regardless of worker
//! interleaving, and adding scenarios to a grid never reshuffles the seeds
//! of the scenarios already present.

use anyhow::{bail, Result};

use crate::area::die::Integration;
use crate::area::node::ALL_NODES;
use crate::area::TechNode;
use crate::carbon::operational::Deployment;
use crate::ga::{GaParams, Objective};

/// What a campaign optimizes per scenario. A thin, nameable layer over
/// [`crate::ga::Objective`]: the CLI and the job keys speak these names,
/// the job context combines them with the campaign's [`Deployment`] into
/// the fitness-level objective it hands the GA.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum CampaignObjective {
    /// The paper's objective: embodied carbon x task delay.
    #[default]
    EmbodiedCdp,
    /// Lifetime operational carbon only.
    Operational,
    /// (embodied + lifetime operational carbon) x task delay.
    LifetimeCdp,
}

impl CampaignObjective {
    /// Stable name (CLI flag values, job keys, result rows).
    pub fn name(&self) -> &'static str {
        match self {
            CampaignObjective::EmbodiedCdp => "embodied-cdp",
            CampaignObjective::Operational => "operational",
            CampaignObjective::LifetimeCdp => "lifetime-cdp",
        }
    }

    pub fn from_name(s: &str) -> Option<Self> {
        match s {
            "embodied-cdp" | "embodied" | "cdp" => Some(CampaignObjective::EmbodiedCdp),
            "operational" | "op" => Some(CampaignObjective::Operational),
            "lifetime-cdp" | "lifetime" => Some(CampaignObjective::LifetimeCdp),
            _ => None,
        }
    }

    /// Combine with a deployment into the fitness-level objective. The
    /// deployment travels along even for the embodied objective, so every
    /// row's reported lifetime fields reflect the campaign's `--ipd`/
    /// `--lifetime-years`/`--grid-gco2-kwh` flags whatever the objective.
    pub fn to_fitness(&self, deployment: Deployment) -> Objective {
        match self {
            CampaignObjective::EmbodiedCdp => Objective::EmbodiedCdp(deployment),
            CampaignObjective::Operational => Objective::OperationalCarbon(deployment),
            CampaignObjective::LifetimeCdp => Objective::LifetimeCdp(deployment),
        }
    }

    /// Which carbon metric spans the campaign's Pareto-archive axis.
    pub fn carbon_axis(&self) -> crate::campaign::pareto::CarbonAxis {
        match self {
            CampaignObjective::EmbodiedCdp => crate::campaign::pareto::CarbonAxis::Embodied,
            CampaignObjective::Operational | CampaignObjective::LifetimeCdp => {
                crate::campaign::pareto::CarbonAxis::Lifetime
            }
        }
    }
}

/// How a campaign walks its grid.
///
/// `Exhaustive` is the legacy mode: every job in ascending analytic-bound
/// order. `Adaptive` re-ranks the remaining grid in deterministic batches
/// by expected improvement over the committed front, using the learned
/// cost surrogate ([`crate::campaign::surrogate`]) to tighten bounds and
/// prune — the propose → evaluate → update loop that makes huge grids
/// tractable. The batch size is part of the determinism contract (it is
/// recorded in the store header and must match on resume): batches, not
/// worker counts, fix where the surrogate refits, so the committed bytes
/// are identical for any worker count or resume boundary.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SamplerMode {
    /// Full grid in ascending analytic-bound order.
    #[default]
    Exhaustive,
    /// Surrogate-guided propose → evaluate → update batches of this size.
    Adaptive { batch: usize },
}

impl SamplerMode {
    /// Stable name (store header, CLI flag values, banners).
    pub fn name(&self) -> &'static str {
        match self {
            SamplerMode::Exhaustive => "exhaustive",
            SamplerMode::Adaptive { .. } => "adaptive",
        }
    }

    /// The batch size, for adaptive mode.
    pub fn batch(&self) -> Option<usize> {
        match self {
            SamplerMode::Exhaustive => None,
            SamplerMode::Adaptive { batch } => Some(*batch),
        }
    }
}

/// Human/stable name for an integration style (used in job keys and rows).
pub fn integration_name(i: Integration) -> &'static str {
    match i {
        Integration::TwoD => "2D",
        Integration::ThreeD => "3D",
    }
}

pub fn integration_from_name(s: &str) -> Option<Integration> {
    match s {
        "2d" | "2D" | "twod" => Some(Integration::TwoD),
        "3d" | "3D" | "threed" => Some(Integration::ThreeD),
        _ => None,
    }
}

/// A full DSE campaign: the scenario grid plus shared GA hyperparameters
/// and the campaign seed all per-job seeds derive from.
#[derive(Debug, Clone)]
pub struct CampaignSpec {
    pub models: Vec<String>,
    pub nodes: Vec<TechNode>,
    pub integrations: Vec<Integration>,
    /// Accuracy budgets δ in percentage points.
    pub deltas: Vec<f64>,
    /// FPS floors; `None` = unconstrained. One job per entry.
    pub fps_floors: Vec<Option<f64>>,
    pub ga: GaParams,
    pub seed: u64,
    /// What each scenario's search minimizes.
    pub objective: CampaignObjective,
    /// Deployment the lifetime objectives account operational carbon under.
    pub deployment: Deployment,
    /// Skip jobs whose optimistic objective bound provably cannot beat the
    /// best committed objective value in their scenario family
    /// (deterministic; trades per-scenario grid completeness for speed —
    /// see `source::prune_reason` for the exact semantics).
    pub prune: bool,
    /// How the grid is walked (exhaustive schedule or surrogate-guided
    /// adaptive batches).
    pub sampler: SamplerMode,
}

impl CampaignSpec {
    /// A grid over the given models/nodes/deltas: 3D integration, no FPS
    /// floor, default GA budget.
    pub fn new(models: Vec<String>, nodes: Vec<TechNode>, deltas: Vec<f64>) -> Self {
        Self {
            models,
            nodes,
            integrations: vec![Integration::ThreeD],
            deltas,
            fps_floors: vec![None],
            ga: GaParams::default(),
            seed: 0xCA4B07,
            objective: CampaignObjective::default(),
            deployment: Deployment::default(),
            prune: true,
            sampler: SamplerMode::Exhaustive,
        }
    }

    /// The paper's full scenario grid (five CNNs x three nodes x δ=1/2/3%).
    pub fn paper_grid() -> Self {
        Self::new(
            crate::coordinator::fig2::FIG2_MODELS.iter().map(|s| s.to_string()).collect(),
            ALL_NODES.to_vec(),
            vec![1.0, 2.0, 3.0],
        )
    }

    /// Grid size.
    pub fn n_jobs(&self) -> usize {
        self.models.len()
            * self.nodes.len()
            * self.integrations.len()
            * self.deltas.len()
            * self.fps_floors.len()
    }

    /// Reject grids with duplicate entries on any axis — a duplicated value
    /// would enumerate the same scenario twice, then hit a duplicate-key
    /// store error at the second commit. Numeric axes are compared in the
    /// 3-decimal form [`JobSpec::key`] encodes, so near-duplicates that
    /// would collide in the store are caught too; the error names the
    /// duplicate.
    pub fn validate(&self) -> Result<()> {
        fn dup_at<T: PartialEq>(vals: &[T]) -> Option<usize> {
            (1..vals.len()).find(|&i| vals[..i].contains(&vals[i]))
        }
        if let SamplerMode::Adaptive { batch } = self.sampler {
            if batch == 0 {
                bail!("adaptive sampler batch size must be >= 1");
            }
        }
        if let Some(i) = dup_at(&self.models) {
            bail!("duplicate model {:?} in campaign grid", self.models[i]);
        }
        if let Some(i) = dup_at(&self.nodes) {
            bail!("duplicate node {:?} in campaign grid", self.nodes[i].name());
        }
        if let Some(i) = dup_at(&self.integrations) {
            bail!(
                "duplicate integration {:?} in campaign grid",
                integration_name(self.integrations[i])
            );
        }
        let delta_keys: Vec<String> = self.deltas.iter().map(|d| format!("{d:.3}")).collect();
        if let Some(i) = dup_at(&delta_keys) {
            bail!(
                "duplicate δ={}% in campaign grid (δ values are identified to 3 decimals \
                 in job keys)",
                self.deltas[i]
            );
        }
        let fps_keys: Vec<Option<String>> =
            self.fps_floors.iter().map(|f| f.map(|v| format!("{v:.3}"))).collect();
        if let Some(i) = dup_at(&fps_keys) {
            match self.fps_floors[i] {
                Some(f) => bail!(
                    "duplicate fps floor {f} in campaign grid (fps floors are identified \
                     to 3 decimals in job keys)"
                ),
                None => bail!("duplicate unconstrained fps entry in campaign grid"),
            }
        }
        Ok(())
    }

    /// Flatten the grid into jobs, in deterministic model-major order.
    pub fn jobs(&self) -> Vec<JobSpec> {
        let mut out = Vec::with_capacity(self.n_jobs());
        for model in &self.models {
            for &node in &self.nodes {
                for &integration in &self.integrations {
                    for &delta_pct in &self.deltas {
                        for &fps_floor in &self.fps_floors {
                            let mut job = JobSpec {
                                id: out.len(),
                                model: model.clone(),
                                node,
                                integration,
                                delta_pct,
                                fps_floor,
                                objective: self.objective,
                                seed: 0,
                            };
                            job.seed = job_seed(self.seed, &job.key());
                            out.push(job);
                        }
                    }
                }
            }
        }
        out
    }
}

/// One scenario of the campaign grid.
#[derive(Debug, Clone, PartialEq)]
pub struct JobSpec {
    /// Position in the flattened grid.
    pub id: usize,
    pub model: String,
    pub node: TechNode,
    pub integration: Integration,
    pub delta_pct: f64,
    pub fps_floor: Option<f64>,
    /// What this scenario's search minimizes (from the campaign spec).
    pub objective: CampaignObjective,
    /// GA seed, derived from campaign seed + job key.
    pub seed: u64,
}

impl JobSpec {
    /// Stable identity of the scenario (checkpoint/resume matches on this).
    /// Non-default objectives are part of the identity, so a store can
    /// never silently resume a lifetime campaign with embodied rows (or
    /// vice versa); the default keeps the legacy key format so pre-existing
    /// stores stay resumable. Deployment knobs are deliberately *not* in
    /// the key — like GA hyperparameters, keeping them consistent across a
    /// resumed campaign is the caller's contract.
    pub fn key(&self) -> String {
        let fps = match self.fps_floor {
            Some(f) => format!("{f:.3}"),
            None => "-".to_string(),
        };
        let obj = match self.objective {
            CampaignObjective::EmbodiedCdp => String::new(),
            other => format!("/obj={}", other.name()),
        };
        format!(
            "{}@{}/{}/d{:.3}/fps{}{}",
            self.model,
            self.node.name(),
            integration_name(self.integration),
            self.delta_pct,
            fps,
            obj
        )
    }

    /// Family identity: scenarios that differ only in δ / FPS floor. The
    /// prune bound compares a job against the best committed result in its
    /// family ("the archive's current front", projected on the objective).
    pub fn family(&self) -> String {
        family_of(
            &self.model,
            self.node.name(),
            integration_name(self.integration),
            self.objective.name(),
        )
    }
}

/// The family string — ONE definition shared by [`JobSpec::family`] and
/// the commit pipeline's row parsing, so the two can never drift apart.
pub(crate) fn family_of(model: &str, node: &str, integration: &str, objective: &str) -> String {
    format!("{model}@{node}/{integration}/{objective}")
}

/// FNV-1a 64-bit hash of a byte string (also keys lease-file names and
/// shard ownership — see `campaign::lease` / `campaign::source`).
pub(crate) fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// splitmix64 finalizer — decorrelates nearby inputs (also the adaptive
/// sampler's seed-keyed tie-break, see `campaign::exec::adaptive`).
pub(crate) fn splitmix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Deterministic per-job GA seed.
pub fn job_seed(campaign_seed: u64, key: &str) -> u64 {
    splitmix64(campaign_seed ^ fnv1a64(key.as_bytes()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> CampaignSpec {
        CampaignSpec::new(
            vec!["vgg16".into(), "resnet50".into()],
            vec![TechNode::N45, TechNode::N7],
            vec![1.0, 3.0],
        )
    }

    #[test]
    fn grid_size_is_cross_product() {
        let s = small();
        assert_eq!(s.n_jobs(), 2 * 2 * 2);
        assert_eq!(s.jobs().len(), s.n_jobs());
    }

    #[test]
    fn keys_unique_and_ids_sequential() {
        let jobs = small().jobs();
        let mut keys: Vec<String> = jobs.iter().map(|j| j.key()).collect();
        keys.sort();
        keys.dedup();
        assert_eq!(keys.len(), jobs.len());
        for (i, j) in jobs.iter().enumerate() {
            assert_eq!(j.id, i);
        }
    }

    #[test]
    fn seeds_depend_on_key_not_index() {
        let s = small();
        let jobs = s.jobs();
        // Growing the grid must not change seeds of pre-existing scenarios.
        let mut bigger = s.clone();
        bigger.models.insert(0, "densenet121".to_string());
        let grown = bigger.jobs();
        for j in &jobs {
            let same = grown.iter().find(|g| g.key() == j.key()).unwrap();
            assert_eq!(same.seed, j.seed, "{}", j.key());
            assert_ne!(same.id, j.id); // ids shifted, seeds did not
        }
    }

    #[test]
    fn seeds_differ_across_jobs_and_campaign_seeds() {
        let s = small();
        let jobs = s.jobs();
        let mut seeds: Vec<u64> = jobs.iter().map(|j| j.seed).collect();
        seeds.sort_unstable();
        seeds.dedup();
        assert_eq!(seeds.len(), jobs.len(), "per-job seed collision");
        let mut reseeded = s.clone();
        reseeded.seed ^= 0xDEAD_BEEF;
        assert_ne!(reseeded.jobs()[0].seed, jobs[0].seed);
    }

    #[test]
    fn paper_grid_is_at_least_45_jobs() {
        assert_eq!(CampaignSpec::paper_grid().n_jobs(), 5 * 3 * 3);
    }

    #[test]
    fn validate_accepts_the_paper_grid() {
        assert!(small().validate().is_ok());
        assert!(CampaignSpec::paper_grid().validate().is_ok());
        // Duplicate-axis rejection (including 3-decimal key-encoding
        // near-duplicates) is covered in tests/integration.rs: validation
        // is part of the public CLI contract.
    }

    #[test]
    fn sampler_mode_names_batch_and_validation() {
        assert_eq!(SamplerMode::Exhaustive.name(), "exhaustive");
        assert_eq!(SamplerMode::Adaptive { batch: 6 }.name(), "adaptive");
        assert_eq!(SamplerMode::Exhaustive.batch(), None);
        assert_eq!(SamplerMode::Adaptive { batch: 6 }.batch(), Some(6));
        let mut s = small();
        s.sampler = SamplerMode::Adaptive { batch: 0 };
        assert!(s.validate().is_err());
        s.sampler = SamplerMode::Adaptive { batch: 4 };
        assert!(s.validate().is_ok());
        // The sampler never touches job identity: keys and seeds are the
        // same whatever walks the grid, which is what lets `--explain-prune`
        // and the front tooling reason about stores from either mode.
        let keys = |spec: &CampaignSpec| -> Vec<(String, u64)> {
            spec.jobs().iter().map(|j| (j.key(), j.seed)).collect()
        };
        assert_eq!(keys(&s), keys(&small()));
    }

    #[test]
    fn integration_names_roundtrip() {
        for i in [Integration::TwoD, Integration::ThreeD] {
            assert_eq!(integration_from_name(integration_name(i)), Some(i));
        }
        assert_eq!(integration_from_name("4d"), None);
    }

    #[test]
    fn objective_names_roundtrip() {
        for o in [
            CampaignObjective::EmbodiedCdp,
            CampaignObjective::Operational,
            CampaignObjective::LifetimeCdp,
        ] {
            assert_eq!(CampaignObjective::from_name(o.name()), Some(o));
        }
        assert_eq!(CampaignObjective::from_name("speed"), None);
    }

    #[test]
    fn default_objective_keeps_legacy_keys_and_seeds() {
        // Embodied (default) keys must not mention the objective, so stores
        // written before objectives existed stay resumable and the seeds
        // derived from keys stay put.
        let jobs = small().jobs();
        assert_eq!(jobs[0].key(), "vgg16@45nm/3D/d1.000/fps-");
        for j in &jobs {
            assert!(!j.key().contains("obj="), "{}", j.key());
        }
    }

    #[test]
    fn non_default_objective_is_part_of_job_identity() {
        let mut s = small();
        let embodied = s.jobs();
        s.objective = CampaignObjective::LifetimeCdp;
        let lifetime = s.jobs();
        for (e, l) in embodied.iter().zip(&lifetime) {
            assert!(l.key().ends_with("/obj=lifetime-cdp"), "{}", l.key());
            assert_ne!(e.key(), l.key());
            // Different key -> different derived GA seed: the two
            // objectives explore independently even at the same scenario.
            assert_ne!(e.seed, l.seed, "{}", e.key());
            // But the family differs only by objective tag.
            assert_ne!(e.family(), l.family());
        }
    }

    #[test]
    fn family_groups_deltas_and_fps_only() {
        let mut s = small();
        s.fps_floors = vec![None, Some(30.0)];
        let jobs = s.jobs();
        let mut families: Vec<String> = jobs.iter().map(|j| j.family()).collect();
        families.sort();
        families.dedup();
        // 2 models x 2 nodes x 1 integration: δ and fps collapse.
        assert_eq!(families.len(), 4);
    }
}
