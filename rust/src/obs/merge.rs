//! `carbon3d trace merge`: fold N shard trace sidecars into one unified
//! `carbon3d-trace/1` stream (DESIGN.md §8.5).
//!
//! Each input is strictly validated first ([`TraceReport::load`]), then
//! its lines are rewritten onto one time base: every `t_us` offset is
//! shifted by the input's wall-clock epoch distance from the earliest
//! input (`epoch_ms` in the header, ms precision), and every span /
//! event / heartbeat line is stamped with the input's lane label (its
//! header shard, or `pid<pid>` for unsharded runs). Per-input `metrics`
//! lines are folded through [`super::Merge`] into a single final
//! snapshot — the campaign-wide counter totals.
//!
//! The output is itself a valid sidecar: it re-validates under
//! `trace report --check`, renders per-lane utilization and lease
//! contention, and is byte-deterministic given the same inputs (the
//! merged header carries pid 0, not the merging process's pid).

use std::path::{Path, PathBuf};

use anyhow::{bail, ensure, Context, Result};

use crate::util::json::{obj, Json};

use super::metrics::{Merge, MetricsSnapshot};
use super::report::TraceReport;
use super::sink::SCHEMA;

/// What [`merge_traces`] wrote, for the CLI's closing message.
#[derive(Debug, Clone)]
pub struct MergeSummary {
    /// Where the merged sidecar was written.
    pub path: PathBuf,
    /// Number of input sidecars folded in.
    pub inputs: usize,
    /// Line count of the merged sidecar (header + body + metrics).
    pub lines: u64,
    /// Lane labels in output order.
    pub lanes: Vec<String>,
    /// The unified wall-clock epoch (earliest input, Unix ms).
    pub epoch_ms: u64,
}

/// Fold the sidecars at `inputs` into one merged sidecar at `out`.
pub fn merge_traces(inputs: &[PathBuf], out: &Path) -> Result<MergeSummary> {
    ensure!(!inputs.is_empty(), "trace merge: no input sidecars given");
    let mut reports = Vec::with_capacity(inputs.len());
    for path in inputs {
        let r = TraceReport::load(path)
            .with_context(|| format!("validating input {}", path.display()))?;
        if r.epoch_ms.is_none() {
            bail!(
                "{}: header lacks epoch_ms (pre-observatory sidecar) — re-run the campaign \
                 with this build to merge its trace",
                path.display()
            );
        }
        reports.push(r);
    }
    let epoch_ms = reports.iter().filter_map(|r| r.epoch_ms).min().unwrap_or(0);

    // Lane label per input: the shard label, else pid; disambiguate
    // collisions (e.g. the same unsharded store traced twice) by index.
    let mut lanes: Vec<String> = Vec::with_capacity(reports.len());
    for r in &reports {
        let mut label =
            r.shard.clone().unwrap_or_else(|| format!("pid{}", r.pid));
        if lanes.contains(&label) {
            label = format!("{label}#{}", lanes.len());
        }
        lanes.push(label);
    }

    // Re-read the raw lines, shift them onto the unified time base, and
    // stamp lane tags. (`t_us`, input index, line index) gives a total,
    // deterministic order.
    let mut merged_lines: Vec<(u64, usize, usize, Json)> = Vec::new();
    let mut snapshot = MetricsSnapshot::default();
    for (idx, (path, r)) in inputs.iter().zip(&reports).enumerate() {
        let offset_us = (r.epoch_ms.unwrap_or(0) - epoch_ms) * 1000;
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        for (lineno, line) in text.lines().enumerate() {
            let mut v = Json::parse(line)?;
            let kind = v.get("kind")?.as_str()?.to_string();
            match kind.as_str() {
                "header" => continue,
                "metrics" => {
                    snapshot.merge(&MetricsSnapshot::from_json(v.get("snapshot")?)?);
                    continue;
                }
                _ => {}
            }
            let t_us = v.get("t_us")?.as_f64()? as u64 + offset_us;
            if let Json::Obj(m) = &mut v {
                m.insert("t_us".into(), Json::from(t_us as f64));
                // Keep per-line tags from already-merged inputs; stamp
                // everything else with this input's lane.
                m.entry("shard".to_string()).or_insert_with(|| Json::from(lanes[idx].as_str()));
            }
            merged_lines.push((t_us, idx, lineno, v));
        }
    }
    merged_lines.sort_by_key(|(t, idx, lineno, _)| (*t, *idx, *lineno));

    let header = obj([
        ("kind", Json::from("header")),
        ("schema", Json::from(SCHEMA)),
        // pid 0 marks a merged stream and keeps the output byte-
        // deterministic across merging processes.
        ("pid", Json::from(0.0)),
        ("store", Json::from(reports[0].store.as_str())),
        ("shard", Json::Null),
        ("epoch_ms", Json::from(epoch_ms as f64)),
        ("merged_from", Json::Arr(lanes.iter().map(|l| Json::from(l.as_str())).collect())),
    ]);
    let last_t_us = merged_lines.last().map(|(t, ..)| *t).unwrap_or(0);
    let metrics_line = obj([
        ("kind", Json::from("metrics")),
        ("t_us", Json::from(last_t_us as f64)),
        ("snapshot", snapshot.to_json()),
    ]);

    let mut text = String::new();
    text.push_str(&header.dumps());
    text.push('\n');
    for (_, _, _, v) in &merged_lines {
        text.push_str(&v.dumps());
        text.push('\n');
    }
    text.push_str(&metrics_line.dumps());
    text.push('\n');
    crate::campaign::checkpoint::write_atomic(out, &text)
        .with_context(|| format!("writing merged trace {}", out.display()))?;

    Ok(MergeSummary {
        path: out.to_path_buf(),
        inputs: inputs.len(),
        lines: merged_lines.len() as u64 + 2,
        lanes,
        epoch_ms,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::metrics::Metrics;
    use std::path::PathBuf;

    fn tmp(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("carbon3d-merge-{tag}-{}.jsonl", std::process::id()))
    }

    fn shard_sidecar(path: &Path, shard: &str, epoch_ms: f64, job: &str, hits: u64) {
        let m = Metrics::default();
        m.incr("mapper_cache_hits", hits);
        m.record("job.eval", 40);
        let lines = [
            obj([
                ("kind", Json::from("header")),
                ("schema", Json::from(SCHEMA)),
                ("pid", Json::from(7.0)),
                ("store", Json::from("/tmp/demo.jsonl")),
                ("shard", Json::from(shard)),
                ("epoch_ms", Json::from(epoch_ms)),
            ]),
            obj([
                ("kind", Json::from("span")),
                ("name", Json::from("job.eval")),
                ("t_us", Json::from(10.0)),
                ("dur_us", Json::from(40.0)),
                ("depth", Json::from(0.0)),
                ("parent", Json::Null),
                ("job", Json::from(job)),
                ("thread", Json::from(0.0)),
            ]),
            obj([
                ("kind", Json::from("metrics")),
                ("t_us", Json::from(50.0)),
                ("snapshot", m.snapshot().to_json()),
            ]),
        ];
        let text: String = lines.iter().map(|l| l.dumps() + "\n").collect();
        std::fs::write(path, text).unwrap();
    }

    #[test]
    fn merge_reconciles_epochs_tags_lanes_and_folds_metrics() {
        let (a, b, out) = (tmp("in-a"), tmp("in-b"), tmp("out"));
        shard_sidecar(&a, "0/2", 1_000.0, "job-a", 3);
        // Shard 1 started 2ms later: its offsets shift by 2000µs.
        shard_sidecar(&b, "1/2", 1_002.0, "job-b", 5);
        let s = merge_traces(&[a.clone(), b.clone()], &out).unwrap();
        assert_eq!(s.lanes, vec!["0/2".to_string(), "1/2".to_string()]);
        assert_eq!(s.epoch_ms, 1_000);

        let r = TraceReport::load(&out).unwrap();
        assert_eq!(r.pid, 0);
        assert_eq!(r.shard, None);
        assert_eq!(r.epoch_ms, Some(1_000));
        let sa = r.spans.iter().find(|x| x.job.as_deref() == Some("job-a")).unwrap();
        let sb = r.spans.iter().find(|x| x.job.as_deref() == Some("job-b")).unwrap();
        assert_eq!(sa.t_us, 10);
        assert_eq!(sb.t_us, 2_010, "later epoch must shift onto the unified time base");
        assert_eq!(sa.shard.as_deref(), Some("0/2"));
        assert_eq!(sb.shard.as_deref(), Some("1/2"));
        // One folded metrics line carrying campaign-wide totals.
        assert_eq!(r.metrics_lines, 1);
        let m = r.final_metrics.unwrap();
        assert_eq!(m.counter("mapper_cache_hits"), 8);
        assert_eq!(m.histograms["job.eval"].count, 2);
        assert_eq!(r.lanes().len(), 2);

        // Byte-deterministic: merging again yields the identical file.
        let out2 = tmp("out2");
        merge_traces(&[a.clone(), b.clone()], &out2).unwrap();
        assert_eq!(std::fs::read(&out).unwrap(), std::fs::read(&out2).unwrap());
        for p in [a, b, out, out2] {
            std::fs::remove_file(p).unwrap();
        }
    }

    #[test]
    fn merge_rejects_epochless_inputs_and_disambiguates_lane_collisions() {
        let old = tmp("epochless");
        std::fs::write(
            &old,
            format!(
                "{}\n",
                obj([
                    ("kind", Json::from("header")),
                    ("schema", Json::from(SCHEMA)),
                    ("pid", Json::from(1.0)),
                    ("store", Json::from("s")),
                    ("shard", Json::Null),
                ])
                .dumps()
            ),
        )
        .unwrap();
        let err = merge_traces(&[old.clone()], &tmp("never")).unwrap_err();
        assert!(format!("{err:#}").contains("epoch_ms"), "{err:#}");
        std::fs::remove_file(&old).unwrap();

        let (a, b, out) = (tmp("dup-a"), tmp("dup-b"), tmp("dup-out"));
        shard_sidecar(&a, "0/2", 1_000.0, "x", 0);
        shard_sidecar(&b, "0/2", 1_000.0, "y", 0);
        let s = merge_traces(&[a.clone(), b.clone()], &out).unwrap();
        assert_eq!(s.lanes, vec!["0/2".to_string(), "0/2#1".to_string()]);
        for p in [a, b, out] {
            std::fs::remove_file(p).unwrap();
        }
    }
}
