//! Carbon analysis report: embodied-carbon breakdowns across nodes,
//! integration styles and multiplier choices, plus the multiplier library's
//! Pareto view — the data behind the paper's §III motivation.
//!
//! Run: `cargo run --release --example carbon_report`

use carbon3d::approx::{library, EXACT_ID};
use carbon3d::area::die::Integration;
use carbon3d::area::mac::{mac_area_um2, multiplier_area_fraction};
use carbon3d::area::node::ALL_NODES;
use carbon3d::carbon::embodied_carbon;
use carbon3d::dataflow::arch::AccelConfig;
use carbon3d::util::{table, Table};

fn main() -> anyhow::Result<()> {
    let lib = library();

    // ---- multiplier Pareto view -------------------------------------------
    println!("== approximate-multiplier library: area vs error (the GA's menu) ==");
    let mut t = Table::new(vec!["mult", "area@45nm", "area@14nm", "area@7nm", "sig_MRED", "rel_area_%"]);
    let exact45 = lib[EXACT_ID].hw_cost(carbon3d::TechNode::N45).area_um2;
    for m in &lib {
        t.row(vec![
            m.name(),
            format!("{:.0}", m.hw_cost(carbon3d::TechNode::N45).area_um2),
            format!("{:.1}", m.hw_cost(carbon3d::TechNode::N14).area_um2),
            format!("{:.2}", m.hw_cost(carbon3d::TechNode::N7).area_um2),
            format!("{:.5}", m.error.sig_mred),
            format!("{:.0}", m.hw_cost(carbon3d::TechNode::N45).area_um2 / exact45 * 100.0),
        ]);
    }
    println!("{}", t.render());

    // ---- MAC composition (paper §III-C) -----------------------------------
    println!("== MAC composition: the multiplier dominates (paper §III-C) ==");
    for &node in &ALL_NODES {
        println!(
            "{}: MAC {:.1} um^2, multiplier share {:.0}%",
            node.name(),
            mac_area_um2(&lib[EXACT_ID], node),
            multiplier_area_fraction(&lib[EXACT_ID], node) * 100.0
        );
    }

    // ---- embodied-carbon breakdowns ---------------------------------------
    println!("\n== embodied carbon: 2D vs 3D, exact vs approximate ==");
    let mut t = Table::new(vec![
        "node", "integration", "mult", "logic_g", "memory_g", "bond_g", "pkg_g", "total_g",
    ]);
    let t2p3 = lib.iter().find(|m| m.name() == "T2P3").unwrap();
    for &node in &ALL_NODES {
        for (integration, label) in
            [(Integration::TwoD, "2D"), (Integration::ThreeD, "3D")]
        {
            for mult in [&lib[EXACT_ID], t2p3] {
                let cfg = AccelConfig {
                    px: 32,
                    py: 32,
                    rf_bytes: 128,
                    sram_bytes: 512 << 10,
                    node,
                    integration,
                    mult_id: mult.id,
                };
                let areas = cfg.die_areas(mult);
                let c = embodied_carbon(&areas, node, integration);
                t.row(vec![
                    node.name().to_string(),
                    label.to_string(),
                    mult.name(),
                    table::fmt(c.logic_die_g),
                    table::fmt(c.memory_die_g),
                    table::fmt(c.bonding_g),
                    table::fmt(c.packaging_g),
                    table::fmt(c.total_g()),
                ]);
            }
        }
    }
    println!("{}", t.render());
    println!("carbon_report OK");
    Ok(())
}
