//! Die-area composition for the 3D memory-on-logic accelerator (paper §III-A/C).
//!
//! Logic die (bottom): Px*Py PEs (MAC + local-buffer RF + PE control) plus
//! array interconnect; in 2D designs the NoC between SRAM and PEs also lives
//! here. Memory die (top): the global SRAM buffer plus hybrid-bond pad field.

use super::mac::mac_area_um2;
use super::node::TechNode;
use super::sram::{rf_area_um2, sram_area_mm2};
use crate::approx::Multiplier;

/// Integration style: the paper's 3D memory-on-logic vs the 2D baseline.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Integration {
    TwoD,
    ThreeD,
}

/// Per-PE control logic in NAND2-equivalents-derived um^2 (sequencer, operand
/// regs outside the RF). A small constant per node — the MAC dominates the
/// PE, per the paper's §III-C area analysis.
fn pe_control_um2(node: TechNode) -> f64 {
    30.0 * node.cell_params().nand2_area_um2
}

/// Areas of the dies making up one accelerator.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DieAreas {
    /// Logic die area, mm^2.
    pub logic_mm2: f64,
    /// Memory die area, mm^2 (zero for 2D, where the SRAM sits on the logic die).
    pub memory_mm2: f64,
    /// Package substrate area, mm^2.
    pub package_mm2: f64,
}

impl DieAreas {
    /// Total silicon area (mm^2) across dies.
    pub fn silicon_mm2(&self) -> f64 {
        self.logic_mm2 + self.memory_mm2
    }

    /// Footprint (mm^2): max die for 3D stacks, the single die for 2D.
    pub fn footprint_mm2(&self) -> f64 {
        self.logic_mm2.max(self.memory_mm2)
    }
}

/// Logic-die area (mm^2): PE array + wiring overhead (+ NoC in 2D).
pub fn logic_die_area_mm2(
    px: usize,
    py: usize,
    rf_bytes: usize,
    mult: &Multiplier,
    node: TechNode,
    integration: Integration,
    sram_bytes: usize,
) -> f64 {
    let n_pe = (px * py) as f64;
    let pe_um2 = mac_area_um2(mult, node) + rf_area_um2(rf_bytes, node) + pe_control_um2(node);
    // Array wiring/whitespace overhead: 18% (place-and-route rule of thumb).
    let array_mm2 = n_pe * pe_um2 / 1e6 * 1.18;
    match integration {
        Integration::ThreeD => {
            // Hybrid-bond pad field adds ~3% to the logic die.
            array_mm2 * 1.03
        }
        Integration::TwoD => {
            // The global SRAM shares the die, connected by a NoC whose area
            // grows with the array perimeter (router per column/row port).
            let noc_mm2 =
                0.3 * (px + py) as f64 * 900.0 * node.cell_params().nand2_area_um2 / 1e6;
            let sram_mm2 = sram_area_mm2(sram_bytes, node);
            array_mm2 + noc_mm2 + sram_mm2
        }
    }
}

/// Memory-die area (mm^2) for the 3D stack: global SRAM + bond pads.
pub fn memory_die_area_mm2(sram_bytes: usize, node: TechNode) -> f64 {
    sram_area_mm2(sram_bytes, node) * 1.05
}

/// Compose full die areas for an accelerator configuration.
#[allow(clippy::too_many_arguments)]
pub fn die_areas(
    px: usize,
    py: usize,
    rf_bytes: usize,
    sram_bytes: usize,
    mult: &Multiplier,
    node: TechNode,
    integration: Integration,
) -> DieAreas {
    let logic = logic_die_area_mm2(px, py, rf_bytes, mult, node, integration, sram_bytes);
    let memory = match integration {
        Integration::ThreeD => memory_die_area_mm2(sram_bytes, node),
        Integration::TwoD => 0.0,
    };
    // Package substrate: footprint + fan-out margin (TSV/BGA field). The
    // substrate scales with the stack footprint for these mm^2-class edge
    // dies (WLCSP-style), with a small fixed keep-out ring.
    let footprint = logic.max(memory);
    let package = footprint * 1.25 + 0.5;
    DieAreas { logic_mm2: logic, memory_mm2: memory, package_mm2: package }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::{library, EXACT_ID};
    use crate::util::prop;

    fn lib_exact() -> Multiplier {
        library()[EXACT_ID].clone()
    }

    #[test]
    fn three_d_logic_die_smaller_than_2d() {
        // Moving the SRAM off-die must shrink the logic die.
        let m = lib_exact();
        let node = TechNode::N14;
        let l3 = logic_die_area_mm2(16, 16, 512, &m, node, Integration::ThreeD, 1 << 20);
        let l2 = logic_die_area_mm2(16, 16, 512, &m, node, Integration::TwoD, 1 << 20);
        assert!(l3 < l2);
    }

    #[test]
    fn three_d_footprint_below_2d_footprint() {
        // The headline 3D benefit: smaller footprint at iso-resources.
        let m = lib_exact();
        let node = TechNode::N7;
        let d3 = die_areas(16, 16, 512, 1 << 20, &m, node, Integration::ThreeD);
        let d2 = die_areas(16, 16, 512, 1 << 20, &m, node, Integration::TwoD);
        assert!(d3.footprint_mm2() < d2.footprint_mm2());
    }

    #[test]
    fn area_scales_with_pe_count() {
        let m = lib_exact();
        let node = TechNode::N45;
        let a8 = logic_die_area_mm2(8, 8, 512, &m, node, Integration::ThreeD, 1 << 20);
        let a16 = logic_die_area_mm2(16, 16, 512, &m, node, Integration::ThreeD, 1 << 20);
        let ratio = a16 / a8;
        assert!((3.5..4.5).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn approx_multiplier_shrinks_logic_die() {
        // At Eyeriss-class local buffers (128B) the MAC dominates the PE
        // (paper §III-C) and swapping the multiplier must cut the logic die
        // by well over 10%.
        let lib = library();
        let node = TechNode::N14;
        let exact = logic_die_area_mm2(32, 32, 128, &lib[EXACT_ID], node, Integration::ThreeD, 1 << 20);
        let small = lib
            .iter()
            .map(|m| logic_die_area_mm2(32, 32, 128, m, node, Integration::ThreeD, 1 << 20))
            .fold(f64::INFINITY, f64::min);
        assert!(small < exact * 0.9, "best {small} vs exact {exact}");
    }

    #[test]
    fn die_areas_positive_prop() {
        let m = lib_exact();
        prop::check("die-areas-positive", 40, |rng| {
            let px = 1 << rng.range(2, 6);
            let py = 1 << rng.range(2, 6);
            let rf = 1 << rng.range(6, 11);
            let sram = 1 << rng.range(16, 23);
            for integration in [Integration::TwoD, Integration::ThreeD] {
                let d = die_areas(px, py, rf, sram, &m, TechNode::N7, integration);
                assert!(d.logic_mm2 > 0.0);
                assert!(d.package_mm2 > d.footprint_mm2());
                if integration == Integration::TwoD {
                    assert_eq!(d.memory_mm2, 0.0);
                } else {
                    assert!(d.memory_mm2 > 0.0);
                }
            }
        });
    }
}
