//! Island-model GA: several independent populations evolved in parallel
//! (std threads — one per island) with periodic elite migration in a ring.
//!
//! An extension beyond the paper's single-population GA (its §III-E notes
//! premature convergence as the motivation for mutation; islands attack the
//! same problem structurally). Used by the ablation bench and available via
//! `carbon3d dse --islands N`.

use std::sync::mpsc;

use super::chromosome::{Chromosome, SearchSpace};
use super::engine::{Ga, GaParams, GaResult};
use super::fitness::{EvalShares, FitnessCtx};
use crate::approx::Multiplier;
use crate::area::die::Integration;
use crate::area::TechNode;
use crate::dataflow::workloads::Workload;

/// Island-model parameters.
#[derive(Debug, Clone, Copy)]
pub struct IslandParams {
    pub islands: usize,
    /// Generations between migrations (per epoch each island runs a full
    /// GA segment of this length).
    pub epoch_generations: usize,
    pub epochs: usize,
    /// Elites injected into the next island each migration.
    pub migrants: usize,
    pub base: GaParams,
}

impl Default for IslandParams {
    fn default() -> Self {
        Self { islands: 4, epoch_generations: 12, epochs: 4, migrants: 2, base: GaParams::default() }
    }
}

/// Run the island GA with fresh caches (see [`run_islands_shared`]).
#[allow(clippy::too_many_arguments)]
pub fn run_islands(
    space: &SearchSpace,
    params: IslandParams,
    workload: &Workload,
    node: TechNode,
    integration: Integration,
    library: &[Multiplier],
    fps_floor: Option<f64>,
) -> GaResult {
    run_islands_shared(
        space,
        params,
        workload,
        node,
        integration,
        library,
        fps_floor,
        &EvalShares::default(),
    )
}

/// Run the island GA. The fitness context is rebuilt per island/thread
/// (models are cheap and pure; the chromosome memo is per-island), but
/// every island shares `shares`' geometry-mapping cache — islands revisit
/// the same geometries constantly, differing mostly in the multiplier
/// gene, so one island's mapper run serves them all.
#[allow(clippy::too_many_arguments)]
pub fn run_islands_shared(
    space: &SearchSpace,
    params: IslandParams,
    workload: &Workload,
    node: TechNode,
    integration: Integration,
    library: &[Multiplier],
    fps_floor: Option<f64>,
    shares: &EvalShares,
) -> GaResult {
    assert!(params.islands >= 1);
    let mut seeds: Vec<Vec<Chromosome>> = vec![Vec::new(); params.islands];
    let mut best: Option<GaResult> = None;
    let mut total_evals = 0usize;
    let mut history = Vec::new();

    for epoch in 0..params.epochs {
        // One scoped thread per island, returning its segment result +
        // elite set.
        let results: Vec<(GaResult, Vec<Chromosome>)> = std::thread::scope(|s| {
            let (tx, rx) = mpsc::channel();
            for island in 0..params.islands {
                let tx = tx.clone();
                let seeds_in = seeds[island].clone();
                let space = space.clone();
                s.spawn(move || {
                    let mut ctx =
                        FitnessCtx::new(workload, node, integration, library, fps_floor)
                            .share(shares);
                    let ga_params = GaParams {
                        generations: params.epoch_generations,
                        // Deterministic per (island, epoch) stream.
                        seed: params
                            .base
                            .seed
                            .wrapping_add(island as u64 * 0x9E37_79B9)
                            .wrapping_add(epoch as u64 * 0x85EB_CA6B),
                        // Long patience within an epoch: migration decides.
                        patience: params.epoch_generations + 1,
                        ..params.base
                    };
                    let ga = Ga::new(space, ga_params);
                    let r = ga.run_seeded(&mut ctx, &seeds_in);
                    // Elites to migrate: best chromosome (the engine keeps
                    // only the single best; replicate it).
                    let elites = vec![r.best.clone(); params.migrants.max(1)];
                    let _ = tx.send((island, r, elites));
                });
            }
            drop(tx);
            let mut out: Vec<Option<(GaResult, Vec<Chromosome>)>> =
                (0..params.islands).map(|_| None).collect();
            for (island, r, e) in rx {
                out[island] = Some((r, e));
            }
            out.into_iter().map(Option::unwrap).collect()
        });

        // Ring migration: island i's elites seed island (i+1) % n.
        let n = params.islands;
        for (i, (r, elites)) in results.into_iter().enumerate() {
            total_evals += r.evaluations;
            let better = match &best {
                None => true,
                Some(b) => r.best_eval.fitness < b.best_eval.fitness,
            };
            if better {
                best = Some(r.clone());
            }
            history.push(r.best_eval.fitness);
            seeds[(i + 1) % n] = elites;
        }
    }

    let mut out = best.expect("at least one island ran");
    out.evaluations = total_evals;
    out.history = history;
    out.generations_run = params.epochs * params.epoch_generations;
    out
}

impl Ga {
    /// Like `run`, but the initial population includes the given seed
    /// chromosomes (migrants), topped up with random samples.
    pub fn run_seeded(&self, ctx: &mut FitnessCtx, seeds: &[Chromosome]) -> GaResult {
        if seeds.is_empty() {
            return self.run(ctx);
        }
        // Inject seeds by evaluating them first: the fitness cache makes
        // them visible to `near_optimal_min_carbon`, and we compare the
        // seeded best against the fresh run.
        let seed_best = seeds
            .iter()
            .filter(|c| self.space.contains(c))
            .map(|c| (c.clone(), ctx.eval(c)))
            .min_by(|a, b| a.1.fitness.partial_cmp(&b.1.fitness).unwrap());
        let mut r = self.run(ctx);
        if let Some((c, e)) = seed_best {
            if e.fitness < r.best_eval.fitness {
                r.best = c;
                r.best_eval = e;
            }
        }
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::{filter_by_mred, library};
    use crate::dataflow::workloads::workload;

    fn setup() -> (Vec<Multiplier>, SearchSpace) {
        let lib = library();
        let feasible = filter_by_mred(&lib, 0.02);
        let space = SearchSpace::standard(feasible);
        (lib, space)
    }

    fn quick_base() -> GaParams {
        GaParams { population: 16, ..Default::default() }
    }

    #[test]
    fn islands_return_a_valid_result() {
        let (lib, space) = setup();
        let w = workload("resnet50").unwrap();
        let p = IslandParams {
            islands: 3,
            epoch_generations: 6,
            epochs: 2,
            migrants: 1,
            base: quick_base(),
        };
        let r = run_islands(&space, p, &w, TechNode::N14, Integration::ThreeD, &lib, None);
        assert!(space.contains(&r.best));
        assert!(r.best_eval.fitness.is_finite());
        assert_eq!(r.history.len(), 3 * 2);
        assert!(r.evaluations > 0);
    }

    #[test]
    fn islands_deterministic_per_seed() {
        let (lib, space) = setup();
        let w = workload("resnet50").unwrap();
        let p = IslandParams {
            islands: 2,
            epoch_generations: 5,
            epochs: 2,
            migrants: 1,
            base: quick_base(),
        };
        let a = run_islands(&space, p, &w, TechNode::N14, Integration::ThreeD, &lib, None);
        let b = run_islands(&space, p, &w, TechNode::N14, Integration::ThreeD, &lib, None);
        assert_eq!(a.best, b.best);
        assert_eq!(a.best_eval.fitness, b.best_eval.fitness);
    }

    #[test]
    fn islands_at_least_match_single_population_quality() {
        let (lib, space) = setup();
        let w = workload("densenet121").unwrap();
        let single = {
            let mut ctx = FitnessCtx::new(&w, TechNode::N14, Integration::ThreeD, &lib, None);
            Ga::new(space.clone(), GaParams { population: 16, generations: 20, ..Default::default() })
                .run(&mut ctx)
        };
        let p = IslandParams {
            islands: 4,
            epoch_generations: 5,
            epochs: 4,
            migrants: 2,
            base: quick_base(),
        };
        let multi = run_islands(&space, p, &w, TechNode::N14, Integration::ThreeD, &lib, None);
        // Same total generation budget; islands must not be meaningfully
        // worse (allow 10% slack for stochastic variation).
        assert!(
            multi.best_eval.fitness <= single.best_eval.fitness * 1.10,
            "islands {} vs single {}",
            multi.best_eval.fitness,
            single.best_eval.fitness
        );
    }

    #[test]
    fn shared_mapping_cache_leaves_results_unchanged() {
        let (lib, space) = setup();
        let w = workload("resnet50").unwrap();
        let p = IslandParams {
            islands: 3,
            epoch_generations: 4,
            epochs: 2,
            migrants: 1,
            base: quick_base(),
        };
        let shares = EvalShares::default();
        let shared = run_islands_shared(
            &space,
            p,
            &w,
            TechNode::N14,
            Integration::ThreeD,
            &lib,
            None,
            &shares,
        );
        let fresh = run_islands(&space, p, &w, TechNode::N14, Integration::ThreeD, &lib, None);
        assert_eq!(shared.best, fresh.best);
        assert_eq!(shared.best_eval.fitness.to_bits(), fresh.best_eval.fitness.to_bits());
        // The islands actually went through the shared cache, and the
        // cross-island/cross-epoch redundancy shows up as hits (each epoch
        // re-evaluates its migrants through a fresh per-island memo, so the
        // shared geometry cache is guaranteed repeat lookups).
        let mc = shares.mapping.counts();
        assert!(mc.lookups() > 0);
        assert!(mc.hits > 0, "{mc:?}");
    }

    #[test]
    fn run_seeded_respects_good_seed() {
        let (lib, space) = setup();
        let w = workload("resnet50").unwrap();
        // Find a good chromosome first.
        let mut ctx = FitnessCtx::new(&w, TechNode::N14, Integration::ThreeD, &lib, None);
        let good = Ga::new(space.clone(), GaParams { population: 24, generations: 24, ..Default::default() })
            .run(&mut ctx)
            .best;
        // A deliberately weak fresh run must still return >= the seed.
        let mut ctx2 = FitnessCtx::new(&w, TechNode::N14, Integration::ThreeD, &lib, None);
        let weak = Ga::new(
            space.clone(),
            GaParams { population: 8, generations: 2, seed: 424242, ..Default::default() },
        );
        let seeded = weak.run_seeded(&mut ctx2, &[good.clone()]);
        let good_fitness = ctx2.eval(&good).fitness;
        assert!(seeded.best_eval.fitness <= good_fitness + 1e-12);
    }
}
