//! Artifact registry: manifest.json + file layout of `artifacts/`.

use std::path::{Path, PathBuf};

use anyhow::{ensure, Context, Result};

use crate::util::Json;

/// Parsed view of the artifacts directory.
#[derive(Debug, Clone)]
pub struct Artifacts {
    pub dir: PathBuf,
    pub batch: usize,
    pub img: usize,
    pub num_classes: usize,
    pub n_test: usize,
    pub exact_test_accuracy: f64,
    /// (name, flattened-size) of each trained parameter tensor.
    pub params: Vec<(String, usize)>,
}

impl Artifacts {
    /// Default location relative to the repo root.
    pub fn default_dir() -> PathBuf {
        // Allow override for tests / deployments.
        if let Ok(d) = std::env::var("CARBON3D_ARTIFACTS") {
            return PathBuf::from(d);
        }
        PathBuf::from("artifacts")
    }

    /// Load and validate the manifest.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("read {} (run `make artifacts`)", manifest_path.display()))?;
        let m = Json::parse(&text).context("parse manifest.json")?;
        let params = m
            .get("params")?
            .as_arr()?
            .iter()
            .map(|p| -> Result<(String, usize)> {
                let pair = p.as_arr()?;
                let name = pair[0].as_str()?.to_string();
                let size = pair[1]
                    .as_arr()?
                    .iter()
                    .map(|d| d.as_usize())
                    .product::<Result<usize>>()?;
                Ok((name, size))
            })
            .collect::<Result<Vec<_>>>()?;
        let a = Self {
            dir: dir.to_path_buf(),
            batch: m.get("batch")?.as_usize()?,
            img: m.get("img")?.as_usize()?,
            num_classes: m.get("num_classes")?.as_usize()?,
            n_test: m.get("n_test")?.as_usize()?,
            exact_test_accuracy: m.get("exact_test_accuracy")?.as_f64()?,
            params,
        };
        ensure!(a.batch > 0 && a.n_test > 0, "degenerate manifest");
        Ok(a)
    }

    /// Path to one of the HLO artifacts.
    pub fn hlo_path(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{name}.hlo.txt"))
    }

    /// All expected HLO artifact names.
    pub fn hlo_names() -> [&'static str; 4] {
        ["matmul_approx", "matmul_exact", "cnn_approx", "cnn_exact"]
    }

    /// Verify every expected file exists and is non-empty.
    pub fn verify(&self) -> Result<()> {
        for name in Self::hlo_names() {
            let p = self.hlo_path(name);
            ensure!(
                p.exists() && std::fs::metadata(&p)?.len() > 0,
                "missing artifact {} (run `make artifacts`)",
                p.display()
            );
        }
        for f in ["weights.f32", "testset_images.f32", "testset_labels.u8"] {
            ensure!(self.dir.join(f).exists(), "missing artifact {f}");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn have_artifacts() -> bool {
        Path::new("artifacts/manifest.json").exists()
    }

    #[test]
    fn load_real_manifest() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let a = Artifacts::load(Path::new("artifacts")).unwrap();
        assert_eq!(a.batch, 64);
        assert_eq!(a.img, 16);
        assert_eq!(a.num_classes, 5);
        assert!(a.exact_test_accuracy > 0.8);
        assert_eq!(a.params.len(), 6);
        a.verify().unwrap();
    }

    #[test]
    fn missing_dir_is_graceful_error() {
        let err = Artifacts::load(Path::new("/nonexistent-dir-xyz")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
