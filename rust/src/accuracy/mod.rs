//! Accuracy-drop evaluation ΔA(M_approx) — the paper's Eq. (7) constraint.
//!
//! Three paths (DESIGN.md §6.3):
//!  1. `native`: a bit-faithful Rust reimplementation of the approximate bf16
//!     MAC datapath running the trained tiny CNN on the held-out test set
//!     (fast, no PJRT) — semantics identical to python/compile/kernels/ref.py.
//!  2. `runtime::pjrt` (see runtime/): the SAME network through the AOT
//!     JAX/Pallas artifact on the PJRT CPU client — cross-checks (1).
//!  3. `model`: an MRED-calibrated analytical ΔA model extrapolating the
//!     measured curve to the five ImageNet-scale paper CNNs, where offline
//!     retraining/inference is infeasible.

pub mod model;
pub mod native;

pub use model::{feasible_multipliers, predicted_drop_pct};
pub use native::{ApproxDatapath, BatchBuffers, MatmulKernel, NativeEvaluator};

use std::collections::BTreeMap;

/// Measured or predicted accuracy per multiplier id.
#[derive(Debug, Clone, Default)]
pub struct AccuracyTable {
    /// multiplier id -> top-1 accuracy in [0,1].
    pub accuracy: BTreeMap<usize, f64>,
    /// Exact-path reference accuracy.
    pub exact: f64,
}

impl AccuracyTable {
    /// Accuracy drop (percentage points) for a multiplier.
    pub fn drop_pct(&self, mult_id: usize) -> Option<f64> {
        self.accuracy.get(&mult_id).map(|a| (self.exact - a) * 100.0)
    }

    /// Multiplier ids whose measured drop fits the threshold δ (pct points).
    pub fn feasible(&self, delta_pct: f64) -> Vec<usize> {
        self.accuracy
            .iter()
            .filter(|(_, &a)| (self.exact - a) * 100.0 <= delta_pct + 1e-9)
            .map(|(&id, _)| id)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn drop_and_feasible_consistent() {
        let mut t = AccuracyTable { exact: 0.95, ..Default::default() };
        t.accuracy.insert(0, 0.95);
        t.accuracy.insert(1, 0.93);
        t.accuracy.insert(2, 0.89);
        assert_eq!(t.drop_pct(0), Some(0.0));
        assert!((t.drop_pct(1).unwrap() - 2.0).abs() < 1e-9);
        assert_eq!(t.feasible(1.0), vec![0]);
        assert_eq!(t.feasible(2.0), vec![0, 1]);
        assert_eq!(t.feasible(10.0), vec![0, 1, 2]);
        assert_eq!(t.drop_pct(99), None);
    }
}
