//! Inter-layer pipelined scheduling (Tangram [13]-style extension).
//!
//! The paper's nn-dataflow integration performs layer-by-layer
//! (latency-optimized) scheduling; nn-dataflow's successors add *inter-layer
//! pipelining*: partition the PE array into segments, map consecutive layers
//! onto segments, and stream tiles between them through the global SRAM so
//! segment delays overlap. We implement a segment scheduler to quantify how
//! much of the paper's headline FPS the simple scheduler leaves on the
//! table (ablation; also available via `carbon3d map --pipeline`).
//!
//! Model: a segment of S consecutive MAC layers gets a contiguous share of
//! the PE array proportional to its MAC count. Within a segment, layer
//! tiles flow producer->consumer with double buffering; the segment's
//! steady-state throughput is set by its slowest layer. Segments execute
//! back-to-back per frame, but across frames the pipeline overlaps, so
//! frame *throughput* is 1 / max(segment_delay) while single-frame
//! *latency* stays the sum.

use super::arch::AccelConfig;
use super::layer::Layer;
use super::mapper::{map_layer, LayerMapping};
use super::workloads::Workload;

/// Result of pipelined scheduling.
#[derive(Debug, Clone)]
pub struct PipelineSchedule {
    pub segments: Vec<Segment>,
    /// Single-frame latency, cycles (sum over segments).
    pub latency_cycles: u64,
    /// Steady-state initiation interval, cycles (max over segments).
    pub interval_cycles: u64,
}

#[derive(Debug, Clone)]
pub struct Segment {
    /// Indices into the workload's layer list.
    pub layer_range: (usize, usize),
    /// PE share assigned to this segment (fraction of the array).
    pub pe_share: f64,
    pub cycles: u64,
}

impl PipelineSchedule {
    pub fn throughput_fps(&self, cfg: &AccelConfig) -> f64 {
        cfg.freq_hz() / self.interval_cycles as f64
    }

    pub fn latency_s(&self, cfg: &AccelConfig) -> f64 {
        self.latency_cycles as f64 / cfg.freq_hz()
    }
}

/// Split a workload into `n_segments` contiguous segments balancing MACs,
/// assign PE shares, and evaluate each segment with the per-layer mapper on
/// a proportionally shrunk array.
pub fn schedule_pipeline(w: &Workload, cfg: &AccelConfig, n_segments: usize) -> PipelineSchedule {
    assert!(n_segments >= 1);
    let total_macs: u64 = w.total_macs().max(1);

    // Greedy contiguous split balanced on *estimated cycles* (full-array
    // per-layer cost), not MACs: this lets the scheduler isolate
    // bandwidth-bound layers (pool/eltwise) into their own segment so they
    // overlap with compute-bound ones — the actual source of pipeline
    // throughput wins.
    let est: Vec<u64> = w.layers.iter().map(|l| map_layer(l, cfg).cycles).collect();
    let total_est: u64 = est.iter().sum::<u64>().max(1);
    let mut cuts: Vec<usize> = Vec::new(); // exclusive end indices
    let mut acc = 0u64;
    let target = total_est / n_segments as u64;
    for (i, &c) in est.iter().enumerate() {
        acc += c;
        if acc >= target && cuts.len() + 1 < n_segments {
            cuts.push(i + 1);
            acc = 0;
        }
    }
    cuts.push(w.layers.len());

    // Evaluate each segment on its PE share.
    let mut segments = Vec::with_capacity(cuts.len());
    let mut start = 0usize;
    let mut latency = 0u64;
    let mut interval = 0u64;
    for &end in &cuts {
        let seg_layers: &[Layer] = &w.layers[start..end];
        let seg_macs: u64 = seg_layers.iter().map(|l| l.macs()).sum();
        let share = (seg_macs as f64 / total_macs as f64).max(0.02);
        // Shrink the array (keep aspect ratio-ish): scale both dims by
        // sqrt(share), min 1.
        let scale = share.sqrt();
        let sub_cfg = AccelConfig {
            px: ((cfg.px as f64 * scale).round() as usize).max(1),
            py: ((cfg.py as f64 * scale).round() as usize).max(1),
            // SRAM is shared; each segment sees its share for tiling
            // decisions.
            sram_bytes: ((cfg.sram_bytes as f64 * share) as usize).max(16 << 10),
            ..cfg.clone()
        };
        let mappings: Vec<LayerMapping> =
            seg_layers.iter().map(|l| map_layer(l, &sub_cfg)).collect();
        let cycles: u64 = mappings.iter().map(|m| m.cycles).sum();
        latency += cycles;
        interval = interval.max(cycles);
        segments.push(Segment { layer_range: (start, end), pe_share: share, cycles });
        start = end;
    }
    PipelineSchedule { segments, latency_cycles: latency, interval_cycles: interval }
}

/// Search segment counts 1..=max_segments and return the schedule with the
/// best steady-state throughput.
pub fn best_pipeline(w: &Workload, cfg: &AccelConfig, max_segments: usize) -> PipelineSchedule {
    (1..=max_segments.max(1))
        .map(|n| schedule_pipeline(w, cfg, n))
        .min_by_key(|s| s.interval_cycles)
        .unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::area::die::Integration;
    use crate::area::TechNode;
    use crate::approx::EXACT_ID;
    use crate::dataflow::mapper::map_network;
    use crate::dataflow::workloads::workload;

    fn cfg() -> AccelConfig {
        AccelConfig {
            px: 32,
            py: 32,
            rf_bytes: 128,
            sram_bytes: 1 << 20,
            node: TechNode::N14,
            integration: Integration::ThreeD,
            mult_id: EXACT_ID,
        }
    }

    #[test]
    fn one_segment_equals_layerwise_schedule() {
        let w = workload("resnet50").unwrap();
        let c = cfg();
        let p = schedule_pipeline(&w, &c, 1);
        assert_eq!(p.segments.len(), 1);
        assert_eq!(p.latency_cycles, p.interval_cycles);
        // One segment on a "share" of 1.0 uses the full array -> close to
        // the plain mapper (sram share rounding aside).
        let plain = map_network(&w, &c).total_cycles;
        let ratio = p.latency_cycles as f64 / plain as f64;
        assert!((0.95..1.1).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn segments_partition_all_layers() {
        let w = workload("densenet121").unwrap();
        let p = schedule_pipeline(&w, &cfg(), 4);
        assert_eq!(p.segments.first().unwrap().layer_range.0, 0);
        assert_eq!(p.segments.last().unwrap().layer_range.1, w.layers.len());
        for pair in p.segments.windows(2) {
            assert_eq!(pair[0].layer_range.1, pair[1].layer_range.0);
        }
    }

    #[test]
    fn best_pipeline_never_worse_than_layerwise() {
        // n=1 is in the search space, so the best schedule can only match
        // or beat it.
        for name in ["densenet121", "resnet50", "vgg16"] {
            let w = workload(name).unwrap();
            let c = cfg();
            let single = schedule_pipeline(&w, &c, 1);
            let best = best_pipeline(&w, &c, 6);
            assert!(best.interval_cycles <= single.interval_cycles, "{name}");
        }
    }

    #[test]
    fn pipelining_wins_on_bandwidth_skewed_workloads() {
        // A workload alternating compute-bound convs with heavy eltwise
        // traffic: layer-by-layer serializes the two resources; a 2-segment
        // pipeline overlaps them, so the initiation interval must drop
        // meaningfully below the single-segment schedule.
        use crate::dataflow::layer::Layer;
        use crate::dataflow::workloads::Workload;
        let mut layers = Vec::new();
        for i in 0..4 {
            layers.push(Layer::conv(&format!("conv{i}"), 56, 56, 64, 64, 3, 1));
        }
        for i in 0..12 {
            layers.push(Layer::eltwise(&format!("elt{i}"), 112, 112, 256));
        }
        let w = Workload { name: "skewed".into(), layers };
        let c = cfg();
        let single = schedule_pipeline(&w, &c, 1);
        let best = best_pipeline(&w, &c, 4);
        assert!(
            (best.interval_cycles as f64) < 0.9 * single.interval_cycles as f64,
            "best {} vs single {}",
            best.interval_cycles,
            single.interval_cycles
        );
    }

    #[test]
    fn latency_never_beats_interval() {
        let w = workload("vgg16").unwrap();
        for n in 1..=5 {
            let p = schedule_pipeline(&w, &cfg(), n);
            assert!(p.latency_cycles >= p.interval_cycles);
        }
    }

    #[test]
    fn pe_shares_sum_to_one_ish() {
        let w = workload("vgg19").unwrap();
        let p = schedule_pipeline(&w, &cfg(), 5);
        let total: f64 = p.segments.iter().map(|s| s.pe_share).sum();
        assert!((0.9..1.2).contains(&total), "shares sum {total}");
    }
}
