//! Die-yield model: Poisson defect model Y = exp(-D0 * A).
//!
//! D0 is the node's defect density (defects/mm^2, see `TechNode`); A is the
//! die area. The paper's Eq. (3) divides the per-area fab carbon by Y, so
//! larger dies at advanced nodes pay a superlinear carbon penalty — exactly
//! the lever the approximate multipliers pull.

use crate::area::TechNode;

/// Poisson yield for a die of `area_mm2` at `node`. Clamped to a small
/// positive floor so pathological areas never divide by zero.
pub fn die_yield(node: TechNode, area_mm2: f64) -> f64 {
    assert!(area_mm2 >= 0.0, "negative die area");
    (-node.defect_density_per_mm2() * area_mm2).exp().max(1e-6)
}

/// Murphy's yield model (alternative used by some fabs); exposed for the
/// sensitivity ablation in benches/ablation.rs.
pub fn die_yield_murphy(node: TechNode, area_mm2: f64) -> f64 {
    assert!(area_mm2 >= 0.0);
    let d0a = node.defect_density_per_mm2() * area_mm2;
    if d0a < 1e-12 {
        return 1.0;
    }
    let inner = (1.0 - (-d0a).exp()) / d0a;
    (inner * inner).max(1e-6)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn yield_is_one_at_zero_area() {
        for node in crate::area::node::ALL_NODES {
            assert!((die_yield(node, 0.0) - 1.0).abs() < 1e-12);
            assert!((die_yield_murphy(node, 0.0) - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn yield_decreases_with_area() {
        let node = TechNode::N7;
        let mut prev = 1.1;
        for a in [1.0, 10.0, 50.0, 200.0, 800.0] {
            let y = die_yield(node, a);
            assert!(y < prev);
            assert!(y > 0.0);
            prev = y;
        }
    }

    #[test]
    fn advanced_nodes_yield_worse_at_same_area() {
        let a = 80.0;
        assert!(die_yield(TechNode::N7, a) < die_yield(TechNode::N14, a));
        assert!(die_yield(TechNode::N14, a) < die_yield(TechNode::N45, a));
    }

    #[test]
    fn murphy_at_least_poisson() {
        // Murphy's model is known to be more optimistic than Poisson.
        prop::check("murphy>=poisson", 50, |rng| {
            let node = *rng.choice(&crate::area::node::ALL_NODES);
            let a = rng.uniform(0.0, 500.0);
            assert!(die_yield_murphy(node, a) >= die_yield(node, a) - 1e-12);
        });
    }

    #[test]
    fn yields_in_unit_interval_prop() {
        prop::check("yield-unit", 50, |rng| {
            let node = *rng.choice(&crate::area::node::ALL_NODES);
            let a = rng.uniform(0.0, 2000.0);
            for y in [die_yield(node, a), die_yield_murphy(node, a)] {
                assert!((0.0..=1.0).contains(&y), "y={y} a={a}");
            }
        });
    }
}
