//! Injectable time source for lease TTLs and heartbeat cadence.
//!
//! Production code uses [`Clock::System`]; tests inject a
//! [`FakeClock`] and advance it explicitly, so TTL-expiry and
//! reclaim behavior is exercised deterministically without `sleep`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// A monotonic-enough wall clock with millisecond resolution.
#[derive(Debug, Clone, Default)]
pub enum Clock {
    /// `SystemTime::now()` relative to the Unix epoch.
    #[default]
    System,
    /// A test clock that only moves when told to.
    Fake(Arc<AtomicU64>),
}

impl Clock {
    /// Milliseconds since the Unix epoch (or since the fake clock's
    /// origin).
    pub fn now_ms(&self) -> u64 {
        match self {
            Clock::System => std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map(|d| d.as_millis() as u64)
                .unwrap_or(0),
            Clock::Fake(ms) => ms.load(Ordering::SeqCst),
        }
    }

    /// Whole seconds since the epoch — the resolution lease documents
    /// record.
    pub fn now_s(&self) -> u64 {
        self.now_ms() / 1000
    }
}

/// Handle that owns a [`Clock::Fake`]'s time and can advance it.
#[derive(Debug, Clone)]
pub struct FakeClock(Arc<AtomicU64>);

impl FakeClock {
    /// A fake clock starting at `start_s` seconds.
    pub fn new(start_s: u64) -> Self {
        Self(Arc::new(AtomicU64::new(start_s * 1000)))
    }

    /// A [`Clock`] reading this handle's time.
    pub fn clock(&self) -> Clock {
        Clock::Fake(Arc::clone(&self.0))
    }

    /// Move time forward by `s` seconds.
    pub fn advance_s(&self, s: u64) {
        self.0.fetch_add(s * 1000, Ordering::SeqCst);
    }

    /// Move time forward by `ms` milliseconds.
    pub fn advance_ms(&self, ms: u64) {
        self.0.fetch_add(ms, Ordering::SeqCst);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fake_clock_only_moves_when_advanced() {
        let fake = FakeClock::new(1_000);
        let clock = fake.clock();
        assert_eq!(clock.now_s(), 1_000);
        assert_eq!(clock.now_s(), 1_000);
        fake.advance_s(30);
        assert_eq!(clock.now_s(), 1_030);
        fake.advance_ms(999);
        assert_eq!(clock.now_s(), 1_030, "sub-second advance rounds down");
        fake.advance_ms(1);
        assert_eq!(clock.now_s(), 1_031);
    }

    #[test]
    fn system_clock_is_sane() {
        let clock = Clock::default();
        // 2020-01-01 is comfortably in the past.
        assert!(clock.now_s() > 1_577_836_800);
    }
}
