//! Performance model: nn-dataflow stand-in extended for 3D memory-on-logic
//! (paper §III-E; DESIGN.md §6.4).
//!
//! Layer-level loop-nest mapping over an Eyeriss-like PE array with a
//! three-level memory hierarchy (per-PE register file, global SRAM, DRAM).
//! The 2D baseline moves SRAM<->PE traffic over a mesh NoC; the 3D design
//! uses hybrid-bond vertical links with much higher aggregate bandwidth —
//! the extension the paper added to nn-dataflow.

pub mod arch;
pub mod cache;
pub mod energy;
pub mod layer;
pub mod mapper;
pub mod pipeline;
pub mod workloads;

pub use arch::AccelConfig;
pub use cache::{geometry_dims, CacheCounts, CacheStats, GeometryDims, MappingCache};
pub use energy::EnergyModel;
pub use layer::{Layer, LayerKind};
pub use mapper::{map_layer, map_network, LayerMapping, NetworkMapping};
pub use workloads::{workload, workload_names, Workload};
