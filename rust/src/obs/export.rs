//! `carbon3d trace export --chrome`: convert a (possibly merged) trace
//! sidecar into Chrome trace-event JSON, loadable by `chrome://tracing`
//! and Perfetto (ui.perfetto.dev) with zero new dependencies
//! (DESIGN.md §8.5).
//!
//! Mapping: each shard lane becomes a Chrome *process* (named via a
//! `process_name` metadata event), each worker thread a *thread* within
//! it; spans become complete (`ph:"X"`) events with start/duration in
//! µs, point events become instants (`ph:"i"`), and heartbeats become
//! counter (`ph:"C"`) series so campaign progress graphs render above
//! the timeline.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::{obj, Json};

use super::report::TraceReport;

/// Build the Chrome trace-event document for a parsed trace.
pub fn chrome_trace(r: &TraceReport) -> Json {
    // Lane -> synthetic pid (1-based, sorted for determinism).
    let lane_of = |shard: &Option<String>| {
        shard.clone().or_else(|| r.shard.clone()).unwrap_or_else(|| "main".to_string())
    };
    let labels: std::collections::BTreeSet<String> = r
        .spans
        .iter()
        .map(|s| lane_of(&s.shard))
        .chain(r.events.iter().map(|e| lane_of(&e.shard)))
        .chain(r.beats.iter().map(|b| lane_of(&b.shard)))
        .collect();
    let pids: BTreeMap<String, u64> =
        labels.into_iter().zip(1u64..).map(|(label, pid)| (label, pid)).collect();

    let mut events: Vec<Json> = Vec::new();
    for (label, pid) in &pids {
        events.push(obj([
            ("ph", Json::from("M")),
            ("name", Json::from("process_name")),
            ("pid", Json::from(*pid as f64)),
            ("tid", Json::from(0.0)),
            ("args", obj([("name", Json::from(format!("shard {label}")))])),
        ]));
    }
    for s in &r.spans {
        events.push(obj([
            ("ph", Json::from("X")),
            ("name", Json::from(s.name.as_str())),
            ("cat", Json::from("span")),
            ("ts", Json::from(s.t_us as f64)),
            ("dur", Json::from(s.dur_us as f64)),
            ("pid", Json::from(pids[&lane_of(&s.shard)] as f64)),
            ("tid", Json::from(s.thread as f64)),
            (
                "args",
                obj([
                    ("job", s.job.as_deref().map(Json::from).unwrap_or(Json::Null)),
                    ("depth", Json::from(s.depth as f64)),
                ]),
            ),
        ]));
    }
    for e in &r.events {
        events.push(obj([
            ("ph", Json::from("i")),
            ("name", Json::from(e.name.as_str())),
            ("cat", Json::from("event")),
            ("ts", Json::from(e.t_us as f64)),
            ("pid", Json::from(pids[&lane_of(&e.shard)] as f64)),
            ("tid", Json::from(0.0)),
            ("s", Json::from("p")),
            ("args", e.fields.clone()),
        ]));
    }
    for b in &r.beats {
        events.push(obj([
            ("ph", Json::from("C")),
            ("name", Json::from("campaign progress")),
            ("ts", Json::from(b.t_us as f64)),
            ("pid", Json::from(pids[&lane_of(&b.shard)] as f64)),
            ("tid", Json::from(0.0)),
            (
                "args",
                obj([
                    ("done", Json::from(b.done as f64)),
                    ("pruned", Json::from(b.pruned as f64)),
                ]),
            ),
        ]));
    }
    obj([("displayTimeUnit", Json::from("ms")), ("traceEvents", Json::Arr(events))])
}

/// Load `trace`, convert, and write the Chrome JSON to `out`. Returns
/// the number of trace events written (excluding metadata records).
pub fn export_chrome(trace: &Path, out: &Path) -> Result<usize> {
    let r = TraceReport::load(trace)?;
    let doc = chrome_trace(&r);
    let n = r.spans.len() + r.events.len() + r.beats.len();
    crate::campaign::checkpoint::write_atomic(out, &doc.dumps())
        .with_context(|| format!("writing chrome trace {}", out.display()))?;
    Ok(n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::sink::SCHEMA;

    fn line(kind: &str, extra: &[(&str, Json)]) -> String {
        let mut fields = vec![("kind", Json::from(kind))];
        fields.extend(extra.iter().cloned());
        Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect()).dumps()
    }

    #[test]
    fn export_maps_lanes_to_processes_and_spans_to_complete_events() {
        let path = std::env::temp_dir()
            .join(format!("carbon3d-export-{}.trace.jsonl", std::process::id()));
        let out = path.with_extension("chrome.json");
        let lines = [
            line(
                "header",
                &[
                    ("schema", Json::from(SCHEMA)),
                    ("pid", Json::from(1.0)),
                    ("store", Json::from("s")),
                    ("shard", Json::Null),
                    ("epoch_ms", Json::from(0.0)),
                ],
            ),
            line(
                "span",
                &[
                    ("name", Json::from("job.eval")),
                    ("t_us", Json::from(5.0)),
                    ("dur_us", Json::from(20.0)),
                    ("depth", Json::from(0.0)),
                    ("parent", Json::Null),
                    ("job", Json::from("j1")),
                    ("thread", Json::from(2.0)),
                    ("shard", Json::from("0/2")),
                ],
            ),
            line(
                "span",
                &[
                    ("name", Json::from("job.eval")),
                    ("t_us", Json::from(6.0)),
                    ("dur_us", Json::from(10.0)),
                    ("depth", Json::from(0.0)),
                    ("parent", Json::Null),
                    ("job", Json::from("j2")),
                    ("thread", Json::from(0.0)),
                    ("shard", Json::from("1/2")),
                ],
            ),
            line(
                "event",
                &[
                    ("name", Json::from("lease.claim")),
                    ("t_us", Json::from(4.0)),
                    ("shard", Json::from("0/2")),
                    ("fields", Json::Obj(Default::default())),
                ],
            ),
        ];
        std::fs::write(&path, lines.join("\n") + "\n").unwrap();
        let n = export_chrome(&path, &out).unwrap();
        assert_eq!(n, 3);

        let doc = Json::parse(&std::fs::read_to_string(&out).unwrap()).unwrap();
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        // 2 process_name metadata records + 3 payload events.
        assert_eq!(events.len(), 5);
        let metas: Vec<_> =
            events.iter().filter(|e| e.get("ph").unwrap() == &Json::from("M")).collect();
        assert_eq!(metas.len(), 2);
        let span = events
            .iter()
            .find(|e| {
                e.get("ph").unwrap() == &Json::from("X")
                    && e.get("args").unwrap().get("job").unwrap() == &Json::from("j1")
            })
            .unwrap();
        assert_eq!(span.get("ts").unwrap().as_f64().unwrap(), 5.0);
        assert_eq!(span.get("dur").unwrap().as_f64().unwrap(), 20.0);
        assert_eq!(span.get("tid").unwrap().as_f64().unwrap(), 2.0);
        // Lanes sort deterministically: 0/2 -> pid 1, 1/2 -> pid 2.
        assert_eq!(span.get("pid").unwrap().as_f64().unwrap(), 1.0);
        for p in [path, out] {
            std::fs::remove_file(p).unwrap();
        }
    }
}
