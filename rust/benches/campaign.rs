//! Bench campaign: grid throughput (jobs/sec) and campaign-global eval
//! cache-hit rate for the worker-pool scheduler vs a serial loop of
//! `ga_appx_cdp` calls over the same scenarios.
//!
//! Modes:
//!   (default)        full sweep: serial baseline + 1/2/4/8-worker campaigns
//!   --smoke          reduced grid, skips the serial baseline — CI-sized
//!   --json FILE      also write the measurements as a JSON document
//!                    (CI uploads this as the `BENCH_campaign.json` artifact
//!                    so the perf trajectory accumulates across commits)

use carbon3d::approx::library;
use carbon3d::area::node::ALL_NODES;
use carbon3d::area::TechNode;
use carbon3d::campaign::{
    run_campaign, CampaignSpec, ResultStore, SamplerMode, SurrogateBackend,
};
use carbon3d::coordinator::ga_appx_cdp;
use carbon3d::dataflow::workloads::workload;
use carbon3d::ga::GaParams;
use carbon3d::obs::{Merge, MetricsSnapshot};
use carbon3d::runtime::EvalService;
use carbon3d::util::json::{obj, Json};
use carbon3d::obs::bench::time_once;

/// 2 models x 3 nodes x 2 deltas = 12 jobs at a reduced GA budget.
fn spec(smoke: bool) -> CampaignSpec {
    let mut s = CampaignSpec::new(
        vec!["vgg16".to_string(), "resnet50".to_string()],
        ALL_NODES.to_vec(),
        if smoke { vec![3.0] } else { vec![1.0, 3.0] },
    );
    s.ga = if smoke {
        GaParams { population: 8, generations: 4, patience: 2, elites: 1, ..Default::default() }
    } else {
        GaParams { population: 16, generations: 8, patience: 4, ..Default::default() }
    };
    s
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let smoke = args.iter().any(|a| a == "--smoke") || std::env::var("BENCH_SMOKE").is_ok();
    let json_out = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1).cloned());

    println!("== campaign benches{} ==", if smoke { " (smoke)" } else { "" });
    let s = spec(smoke);
    let n = s.n_jobs();
    let lib = library();
    let mut measurements: Vec<Json> = Vec::new();
    let metrics_before = MetricsSnapshot::collect();

    // Serial baseline: one GA-APPX-CDP invocation per scenario, nothing
    // shared across runs (the pre-campaign workflow). Skipped in smoke
    // mode to keep the CI job short.
    let mut serial_t = None;
    if !smoke {
        let (_, t) = time_once(|| {
            for job in s.jobs() {
                let w = workload(&job.model).unwrap();
                std::hint::black_box(ga_appx_cdp(
                    &w,
                    job.node,
                    &lib,
                    job.delta_pct,
                    job.fps_floor,
                    GaParams { seed: job.seed, ..s.ga },
                ));
            }
        });
        println!(
            "serial ga_appx_cdp loop                      {n} jobs in {t:.2}s = {:.2} jobs/s",
            n as f64 / t
        );
        serial_t = Some(t);
    }

    let worker_counts: &[usize] = if smoke { &[1, 4] } else { &[1, 2, 4, 8] };
    for &workers in worker_counts {
        let path = std::env::temp_dir().join(format!(
            "carbon3d-bench-campaign-{}-{workers}.jsonl",
            std::process::id()
        ));
        let _ = std::fs::remove_file(&path);
        let mut store = ResultStore::open(&path).unwrap();
        let svc = EvalService::start(SurrogateBackend::default());
        let (report, t) =
            time_once(|| run_campaign(&s, workers, &mut store, &svc).unwrap());
        svc.shutdown();
        let speedup = serial_t.map(|st| st / t);
        println!(
            "campaign {workers} worker{}                           \
             {n} jobs in {t:.2}s = {:.2} jobs/s | cache-hit {:.0}%{}",
            if workers == 1 { " " } else { "s" },
            report.jobs_per_sec(),
            report.stats.hit_rate() * 100.0,
            match speedup {
                Some(x) => format!(" | {x:.2}x vs serial"),
                None => String::new(),
            }
        );
        measurements.push(obj([
            ("workers", Json::from(workers)),
            ("jobs", Json::from(n)),
            ("elapsed_s", Json::from(t)),
            ("jobs_per_sec", Json::from(report.jobs_per_sec())),
            ("hit_rate", Json::from(report.stats.hit_rate())),
            ("mapping_hit_rate", Json::from(report.mapping.hit_rate())),
            ("memo_hit_rate", Json::from(report.memo.hit_rate())),
            ("jobs_pruned", Json::from(report.jobs_pruned)),
            (
                "speedup_vs_serial",
                match speedup {
                    Some(x) => Json::from(x),
                    None => Json::Null,
                },
            ),
        ]));
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_file(
            carbon3d::campaign::CampaignArchive::checkpoint_path(&path),
        );
        let _ = std::fs::remove_file(carbon3d::obs::status::status_path(&path));
    }

    // Adaptive-vs-exhaustive leg: one single-family δ ladder — the grid
    // shape the learned surrogate is built for — run both ways with the
    // same GA budget. The adaptive sampler should evaluate fewer jobs
    // (surrogate prunes) for the same family-best objective.
    let mut ladder = CampaignSpec::new(
        vec!["vgg16".to_string()],
        vec![TechNode::N7],
        if smoke {
            (1..=8).map(|i| i as f64 * 0.5).collect()
        } else {
            (1..=16).map(|i| i as f64 * 0.25).collect()
        },
    );
    ladder.ga = s.ga.clone();
    let ladder_jobs = ladder.n_jobs();
    let ladder_leg = |tag: &str, sampler: SamplerMode| {
        let path = std::env::temp_dir().join(format!(
            "carbon3d-bench-ladder-{}-{tag}.jsonl",
            std::process::id()
        ));
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_file(carbon3d::campaign::mapcache_path(&path));
        let mut spec = ladder.clone();
        spec.sampler = sampler;
        let mut store = ResultStore::open(&path).unwrap();
        let svc = EvalService::start(SurrogateBackend::default());
        let (report, t) =
            time_once(|| run_campaign(&spec, 4, &mut store, &svc).unwrap());
        svc.shutdown();
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_file(
            carbon3d::campaign::CampaignArchive::checkpoint_path(&path),
        );
        let _ = std::fs::remove_file(carbon3d::obs::status::status_path(&path));
        (report, t)
    };
    let (ladder_ex, t_ex) = ladder_leg("exhaustive", SamplerMode::Exhaustive);
    let (ladder_ad, t_ad) = ladder_leg("adaptive", SamplerMode::Adaptive { batch: 2 });
    let speedup_adaptive = t_ex / t_ad;
    println!(
        "δ-ladder exhaustive                          {ladder_jobs} jobs in {t_ex:.2}s \
         ({} evaluated)",
        ladder_ex.jobs_run
    );
    println!(
        "δ-ladder adaptive (batch 2)                  {ladder_jobs} jobs in {t_ad:.2}s \
         ({} evaluated, {} surrogate-pruned) | {speedup_adaptive:.2}x vs exhaustive",
        ladder_ad.jobs_run, ladder_ad.jobs_pruned_surrogate
    );
    let adaptive_doc = obj([
        ("jobs", Json::from(ladder_jobs)),
        ("exhaustive_elapsed_s", Json::from(t_ex)),
        ("adaptive_elapsed_s", Json::from(t_ad)),
        ("speedup_adaptive", Json::from(speedup_adaptive)),
        ("jobs_run_exhaustive", Json::from(ladder_ex.jobs_run)),
        ("jobs_run_adaptive", Json::from(ladder_ad.jobs_run)),
        ("jobs_pruned_surrogate", Json::from(ladder_ad.jobs_pruned_surrogate)),
        (
            "sampler_reranks",
            Json::from(ladder_ad.metrics.counter("sampler_reranks") as f64),
        ),
    ]);

    if let Some(out) = json_out {
        let doc = obj([
            ("bench", Json::from("campaign")),
            ("mode", Json::from(if smoke { "smoke" } else { "full" })),
            ("adaptive", adaptive_doc),
            (
                "serial_jobs_per_sec",
                match serial_t {
                    Some(t) => Json::from(n as f64 / t),
                    None => Json::Null,
                },
            ),
            ("runs", Json::Arr(measurements)),
            // Process metrics over the whole bench (phase histograms,
            // cache counters) so the perf trajectory keeps the internals.
            ("metrics", MetricsSnapshot::collect().diff(&metrics_before).to_json()),
        ]);
        std::fs::write(&out, doc.pretty(2)).expect("write bench json");
        println!("wrote {out}");
    }
}
