//! Native bit-faithful evaluator: the trained tiny CNN through the
//! approximate bf16 MAC datapath, entirely in Rust.
//!
//! Semantics mirror python/compile/kernels/ref.py exactly:
//!   bf16 RNE rounding -> sign/exp/mant decompose -> LUT significand product
//!   -> exact power-of-two scale -> f32 accumulation; zeros/denormals flush.
//! Layer plumbing mirrors python/compile/model.py (im2col patch order
//! (dy,dx,c), 'same' padding, maxpool2, fc).

use std::path::Path;
use std::sync::OnceLock;

use anyhow::{ensure, Context, Result};

use crate::approx::Multiplier;
use crate::runtime::artifacts::Artifacts;

/// bf16 round-to-nearest-even, result as f32 with low 16 bits zero.
#[inline]
pub fn bf16_round(x: f32) -> f32 {
    let bits = x.to_bits();
    let lsb = (bits >> 16) & 1;
    f32::from_bits(bits.wrapping_add(0x7FFF + lsb) & 0xFFFF_0000)
}

/// Exact f32 2^e for integer e (3-factor clamped chain; matches
/// ref.pow2_exact).
#[inline]
fn pow2_exact(e: i32) -> f32 {
    let factor = |ei: i32| f32::from_bits(((ei + 127) as u32) << 23);
    let e1 = e.clamp(-126, 127);
    let r = e - e1;
    let e2 = r.clamp(-126, 127);
    let e3 = (r - e2).clamp(-126, 127);
    factor(e1) * factor(e2) * factor(e3)
}

/// The shared 512-entry exponent-scale table: entry `s` — the sum of two
/// biased bf16 exponents, so 2..=510 for non-flushed operands — holds
/// `pow2_exact(s - 268)`, replacing the per-product `pow2_exact` chain of
/// the scalar path with one load. Process-global: the table depends on
/// nothing but IEEE-754, so every datapath (and the eval service's
/// backends) shares one copy.
fn scale_table() -> &'static [f32] {
    static SCALE: OnceLock<Vec<f32>> = OnceLock::new();
    SCALE.get_or_init(|| (0..512i32).map(|s| pow2_exact(s - 268)).collect())
}

/// Worker threads for row-chunked matmuls: `CARBON3D_MATMUL_THREADS` if
/// set (0/unparsable ignored), else the machine's available parallelism.
/// Thread count never changes results — rows are independent and per-row
/// accumulation order is fixed — so this is purely a throughput knob.
fn matmul_threads() -> usize {
    static THREADS: OnceLock<usize> = OnceLock::new();
    *THREADS.get_or_init(|| {
        std::env::var("CARBON3D_MATMUL_THREADS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&t| t > 0)
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            })
    })
}

/// Decode one operand for the table-driven path: pack `mant<<1 | signbit`
/// (the sign-folded-LUT index half) and keep the biased exponent
/// separately; exp == 0 marks zero/denormal (flushed).
#[inline]
fn decode(x: f32) -> (u32, i32) {
    let bits = bf16_round(x).to_bits();
    let exp = ((bits >> 23) & 0xFF) as i32;
    let key = ((bits >> 16) & 0x7F) << 1 | (bits >> 31);
    (key, exp)
}

/// The approximate MAC datapath for one multiplier LUT.
pub struct ApproxDatapath {
    /// 128x128 significand products (u16 range), f32 for parity with the
    /// AOT kernel input. Retained for `mul` / `matmul_reference`.
    lut: Vec<f32>,
    /// 256x256 sign-folded LUT: entry `(ma<<1|sa, mb<<1|sb)` holds
    /// `±lut[ma][mb]` with the product sign folded in, replacing the
    /// per-product XOR branch with a straight load. Bit-exact because
    /// IEEE-754 multiplication makes `(-sig)*scale == -(sig*scale)`.
    slut: Vec<f32>,
}

impl ApproxDatapath {
    pub fn new(mult: &Multiplier) -> Self {
        Self::from_lut(crate::approx::lut_f32(mult))
    }

    pub fn from_lut(lut: Vec<f32>) -> Self {
        assert_eq!(lut.len(), 128 * 128);
        let mut slut = vec![0f32; 256 * 256];
        for ma in 0..128usize {
            for mb in 0..128usize {
                let sig = lut[ma * 128 + mb];
                for sa in 0..2usize {
                    for sb in 0..2usize {
                        let v = if sa != sb { -sig } else { sig };
                        slut[((ma << 1) | sa) * 256 + ((mb << 1) | sb)] = v;
                    }
                }
            }
        }
        Self { lut, slut }
    }

    /// One approximate product (ref.approx_mul_elementwise semantics).
    #[inline]
    pub fn mul(&self, a: f32, b: f32) -> f32 {
        let ab = bf16_round(a).to_bits();
        let bb = bf16_round(b).to_bits();
        let ea = (ab >> 23) & 0xFF;
        let eb = (bb >> 23) & 0xFF;
        if ea == 0 || eb == 0 {
            return 0.0;
        }
        let ma = (ab >> 16) & 0x7F;
        let mb = (bb >> 16) & 0x7F;
        let sig = self.lut[(ma * 128 + mb) as usize];
        let scale = pow2_exact(ea as i32 + eb as i32 - 268);
        let sign = if (ab ^ bb) & 0x8000_0000 != 0 { -1.0f32 } else { 1.0f32 };
        sign * (sig * scale)
    }

    /// [M,K] x [K,N] matmul with f32 accumulation over ascending k.
    ///
    /// Hot path of the native evaluator, table-driven (DESIGN.md §7.6):
    /// operands are decomposed to (sign|mant, exp) *once* up front; each
    /// product is then two loads and a fused sign (the 256x256 sign-folded
    /// LUT) times a scale lookup (the shared 512-entry exponent table),
    /// and rows of M are chunked across std threads. Per-row accumulation
    /// order is unchanged, so results are bit-identical to
    /// [`ApproxDatapath::matmul_reference`] for every thread count.
    pub fn matmul(&self, a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        // Small problems (the tiny CNN's fc layer, unit-test shapes) don't
        // amortize scoped-thread spawn/join; run them inline.
        const PARALLEL_MIN_PRODUCTS: usize = 1 << 20;
        let threads =
            if m * k * n < PARALLEL_MIN_PRODUCTS { 1 } else { matmul_threads() };
        self.matmul_with_threads(a, b, m, k, n, threads)
    }

    /// [`ApproxDatapath::matmul`] with an explicit worker count (the
    /// property tests sweep this to pin thread-count independence).
    pub fn matmul_with_threads(
        &self,
        a: &[f32],
        b: &[f32],
        m: usize,
        k: usize,
        n: usize,
        threads: usize,
    ) -> Vec<f32> {
        let _span = crate::obs::span("native.matmul");
        assert_eq!(a.len(), m * k);
        assert_eq!(b.len(), k * n);
        let da: Vec<(u32, i32)> = a.iter().map(|&x| decode(x)).collect();
        let db: Vec<(u32, i32)> = b.iter().map(|&x| decode(x)).collect();
        let mut out = vec![0f32; m * n];
        if m == 0 || k == 0 || n == 0 {
            return out; // no products: all-zero output, as the loops produce
        }
        let threads = threads.clamp(1, m.max(1));
        if threads == 1 {
            let _chunk = crate::obs::span("native.matmul_chunk");
            self.matmul_rows(&da, &db, &mut out, k, n);
            return out;
        }
        let rows_per = m.div_ceil(threads);
        std::thread::scope(|scope| {
            for (a_rows, out_rows) in
                da.chunks(rows_per * k).zip(out.chunks_mut(rows_per * n))
            {
                let db = &db;
                scope.spawn(move || {
                    let _chunk = crate::obs::span("native.matmul_chunk");
                    self.matmul_rows(a_rows, db, out_rows, k, n)
                });
            }
        });
        out
    }

    /// The table-driven row kernel shared by every thread: `a_rows` and
    /// `out_rows` are matching row chunks of the operand/output matrices.
    fn matmul_rows(
        &self,
        a_rows: &[(u32, i32)],
        db: &[(u32, i32)],
        out_rows: &mut [f32],
        k: usize,
        n: usize,
    ) {
        let scale = scale_table();
        for (a_row, out_row) in a_rows.chunks(k).zip(out_rows.chunks_mut(n)) {
            for (kk, &(ka, ea)) in a_row.iter().enumerate() {
                if ea == 0 {
                    continue;
                }
                let base = (ka as usize) << 8;
                let srow = &self.slut[base..base + 256];
                let b_row = &db[kk * n..(kk + 1) * n];
                for (o, &(kb, eb)) in out_row.iter_mut().zip(b_row) {
                    if eb == 0 {
                        continue;
                    }
                    *o += srow[kb as usize] * scale[(ea + eb) as usize];
                }
            }
        }
    }

    /// The retained scalar reference: one `mul` per product with the same
    /// ascending-k accumulation order. Slow by design — the bit-identity
    /// property tests and `benches/native.rs` measure the table-driven
    /// path against this loop.
    pub fn matmul_reference(&self, a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        assert_eq!(a.len(), m * k);
        assert_eq!(b.len(), k * n);
        let mut out = vec![0f32; m * n];
        for i in 0..m {
            for kk in 0..k {
                let av = a[i * k + kk];
                let out_row = &mut out[i * n..(i + 1) * n];
                for (o, &bv) in out_row.iter_mut().zip(&b[kk * n..(kk + 1) * n]) {
                    *o += self.mul(av, bv);
                }
            }
        }
        out
    }
}

/// Trained tiny-CNN weights (PARAM_SPECS order, see python/compile/model.py).
#[derive(Debug, Clone)]
pub struct Weights {
    pub conv1_w: Vec<f32>, // [3,3,1,8]
    pub conv1_b: Vec<f32>, // [8]
    pub conv2_w: Vec<f32>, // [3,3,8,16]
    pub conv2_b: Vec<f32>, // [16]
    pub fc_w: Vec<f32>,    // [256,5]
    pub fc_b: Vec<f32>,    // [5]
}

/// Test-set images + labels.
#[derive(Debug, Clone)]
pub struct TestSet {
    pub images: Vec<f32>, // [n,16,16,1]
    pub labels: Vec<u8>,
    pub n: usize,
}

/// The native evaluator: weights + test set + forward pass.
pub struct NativeEvaluator {
    pub weights: Weights,
    pub testset: TestSet,
    pub exact_accuracy: f64,
}

pub const IMG: usize = 16;
pub const NUM_CLASSES: usize = 5;

impl NativeEvaluator {
    /// Load from the artifacts directory (weights.f32, testset_*, manifest).
    pub fn load(artifacts: &Artifacts) -> Result<Self> {
        let dir = &artifacts.dir;
        let w = read_f32(&dir.join("weights.f32"))?;
        let sizes = [3 * 3 * 8, 8, 3 * 3 * 8 * 16, 16, 256 * 5, 5];
        ensure!(
            w.len() == sizes.iter().sum::<usize>(),
            "weights.f32 has {} floats, want {}",
            w.len(),
            sizes.iter().sum::<usize>()
        );
        let mut off = 0;
        let mut take = |n: usize| {
            let v = w[off..off + n].to_vec();
            off += n;
            v
        };
        let weights = Weights {
            conv1_w: take(sizes[0]),
            conv1_b: take(sizes[1]),
            conv2_w: take(sizes[2]),
            conv2_b: take(sizes[3]),
            fc_w: take(sizes[4]),
            fc_b: take(sizes[5]),
        };
        let images = read_f32(&dir.join("testset_images.f32"))?;
        let labels = std::fs::read(dir.join("testset_labels.u8"))
            .context("read testset_labels.u8")?;
        let n = labels.len();
        ensure!(images.len() == n * IMG * IMG, "testset images/labels mismatch");
        Ok(Self {
            weights,
            testset: TestSet { images, labels, n },
            exact_accuracy: artifacts.exact_test_accuracy,
        })
    }

    /// Forward pass for a batch of images through the approximate datapath.
    /// `images` is [b,16,16,1] row-major. Returns logits [b,NUM_CLASSES].
    pub fn forward(&self, dp: &ApproxDatapath, images: &[f32], b: usize) -> Vec<f32> {
        let w = &self.weights;
        // conv1: 16x16x1 -> 16x16x8, relu, pool -> 8x8x8
        let c1 = conv2d_same(dp, images, b, IMG, IMG, 1, &w.conv1_w, &w.conv1_b, 8);
        let p1 = maxpool2(&relu(c1), b, IMG, IMG, 8);
        // conv2: 8x8x8 -> 8x8x16, relu, pool -> 4x4x16
        let c2 = conv2d_same(dp, &p1, b, 8, 8, 8, &w.conv2_w, &w.conv2_b, 16);
        let p2 = maxpool2(&relu(c2), b, 8, 8, 16);
        // fc: 256 -> 5
        let mut logits = dp.matmul(&p2, &w.fc_w, b, 256, NUM_CLASSES);
        for row in logits.chunks_mut(NUM_CLASSES) {
            for (x, bias) in row.iter_mut().zip(&w.fc_b) {
                *x += bias;
            }
        }
        logits
    }

    /// Top-1 accuracy of a multiplier datapath over the whole test set.
    pub fn accuracy(&self, dp: &ApproxDatapath) -> f64 {
        let n = self.testset.n;
        let mut correct = 0usize;
        // Batch to keep im2col buffers small.
        let bs = 64;
        for start in (0..n).step_by(bs) {
            let b = bs.min(n - start);
            let imgs = &self.testset.images[start * IMG * IMG..(start + b) * IMG * IMG];
            let logits = self.forward(dp, imgs, b);
            for i in 0..b {
                let row = &logits[i * NUM_CLASSES..(i + 1) * NUM_CLASSES];
                if argmax(row) == self.testset.labels[start + i] as usize {
                    correct += 1;
                }
            }
        }
        correct as f64 / n as f64
    }
}

/// Deterministic, NaN-safe top-1 argmax: the *first* index holding the
/// maximum non-NaN value. NaN logits never win (a NaN incumbent is
/// replaced by the first non-NaN candidate; `>` against NaN is false
/// otherwise), and an all-NaN row deterministically yields 0 — where the
/// old `partial_cmp(..).unwrap()` argmax panicked the whole evaluation.
/// Aggressive approximate multipliers can overflow logits to ±inf and
/// breed NaNs downstream, so this is reachable from real LUTs, not just
/// adversarial inputs.
pub fn argmax(row: &[f32]) -> usize {
    let mut best = 0usize;
    for (i, &v) in row.iter().enumerate().skip(1) {
        if (row[best].is_nan() && !v.is_nan()) || v > row[best] {
            best = i;
        }
    }
    best
}

fn relu(mut v: Vec<f32>) -> Vec<f32> {
    for x in &mut v {
        if *x < 0.0 {
            *x = 0.0;
        }
    }
    v
}

/// 'same' 3x3 conv via im2col + approx matmul; patch order (dy,dx,c) matches
/// model.im2col.
#[allow(clippy::too_many_arguments)]
fn conv2d_same(
    dp: &ApproxDatapath,
    x: &[f32],
    b: usize,
    h: usize,
    wd: usize,
    cin: usize,
    weights: &[f32], // [3,3,cin,cout]
    bias: &[f32],
    cout: usize,
) -> Vec<f32> {
    let k = 3usize;
    let pad = 1usize;
    let patch = k * k * cin;
    let mut cols = vec![0f32; b * h * wd * patch];
    for bi in 0..b {
        for y in 0..h {
            for xx in 0..wd {
                let row = ((bi * h + y) * wd + xx) * patch;
                let mut p = 0usize;
                for dy in 0..k {
                    for dx in 0..k {
                        let sy = y as isize + dy as isize - pad as isize;
                        let sx = xx as isize + dx as isize - pad as isize;
                        for c in 0..cin {
                            cols[row + p] = if sy >= 0
                                && sy < h as isize
                                && sx >= 0
                                && sx < wd as isize
                            {
                                x[((bi * h + sy as usize) * wd + sx as usize) * cin + c]
                            } else {
                                0.0
                            };
                            p += 1;
                        }
                    }
                }
            }
        }
    }
    // weights [3,3,cin,cout] flatten to [patch, cout] in the same (dy,dx,c)
    // order — the natural row-major flattening.
    let mut out = dp.matmul(&cols, weights, b * h * wd, patch, cout);
    for row in out.chunks_mut(cout) {
        for (v, bb) in row.iter_mut().zip(bias) {
            *v += bb;
        }
    }
    out
}

/// 2x2 max pooling, NHWC.
fn maxpool2(x: &[f32], b: usize, h: usize, w: usize, c: usize) -> Vec<f32> {
    let (oh, ow) = (h / 2, w / 2);
    let mut out = vec![f32::NEG_INFINITY; b * oh * ow * c];
    for bi in 0..b {
        for y in 0..oh {
            for xx in 0..ow {
                for ch in 0..c {
                    let mut m = f32::NEG_INFINITY;
                    for dy in 0..2 {
                        for dx in 0..2 {
                            let v = x[((bi * h + 2 * y + dy) * w + 2 * xx + dx) * c + ch];
                            if v > m {
                                m = v;
                            }
                        }
                    }
                    out[((bi * oh + y) * ow + xx) * c + ch] = m;
                }
            }
        }
    }
    out
}

fn read_f32(path: &Path) -> Result<Vec<f32>> {
    let bytes = std::fs::read(path).with_context(|| format!("read {}", path.display()))?;
    ensure!(bytes.len() % 4 == 0, "{}: not a multiple of 4 bytes", path.display());
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::{library, EXACT_ID};

    #[test]
    fn bf16_round_known_values() {
        assert_eq!(bf16_round(1.0), 1.0);
        assert_eq!(bf16_round(0.0), 0.0);
        // 1.00390625 = 1 + 2^-8 rounds to 1.0 in bf16 (RNE ties-to-even).
        assert_eq!(bf16_round(1.00390625), 1.0);
        // 1.0078125 = 1 + 2^-7 is exactly representable.
        assert_eq!(bf16_round(1.0078125), 1.0078125);
        assert_eq!(bf16_round(-2.5), -2.5);
    }

    #[test]
    fn pow2_exact_matches_f64() {
        for e in -250..=250 {
            let got = pow2_exact(e) as f64;
            let want = 2f64.powi(e);
            // Representable range of f32 (incl. denormals handled by chain).
            if (-126..=127).contains(&e) {
                assert_eq!(got, want, "e={e}");
            }
        }
    }

    #[test]
    fn exact_datapath_matches_bf16_product() {
        let lib = library();
        let dp = ApproxDatapath::new(&lib[EXACT_ID]);
        let vals = [0.0f32, 1.0, -1.5, 0.3, 7.25, -100.0, 3.1415926, 1e-3];
        for &a in &vals {
            for &b in &vals {
                let want = bf16_round(a) * bf16_round(b);
                let got = dp.mul(a, b);
                assert_eq!(got, want, "mul({a},{b})");
            }
        }
    }

    #[test]
    fn matmul_exact_lut_matches_naive() {
        let lib = library();
        let dp = ApproxDatapath::new(&lib[EXACT_ID]);
        let a: Vec<f32> = (0..6).map(|i| i as f32 * 0.5 - 1.0).collect(); // 2x3
        let b: Vec<f32> = (0..12).map(|i| (i as f32).sin()).collect(); // 3x4
        let got = dp.matmul(&a, &b, 2, 3, 4);
        for i in 0..2 {
            for j in 0..4 {
                let mut want = 0f32;
                for k in 0..3 {
                    want += bf16_round(a[i * 3 + k]) * bf16_round(b[k * 4 + j]);
                }
                assert!((got[i * 4 + j] - want).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn scale_table_matches_pow2_exact() {
        let t = scale_table();
        assert_eq!(t.len(), 512);
        for s in 2..=510i32 {
            assert_eq!(
                t[s as usize].to_bits(),
                pow2_exact(s - 268).to_bits(),
                "exponent sum {s}"
            );
        }
    }

    #[test]
    fn sign_folded_lut_matches_mul_scalar() {
        // Single products through the table-driven path equal `mul` bitwise,
        // across signs, magnitudes, zeros, and denormals.
        let lib = library();
        for m in [&lib[EXACT_ID], &lib[5], &lib[17], lib.last().unwrap()] {
            let dp = ApproxDatapath::new(m);
            let vals = [
                0.0f32, -0.0, 1.0, -1.0, 0.3, -0.7, 7.25, -100.0, 1e-3, 1e-39, -1e-39, 3e38,
            ];
            for &a in &vals {
                for &b in &vals {
                    let got = dp.matmul(&[a], &[b], 1, 1, 1)[0];
                    let want = {
                        // Flushed products are skipped by matmul (output
                        // stays +0.0) and returned as +0.0 by mul; both add
                        // to the same accumulation.
                        let v = dp.mul(a, b);
                        0.0f32 + v
                    };
                    assert_eq!(got.to_bits(), want.to_bits(), "{}: mul({a},{b})", m.name());
                }
            }
        }
    }

    #[test]
    fn matmul_bit_identical_to_reference_prop() {
        // The tentpole oracle: the table-driven, row-chunked matmul must be
        // byte-equal (`to_bits`) to the retained scalar `mul` loop across
        // multiplier families, random shapes, zeros/denormals, and thread
        // counts.
        let lib = library();
        // One design per family: exact, perforation, truncation,
        // broken-array, OR-compress, Mitchell, DRUM, hybrid.
        let family_ids =
            [EXACT_ID, 1, 8, 13, 21, 28, 29, lib.len() - 1];
        for (fi, &mid) in family_ids.iter().enumerate() {
            let dp = ApproxDatapath::new(&lib[mid]);
            crate::util::prop::check(&format!("matmul-bits-{mid}"), 6, |rng| {
                let (m, k, n) = (rng.range(1, 9), rng.range(1, 20), rng.range(1, 7));
                let mut sample = |len: usize| -> Vec<f32> {
                    (0..len)
                        .map(|_| match rng.below(8) {
                            0 => 0.0,
                            1 => -0.0,
                            2 => 1e-39,                      // denormal: flushed
                            3 => (rng.uniform(-3e4, 3e4)) as f32,
                            _ => (rng.uniform(-4.0, 4.0)) as f32,
                        })
                        .collect()
                };
                let a = sample(m * k);
                let b = sample(k * n);
                let want = dp.matmul_reference(&a, &b, m, k, n);
                for threads in [1usize, 2, 3, 8] {
                    let got = dp.matmul_with_threads(&a, &b, m, k, n, threads);
                    let want_bits: Vec<u32> = want.iter().map(|x| x.to_bits()).collect();
                    let got_bits: Vec<u32> = got.iter().map(|x| x.to_bits()).collect();
                    assert_eq!(
                        got_bits, want_bits,
                        "family #{fi} (mult {mid}), shape {m}x{k}x{n}, {threads} threads"
                    );
                }
            });
        }
    }

    #[test]
    fn matmul_empty_dims_are_safe() {
        let lib = library();
        let dp = ApproxDatapath::new(&lib[EXACT_ID]);
        assert!(dp.matmul(&[], &[0.0; 12], 0, 3, 4).is_empty());
        assert_eq!(dp.matmul(&[], &[], 2, 0, 3), vec![0.0; 6]);
        assert!(dp.matmul(&[1.0, 2.0], &[], 2, 1, 0).is_empty());
    }

    #[test]
    fn argmax_is_nan_safe_deterministic_first_max() {
        // Regression for the `partial_cmp(..).unwrap()` panic: NaN logits
        // must neither panic nor win, and ties resolve to the first index.
        assert_eq!(argmax(&[1.0, f32::NAN, 2.0]), 2);
        assert_eq!(argmax(&[f32::NAN, 1.0, 0.5]), 1);
        assert_eq!(argmax(&[f32::NAN, f32::NAN]), 0);
        assert_eq!(argmax(&[3.0, 3.0, 1.0]), 0);
        assert_eq!(argmax(&[f32::NEG_INFINITY, f32::INFINITY]), 1);
        assert_eq!(argmax(&[0.25]), 0);
        assert_eq!(argmax(&[-0.0, 0.0]), 0); // -0.0 == 0.0: first wins
    }

    #[test]
    fn accuracy_survives_nan_logits() {
        // A weight set whose fc bias is NaN drives every logit to NaN; the
        // pass must yield a deterministic accuracy, not a panic.
        let n = 4usize;
        let ne = NativeEvaluator {
            weights: Weights {
                conv1_w: vec![0.0; 72],
                conv1_b: vec![0.0; 8],
                conv2_w: vec![0.0; 1152],
                conv2_b: vec![0.0; 16],
                fc_w: vec![0.0; 1280],
                fc_b: vec![f32::NAN; 5],
            },
            testset: TestSet {
                images: vec![0.5; n * IMG * IMG],
                labels: vec![0, 1, 0, 2],
                n,
            },
            exact_accuracy: 0.0,
        };
        let lib = library();
        let dp = ApproxDatapath::new(&lib[EXACT_ID]);
        // All-NaN rows argmax to class 0: exactly the label-0 images score.
        let acc = ne.accuracy(&dp);
        assert!((acc - 0.5).abs() < 1e-12, "accuracy {acc}");
    }

    #[test]
    fn truncated_datapath_underestimates_magnitude() {
        let lib = library();
        let trunc = lib.iter().find(|m| m.name() == "TRUNC4").unwrap();
        let dp_t = ApproxDatapath::new(trunc);
        let dp_e = ApproxDatapath::new(&lib[EXACT_ID]);
        for (a, b) in [(1.7f32, 2.3f32), (0.9, -0.4), (-3.3, -1.1)] {
            assert!(dp_t.mul(a, b).abs() <= dp_e.mul(a, b).abs() + 1e-9);
        }
    }

    #[test]
    fn maxpool_hand_case() {
        // 1x4x4x1 ascending values.
        let x: Vec<f32> = (0..16).map(|i| i as f32).collect();
        let out = maxpool2(&x, 1, 4, 4, 1);
        assert_eq!(out, vec![5.0, 7.0, 13.0, 15.0]);
    }

    #[test]
    fn conv_identity_kernel_preserves_input() {
        // 3x3 kernel with only the center tap = 1 reproduces the input.
        let lib = library();
        let dp = ApproxDatapath::new(&lib[EXACT_ID]);
        let x: Vec<f32> = (0..16).map(|i| (i as f32) * 0.125).collect(); // 1x4x4x1
        let mut w = vec![0f32; 9];
        w[4] = 1.0; // center (dy=1,dx=1)
        let out = conv2d_same(&dp, &x, 1, 4, 4, 1, &w, &[0.0], 1);
        for (got, want) in out.iter().zip(&x) {
            assert!((got - bf16_round(*want)).abs() < 1e-6);
        }
    }
}
