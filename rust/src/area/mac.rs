//! bfloat16 MAC-unit area composition (paper §III-C).
//!
//! Each MAC = mantissa multiplier (the approximated block) + two exact 8-bit
//! exponent adders + exact 24-bit accumulator adder + normalization/rounding
//! logic + pipeline registers. Only the multiplier is swapped by the DSE.

use super::node::TechNode;
use crate::approx::cost::GateCounts;
use crate::approx::Multiplier;

/// Gate counts of the fixed (never approximated) MAC blocks.
fn fixed_blocks() -> GateCounts {
    GateCounts {
        and2: 0,
        // two 8-bit ripple adders (16 FA) + 24-bit accumulator (24 FA)
        fa: 16 + 24,
        ha: 2,
        // alignment shifter, normalization, rounding, sign logic, pipeline
        // registers of the bf16 datapath (~70 NAND2-equivalents; the
        // multiplier dominates the MAC, paper §III-C).
        aux: 70,
    }
}

/// Total MAC area (um^2) for a given mantissa multiplier at a node.
pub fn mac_area_um2(mult: &Multiplier, node: TechNode) -> f64 {
    let fixed = fixed_blocks().hw_cost(node).area_um2;
    fixed + mult.hw_cost(node).area_um2
}

/// MAC dynamic power (uW) at the node clock.
pub fn mac_power_uw(mult: &Multiplier, node: TechNode) -> f64 {
    fixed_blocks().hw_cost(node).power_uw + mult.hw_cost(node).power_uw
}

/// Fraction of the MAC area occupied by the multiplier (the paper's
/// motivation: multipliers dominate).
pub fn multiplier_area_fraction(mult: &Multiplier, node: TechNode) -> f64 {
    mult.hw_cost(node).area_um2 / mac_area_um2(mult, node)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::{library, EXACT_ID};

    #[test]
    fn multiplier_dominates_exact_mac() {
        // Paper §III-C: the multiplier is the most area-intensive component.
        let lib = library();
        for node in crate::area::node::ALL_NODES {
            let frac = multiplier_area_fraction(&lib[EXACT_ID], node);
            assert!(frac > 0.4, "{}: multiplier fraction {frac}", node.name());
        }
    }

    #[test]
    fn approx_mac_smaller_than_exact_mac() {
        let lib = library();
        let node = TechNode::N14;
        let exact = mac_area_um2(&lib[EXACT_ID], node);
        for m in &lib[1..] {
            assert!(mac_area_um2(m, node) < exact, "{}", m.name());
        }
    }

    #[test]
    fn mac_area_savings_bounded_by_multiplier_share() {
        // Even the tiniest multiplier cannot shrink the MAC below the fixed
        // blocks' area.
        let lib = library();
        let node = TechNode::N7;
        let fixed = fixed_blocks().hw_cost(node).area_um2;
        for m in &lib {
            assert!(mac_area_um2(m, node) > fixed);
        }
    }

    #[test]
    fn power_positive_and_ordered() {
        let lib = library();
        let exact = mac_power_uw(&lib[EXACT_ID], TechNode::N45);
        let small = lib
            .iter()
            .map(|m| mac_power_uw(m, TechNode::N45))
            .fold(f64::INFINITY, f64::min);
        assert!(small > 0.0 && small < exact);
    }
}
