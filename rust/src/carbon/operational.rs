//! Operational carbon + lifetime totals (the §II discussion around [17]:
//! embodied and operational emissions live on different scales and the
//! paper therefore optimizes embodied carbon; this module quantifies the
//! comparison for our reproduction instead of asserting it).

use crate::area::TechNode;
use crate::dataflow::arch::AccelConfig;
use crate::dataflow::energy::EnergyModel;
use crate::dataflow::mapper::NetworkMapping;
use crate::approx::Multiplier;

/// Grid carbon intensity at the *deployment* site, kgCO2/kWh (world-average
/// edge deployment; the fab's CI is a separate constant in `super`).
pub const CI_USE_KGCO2_PER_KWH: f64 = 0.4;

/// Device lifetime assumptions for edge AI (ACT-style): 3 years, duty-cycled
/// inference.
pub const LIFETIME_YEARS: f64 = 3.0;

/// Default duty cycle: 10k inferences/day (a few per second, duty-cycled).
pub const DEFAULT_INFERENCES_PER_DAY: f64 = 10_000.0;

/// Deployment assumptions for lifetime-carbon accounting: how long the
/// device serves, how hard it works, and how dirty its electricity is.
/// These are the knobs the `lifetime-cdp` campaign objective exposes
/// (`--lifetime-years`, `--ipd`, `--grid-gco2-kwh`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Deployment {
    pub lifetime_years: f64,
    /// Duty cycle, expressed as inferences per day.
    pub inferences_per_day: f64,
    pub grid_kgco2_per_kwh: f64,
}

impl Default for Deployment {
    fn default() -> Self {
        Self {
            lifetime_years: LIFETIME_YEARS,
            inferences_per_day: DEFAULT_INFERENCES_PER_DAY,
            grid_kgco2_per_kwh: CI_USE_KGCO2_PER_KWH,
        }
    }
}

impl Deployment {
    /// Total inferences served over the deployment's lifetime.
    pub fn lifetime_inferences(&self) -> f64 {
        self.inferences_per_day * self.lifetime_years * 365.0
    }

    /// Lifetime operational energy (kWh) at a given energy per inference.
    pub fn lifetime_kwh(&self, energy_per_inference_j: f64) -> f64 {
        energy_per_inference_j * self.lifetime_inferences() / 3.6e6
    }

    /// Lifetime operational carbon (gCO2) at a given energy per inference.
    /// Strictly monotone in every deployment knob and in the energy.
    pub fn lifetime_gco2(&self, energy_per_inference_j: f64) -> f64 {
        self.lifetime_kwh(energy_per_inference_j) * self.grid_kgco2_per_kwh * 1000.0
    }
}

/// Operational-carbon summary for a deployment scenario.
#[derive(Debug, Clone, Copy)]
pub struct OperationalCarbon {
    pub energy_per_inference_j: f64,
    pub inferences_per_day: f64,
    pub lifetime_kwh: f64,
    pub lifetime_gco2: f64,
}

/// Operational carbon over a configurable deployment.
pub fn operational_carbon_with(
    cfg: &AccelConfig,
    mult: &Multiplier,
    mapping: &NetworkMapping,
    deployment: &Deployment,
) -> OperationalCarbon {
    let em = EnergyModel::for_config(cfg, mult);
    let e_inf = em.network_energy_j(mapping);
    OperationalCarbon {
        energy_per_inference_j: e_inf,
        inferences_per_day: deployment.inferences_per_day,
        lifetime_kwh: deployment.lifetime_kwh(e_inf),
        lifetime_gco2: deployment.lifetime_gco2(e_inf),
    }
}

/// Operational carbon over the default device lifetime at a given inference
/// rate (the `Deployment`-less convenience entry point).
pub fn operational_carbon(
    cfg: &AccelConfig,
    mult: &Multiplier,
    mapping: &NetworkMapping,
    inferences_per_day: f64,
) -> OperationalCarbon {
    let deployment = Deployment { inferences_per_day, ..Deployment::default() };
    operational_carbon_with(cfg, mult, mapping, &deployment)
}

/// Embodied share of the lifetime total: the paper's edge-device motivation
/// is that this is large.
pub fn embodied_share(embodied_g: f64, operational: &OperationalCarbon) -> f64 {
    embodied_g / (embodied_g + operational.lifetime_gco2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::area::die::Integration;
    use crate::approx::{library, EXACT_ID};
    use crate::carbon::embodied_carbon;
    use crate::dataflow::mapper::map_network;
    use crate::dataflow::workloads::workload;

    fn setup() -> (AccelConfig, NetworkMapping) {
        let cfg = AccelConfig {
            px: 32,
            py: 32,
            rf_bytes: 128,
            sram_bytes: 512 << 10,
            node: TechNode::N7,
            integration: Integration::ThreeD,
            mult_id: EXACT_ID,
        };
        let w = workload("resnet50").unwrap();
        let m = map_network(&w, &cfg);
        (cfg, m)
    }

    #[test]
    fn lifetime_scales_linearly_with_rate() {
        let lib = library();
        let (cfg, m) = setup();
        let a = operational_carbon(&cfg, &lib[EXACT_ID], &m, 1000.0);
        let b = operational_carbon(&cfg, &lib[EXACT_ID], &m, 2000.0);
        assert!((b.lifetime_gco2 / a.lifetime_gco2 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn embodied_dominates_light_duty_edge_devices() {
        // The paper's §I premise: for duty-cycled edge inference, embodied
        // carbon is a significant (often dominant) share.
        let lib = library();
        let (cfg, m) = setup();
        let areas = cfg.die_areas(&lib[EXACT_ID]);
        let emb = embodied_carbon(&areas, cfg.node, cfg.integration).total_g();
        // 10k inferences/day (a few per second duty-cycled).
        let op = operational_carbon(&cfg, &lib[EXACT_ID], &m, 10_000.0);
        let share = embodied_share(emb, &op);
        assert!(share > 0.25, "embodied share {share} (emb {emb} g vs op {} g)", op.lifetime_gco2);
    }

    #[test]
    fn heavy_duty_flips_toward_operational() {
        let lib = library();
        let (cfg, m) = setup();
        let areas = cfg.die_areas(&lib[EXACT_ID]);
        let emb = embodied_carbon(&areas, cfg.node, cfg.integration).total_g();
        let light = operational_carbon(&cfg, &lib[EXACT_ID], &m, 1_000.0);
        let heavy = operational_carbon(&cfg, &lib[EXACT_ID], &m, 3_000_000.0);
        assert!(embodied_share(emb, &light) > embodied_share(emb, &heavy));
        assert!(embodied_share(emb, &heavy) < 0.5);
    }

    #[test]
    fn lifetime_gco2_is_monotone_in_every_deployment_knob() {
        // Property-style sweep: bumping any single knob (or the energy)
        // strictly increases lifetime operational carbon.
        let base = Deployment::default();
        let energies = [1e-4, 1e-3, 1e-2, 1e-1, 1.0];
        for &e in &energies {
            let v0 = base.lifetime_gco2(e);
            assert!(v0 > 0.0);
            for factor in [1.5, 2.0, 10.0] {
                let years = Deployment { lifetime_years: base.lifetime_years * factor, ..base };
                let duty =
                    Deployment { inferences_per_day: base.inferences_per_day * factor, ..base };
                let grid =
                    Deployment { grid_kgco2_per_kwh: base.grid_kgco2_per_kwh * factor, ..base };
                assert!(years.lifetime_gco2(e) > v0, "years x{factor} at {e} J");
                assert!(duty.lifetime_gco2(e) > v0, "duty x{factor} at {e} J");
                assert!(grid.lifetime_gco2(e) > v0, "grid x{factor} at {e} J");
                assert!(base.lifetime_gco2(e * factor) > v0, "energy x{factor} at {e} J");
            }
        }
        // And each knob scales linearly: doubling it doubles the total.
        let d2 = Deployment { lifetime_years: base.lifetime_years * 2.0, ..base };
        assert!((d2.lifetime_gco2(0.01) / base.lifetime_gco2(0.01) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn deployment_default_matches_legacy_constants() {
        // `operational_carbon` (the pre-Deployment API) and
        // `operational_carbon_with` at the default deployment must agree.
        let lib = library();
        let (cfg, m) = setup();
        let a = operational_carbon(&cfg, &lib[EXACT_ID], &m, DEFAULT_INFERENCES_PER_DAY);
        let d = Deployment::default();
        let b = operational_carbon_with(&cfg, &lib[EXACT_ID], &m, &d);
        assert_eq!(a.lifetime_gco2, b.lifetime_gco2);
        assert_eq!(a.lifetime_kwh, b.lifetime_kwh);
        assert!((d.lifetime_gco2(a.energy_per_inference_j) - a.lifetime_gco2).abs() < 1e-12);
    }

    #[test]
    fn approx_mult_cuts_operational_energy_too() {
        let lib = library();
        let (mut cfg, m) = setup();
        let t2p3 = lib.iter().find(|x| x.name() == "T2P3").unwrap();
        let exact = operational_carbon(&cfg, &lib[EXACT_ID], &m, 10_000.0);
        cfg.mult_id = t2p3.id;
        let appx = operational_carbon(&cfg, t2p3, &m, 10_000.0);
        assert!(appx.energy_per_inference_j < exact.energy_per_inference_j);
    }
}
