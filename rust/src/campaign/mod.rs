//! Design-space-exploration **campaign engine**: run an entire scenario
//! grid — {workload} x {TechNode} x {Integration} x {δ} x {FPS floor} — as
//! a job queue drained by a pool of std-thread workers, instead of one
//! GA-APPX-CDP invocation at a time.
//!
//! The pieces:
//! - [`spec`]: grid definition; per-job GA seeds derive from the campaign
//!   seed + the job *key*, so results are reproducible for any worker count
//!   and stable under grid growth.
//! - [`scheduler`]: the worker pool. All workers share ONE
//!   [`crate::runtime::EvalService`], so multiplier-accuracy evaluations are
//!   cached campaign-globally — the δ-feasible sets of neighboring scenarios
//!   overlap almost entirely, making every job after the first nearly free
//!   on the accuracy side. Results are committed in job-id order through a
//!   reorder buffer.
//! - [`store`]: append-only JSONL with checkpoint/resume — on restart,
//!   completed jobs are detected by key and skipped; a torn final line from
//!   an interrupted write is dropped and its job redone.
//! - [`pareto`]: cross-scenario Pareto archive over (embodied carbon, task
//!   delay, accuracy drop) with per-node / per-workload aggregates.
//!
//! Invariant the tests pin down: for a fixed campaign seed, the final store
//! bytes are identical whether the campaign ran uninterrupted with any
//! number of workers or was killed and resumed.

pub mod pareto;
pub mod scheduler;
pub mod spec;
pub mod store;

pub use pareto::{CampaignArchive, GroupBy};
pub use scheduler::{run_campaign, start_service, CampaignReport, SurrogateBackend};
pub use spec::{CampaignSpec, JobSpec};
pub use store::ResultStore;

#[cfg(test)]
mod tests {
    use std::path::PathBuf;

    use super::*;
    use crate::area::TechNode;
    use crate::ga::GaParams;
    use crate::runtime::EvalService;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!(
            "carbon3d-campaign-{}-{name}.jsonl",
            std::process::id()
        ))
    }

    /// 2 models x 2 nodes x 2 deltas = 8 jobs, tiny GA budget.
    fn quick_spec() -> CampaignSpec {
        let mut s = CampaignSpec::new(
            vec!["vgg16".to_string(), "resnet50".to_string()],
            vec![TechNode::N45, TechNode::N7],
            vec![1.0, 3.0],
        );
        s.ga = GaParams { population: 8, generations: 4, patience: 2, elites: 1, ..Default::default() };
        s
    }

    fn run_to(path: &PathBuf, workers: usize) -> (CampaignReport, String) {
        let mut store = ResultStore::open(path).unwrap();
        // Surrogate backend: deterministic and artifact-free.
        let svc = EvalService::start(SurrogateBackend::default());
        let report = run_campaign(&quick_spec(), workers, &mut store, &svc).unwrap();
        svc.shutdown();
        (report, std::fs::read_to_string(path).unwrap())
    }

    #[test]
    fn campaign_resume_and_worker_count_are_invisible_in_the_store() {
        let (p4, p1, pr) = (tmp("w4"), tmp("w1"), tmp("resume"));
        for p in [&p4, &p1, &pr] {
            let _ = std::fs::remove_file(p);
        }

        // Uninterrupted, 4 workers.
        let (report, bytes4) = run_to(&p4, 4);
        assert_eq!(report.jobs_total, 8);
        assert_eq!(report.jobs_run, 8);
        assert_eq!(report.jobs_skipped, 0);
        assert_eq!(bytes4.lines().count(), 8);

        // Campaign-global cache: 8 jobs each request the full library, but
        // only the first evaluates it — everything later is cross-job hits.
        let lib_len = crate::approx::library().len();
        assert_eq!(report.stats.served, 8 * lib_len);
        assert!(report.stats.evaluated <= lib_len, "{:?}", report.stats);
        assert!(report.stats.cache_hits > 0, "{:?}", report.stats);
        assert!(report.stats.hit_rate() > 0.5, "{:?}", report.stats);

        // Same grid, 1 worker: byte-identical store.
        let (_, bytes1) = run_to(&p1, 1);
        assert_eq!(bytes4, bytes1, "store depends on worker interleaving");

        // Kill after 5 jobs (truncate), then resume: identical store again.
        let prefix: String =
            bytes4.lines().take(5).map(|l| format!("{l}\n")).collect();
        std::fs::write(&pr, prefix).unwrap();
        let (resumed, bytes_r) = run_to(&pr, 3);
        assert_eq!(resumed.jobs_skipped, 5);
        assert_eq!(resumed.jobs_run, 3);
        assert_eq!(bytes_r, bytes4, "resume diverged from uninterrupted run");

        // The archive reads the store back: 8 points, a nonempty front,
        // and aggregates grouped by the grid's 2 nodes / 2 models.
        let store = ResultStore::open(&p4).unwrap();
        let arch = CampaignArchive::from_rows(store.rows()).unwrap();
        assert_eq!(arch.points.len(), 8);
        assert!(!arch.front.is_empty());
        assert_eq!(arch.aggregate_table(GroupBy::Node).n_rows(), 2);
        assert_eq!(arch.aggregate_table(GroupBy::Model).n_rows(), 2);

        for p in [&p4, &p1, &pr] {
            let _ = std::fs::remove_file(p);
        }
    }

    #[test]
    fn rerun_of_complete_campaign_is_a_noop() {
        let p = tmp("noop");
        let _ = std::fs::remove_file(&p);
        let (_, bytes) = run_to(&p, 2);
        let (report, bytes_again) = run_to(&p, 2);
        assert_eq!(report.jobs_run, 0);
        assert_eq!(report.jobs_skipped, 8);
        assert_eq!(report.stats.served, 0);
        assert_eq!(bytes, bytes_again);
        let _ = std::fs::remove_file(&p);
    }
}
