//! CACTI-lite: analytical SRAM / register-file area model (CACTI stand-in).
//!
//! CACTI decomposes a memory into banks -> subarrays of bit cells plus
//! periphery (row decoders, wordline drivers, sense amps, column muxes,
//! output drivers). The area trend it produces is:
//!
//!   A(bits) = bits * cell_area * array_efficiency^-1
//!
//! where array efficiency rises with capacity (periphery amortizes) and
//! saturates around 70-80% for megabyte-class SRAMs, dropping steeply for
//! small arrays. We model efficiency with the subarray decomposition
//! directly, which reproduces CACTI's published area-vs-capacity curve
//! shape per node (DESIGN.md §6.4).

use super::node::TechNode;

/// Bits per subarray (CACTI default-ish 512 rows x 512 cols is too large for
/// small buffers; 256x256 balances decoder depth vs cell count).
const SUBARRAY_BITS: f64 = 256.0 * 256.0;

/// Periphery overhead of one subarray, in bit-cell equivalents:
/// row decoder + wordline drivers ~ 2 cells/row, sense amps + column mux
/// ~ 8 cells/column, plus fixed control.
fn subarray_overhead_cells(rows: f64, cols: f64) -> f64 {
    2.0 * rows + 8.0 * cols + 1500.0
}

/// SRAM macro area in mm^2 for a capacity in bytes at a node.
pub fn sram_area_mm2(bytes: usize, node: TechNode) -> f64 {
    assert!(bytes > 0, "sram_area_mm2: zero capacity");
    let bits = bytes as f64 * 8.0;
    let n_sub = (bits / SUBARRAY_BITS).ceil().max(1.0);
    let rows = 256.0_f64.min((bits / n_sub).sqrt().ceil());
    let cols = (bits / n_sub / rows).ceil();
    let cells_per_sub = rows * cols + subarray_overhead_cells(rows, cols);
    // Bank-level routing/control overhead: 8% + H-tree growing slowly with
    // the number of subarrays.
    let bank_factor = 1.08 + 0.02 * (n_sub.log2().max(0.0));
    let total_cells = n_sub * cells_per_sub * bank_factor;
    total_cells * node.sram_bitcell_um2() / 1e6
}

/// Register-file area in um^2 for a per-PE local buffer of `bytes`.
/// RFs are flop/multi-port-cell based: bigger cells, higher periphery ratio
/// at small sizes.
pub fn rf_area_um2(bytes: usize, node: TechNode) -> f64 {
    assert!(bytes > 0, "rf_area_um2: zero capacity");
    let bits = bytes as f64 * 8.0;
    // Decoder + read/write ports amortized: small RFs pay proportionally
    // more (floor of ~25% overhead, shrinking to ~12% at 1KB+).
    let overhead = 1.12 + 0.13 * (512.0 / (bits + 512.0));
    bits * node.rf_bitcell_um2() * overhead
}

/// Array efficiency (cell area / total area) — exposed for tests and reports.
pub fn array_efficiency(bytes: usize, node: TechNode) -> f64 {
    let cell = bytes as f64 * 8.0 * node.sram_bitcell_um2() / 1e6;
    cell / sram_area_mm2(bytes, node)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn area_monotone_in_capacity() {
        let node = TechNode::N14;
        let mut prev = 0.0;
        for kb in [4usize, 16, 64, 256, 1024, 4096] {
            let a = sram_area_mm2(kb * 1024, node);
            assert!(a > prev, "{kb}KB: {a} !> {prev}");
            prev = a;
        }
    }

    #[test]
    fn efficiency_rises_then_saturates() {
        // Periphery amortizes from small to mid arrays; at multi-MB sizes
        // the H-tree/banking overhead grows again but efficiency stays high.
        let node = TechNode::N7;
        let small = array_efficiency(2 * 1024, node);
        let mid = array_efficiency(128 * 1024, node);
        let big = array_efficiency(4 * 1024 * 1024, node);
        assert!(small < mid, "{small} !< {mid}");
        assert!((0.5..0.95).contains(&big), "big-array efficiency {big}");
        assert!((0.5..0.95).contains(&mid), "mid-array efficiency {mid}");
    }

    #[test]
    fn megabyte_sram_area_ballpark() {
        // 1MB at 14nm: cell area alone = 8Mbit * 0.064um^2 ~ 0.54mm^2;
        // with periphery we expect ~0.6-0.9mm^2 (CACTI-like).
        let a = sram_area_mm2(1024 * 1024, TechNode::N14);
        assert!((0.55..0.95).contains(&a), "1MB@14nm = {a} mm^2");
    }

    #[test]
    fn node_scaling_follows_bitcell() {
        let b = 256 * 1024;
        let r45 = sram_area_mm2(b, TechNode::N45) / sram_area_mm2(b, TechNode::N7);
        let cell_ratio = TechNode::N45.sram_bitcell_um2() / TechNode::N7.sram_bitcell_um2();
        assert!((r45 / cell_ratio - 1.0).abs() < 0.25, "ratio {r45} vs cell {cell_ratio}");
    }

    #[test]
    fn rf_area_scales_linearly_at_large_sizes() {
        let node = TechNode::N45;
        let a1 = rf_area_um2(512, node);
        let a2 = rf_area_um2(1024, node);
        let ratio = a2 / a1;
        assert!((1.8..2.2).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn rf_cell_bigger_than_sram_cell() {
        // Same capacity: RF must be bigger than SRAM cells alone.
        let bytes = 64 * 1024;
        let rf = rf_area_um2(bytes, TechNode::N14) / 1e6;
        let sram = sram_area_mm2(bytes, TechNode::N14);
        assert!(rf > sram);
    }

    #[test]
    fn area_superadditive_under_split_prop() {
        // Building one big SRAM is never worse than two halves (periphery
        // amortization) — property over random capacities.
        prop::check("sram-superadd", 40, |rng| {
            let bytes = rng.range(8 * 1024, 4 * 1024 * 1024);
            let whole = sram_area_mm2(bytes, TechNode::N14);
            let half = sram_area_mm2(bytes / 2, TechNode::N14);
            assert!(whole <= 2.0 * half * 1.02, "bytes={bytes} {whole} vs {}", 2.0 * half);
        });
    }

    #[test]
    #[should_panic]
    fn zero_capacity_panics() {
        sram_area_mm2(0, TechNode::N45);
    }
}
