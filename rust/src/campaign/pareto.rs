//! Cross-scenario Pareto archive: every committed campaign row is a point
//! in (carbon, task delay, accuracy drop) space — where "carbon" is the
//! campaign objective's metric (embodied gCO2, or lifetime gCO2 for the
//! lifetime objectives) — and the archive keeps the non-dominated set
//! across ALL scenarios.
//!
//! The archive is **incremental**: the commit pipeline calls the method
//! [`CampaignArchive::insert_row`] as each row commits, so the front is
//! maintained in O(|front|) per insert instead of recomputed O(n^2) from
//! the full store. It is also **checkpointed** beside the JSONL store (see
//! [`crate::campaign::checkpoint`]) and rendered into summary tables and
//! cross-campaign merged fronts (see [`crate::campaign::front`]).

use anyhow::{Context, Result};

use crate::util::Json;

/// Which carbon metric spans the archive's first objective axis.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CarbonAxis {
    /// Embodied gCO2 (the paper's view).
    Embodied,
    /// Embodied + lifetime operational gCO2.
    Lifetime,
}

impl CarbonAxis {
    pub fn name(&self) -> &'static str {
        match self {
            CarbonAxis::Embodied => "embodied",
            CarbonAxis::Lifetime => "lifetime",
        }
    }

    pub fn from_name(s: &str) -> Option<Self> {
        match s {
            "embodied" => Some(CarbonAxis::Embodied),
            "lifetime" => Some(CarbonAxis::Lifetime),
            _ => None,
        }
    }
}

/// One campaign result as an objective-space point (all minimized).
#[derive(Debug, Clone)]
pub struct ArchivePoint {
    pub key: String,
    pub model: String,
    pub node: String,
    pub mult: String,
    /// The objective the row's campaign optimized (cross-campaign merges
    /// tag points with it; legacy rows default to the paper's objective).
    pub objective: String,
    pub carbon_g: f64,
    /// Embodied + lifetime operational carbon; equals `carbon_g` for rows
    /// written before lifetime accounting existed.
    pub lifetime_gco2: f64,
    pub delay_s: f64,
    pub drop_pct: f64,
    pub cdp: f64,
}

impl ArchivePoint {
    pub(crate) fn from_row(row: &Json) -> Result<Self> {
        let s = |k: &str| -> Result<String> {
            row.get(k).and_then(|v| v.as_str().map(str::to_string)).context(format!("field {k}"))
        };
        let f = |k: &str| -> Result<f64> {
            row.get(k).and_then(|v| v.as_f64()).context(format!("field {k}"))
        };
        let carbon_g = f("carbon_g")?;
        Ok(Self {
            key: s("key")?,
            model: s("model")?,
            node: s("node")?,
            mult: s("mult")?,
            objective: s("objective").unwrap_or_else(|_| "embodied-cdp".to_string()),
            carbon_g,
            lifetime_gco2: f("lifetime_gco2").unwrap_or(carbon_g),
            delay_s: f("delay_s")?,
            drop_pct: f("drop_pct")?,
            cdp: f("cdp")?,
        })
    }

    pub(crate) fn carbon_on(&self, axis: CarbonAxis) -> f64 {
        match axis {
            CarbonAxis::Embodied => self.carbon_g,
            CarbonAxis::Lifetime => self.lifetime_gco2,
        }
    }
}

/// 3-objective dominance (<= everywhere, < somewhere; minimize all).
pub(crate) fn dominates(axis: CarbonAxis, a: &ArchivePoint, b: &ArchivePoint) -> bool {
    let (ca, cb) = (a.carbon_on(axis), b.carbon_on(axis));
    let le = ca <= cb && a.delay_s <= b.delay_s && a.drop_pct <= b.drop_pct;
    let lt = ca < cb || a.delay_s < b.delay_s || a.drop_pct < b.drop_pct;
    le && lt
}

/// Grouping axis for aggregate summaries.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GroupBy {
    Node,
    Model,
}

/// The archive: all points plus the indices of the cross-scenario front.
#[derive(Debug, Clone)]
pub struct CampaignArchive {
    pub axis: CarbonAxis,
    pub points: Vec<ArchivePoint>,
    /// Indices into `points` on the (carbon, delay, drop) Pareto front,
    /// in ascending insertion (store) order.
    pub front: Vec<usize>,
}

impl CampaignArchive {
    /// An empty archive over the given carbon axis.
    pub fn new(axis: CarbonAxis) -> Self {
        Self { axis, points: Vec::new(), front: Vec::new() }
    }

    /// Insert one point, updating the front incrementally. Returns whether
    /// the point landed on the front. Checking the new point against the
    /// current front members alone is sufficient: any dominator of the new
    /// point is itself dominated only by front members, and dominance is
    /// transitive.
    pub fn insert(&mut self, p: ArchivePoint) -> bool {
        let axis = self.axis;
        let dominated = self.front.iter().any(|&j| dominates(axis, &self.points[j], &p));
        let idx = self.points.len();
        if !dominated {
            let points = &self.points;
            self.front.retain(|&j| !dominates(axis, &p, &points[j]));
            self.front.push(idx);
        }
        self.points.push(p);
        !dominated
    }

    /// Parse and insert one committed store row. Quarantined-failure
    /// rows (see [`crate::campaign::store::row_is_failed`]) carry no
    /// objective point: they are skipped — never inserted, never on the
    /// front — and every archive build path applies the same skip, so
    /// point indices stay aligned between the incremental archive, the
    /// full recompute, and the checkpoint restore.
    pub fn insert_row(&mut self, row: &Json) -> Result<bool> {
        if super::store::row_is_failed(row) {
            return Ok(false);
        }
        let p = ArchivePoint::from_row(row)
            .with_context(|| format!("store row {}", self.points.len() + 1))?;
        Ok(self.insert(p))
    }

    /// Build from committed store rows on the embodied axis (the legacy
    /// full-recompute entry point; kept O(n^2) and independent of the
    /// incremental path so tests can pit one against the other).
    pub fn from_rows(rows: &[Json]) -> Result<Self> {
        Self::from_rows_on(rows, CarbonAxis::Embodied)
    }

    /// Full O(n^2) recompute on an explicit axis. Failed rows are
    /// skipped, matching the incremental path.
    pub fn from_rows_on(rows: &[Json], axis: CarbonAxis) -> Result<Self> {
        let points: Vec<ArchivePoint> = rows
            .iter()
            .enumerate()
            .filter(|(_, r)| !super::store::row_is_failed(r))
            .map(|(i, r)| ArchivePoint::from_row(r).with_context(|| format!("store row {}", i + 1)))
            .collect::<Result<_>>()?;
        let front = (0..points.len())
            .filter(|&i| {
                points
                    .iter()
                    .enumerate()
                    .all(|(j, other)| j == i || !dominates(axis, other, &points[i]))
            })
            .collect();
        Ok(Self { axis, points, front })
    }

    /// Stream all rows through the incremental path.
    pub fn from_rows_incremental(rows: &[Json], axis: CarbonAxis) -> Result<Self> {
        let mut arch = Self::new(axis);
        for row in rows {
            arch.insert_row(row)?;
        }
        Ok(arch)
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::util::json::obj;
    use crate::util::Rng;

    pub(crate) fn row(key: &str, model: &str, node: &str, c: f64, d: f64, a: f64) -> Json {
        obj([
            ("key", Json::from(key)),
            ("model", Json::from(model)),
            ("node", Json::from(node)),
            ("mult", Json::from("M")),
            ("carbon_g", Json::from(c)),
            ("delay_s", Json::from(d)),
            ("drop_pct", Json::from(a)),
            ("cdp", Json::from(c * d)),
        ])
    }

    fn row_lifetime(key: &str, c: f64, life: f64, d: f64, a: f64) -> Json {
        obj([
            ("key", Json::from(key)),
            ("model", Json::from("m")),
            ("node", Json::from("14nm")),
            ("mult", Json::from("M")),
            ("carbon_g", Json::from(c)),
            ("lifetime_gco2", Json::from(life)),
            ("delay_s", Json::from(d)),
            ("drop_pct", Json::from(a)),
            ("cdp", Json::from(c * d)),
        ])
    }

    #[test]
    fn front_excludes_dominated_points() {
        let rows = vec![
            row("a", "vgg16", "14nm", 10.0, 1.0, 1.0),
            row("b", "vgg16", "14nm", 12.0, 2.0, 1.5), // dominated by a
            row("c", "vgg16", "7nm", 8.0, 3.0, 1.0),   // trades delay for carbon
            row("d", "vgg16", "7nm", 11.0, 1.0, 0.5),  // trades carbon for drop
        ];
        let arch = CampaignArchive::from_rows(&rows).unwrap();
        assert_eq!(arch.front, vec![0, 2, 3]);
    }

    #[test]
    fn duplicate_points_both_survive() {
        // Equal points do not dominate each other (no strict improvement).
        let rows = vec![
            row("a", "m", "14nm", 1.0, 1.0, 1.0),
            row("b", "m", "14nm", 1.0, 1.0, 1.0),
        ];
        let arch = CampaignArchive::from_rows(&rows).unwrap();
        assert_eq!(arch.front.len(), 2);
    }

    #[test]
    fn missing_fields_error_with_row_number() {
        let rows = vec![obj([("key", Json::from("a"))])];
        let e = CampaignArchive::from_rows(&rows).unwrap_err();
        assert!(format!("{e:#}").contains("store row 1"), "{e:#}");
    }

    #[test]
    fn objective_tag_defaults_for_legacy_rows() {
        let p = ArchivePoint::from_row(&row("a", "m", "14nm", 1.0, 1.0, 1.0)).unwrap();
        assert_eq!(p.objective, "embodied-cdp");
        let mut tagged = row("b", "m", "14nm", 1.0, 1.0, 1.0);
        if let Json::Obj(m) = &mut tagged {
            m.insert("objective".to_string(), Json::from("lifetime-cdp"));
        }
        let p = ArchivePoint::from_row(&tagged).unwrap();
        assert_eq!(p.objective, "lifetime-cdp");
    }

    /// A pseudo-random row set with plenty of dominance structure (values
    /// drawn from a small menu so ties and duplicates occur too).
    pub(crate) fn random_rows(rng: &mut Rng, n: usize) -> Vec<Json> {
        let menu = [1.0, 2.0, 3.0, 5.0, 8.0];
        (0..n)
            .map(|i| {
                row(
                    &format!("k{i}"),
                    "m",
                    "14nm",
                    *rng.choice(&menu),
                    *rng.choice(&menu),
                    *rng.choice(&menu),
                )
            })
            .collect()
    }

    fn front_keys(arch: &CampaignArchive) -> Vec<String> {
        let mut ks: Vec<String> =
            arch.front.iter().map(|&i| arch.points[i].key.clone()).collect();
        ks.sort();
        ks
    }

    #[test]
    fn streaming_matches_full_recompute() {
        // Property: for many random row sets, the incremental archive's
        // front is exactly the full-recompute front (same indices).
        let mut rng = Rng::new(0xA5C4DE);
        for n in [0usize, 1, 2, 7, 20, 50] {
            let rows = random_rows(&mut rng, n);
            let full = CampaignArchive::from_rows(&rows).unwrap();
            let inc =
                CampaignArchive::from_rows_incremental(&rows, CarbonAxis::Embodied).unwrap();
            assert_eq!(inc.front, full.front, "n={n}");
            assert_eq!(inc.points.len(), full.points.len());
        }
    }

    #[test]
    fn front_membership_is_insert_order_independent() {
        // Property: permuting the insertion order never changes *which*
        // scenarios are on the front (indices shift, the key set must not).
        let mut rng = Rng::new(0xF00D);
        for trial in 0..10 {
            let rows = random_rows(&mut rng, 16);
            let base = CampaignArchive::from_rows_incremental(&rows, CarbonAxis::Embodied).unwrap();
            let mut perm = rows.clone();
            rng.shuffle(&mut perm);
            let shuffled =
                CampaignArchive::from_rows_incremental(&perm, CarbonAxis::Embodied).unwrap();
            assert_eq!(front_keys(&base), front_keys(&shuffled), "trial {trial}");
        }
    }

    #[test]
    fn failed_rows_are_skipped_on_every_build_path() {
        let failed = obj([
            ("key", Json::from("poison")),
            ("failed", Json::from(true)),
            ("error", Json::from("injected panic")),
        ]);
        let rows = vec![
            row("a", "m", "14nm", 10.0, 1.0, 1.0),
            failed,
            row("b", "m", "14nm", 8.0, 2.0, 1.0),
        ];
        let full = CampaignArchive::from_rows(&rows).unwrap();
        let inc = CampaignArchive::from_rows_incremental(&rows, CarbonAxis::Embodied).unwrap();
        assert_eq!(full.points.len(), 2, "failed row contributes no point");
        assert_eq!(inc.front, full.front);
        assert_eq!(front_keys(&inc), vec!["a".to_string(), "b".to_string()]);
        // insert_row reports a failed row as off-front, not an error.
        let mut arch = CampaignArchive::new(CarbonAxis::Embodied);
        assert!(!arch
            .insert_row(&obj([("key", Json::from("p")), ("failed", Json::from(true))]))
            .unwrap());
        assert!(arch.points.is_empty());
    }

    #[test]
    fn insert_reports_front_membership() {
        let mut arch = CampaignArchive::new(CarbonAxis::Embodied);
        assert!(arch.insert_row(&row("a", "m", "14nm", 10.0, 1.0, 1.0)).unwrap());
        // Dominated by a -> not on the front.
        assert!(!arch.insert_row(&row("b", "m", "14nm", 12.0, 2.0, 1.5)).unwrap());
        // Dominates a -> replaces it.
        assert!(arch.insert_row(&row("c", "m", "14nm", 9.0, 0.5, 0.5)).unwrap());
        assert_eq!(arch.front, vec![2]);
        assert_eq!(arch.points.len(), 3);
    }

    #[test]
    fn lifetime_axis_orders_fronts_differently() {
        // Point a: low embodied, high lifetime. Point b: the reverse.
        // Each axis must pick its own winner.
        let rows = vec![
            row_lifetime("a", 5.0, 100.0, 1.0, 1.0),
            row_lifetime("b", 8.0, 40.0, 1.0, 1.0),
        ];
        let emb = CampaignArchive::from_rows_on(&rows, CarbonAxis::Embodied).unwrap();
        let life = CampaignArchive::from_rows_on(&rows, CarbonAxis::Lifetime).unwrap();
        assert_eq!(emb.front, vec![0]);
        assert_eq!(life.front, vec![1]);
        // And rows without the lifetime field fall back to embodied carbon.
        let legacy = vec![row("x", "m", "14nm", 3.0, 1.0, 1.0)];
        let arch = CampaignArchive::from_rows_on(&legacy, CarbonAxis::Lifetime).unwrap();
        assert_eq!(arch.points[0].lifetime_gco2, 3.0);
    }
}
