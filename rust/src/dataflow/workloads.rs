//! DNN workloads evaluated by the paper (§IV): VGG16, VGG19, ResNet50,
//! ResNet50V2, DenseNet121 — plus the tiny CNN used for measured-accuracy
//! experiments. Layer tables follow the published architectures (224x224x3
//! ImageNet inputs); batch-norm/activation layers are folded (no MACs at
//! inference relative to conv cost).

use super::layer::Layer;

/// A named DNN workload: an ordered list of layers.
#[derive(Debug, Clone)]
pub struct Workload {
    pub name: String,
    pub layers: Vec<Layer>,
}

impl Workload {
    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs()).sum()
    }

    pub fn total_weight_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.weight_bytes()).sum()
    }

    pub fn n_conv_fc(&self) -> usize {
        self.layers.iter().filter(|l| l.macs() > 0).count()
    }
}

/// Names accepted by `workload()`.
pub fn workload_names() -> Vec<&'static str> {
    vec!["vgg16", "vgg19", "resnet50", "resnet50v2", "densenet121", "tinycnn"]
}

/// Build a workload by name.
pub fn workload(name: &str) -> Option<Workload> {
    match name {
        "vgg16" => Some(vgg(16)),
        "vgg19" => Some(vgg(19)),
        "resnet50" => Some(resnet50(false)),
        "resnet50v2" => Some(resnet50(true)),
        "densenet121" => Some(densenet121()),
        "tinycnn" => Some(tinycnn()),
        _ => None,
    }
}

/// VGG-16/19: stacks of 3x3 convs with 2x2 maxpools, then 3 FC layers.
fn vgg(depth: usize) -> Workload {
    // convs per stage: VGG16 = [2,2,3,3,3], VGG19 = [2,2,4,4,4]
    let per_stage: [usize; 5] = if depth == 16 { [2, 2, 3, 3, 3] } else { [2, 2, 4, 4, 4] };
    let chans = [64usize, 128, 256, 512, 512];
    let mut layers = Vec::new();
    let (mut h, mut w, mut c) = (224usize, 224usize, 3usize);
    for (s, (&n, &oc)) in per_stage.iter().zip(&chans).enumerate() {
        for i in 0..n {
            layers.push(Layer::conv(&format!("conv{}_{}", s + 1, i + 1), h, w, c, oc, 3, 1));
            c = oc;
        }
        layers.push(Layer::pool(&format!("pool{}", s + 1), h, w, c, 2, 2));
        h /= 2;
        w /= 2;
    }
    // 7x7x512 = 25088 -> 4096 -> 4096 -> 1000
    layers.push(Layer::fc("fc6", h * w * c, 4096));
    layers.push(Layer::fc("fc7", 4096, 4096));
    layers.push(Layer::fc("fc8", 4096, 1000));
    Workload { name: format!("vgg{depth}"), layers }
}

/// ResNet-50 (v1 or v2 — identical MAC structure; v2's pre-activation moves
/// BN/ReLU, which we model as slightly higher eltwise traffic).
fn resnet50(v2: bool) -> Workload {
    let mut layers = Vec::new();
    layers.push(Layer::conv("conv1", 224, 224, 3, 64, 7, 2));
    layers.push(Layer::pool("pool1", 112, 112, 64, 3, 2));
    let stages: [(usize, usize, usize, usize); 4] = [
        // (blocks, in_c at stage entry, bottleneck width, out_c)
        (3, 64, 64, 256),
        (4, 256, 128, 512),
        (6, 512, 256, 1024),
        (3, 1024, 512, 2048),
    ];
    let mut h = 56usize;
    let mut w = 56usize;
    for (si, &(blocks, stage_in, width, out_c)) in stages.iter().enumerate() {
        let mut in_c = stage_in;
        for b in 0..blocks {
            let stride = if b == 0 && si > 0 { 2 } else { 1 };
            if stride == 2 {
                h /= 2;
                w /= 2;
            }
            let p = format!("s{}b{}", si + 1, b + 1);
            // Bottleneck: 1x1 reduce -> 3x3 -> 1x1 expand. The strided conv
            // is the 3x3 (v1.5/standard implementations).
            layers.push(Layer::conv(&format!("{p}_1x1a"), h * stride, w * stride, in_c, width, 1, stride));
            layers.push(Layer::conv(&format!("{p}_3x3"), h, w, width, width, 3, 1));
            layers.push(Layer::conv(&format!("{p}_1x1b"), h, w, width, out_c, 1, 1));
            if b == 0 {
                // Projection shortcut.
                layers.push(Layer::conv(
                    &format!("{p}_proj"),
                    h * stride,
                    w * stride,
                    in_c,
                    out_c,
                    1,
                    stride,
                ));
            }
            layers.push(Layer::eltwise(&format!("{p}_add"), h, w, out_c));
            if v2 {
                // Pre-activation: BN/ReLU on the trunk adds an extra
                // read-modify-write of the feature map.
                layers.push(Layer::eltwise(&format!("{p}_preact"), h, w, out_c / 2));
            }
            in_c = out_c;
        }
    }
    layers.push(Layer::pool("gap", 7, 7, 2048, 7, 7));
    layers.push(Layer::fc("fc", 2048, 1000));
    Workload { name: if v2 { "resnet50v2".into() } else { "resnet50".into() }, layers }
}

/// DenseNet-121: growth rate 32, blocks [6,12,24,16], 1x1(4k)+3x3(k) pairs,
/// transition layers halve channels and spatial dims.
fn densenet121() -> Workload {
    let growth = 32usize;
    let blocks = [6usize, 12, 24, 16];
    let mut layers = Vec::new();
    layers.push(Layer::conv("conv1", 224, 224, 3, 64, 7, 2));
    layers.push(Layer::pool("pool1", 112, 112, 64, 3, 2));
    let mut h = 56usize;
    let mut w = 56usize;
    let mut c = 64usize;
    for (bi, &n) in blocks.iter().enumerate() {
        for l in 0..n {
            let p = format!("d{}l{}", bi + 1, l + 1);
            // Bottleneck 1x1 -> 4*growth, then 3x3 -> growth; input is the
            // concatenation of all previous maps in the block.
            layers.push(Layer::conv(&format!("{p}_1x1"), h, w, c, 4 * growth, 1, 1));
            layers.push(Layer::conv(&format!("{p}_3x3"), h, w, 4 * growth, growth, 3, 1));
            // Concat bookkeeping: the new features are appended (traffic only).
            layers.push(Layer::eltwise(&format!("{p}_cat"), h, w, growth));
            c += growth;
        }
        if bi + 1 < blocks.len() {
            // Transition: 1x1 conv halving channels + 2x2 avgpool.
            layers.push(Layer::conv(&format!("t{}_1x1", bi + 1), h, w, c, c / 2, 1, 1));
            c /= 2;
            layers.push(Layer::pool(&format!("t{}_pool", bi + 1), h, w, c, 2, 2));
            h /= 2;
            w /= 2;
        }
    }
    layers.push(Layer::pool("gap", 7, 7, c, 7, 7));
    layers.push(Layer::fc("fc", c, 1000));
    Workload { name: "densenet121".into(), layers }
}

/// The tiny CNN trained at artifact-build time (python/compile/model.py) —
/// used for the measured-accuracy E2E experiments.
fn tinycnn() -> Workload {
    Workload {
        name: "tinycnn".into(),
        layers: vec![
            Layer::conv("conv1", 16, 16, 1, 8, 3, 1),
            Layer::pool("pool1", 16, 16, 8, 2, 2),
            Layer::conv("conv2", 8, 8, 8, 16, 3, 1),
            Layer::pool("pool2", 8, 8, 16, 2, 2),
            Layer::fc("fc", 256, 5),
        ],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vgg16_mac_count_matches_published() {
        // VGG16 ~ 15.47 GMACs (30.9 GFLOPs) at 224x224.
        let w = workload("vgg16").unwrap();
        let g = w.total_macs() as f64 / 1e9;
        assert!((15.0..16.0).contains(&g), "VGG16 {g} GMACs");
    }

    #[test]
    fn vgg19_more_macs_than_vgg16() {
        let m16 = workload("vgg16").unwrap().total_macs();
        let m19 = workload("vgg19").unwrap().total_macs();
        assert!(m19 > m16);
        // ~19.6 GMACs published.
        let g = m19 as f64 / 1e9;
        assert!((19.0..20.5).contains(&g), "VGG19 {g} GMACs");
    }

    #[test]
    fn resnet50_mac_count_matches_published() {
        // ResNet50 ~ 3.8-4.1 GMACs.
        let w = workload("resnet50").unwrap();
        let g = w.total_macs() as f64 / 1e9;
        assert!((3.5..4.3).contains(&g), "ResNet50 {g} GMACs");
    }

    #[test]
    fn resnet50_weight_count_matches_published() {
        // ~25.5M params; conv+fc weights ~ 25M * 2 bytes.
        let w = workload("resnet50").unwrap();
        let params = w.total_weight_bytes() / 2;
        assert!(
            (23_000_000..27_000_000).contains(&params),
            "ResNet50 params {params}"
        );
    }

    #[test]
    fn densenet121_mac_count_matches_published() {
        // DenseNet121 ~ 2.8-2.9 GMACs.
        let w = workload("densenet121").unwrap();
        let g = w.total_macs() as f64 / 1e9;
        assert!((2.6..3.1).contains(&g), "DenseNet121 {g} GMACs");
    }

    #[test]
    fn densenet121_param_count_matches_published() {
        // ~8.0M params.
        let w = workload("densenet121").unwrap();
        let params = w.total_weight_bytes() / 2;
        assert!((6_800_000..8_800_000).contains(&params), "params {params}");
    }

    #[test]
    fn vgg16_param_count_matches_published() {
        // ~138M params.
        let w = workload("vgg16").unwrap();
        let params = w.total_weight_bytes() / 2;
        assert!((130_000_000..145_000_000).contains(&params), "params {params}");
    }

    #[test]
    fn all_workloads_build_and_are_nonempty() {
        for name in workload_names() {
            let w = workload(name).unwrap();
            assert!(!w.layers.is_empty(), "{name}");
            assert!(w.total_macs() > 0, "{name}");
        }
        assert!(workload("nope").is_none());
    }

    #[test]
    fn resnet_v2_has_more_traffic_same_macs() {
        let v1 = workload("resnet50").unwrap();
        let v2 = workload("resnet50v2").unwrap();
        assert_eq!(v1.total_macs(), v2.total_macs());
        let t1: usize = v1.layers.iter().map(|l| l.ifmap_bytes()).sum();
        let t2: usize = v2.layers.iter().map(|l| l.ifmap_bytes()).sum();
        assert!(t2 > t1);
    }

    #[test]
    fn tinycnn_matches_python_model() {
        let w = workload("tinycnn").unwrap();
        // conv1: 16*16*8*9*1, conv2: 8*8*16*9*8, fc: 256*5
        assert_eq!(
            w.total_macs(),
            (16 * 16 * 8 * 9) as u64 + (8 * 8 * 16 * 9 * 8) as u64 + (256 * 5) as u64
        );
    }
}
