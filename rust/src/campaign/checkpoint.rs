//! Sidecar **checkpointing** for the incremental Pareto archive: a small
//! `<store>.front.json` document (axis, row count, front indices) written
//! beside the JSONL store after every commit and restored on resume.
//!
//! Every write goes through [`write_atomic`] — temp file + rename — so a
//! crash mid-checkpoint can never leave a torn sidecar. That guarantee
//! sharpens the read side: a sidecar that *parses wrong* is real damage
//! (external truncation or editing), and [`CampaignArchive::load_or_rebuild`]
//! rejects it loudly instead of silently rebuilding over it. A *missing*
//! sidecar or a *stale* one (rows were appended after the last checkpoint,
//! or the axis changed) is normal operation and rebuilds quietly — the
//! store rows remain the sole source of truth.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::{obj, Json};

use super::pareto::{ArchivePoint, CampaignArchive, CarbonAxis};

/// Write `text` to `path` atomically: a sibling temp file is written in
/// full, then renamed over the destination, so readers only ever see the
/// old complete document or the new complete document.
pub fn write_atomic(path: &Path, text: &str) -> Result<()> {
    let name = path
        .file_name()
        .map(|n| n.to_string_lossy().into_owned())
        .unwrap_or_else(|| "checkpoint".to_string());
    let tmp = path.with_file_name(format!("{name}.tmp"));
    std::fs::write(&tmp, text).with_context(|| format!("write {}", tmp.display()))?;
    std::fs::rename(&tmp, path)
        .with_context(|| format!("finalize checkpoint {}", path.display()))
}

impl CampaignArchive {
    /// Sidecar path for a store at `store_path` (e.g. `campaign.jsonl` ->
    /// `campaign.front.json`).
    pub fn checkpoint_path(store_path: &Path) -> PathBuf {
        store_path.with_extension("front.json")
    }

    /// The checkpoint document: enough to validate freshness and restore
    /// the front without re-running dominance checks.
    pub fn checkpoint(&self) -> Json {
        obj([
            ("axis", Json::from(self.axis.name())),
            ("n_points", Json::from(self.points.len() as f64)),
            (
                "front",
                Json::Arr(self.front.iter().map(|&i| Json::from(i as f64)).collect()),
            ),
        ])
    }

    /// Atomically persist the checkpoint document.
    pub fn save_checkpoint(&self, path: &Path) -> Result<()> {
        write_atomic(path, &self.checkpoint().dumps())
    }

    /// Restore from a checkpoint if it matches the store (same axis, same
    /// row count); rebuild incrementally from the rows when the sidecar is
    /// missing or merely stale. A sidecar that exists but does not parse
    /// as a well-formed checkpoint is a **loud error**: checkpoints are
    /// written atomically, so a torn document means external damage, and
    /// resuming over it silently would hide that something corrupted the
    /// campaign directory.
    pub fn load_or_rebuild(rows: &[Json], axis: CarbonAxis, ckpt_path: &Path) -> Result<Self> {
        let text = match std::fs::read_to_string(ckpt_path) {
            Ok(text) => text,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                return Self::from_rows_incremental(rows, axis);
            }
            Err(e) => {
                return Err(e)
                    .with_context(|| format!("read front sidecar {}", ckpt_path.display()));
            }
        };
        match Self::restore_from(&text, rows, axis).with_context(|| {
            format!(
                "front sidecar {} is corrupt — checkpoints are written atomically, so \
                 this is external damage; delete the sidecar to rebuild it from the \
                 store rows",
                ckpt_path.display()
            )
        })? {
            Some(arch) => Ok(arch),
            None => Self::from_rows_incremental(rows, axis),
        }
    }

    /// Parse a checkpoint document against the store rows. `Ok(None)`
    /// means the sidecar is well-formed but stale (different axis or row
    /// count) and a rebuild should proceed; `Err` means the document is
    /// damaged and must surface to the operator.
    fn restore_from(text: &str, rows: &[Json], axis: CarbonAxis) -> Result<Option<Self>> {
        let ck = Json::parse(text).context("unparseable checkpoint document")?;
        let axis_name = ck.get("axis")?.as_str()?;
        let ck_axis = CarbonAxis::from_name(axis_name)
            .ok_or_else(|| anyhow!("unknown carbon axis {axis_name:?}"))?;
        let n = ck.get("n_points")?.as_usize()?;
        let mut front = Vec::new();
        let mut prev: Option<usize> = None;
        for v in ck.get("front")?.as_arr()? {
            let i = v.as_usize().context("front index")?;
            if i >= n || prev.is_some_and(|p| p >= i) {
                bail!("front indices out of range or not ascending");
            }
            front.push(i);
            prev = Some(i);
        }
        // `n_points` counts archive points, which exclude quarantined
        // failed rows — filter the same way here so the index spaces and
        // the staleness check agree with the incremental writer.
        let live: Vec<&Json> =
            rows.iter().filter(|r| !crate::campaign::store::row_is_failed(r)).collect();
        if ck_axis != axis || n != live.len() {
            return Ok(None); // stale, not damaged: rebuild from the rows
        }
        let points: Vec<ArchivePoint> = live
            .into_iter()
            .map(ArchivePoint::from_row)
            .collect::<Result<_>>()
            .context("store rows no longer parse")?;
        Ok(Some(Self { axis, points, front }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::campaign::pareto::tests::{random_rows, row};
    use crate::util::Rng;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!(
            "carbon3d-ckpt-{}-{name}.front.json",
            std::process::id()
        ))
    }

    #[test]
    fn checkpoint_roundtrip_and_staleness() {
        let mut rng = Rng::new(0xCAFE);
        let rows = random_rows(&mut rng, 12);
        let arch = CampaignArchive::from_rows_incremental(&rows, CarbonAxis::Embodied).unwrap();
        let path = tmp("roundtrip");
        arch.save_checkpoint(&path).unwrap();

        // Fresh checkpoint restores the exact front.
        let restored =
            CampaignArchive::load_or_rebuild(&rows, CarbonAxis::Embodied, &path).unwrap();
        assert_eq!(restored.front, arch.front);

        // Stale checkpoint (more rows than it covers) -> rebuilt, not trusted.
        let mut more = rows.clone();
        more.push(row("extra", "m", "14nm", 0.5, 0.5, 0.5));
        let rebuilt =
            CampaignArchive::load_or_rebuild(&more, CarbonAxis::Embodied, &path).unwrap();
        let full = CampaignArchive::from_rows(&more).unwrap();
        assert_eq!(rebuilt.front, full.front);

        // Axis mismatch -> rebuilt on the requested axis.
        let other = CampaignArchive::load_or_rebuild(&rows, CarbonAxis::Lifetime, &path).unwrap();
        assert_eq!(other.axis, CarbonAxis::Lifetime);

        // Missing checkpoint -> rebuilt.
        let _ = std::fs::remove_file(&path);
        let rebuilt2 =
            CampaignArchive::load_or_rebuild(&rows, CarbonAxis::Embodied, &path).unwrap();
        assert_eq!(rebuilt2.front, arch.front);
    }

    #[test]
    fn failed_rows_do_not_desync_the_checkpoint() {
        // A quarantined failed row sits in the store but contributes no
        // archive point; a checkpoint written after it must restore (not
        // be treated as stale) and reproduce the same front.
        let mut rows = vec![
            row("a", "m", "14nm", 10.0, 1.0, 1.0),
            row("b", "m", "14nm", 8.0, 2.0, 1.0),
        ];
        rows.push(crate::util::json::obj([
            ("key", Json::from("poison")),
            ("failed", Json::from(true)),
            ("error", Json::from("injected panic")),
        ]));
        let arch = CampaignArchive::from_rows_incremental(&rows, CarbonAxis::Embodied).unwrap();
        assert_eq!(arch.points.len(), 2);
        let path = tmp("failed-rows");
        arch.save_checkpoint(&path).unwrap();
        let restored =
            CampaignArchive::load_or_rebuild(&rows, CarbonAxis::Embodied, &path).unwrap();
        assert_eq!(restored.front, arch.front);
        assert_eq!(restored.points.len(), 2);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn truncated_or_garbage_sidecars_are_rejected_loudly() {
        let mut rng = Rng::new(0xBEEF);
        let rows = random_rows(&mut rng, 8);
        let arch = CampaignArchive::from_rows_incremental(&rows, CarbonAxis::Embodied).unwrap();
        let path = tmp("truncated");
        arch.save_checkpoint(&path).unwrap();

        // Truncate the (atomically written) sidecar: that cannot happen
        // through the writer, so resume must refuse rather than rebuild.
        let full_text = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, &full_text[..full_text.len() / 2]).unwrap();
        let err = CampaignArchive::load_or_rebuild(&rows, CarbonAxis::Embodied, &path)
            .expect_err("truncated sidecar must be rejected");
        assert!(format!("{err:#}").contains("corrupt"), "{err:#}");

        // Outright garbage: same loud refusal.
        std::fs::write(&path, "not json at all").unwrap();
        assert!(CampaignArchive::load_or_rebuild(&rows, CarbonAxis::Embodied, &path).is_err());

        // A malformed front (index out of range) is damage too.
        std::fs::write(
            &path,
            "{\"axis\": \"embodied\", \"n_points\": 8, \"front\": [99]}",
        )
        .unwrap();
        assert!(CampaignArchive::load_or_rebuild(&rows, CarbonAxis::Embodied, &path).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn save_checkpoint_is_atomic_and_leaves_no_temp() {
        let mut rng = Rng::new(0x50DA);
        let rows = random_rows(&mut rng, 5);
        let arch = CampaignArchive::from_rows_incremental(&rows, CarbonAxis::Embodied).unwrap();
        let path = tmp("atomic");
        // Overwrite an existing (different) document in place.
        std::fs::write(&path, "{\"axis\": \"embodied\", \"n_points\": 0, \"front\": []}")
            .unwrap();
        arch.save_checkpoint(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(Json::parse(&text).unwrap(), arch.checkpoint());
        let name = path.file_name().unwrap().to_string_lossy().into_owned();
        assert!(
            !path.with_file_name(format!("{name}.tmp")).exists(),
            "temp file left behind"
        );
        let _ = std::fs::remove_file(&path);
    }
}
