//! Reproduce the paper's Figure 3: embodied-carbon efficiency (gCO2/mm^2)
//! vs performance (FPS) for VGG16 across nodes — 2D-Exact / 3D-Exact /
//! 3D-Appx NVDLA-like sweeps (64..2048 PEs) plus GA-APPX-CDP points at the
//! FPS targets {10, 15, 20, 30, 40}.
//!
//! Writes results/fig3.csv + results/fig3.txt and prints the headline
//! §IV-B comparisons.
//!
//! Run: `cargo run --release --example fig3_sweep [-- --quick]`

use carbon3d::approx::library;
use carbon3d::area::TechNode;
use carbon3d::coordinator::baselines::Approach;
use carbon3d::coordinator::fig3::run_fig3;
use carbon3d::ga::GaParams;
use carbon3d::util::stats::pct_change;
use carbon3d::util::{table, Table};

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let params = if quick {
        GaParams { population: 32, generations: 20, patience: 8, ..Default::default() }
    } else {
        GaParams::default()
    };
    let lib = library();
    let (r, secs) = carbon3d::util::timer::time_once(|| run_fig3(&lib, "vgg16", params));
    println!("{}", r.render());

    // §IV-B headline: @7nm / 20 FPS.
    let node = TechNode::N7;
    let fps = 20.0;
    if let (Some(ga), Some(e3), Some(e2)) = (
        r.best_meeting_fps(node, Approach::GaAppxCdp, fps),
        r.best_meeting_fps(node, Approach::ThreeDExact, fps),
        r.best_meeting_fps(node, Approach::TwoDExact, fps),
    ) {
        println!(
            "@7nm 20FPS: GA {:.2} g vs 3D-Exact {:.2} g  -> {:.1}% carbon cut (paper: 32%)",
            ga.carbon_g,
            e3.carbon_g,
            -pct_change(e3.carbon_g, ga.carbon_g)
        );
        println!(
            "@7nm 20FPS: GA {:.2} g/mm^2 vs 2D {:.2} g/mm^2 -> {:.1}% lower (paper: 7%)",
            ga.carbon_per_mm2,
            e2.carbon_per_mm2,
            -pct_change(e2.carbon_per_mm2, ga.carbon_per_mm2)
        );
    }
    println!("fig3 sweeps completed in {}", carbon3d::util::timer::human_time(secs));

    std::fs::create_dir_all("results")?;
    let mut csv = Table::new(vec![
        "node", "approach", "n_pes", "fps", "gco2_per_mm2", "gco2", "fps_target",
    ]);
    for p in &r.points {
        csv.row(vec![
            p.node.name().to_string(),
            p.approach.name().to_string(),
            p.n_pes.to_string(),
            table::fmt(p.fps),
            table::fmt(p.carbon_per_mm2),
            table::fmt(p.carbon_g),
            p.fps_target.map(|f| format!("{f}")).unwrap_or_default(),
        ]);
    }
    std::fs::write("results/fig3.csv", csv.to_csv())?;
    std::fs::write("results/fig3.txt", r.render())?;
    println!("wrote results/fig3.csv, results/fig3.txt");
    Ok(())
}
