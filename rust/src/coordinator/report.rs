//! Headline report: the paper's §I/§V claims vs our measured numbers.
//!
//!  - carbon reduction up to ~25% @45nm, ~30% @14nm, ~15% @7nm (Fig. 2)
//!  - @7nm with a 20 FPS floor: ~32% better carbon than 3D-Exact and ~7%
//!    lower gCO2/mm^2 than a 2D design meeting the same target (Fig. 3)

use crate::area::TechNode;
use crate::util::stats::pct_change;

use super::baselines::Approach;
use super::fig2::Fig2Result;
use super::fig3::Fig3Result;

/// One headline claim with paper value and our measurement.
#[derive(Debug, Clone)]
pub struct Claim {
    pub name: String,
    pub paper: f64,
    pub measured: f64,
    pub unit: &'static str,
}

impl Claim {
    pub fn line(&self) -> String {
        format!(
            "{:<58} paper {:>7.1}{}  measured {:>7.1}{}",
            self.name, self.paper, self.unit, self.measured, self.unit
        )
    }
}

/// Compose the headline claims from completed Fig. 2 / Fig. 3 runs.
pub fn headline_report(fig2: &Fig2Result, fig3: &Fig3Result) -> Vec<Claim> {
    let mut out = vec![
        Claim {
            name: "max embodied-carbon reduction @45nm (Fig.2)".into(),
            paper: 25.0,
            measured: fig2.max_carbon_cut_pct(TechNode::N45),
            unit: "%",
        },
        Claim {
            name: "max embodied-carbon reduction @14nm (Fig.2)".into(),
            paper: 30.0,
            measured: fig2.max_carbon_cut_pct(TechNode::N14),
            unit: "%",
        },
        Claim {
            name: "max embodied-carbon reduction @7nm (Fig.2)".into(),
            paper: 15.0,
            measured: fig2.max_carbon_cut_pct(TechNode::N7),
            unit: "%",
        },
    ];

    // §IV-B @7nm, 20 FPS: GA vs 3D-Exact carbon; GA vs 2D gCO2/mm^2.
    let node = TechNode::N7;
    let fps = 20.0;
    let ga = fig3.best_meeting_fps(node, Approach::GaAppxCdp, fps);
    let e3 = fig3.best_meeting_fps(node, Approach::ThreeDExact, fps);
    let e2 = fig3.best_meeting_fps(node, Approach::TwoDExact, fps);
    if let (Some(ga), Some(e3)) = (ga, e3) {
        out.push(Claim {
            name: "carbon cut vs 3D-Exact @7nm, 20FPS (Fig.3)".into(),
            paper: 32.0,
            measured: -pct_change(e3.carbon_g, ga.carbon_g),
            unit: "%",
        });
    }
    if let (Some(ga), Some(e2)) = (ga, e2) {
        out.push(Claim {
            name: "gCO2/mm^2 cut vs 2D @7nm, 20FPS (Fig.3)".into(),
            paper: 7.0,
            measured: -pct_change(e2.carbon_per_mm2, ga.carbon_per_mm2),
            unit: "%",
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn claim_line_formats() {
        let c = Claim { name: "x".into(), paper: 30.0, measured: 28.3, unit: "%" };
        let s = c.line();
        assert!(s.contains("30.0%") && s.contains("28.3%"));
    }
}
