//! Experiment orchestration: the paper's evaluation pipelines (Fig. 2,
//! Fig. 3, headline report) and the baselines they compare against.

pub mod baselines;
pub mod fig2;
pub mod fig3;
pub mod report;

pub use baselines::{ga_cdp_exact, nvdla_like_config, sweep_nvdla, Approach};
pub use fig2::{run_fig2, Fig2Cell, Fig2Result};
pub use fig3::{run_fig3, Fig3Point, Fig3Result};
pub use report::headline_report;

use crate::accuracy::model::{feasible_multipliers, DEFAULT_K};
use crate::approx::Multiplier;
use crate::dataflow::workloads::Workload;
use crate::ga::{Ga, GaParams, GaResult, SearchSpace};
use crate::ga::fitness::{EvalShares, FitnessCtx};
use crate::area::die::Integration;
use crate::area::TechNode;

/// Run the paper's GA-APPX-CDP search: multiplier gene restricted to the
/// δ-feasible set, CDP objective, optional FPS floor.
pub fn ga_appx_cdp(
    workload: &Workload,
    node: TechNode,
    library: &[Multiplier],
    delta_pct: f64,
    fps_floor: Option<f64>,
    params: GaParams,
) -> GaResult {
    let feasible = feasible_multipliers(library, workload, delta_pct, DEFAULT_K);
    assert!(!feasible.is_empty(), "no multiplier satisfies δ={delta_pct}%");
    ga_appx_cdp_with_feasible(
        workload,
        node,
        Integration::ThreeD,
        library,
        feasible,
        fps_floor,
        params,
    )
}

/// GA-APPX-CDP over an explicit feasible-multiplier set and integration
/// style. The campaign executors use this with feasibility derived from
/// the campaign-global `EvalService` accuracy table (measured or surrogate)
/// instead of the `DEFAULT_K` analytical model, so accuracy evaluations are
/// shared across every run in the grid.
#[allow(clippy::too_many_arguments)]
pub fn ga_appx_cdp_with_feasible(
    workload: &Workload,
    node: TechNode,
    integration: Integration,
    library: &[Multiplier],
    feasible: Vec<usize>,
    fps_floor: Option<f64>,
    params: GaParams,
) -> GaResult {
    ga_appx_with_feasible_objective(
        workload,
        node,
        integration,
        library,
        feasible,
        fps_floor,
        crate::ga::Objective::embodied(),
        params,
    )
}

/// The fully-general search entry point: explicit feasible set, integration
/// style, and objective (embodied CDP, operational-only, or lifetime CDP
/// under a deployment). `campaign::exec::run_job` threads the campaign's
/// `CampaignObjective` through here so every candidate the GA evaluates is
/// scored on lifetime carbon when the campaign asks for it — and because
/// the GA seed derives from the job key, the result row is a pure function
/// of the job spec whichever executor (threads or shard process) calls in.
#[allow(clippy::too_many_arguments)]
pub fn ga_appx_with_feasible_objective(
    workload: &Workload,
    node: TechNode,
    integration: Integration,
    library: &[Multiplier],
    feasible: Vec<usize>,
    fps_floor: Option<f64>,
    objective: crate::ga::Objective,
    params: GaParams,
) -> GaResult {
    ga_appx_with_feasible_objective_shared(
        workload,
        node,
        integration,
        library,
        feasible,
        fps_floor,
        objective,
        params,
        &EvalShares::default(),
    )
}

/// [`ga_appx_with_feasible_objective`] over shared evaluation caches
/// (DESIGN.md §7.6): the campaign executors pass one [`EvalShares`] per
/// process so every job's GA hits the same geometry-mapping cache — a
/// geometry mapped for one scenario is free for every later scenario that
/// shares its `(workload, node, integration)` — and the `dse` CLI passes
/// one to report cache efficacy. Sharing never changes results: the
/// cached mapping is the value the mapper computes.
#[allow(clippy::too_many_arguments)]
pub fn ga_appx_with_feasible_objective_shared(
    workload: &Workload,
    node: TechNode,
    integration: Integration,
    library: &[Multiplier],
    feasible: Vec<usize>,
    fps_floor: Option<f64>,
    objective: crate::ga::Objective,
    params: GaParams,
    shares: &EvalShares,
) -> GaResult {
    assert!(!feasible.is_empty(), "empty feasible-multiplier set");
    let space = SearchSpace::standard(feasible);
    let mut ctx =
        FitnessCtx::with_objective(workload, node, integration, library, fps_floor, objective)
            .share(shares);
    let mut r = Ga::new(space, params).run(&mut ctx);
    refine_to_min_carbon(&mut r, &ctx);
    r
}

/// Among CDP-near-optimal feasible designs (within 10%), report the lowest
/// carbon one — CDP is flat near its optimum, and the paper reports the
/// sustainable end of that plateau. Applied identically to the baseline
/// (`ga_cdp_exact`), so every comparison stays like-for-like.
pub(crate) fn refine_to_min_carbon(r: &mut GaResult, ctx: &FitnessCtx) {
    if let Some((c, e)) = ctx.near_optimal_min_carbon(r.best_eval.fitness * 1.10) {
        if ctx.objective.carbon_g(&e) < ctx.objective.carbon_g(&r.best_eval) {
            r.best = c;
            r.best_eval = e;
        }
    }
}

/// Greedy carbon descent: starting from a chromosome, repeatedly take the
/// single-gene move (one menu step down on px/py/rf/sram, or any smaller
/// feasible multiplier) that lowers embodied carbon the most while staying
/// feasible (FPS floor + δ set). Deterministic polish applied after the GA
/// for the figure pipelines — removes GA sampling noise from the reported
/// min-carbon points.
pub fn carbon_descend(
    start: &crate::ga::Chromosome,
    space: &SearchSpace,
    ctx: &mut FitnessCtx,
) -> (crate::ga::Chromosome, crate::ga::Evaluation) {
    let mut cur = start.clone();
    let mut cur_eval = ctx.eval(&cur);
    loop {
        let mut best_next: Option<(crate::ga::Chromosome, crate::ga::Evaluation)> = None;
        let mut consider = |c: crate::ga::Chromosome, ctx: &mut FitnessCtx| {
            if !space.contains(&c) {
                return;
            }
            let e = ctx.eval(&c);
            if e.feasible
                && e.carbon_g < cur_eval.carbon_g
                && best_next.as_ref().is_none_or(|(_, b)| e.carbon_g < b.carbon_g)
            {
                best_next = Some((c, e));
            }
        };
        let step_down = |menu: &[usize], v: usize| -> Option<usize> {
            let i = menu.iter().position(|&x| x == v)?;
            (i > 0).then(|| menu[i - 1])
        };
        if let Some(px) = step_down(&space.px, cur.px) {
            consider(crate::ga::Chromosome { px, ..cur.clone() }, ctx);
        }
        if let Some(py) = step_down(&space.py, cur.py) {
            consider(crate::ga::Chromosome { py, ..cur.clone() }, ctx);
        }
        if let Some(rf_bytes) = step_down(&space.rf_bytes, cur.rf_bytes) {
            consider(crate::ga::Chromosome { rf_bytes, ..cur.clone() }, ctx);
        }
        if let Some(sram_bytes) = step_down(&space.sram_bytes, cur.sram_bytes) {
            consider(crate::ga::Chromosome { sram_bytes, ..cur.clone() }, ctx);
        }
        for &mult_id in &space.mult_ids {
            if mult_id != cur.mult_id {
                consider(crate::ga::Chromosome { mult_id, ..cur.clone() }, ctx);
            }
        }
        match best_next {
            Some((c, e)) => {
                cur = c;
                cur_eval = e;
            }
            None => return (cur, cur_eval),
        }
    }
}

/// The Fig. 2 point: GA-APPX-CDP constrained to the baseline's FPS, then
/// polished to the minimum-carbon feasible design (the paper's "lower
/// embodied carbon while maintaining competitive performance").
pub fn ga_appx_min_carbon(
    workload: &Workload,
    node: TechNode,
    library: &[Multiplier],
    delta_pct: f64,
    fps_floor: f64,
    params: GaParams,
    baseline: Option<&crate::ga::Chromosome>,
) -> GaResult {
    let feasible = feasible_multipliers(library, workload, delta_pct, DEFAULT_K);
    assert!(!feasible.is_empty(), "no multiplier satisfies δ={delta_pct}%");
    let space = SearchSpace::standard(feasible);
    let mut ctx = FitnessCtx::new(workload, node, Integration::ThreeD, library, Some(fps_floor));
    let mut r = Ga::new(space.clone(), params).run(&mut ctx);

    // Descend from several seeds and keep the best: the GA's best feasible
    // design, the cache-wide min-carbon feasible design, and the baseline's
    // chromosome (always floor-feasible by construction — it *is* the
    // design defining the floor, and any δ-feasible multiplier swap keeps
    // its delay while cutting carbon).
    let mut seeds: Vec<crate::ga::Chromosome> = Vec::new();
    if r.best_eval.feasible {
        seeds.push(r.best.clone());
    }
    if let Some((c, _)) = ctx.near_optimal_min_carbon(f64::INFINITY) {
        seeds.push(c);
    }
    if let Some(b) = baseline {
        if space.contains(b) {
            seeds.push(b.clone());
        } else {
            // Baseline multiplier (EXACT) is always in the feasible set;
            // re-home the chromosome onto this space's multiplier menu.
            let mut b2 = b.clone();
            b2.mult_id = crate::approx::EXACT_ID;
            if space.contains(&b2) {
                seeds.push(b2);
            }
        }
    }
    let mut best: Option<(crate::ga::Chromosome, crate::ga::Evaluation)> = None;
    for seed in seeds {
        let (c, e) = carbon_descend(&seed, &space, &mut ctx);
        if e.feasible && best.as_ref().is_none_or(|(_, b)| e.carbon_g < b.carbon_g) {
            best = Some((c, e));
        }
    }
    if let Some((c, e)) = best {
        if e.carbon_g <= r.best_eval.carbon_g || !r.best_eval.feasible {
            r.best = c;
            r.best_eval = e;
        }
    }
    r.evaluations = ctx.cache_len();
    r
}
