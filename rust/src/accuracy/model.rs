//! MRED-calibrated analytical ΔA model for ImageNet-scale CNNs.
//!
//! ImageNet inference for the five paper CNNs is infeasible offline, so we
//! extrapolate the *measured* tiny-CNN ΔA(multiplier) curve (native/PJRT
//! paths) with a two-parameter model:
//!
//!   ΔA% (mult, net) = A_SCALE * 100 * tanh( K * e_eff * depth_factor )
//!   depth_factor    = 1 + 0.15 * ln(depth / 3)
//!
//! where e_eff = sig_MRED + |sig_bias|/E[sig product] captures both the
//! spread and the systematic bias of the multiplier on the significand
//! domain, and depth = number of MAC layers. The depth dependence is mild:
//! per-layer perturbations largely average out (the paper's §III-D "errors
//! tend to cancel rather than propagate destructively"), but systematic
//! bias compounds slowly with depth. K is calibrated once against the
//! measured tiny-CNN table (see `calibrate_k`); the model preserves the
//! ordering the GA consumes: ΔA is strictly monotone in e_eff for a fixed
//! network.

use super::AccuracyTable;
use crate::approx::Multiplier;
use crate::dataflow::workloads::Workload;

/// Mean exact significand product over [128,255]^2 (~ (191.5)^2).
pub const MEAN_SIG_PRODUCT: f64 = 36672.25;

/// Default calibration constant (fit against the measured tiny-CNN table at
/// artifact-build time; `calibrate_k` recomputes it from live data).
pub const DEFAULT_K: f64 = 0.45;

/// Saturation ceiling: a fully broken multiplier drives a 5-class net to
/// chance (80% drop), an ImageNet net to ~ top-1 loss.
const A_SCALE: f64 = 0.8;

/// Effective arithmetic error of a multiplier on the MAC's input domain.
pub fn effective_error(m: &Multiplier) -> f64 {
    m.error.sig_mred + m.error.sig_bias.abs() / MEAN_SIG_PRODUCT
}

/// Mild depth amplification (1.0 for the 3-MAC-layer tiny CNN).
fn depth_factor(w: &Workload) -> f64 {
    let depth = w.n_conv_fc().max(1) as f64;
    1.0 + 0.15 * (depth / 3.0).max(1.0).ln()
}

/// Predicted accuracy drop in percentage points for a workload.
pub fn predicted_drop_pct(m: &Multiplier, w: &Workload, k: f64) -> f64 {
    drop_pct_from_error(effective_error(m), w, k)
}

/// The drop model on a raw effective-error value. Exposed for the campaign
/// engine's surrogate `EvalBackend`, which measures e_eff directly from a
/// significand LUT instead of a library entry.
pub fn drop_pct_from_error(e_eff: f64, w: &Workload, k: f64) -> f64 {
    A_SCALE * 100.0 * (k * e_eff * depth_factor(w)).tanh()
}

/// Calibrate K by least squares against a measured accuracy table on the
/// tiny CNN (minimizes sum (pred - measured)^2 over multipliers with
/// measurable drops). Returns `DEFAULT_K` when no informative points exist.
pub fn calibrate_k(lib: &[Multiplier], tiny: &Workload, measured: &AccuracyTable) -> f64 {
    let depth = depth_factor(tiny);
    let mut pts: Vec<(f64, f64)> = Vec::new();
    for m in lib {
        if let Some(drop) = measured.drop_pct(m.id) {
            let e = effective_error(m);
            if e > 1e-9 && drop > 0.05 {
                pts.push((e * depth, (drop / 100.0 / A_SCALE).clamp(0.0, 0.999)));
            }
        }
    }
    if pts.is_empty() {
        return DEFAULT_K;
    }
    // tanh(K x) = y  ->  K = atanh(y)/x ; robust aggregate = median.
    let mut ks: Vec<f64> = pts.iter().map(|&(x, y)| y.atanh() / x).collect();
    ks.sort_by(|a, b| a.partial_cmp(b).unwrap());
    ks[ks.len() / 2].clamp(0.5, 200.0)
}

/// Multiplier ids predicted to satisfy ΔA <= δ for a workload (Eq. 7).
/// The exact multiplier always qualifies.
pub fn feasible_multipliers(
    lib: &[Multiplier],
    w: &Workload,
    delta_pct: f64,
    k: f64,
) -> Vec<usize> {
    lib.iter()
        .filter(|m| predicted_drop_pct(m, w, k) <= delta_pct + 1e-9)
        .map(|m| m.id)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::{library, EXACT_ID};
    use crate::dataflow::workloads::workload;

    #[test]
    fn exact_has_zero_predicted_drop() {
        let lib = library();
        let w = workload("vgg16").unwrap();
        assert_eq!(predicted_drop_pct(&lib[EXACT_ID], &w, DEFAULT_K), 0.0);
    }

    #[test]
    fn drop_monotone_in_mred_within_family() {
        let lib = library();
        let w = workload("resnet50").unwrap();
        let drops: Vec<f64> = (1..=5)
            .map(|k| {
                let m = lib.iter().find(|m| m.name() == format!("TRUNC{k}")).unwrap();
                predicted_drop_pct(m, &w, DEFAULT_K)
            })
            .collect();
        for w2 in drops.windows(2) {
            assert!(w2[1] > w2[0], "{drops:?}");
        }
    }

    #[test]
    fn deeper_nets_degrade_more() {
        let lib = library();
        let m = lib.iter().find(|m| m.name() == "PERF3").unwrap();
        let shallow = workload("tinycnn").unwrap();
        let deep = workload("densenet121").unwrap();
        assert!(
            predicted_drop_pct(m, &deep, DEFAULT_K) > predicted_drop_pct(m, &shallow, DEFAULT_K)
        );
    }

    #[test]
    fn drop_bounded_by_scale() {
        let lib = library();
        let w = workload("densenet121").unwrap();
        for m in &lib {
            let d = predicted_drop_pct(m, &w, DEFAULT_K);
            assert!((0.0..=A_SCALE * 100.0).contains(&d), "{}: {d}", m.name());
        }
    }

    #[test]
    fn feasible_sets_nested_in_delta() {
        let lib = library();
        let w = workload("vgg16").unwrap();
        let f1 = feasible_multipliers(&lib, &w, 1.0, DEFAULT_K);
        let f2 = feasible_multipliers(&lib, &w, 2.0, DEFAULT_K);
        let f3 = feasible_multipliers(&lib, &w, 3.0, DEFAULT_K);
        assert!(f1.len() <= f2.len() && f2.len() <= f3.len());
        for id in &f1 {
            assert!(f2.contains(id));
        }
        for id in &f2 {
            assert!(f3.contains(id));
        }
        assert!(f1.contains(&EXACT_ID));
        // Looser δ must admit at least one non-exact design.
        assert!(f3.len() > 1, "3% admits only the exact multiplier");
    }

    #[test]
    fn calibration_recovers_k_from_synthetic_table() {
        let lib = library();
        let tiny = workload("tinycnn").unwrap();
        let k_true = 12.0;
        let mut table = AccuracyTable { exact: 0.95, ..Default::default() };
        for m in &lib {
            let drop = predicted_drop_pct(m, &tiny, k_true) / 100.0;
            table.accuracy.insert(m.id, 0.95 - drop);
        }
        let k_fit = calibrate_k(&lib, &tiny, &table);
        assert!((k_fit - k_true).abs() / k_true < 0.05, "k_fit {k_fit}");
    }

    #[test]
    fn calibration_empty_table_falls_back() {
        let lib = library();
        let tiny = workload("tinycnn").unwrap();
        let table = AccuracyTable { exact: 0.95, ..Default::default() };
        assert_eq!(calibrate_k(&lib, &tiny, &table), DEFAULT_K);
    }
}
