//! The evolutionary engine (paper §III-E, Steps 1-6): initialization,
//! fitness evaluation, tournament selection, uniform crossover, bounded
//! mutation, elitism, convergence-based termination.

use super::chromosome::{Chromosome, SearchSpace};
use super::fitness::{Evaluation, FitnessCtx};
use crate::util::Rng;

/// GA hyperparameters.
#[derive(Debug, Clone, Copy)]
pub struct GaParams {
    pub population: usize,
    pub generations: usize,
    pub tournament: usize,
    pub crossover_rate: f64,
    pub mutation_rate: f64,
    pub elites: usize,
    /// Stop early after this many generations without best-fitness
    /// improvement (> 0.1% relative).
    pub patience: usize,
    pub seed: u64,
}

impl Default for GaParams {
    fn default() -> Self {
        Self {
            population: 64,
            generations: 48,
            tournament: 3,
            crossover_rate: 0.9,
            mutation_rate: 0.45,
            elites: 2,
            patience: 12,
            seed: 0xCAFE,
        }
    }
}

/// Outcome of a GA run.
#[derive(Debug, Clone)]
pub struct GaResult {
    pub best: Chromosome,
    pub best_eval: Evaluation,
    /// Best fitness after each generation (for convergence plots/tests).
    pub history: Vec<f64>,
    pub generations_run: usize,
    pub evaluations: usize,
}

/// The GA driver.
pub struct Ga {
    pub space: SearchSpace,
    pub params: GaParams,
}

impl Ga {
    pub fn new(space: SearchSpace, params: GaParams) -> Self {
        assert!(params.population >= 4, "population too small");
        assert!(params.elites < params.population);
        assert!(params.tournament >= 1);
        Self { space, params }
    }

    /// Run the evolutionary loop against a fitness context.
    pub fn run(&self, ctx: &mut FitnessCtx) -> GaResult {
        let _span = crate::obs::span("ga.run");
        let p = self.params;
        let mut rng = Rng::new(p.seed);

        // Step 1: initialization.
        let mut pop: Vec<Chromosome> =
            (0..p.population).map(|_| self.space.sample(&mut rng)).collect();
        let mut history = Vec::with_capacity(p.generations);
        let mut best: Option<(Chromosome, Evaluation)> = None;
        let mut stale = 0usize;
        let mut gens = 0usize;

        for _gen in 0..p.generations {
            let _gen_span = crate::obs::span("ga.generation");
            gens += 1;
            // Step 2: fitness evaluation.
            let evals: Vec<Evaluation> = pop.iter().map(|c| ctx.eval(c)).collect();

            // Track the incumbent.
            let (gen_best_i, gen_best) = evals
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.fitness.partial_cmp(&b.1.fitness).unwrap())
                .map(|(i, e)| (i, *e))
                .unwrap();
            let improved = match &best {
                None => true,
                Some((_, e)) => gen_best.fitness < e.fitness * (1.0 - 1e-3),
            };
            if improved {
                best = Some((pop[gen_best_i].clone(), gen_best));
                stale = 0;
            } else {
                stale += 1;
            }
            history.push(best.as_ref().unwrap().1.fitness);

            // Step 6: termination (convergence criterion).
            if stale >= p.patience {
                break;
            }

            // Steps 3-5: selection, crossover, mutation (+ elitism).
            let mut order: Vec<usize> = (0..pop.len()).collect();
            order.sort_by(|&a, &b| evals[a].fitness.partial_cmp(&evals[b].fitness).unwrap());
            let mut next: Vec<Chromosome> =
                order.iter().take(p.elites).map(|&i| pop[i].clone()).collect();

            let tournament = |rng: &mut Rng| -> usize {
                let mut winner = rng.below(pop.len() as u64) as usize;
                for _ in 1..p.tournament {
                    let cand = rng.below(pop.len() as u64) as usize;
                    if evals[cand].fitness < evals[winner].fitness {
                        winner = cand;
                    }
                }
                winner
            };

            while next.len() < p.population {
                let a = tournament(&mut rng);
                let mut child = if rng.chance(p.crossover_rate) {
                    let b = tournament(&mut rng);
                    pop[a].crossover(&pop[b], &mut rng)
                } else {
                    pop[a].clone()
                };
                if rng.chance(p.mutation_rate) {
                    child = self.space.mutate(&child, &mut rng);
                }
                next.push(child);
            }
            pop = next;
        }

        let (best, best_eval) = best.expect("at least one generation ran");
        GaResult { best, best_eval, history, generations_run: gens, evaluations: ctx.cache_len() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::area::die::Integration;
    use crate::area::TechNode;
    use crate::approx::{filter_by_mred, library};
    use crate::dataflow::workloads::workload;
    use crate::ga::fitness::FitnessCtx;

    fn run_ga(seed: u64, pop: usize, gens: usize) -> GaResult {
        let lib = library();
        let w = workload("resnet50").unwrap();
        let feasible = filter_by_mred(&lib, 0.02);
        let space = SearchSpace::standard(feasible);
        let mut ctx = FitnessCtx::new(&w, TechNode::N14, Integration::ThreeD, &lib, None);
        let params = GaParams { population: pop, generations: gens, seed, ..Default::default() };
        Ga::new(space, params).run(&mut ctx)
    }

    #[test]
    fn history_is_monotone_nonincreasing() {
        let r = run_ga(1, 24, 15);
        for w in r.history.windows(2) {
            assert!(w[1] <= w[0] + 1e-12, "history regressed: {:?}", w);
        }
    }

    #[test]
    fn ga_beats_random_sampling_budget_matched() {
        let lib = library();
        let w = workload("resnet50").unwrap();
        let feasible = filter_by_mred(&lib, 0.02);
        let space = SearchSpace::standard(feasible.clone());

        let r = run_ga(7, 24, 20);

        // Random search with the same number of evaluations.
        let mut ctx = FitnessCtx::new(&w, TechNode::N14, Integration::ThreeD, &lib, None);
        let mut rng = crate::util::Rng::new(999);
        let mut best_rand = f64::INFINITY;
        for _ in 0..r.evaluations {
            let c = space.sample(&mut rng);
            best_rand = best_rand.min(ctx.eval(&c).fitness);
        }
        assert!(
            r.best_eval.fitness <= best_rand * 1.05,
            "GA {} vs random {}",
            r.best_eval.fitness,
            best_rand
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run_ga(5, 16, 8);
        let b = run_ga(5, 16, 8);
        assert_eq!(a.best, b.best);
        assert_eq!(a.history, b.history);
    }

    #[test]
    fn best_is_in_space() {
        let lib = library();
        let feasible = filter_by_mred(&lib, 0.02);
        let space = SearchSpace::standard(feasible);
        let r = run_ga(3, 16, 10);
        assert!(space.contains(&r.best));
    }

    #[test]
    fn early_stop_respects_patience() {
        let r = run_ga(11, 16, 40);
        assert!(r.generations_run <= 40);
        // History length equals generations actually run.
        assert_eq!(r.history.len(), r.generations_run);
    }

    #[test]
    #[should_panic]
    fn tiny_population_rejected() {
        let lib = library();
        let space = SearchSpace::standard(vec![0]);
        let _ = Ga::new(
            space,
            GaParams { population: 2, ..Default::default() },
        );
        let _ = lib;
    }
}
