//! The in-process thread-pool executor: N std-threads drain the schedule
//! through an atomic cursor, all sharing the process-wide `EvalService`;
//! results flow back over a channel and the single writer feeds them to
//! the commit pipeline, whose reorder buffer restores schedule order.
//!
//! Workers run the pipeline's own [`PruneMode`](super::super::commit::PruneMode)
//! predicate as a dispatch-side early-out against the shared front cell — sound because
//! incumbents only ever improve as rows commit, so a prune visible at
//! dispatch still holds when the pipeline re-checks authoritatively at the
//! commit slot.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;

use anyhow::{Context as _, Result};

use crate::runtime::EvalService;

use super::super::commit::{CommitPipeline, JobOutcome};
use super::super::source::{JobCtx, JobSource};
use super::{job_context, run_job_quarantined, Executor};

/// The classic worker pool. `workers` is clamped to at least 1 and at most
/// the number of scheduled jobs.
pub struct ThreadPoolExecutor {
    pub workers: usize,
}

impl ThreadPoolExecutor {
    pub fn new(workers: usize) -> Self {
        Self { workers }
    }
}

impl Executor for ThreadPoolExecutor {
    fn describe(&self) -> String {
        format!("{} worker threads", self.workers.max(1))
    }

    // Single-process runs write the canonical `<store>.status.json` with
    // no lane label.
    fn status_shard(&self) -> Option<String> {
        None
    }

    fn drain(
        &self,
        ctx: &JobCtx,
        source: &JobSource,
        service: &EvalService,
        pipeline: &mut CommitPipeline<'_>,
    ) -> Result<()> {
        let schedule = source.schedule();
        let n_workers = self.workers.max(1).min(schedule.len().max(1));
        let next = AtomicUsize::new(0);
        let (tx, rx) = mpsc::channel::<Result<(usize, JobOutcome)>>();
        let front = pipeline.front();
        let mode = pipeline.mode();

        std::thread::scope(|scope| -> Result<()> {
            for _ in 0..n_workers {
                let tx = tx.clone();
                let client = service.client();
                let (ctx, source, front, next, schedule) = (ctx, source, front, &next, schedule);
                scope.spawn(move || loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= schedule.len() {
                        break;
                    }
                    let job = &schedule[i];
                    let pruned = mode
                        .fires(job, source.bound(job.id), || front.incumbent(&job.family()));
                    let out = if pruned {
                        Ok((job.id, JobOutcome::Pruned))
                    } else {
                        // Quarantined: a panicking evaluation becomes a
                        // `failed` row instead of unwinding into the pool.
                        run_job_quarantined(job, ctx, &client)
                            .with_context(|| job_context(job))
                            .map(|row| (job.id, JobOutcome::Row(row)))
                    };
                    if tx.send(out).is_err() {
                        break;
                    }
                });
            }
            drop(tx);

            for msg in rx {
                let (id, out) = msg?;
                pipeline.offer(id, out)?;
            }
            Ok(())
        })
    }
}
