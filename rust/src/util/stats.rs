//! Descriptive statistics over f64 samples.

/// Summary statistics of a sample.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Summary {
    /// Compute summary statistics. Panics on an empty sample.
    pub fn of(xs: &[f64]) -> Self {
        assert!(!xs.is_empty(), "Summary::of empty sample");
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / (n.max(2) - 1) as f64;
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Self {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile_sorted(&sorted, 50.0),
            p95: percentile_sorted(&sorted, 95.0),
            p99: percentile_sorted(&sorted, 99.0),
        }
    }
}

/// Linear-interpolated percentile of a pre-sorted slice; q in [0, 100].
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q / 100.0 * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Geometric mean (all inputs must be positive).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let s: f64 = xs.iter().map(|x| {
        assert!(*x > 0.0, "geomean needs positive inputs, got {x}");
        x.ln()
    }).sum();
    (s / xs.len() as f64).exp()
}

/// Relative change (b - a) / a, in percent.
pub fn pct_change(a: f64, b: f64) -> f64 {
    (b - a) / a * 100.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn summary_singleton() {
        let s = Summary::of(&[7.0]);
        assert_eq!(s.p50, 7.0);
        assert_eq!(s.p99, 7.0);
        assert_eq!(s.std, 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile_sorted(&xs, 50.0) - 5.0).abs() < 1e-12);
        assert_eq!(percentile_sorted(&xs, 0.0), 0.0);
        assert_eq!(percentile_sorted(&xs, 100.0), 10.0);
    }

    #[test]
    fn geomean_matches_hand_calc() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn pct_change_signs() {
        assert!((pct_change(100.0, 70.0) + 30.0).abs() < 1e-12);
        assert!((pct_change(50.0, 75.0) - 50.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn empty_summary_panics() {
        let _ = Summary::of(&[]);
    }
}
