//! Fitness evaluation: CDP = C_embodied x D_task with constraint handling,
//! plus a memoizing cache (the GA revisits configurations constantly).

use std::collections::HashMap;

use super::chromosome::Chromosome;
use crate::area::die::Integration;
use crate::area::TechNode;
use crate::carbon::{carbon_per_mm2, embodied_carbon, CarbonBreakdown};
use crate::dataflow::arch::AccelConfig;
use crate::dataflow::mapper::map_network;
use crate::dataflow::workloads::Workload;
use crate::approx::Multiplier;

/// Everything a fitness evaluation needs.
pub struct FitnessCtx<'a> {
    pub workload: &'a Workload,
    pub node: TechNode,
    pub integration: Integration,
    pub library: &'a [Multiplier],
    /// Optional FPS floor (paper §IV-B); designs below pay a penalty.
    pub fps_floor: Option<f64>,
    cache: HashMap<Chromosome, Evaluation>,
}

impl<'a> FitnessCtx<'a> {
    pub fn new(
        workload: &'a Workload,
        node: TechNode,
        integration: Integration,
        library: &'a [Multiplier],
        fps_floor: Option<f64>,
    ) -> Self {
        Self { workload, node, integration, library, fps_floor, cache: HashMap::new() }
    }

    /// Evaluate with memoization.
    pub fn eval(&mut self, c: &Chromosome) -> Evaluation {
        if let Some(e) = self.cache.get(c) {
            return *e;
        }
        let e = evaluate(
            c,
            self.workload,
            self.node,
            self.integration,
            self.library,
            self.fps_floor,
        );
        self.cache.insert(c.clone(), e);
        e
    }

    pub fn cache_len(&self) -> usize {
        self.cache.len()
    }

    /// Lowest-carbon *feasible* design among all evaluated configurations
    /// whose fitness is within `max_fitness`. Used by the figure pipelines:
    /// among CDP-near-optimal designs, report the most sustainable one
    /// (CDP is flat near its optimum — carbon/delay splits there are
    /// interchangeable, and the paper reports the carbon-efficient end).
    /// Carbon ties break on the chromosome's genes, never on `HashMap`
    /// iteration order — campaign stores are compared byte-for-byte across
    /// runs, so this selection must be deterministic.
    pub fn near_optimal_min_carbon(&self, max_fitness: f64) -> Option<(Chromosome, Evaluation)> {
        let gene_key =
            |c: &Chromosome| (c.px, c.py, c.rf_bytes, c.sram_bytes, c.mult_id);
        self.cache
            .iter()
            .filter(|(_, e)| e.feasible && e.fitness <= max_fitness)
            .min_by(|a, b| {
                a.1.carbon_g
                    .partial_cmp(&b.1.carbon_g)
                    .unwrap()
                    .then_with(|| gene_key(a.0).cmp(&gene_key(b.0)))
            })
            .map(|(c, e)| (c.clone(), *e))
    }

    /// Build the `AccelConfig` for a chromosome.
    pub fn config(&self, c: &Chromosome) -> AccelConfig {
        to_config(c, self.node, self.integration)
    }
}

/// Full evaluation of one configuration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Evaluation {
    /// Embodied carbon, gCO2.
    pub carbon_g: f64,
    /// Task delay, seconds.
    pub delay_s: f64,
    /// Frames per second.
    pub fps: f64,
    /// Carbon-Delay-Product (gCO2 * s).
    pub cdp: f64,
    /// Penalized fitness the GA minimizes (== cdp when constraints hold).
    pub fitness: f64,
    /// Carbon per package mm^2 (Fig. 3 y-axis).
    pub carbon_per_mm2: f64,
    /// Total silicon, mm^2.
    pub silicon_mm2: f64,
    pub feasible: bool,
}

pub fn to_config(c: &Chromosome, node: TechNode, integration: Integration) -> AccelConfig {
    AccelConfig {
        px: c.px,
        py: c.py,
        rf_bytes: c.rf_bytes,
        sram_bytes: c.sram_bytes,
        node,
        integration,
        mult_id: c.mult_id,
    }
}

/// CDP metric (paper's objective).
pub fn cdp(carbon_g: f64, delay_s: f64) -> f64 {
    carbon_g * delay_s
}

/// Evaluate one chromosome: carbon model (Eq. 1-5) + dataflow delay model,
/// FPS-constraint penalty if requested.
pub fn evaluate(
    c: &Chromosome,
    workload: &Workload,
    node: TechNode,
    integration: Integration,
    library: &[Multiplier],
    fps_floor: Option<f64>,
) -> Evaluation {
    let mult = &library[c.mult_id];
    let cfg = to_config(c, node, integration);
    let areas = cfg.die_areas(mult);
    let breakdown: CarbonBreakdown = embodied_carbon(&areas, node, integration);
    let carbon_g = breakdown.total_g();
    let mapping = map_network(workload, &cfg);
    let delay_s = mapping.delay_s(&cfg);
    let fps = 1.0 / delay_s;
    let cdp_v = cdp(carbon_g, delay_s);
    let (fitness, feasible) = match fps_floor {
        Some(floor) if fps < floor => {
            // Multiplicative penalty growing with the violation: keeps the
            // search surface smooth while making infeasible designs lose
            // every tournament against feasible ones of similar CDP.
            let violation = floor / fps;
            (cdp_v * (1.0 + 10.0 * (violation - 1.0)).max(1.0) * violation, false)
        }
        _ => (cdp_v, true),
    };
    Evaluation {
        carbon_g,
        delay_s,
        fps,
        cdp: cdp_v,
        fitness,
        carbon_per_mm2: carbon_per_mm2(&breakdown, &areas),
        silicon_mm2: areas.silicon_mm2(),
        feasible,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::{library, EXACT_ID};
    use crate::dataflow::workloads::workload;

    fn chrom(mult_id: usize) -> Chromosome {
        Chromosome { px: 16, py: 16, rf_bytes: 512, sram_bytes: 1 << 20, mult_id }
    }

    #[test]
    fn evaluation_fields_consistent() {
        let lib = library();
        let w = workload("resnet50").unwrap();
        let e = evaluate(&chrom(EXACT_ID), &w, TechNode::N14, Integration::ThreeD, &lib, None);
        assert!(e.carbon_g > 0.0 && e.delay_s > 0.0);
        assert!((e.cdp - e.carbon_g * e.delay_s).abs() < 1e-12);
        assert!((e.fps - 1.0 / e.delay_s).abs() < 1e-9);
        assert_eq!(e.fitness, e.cdp);
        assert!(e.feasible);
    }

    #[test]
    fn approx_multiplier_lowers_carbon_same_delay() {
        let lib = library();
        let w = workload("vgg16").unwrap();
        let exact = evaluate(&chrom(EXACT_ID), &w, TechNode::N14, Integration::ThreeD, &lib, None);
        // An aggressive truncation design (id of TRUNC4).
        let trunc = lib.iter().find(|m| m.name() == "TRUNC4").unwrap().id;
        let appr = evaluate(&chrom(trunc), &w, TechNode::N14, Integration::ThreeD, &lib, None);
        assert!(appr.carbon_g < exact.carbon_g);
        assert_eq!(appr.delay_s, exact.delay_s); // same array dims -> same delay
        assert!(appr.cdp < exact.cdp);
    }

    #[test]
    fn fps_penalty_applies_only_below_floor() {
        let lib = library();
        let w = workload("vgg16").unwrap();
        let free = evaluate(&chrom(EXACT_ID), &w, TechNode::N14, Integration::ThreeD, &lib, None);
        let hard_floor = free.fps * 4.0;
        let pen = evaluate(
            &chrom(EXACT_ID),
            &w,
            TechNode::N14,
            Integration::ThreeD,
            &lib,
            Some(hard_floor),
        );
        assert!(!pen.feasible);
        assert!(pen.fitness > pen.cdp);
        let easy = evaluate(
            &chrom(EXACT_ID),
            &w,
            TechNode::N14,
            Integration::ThreeD,
            &lib,
            Some(free.fps * 0.5),
        );
        assert!(easy.feasible);
        assert_eq!(easy.fitness, easy.cdp);
    }

    #[test]
    fn cache_hits_return_identical_results() {
        let lib = library();
        let w = workload("densenet121").unwrap();
        let mut ctx = FitnessCtx::new(&w, TechNode::N7, Integration::ThreeD, &lib, None);
        let c = chrom(EXACT_ID);
        let a = ctx.eval(&c);
        let n = ctx.cache_len();
        let b = ctx.eval(&c);
        assert_eq!(a, b);
        assert_eq!(ctx.cache_len(), n);
    }
}
