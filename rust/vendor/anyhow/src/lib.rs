//! Offline stand-in for the `anyhow` crate (crates.io is unreachable in the
//! build environment). API-compatible with the subset carbon3d uses:
//!
//! - `anyhow::Error` / `anyhow::Result<T>`
//! - `anyhow!`, `bail!`, `ensure!` macros
//! - `Context::{context, with_context}` on `Result` and `Option`
//! - `?` conversion from any `std::error::Error + Send + Sync + 'static`
//! - `{e}` prints the outermost message, `{e:#}` the full context chain
//!
//! Swap back to the real crate by replacing the `path` dependency in
//! rust/Cargo.toml with `anyhow = "1"`.

use std::fmt;

/// `Result` specialized to [`Error`], as in anyhow.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A context-carrying error. `chain[0]` is the outermost message; deeper
/// entries are the causes it wraps.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a single message.
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Error { chain: vec![m.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, c: C) -> Self {
        self.chain.insert(0, c.to_string());
        self
    }

    /// The cause messages from outermost to innermost.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// Root (innermost) cause message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the whole chain, outermost first — matches anyhow.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// Like anyhow: `Error` deliberately does NOT implement `std::error::Error`,
// which is what makes this blanket `From` coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Context extension for `Result` and `Option`, as in anyhow.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.into().context(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::Error::msg(format!($($arg)*)))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(format!(
                "condition failed: `{}`",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::Error::msg(format!($($arg)*)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/path").context("read config")?;
        Ok(())
    }

    #[test]
    fn context_chain_formats() {
        let e = io_fail().unwrap_err();
        assert_eq!(format!("{e}"), "read config");
        let full = format!("{e:#}");
        assert!(full.starts_with("read config: "), "{full}");
    }

    #[test]
    fn macros_roundtrip() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative: {x}");
            if x == 0 {
                bail!("zero not allowed");
            }
            Err(anyhow!("got {x}"))
        }
        assert_eq!(format!("{}", f(-1).unwrap_err()), "negative: -1");
        assert_eq!(format!("{}", f(0).unwrap_err()), "zero not allowed");
        assert_eq!(format!("{}", f(3).unwrap_err()), "got 3");
    }

    #[test]
    fn option_context() {
        let none: Option<u8> = None;
        let e = none.context("missing").unwrap_err();
        assert_eq!(format!("{e}"), "missing");
    }

    #[test]
    fn bare_ensure() {
        fn f() -> Result<()> {
            ensure!(1 + 1 == 3);
            Ok(())
        }
        assert!(format!("{}", f().unwrap_err()).contains("condition failed"));
    }
}
