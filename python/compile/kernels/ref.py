"""Pure-jnp oracle for LUT-based approximate bfloat16 matmul.

This module defines the *semantics* that both the Pallas kernel
(`approx_matmul.py`) and the native Rust evaluator must match bit-for-bit:

  1. Inputs are rounded f32 -> bf16 (round-to-nearest-even).
  2. Each scalar product is computed as the approximate MAC datapath does:
       sign     : exact XOR
       exponent : exact 8-bit addition (two exact 8-bit adders in the paper)
       mantissa : 8x8 significand product looked up in a 128x128 LUT
                  (the approximate multiplier under evaluation; index = the
                  two 7-bit stored mantissas)
       zeros / denormals are flushed to zero (exp field == 0).
  3. Accumulation over K is exact f32 (the paper's exact 24-bit accumulator).

With the *exact* LUT (lut[i,j] = (128+i)*(128+j)) the result equals
float32(bf16(a)) @ float32(bf16(b)) exactly, which is the main test oracle.
"""

import jax
import jax.numpy as jnp
import numpy as np


def exact_lut() -> np.ndarray:
    """128x128 f32 LUT of exact 8-bit significand products."""
    i = np.arange(128, dtype=np.uint32) + 128
    return (i[:, None] * i[None, :]).astype(np.float32)


def truncated_lut(k: int) -> np.ndarray:
    """LUT for a multiplier whose k LSBs of each operand are zeroed (DRUM-like
    truncation). Mirrors `ApproxKind::Truncate` on the Rust side."""
    i = np.arange(128, dtype=np.uint32) + 128
    mask = np.uint32(0xFFFFFFFF) ^ np.uint32((1 << k) - 1)
    it = i & mask
    return (it[:, None] * it[None, :]).astype(np.float32)


def perforated_lut(p: int) -> np.ndarray:
    """LUT for a multiplier with the p least-significant partial products
    perforated (EvoApprox-style PP perforation): drops the contribution of
    b's p low bits. Mirrors `ApproxKind::Perforate`."""
    i = (np.arange(128, dtype=np.uint32) + 128).astype(np.uint64)
    bl = i & np.uint64((1 << p) - 1)
    return (i[:, None] * (i - bl)[None, :]).astype(np.float32)


def bf16_round(x: jnp.ndarray) -> jnp.ndarray:
    """Round f32 -> bf16 (RNE) and return as f32 with the low 16 bits zero."""
    bits = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)
    lsb = (bits >> 16) & jnp.uint32(1)
    rounded = (bits + jnp.uint32(0x7FFF) + lsb) & jnp.uint32(0xFFFF0000)
    return jax.lax.bitcast_convert_type(rounded, jnp.float32)


def decompose(x: jnp.ndarray):
    """Split bf16-rounded f32 values into (sign_factor f32, exp u32, mant u32).

    sign_factor is +-1.0; exp is the raw 8-bit biased exponent; mant is the
    7-bit stored mantissa.
    """
    bits = jax.lax.bitcast_convert_type(bf16_round(x), jnp.uint32)
    sign = jnp.where((bits >> 31) != 0, -1.0, 1.0).astype(jnp.float32)
    exp = (bits >> 23) & jnp.uint32(0xFF)
    mant = (bits >> 16) & jnp.uint32(0x7F)
    return sign, exp, mant


def pow2_exact(e: jnp.ndarray) -> jnp.ndarray:
    """Exact f32 2^e for integer e (i32 array), via exponent-field bit
    construction. XLA lowers `exp2` to an inexact polynomial, which breaks
    bit-exactness of the emulated datapath; this does not. A 3-factor chain
    covers e in [-378, 381] (each factor a representable power of two, and
    products of powers of two are exact — including the denormal range)."""
    e = e.astype(jnp.int32)

    def factor(ei):
        return jax.lax.bitcast_convert_type(
            ((ei + 127) << 23).astype(jnp.uint32), jnp.float32
        )

    e1 = jnp.clip(e, -126, 127)
    r = e - e1
    e2 = jnp.clip(r, -126, 127)
    e3 = r - e2
    return factor(e1) * factor(e2) * factor(jnp.clip(e3, -126, 127))


def approx_mul_elementwise(a: jnp.ndarray, b: jnp.ndarray, lut: jnp.ndarray) -> jnp.ndarray:
    """Elementwise approximate bf16 product of broadcast-compatible arrays."""
    sa, ea, ma = decompose(a)
    sb, eb, mb = decompose(b)
    sig = lut[ma, mb]  # f32; exact LUT values lie in [16384, 65025]
    # value = sig * 2^(ea-127-7) * 2^(eb-127-7) = sig * 2^(ea+eb-268)
    scale = pow2_exact((ea + eb).astype(jnp.int32) - 268)
    prod = sa * sb * (sig * scale)
    nonzero = (ea > 0) & (eb > 0)
    return jnp.where(nonzero, prod, 0.0)


def approx_matmul_ref(a: jnp.ndarray, b: jnp.ndarray, lut: jnp.ndarray) -> jnp.ndarray:
    """[M,K] x [K,N] approximate matmul, f32 accumulation. Oracle — O(M*K*N)
    memory; use only at test sizes."""
    prods = approx_mul_elementwise(a[:, :, None], b[None, :, :], lut)
    return jnp.sum(prods, axis=1)


def exact_matmul_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """bf16-quantized exact matmul with f32 accumulation (what the exact-LUT
    approximate path must reproduce)."""
    return jnp.matmul(bf16_round(a), bf16_round(b), preferred_element_type=jnp.float32)
