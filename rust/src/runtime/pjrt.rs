//! Thin wrapper over the `xla` crate's PJRT CPU client.
//!
//! Pattern from /opt/xla-example/load_hlo: HLO text -> HloModuleProto ->
//! XlaComputation -> compile -> execute. Artifacts are lowered with
//! return_tuple=True, so results unwrap with `to_tuple1`.

use std::path::Path;

use anyhow::{ensure, Context, Result};

/// A compiled executable plus its human name (for errors/metrics).
pub struct Executable {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
}

/// The PJRT client wrapper.
pub struct PjrtClient {
    client: xla::PjRtClient,
}

impl PjrtClient {
    /// Create the CPU client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Self { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Load an HLO-text artifact and compile it.
    pub fn compile_hlo_text(&self, name: &str, path: &Path) -> Result<Executable> {
        ensure!(path.exists(), "HLO artifact {} missing (run `make artifacts`)", path.display());
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compile artifact {name}"))?;
        Ok(Executable { name: name.to_string(), exe })
    }
}

impl Executable {
    /// Execute with f32 tensor inputs (shape per tensor), returning the
    /// flattened f32 output of the 1-tuple result.
    pub fn run_f32(&self, inputs: &[(&[f32], &[usize])]) -> Result<Vec<f32>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|(data, shape)| -> Result<xla::Literal> {
                let lit = xla::Literal::vec1(data);
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                lit.reshape(&dims)
                    .with_context(|| format!("reshape input to {shape:?} for {}", self.name))
            })
            .collect::<Result<Vec<_>>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("execute {}", self.name))?;
        let lit = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetch result of {}", self.name))?;
        let out = lit.to_tuple1().with_context(|| format!("untuple result of {}", self.name))?;
        out.to_vec::<f32>().with_context(|| format!("read f32 result of {}", self.name))
    }
}
