"""L2 correctness: CNN shapes, im2col semantics, training, approx-path wiring."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import dataset, model
from compile.kernels import ref


@pytest.fixture(scope="module")
def tiny_data():
    x, y = dataset.generate(128, seed=42)
    return jnp.asarray(x), jnp.asarray(y)


@pytest.fixture(scope="module")
def params():
    return model.init_params(seed=3)


# ----------------------------------------------------------------- dataset
def test_dataset_shapes_and_ranges():
    x, y = dataset.generate(64, seed=0)
    assert x.shape == (64, 16, 16, 1) and x.dtype == np.float32
    assert y.shape == (64,) and y.min() >= 0 and y.max() < dataset.NUM_CLASSES


def test_dataset_deterministic_per_seed():
    x1, y1 = dataset.generate(32, seed=9)
    x2, y2 = dataset.generate(32, seed=9)
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(y1, y2)


def test_dataset_differs_across_seeds():
    x1, _ = dataset.generate(32, seed=1)
    x2, _ = dataset.generate(32, seed=2)
    assert not np.array_equal(x1, x2)


def test_dataset_all_classes_present():
    _, y = dataset.generate(256, seed=5)
    assert set(np.unique(y)) == set(range(dataset.NUM_CLASSES))


# ----------------------------------------------------------------- im2col
def test_im2col_shape():
    x = jnp.zeros((2, 8, 8, 3))
    cols = model.im2col(x, 3, 3)
    assert cols.shape == (2 * 8 * 8, 9 * 3)


def test_im2col_center_tap_identity():
    """The (dy=1,dx=1) column of a 3x3 im2col is the input itself."""
    rng = np.random.default_rng(0)
    x = rng.normal(size=(1, 6, 6, 2)).astype(np.float32)
    cols = np.asarray(model.im2col(jnp.asarray(x), 3, 3))
    center = cols[:, 4 * 2 : 4 * 2 + 2].reshape(6, 6, 2)
    np.testing.assert_array_equal(center, x[0])


def test_conv2d_matches_lax_conv():
    rng = np.random.default_rng(1)
    x = rng.normal(size=(2, 8, 8, 3)).astype(np.float32)
    w = rng.normal(size=(3, 3, 3, 5)).astype(np.float32)
    b = rng.normal(size=(5,)).astype(np.float32)
    ours = model.conv2d(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b))
    want = jax.lax.conv_general_dilated(
        jnp.asarray(x), jnp.asarray(w), (1, 1), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    ) + b
    np.testing.assert_allclose(np.asarray(ours), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_maxpool2():
    x = jnp.arange(16, dtype=jnp.float32).reshape(1, 4, 4, 1)
    got = np.asarray(model.maxpool2(x))[0, :, :, 0]
    np.testing.assert_array_equal(got, [[5, 7], [13, 15]])


# ----------------------------------------------------------------- forward
def test_forward_shape(params, tiny_data):
    x, _ = tiny_data
    logits = model.forward(params, x[:8])
    assert logits.shape == (8, model.NUM_CLASSES)
    assert np.all(np.isfinite(np.asarray(logits)))


def test_forward_exact_lut_close_to_exact(params, tiny_data):
    """Exact LUT through the approx datapath = bf16-rounded forward pass;
    logits should be close to the f32 exact path (quantization only)."""
    x, _ = tiny_data
    exact = np.asarray(model.forward(params, x[:16]))
    lut = np.asarray(model.forward(params, x[:16], lut=jnp.asarray(ref.exact_lut())))
    denom = np.abs(exact).max()
    assert np.abs(exact - lut).max() / denom < 0.05


def test_forward_batch_consistency(params, tiny_data):
    """Per-image results must not depend on batch composition."""
    x, _ = tiny_data
    full = np.asarray(model.forward(params, x[:8]))
    halves = np.concatenate(
        [np.asarray(model.forward(params, x[:4])), np.asarray(model.forward(params, x[4:8]))]
    )
    np.testing.assert_allclose(full, halves, rtol=1e-5, atol=1e-6)


@given(st.integers(0, 6))
@settings(max_examples=7, deadline=None)
def test_forward_monotone_degradation_in_perforation(p):
    """More perforation -> logits drift further from exact (weak monotonicity:
    error at p must be >= error at 0, and large p must exceed small p)."""
    x, _ = dataset.generate(8, seed=11)
    prm = model.init_params(seed=3)
    exact = np.asarray(model.forward(prm, jnp.asarray(x)))
    lut = jnp.asarray(ref.perforated_lut(p))
    approx = np.asarray(model.forward(prm, jnp.asarray(x), lut=lut))
    err = np.abs(exact - approx).mean()
    base = np.abs(exact - np.asarray(model.forward(prm, jnp.asarray(x), lut=jnp.asarray(ref.exact_lut())))).mean()
    assert err >= base - 1e-6


# ----------------------------------------------------------------- training
def test_training_reduces_loss():
    x, y = dataset.generate(512, seed=21)
    p = model.init_params(seed=2)
    p, hist = model.train(p, jnp.asarray(x), jnp.asarray(y), steps=60, lr=0.08)
    assert np.mean(hist[-10:]) < np.mean(hist[:10]) * 0.7


def test_training_improves_accuracy():
    x, y = dataset.generate(512, seed=22)
    vx, vy = dataset.generate(128, seed=23)
    p0 = model.init_params(seed=2)
    acc0 = model.accuracy(p0, jnp.asarray(vx), jnp.asarray(vy))
    p1, _ = model.train(p0, jnp.asarray(x), jnp.asarray(y), steps=120, lr=0.08)
    acc1 = model.accuracy(p1, jnp.asarray(vx), jnp.asarray(vy))
    assert acc1 > max(acc0, 0.5)


def test_accuracy_batching_invariance(params, tiny_data):
    x, y = tiny_data
    a1 = model.accuracy(params, x, y, batch=32)
    a2 = model.accuracy(params, x, y, batch=128)
    assert a1 == a2


def test_param_specs_cover_params():
    p = model.init_params(0)
    assert set(p.keys()) == {name for name, _ in model.PARAM_SPECS}
    for name, shape in model.PARAM_SPECS:
        assert tuple(p[name].shape) == shape
