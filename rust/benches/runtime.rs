//! Bench RUNTIME: PJRT compile + execute latency for every artifact — the
//! L3 hot path of the accuracy-evaluation service. Skips gracefully when
//! artifacts are absent (run `make artifacts`).

use std::path::Path;

use carbon3d::approx::{library, lut_f32, EXACT_ID};
use carbon3d::runtime::{Artifacts, Engine};
use carbon3d::obs::bench::{bench, time_once};

fn main() {
    println!("== RUNTIME (PJRT) benches ==");
    let artifacts = match Artifacts::load(Path::new("artifacts")) {
        Ok(a) => a,
        Err(e) => {
            println!("SKIP: {e:#}");
            return;
        }
    };
    let (engine, t) = time_once(|| Engine::new(artifacts));
    let engine = match engine {
        Ok(e) => e,
        Err(e) => {
            println!("SKIP: {e:#}");
            return;
        }
    };
    println!("engine init (4 artifact compiles) in {t:.2}s on {}", engine.platform());

    let lib = library();
    let lut = lut_f32(&lib[EXACT_ID]);
    let imgs = &engine.native().testset.images[..64 * 256];

    let res = bench("matmul_approx execute (64x64x64 + LUT)", 5, 100, || {
        let a = vec![0.5f32; 64 * 64];
        let b = vec![0.25f32; 64 * 64];
        engine
            .executable("matmul_approx")
            .unwrap()
            .run_f32(&[(&a, &[64, 64]), (&b, &[64, 64]), (&lut, &[128, 128])])
            .unwrap()
    });
    println!("{}", res.line());

    let res = bench("cnn_exact execute (batch 64)", 3, 50, || {
        engine.cnn_logits_exact(imgs).unwrap()
    });
    println!("{}", res.line());

    let res = bench("cnn_approx execute (batch 64 + LUT)", 3, 50, || {
        engine.cnn_logits_approx(imgs, &lut).unwrap()
    });
    println!("{}", res.line());

    let res = bench("accuracy_pjrt full test set (512 imgs)", 1, 10, || {
        engine.accuracy_pjrt(Some(&lut)).unwrap()
    });
    println!("{}", res.line());

    // Native (non-PJRT) path for comparison — same datapath in pure rust.
    let dp = carbon3d::accuracy::native::ApproxDatapath::new(&lib[EXACT_ID]);
    let res = bench("accuracy_native full test set (512 imgs)", 1, 10, || {
        engine.native().accuracy(&dp)
    });
    println!("{}", res.line());
}
