//! Accelerator configuration — the GA chromosome (paper Eq. 6) plus fixed
//! platform parameters.

use crate::area::die::{die_areas, DieAreas, Integration};
use crate::area::TechNode;
use crate::approx::Multiplier;

/// DRAM bandwidth shared by all configurations (LPDDR5X-class edge device).
pub const DRAM_GBPS: f64 = 51.2;

/// Fixed per-layer launch overhead, cycles (descriptor setup, drain).
pub const LAYER_OVERHEAD_CYCLES: u64 = 2000;

/// An accelerator configuration: C = {Px, Py, B_local, B_global} (Eq. 6)
/// plus the selected mantissa multiplier and platform choices.
#[derive(Debug, Clone, PartialEq)]
pub struct AccelConfig {
    /// PE array dimensions.
    pub px: usize,
    pub py: usize,
    /// Local (per-PE) buffer, bytes.
    pub rf_bytes: usize,
    /// Global SRAM buffer, bytes.
    pub sram_bytes: usize,
    /// Technology node.
    pub node: TechNode,
    /// 2D baseline or the paper's 3D memory-on-logic.
    pub integration: Integration,
    /// Index into `approx::library()`.
    pub mult_id: usize,
}

impl AccelConfig {
    pub fn n_pes(&self) -> usize {
        self.px * self.py
    }

    /// Clock frequency in Hz (set by the node; paper §IV).
    pub fn freq_hz(&self) -> f64 {
        self.node.freq_mhz() * 1e6
    }

    /// Aggregate SRAM->PE bandwidth in words/cycle.
    ///
    /// 2D: a mesh NoC delivers one word per row/column port per cycle —
    /// scales with the array perimeter. 3D: hybrid-bond vertical links give
    /// every PE-column group its own port — scales with array *area*
    /// (the memory-on-logic advantage, paper §III-A).
    pub fn sram_bw_words_per_cycle(&self) -> f64 {
        match self.integration {
            Integration::TwoD => (self.px + self.py) as f64 / 2.0,
            Integration::ThreeD => (self.n_pes() as f64 / 4.0).max((self.px + self.py) as f64),
        }
    }

    /// DRAM bandwidth in bytes/cycle at this node's clock.
    pub fn dram_bytes_per_cycle(&self) -> f64 {
        DRAM_GBPS * 1e9 / self.freq_hz()
    }

    /// Die areas for this configuration.
    pub fn die_areas(&self, mult: &Multiplier) -> DieAreas {
        assert_eq!(mult.id, self.mult_id, "multiplier/config mismatch");
        die_areas(
            self.px,
            self.py,
            self.rf_bytes,
            self.sram_bytes,
            mult,
            self.node,
            self.integration,
        )
    }

    /// Human-readable one-liner.
    pub fn describe(&self, mult: &Multiplier) -> String {
        format!(
            "{}x{} PEs, RF {}B, SRAM {}KB, {} {}, mult {}",
            self.px,
            self.py,
            self.rf_bytes,
            self.sram_bytes / 1024,
            self.node.name(),
            match self.integration {
                Integration::TwoD => "2D",
                Integration::ThreeD => "3D",
            },
            mult.name()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::{library, EXACT_ID};

    fn cfg(integration: Integration) -> AccelConfig {
        AccelConfig {
            px: 16,
            py: 16,
            rf_bytes: 512,
            sram_bytes: 1 << 20,
            node: TechNode::N14,
            integration,
            mult_id: EXACT_ID,
        }
    }

    #[test]
    fn three_d_bandwidth_exceeds_2d() {
        let b2 = cfg(Integration::TwoD).sram_bw_words_per_cycle();
        let b3 = cfg(Integration::ThreeD).sram_bw_words_per_cycle();
        assert!(b3 > 2.0 * b2, "3D {b3} vs 2D {b2}");
    }

    #[test]
    fn three_d_bw_scales_with_area_2d_with_perimeter() {
        let small3 = cfg(Integration::ThreeD);
        let mut big3 = small3.clone();
        big3.px = 32;
        big3.py = 32;
        let ratio3 = big3.sram_bw_words_per_cycle() / small3.sram_bw_words_per_cycle();
        assert!((3.5..4.5).contains(&ratio3), "3D ratio {ratio3}");

        let small2 = cfg(Integration::TwoD);
        let mut big2 = small2.clone();
        big2.px = 32;
        big2.py = 32;
        let ratio2 = big2.sram_bw_words_per_cycle() / small2.sram_bw_words_per_cycle();
        assert!((1.8..2.2).contains(&ratio2), "2D ratio {ratio2}");
    }

    #[test]
    fn dram_bytes_per_cycle_scales_inverse_with_freq() {
        let c45 = AccelConfig { node: TechNode::N45, ..cfg(Integration::ThreeD) };
        let c7 = AccelConfig { node: TechNode::N7, ..cfg(Integration::ThreeD) };
        assert!(c45.dram_bytes_per_cycle() > c7.dram_bytes_per_cycle());
    }

    #[test]
    #[should_panic]
    fn mismatched_multiplier_panics() {
        let lib = library();
        let c = AccelConfig { mult_id: 3, ..cfg(Integration::ThreeD) };
        let _ = c.die_areas(&lib[EXACT_ID]);
    }
}
