//! Exhaustive arithmetic-error characterization of multiplier designs.
//!
//! Same methodology as the EvoApprox datasheets: every metric is computed by
//! enumerating the full input space (256x256 = 65536 pairs — microseconds),
//! plus the bf16-significand subdomain [128,255]^2 that the MAC actually
//! exercises (the paper's multipliers see only normalized significands).

use super::models::ApproxKind;

/// Error metrics of an approximate multiplier vs the exact product.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ErrorMetrics {
    // ---- full domain [0,255]^2 ----
    /// Mean error distance E[|approx - exact|].
    pub full_med: f64,
    /// Mean relative error distance E[|approx - exact| / max(1, exact)].
    pub full_mred: f64,
    /// Worst-case absolute error.
    pub full_wce: u32,
    /// Probability of a non-zero error.
    pub full_err_prob: f64,
    // ---- significand domain [128,255]^2 (what the bf16 MAC sees) ----
    pub sig_med: f64,
    pub sig_mred: f64,
    pub sig_wce: u32,
    pub sig_err_prob: f64,
    /// Signed mean error on the significand domain (bias; <0 = underestimates).
    pub sig_bias: f64,
}

impl ErrorMetrics {
    /// Exhaustively characterize a design.
    pub fn exhaustive(kind: &ApproxKind) -> Self {
        let mut full = Acc::default();
        let mut sig = Acc::default();
        for a in 0..=255u32 {
            for b in 0..=255u32 {
                let exact = a * b;
                let approx = kind.mul(a as u8, b as u8);
                full.push(exact, approx);
                if a >= 128 && b >= 128 {
                    sig.push(exact, approx);
                }
            }
        }
        Self {
            full_med: full.med(),
            full_mred: full.mred(),
            full_wce: full.wce,
            full_err_prob: full.err_prob(),
            sig_med: sig.med(),
            sig_mred: sig.mred(),
            sig_wce: sig.wce,
            sig_err_prob: sig.err_prob(),
            sig_bias: sig.bias(),
        }
    }
}

#[derive(Default)]
struct Acc {
    n: u64,
    sum_ed: f64,
    sum_red: f64,
    sum_signed: f64,
    wce: u32,
    n_err: u64,
}

impl Acc {
    fn push(&mut self, exact: u32, approx: u32) {
        self.n += 1;
        let signed = approx as f64 - exact as f64;
        let ed = signed.abs();
        self.sum_ed += ed;
        self.sum_signed += signed;
        self.sum_red += ed / (exact.max(1) as f64);
        let ed_u = (approx as i64 - exact as i64).unsigned_abs() as u32;
        self.wce = self.wce.max(ed_u);
        if ed_u != 0 {
            self.n_err += 1;
        }
    }
    fn med(&self) -> f64 {
        self.sum_ed / self.n as f64
    }
    fn mred(&self) -> f64 {
        self.sum_red / self.n as f64
    }
    fn bias(&self) -> f64 {
        self.sum_signed / self.n as f64
    }
    fn err_prob(&self) -> f64 {
        self.n_err as f64 / self.n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_has_zero_error() {
        let e = ErrorMetrics::exhaustive(&ApproxKind::Exact);
        assert_eq!(e.full_med, 0.0);
        assert_eq!(e.full_wce, 0);
        assert_eq!(e.sig_err_prob, 0.0);
        assert_eq!(e.sig_bias, 0.0);
    }

    #[test]
    fn truncation_error_grows_with_k() {
        let mut prev = -1.0;
        for k in 1..=5 {
            let e = ErrorMetrics::exhaustive(&ApproxKind::Truncate(k));
            assert!(e.sig_mred > prev, "TRUNC{k} mred {} !> {prev}", e.sig_mred);
            prev = e.sig_mred;
        }
    }

    #[test]
    fn perforation_error_grows_with_p() {
        let mut prev = -1.0;
        for p in 1..=7 {
            let e = ErrorMetrics::exhaustive(&ApproxKind::Perforate(p));
            assert!(e.sig_mred > prev);
            prev = e.sig_mred;
        }
    }

    #[test]
    fn underestimating_designs_have_negative_bias() {
        for kind in [
            ApproxKind::Perforate(4),
            ApproxKind::Truncate(3),
            ApproxKind::BrokenArray(6),
            ApproxKind::Mitchell,
        ] {
            let e = ErrorMetrics::exhaustive(&kind);
            assert!(e.sig_bias < 0.0, "{kind:?} bias {}", e.sig_bias);
        }
    }

    #[test]
    fn sig_domain_wce_le_full_domain_wce() {
        for kind in [
            ApproxKind::Perforate(5),
            ApproxKind::Truncate(4),
            ApproxKind::Drum(4),
            ApproxKind::OrCompress(5),
        ] {
            let e = ErrorMetrics::exhaustive(&kind);
            assert!(e.sig_wce <= e.full_wce, "{kind:?}");
        }
    }

    #[test]
    fn mitchell_sig_mred_near_known_value() {
        // Mitchell's mean relative error is ~3.8% over uniform inputs.
        let e = ErrorMetrics::exhaustive(&ApproxKind::Mitchell);
        assert!(
            (0.01..0.08).contains(&e.sig_mred),
            "mitchell sig_mred {}",
            e.sig_mred
        );
    }

    #[test]
    fn drum_error_shrinks_with_k() {
        let e3 = ErrorMetrics::exhaustive(&ApproxKind::Drum(3));
        let e6 = ErrorMetrics::exhaustive(&ApproxKind::Drum(6));
        assert!(e6.sig_mred < e3.sig_mred);
    }
}
