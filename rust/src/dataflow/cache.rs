//! Geometry-keyed mapping cache — the central memo of the evaluation hot
//! path (DESIGN.md §7.6).
//!
//! `map_network`, task delay, and the memory-side area inputs depend only
//! on the *geometry* of a configuration — `(px, py, rf_bytes, sram_bytes,
//! node, integration)` plus the workload — and never on the multiplier
//! gene (`approx_multiplier_lowers_carbon_same_delay` pins `delay_s`
//! equality across multipliers). The GA, its islands, and every campaign
//! job therefore re-ran the same mapper search once per multiplier for
//! each geometry they visited. [`MappingCache`] memoizes the mapping by
//! workload name + [`GeometryDims`], turning those ~|library|-fold
//! redundant searches into one; the cached [`NetworkMapping`] is the very
//! value a direct `map_network` call computes (`Arc`-shared, never
//! mutated), so evaluations are bit-identical with and without the cache.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, RwLock};

use super::arch::AccelConfig;
use super::mapper::{map_network, NetworkMapping};
use super::workloads::Workload;
use crate::area::die::Integration;
use crate::area::TechNode;

/// Everything the mapper's output depends on, minus the workload (which
/// keys the outer map by name so lookups borrow instead of allocating).
/// Deliberately excludes `mult_id`: the multiplier changes area, energy,
/// and accuracy — never the tiling, traffic, or delay.
pub type GeometryDims = (usize, usize, usize, usize, TechNode, Integration);

/// The geometry half of a configuration.
pub fn geometry_dims(cfg: &AccelConfig) -> GeometryDims {
    (cfg.px, cfg.py, cfg.rf_bytes, cfg.sram_bytes, cfg.node, cfg.integration)
}

/// Shared hit/miss counters (relaxed atomics: observability, not
/// synchronization). Also used for the fitness contexts' chromosome-memo
/// counters, so one type serves every cache the reports surface. The
/// persistence counters (`persisted_hits`, `preloaded`) stay zero for
/// caches that never touch a sidecar.
#[derive(Debug, Default)]
pub struct CacheStats {
    hits: AtomicUsize,
    misses: AtomicUsize,
    persisted_hits: AtomicUsize,
    preloaded: AtomicUsize,
}

impl CacheStats {
    /// Count a lookup served from the cache.
    pub fn hit(&self) {
        self.hits.fetch_add(1, Ordering::Relaxed);
    }

    /// Count a lookup that had to compute its value.
    pub fn miss(&self) {
        self.misses.fetch_add(1, Ordering::Relaxed);
    }

    /// Count a hit served by an entry preloaded from a persisted sidecar
    /// (counted *in addition to* [`CacheStats::hit`]).
    pub fn persisted_hit(&self) {
        self.persisted_hits.fetch_add(1, Ordering::Relaxed);
    }

    /// Count `n` entries preloaded from a persisted sidecar.
    pub fn preloaded(&self, n: usize) {
        self.preloaded.fetch_add(n, Ordering::Relaxed);
    }

    /// A point-in-time snapshot of every counter.
    pub fn counts(&self) -> CacheCounts {
        CacheCounts {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            persisted_hits: self.persisted_hits.load(Ordering::Relaxed),
            preloaded: self.preloaded.load(Ordering::Relaxed),
        }
    }
}

/// A point-in-time snapshot of [`CacheStats`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheCounts {
    /// Lookups served from the cache.
    pub hits: usize,
    /// Lookups that recomputed their value.
    pub misses: usize,
    /// The subset of `hits` served by entries a persisted sidecar
    /// preloaded — the mapper searches this process skipped outright
    /// because an earlier process already paid for them.
    pub persisted_hits: usize,
    /// Entries injected from persisted sidecars before the run.
    pub preloaded: usize,
}

impl CacheCounts {
    /// Total lookups (hits + misses).
    pub fn lookups(&self) -> usize {
        self.hits + self.misses
    }

    /// Fraction of lookups served from the cache; 0.0 before any lookup.
    pub fn hit_rate(&self) -> f64 {
        if self.lookups() == 0 {
            0.0
        } else {
            self.hits as f64 / self.lookups() as f64
        }
    }
}

/// Thread-safe memo of `map_network` results keyed by geometry. Cheap to
/// share (`Arc<MappingCache>` inside `ga::EvalShares`) across the GA
/// population, island threads, and every job a campaign process runs.
/// Two-level: workload name (probed borrowed — no allocation per lookup)
/// over the all-`Copy` [`GeometryDims`].
pub struct MappingCache {
    map: RwLock<HashMap<String, HashMap<GeometryDims, CacheEntry>>>,
    stats: CacheStats,
    enabled: bool,
}

/// One cached mapping plus its provenance: entries preloaded from a
/// persisted sidecar are tagged so hits on them can be attributed to the
/// persistence layer (`persisted_hits`).
struct CacheEntry {
    mapping: Arc<NetworkMapping>,
    preloaded: bool,
}

impl Default for MappingCache {
    fn default() -> Self {
        Self::new()
    }
}

impl MappingCache {
    pub fn new() -> Self {
        Self { map: RwLock::new(HashMap::new()), stats: CacheStats::default(), enabled: true }
    }

    /// A cache that never stores: every lookup recomputes, reproducing the
    /// pre-cache evaluation path. Exists so `benches/native.rs` can measure
    /// the cache's wall-clock win on a like-for-like grid.
    pub fn disabled() -> Self {
        Self { enabled: false, ..Self::new() }
    }

    /// The mapping for a configuration's geometry, computed at most once
    /// per key. Two threads racing on a fresh key may both compute (both
    /// counted as misses; the first insert wins) — harmless, because the
    /// value is a pure function of the key.
    pub fn mapping(&self, w: &Workload, cfg: &AccelConfig) -> Arc<NetworkMapping> {
        if !self.enabled {
            self.stats.miss();
            crate::obs::metrics().incr("mapper_cache_misses", 1);
            let _span = crate::obs::span("mapper.search");
            return Arc::new(map_network(w, cfg));
        }
        let dims = geometry_dims(cfg);
        if let Some(hit) = self
            .map
            .read()
            .expect("mapping cache poisoned")
            .get(&w.name)
            .and_then(|per| per.get(&dims))
        {
            self.stats.hit();
            crate::obs::metrics().incr("mapper_cache_hits", 1);
            if hit.preloaded {
                self.stats.persisted_hit();
                crate::obs::metrics().incr("mapper_cache_persisted_hits", 1);
            }
            return hit.mapping.clone();
        }
        self.stats.miss();
        crate::obs::metrics().incr("mapper_cache_misses", 1);
        let fresh = {
            let _span = crate::obs::span("mapper.search");
            Arc::new(map_network(w, cfg))
        };
        let mut map = self.map.write().expect("mapping cache poisoned");
        map.entry(w.name.clone())
            .or_default()
            .entry(dims)
            .or_insert(CacheEntry { mapping: fresh, preloaded: false })
            .mapping
            .clone()
    }

    /// Inject entries recovered from a persisted sidecar, insert-if-absent
    /// (an entry computed this process wins over a preloaded duplicate, so
    /// preloading commutes with computation). Returns how many entries
    /// were actually added; a [`MappingCache::disabled`] cache ignores the
    /// injection entirely. Safe because a mapping is a pure function of
    /// its (workload, geometry) key: a preloaded value is byte-for-byte
    /// the value this process would have computed.
    pub fn preload<I>(&self, entries: I) -> usize
    where
        I: IntoIterator<Item = (String, GeometryDims, NetworkMapping)>,
    {
        if !self.enabled {
            return 0;
        }
        let mut added = 0usize;
        let mut map = self.map.write().expect("mapping cache poisoned");
        for (workload, dims, mapping) in entries {
            if let std::collections::hash_map::Entry::Vacant(slot) =
                map.entry(workload).or_default().entry(dims)
            {
                slot.insert(CacheEntry { mapping: Arc::new(mapping), preloaded: true });
                added += 1;
            }
        }
        drop(map);
        if added > 0 {
            self.stats.preloaded(added);
            crate::obs::metrics().incr("mapper_cache_preloaded", added as u64);
        }
        added
    }

    /// Snapshot every cached entry for persistence: (workload, geometry,
    /// mapping) triples in unspecified order — the sidecar serializer
    /// sorts by key, so the snapshot order never reaches disk.
    pub fn export(&self) -> Vec<(String, GeometryDims, Arc<NetworkMapping>)> {
        let map = self.map.read().expect("mapping cache poisoned");
        let mut out = Vec::with_capacity(map.values().map(|per| per.len()).sum());
        for (workload, per) in map.iter() {
            for (&dims, entry) in per.iter() {
                out.push((workload.clone(), dims, entry.mapping.clone()));
            }
        }
        out
    }

    /// Hit/miss counters since construction.
    pub fn counts(&self) -> CacheCounts {
        self.stats.counts()
    }

    /// Distinct (workload, geometry) entries cached so far.
    pub fn len(&self) -> usize {
        self.map
            .read()
            .expect("mapping cache poisoned")
            .values()
            .map(|per| per.len())
            .sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::EXACT_ID;
    use crate::dataflow::workloads::workload;

    fn cfg(mult_id: usize) -> AccelConfig {
        AccelConfig {
            px: 16,
            py: 16,
            rf_bytes: 512,
            sram_bytes: 1 << 20,
            node: TechNode::N14,
            integration: Integration::ThreeD,
            mult_id,
        }
    }

    #[test]
    fn key_ignores_multiplier_gene() {
        assert_eq!(geometry_dims(&cfg(EXACT_ID)), geometry_dims(&cfg(7)));
    }

    #[test]
    fn same_geometry_different_multiplier_is_one_mapper_run() {
        let cache = MappingCache::new();
        let w = workload("resnet50").unwrap();
        let a = cache.mapping(&w, &cfg(EXACT_ID));
        let b = cache.mapping(&w, &cfg(9));
        assert!(Arc::ptr_eq(&a, &b), "distinct mappings for one geometry");
        let c = cache.counts();
        assert_eq!((c.hits, c.misses), (1, 1));
        assert_eq!(cache.len(), 1);
        assert!((c.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn cached_mapping_equals_direct_call() {
        let cache = MappingCache::new();
        let w = workload("vgg16").unwrap();
        let c = cfg(3);
        let cached = cache.mapping(&w, &c);
        let direct = map_network(&w, &c);
        assert_eq!(cached.total_cycles, direct.total_cycles);
        assert_eq!(cached.layers, direct.layers);
        assert_eq!(cached.delay_s(&c).to_bits(), direct.delay_s(&c).to_bits());
    }

    #[test]
    fn different_geometry_or_workload_is_a_fresh_entry() {
        let cache = MappingCache::new();
        let w1 = workload("vgg16").unwrap();
        let w2 = workload("resnet50").unwrap();
        let mut big = cfg(EXACT_ID);
        big.px = 32;
        cache.mapping(&w1, &cfg(EXACT_ID));
        cache.mapping(&w1, &big);
        cache.mapping(&w2, &cfg(EXACT_ID));
        assert_eq!(cache.len(), 3);
        assert_eq!(cache.counts().hits, 0);
    }

    #[test]
    fn disabled_cache_always_recomputes_but_stays_correct() {
        let cache = MappingCache::disabled();
        let w = workload("tinycnn").unwrap();
        let a = cache.mapping(&w, &cfg(EXACT_ID));
        let b = cache.mapping(&w, &cfg(EXACT_ID));
        assert!(!Arc::ptr_eq(&a, &b));
        assert_eq!(a.total_cycles, b.total_cycles);
        assert_eq!(
            cache.counts(),
            CacheCounts { hits: 0, misses: 2, ..Default::default() }
        );
        assert!(cache.is_empty());
        // Preloading a disabled cache is a no-op, not an error.
        let w2 = workload("tinycnn").unwrap();
        let direct = map_network(&w2, &cfg(EXACT_ID));
        assert_eq!(cache.preload([(w2.name.clone(), geometry_dims(&cfg(0)), direct)]), 0);
        assert!(cache.is_empty());
    }

    #[test]
    fn preloaded_entries_hit_and_are_attributed() {
        let cache = MappingCache::new();
        let w = workload("tinycnn").unwrap();
        let direct = map_network(&w, &cfg(EXACT_ID));
        let added = cache.preload([(w.name.clone(), geometry_dims(&cfg(0)), direct.clone())]);
        assert_eq!(added, 1);
        assert_eq!(cache.len(), 1);
        // A lookup on the preloaded geometry is a hit AND a persisted hit,
        // and returns exactly the mapping a direct call computes.
        let got = cache.mapping(&w, &cfg(5));
        assert_eq!(got.total_cycles, direct.total_cycles);
        assert_eq!(got.layers, direct.layers);
        let c = cache.counts();
        assert_eq!(
            c,
            CacheCounts { hits: 1, misses: 0, persisted_hits: 1, preloaded: 1 }
        );
        // A fresh geometry misses and its later hits are NOT persisted.
        let mut big = cfg(EXACT_ID);
        big.px = 32;
        cache.mapping(&w, &big);
        cache.mapping(&w, &big);
        let c = cache.counts();
        assert_eq!(
            c,
            CacheCounts { hits: 2, misses: 1, persisted_hits: 1, preloaded: 1 }
        );
        // Preloading a key the process already computed is ignored
        // (computed entry wins), so duplicate injection adds nothing.
        let dup = map_network(&w, &big);
        assert_eq!(cache.preload([(w.name.clone(), geometry_dims(&big), dup)]), 0);
        assert_eq!(cache.counts().preloaded, 1);
    }

    #[test]
    fn preload_merge_is_order_independent() {
        // Property: folding sidecar entry sets into a cache in any order
        // yields the same cached mappings — values are pure functions of
        // their keys, and insert-if-absent makes the union idempotent.
        let w = workload("tinycnn").unwrap();
        let mut geoms = Vec::new();
        for px in [4usize, 8, 16] {
            let mut c = cfg(EXACT_ID);
            c.px = px;
            geoms.push(c);
        }
        let entries: Vec<(String, GeometryDims, NetworkMapping)> = geoms
            .iter()
            .map(|c| (w.name.clone(), geometry_dims(c), map_network(&w, c)))
            .collect();
        // Three overlapping "shards" of the entry set.
        let shards: [Vec<usize>; 3] = [vec![0, 1], vec![1, 2], vec![2, 0]];
        let fold = |order: &[usize]| -> Vec<u64> {
            let cache = MappingCache::new();
            for &si in order {
                let batch: Vec<_> = shards[si].iter().map(|&ei| entries[ei].clone()).collect();
                cache.preload(batch);
            }
            let mut snap: Vec<(String, String, u64)> = cache
                .export()
                .into_iter()
                .map(|(wname, dims, m)| (wname, format!("{dims:?}"), m.total_cycles))
                .collect();
            snap.sort();
            assert_eq!(snap.len(), 3);
            snap.into_iter().map(|(_, _, cyc)| cyc).collect()
        };
        let want = fold(&[0, 1, 2]);
        for order in [[0, 2, 1], [1, 0, 2], [1, 2, 0], [2, 0, 1], [2, 1, 0]] {
            assert_eq!(fold(&order), want, "order {order:?}");
        }
    }

    #[test]
    fn shared_across_threads() {
        let cache = Arc::new(MappingCache::new());
        let w = workload("tinycnn").unwrap();
        std::thread::scope(|s| {
            for _ in 0..4 {
                let cache = cache.clone();
                let w = &w;
                s.spawn(move || {
                    for mult_id in 0..8 {
                        let m = cache.mapping(w, &cfg(mult_id));
                        assert!(m.total_cycles > 0);
                    }
                });
            }
        });
        assert_eq!(cache.len(), 1);
        let c = cache.counts();
        assert_eq!(c.lookups(), 32);
        // At least the strictly-later lookups hit; racing first lookups may
        // each count a miss, so only the sum is exact.
        assert!(c.hits >= 32 - 4, "{c:?}");
    }
}
