//! Bench eval: the evaluation hot path (DESIGN.md §7.6) — the table-driven
//! native accuracy datapath vs the retained scalar reference, the lane
//! (SIMD-shaped) matmul kernel vs the always-compiled scalar kernel, the
//! batched evaluator entry point vs per-image calls, and the geometry-keyed
//! mapping cache vs an uncached GA loop over the campaign smoke grid.
//! Speedups are ratios measured on one machine, so they are comparable
//! across runners; CI gates on them (including a `CARBON3D_SIMD=0` leg
//! proving the scalar fallback stays healthy).
//!
//! Modes:
//!   (default)        more timed iterations, grid repetitions, and a
//!                    larger synthetic test set (same shapes and grid)
//!   --smoke          reduced iteration counts — CI-sized
//!   --json FILE      write the measurements as a JSON document
//!                    (CI uploads this as the `BENCH_eval.json` artifact)
//!   --check FILE     compare against a committed baseline and exit
//!                    non-zero on a >20% speedup regression

use std::sync::Arc;

use carbon3d::accuracy::model::{feasible_multipliers, DEFAULT_K};
use carbon3d::accuracy::native::{
    ApproxDatapath, MatmulKernel, NativeEvaluator, TestSet, Weights, IMG,
};
use carbon3d::approx::{library, EXACT_ID};
use carbon3d::area::node::ALL_NODES;
use carbon3d::campaign::CampaignSpec;
use carbon3d::coordinator::ga_appx_with_feasible_objective_shared;
use carbon3d::dataflow::cache::MappingCache;
use carbon3d::dataflow::workloads::workload;
use carbon3d::ga::{EvalShares, GaParams, Objective};
use carbon3d::obs::{Merge, MetricsSnapshot};
use carbon3d::util::json::{obj, Json};
use carbon3d::obs::bench::{bench, time_once};
use carbon3d::util::Rng;

/// The matmul shapes one batch-64 accuracy pass issues (tiny CNN: conv1,
/// conv2, fc) — the native evaluator's entire hot path.
const ACCURACY_SHAPES: [(usize, usize, usize); 3] =
    [(64 * 16 * 16, 9, 8), (64 * 8 * 8, 72, 16), (64, 256, 5)];

fn rand_vec(rng: &mut Rng, len: usize, scale: f64) -> Vec<f32> {
    (0..len).map(|_| (rng.uniform(-1.0, 1.0) * scale) as f32).collect()
}

/// Synthetic evaluator: the accuracy pass does not depend on trained
/// weights for its *timing*, so the bench runs artifact-free.
fn synthetic_evaluator(n: usize, rng: &mut Rng) -> NativeEvaluator {
    NativeEvaluator {
        weights: Weights {
            conv1_w: rand_vec(rng, 3 * 3 * 8, 0.5),
            conv1_b: rand_vec(rng, 8, 0.1),
            conv2_w: rand_vec(rng, 3 * 3 * 8 * 16, 0.25),
            conv2_b: rand_vec(rng, 16, 0.1),
            fc_w: rand_vec(rng, 256 * 5, 0.2),
            fc_b: rand_vec(rng, 5, 0.1),
        },
        testset: TestSet {
            images: rand_vec(rng, n * IMG * IMG, 1.0),
            labels: (0..n).map(|i| (i % 5) as u8).collect(),
            n,
        },
        exact_accuracy: 0.0,
    }
}

/// The campaign bench's smoke grid (2 models x 3 nodes x 1 delta), run as
/// a plain GA loop so the mapping cache is the only variable.
fn smoke_spec() -> CampaignSpec {
    let mut s = CampaignSpec::new(
        vec!["vgg16".to_string(), "resnet50".to_string()],
        ALL_NODES.to_vec(),
        vec![3.0],
    );
    s.ga = GaParams { population: 8, generations: 4, patience: 2, elites: 1, ..Default::default() };
    s
}

fn run_grid(spec: &CampaignSpec, shares: &EvalShares) {
    let lib = library();
    for job in spec.jobs() {
        let w = workload(&job.model).unwrap();
        let feasible = feasible_multipliers(&lib, &w, job.delta_pct, DEFAULT_K);
        std::hint::black_box(ga_appx_with_feasible_objective_shared(
            &w,
            job.node,
            job.integration,
            &lib,
            feasible,
            job.fps_floor,
            Objective::embodied(),
            GaParams { seed: job.seed, ..spec.ga },
            shares,
        ));
    }
}

/// Gate the measured speedups against a committed baseline: fail when a
/// current ratio drops below 80% of its baseline (>20% regression).
fn check_against(doc: &Json, path: &str) -> bool {
    let text = std::fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("read baseline {path}: {e}"));
    let base = Json::parse(&text).unwrap_or_else(|e| panic!("parse baseline {path}: {e}"));
    let speedup = |v: &Json, section: &str| -> f64 {
        v.get(section)
            .and_then(|s| s.get("speedup"))
            .and_then(|s| s.as_f64())
            .unwrap_or_else(|e| panic!("{section}.speedup missing: {e}"))
    };
    let mut ok = true;
    for section in ["native", "campaign"] {
        let b = speedup(&base, section);
        let c = speedup(doc, section);
        let floor = b * 0.8;
        println!("{section} speedup: current {c:.2}x vs baseline {b:.2}x (floor {floor:.2}x)");
        if c < floor {
            println!("REGRESSION: {section} speedup {c:.2}x below floor {floor:.2}x");
            ok = false;
        }
    }
    ok
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let smoke = args.iter().any(|a| a == "--smoke") || std::env::var("BENCH_SMOKE").is_ok();
    let flag_val = |name: &str| -> Option<String> {
        args.iter().position(|a| a == name).and_then(|i| args.get(i + 1).cloned())
    };
    let json_out = flag_val("--json");
    let check = flag_val("--check");
    let iters = if smoke { 3 } else { 10 };

    println!("== native eval benches{} ==", if smoke { " (smoke)" } else { "" });
    let metrics_before = MetricsSnapshot::collect();
    let lib = library();
    let dp = ApproxDatapath::new(&lib[EXACT_ID]);
    let mut rng = Rng::new(0xBE7C);

    // --- table-driven matmul vs the scalar reference, on the accuracy
    // pass's own shapes. One correctness pass first: the bench must never
    // report a speedup for a wrong result. The *gated* ratio is measured
    // single-threaded — the pure table win, independent of the runner's
    // core count — with the row-threaded number recorded beside it.
    let mut shape_docs: Vec<Json> = Vec::new();
    let (mut ref_total, mut table_total, mut threaded_total) = (0f64, 0f64, 0f64);
    let (mut lanes_total, mut scalar_total) = (0f64, 0f64);
    for &(m, k, n) in &ACCURACY_SHAPES {
        let a = rand_vec(&mut rng, m * k, 2.0);
        let b = rand_vec(&mut rng, k * n, 2.0);
        let want = dp.matmul_reference(&a, &b, m, k, n);
        for kernel in [MatmulKernel::Auto, MatmulKernel::Lanes, MatmulKernel::Scalar] {
            let got = dp.matmul_with_kernel(&a, &b, m, k, n, 1, kernel);
            assert_eq!(
                got.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                want.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "{kernel:?} matmul diverged on {m}x{k}x{n}"
            );
        }
        let r_ref = bench(
            &format!("matmul_reference {m}x{k}x{n}"),
            1,
            iters,
            || dp.matmul_reference(&a, &b, m, k, n),
        );
        let r_table = bench(&format!("matmul (tables, 1 thread) {m}x{k}x{n}"), 1, iters, || {
            dp.matmul_with_threads(&a, &b, m, k, n, 1)
        });
        let r_lanes =
            bench(&format!("matmul (lane kernel, 1 thread) {m}x{k}x{n}"), 1, iters, || {
                dp.matmul_with_kernel(&a, &b, m, k, n, 1, MatmulKernel::Lanes)
            });
        let r_scalar =
            bench(&format!("matmul (scalar kernel, 1 thread) {m}x{k}x{n}"), 1, iters, || {
                dp.matmul_with_kernel(&a, &b, m, k, n, 1, MatmulKernel::Scalar)
            });
        let r_threaded =
            bench(&format!("matmul (tables+threads) {m}x{k}x{n}"), 1, iters, || {
                dp.matmul(&a, &b, m, k, n)
            });
        println!("{}", r_ref.line());
        println!("{}", r_table.line());
        println!("{}", r_lanes.line());
        println!("{}", r_scalar.line());
        println!("{}", r_threaded.line());
        ref_total += r_ref.summary.mean;
        table_total += r_table.summary.mean;
        lanes_total += r_lanes.summary.mean;
        scalar_total += r_scalar.summary.mean;
        threaded_total += r_threaded.summary.mean;
        shape_docs.push(obj([
            ("m", Json::from(m)),
            ("k", Json::from(k)),
            ("n", Json::from(n)),
            ("reference_s", Json::from(r_ref.summary.mean)),
            ("table_1t_s", Json::from(r_table.summary.mean)),
            ("lanes_1t_s", Json::from(r_lanes.summary.mean)),
            ("scalar_1t_s", Json::from(r_scalar.summary.mean)),
            ("threaded_s", Json::from(r_threaded.summary.mean)),
        ]));
    }
    let native_speedup = ref_total / table_total;
    let threaded_speedup = ref_total / threaded_total;
    let simd_speedup = scalar_total / lanes_total;
    println!(
        "native accuracy datapath: reference {:.1}ms vs tables {:.1}ms = {:.2}x \
         (with row threads: {:.1}ms = {:.2}x)",
        ref_total * 1e3,
        table_total * 1e3,
        native_speedup,
        threaded_total * 1e3,
        threaded_speedup
    );
    println!(
        "lane kernel vs scalar kernel (1 thread): {:.1}ms vs {:.1}ms = {:.2}x",
        lanes_total * 1e3,
        scalar_total * 1e3,
        simd_speedup
    );

    // --- full accuracy pass over a synthetic test set (trajectory metric):
    // the batched entry point (one buffer pool, batch-64 forward passes)
    // vs pushing the same set through image-at-a-time batches.
    let ne = synthetic_evaluator(if smoke { 128 } else { 512 }, &mut rng);
    let acc_batched = ne.accuracy(&dp);
    let acc_per_image = ne.accuracy_batched(&dp, 1);
    assert_eq!(
        acc_batched.to_bits(),
        acc_per_image.to_bits(),
        "batched and per-image accuracy diverged"
    );
    let r_acc = bench("accuracy pass (batch 64)", 1, iters, || ne.accuracy(&dp));
    let r_acc_1 =
        bench("accuracy pass (per image)", 1, iters, || ne.accuracy_batched(&dp, 1));
    println!("{}", r_acc.line());
    println!("{}", r_acc_1.line());
    let batch_speedup = r_acc_1.summary.mean / r_acc.summary.mean;
    println!(
        "batched evaluator: per-image {:.1}ms vs batch-64 {:.1}ms = {:.2}x",
        r_acc_1.summary.mean * 1e3,
        r_acc.summary.mean * 1e3,
        batch_speedup
    );

    // --- mapping cache on the campaign smoke grid: identical GA loop, the
    // shared geometry cache on vs off. Best-of-N per arm: a single sample
    // is at the mercy of a shared runner's scheduler, and this ratio gates
    // CI. (A fresh cache per repetition keeps the arms honest.)
    let spec = smoke_spec();
    let n_jobs = spec.n_jobs();
    let grid_reps = if smoke { 2 } else { 3 };
    let best_of = |mk_shares: &dyn Fn() -> EvalShares| -> (f64, EvalShares) {
        let mut best = f64::INFINITY;
        let mut last = mk_shares();
        for _ in 0..grid_reps {
            let shares = mk_shares();
            let (_, t) = time_once(|| run_grid(&spec, &shares));
            if t < best {
                best = t;
            }
            last = shares;
        }
        (best, last)
    };
    let (uncached_s, _) = best_of(&|| EvalShares {
        mapping: Arc::new(MappingCache::disabled()),
        ..Default::default()
    });
    let (cached_s, cached) = best_of(&EvalShares::default);
    let campaign_speedup = uncached_s / cached_s;
    let mc = cached.mapping.counts();
    println!(
        "campaign smoke grid ({n_jobs} jobs): uncached {uncached_s:.2}s vs cached {cached_s:.2}s \
         = {campaign_speedup:.2}x | mapping {}/{} hits ({:.0}%), {} unique geometries",
        mc.hits,
        mc.lookups(),
        mc.hit_rate() * 100.0,
        cached.mapping.len(),
    );

    let doc = obj([
        ("bench", Json::from("eval")),
        ("mode", Json::from(if smoke { "smoke" } else { "full" })),
        (
            "native",
            obj([
                ("shapes", Json::Arr(shape_docs)),
                ("reference_s", Json::from(ref_total)),
                ("table_1t_s", Json::from(table_total)),
                ("threaded_s", Json::from(threaded_total)),
                // The gated, core-count-independent ratio: tables vs the
                // scalar reference, both single-threaded.
                ("speedup", Json::from(native_speedup)),
                ("speedup_threaded", Json::from(threaded_speedup)),
                // Lane kernel vs the always-compiled scalar kernel, both
                // single-threaded (informational: LLVM's auto-vectorizer
                // decides how much of the lane shape becomes SIMD).
                ("speedup_simd", Json::from(simd_speedup)),
                ("accuracy_pass_s", Json::from(r_acc.summary.mean)),
                ("accuracy_per_image_s", Json::from(r_acc_1.summary.mean)),
                ("speedup_batched", Json::from(batch_speedup)),
            ]),
        ),
        (
            "campaign",
            obj([
                ("jobs", Json::from(n_jobs)),
                ("uncached_s", Json::from(uncached_s)),
                ("cached_s", Json::from(cached_s)),
                ("speedup", Json::from(campaign_speedup)),
                ("mapping_hits", Json::from(mc.hits)),
                ("mapping_misses", Json::from(mc.misses)),
                ("unique_geometries", Json::from(cached.mapping.len())),
            ]),
        ),
        // Process metrics over the whole bench (native.matmul histograms,
        // mapper counters) so the perf trajectory keeps the internals.
        ("metrics", MetricsSnapshot::collect().diff(&metrics_before).to_json()),
    ]);
    if let Some(out) = json_out {
        std::fs::write(&out, doc.pretty(2)).expect("write bench json");
        println!("wrote {out}");
    }
    if let Some(baseline) = check {
        if !check_against(&doc, &baseline) {
            std::process::exit(1);
        }
        println!("baseline check passed ({baseline})");
    }
}
