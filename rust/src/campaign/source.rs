//! **JobSource** — the deterministic front half of a campaign: flatten the
//! grid into pending jobs, compute each job's analytic optimistic bound
//! ([`JobBound`]), and fix the schedule order (ascending bound, ties by
//! grid id). Everything downstream — the [`crate::campaign::commit`]
//! pipeline and every [`crate::campaign::exec::Executor`] — consumes the
//! schedule read-only, so the slot sequence is a pure function of the spec
//! and the rows already in the store, identical across worker counts,
//! shard counts, and resume boundaries.

use std::collections::HashMap;
use std::sync::OnceLock;

use anyhow::{anyhow, ensure, Result};

use crate::accuracy::model::feasible_multipliers;
use crate::accuracy::AccuracyTable;
use crate::approx::{library, Multiplier, EXACT_ID};
use crate::area::mac::mac_power_uw;
use crate::carbon::embodied_carbon;
use crate::dataflow::arch::AccelConfig;
use crate::dataflow::workloads::{workload, Workload};
use crate::ga::{EvalShares, GaParams, Objective, SearchSpace};
use crate::runtime::{EvalClient, EvalService};

use super::spec::{CampaignSpec, JobSpec};
use super::store::ResultStore;

/// Everything shared by the bound pre-pass and by job evaluation: the
/// multiplier library, preloaded workloads, the calibration workload, and
/// the fitness-level objective the campaign optimizes. Built once per
/// campaign and handed to the source and the executor by reference.
pub struct JobCtx {
    pub lib: Vec<Multiplier>,
    pub workloads: HashMap<String, Workload>,
    pub tiny: Workload,
    pub objective: Objective,
    pub ga: GaParams,
    /// Whether provably-hopeless jobs may be skipped (spec `prune`).
    pub prune: bool,
    /// Evaluation caches shared by every GA run this campaign dispatches
    /// (DESIGN.md §7.6): the geometry-keyed mapping cache plus the memo
    /// counters, threaded through every executor's `run_job`.
    pub shares: EvalShares,
    /// Calibrated ΔA-model K, computed at most once per process — the
    /// value is a pure function of the library and the accuracy backend,
    /// so every job (and the bound pre-pass) agrees by construction.
    k_cell: OnceLock<f64>,
}

impl JobCtx {
    pub fn new(spec: &CampaignSpec) -> Result<Self> {
        let mut workloads = HashMap::new();
        for m in &spec.models {
            workloads
                .insert(m.clone(), workload(m).ok_or_else(|| anyhow!("unknown model {m}"))?);
        }
        Ok(Self {
            lib: library(),
            workloads,
            tiny: workload("tinycnn").expect("tinycnn workload exists"),
            objective: spec.objective.to_fitness(spec.deployment),
            ga: spec.ga,
            prune: spec.prune,
            shares: EvalShares::default(),
            k_cell: OnceLock::new(),
        })
    }

    pub fn workload(&self, model: &str) -> Result<&Workload> {
        self.workloads
            .get(model)
            .ok_or_else(|| anyhow!("workload {model} not preloaded"))
    }

    /// The campaign's calibrated K, fetched through the shared accuracy
    /// service on first use and memoized for the life of the process.
    /// Previously every job re-derived it (36 cached service round-trips
    /// plus 36 LUT rebuilds per job); the value never changes, so the
    /// redundancy bought nothing.
    pub fn k(&self, client: &EvalClient) -> Result<f64> {
        if let Some(&k) = self.k_cell.get() {
            return Ok(k);
        }
        let k = calibrated_k(client, &self.lib, &self.tiny)?;
        // A concurrent first use computes the same value; first set wins.
        Ok(*self.k_cell.get_or_init(|| k))
    }
}

/// Fetch the campaign-global accuracy table through the shared service and
/// calibrate the ΔA model's K against it. Used identically by the bound
/// pre-pass and by every job — a single definition is what guarantees the
/// pre-pass δ-feasible sets (and therefore the prune bounds) agree exactly
/// with the sets the GA searches.
pub(crate) fn calibrated_k(
    client: &EvalClient,
    lib: &[Multiplier],
    tiny: &Workload,
) -> Result<f64> {
    let mult_refs: Vec<&Multiplier> = lib.iter().collect();
    let accs = client
        .eval_all(&mult_refs)
        .map_err(|e| anyhow!("accuracy service: {e}"))?;
    let mut table = AccuracyTable { exact: accs[EXACT_ID], ..Default::default() };
    for (m, &a) in lib.iter().zip(&accs) {
        table.accuracy.insert(m.id, a);
    }
    Ok(crate::accuracy::model::calibrate_k(lib, tiny, &table))
}

/// Analytic optimistic bounds for one pending job: component-wise lower
/// bounds over the job's *entire* search space, so no achievable design can
/// beat them. Used to order the queue (most promising first) and to prune
/// jobs that provably cannot improve the committed front.
#[derive(Debug, Clone, Copy)]
pub struct JobBound {
    /// Lower bound on embodied carbon (g): the min-area corner of the
    /// search space with the cheapest δ-feasible multiplier.
    pub carbon_lb_g: f64,
    /// Lower bound on task delay (s): compute-bound at the largest array.
    pub delay_lb_s: f64,
    /// Lower bound on energy/inference (J): MAC energy only, at the most
    /// frugal δ-feasible multiplier (memory traffic ignored).
    pub energy_lb_j: f64,
    /// Upper bound on achievable FPS (`1 / delay_lb_s`).
    pub fps_ub: f64,
    /// Lower bound on the campaign objective value.
    pub objective_lb: f64,
}

/// Compute the optimistic bound for a job over its δ-feasible multiplier
/// set. Every component combines best-cases that no single design attains
/// simultaneously, which is exactly what makes it a valid lower bound.
pub fn job_bound(
    job: &JobSpec,
    w: &Workload,
    lib: &[Multiplier],
    feasible: &[usize],
    objective: &Objective,
) -> JobBound {
    let space = SearchSpace::standard(feasible.to_vec());
    let (px_min, py_min) = (space.px[0], space.py[0]);
    let (px_max, py_max) = (*space.px.last().unwrap(), *space.py.last().unwrap());
    let (rf_min, sram_min) = (space.rf_bytes[0], space.sram_bytes[0]);
    let mut carbon_lb_g = f64::INFINITY;
    let mut mac_pj_min = f64::INFINITY;
    for &mid in feasible {
        let cfg = AccelConfig {
            px: px_min,
            py: py_min,
            rf_bytes: rf_min,
            sram_bytes: sram_min,
            node: job.node,
            integration: job.integration,
            mult_id: mid,
        };
        let areas = cfg.die_areas(&lib[mid]);
        let c = embodied_carbon(&areas, job.node, job.integration).total_g();
        carbon_lb_g = carbon_lb_g.min(c);
        mac_pj_min = mac_pj_min.min(mac_power_uw(&lib[mid], job.node) / job.node.freq_mhz());
    }
    let macs = w.total_macs() as f64;
    let freq_hz = job.node.freq_mhz() * 1e6;
    let delay_lb_s = macs / ((px_max * py_max) as f64 * freq_hz);
    let energy_lb_j = macs * mac_pj_min * 1e-12;
    let objective_lb = objective.lower_bound(carbon_lb_g, energy_lb_j, delay_lb_s);
    JobBound { carbon_lb_g, delay_lb_s, energy_lb_j, fps_ub: 1.0 / delay_lb_s, objective_lb }
}

/// Why a job may be skipped without running, given its bound and the best
/// committed objective value in its family (None = no incumbent yet).
/// Returns `None` when the job must run.
///
/// Note the exact semantics: rule (b) prunes on the *scalar objective*
/// projected per (model, node, integration) family — a pruned scenario can
/// never improve the family's best objective value, but its row might have
/// contributed to the 3-axis (carbon, delay, drop) archive through a lower
/// accuracy drop alone. Pruning trades that per-scenario completeness for
/// speed; campaigns that need every grid point exhaustively set
/// `CampaignSpec::prune = false` (CLI `--no-prune`).
pub fn prune_reason(
    job: &JobSpec,
    bound: &JobBound,
    incumbent: Option<f64>,
) -> Option<&'static str> {
    if let Some(floor) = job.fps_floor {
        if bound.fps_ub < floor {
            // Even the compute-bound best case misses the floor: every
            // design in the space is infeasible.
            return Some("fps floor exceeds the reachable bound");
        }
    }
    if let Some(best) = incumbent {
        if bound.objective_lb >= best {
            // The optimistic bound already loses to a committed result in
            // this (model, node, integration) family.
            return Some("objective bound cannot beat the committed front");
        }
    }
    None
}

/// The deterministic job front-end: pending jobs in schedule order plus
/// their bounds. Schedule order is ascending optimistic objective bound,
/// ties broken by grid id — commits follow this order, so the ordering
/// itself is part of the byte-determinism contract.
pub struct JobSource {
    jobs_total: usize,
    jobs_skipped: usize,
    schedule: Vec<JobSpec>,
    bounds: HashMap<usize, JobBound>,
    grid: Vec<JobSpec>,
}

impl JobSource {
    /// Enumerate the grid, drop jobs whose key is already in `store`
    /// (checkpoint/resume), compute bounds through the shared service's
    /// accuracy table, and sort into schedule order.
    pub fn build(
        spec: &CampaignSpec,
        ctx: &JobCtx,
        store: &ResultStore,
        service: &EvalService,
    ) -> Result<Self> {
        Self::build_inner(spec, ctx, store, service, false)
    }

    /// [`JobSource::build`], but bounds are computed even when every job is
    /// already in the store — `campaign --explain-prune` diagnoses complete
    /// stores, where the normal pre-pass would have nothing to do.
    pub fn build_with_all_bounds(
        spec: &CampaignSpec,
        ctx: &JobCtx,
        store: &ResultStore,
        service: &EvalService,
    ) -> Result<Self> {
        Self::build_inner(spec, ctx, store, service, true)
    }

    fn build_inner(
        spec: &CampaignSpec,
        ctx: &JobCtx,
        store: &ResultStore,
        service: &EvalService,
        force_bounds: bool,
    ) -> Result<Self> {
        let grid = spec.jobs();
        let jobs_total = grid.len();
        let mut pending: Vec<JobSpec> =
            grid.iter().filter(|j| !store.contains(&j.key())).cloned().collect();
        let jobs_skipped = jobs_total - pending.len();
        let mut bounds: HashMap<usize, JobBound> = HashMap::new();
        if !pending.is_empty() || force_bounds {
            // Bounds for the *whole* grid, not just the pending jobs: the
            // adaptive planner replays its batch decisions over stored rows
            // too, and the replay needs the same bounds the original run
            // saw. (Pure computation after the one shared K calibration —
            // enumerating the extra jobs costs no service round-trips.)
            let client = service.client();
            let k = ctx.k(&client)?;
            let mut feasible_sets: HashMap<(String, u64), Vec<usize>> = HashMap::new();
            for job in &grid {
                let w = ctx.workload(&job.model)?;
                let f = feasible_sets
                    .entry((job.model.clone(), job.delta_pct.to_bits()))
                    .or_insert_with(|| feasible_multipliers(&ctx.lib, w, job.delta_pct, k));
                ensure!(
                    !f.is_empty(),
                    "no multiplier satisfies δ={}% for {}",
                    job.delta_pct,
                    job.model
                );
                bounds.insert(job.id, job_bound(job, w, &ctx.lib, f, &ctx.objective));
            }
            pending.sort_by(|a, b| {
                bounds[&a.id]
                    .objective_lb
                    .partial_cmp(&bounds[&b.id].objective_lb)
                    .unwrap()
                    .then(a.id.cmp(&b.id))
            });
        }
        Ok(Self { jobs_total, jobs_skipped, schedule: pending, bounds, grid })
    }

    /// Every grid job in flattened (id) order, stored or pending — the
    /// adaptive planner's replay domain.
    pub fn grid(&self) -> &[JobSpec] {
        &self.grid
    }

    /// Grid size before resume filtering.
    pub fn jobs_total(&self) -> usize {
        self.jobs_total
    }

    /// Jobs dropped because the store already had their row.
    pub fn jobs_skipped(&self) -> usize {
        self.jobs_skipped
    }

    /// Pending jobs in schedule (commit) order.
    pub fn schedule(&self) -> &[JobSpec] {
        &self.schedule
    }

    /// The optimistic bound for a job id (None for jobs without a bound,
    /// which can only happen for ids outside this campaign).
    pub fn bound(&self, job_id: usize) -> Option<&JobBound> {
        self.bounds.get(&job_id)
    }

    /// The schedule slots shard `index` of `count` primarily owns. The
    /// slices partition the schedule — union is the full slot range, no
    /// slot owned twice (pinned by a property test) — and because
    /// ownership hashes the job *key* (never the slot), it is stable under
    /// resume: a shard whose store already holds some rows sees a shorter
    /// schedule, yet every job still maps to the same owner. Test-only:
    /// the executors decide ownership per job via the same [`shard_owner`]
    /// (a shard must visit *every* slot to steal abandoned foreign jobs),
    /// so this slicing exists to state the partition property, not to
    /// drive dispatch.
    #[cfg(test)]
    pub(crate) fn shard_slots(&self, index: usize, count: usize) -> Vec<usize> {
        assert!(count > 0 && index < count, "shard {index}/{count} out of range");
        self.schedule
            .iter()
            .enumerate()
            .filter(|(_, j)| shard_owner(&j.key(), count) == index)
            .map(|(slot, _)| slot)
            .collect()
    }
}

/// Which shard (of `count`) primarily owns a job: a pure function of the
/// job key, so every process — whatever its store or resume state — agrees
/// on the assignment without coordination.
pub fn shard_owner(key: &str, count: usize) -> usize {
    (super::spec::fnv1a64(key.as_bytes()) % count as u64) as usize
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::area::die::Integration;
    use crate::area::TechNode;
    use crate::campaign::exec::SurrogateBackend;
    use crate::campaign::spec::CampaignObjective;
    use crate::ga::evaluate_objective;
    use crate::util::Rng;

    fn test_job(fps_floor: Option<f64>) -> JobSpec {
        let mut j = JobSpec {
            id: 0,
            model: "vgg16".to_string(),
            node: TechNode::N14,
            integration: Integration::ThreeD,
            delta_pct: 3.0,
            fps_floor,
            objective: CampaignObjective::EmbodiedCdp,
            seed: 0,
        };
        j.seed = super::super::spec::job_seed(1, &j.key());
        j
    }

    #[test]
    fn prune_rules_fire_on_bound_violations_only() {
        let bound = JobBound {
            carbon_lb_g: 1.0,
            delay_lb_s: 0.5,
            energy_lb_j: 0.01,
            fps_ub: 2.0,
            objective_lb: 5.0,
        };
        let free = test_job(None);
        // No incumbent, no floor: must run.
        assert_eq!(prune_reason(&free, &bound, None), None);
        // Incumbent worse than the bound: still must run (could beat it).
        assert_eq!(prune_reason(&free, &bound, Some(6.0)), None);
        // Incumbent at/below the bound: provably cannot beat it.
        assert!(prune_reason(&free, &bound, Some(5.0)).is_some());
        assert!(prune_reason(&free, &bound, Some(4.0)).is_some());
        // FPS floor above the compute-bound best case: infeasible.
        assert!(prune_reason(&test_job(Some(3.0)), &bound, None).is_some());
        assert_eq!(prune_reason(&test_job(Some(1.0)), &bound, None), None);
    }

    #[test]
    fn job_bound_is_a_true_lower_bound_on_sampled_designs() {
        // Property: the analytic bound never exceeds any achievable design's
        // metrics, across objectives and random chromosomes.
        let lib = library();
        let w = workload("resnet50").unwrap();
        let feasible: Vec<usize> = (0..lib.len()).collect();
        let dep = crate::carbon::operational::Deployment::default();
        for objective in [
            Objective::EmbodiedCdp(dep),
            Objective::OperationalCarbon(dep),
            Objective::LifetimeCdp(dep),
        ] {
            let job = test_job(None);
            let b = job_bound(&job, &w, &lib, &feasible, &objective);
            let space = SearchSpace::standard(feasible.clone());
            let mut rng = Rng::new(42);
            for _ in 0..25 {
                let c = space.sample(&mut rng);
                let e = evaluate_objective(
                    &c,
                    &w,
                    job.node,
                    job.integration,
                    &lib,
                    None,
                    &objective,
                );
                assert!(b.carbon_lb_g <= e.carbon_g + 1e-9, "{objective:?}");
                assert!(b.delay_lb_s <= e.delay_s + 1e-12, "{objective:?}");
                assert!(b.energy_lb_j <= e.energy_per_inference_j + 1e-15, "{objective:?}");
                assert!(b.fps_ub >= e.fps - 1e-9, "{objective:?}");
                assert!(
                    b.objective_lb <= objective.value(&e) * (1.0 + 1e-9),
                    "{objective:?}: bound {} vs value {}",
                    b.objective_lb,
                    objective.value(&e)
                );
            }
        }
    }

    fn quick_source(path: &std::path::Path) -> JobSource {
        let mut spec = CampaignSpec::new(
            vec!["vgg16".to_string(), "resnet50".to_string()],
            vec![TechNode::N45, TechNode::N7],
            vec![1.0, 3.0],
        );
        spec.fps_floors = vec![None, Some(30.0)];
        let ctx = JobCtx::new(&spec).unwrap();
        let store = ResultStore::open(path).unwrap();
        let svc = EvalService::start(SurrogateBackend::default());
        let source = JobSource::build(&spec, &ctx, &store, &svc).unwrap();
        svc.shutdown();
        source
    }

    #[test]
    fn shard_slots_partition_the_schedule_for_every_count() {
        // Property: for any shard count, the ownership slices are
        // disjoint, cover every slot, and the underlying schedule is the
        // same regardless of how it is sliced — sharding can never change
        // *what* runs, only *who* runs it.
        let path = std::env::temp_dir().join(format!(
            "carbon3d-source-shard-{}.jsonl",
            std::process::id()
        ));
        let _ = std::fs::remove_file(&path);
        let source = quick_source(&path);
        let n = source.schedule().len();
        assert_eq!(n, 16);
        for count in 1..=5usize {
            let mut seen = vec![false; n];
            for index in 0..count {
                for slot in source.shard_slots(index, count) {
                    assert!(slot < n, "slot {slot} out of range");
                    assert!(!seen[slot], "slot {slot} owned by two shards at count {count}");
                    seen[slot] = true;
                }
            }
            assert!(seen.iter().all(|&s| s), "count {count} left slots unowned");
        }
        // And a rebuilt source over the same spec/store yields the same
        // schedule: enumeration is stable across processes (each shard
        // builds its own source and must agree on the slot map).
        let again = quick_source(&path);
        let keys = |s: &JobSource| -> Vec<String> {
            s.schedule().iter().map(|j| j.key()).collect()
        };
        assert_eq!(keys(&source), keys(&again));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn schedule_orders_by_bound_and_skips_stored_rows() {
        let path = std::env::temp_dir().join(format!(
            "carbon3d-source-order-{}.jsonl",
            std::process::id()
        ));
        let _ = std::fs::remove_file(&path);
        let source = quick_source(&path);
        assert_eq!(source.jobs_total(), 16);
        assert_eq!(source.jobs_skipped(), 0);
        // The full grid is exposed (in id order) and every grid job — not
        // just the pending ones — has a bound, for the adaptive replay.
        assert_eq!(source.grid().len(), 16);
        for (i, job) in source.grid().iter().enumerate() {
            assert_eq!(job.id, i);
            assert!(source.bound(job.id).is_some(), "{}", job.key());
        }
        let mut prev = f64::NEG_INFINITY;
        for job in source.schedule() {
            let b = source.bound(job.id).expect("every pending job has a bound");
            assert!(b.objective_lb >= prev, "schedule not sorted by bound");
            prev = b.objective_lb;
        }
        let _ = std::fs::remove_file(&path);
    }
}
