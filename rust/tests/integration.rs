//! Integration tests across modules: runtime x accuracy x coordinator.
//!
//! PJRT-dependent tests skip (with a message) when `artifacts/` has not
//! been built — run `make artifacts` first for full coverage.

use std::path::Path;

use carbon3d::accuracy::model::{calibrate_k, feasible_multipliers, DEFAULT_K};
use carbon3d::accuracy::native::{ApproxDatapath, NativeEvaluator};
use carbon3d::approx::{library, lut_f32, EXACT_ID};
use carbon3d::area::die::Integration;
use carbon3d::area::node::ALL_NODES;
use carbon3d::area::TechNode;
use carbon3d::coordinator::baselines::Approach;
use carbon3d::coordinator::{ga_appx_min_carbon, ga_cdp_exact, headline_report, run_fig2, run_fig3};
use carbon3d::dataflow::workloads::workload;
use carbon3d::ga::GaParams;
use carbon3d::runtime::{Artifacts, Engine};

fn have_artifacts() -> bool {
    Path::new("artifacts/manifest.json").exists()
}

fn quick() -> GaParams {
    GaParams { population: 24, generations: 14, patience: 7, ..Default::default() }
}

// ---------------------------------------------------------------- figure pipelines

#[test]
fn fig2_pipeline_never_regresses_carbon() {
    let lib = library();
    let r = run_fig2(&lib, &["resnet50"], quick());
    assert_eq!(r.cells.len(), 9);
    for c in &r.cells {
        assert!(
            c.norm_carbon <= 1.0 + 1e-9,
            "{} {} δ{}: norm carbon {}",
            c.node.name(),
            c.model,
            c.delta_pct,
            c.norm_carbon
        );
        assert!(c.norm_delay <= 1.0 + 1e-9, "delay regressed: {}", c.norm_delay);
    }
}

#[test]
fn fig2_carbon_cut_monotone_in_delta() {
    let lib = library();
    let r = run_fig2(&lib, &["vgg19"], quick());
    for &node in &ALL_NODES {
        let cut = |d: f64| r.mean_carbon_cut_pct(node, d);
        assert!(cut(2.0) >= cut(1.0) - 1e-9, "{}", node.name());
        assert!(cut(3.0) >= cut(2.0) - 1e-9, "{}", node.name());
    }
}

#[test]
fn fig3_ga_points_meet_their_targets() {
    let lib = library();
    let r = run_fig3(&lib, "vgg16", quick());
    for p in r.points.iter().filter(|p| p.approach == Approach::GaAppxCdp) {
        let target = p.fps_target.unwrap();
        // GA points must meet reachable targets; the paper's max target is
        // within reach at every node for 3D arrays <= 64x64.
        assert!(p.feasible, "{} target {target}", p.node.name());
        assert!(p.fps >= target * 0.999, "{}: {} < {target}", p.node.name(), p.fps);
    }
}

#[test]
fn headline_report_directions_match_paper() {
    let lib = library();
    let fig2 = run_fig2(&lib, &["vgg16", "densenet121"], quick());
    let fig3 = run_fig3(&lib, "vgg16", quick());
    let claims = headline_report(&fig2, &fig3);
    assert!(claims.len() >= 4);
    for c in &claims {
        // Every measured claim must at least point the same way as the
        // paper's (positive = improvement).
        assert!(
            c.measured > 0.0,
            "{}: measured {} has wrong sign (paper {})",
            c.name,
            c.measured,
            c.paper
        );
    }
}

#[test]
fn baseline_vs_appx_like_for_like() {
    // The APPX search space strictly contains the baseline's, so with the
    // deterministic descent the reported carbon can never exceed baseline.
    let lib = library();
    let w = workload("resnet50v2").unwrap();
    for &node in &ALL_NODES {
        let base = ga_cdp_exact(&w, node, &lib, None, quick());
        let r = ga_appx_min_carbon(
            &w,
            node,
            &lib,
            3.0,
            base.best_eval.fps * 0.999,
            quick(),
            Some(&base.best),
        );
        assert!(r.best_eval.carbon_g <= base.best_eval.carbon_g + 1e-9, "{}", node.name());
        assert!(r.best_eval.fps >= base.best_eval.fps * 0.998, "{}", node.name());
    }
}

// ---------------------------------------------------------------- campaign engine

#[test]
fn campaign_runs_a_grid_through_the_public_api() {
    use carbon3d::campaign::{
        run_campaign, CampaignArchive, CampaignSpec, GroupBy, ResultStore, SurrogateBackend,
    };
    use carbon3d::runtime::EvalService;

    let mut spec = CampaignSpec::new(
        vec!["vgg16".to_string()],
        vec![TechNode::N14, TechNode::N7],
        vec![3.0],
    );
    spec.ga = quick();
    let path = std::env::temp_dir()
        .join(format!("carbon3d-it-campaign-{}.jsonl", std::process::id()));
    let _ = std::fs::remove_file(&path);
    let mut store = ResultStore::open(&path).unwrap();
    let svc = EvalService::start(SurrogateBackend::default());
    let report = run_campaign(&spec, 2, &mut store, &svc).unwrap();
    let stats = svc.shutdown();
    assert_eq!(report.jobs_run, 2);
    // With 2 concurrent jobs the duplicate library requests are answered
    // either from cache or by in-batch coalescing, depending on timing —
    // both count as the shared service saving re-evaluation.
    assert!(
        stats.cache_hits + stats.coalesced > 0,
        "second job should reuse the shared service's work: {stats:?}"
    );
    // The shared geometry-mapping cache served the GA runs and its
    // counters surface in the report beside the prune/service stats.
    assert!(report.mapping.lookups() > 0, "{:?}", report.mapping);
    assert!(report.memo.lookups() > 0, "{:?}", report.memo);
    assert!(report.line().contains("mapping cache:"), "{}", report.line());

    let arch = CampaignArchive::from_rows(store.rows()).unwrap();
    assert_eq!(arch.points.len(), 2);
    assert!(!arch.front.is_empty());
    assert_eq!(arch.aggregate_table(GroupBy::Node).n_rows(), 2);
    for row in store.rows() {
        assert!(row.get("carbon_g").unwrap().as_f64().unwrap() > 0.0);
        assert!(row.get("cdp").unwrap().as_f64().unwrap() > 0.0);
        assert!(row.get("feasible").unwrap() == &carbon3d::util::Json::Bool(true));
    }
    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_file(CampaignArchive::checkpoint_path(&path));
}

#[test]
fn adaptive_campaign_runs_through_the_public_api() {
    use carbon3d::campaign::{
        run_campaign, CampaignArchive, CampaignSpec, ResultStore, SamplerMode,
        SurrogateBackend,
    };
    use carbon3d::runtime::EvalService;

    let mut spec = CampaignSpec::new(
        vec!["vgg16".to_string()],
        vec![TechNode::N7],
        vec![1.0, 2.0, 3.0, 4.0],
    );
    spec.ga = GaParams { population: 8, generations: 4, patience: 2, ..Default::default() };
    spec.sampler = SamplerMode::Adaptive { batch: 2 };
    let dir = std::env::temp_dir();
    let pa = dir.join(format!("carbon3d-it-adaptive-{}.jsonl", std::process::id()));
    let pb = dir.join(format!("carbon3d-it-adaptive-b-{}.jsonl", std::process::id()));
    let cleanup = |p: &std::path::Path| {
        let _ = std::fs::remove_file(p);
        let _ = std::fs::remove_file(CampaignArchive::checkpoint_path(p));
        let _ = std::fs::remove_file(carbon3d::obs::status::status_path(p));
        let _ = std::fs::remove_file(carbon3d::campaign::mapcache_path(p));
    };
    cleanup(&pa);
    cleanup(&pb);

    let run = |p: &std::path::Path, workers: usize| {
        let mut store = ResultStore::open(p).unwrap();
        let svc = EvalService::start(SurrogateBackend::default());
        let report = run_campaign(&spec, workers, &mut store, &svc).unwrap();
        svc.shutdown();
        (report, std::fs::read_to_string(p).unwrap())
    };
    let (report, bytes) = run(&pa, 3);
    // The adaptive store announces its sampler on the first line; data
    // rows follow in planner-commit order.
    let header = bytes.lines().next().unwrap();
    assert!(header.contains("\"sampler\":\"adaptive\""), "{header}");
    assert_eq!(bytes.lines().count(), report.jobs_run + 1);
    assert_eq!(report.jobs_run + report.jobs_pruned, 4);
    assert!(report.jobs_run > 0);
    assert!(report.jobs_pruned_surrogate <= report.jobs_pruned);
    // The planner re-ranked at least once and its activity reaches the
    // human report line.
    assert!(report.metrics.counter("sampler_reranks") > 0);
    if report.jobs_pruned_surrogate > 0 {
        assert!(report.line().contains("by surrogate"), "{}", report.line());
    }

    // A second fresh run with a different worker count is byte-identical.
    let (_, bytes_b) = run(&pb, 1);
    assert_eq!(bytes, bytes_b, "adaptive campaign depends on worker count");

    // The archive reads over the data rows (the header is not a point).
    let store = ResultStore::open(&pa).unwrap();
    let arch = CampaignArchive::from_rows(store.rows()).unwrap();
    assert_eq!(arch.points.len(), report.jobs_run);
    assert!(!arch.front.is_empty());

    cleanup(&pa);
    cleanup(&pb);
}

#[test]
fn lifetime_objective_shifts_the_campaign_front() {
    use carbon3d::campaign::{
        run_campaign, CampaignArchive, CampaignObjective, CampaignSpec, CarbonAxis, ResultStore,
        SurrogateBackend,
    };
    use carbon3d::carbon::operational::Deployment;
    use carbon3d::runtime::EvalService;
    use carbon3d::util::Json;

    let mk_spec = |objective: CampaignObjective| {
        let mut spec = CampaignSpec::new(
            vec!["resnet50".to_string()],
            vec![TechNode::N45, TechNode::N7],
            vec![3.0],
        );
        spec.ga = GaParams { population: 12, generations: 8, patience: 4, ..Default::default() };
        spec.objective = objective;
        // Heavy-duty deployment: operational carbon dominates embodied by
        // orders of magnitude, so the optimal area/energy split must shift.
        spec.deployment = Deployment {
            lifetime_years: 10.0,
            inferences_per_day: 50_000_000.0,
            grid_kgco2_per_kwh: 0.7,
        };
        spec
    };
    let run = |objective: CampaignObjective, tag: &str| {
        let path = std::env::temp_dir().join(format!(
            "carbon3d-it-objective-{}-{tag}.jsonl",
            std::process::id()
        ));
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_file(CampaignArchive::checkpoint_path(&path));
        let mut store = ResultStore::open(&path).unwrap();
        let svc = EvalService::start(SurrogateBackend::default());
        run_campaign(&mk_spec(objective), 2, &mut store, &svc).unwrap();
        svc.shutdown();
        (path, store)
    };
    let (pe, emb_store) = run(CampaignObjective::EmbodiedCdp, "embodied");
    let (pl, life_store) = run(CampaignObjective::LifetimeCdp, "lifetime");

    // Under this deployment the operational term dwarfs the embodied one.
    for row in life_store.rows() {
        let c = row.get("carbon_g").unwrap().as_f64().unwrap();
        let l = row.get("lifetime_gco2").unwrap().as_f64().unwrap();
        assert!(l > 10.0 * c, "operational term unexpectedly small: {l} vs embodied {c}");
    }

    // The acceptance bar: the lifetime-cdp front differs from the
    // embodied-cdp front on at least one node (different winning design).
    let config_of = |row: &Json| {
        (
            row.get("node").unwrap().as_str().unwrap().to_string(),
            row.get("px").unwrap().as_usize().unwrap(),
            row.get("py").unwrap().as_usize().unwrap(),
            row.get("rf_bytes").unwrap().as_usize().unwrap(),
            row.get("sram_bytes").unwrap().as_usize().unwrap(),
            row.get("mult_id").unwrap().as_usize().unwrap(),
        )
    };
    let mut emb: Vec<_> = emb_store.rows().iter().map(config_of).collect();
    let mut life: Vec<_> = life_store.rows().iter().map(config_of).collect();
    emb.sort();
    life.sort();
    assert_eq!(emb.len(), 2);
    assert_eq!(life.len(), 2);
    assert_ne!(emb, life, "lifetime objective chose identical designs on every node");

    // Incremental archive == full recompute on the same store, and the
    // checkpointed sidecar written during the run restores the same front.
    let full = CampaignArchive::from_rows_on(life_store.rows(), CarbonAxis::Lifetime).unwrap();
    let inc =
        CampaignArchive::from_rows_incremental(life_store.rows(), CarbonAxis::Lifetime).unwrap();
    assert_eq!(inc.front, full.front, "incremental archive diverged from full recompute");
    let restored = CampaignArchive::load_or_rebuild(
        life_store.rows(),
        CarbonAxis::Lifetime,
        &CampaignArchive::checkpoint_path(&pl),
    )
    .unwrap();
    assert_eq!(restored.front, full.front, "checkpoint restore diverged");

    for p in [&pe, &pl] {
        let _ = std::fs::remove_file(p);
        let _ = std::fs::remove_file(CampaignArchive::checkpoint_path(p));
    }
}

#[test]
fn sharded_campaign_merge_matches_single_process_through_public_api() {
    use carbon3d::campaign::{
        run_campaign, run_campaign_with, shard_store_path, CampaignArchive, CampaignSpec,
        LeaseDir, MergeExecutor, ResultStore, ShardId, ShardedExecutor, SurrogateBackend,
    };
    use carbon3d::obs::diff::DiffReport;
    use carbon3d::obs::{merge_traces, ObsRecord, TraceReport};
    use carbon3d::runtime::EvalService;

    let mut spec = CampaignSpec::new(
        vec!["vgg16".to_string()],
        vec![TechNode::N45, TechNode::N7],
        vec![1.0, 3.0],
    );
    spec.ga = GaParams { population: 8, generations: 4, patience: 2, ..Default::default() };

    let dir = std::env::temp_dir();
    let single = dir.join(format!("carbon3d-it-shard-single-{}.jsonl", std::process::id()));
    let canonical = dir.join(format!("carbon3d-it-shard-merged-{}.jsonl", std::process::id()));
    let cleanup = |p: &std::path::Path| {
        let _ = std::fs::remove_file(p);
        let _ = std::fs::remove_file(CampaignArchive::checkpoint_path(p));
        let _ = std::fs::remove_file(carbon3d::obs::status::status_path(p));
        let _ = std::fs::remove_file(p.with_extension("trace.jsonl"));
    };
    cleanup(&single);
    cleanup(&canonical);
    let _ = std::fs::remove_dir_all(LeaseDir::for_store(&canonical));
    let shard_paths: Vec<_> =
        (0..2).map(|i| shard_store_path(&canonical, ShardId { index: i, count: 2 })).collect();
    for p in &shard_paths {
        cleanup(p);
    }

    // Single-process reference.
    let mut ref_store = ResultStore::open(&single).unwrap();
    let svc = EvalService::start(SurrogateBackend::default());
    let ref_report = run_campaign(&spec, 3, &mut ref_store, &svc).unwrap();
    svc.shutdown();
    // Every grid point is accounted for (run, or deterministically pruned —
    // either way the merge below must reproduce the exact same split).
    assert_eq!(ref_report.jobs_run + ref_report.jobs_pruned, 4);
    assert!(ref_report.jobs_run > 0);

    // Two lease-coordinated shards (traced: each writes its own sidecar
    // with its shard label, exactly like `campaign --shard i/N --trace`),
    // then the merge.
    for index in 0..2usize {
        let shard = ShardId { index, count: 2 };
        let store_path = shard_store_path(&canonical, shard);
        carbon3d::obs::install(
            &store_path.with_extension("trace.jsonl"),
            &store_path,
            Some(&shard.to_string()),
        )
        .unwrap();
        let mut store = ResultStore::open(&store_path).unwrap();
        let leases = LeaseDir::open(
            LeaseDir::for_store(&canonical),
            format!("it-shard-{index}"),
            600,
        )
        .unwrap();
        let svc = EvalService::start(SurrogateBackend::default());
        run_campaign_with(&spec, &ShardedExecutor { shard, leases }, &mut store, &svc).unwrap();
        svc.shutdown();
        carbon3d::obs::uninstall().unwrap();
    }
    let merge = MergeExecutor::from_shard_stores(&canonical, 2).unwrap();
    let mut merged_store = ResultStore::open(&canonical).unwrap();
    let svc = EvalService::start(SurrogateBackend::default());
    let merged_report = run_campaign_with(&spec, &merge, &mut merged_store, &svc).unwrap();
    svc.shutdown();

    let bytes = |p: &std::path::Path| std::fs::read_to_string(p).unwrap();
    assert_eq!(bytes(&single), bytes(&canonical), "merged store diverged");
    assert_eq!(
        bytes(&CampaignArchive::checkpoint_path(&single)),
        bytes(&CampaignArchive::checkpoint_path(&canonical)),
        "merged front sidecar diverged"
    );
    assert_eq!(
        ref_report.deterministic_json().dumps(),
        merged_report.deterministic_json().dumps()
    );

    // ---- observatory on top of the same run: fold the shard sidecars
    // into one stream, validate its lanes, diff it against itself, export
    // a timeline, and check the live status snapshot closed out "done".
    let shard_traces: Vec<std::path::PathBuf> =
        shard_paths.iter().map(|p| p.with_extension("trace.jsonl")).collect();
    let merged_trace = dir.join(format!("carbon3d-it-merged-{}.trace.jsonl", std::process::id()));
    let summary = merge_traces(&shard_traces, &merged_trace).unwrap();
    assert_eq!(summary.lanes, vec!["0/2".to_string(), "1/2".to_string()]);

    let r = TraceReport::load(&merged_trace).unwrap();
    assert!(r.lanes().len() >= 2, "merged trace lost its per-shard lanes");
    assert!(
        r.spans.iter().any(|s| s.name == "campaign.run"),
        "merged trace carries no campaign spans"
    );
    assert!(r.final_metrics.is_some());

    // Two identical records diff to zero regressions under any gate.
    let d = DiffReport::new(
        ObsRecord::load(&merged_trace).unwrap(),
        ObsRecord::load(&merged_trace).unwrap(),
    );
    assert!(d.regressions(1.0).is_empty(), "identical records regressed");

    // The Chrome export maps each lane to its own synthetic process.
    let chrome = merged_trace.with_extension("chrome.json");
    carbon3d::obs::export::export_chrome(&merged_trace, &chrome).unwrap();
    let doc =
        carbon3d::util::Json::parse(&std::fs::read_to_string(&chrome).unwrap()).unwrap();
    let metas = doc
        .get("traceEvents")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .filter(|e| e.get("ph").unwrap() == &carbon3d::util::Json::from("M"))
        .count();
    assert_eq!(metas, 2, "one process_name per shard lane");

    // The merge run's status snapshot agrees with its report counters.
    let status = carbon3d::util::Json::parse(
        &std::fs::read_to_string(carbon3d::obs::status::status_path(&canonical)).unwrap(),
    )
    .unwrap();
    assert_eq!(status.get("state").unwrap().as_str().unwrap(), "done");
    assert_eq!(status.get("shard").unwrap().as_str().unwrap(), "merge");
    assert_eq!(
        status.get("jobs_done").unwrap().as_usize().unwrap(),
        merged_report.jobs_run
    );
    carbon3d::obs::status::prometheus_text(&status).unwrap();

    let _ = std::fs::remove_file(&merged_trace);
    let _ = std::fs::remove_file(&chrome);
    cleanup(&single);
    cleanup(&canonical);
    let _ = std::fs::remove_dir_all(LeaseDir::for_store(&canonical));
    for p in &shard_paths {
        cleanup(p);
    }
}

#[test]
fn campaign_spec_validation_names_the_duplicate_axis_entry() {
    use carbon3d::campaign::CampaignSpec;

    let small = || {
        CampaignSpec::new(
            vec!["vgg16".to_string(), "resnet50".to_string()],
            vec![TechNode::N45, TechNode::N7],
            vec![1.0, 3.0],
        )
    };
    assert!(small().validate().is_ok());
    let err = |s: &CampaignSpec| s.validate().unwrap_err().to_string();

    let mut s = small();
    s.models.push("vgg16".into());
    assert!(err(&s).contains("vgg16"), "{}", err(&s));

    let mut s = small();
    s.deltas = vec![1.0, 3.0, 1.0];
    assert!(err(&s).contains('1'), "{}", err(&s));

    let mut s = small();
    s.nodes.push(TechNode::N45);
    assert!(err(&s).contains("45nm"));

    let mut s = small();
    s.integrations = vec![Integration::ThreeD, Integration::ThreeD];
    assert!(err(&s).contains("3D"));

    let mut s = small();
    s.fps_floors = vec![None, Some(30.0), None];
    assert!(err(&s).contains("unconstrained"));
    s.fps_floors = vec![Some(30.0), Some(30.0)];
    assert!(err(&s).contains("30"));

    // Near-duplicates that collide in the key's 3-decimal encoding are
    // duplicates too: they would produce identical job keys and crash the
    // store at the second commit if allowed through.
    let mut s = small();
    s.deltas = vec![1.0001, 1.0002];
    assert!(err(&s).contains("3 decimals"), "{}", err(&s));
    let mut s = small();
    s.fps_floors = vec![Some(30.0001), Some(30.0002)];
    assert!(err(&s).contains("3 decimals"), "{}", err(&s));
}

// ---------------------------------------------------------------- accuracy model

#[test]
fn feasible_sets_respect_delta_ordering_on_all_workloads() {
    let lib = library();
    for name in ["vgg16", "vgg19", "resnet50", "resnet50v2", "densenet121"] {
        let w = workload(name).unwrap();
        let f1 = feasible_multipliers(&lib, &w, 1.0, DEFAULT_K);
        let f3 = feasible_multipliers(&lib, &w, 3.0, DEFAULT_K);
        assert!(f1.contains(&EXACT_ID), "{name}");
        assert!(f3.len() > f1.len(), "{name}: δ=3% adds nothing over δ=1%");
    }
}

// ---------------------------------------------------------------- PJRT runtime

#[test]
fn pjrt_exact_accuracy_matches_manifest() {
    if !have_artifacts() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let engine = Engine::new(Artifacts::load(Path::new("artifacts")).unwrap()).unwrap();
    let acc = engine.accuracy_pjrt(None).unwrap();
    assert!((acc - engine.artifacts.exact_test_accuracy).abs() < 1e-9);
}

#[test]
fn pjrt_exact_lut_equals_exact_executable() {
    if !have_artifacts() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let engine = Engine::new(Artifacts::load(Path::new("artifacts")).unwrap()).unwrap();
    let lib = library();
    let lut = lut_f32(&lib[EXACT_ID]);
    let imgs = &engine.native().testset.images[..64 * 256];
    let exact = engine.cnn_logits_exact(imgs).unwrap();
    let viaapx = engine.cnn_logits_approx(imgs, &lut).unwrap();
    // Approximate path quantizes to bf16; logits must stay close.
    let max_abs = exact.iter().fold(0f32, |m, x| m.max(x.abs()));
    for (a, b) in exact.iter().zip(&viaapx) {
        assert!((a - b).abs() < 0.05 * max_abs, "{a} vs {b}");
    }
}

#[test]
fn pjrt_and_native_agree_on_an_aggressive_multiplier() {
    if !have_artifacts() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let engine = Engine::new(Artifacts::load(Path::new("artifacts")).unwrap()).unwrap();
    let lib = library();
    let m = lib.iter().find(|m| m.name() == "TRUNC5").unwrap();
    let pjrt = engine.accuracy_pjrt(Some(&lut_f32(m))).unwrap();
    let native = engine.native().accuracy(&ApproxDatapath::new(m));
    assert!(
        (pjrt - native).abs() < 0.01,
        "TRUNC5: pjrt {pjrt} vs native {native}"
    );
}

#[test]
fn native_evaluator_accuracy_matches_manifest() {
    if !have_artifacts() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let artifacts = Artifacts::load(Path::new("artifacts")).unwrap();
    let native = NativeEvaluator::load(&artifacts).unwrap();
    let lib = library();
    let acc = native.accuracy(&ApproxDatapath::new(&lib[EXACT_ID]));
    assert!((acc - artifacts.exact_test_accuracy).abs() < 1e-9);
}

#[test]
fn measured_calibration_is_stable() {
    if !have_artifacts() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let artifacts = Artifacts::load(Path::new("artifacts")).unwrap();
    let native = NativeEvaluator::load(&artifacts).unwrap();
    let lib = library();
    let tiny = workload("tinycnn").unwrap();
    let mut table = carbon3d::accuracy::AccuracyTable {
        exact: native.accuracy(&ApproxDatapath::new(&lib[EXACT_ID])),
        ..Default::default()
    };
    // A handful of informative designs suffices for a stable K.
    for name in ["PERF6", "PERF7", "TRUNC5"] {
        let m = lib.iter().find(|m| m.name() == name).unwrap();
        table.accuracy.insert(m.id, native.accuracy(&ApproxDatapath::new(m)));
    }
    let k = calibrate_k(&lib, &tiny, &table);
    assert!((0.05..50.0).contains(&k), "k={k}");
}

// ---------------------------------------------------------------- cross-model glue

#[test]
fn config_describe_roundtrips_all_nodes_integrations() {
    let lib = library();
    for &node in &ALL_NODES {
        for integration in [Integration::TwoD, Integration::ThreeD] {
            let cfg = carbon3d::dataflow::arch::AccelConfig {
                px: 16,
                py: 16,
                rf_bytes: 128,
                sram_bytes: 512 << 10,
                node,
                integration,
                mult_id: EXACT_ID,
            };
            let d = cfg.describe(&lib[EXACT_ID]);
            assert!(d.contains(node.name()));
            let areas = cfg.die_areas(&lib[EXACT_ID]);
            assert!(areas.logic_mm2 > 0.0);
        }
    }
}

#[test]
fn tech_node_sanity_against_paper_frequencies() {
    assert_eq!(TechNode::N45.freq_mhz(), 500.0);
    assert_eq!(TechNode::N14.freq_mhz(), 940.0);
    assert_eq!(TechNode::N7.freq_mhz(), 1050.0);
}
