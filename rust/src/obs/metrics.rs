//! Process-wide metrics registry: named atomic counters, gauges, and
//! fixed-bucket histograms, snapshotted behind one [`MetricsSnapshot`].
//!
//! The registry is always on — recording is a read-locked map probe plus
//! relaxed atomic adds, cheap enough for every instrumentation site the
//! evaluation stack carries — and it never touches deterministic outputs:
//! snapshots surface in `CampaignReport::line()`, bench `--json`
//! artifacts, and the trace sidecar's final `metrics` line, all of which
//! stay outside the byte-compared store/front/`deterministic_json`.
//!
//! Naming convention (DESIGN.md §8): standalone counters and gauges are
//! `snake_case` (`mapper_cache_hits`, `lease_reclaims`,
//! `commit_reorder_depth`); histograms are named after the span that
//! feeds them (`job.eval`, `mapper.search`) and record microseconds.
//! Value histograms (non-durations) share the same bucket ladder.

use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock, RwLock};
use std::time::Duration;

use anyhow::{ensure, Context, Result};

use crate::util::json::{obj, Json};

/// Histogram bucket upper bounds (inclusive), a 1-2-5 ladder from 1 to
/// 60e6. For duration histograms the unit is microseconds, so the ladder
/// spans 1µs..60s; one overflow bucket catches everything above.
pub const BUCKET_BOUNDS: [u64; 24] = [
    1,
    2,
    5,
    10,
    20,
    50,
    100,
    200,
    500,
    1_000,
    2_000,
    5_000,
    10_000,
    20_000,
    50_000,
    100_000,
    200_000,
    500_000,
    1_000_000,
    2_000_000,
    5_000_000,
    10_000_000,
    30_000_000,
    60_000_000,
];

/// Bucket count: one per bound plus the overflow bucket.
pub const N_BUCKETS: usize = BUCKET_BOUNDS.len() + 1;

/// A fixed-bucket histogram over `u64` values (relaxed atomics:
/// observability, not synchronization — the same contract as
/// [`crate::dataflow::cache::CacheStats`]).
pub struct Histogram {
    buckets: [AtomicU64; N_BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    /// Bucket index for a value: the first bound >= `v`, else overflow.
    pub fn bucket_index(v: u64) -> usize {
        BUCKET_BOUNDS.partition_point(|&b| b < v)
    }

    /// Record one value: bump its bucket, the count, and the sum.
    pub fn record(&self, v: u64) {
        self.buckets[Self::bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    /// Freeze the live atomics into a [`HistogramCounts`] snapshot.
    pub fn counts(&self) -> HistogramCounts {
        HistogramCounts {
            buckets: std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed)),
            count: self.count.load(Ordering::Relaxed),
            sum: self.sum.load(Ordering::Relaxed),
        }
    }
}

/// A point-in-time snapshot of a [`Histogram`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HistogramCounts {
    /// Per-bucket observation counts, overflow bucket last.
    pub buckets: [u64; N_BUCKETS],
    /// Total observations.
    pub count: u64,
    /// Sum of all observed values.
    pub sum: u64,
}

impl Default for HistogramCounts {
    fn default() -> Self {
        Self { buckets: [0; N_BUCKETS], count: 0, sum: 0 }
    }
}

impl HistogramCounts {
    /// Exact mean of the observed values (`sum / count`), 0 when empty.
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Quantile estimate at bucket resolution: the upper bound of the
    /// bucket where the cumulative count crosses `q` (the overflow bucket
    /// reports the last finite bound — an underestimate, by design, so
    /// JSON output never carries non-finite numbers).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            cum += c;
            if cum >= target {
                return BUCKET_BOUNDS.get(i).copied().unwrap_or(BUCKET_BOUNDS[23]) as f64;
            }
        }
        BUCKET_BOUNDS[23] as f64
    }

    /// Median at bucket resolution (see [`Self::quantile`]).
    pub fn p50(&self) -> f64 {
        self.quantile(0.5)
    }

    /// 95th percentile at bucket resolution (see [`Self::quantile`]).
    pub fn p95(&self) -> f64 {
        self.quantile(0.95)
    }

    /// Serialize, including the raw bucket array: the derived quantiles
    /// are convenient for human eyeballing but only the buckets make the
    /// snapshot losslessly mergeable (`trace merge` / `trace diff` fold
    /// parsed snapshots through [`Merge`]).
    pub fn to_json(&self) -> Json {
        obj([
            ("buckets", Json::Arr(self.buckets.iter().map(|&b| Json::from(b as f64)).collect())),
            ("count", Json::from(self.count as f64)),
            ("sum", Json::from(self.sum as f64)),
            ("mean", Json::from(self.mean())),
            ("p50", Json::from(self.p50())),
            ("p95", Json::from(self.p95())),
        ])
    }

    /// Parse a serialized histogram. The `buckets` array is optional
    /// (pre-observatory sidecars and bench files omit it) — without it
    /// the counts still carry `count`/`sum`, but quantiles read 0.
    pub fn from_json(v: &Json) -> Result<Self> {
        let mut h = HistogramCounts {
            buckets: [0; N_BUCKETS],
            count: v.get("count")?.as_f64()? as u64,
            sum: v.get("sum")?.as_f64()? as u64,
        };
        if let Ok(arr) = v.get("buckets") {
            let arr = arr.as_arr()?;
            ensure!(
                arr.len() == N_BUCKETS,
                "histogram buckets: expected {N_BUCKETS} entries, got {}",
                arr.len()
            );
            for (i, b) in arr.iter().enumerate() {
                h.buckets[i] = b.as_f64()? as u64;
            }
        }
        Ok(h)
    }
}

/// Last-written + high-water gauge.
#[derive(Default)]
pub struct Gauge {
    last: AtomicU64,
    max: AtomicU64,
}

impl Gauge {
    /// Set the current value, ratcheting the high-water mark.
    pub fn set(&self, v: u64) {
        self.last.store(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    /// Freeze the live atomics into a [`GaugeCounts`] snapshot.
    pub fn counts(&self) -> GaugeCounts {
        GaugeCounts {
            last: self.last.load(Ordering::Relaxed),
            max: self.max.load(Ordering::Relaxed),
        }
    }
}

/// A point-in-time snapshot of a [`Gauge`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct GaugeCounts {
    /// Most recently set value.
    pub last: u64,
    /// High-water mark over the gauge's lifetime.
    pub max: u64,
}

/// The process-wide registry. Instrumentation sites record by `&'static
/// str` name; names register lazily (one write-lock insert on first use,
/// read-locked probes — no allocation — after).
#[derive(Default)]
pub struct Metrics {
    counters: RwLock<HashMap<&'static str, Arc<AtomicU64>>>,
    gauges: RwLock<HashMap<&'static str, Arc<Gauge>>>,
    hists: RwLock<HashMap<&'static str, Arc<Histogram>>>,
}

impl Metrics {
    /// Add `by` to the named counter (registering it on first use).
    pub fn incr(&self, name: &'static str, by: u64) {
        if let Some(c) = self.counters.read().expect("metrics poisoned").get(name) {
            c.fetch_add(by, Ordering::Relaxed);
            return;
        }
        self.counters
            .write()
            .expect("metrics poisoned")
            .entry(name)
            .or_default()
            .fetch_add(by, Ordering::Relaxed);
    }

    /// Current value of the named counter (0 if never incremented).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters
            .read()
            .expect("metrics poisoned")
            .get(name)
            .map(|c| c.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Set the named gauge (registering it on first use).
    pub fn gauge_set(&self, name: &'static str, v: u64) {
        if let Some(g) = self.gauges.read().expect("metrics poisoned").get(name) {
            g.set(v);
            return;
        }
        self.gauges.write().expect("metrics poisoned").entry(name).or_default().set(v);
    }

    /// Record a raw value into the named histogram.
    pub fn record(&self, name: &'static str, v: u64) {
        if let Some(h) = self.hists.read().expect("metrics poisoned").get(name) {
            h.record(v);
            return;
        }
        self.hists.write().expect("metrics poisoned").entry(name).or_default().record(v);
    }

    /// Record a duration (microsecond resolution) into the named histogram.
    pub fn record_duration(&self, name: &'static str, d: Duration) {
        self.record(name, d.as_micros().min(u128::from(u64::MAX)) as u64);
    }

    /// Freeze every registered instrument into a [`MetricsSnapshot`].
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            counters: self
                .counters
                .read()
                .expect("metrics poisoned")
                .iter()
                .map(|(k, v)| (k.to_string(), v.load(Ordering::Relaxed)))
                .collect(),
            gauges: self
                .gauges
                .read()
                .expect("metrics poisoned")
                .iter()
                .map(|(k, v)| (k.to_string(), v.counts()))
                .collect(),
            histograms: self
                .hists
                .read()
                .expect("metrics poisoned")
                .iter()
                .map(|(k, v)| (k.to_string(), v.counts()))
                .collect(),
        }
    }
}

/// The process-wide registry instance.
pub fn metrics() -> &'static Metrics {
    static M: OnceLock<Metrics> = OnceLock::new();
    M.get_or_init(Metrics::default)
}

/// Counter-set arithmetic shared by every stats type the reports surface
/// — the ONE definition of "add two snapshots" / "what happened between
/// two snapshots", so shard merges, report deltas, and bench embeddings
/// can never drift apart in how they sum fields.
pub trait Merge: Sized {
    /// Fold `other`'s counts into `self` (field-wise add).
    fn merge(&mut self, other: &Self);

    /// Counts accumulated since `earlier` (field-wise saturating subtract
    /// — both sides must come from the same monotone source).
    fn diff(&self, earlier: &Self) -> Self;
}

/// Fold any number of counter sets into one.
pub fn merged<T: Merge + Default>(parts: impl IntoIterator<Item = T>) -> T {
    let mut out = T::default();
    for p in parts {
        out.merge(&p);
    }
    out
}

impl Merge for crate::runtime::ServiceStats {
    fn merge(&mut self, other: &Self) {
        self.served += other.served;
        self.evaluated += other.evaluated;
        self.cache_hits += other.cache_hits;
        self.coalesced += other.coalesced;
    }

    fn diff(&self, earlier: &Self) -> Self {
        Self {
            served: self.served.saturating_sub(earlier.served),
            evaluated: self.evaluated.saturating_sub(earlier.evaluated),
            cache_hits: self.cache_hits.saturating_sub(earlier.cache_hits),
            coalesced: self.coalesced.saturating_sub(earlier.coalesced),
        }
    }
}

impl Merge for crate::dataflow::cache::CacheCounts {
    fn merge(&mut self, other: &Self) {
        self.hits += other.hits;
        self.misses += other.misses;
        self.persisted_hits += other.persisted_hits;
        self.preloaded += other.preloaded;
    }

    fn diff(&self, earlier: &Self) -> Self {
        Self {
            hits: self.hits.saturating_sub(earlier.hits),
            misses: self.misses.saturating_sub(earlier.misses),
            persisted_hits: self.persisted_hits.saturating_sub(earlier.persisted_hits),
            preloaded: self.preloaded.saturating_sub(earlier.preloaded),
        }
    }
}

impl Merge for HistogramCounts {
    fn merge(&mut self, other: &Self) {
        for (b, o) in self.buckets.iter_mut().zip(&other.buckets) {
            *b += o;
        }
        self.count += other.count;
        self.sum += other.sum;
    }

    fn diff(&self, earlier: &Self) -> Self {
        Self {
            buckets: std::array::from_fn(|i| {
                self.buckets[i].saturating_sub(earlier.buckets[i])
            }),
            count: self.count.saturating_sub(earlier.count),
            sum: self.sum.saturating_sub(earlier.sum),
        }
    }
}

/// A point-in-time view of the whole registry: the one structure that
/// carries observability counters between layers (report lines, bench
/// JSON, the trace sidecar's final `metrics` line).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MetricsSnapshot {
    /// Counter values by name.
    pub counters: BTreeMap<String, u64>,
    /// Gauge last/max values by name.
    pub gauges: BTreeMap<String, GaugeCounts>,
    /// Histogram bucket counts by name.
    pub histograms: BTreeMap<String, HistogramCounts>,
}

impl MetricsSnapshot {
    /// Snapshot the process-wide registry.
    pub fn collect() -> Self {
        metrics().snapshot()
    }

    /// Counter value by name (0 if the counter never registered).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    /// Histogram counts by name; `None` when absent or empty.
    pub fn histogram(&self, name: &str) -> Option<&HistogramCounts> {
        self.histograms.get(name).filter(|h| h.count > 0)
    }

    /// Serialize the snapshot for sidecar / bench-JSON embedding.
    pub fn to_json(&self) -> Json {
        obj([
            (
                "counters",
                Json::Obj(
                    self.counters
                        .iter()
                        .map(|(k, &v)| (k.clone(), Json::from(v as f64)))
                        .collect(),
                ),
            ),
            (
                "gauges",
                Json::Obj(
                    self.gauges
                        .iter()
                        .map(|(k, g)| {
                            (
                                k.clone(),
                                obj([
                                    ("last", Json::from(g.last as f64)),
                                    ("max", Json::from(g.max as f64)),
                                ]),
                            )
                        })
                        .collect(),
                ),
            ),
            (
                "histograms",
                Json::Obj(
                    self.histograms
                        .iter()
                        .map(|(k, h)| (k.clone(), h.to_json()))
                        .collect(),
                ),
            ),
        ])
    }

    /// Parse a snapshot back from its [`Self::to_json`] form — the read
    /// side of the trace sidecar's `metrics` lines and the bench `--json`
    /// embeddings, so `trace merge`/`trace diff` can fold them through
    /// [`Merge`].
    pub fn from_json(v: &Json) -> Result<Self> {
        let mut s = Self::default();
        for (k, c) in v.get("counters")?.as_obj()? {
            s.counters.insert(k.clone(), c.as_f64()? as u64);
        }
        for (k, g) in v.get("gauges")?.as_obj()? {
            s.gauges.insert(
                k.clone(),
                GaugeCounts {
                    last: g.get("last")?.as_f64()? as u64,
                    max: g.get("max")?.as_f64()? as u64,
                },
            );
        }
        for (k, h) in v.get("histograms")?.as_obj()? {
            s.histograms.insert(
                k.clone(),
                HistogramCounts::from_json(h).with_context(|| format!("histogram {k:?}"))?,
            );
        }
        Ok(s)
    }
}

impl Merge for MetricsSnapshot {
    fn merge(&mut self, other: &Self) {
        for (k, v) in &other.counters {
            *self.counters.entry(k.clone()).or_insert(0) += v;
        }
        for (k, g) in &other.gauges {
            let e = self.gauges.entry(k.clone()).or_default();
            e.last = e.last.max(g.last);
            e.max = e.max.max(g.max);
        }
        for (k, h) in &other.histograms {
            self.histograms.entry(k.clone()).or_default().merge(h);
        }
    }

    fn diff(&self, earlier: &Self) -> Self {
        Self {
            counters: self
                .counters
                .iter()
                .map(|(k, &v)| {
                    (k.clone(), v.saturating_sub(earlier.counters.get(k).copied().unwrap_or(0)))
                })
                .collect(),
            // Gauges are not monotone: a delta keeps the later values.
            gauges: self.gauges.clone(),
            histograms: self
                .histograms
                .iter()
                .map(|(k, h)| {
                    (
                        k.clone(),
                        match earlier.histograms.get(k) {
                            Some(e) => h.diff(e),
                            None => h.clone(),
                        },
                    )
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::cache::CacheCounts;
    use crate::runtime::ServiceStats;

    #[test]
    fn bucket_boundaries_are_inclusive_upper_bounds() {
        // Values at a bound land in that bound's bucket; one past it spills
        // into the next; anything above the ladder lands in overflow.
        assert_eq!(Histogram::bucket_index(0), 0);
        assert_eq!(Histogram::bucket_index(1), 0);
        assert_eq!(Histogram::bucket_index(2), 1);
        assert_eq!(Histogram::bucket_index(3), 2);
        assert_eq!(Histogram::bucket_index(5), 2);
        assert_eq!(Histogram::bucket_index(6), 3);
        assert_eq!(Histogram::bucket_index(1_000), 9);
        assert_eq!(Histogram::bucket_index(1_001), 10);
        assert_eq!(Histogram::bucket_index(60_000_000), 23);
        assert_eq!(Histogram::bucket_index(60_000_001), 24);
        assert_eq!(Histogram::bucket_index(u64::MAX), 24);
    }

    #[test]
    fn histogram_counts_and_quantiles() {
        let h = Histogram::default();
        for v in [1u64, 1, 2, 10, 100, 1_000, 100_000] {
            h.record(v);
        }
        let c = h.counts();
        assert_eq!(c.count, 7);
        assert_eq!(c.sum, 101_114);
        assert_eq!(c.buckets[0], 2); // two 1s
        assert_eq!(c.buckets[1], 1); // the 2
        // p50 of 7 values = 4th smallest (10) -> its bucket bound 10.
        assert_eq!(c.p50(), 10.0);
        // p95 -> 7th value (100_000) -> bound 100_000.
        assert_eq!(c.p95(), 100_000.0);
        assert!((c.mean() - 101_114.0 / 7.0).abs() < 1e-9);
    }

    #[test]
    fn empty_histogram_quantiles_are_zero() {
        let c = HistogramCounts::default();
        assert_eq!(c.p50(), 0.0);
        assert_eq!(c.p95(), 0.0);
        assert_eq!(c.mean(), 0.0);
    }

    #[test]
    fn overflow_bucket_reports_finite_quantile() {
        let h = Histogram::default();
        h.record(u64::MAX);
        let c = h.counts();
        assert_eq!(c.p50(), 60_000_000.0);
        assert!(c.p50().is_finite());
    }

    #[test]
    fn merge_and_diff_are_fieldwise() {
        let mut a = ServiceStats { served: 10, evaluated: 4, cache_hits: 5, coalesced: 1 };
        let b = ServiceStats { served: 3, evaluated: 1, cache_hits: 2, coalesced: 0 };
        a.merge(&b);
        assert_eq!(a, ServiceStats { served: 13, evaluated: 5, cache_hits: 7, coalesced: 1 });
        let d = a.diff(&b);
        assert_eq!(d, ServiceStats { served: 10, evaluated: 4, cache_hits: 5, coalesced: 1 });

        let earlier = CacheCounts { hits: 1, misses: 2, persisted_hits: 1, preloaded: 4 };
        let merged_counts = merged([
            earlier,
            CacheCounts { hits: 10, misses: 20, persisted_hits: 3, preloaded: 0 },
        ]);
        assert_eq!(
            merged_counts,
            CacheCounts { hits: 11, misses: 22, persisted_hits: 4, preloaded: 4 }
        );
        assert_eq!(
            merged_counts.diff(&earlier),
            CacheCounts { hits: 10, misses: 20, persisted_hits: 3, preloaded: 0 }
        );
    }

    #[test]
    fn snapshot_diff_isolates_an_interval() {
        let m = Metrics::default();
        m.incr("snap_test_counter", 5);
        m.record("snap_test_hist", 100);
        let before = m.snapshot();
        m.incr("snap_test_counter", 2);
        m.record("snap_test_hist", 200);
        m.gauge_set("snap_test_gauge", 7);
        let delta = m.snapshot().diff(&before);
        assert_eq!(delta.counter("snap_test_counter"), 2);
        let h = delta.histogram("snap_test_hist").unwrap();
        assert_eq!(h.count, 1);
        assert_eq!(h.sum, 200);
        assert_eq!(delta.gauges["snap_test_gauge"].max, 7);
    }

    #[test]
    fn snapshot_json_round_trips_through_the_parser() {
        let m = Metrics::default();
        m.incr("json_test_counter", 3);
        m.record("json.test.hist", 42);
        m.gauge_set("json_test_gauge", 9);
        let text = m.snapshot().to_json().dumps();
        let back = Json::parse(&text).unwrap();
        assert_eq!(
            back.get("counters").unwrap().get("json_test_counter").unwrap().as_f64().unwrap(),
            3.0
        );
        assert_eq!(
            back.get("histograms").unwrap().get("json.test.hist").unwrap().get("count").unwrap()
                .as_f64()
                .unwrap(),
            1.0
        );
        // Lossless: buckets survive the round trip, so a re-parsed
        // snapshot is Merge-equivalent to the original.
        let snap = m.snapshot();
        let reparsed = MetricsSnapshot::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(reparsed, snap);
    }

    #[test]
    fn histogram_from_json_tolerates_missing_buckets_and_rejects_bad_arity() {
        let legacy = Json::parse("{\"count\":3,\"sum\":30}").unwrap();
        let h = HistogramCounts::from_json(&legacy).unwrap();
        assert_eq!((h.count, h.sum), (3, 30));
        assert_eq!(h.buckets, [0; N_BUCKETS]);
        let bad = Json::parse("{\"count\":1,\"sum\":1,\"buckets\":[1,2]}").unwrap();
        assert!(HistogramCounts::from_json(&bad).is_err());
    }

    // --- Merge algebra properties: the soundness basis for `trace merge`
    // and `trace diff`, which fold snapshots from many shards in whatever
    // order the CLI receives them. ---

    fn random_hist(rng: &mut crate::util::Rng) -> HistogramCounts {
        let mut h = HistogramCounts::default();
        for _ in 0..rng.range(0, 40) {
            // Spread values across the whole ladder including overflow.
            let v = 1u64 << rng.range(0, 40);
            h.buckets[Histogram::bucket_index(v)] += 1;
            h.count += 1;
            h.sum += v;
        }
        h
    }

    fn random_snapshot(rng: &mut crate::util::Rng) -> MetricsSnapshot {
        let mut s = MetricsSnapshot::default();
        for name in ["alpha", "beta", "gamma"] {
            if rng.chance(0.7) {
                s.counters.insert(name.into(), rng.below(1000));
            }
            if rng.chance(0.5) {
                let last = rng.below(100);
                s.gauges.insert(name.into(), GaugeCounts { last, max: last + rng.below(50) });
            }
            if rng.chance(0.7) {
                s.histograms.insert(name.into(), random_hist(rng));
            }
        }
        s
    }

    #[test]
    fn prop_histogram_merge_is_commutative_and_associative() {
        crate::util::prop::check("hist-merge-algebra", 64, |rng| {
            let (a, b, c) = (random_hist(rng), random_hist(rng), random_hist(rng));
            let mut ab = a.clone();
            ab.merge(&b);
            let mut ba = b.clone();
            ba.merge(&a);
            assert_eq!(ab, ba, "merge must commute");
            let mut ab_c = ab.clone();
            ab_c.merge(&c);
            let mut bc = b.clone();
            bc.merge(&c);
            let mut a_bc = a.clone();
            a_bc.merge(&bc);
            assert_eq!(ab_c, a_bc, "merge must associate");
        });
    }

    #[test]
    fn prop_snapshot_merge_is_commutative_and_associative() {
        crate::util::prop::check("snapshot-merge-algebra", 64, |rng| {
            let (a, b, c) = (random_snapshot(rng), random_snapshot(rng), random_snapshot(rng));
            let mut ab = a.clone();
            ab.merge(&b);
            let mut ba = b.clone();
            ba.merge(&a);
            assert_eq!(ab, ba, "merge must commute");
            let mut ab_c = ab.clone();
            ab_c.merge(&c);
            let mut bc = b.clone();
            bc.merge(&c);
            let mut a_bc = a.clone();
            a_bc.merge(&bc);
            assert_eq!(ab_c, a_bc, "merge must associate");
        });
    }

    #[test]
    fn prop_bucket_ladder_is_stable_across_merge_order() {
        // Recording values into one histogram, or partitioning them across
        // shards and merging the parts in any order, must land every value
        // in the same 1-2-5-ladder bucket and report identical quantiles.
        crate::util::prop::check("bucket-ladder-stability", 64, |rng| {
            let n = rng.range(1, 60);
            let values: Vec<u64> =
                (0..n).map(|_| rng.below(1u64 << rng.range(1, 40))).collect();
            let whole = Histogram::default();
            for &v in &values {
                whole.record(v);
            }
            let shards: Vec<Histogram> = (0..3).map(|_| Histogram::default()).collect();
            for &v in &values {
                shards[rng.range(0, 2)].record(v);
            }
            let mut parts: Vec<HistogramCounts> = shards.iter().map(|h| h.counts()).collect();
            rng.shuffle(&mut parts);
            let folded = merged(parts);
            assert_eq!(folded, whole.counts());
            assert_eq!(folded.p50(), whole.counts().p50());
            assert_eq!(folded.p95(), whole.counts().p95());
        });
    }
}
