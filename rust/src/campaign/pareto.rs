//! Cross-scenario Pareto archive: every committed campaign row is a point
//! in (embodied carbon, task delay, accuracy drop) space; the archive keeps
//! the non-dominated set across ALL scenarios plus per-node and
//! per-workload aggregate summaries. This is the campaign-level view the
//! single-run pipelines (fig2/fig3) cannot give: which (workload, node, δ)
//! corners the grid actually pays for.

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::util::{table, Json, Table};

/// One campaign result as an objective-space point (all minimized).
#[derive(Debug, Clone)]
pub struct ArchivePoint {
    pub key: String,
    pub model: String,
    pub node: String,
    pub mult: String,
    pub carbon_g: f64,
    pub delay_s: f64,
    pub drop_pct: f64,
    pub cdp: f64,
}

impl ArchivePoint {
    fn from_row(row: &Json) -> Result<Self> {
        let s = |k: &str| -> Result<String> {
            row.get(k).and_then(|v| v.as_str().map(str::to_string)).context(format!("field {k}"))
        };
        let f = |k: &str| -> Result<f64> {
            row.get(k).and_then(|v| v.as_f64()).context(format!("field {k}"))
        };
        Ok(Self {
            key: s("key")?,
            model: s("model")?,
            node: s("node")?,
            mult: s("mult")?,
            carbon_g: f("carbon_g")?,
            delay_s: f("delay_s")?,
            drop_pct: f("drop_pct")?,
            cdp: f("cdp")?,
        })
    }
}

/// 3-objective dominance (<= everywhere, < somewhere; minimize all).
fn dominates(a: &ArchivePoint, b: &ArchivePoint) -> bool {
    let le = a.carbon_g <= b.carbon_g && a.delay_s <= b.delay_s && a.drop_pct <= b.drop_pct;
    let lt = a.carbon_g < b.carbon_g || a.delay_s < b.delay_s || a.drop_pct < b.drop_pct;
    le && lt
}

/// Grouping axis for aggregate summaries.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GroupBy {
    Node,
    Model,
}

/// The archive: all points plus the indices of the cross-scenario front.
#[derive(Debug, Clone)]
pub struct CampaignArchive {
    pub points: Vec<ArchivePoint>,
    /// Indices into `points` on the (carbon, delay, drop) Pareto front,
    /// in store order.
    pub front: Vec<usize>,
}

impl CampaignArchive {
    /// Build from committed store rows.
    pub fn from_rows(rows: &[Json]) -> Result<Self> {
        let points: Vec<ArchivePoint> = rows
            .iter()
            .enumerate()
            .map(|(i, r)| ArchivePoint::from_row(r).with_context(|| format!("store row {}", i + 1)))
            .collect::<Result<_>>()?;
        let front = (0..points.len())
            .filter(|&i| {
                points
                    .iter()
                    .enumerate()
                    .all(|(j, other)| j == i || !dominates(other, &points[i]))
            })
            .collect();
        Ok(Self { points, front })
    }

    /// The cross-scenario Pareto front as a printable table.
    pub fn pareto_table(&self) -> Table {
        let mut t = Table::new(vec![
            "scenario", "mult", "carbon_g", "delay_ms", "drop_pp", "cdp",
        ]);
        for &i in &self.front {
            let p = &self.points[i];
            t.row(vec![
                p.key.clone(),
                p.mult.clone(),
                table::fmt(p.carbon_g),
                format!("{:.3}", p.delay_s * 1e3),
                format!("{:.2}", p.drop_pct),
                format!("{:.4}", p.cdp),
            ]);
        }
        t
    }

    /// Aggregate summary per node or per workload: scenario count, how many
    /// sit on the cross-scenario front, carbon/cdp extremes and means.
    pub fn aggregate_table(&self, by: GroupBy) -> Table {
        let label = match by {
            GroupBy::Node => "node",
            GroupBy::Model => "model",
        };
        let mut groups: BTreeMap<String, Vec<usize>> = BTreeMap::new();
        for (i, p) in self.points.iter().enumerate() {
            let g = match by {
                GroupBy::Node => p.node.clone(),
                GroupBy::Model => p.model.clone(),
            };
            groups.entry(g).or_default().push(i);
        }
        let mut t = Table::new(vec![
            label, "jobs", "on_front", "min_carbon_g", "mean_carbon_g", "best_cdp", "min_delay_ms",
        ]);
        for (g, idxs) in &groups {
            let carbons: Vec<f64> = idxs.iter().map(|&i| self.points[i].carbon_g).collect();
            let min_c = carbons.iter().cloned().fold(f64::INFINITY, f64::min);
            let mean_c = carbons.iter().sum::<f64>() / carbons.len() as f64;
            let best_cdp =
                idxs.iter().map(|&i| self.points[i].cdp).fold(f64::INFINITY, f64::min);
            let min_delay =
                idxs.iter().map(|&i| self.points[i].delay_s).fold(f64::INFINITY, f64::min);
            let on_front = idxs.iter().filter(|&&i| self.front.contains(&i)).count();
            t.row(vec![
                g.clone(),
                idxs.len().to_string(),
                on_front.to_string(),
                table::fmt(min_c),
                table::fmt(mean_c),
                format!("{:.4}", best_cdp),
                format!("{:.3}", min_delay * 1e3),
            ]);
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::obj;

    fn row(key: &str, model: &str, node: &str, c: f64, d: f64, a: f64) -> Json {
        obj([
            ("key", Json::from(key)),
            ("model", Json::from(model)),
            ("node", Json::from(node)),
            ("mult", Json::from("M")),
            ("carbon_g", Json::from(c)),
            ("delay_s", Json::from(d)),
            ("drop_pct", Json::from(a)),
            ("cdp", Json::from(c * d)),
        ])
    }

    #[test]
    fn front_excludes_dominated_points() {
        let rows = vec![
            row("a", "vgg16", "14nm", 10.0, 1.0, 1.0),
            row("b", "vgg16", "14nm", 12.0, 2.0, 1.5), // dominated by a
            row("c", "vgg16", "7nm", 8.0, 3.0, 1.0),   // trades delay for carbon
            row("d", "vgg16", "7nm", 11.0, 1.0, 0.5),  // trades carbon for drop
        ];
        let arch = CampaignArchive::from_rows(&rows).unwrap();
        assert_eq!(arch.front, vec![0, 2, 3]);
    }

    #[test]
    fn duplicate_points_both_survive() {
        // Equal points do not dominate each other (no strict improvement).
        let rows = vec![
            row("a", "m", "14nm", 1.0, 1.0, 1.0),
            row("b", "m", "14nm", 1.0, 1.0, 1.0),
        ];
        let arch = CampaignArchive::from_rows(&rows).unwrap();
        assert_eq!(arch.front.len(), 2);
    }

    #[test]
    fn aggregates_group_and_count() {
        let rows = vec![
            row("a", "vgg16", "14nm", 10.0, 1.0, 1.0),
            row("b", "resnet50", "14nm", 20.0, 2.0, 1.0),
            row("c", "vgg16", "7nm", 8.0, 3.0, 1.0),
        ];
        let arch = CampaignArchive::from_rows(&rows).unwrap();
        let t = arch.aggregate_table(GroupBy::Node);
        assert_eq!(t.n_rows(), 2); // 14nm, 7nm
        let t = arch.aggregate_table(GroupBy::Model);
        assert_eq!(t.n_rows(), 2); // vgg16, resnet50
    }

    #[test]
    fn missing_fields_error_with_row_number() {
        let rows = vec![obj([("key", Json::from("a"))])];
        let e = CampaignArchive::from_rows(&rows).unwrap_err();
        assert!(format!("{e:#}").contains("store row 1"), "{e:#}");
    }
}
