//! Deterministic fault injection for the campaign stack (DESIGN.md §11).
//!
//! A fault *plan* is a list of rules `site:nth:kind`: on the `nth` time
//! execution reaches the named site, inject the fault of the given
//! kind. Sites are the span-site names the observability layer already
//! established (`commit.row`, `store.append`, `checkpoint.write`, …) —
//! see [`SITES`]. Plans come from `CARBON3D_FAULTS` or `--fault-plan
//! file.json` and are armed once at campaign start.
//!
//! Kinds:
//!
//! - `crash` — `std::process::abort()` at the site (simulates SIGKILL /
//!   power loss). The process dies mid-operation; recovery is proven by
//!   resuming and byte-comparing against a fault-free run.
//! - `torn-write` — at buffer-write sites ([`write_all`]), write a
//!   prefix of the buffer, flush, then abort: a crash mid-`write(2)`.
//!   At non-buffer sites this escalates to `crash`.
//! - `io-error` — return an injected [`std::io::Error`] from the site,
//!   exercising the caller's retry/error path without killing the
//!   process. Because the per-site hit counter advances on every pass,
//!   an `nth`-scoped io-error fires exactly once and the retry then
//!   succeeds deterministically.
//! - `delay` — sleep a fixed 25 ms at the site (scheduling jitter).
//! - `panic` — `panic!` at the site; used to drive the poison-job
//!   quarantine (`job.eval` site) without touching evaluation code.
//!
//! Cost when disarmed: a single relaxed atomic load per site, no
//! allocation — the same budget as a disabled trace span, preserving
//! the traced-vs-untraced byte-identity and bench gates.
//!
//! Every injected fault emits a `fault.injected` obs event (counted in
//! the metrics registry even with tracing off) before it takes effect,
//! so chaos runs are auditable via `trace report` / `trace diff`.

use std::collections::BTreeMap;
use std::io::{self, Write};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Every instrumented fault site, in the order the chaos harness probes
/// them. Adding a site here is how it becomes chaos-tested.
pub const SITES: &[&str] = &[
    "store.append",
    "commit.row",
    "checkpoint.write",
    "mapcache.save",
    "status.write",
    "lease.claim",
    "lease.done",
    "surrogate.fit",
    "job.eval",
];

/// Fixed, jitterless retry backoff schedule used by [`retry_io`], in
/// milliseconds. Deterministic by construction: no randomness, no
/// wall-clock dependence in the decision to retry.
pub const RETRY_DELAYS_MS: [u64; 3] = [1, 5, 25];

/// What to inject when a rule fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Abort the process at the site.
    Crash,
    /// Write a partial buffer, flush, then abort (buffer sites only).
    TornWrite,
    /// Return an injected `io::Error` from the site.
    IoError,
    /// Sleep 25 ms at the site.
    Delay,
    /// `panic!` at the site (drives the quarantine path).
    Panic,
}

impl FaultKind {
    /// Parse the plan-syntax kind name.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "crash" => Self::Crash,
            "torn-write" => Self::TornWrite,
            "io-error" => Self::IoError,
            "delay" => Self::Delay,
            "panic" => Self::Panic,
            other => bail!(
                "unknown fault kind {other:?} (expected crash, torn-write, io-error, delay, panic)"
            ),
        })
    }

    /// The plan-syntax name, inverse of [`FaultKind::parse`].
    pub fn name(self) -> &'static str {
        match self {
            Self::Crash => "crash",
            Self::TornWrite => "torn-write",
            Self::IoError => "io-error",
            Self::Delay => "delay",
            Self::Panic => "panic",
        }
    }
}

/// One scheduled fault: fire `kind` on the `nth` (1-based) hit of
/// `site` in this process.
#[derive(Debug, Clone)]
pub struct FaultRule {
    /// Site name, one of [`SITES`] for plans that pass validation.
    pub site: String,
    /// 1-based hit ordinal at which the fault fires.
    pub nth: u64,
    /// What to inject.
    pub kind: FaultKind,
}

struct PlanState {
    rules: Vec<FaultRule>,
    hits: BTreeMap<String, u64>,
}

static ARMED: AtomicBool = AtomicBool::new(false);
static PLAN: Mutex<Option<PlanState>> = Mutex::new(None);

fn plan_lock() -> std::sync::MutexGuard<'static, Option<PlanState>> {
    PLAN.lock().unwrap_or_else(|e| e.into_inner())
}

/// Arm a fault plan for this process. Replaces any previous plan and
/// resets all hit counters. Rules are taken as-is (site names are
/// validated by the plan parsers, not here, so tests can use synthetic
/// sites).
pub fn arm(rules: Vec<FaultRule>) {
    let mut guard = plan_lock();
    *guard = Some(PlanState { rules, hits: BTreeMap::new() });
    ARMED.store(true, Ordering::Release);
}

/// Drop the active plan; sites go back to the single-atomic-load fast
/// path.
pub fn disarm() {
    let mut guard = plan_lock();
    *guard = None;
    ARMED.store(false, Ordering::Release);
}

/// Whether a fault plan is currently armed.
pub fn armed() -> bool {
    ARMED.load(Ordering::Relaxed)
}

/// Parse the compact plan syntax `site:nth:kind[,site:nth:kind...]`
/// (the `CARBON3D_FAULTS` format). Site names are validated against
/// [`SITES`] so typos fail loudly instead of silently never firing.
pub fn parse_plan(spec: &str) -> Result<Vec<FaultRule>> {
    let mut rules = Vec::new();
    for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
        let fields: Vec<&str> = part.split(':').collect();
        let [site, nth, kind] = fields[..] else {
            bail!("fault rule {part:?}: expected site:nth:kind");
        };
        if !SITES.contains(&site) {
            bail!("fault rule {part:?}: unknown site {site:?} (known: {})", SITES.join(", "));
        }
        let nth: u64 = nth.parse().with_context(|| format!("fault rule {part:?}: bad nth"))?;
        if nth == 0 {
            bail!("fault rule {part:?}: nth is 1-based");
        }
        rules.push(FaultRule { site: site.to_string(), nth, kind: FaultKind::parse(kind)? });
    }
    Ok(rules)
}

/// Parse a `--fault-plan` JSON document: `{"faults": [{"site": ...,
/// "nth": N, "kind": ...}, ...]}`.
pub fn plan_from_json(doc: &Json) -> Result<Vec<FaultRule>> {
    let faults = doc.get("faults").context("fault plan: no \"faults\" key")?.as_arr()?;
    let mut rules = Vec::new();
    for (i, f) in faults.iter().enumerate() {
        let ctx = || format!("fault plan entry {i}");
        let site = f.get("site").with_context(ctx)?.as_str()?.to_string();
        if !SITES.contains(&site.as_str()) {
            bail!("fault plan entry {i}: unknown site {site:?} (known: {})", SITES.join(", "));
        }
        let nth = f.get("nth").with_context(ctx)?.as_f64()? as u64;
        if nth == 0 {
            bail!("fault plan entry {i}: nth is 1-based");
        }
        let kind = FaultKind::parse(f.get("kind").with_context(ctx)?.as_str()?)?;
        rules.push(FaultRule { site, nth, kind });
    }
    Ok(rules)
}

/// Read a `--fault-plan` file and parse it.
pub fn load_plan_file(path: &std::path::Path) -> Result<Vec<FaultRule>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading fault plan {}", path.display()))?;
    plan_from_json(
        &Json::parse(&text).with_context(|| format!("fault plan {}", path.display()))?,
    )
}

/// Arm from the `CARBON3D_FAULTS` environment variable if set. Returns
/// whether a plan was armed.
pub fn arm_from_env() -> Result<bool> {
    match std::env::var("CARBON3D_FAULTS") {
        Ok(spec) if !spec.trim().is_empty() => {
            let rules = parse_plan(&spec).context("CARBON3D_FAULTS")?;
            arm(rules);
            Ok(true)
        }
        _ => Ok(false),
    }
}

/// Faults a caller must act on (the process-terminating kinds never
/// return from [`consume`]).
enum Injected {
    TornWrite,
    IoError,
}

fn fatal(site: &str, hit: u64, kind: &str) -> ! {
    eprintln!("fault: injected {kind} at {site} (hit {hit}) — aborting");
    std::process::abort();
}

/// Slow path: count the hit, fire a matching rule. Crash/delay/panic
/// are handled here; torn-write and io-error are returned for the site
/// to apply.
fn consume(site: &'static str) -> Option<Injected> {
    let (hit, rule) = {
        let mut guard = plan_lock();
        let state = guard.as_mut()?;
        let h = state.hits.entry(site.to_string()).or_insert(0);
        *h += 1;
        let hit = *h;
        let rule = state.rules.iter().find(|r| r.site == site && r.nth == hit)?.clone();
        (hit, rule)
    };
    crate::obs::event(
        "fault.injected",
        &[
            ("site", Json::from(site)),
            ("nth", Json::from(hit as f64)),
            ("kind", Json::from(rule.kind.name())),
        ],
    );
    match rule.kind {
        FaultKind::Crash => fatal(site, hit, "crash"),
        FaultKind::Delay => {
            std::thread::sleep(Duration::from_millis(25));
            None
        }
        FaultKind::Panic => panic!("fault: injected panic at {site} (hit {hit})"),
        FaultKind::TornWrite => Some(Injected::TornWrite),
        FaultKind::IoError => Some(Injected::IoError),
    }
}

fn injected_error(site: &str) -> io::Error {
    io::Error::other(format!("fault: injected io-error at {site}"))
}

/// A non-buffer fault site. Free when disarmed (one relaxed atomic
/// load). `crash`/`delay`/`panic` take effect inside; `io-error` is
/// returned; `torn-write` escalates to `crash` (there is no buffer to
/// tear).
#[inline]
pub fn point(site: &'static str) -> io::Result<()> {
    if !ARMED.load(Ordering::Relaxed) {
        return Ok(());
    }
    point_slow(site)
}

#[cold]
fn point_slow(site: &'static str) -> io::Result<()> {
    match consume(site) {
        None => Ok(()),
        Some(Injected::IoError) => Err(injected_error(site)),
        Some(Injected::TornWrite) => fatal(site, 0, "torn-write (escalated to crash)"),
    }
}

/// A buffer-write fault site: `w.write_all(buf)` with fault injection.
/// `torn-write` writes a prefix of `buf`, flushes, and aborts —
/// simulating a crash mid-`write(2)` that leaves a torn tail for the
/// reopen path to recover.
#[inline]
pub fn write_all(site: &'static str, w: &mut dyn Write, buf: &[u8]) -> io::Result<()> {
    if !ARMED.load(Ordering::Relaxed) {
        return w.write_all(buf);
    }
    write_all_slow(site, w, buf)
}

#[cold]
fn write_all_slow(site: &'static str, w: &mut dyn Write, buf: &[u8]) -> io::Result<()> {
    match consume(site) {
        None => w.write_all(buf),
        Some(Injected::IoError) => Err(injected_error(site)),
        Some(Injected::TornWrite) => {
            let keep = buf.len() / 2;
            let _ = w.write_all(&buf[..keep]);
            let _ = w.flush();
            fatal(site, 0, "torn-write");
        }
    }
}

/// Run a fallible IO operation with the fixed [`RETRY_DELAYS_MS`]
/// backoff schedule. Each retry bumps the `io_retries` counter (and
/// event); exhausting the schedule bumps `io_gave_up`, warns on stderr,
/// and returns the last error. Safe for operations that are atomic or
/// idempotent (temp+rename writes, full-buffer appends that wrote
/// nothing on failure).
pub fn retry_io<T, E: std::fmt::Display>(
    site: &'static str,
    mut op: impl FnMut() -> std::result::Result<T, E>,
) -> std::result::Result<T, E> {
    let mut last = match op() {
        Ok(v) => return Ok(v),
        Err(e) => e,
    };
    for &ms in RETRY_DELAYS_MS.iter() {
        crate::obs::event(
            "io_retries",
            &[("site", Json::from(site)), ("error", Json::from(format!("{last}").as_str()))],
        );
        std::thread::sleep(Duration::from_millis(ms));
        match op() {
            Ok(v) => return Ok(v),
            Err(e) => last = e,
        }
    }
    crate::obs::warn_event(
        "io_gave_up",
        &format!("io: giving up at {site} after {} retries: {last}", RETRY_DELAYS_MS.len()),
        &[("site", Json::from(site))],
    );
    Err(last)
}

/// Serializes tests that arm the process-global fault plan (cargo runs
/// one binary's tests concurrently in one process).
#[cfg(test)]
pub(crate) fn test_guard() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Extract a human-readable message from a caught panic payload.
pub fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "panic with non-string payload".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    use super::test_guard as fault_test_guard;
    use crate::obs::Merge as _;

    #[test]
    fn plan_syntax_round_trips_and_rejects_garbage() {
        let rules = parse_plan("store.append:3:io-error, lease.claim:1:delay").unwrap();
        assert_eq!(rules.len(), 2);
        assert_eq!(rules[0].site, "store.append");
        assert_eq!(rules[0].nth, 3);
        assert_eq!(rules[0].kind, FaultKind::IoError);
        assert_eq!(rules[1].kind, FaultKind::Delay);
        assert!(parse_plan("store.append:3").is_err(), "missing kind");
        assert!(parse_plan("no.such.site:1:crash").is_err(), "unknown site");
        assert!(parse_plan("store.append:0:crash").is_err(), "nth is 1-based");
        assert!(parse_plan("store.append:1:explode").is_err(), "unknown kind");
        assert!(parse_plan("").unwrap().is_empty());
    }

    #[test]
    fn json_plan_parses_and_validates() {
        let doc = Json::parse(
            r#"{"faults":[{"site":"commit.row","nth":2,"kind":"crash"},
                          {"site":"job.eval","nth":1,"kind":"panic"}]}"#,
        )
        .unwrap();
        let rules = plan_from_json(&doc).unwrap();
        assert_eq!(rules.len(), 2);
        assert_eq!(rules[0].kind, FaultKind::Crash);
        assert_eq!(rules[1].kind, FaultKind::Panic);
        let bad = Json::parse(r#"{"faults":[{"site":"nope","nth":1,"kind":"crash"}]}"#).unwrap();
        assert!(plan_from_json(&bad).is_err());
    }

    #[test]
    fn io_error_fires_on_exactly_the_nth_hit() {
        let _guard = fault_test_guard();
        arm(vec![FaultRule { site: "t.nth".into(), nth: 3, kind: FaultKind::IoError }]);
        assert!(point("t.nth").is_ok(), "hit 1");
        assert!(point("t.nth").is_ok(), "hit 2");
        let err = point("t.nth").unwrap_err();
        assert!(err.to_string().contains("injected io-error"), "{err}");
        assert!(point("t.nth").is_ok(), "hit 4: rule already consumed");
        disarm();
        assert!(point("t.nth").is_ok(), "disarmed");
    }

    #[test]
    fn write_all_injects_io_error_without_touching_the_sink() {
        let _guard = fault_test_guard();
        arm(vec![FaultRule { site: "t.write".into(), nth: 1, kind: FaultKind::IoError }]);
        let mut sink = Vec::new();
        assert!(write_all("t.write", &mut sink, b"payload").is_err());
        assert!(sink.is_empty(), "io-error must fire before any bytes land");
        assert!(write_all("t.write", &mut sink, b"payload").is_ok());
        assert_eq!(sink, b"payload");
        disarm();
    }

    #[test]
    fn panic_kind_panics_and_is_catchable() {
        let _guard = fault_test_guard();
        arm(vec![FaultRule { site: "t.panic".into(), nth: 1, kind: FaultKind::Panic }]);
        let caught =
            std::panic::catch_unwind(|| point("t.panic").unwrap()).expect_err("must panic");
        assert!(panic_message(&*caught).contains("injected panic at t.panic"));
        disarm();
    }

    #[test]
    fn retry_recovers_from_a_single_injected_error_and_counts_it() {
        let _guard = fault_test_guard();
        arm(vec![FaultRule { site: "t.retry".into(), nth: 1, kind: FaultKind::IoError }]);
        let before = crate::obs::metrics().snapshot();
        let v = retry_io("t.retry", || point("t.retry").map(|()| 42)).unwrap();
        assert_eq!(v, 42);
        let delta = crate::obs::metrics().snapshot().diff(&before);
        assert_eq!(delta.counter("io_retries"), 1);
        assert_eq!(delta.counter("io_gave_up"), 0);
        assert_eq!(delta.counter("fault.injected"), 1);
        disarm();
    }

    #[test]
    fn retry_gives_up_after_the_fixed_schedule() {
        let _guard = fault_test_guard();
        disarm();
        let before = crate::obs::metrics().snapshot();
        let mut calls = 0u64;
        let err = retry_io("t.giveup", || -> io::Result<()> {
            calls += 1;
            Err(io::Error::other("persistent"))
        })
        .unwrap_err();
        assert_eq!(calls, 1 + RETRY_DELAYS_MS.len() as u64);
        assert!(err.to_string().contains("persistent"));
        let delta = crate::obs::metrics().snapshot().diff(&before);
        assert_eq!(delta.counter("io_retries"), RETRY_DELAYS_MS.len() as u64);
        assert_eq!(delta.counter("io_gave_up"), 1);
    }

    #[test]
    fn disarmed_sites_are_free_and_infallible() {
        let _guard = fault_test_guard();
        disarm();
        assert!(!armed());
        for site in SITES {
            // &'static str via SITES entries.
            assert!(point(site).is_ok());
        }
        let mut sink = Vec::new();
        assert!(write_all("store.append", &mut sink, b"x").is_ok());
        assert_eq!(sink, b"x");
    }
}
