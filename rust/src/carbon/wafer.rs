//! Wafer geometry: dies-per-wafer and dicing waste (Eq. (2)'s A_wasted).

/// Standard 300mm production wafer.
pub const WAFER_DIAMETER_MM: f64 = 300.0;
/// Edge exclusion ring (unusable rim).
pub const EDGE_EXCLUSION_MM: f64 = 3.0;
/// Saw-street (kerf) width between dies.
pub const KERF_MM: f64 = 0.1;

/// Usable wafer area, mm^2.
pub fn usable_wafer_area_mm2() -> f64 {
    let r = WAFER_DIAMETER_MM / 2.0 - EDGE_EXCLUSION_MM;
    std::f64::consts::PI * r * r
}

/// Gross dies per wafer for a square-ish die of `die_area_mm2`.
/// Uses the standard DPW formula with edge-loss correction:
///   DPW = pi*r^2/A - pi*d/sqrt(2A)
pub fn dies_per_wafer(die_area_mm2: f64) -> f64 {
    assert!(die_area_mm2 > 0.0, "dies_per_wafer: non-positive area");
    let side = die_area_mm2.sqrt() + KERF_MM;
    let a = side * side;
    let d = WAFER_DIAMETER_MM - 2.0 * EDGE_EXCLUSION_MM;
    let dpw = std::f64::consts::PI * d * d / (4.0 * a)
        - std::f64::consts::PI * d / (2.0 * a).sqrt();
    dpw.max(1.0)
}

/// Wasted silicon attributed to each die (Eq. (2)'s A_wasted / DPW):
/// the unused wafer area (edge partials + kerf) divided among good dies.
pub fn wasted_area_per_die_mm2(die_area_mm2: f64) -> f64 {
    let dpw = dies_per_wafer(die_area_mm2);
    let used = dpw * die_area_mm2;
    ((usable_wafer_area_mm2() - used) / dpw).max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn small_dies_yield_many_per_wafer() {
        // 10mm^2 die on 300mm wafer: several thousand dies.
        let dpw = dies_per_wafer(10.0);
        assert!((3000.0..7000.0).contains(&dpw), "dpw {dpw}");
    }

    #[test]
    fn dpw_decreases_with_die_area() {
        let mut prev = f64::INFINITY;
        for a in [5.0, 20.0, 80.0, 320.0] {
            let dpw = dies_per_wafer(a);
            assert!(dpw < prev);
            prev = dpw;
        }
    }

    #[test]
    fn waste_fraction_grows_for_large_dies() {
        // Larger dies waste proportionally more of the wafer (edge partials).
        let frac = |a: f64| wasted_area_per_die_mm2(a) / a;
        assert!(frac(400.0) > frac(10.0));
    }

    #[test]
    fn used_area_below_wafer_area_prop() {
        prop::check("wafer-conservation", 60, |rng| {
            let a = rng.uniform(1.0, 600.0);
            let used = dies_per_wafer(a) * a;
            assert!(
                used <= usable_wafer_area_mm2() * 1.001,
                "area {a}: used {used} exceeds wafer"
            );
            assert!(wasted_area_per_die_mm2(a) >= 0.0);
        });
    }
}
