//! DNN layer IR: shapes and arithmetic/traffic footprints.

/// Data word size: bfloat16 everywhere (paper §III-C).
pub const WORD_BYTES: usize = 2;

/// One layer of a DNN workload.
#[derive(Debug, Clone, PartialEq)]
pub struct Layer {
    pub name: String,
    pub kind: LayerKind,
}

/// Layer types the mapper understands.
#[derive(Debug, Clone, PartialEq)]
pub enum LayerKind {
    /// 2-D convolution, NHWC, 'same'-style padding already folded into
    /// out_h/out_w.
    Conv {
        in_h: usize,
        in_w: usize,
        in_c: usize,
        out_c: usize,
        kh: usize,
        kw: usize,
        stride: usize,
    },
    /// Fully connected.
    Fc { in_f: usize, out_f: usize },
    /// Pooling (no MACs; memory traffic only).
    Pool { in_h: usize, in_w: usize, in_c: usize, k: usize, stride: usize },
    /// Elementwise residual add (ResNet) / concat bookkeeping (DenseNet):
    /// pure memory traffic.
    Eltwise { h: usize, w: usize, c: usize },
}

impl Layer {
    pub fn conv(
        name: &str,
        in_h: usize,
        in_w: usize,
        in_c: usize,
        out_c: usize,
        k: usize,
        stride: usize,
    ) -> Self {
        Layer {
            name: name.to_string(),
            kind: LayerKind::Conv { in_h, in_w, in_c, out_c, kh: k, kw: k, stride },
        }
    }

    pub fn fc(name: &str, in_f: usize, out_f: usize) -> Self {
        Layer { name: name.to_string(), kind: LayerKind::Fc { in_f, out_f } }
    }

    pub fn pool(name: &str, in_h: usize, in_w: usize, in_c: usize, k: usize, stride: usize) -> Self {
        Layer { name: name.to_string(), kind: LayerKind::Pool { in_h, in_w, in_c, k, stride } }
    }

    pub fn eltwise(name: &str, h: usize, w: usize, c: usize) -> Self {
        Layer { name: name.to_string(), kind: LayerKind::Eltwise { h, w, c } }
    }

    /// Output spatial/channel shape.
    pub fn out_shape(&self) -> (usize, usize, usize) {
        match self.kind {
            LayerKind::Conv { in_h, in_w, out_c, stride, .. } => {
                (in_h.div_ceil(stride), in_w.div_ceil(stride), out_c)
            }
            LayerKind::Fc { out_f, .. } => (1, 1, out_f),
            LayerKind::Pool { in_h, in_w, in_c, stride, .. } => {
                (in_h / stride, in_w / stride, in_c)
            }
            LayerKind::Eltwise { h, w, c } => (h, w, c),
        }
    }

    /// Multiply-accumulate count.
    pub fn macs(&self) -> u64 {
        match self.kind {
            LayerKind::Conv { in_c, out_c, kh, kw, .. } => {
                let (oh, ow, _) = self.out_shape();
                (oh * ow * out_c * kh * kw * in_c) as u64
            }
            LayerKind::Fc { in_f, out_f } => (in_f * out_f) as u64,
            LayerKind::Pool { .. } | LayerKind::Eltwise { .. } => 0,
        }
    }

    /// Weight footprint, bytes.
    pub fn weight_bytes(&self) -> usize {
        match self.kind {
            LayerKind::Conv { in_c, out_c, kh, kw, .. } => kh * kw * in_c * out_c * WORD_BYTES,
            LayerKind::Fc { in_f, out_f } => in_f * out_f * WORD_BYTES,
            _ => 0,
        }
    }

    /// Input feature-map footprint, bytes.
    pub fn ifmap_bytes(&self) -> usize {
        match self.kind {
            LayerKind::Conv { in_h, in_w, in_c, .. } => in_h * in_w * in_c * WORD_BYTES,
            LayerKind::Fc { in_f, .. } => in_f * WORD_BYTES,
            LayerKind::Pool { in_h, in_w, in_c, .. } => in_h * in_w * in_c * WORD_BYTES,
            LayerKind::Eltwise { h, w, c } => 2 * h * w * c * WORD_BYTES,
        }
    }

    /// Output feature-map footprint, bytes.
    pub fn ofmap_bytes(&self) -> usize {
        let (oh, ow, oc) = self.out_shape();
        oh * ow * oc * WORD_BYTES
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_macs_hand_check() {
        // 3x3 conv, 224x224x3 -> 64, stride 1: 224*224*64*3*3*3
        let l = Layer::conv("c", 224, 224, 3, 64, 3, 1);
        assert_eq!(l.macs(), 224 * 224 * 64 * 9 * 3);
        assert_eq!(l.weight_bytes(), 3 * 3 * 3 * 64 * 2);
    }

    #[test]
    fn strided_conv_shrinks_output() {
        let l = Layer::conv("c", 224, 224, 3, 64, 7, 2);
        assert_eq!(l.out_shape(), (112, 112, 64));
    }

    #[test]
    fn fc_macs() {
        let l = Layer::fc("fc", 4096, 1000);
        assert_eq!(l.macs(), 4096 * 1000);
        assert_eq!(l.ifmap_bytes(), 4096 * 2);
        assert_eq!(l.ofmap_bytes(), 1000 * 2);
    }

    #[test]
    fn pool_has_no_macs() {
        let l = Layer::pool("p", 112, 112, 64, 2, 2);
        assert_eq!(l.macs(), 0);
        assert_eq!(l.out_shape(), (56, 56, 64));
    }

    #[test]
    fn eltwise_reads_two_operands() {
        let l = Layer::eltwise("add", 56, 56, 256);
        assert_eq!(l.ifmap_bytes(), 2 * 56 * 56 * 256 * 2);
    }
}
