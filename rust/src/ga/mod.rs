//! Genetic-algorithm design-space exploration (paper §III-E).
//!
//! Chromosome C = {Px, Py, B_local, B_global} (Eq. 6) + the approximate
//! multiplier id; fitness = Carbon-Delay-Product CDP = C_embodied * D_task,
//! with an optional FPS floor handled as a multiplicative penalty. The
//! multiplier gene is restricted to the set that satisfies the accuracy-drop
//! constraint ΔA(M) <= δ (Eq. 7), established *before* the search from
//! ApproxTrain-style simulation (here: the measured tiny-CNN table or the
//! MRED-calibrated model — see `accuracy/`).

pub mod chromosome;
pub mod engine;
pub mod fitness;
pub mod islands;
pub mod nsga;

pub use chromosome::{Chromosome, SearchSpace};
pub use engine::{Ga, GaParams, GaResult};
pub use islands::{run_islands, run_islands_shared, IslandParams};
pub use fitness::{
    cdp, evaluate, evaluate_objective, evaluate_objective_cached, EvalShares, Evaluation,
    FitnessCtx, Objective,
};
pub use nsga::{crowding_distance, non_dominated_sort, pareto_front};
