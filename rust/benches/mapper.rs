//! Bench MAPPER: the nn-dataflow-stand-in hot path — per-layer and
//! per-network mapping cost for every workload, 2D vs 3D.
//!
//! This is the innermost loop of every GA fitness evaluation, so its cost
//! bounds the whole DSE (and thereby campaign throughput; see
//! benches/campaign.rs).

use carbon3d::approx::EXACT_ID;
use carbon3d::area::die::Integration;
use carbon3d::area::TechNode;
use carbon3d::dataflow::arch::AccelConfig;
use carbon3d::dataflow::mapper::map_network;
use carbon3d::dataflow::workloads::{workload, workload_names};
use carbon3d::obs::bench::bench;

fn cfg(integration: Integration) -> AccelConfig {
    AccelConfig {
        px: 32,
        py: 32,
        rf_bytes: 128,
        sram_bytes: 512 << 10,
        node: TechNode::N14,
        integration,
        mult_id: EXACT_ID,
    }
}

fn main() {
    println!("== MAPPER benches (GA inner loop) ==");
    for name in workload_names() {
        let w = workload(name).unwrap();
        let c = cfg(Integration::ThreeD);
        let res = bench(
            &format!("map_network {name} ({} layers, 3D)", w.layers.len()),
            3,
            50,
            || map_network(&w, &c),
        );
        println!("{}", res.line());
    }
    let w = workload("vgg16").unwrap();
    let c2 = cfg(Integration::TwoD);
    let res = bench("map_network vgg16 (2D NoC)", 3, 50, || map_network(&w, &c2));
    println!("{}", res.line());

    // Sanity: print the mapped fps so the bench doubles as a smoke check.
    let c3 = cfg(Integration::ThreeD);
    let m = map_network(&w, &c3);
    println!(
        "vgg16@14nm 32x32 3D: {:.1} fps, utilization {:.2}",
        m.fps(&c3),
        m.mean_utilization()
    );
}
