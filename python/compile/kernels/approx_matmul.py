"""L1 Pallas kernel: LUT-based approximate bfloat16 matmul.

Emulates the paper's approximate MAC datapath (exact sign/exponent/accumulate,
approximate 8x8 significand multiplier via a 128x128 LUT) as a tiled Pallas
kernel. `interpret=True` is mandatory on this CPU-only image — real-TPU
lowering would emit a Mosaic custom-call the CPU PJRT plugin cannot execute.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the LUT is the stationary
operand (constant BlockSpec index_map → resident in VMEM across grid steps,
mirroring Eyeriss's weight-stationary register file); output is gridded over
(M/bm, N/bn) tiles with the full K panel streamed per program instance; the
accumulator lives in f32 (TPU-native bf16xbf16→f32, and the paper's exact
24-bit accumulator).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _kernel(a_ref, b_ref, lut_ref, o_ref, *, block_k: int):
    """One (bm, bn) output tile: accumulate LUT outer products over K."""
    lut = lut_ref[...]

    def body(kk, acc):
        a = a_ref[:, pl.dslice(kk * block_k, block_k)]      # [bm, bk]
        b = b_ref[pl.dslice(kk * block_k, block_k), :]      # [bk, bn]
        sa, ea, ma = ref.decompose(a)
        sb, eb, mb = ref.decompose(b)
        # Gather the approximate significand products for every (m,k)x(k,n)
        # pair of this K-slab: [bm, bk, bn].
        sig = lut[ma[:, :, None], mb[None, :, :]]
        scale = ref.pow2_exact((ea[:, :, None] + eb[None, :, :]).astype(jnp.int32) - 268)
        prod = (sa[:, :, None] * sb[None, :, :]) * (sig * scale)
        nonzero = (ea[:, :, None] > 0) & (eb[None, :, :] > 0)
        prod = jnp.where(nonzero, prod, 0.0)
        return acc + jnp.sum(prod, axis=1)

    nk = a_ref.shape[1] // block_k
    acc = jnp.zeros(o_ref.shape, jnp.float32)
    o_ref[...] = jax.lax.fori_loop(0, nk, body, acc)


def approx_matmul(
    a: jnp.ndarray,
    b: jnp.ndarray,
    lut: jnp.ndarray,
    *,
    block_m: int = 32,
    block_n: int = 32,
    block_k: int = 32,
) -> jnp.ndarray:
    """[M,K] x [K,N] approximate bf16 matmul with f32 accumulation.

    M, N, K must be divisible by the respective block sizes (callers pad).
    `lut` is f32[128,128]: significand products of the approximate multiplier,
    indexed by the two 7-bit stored mantissas. A *runtime input*, so one AOT
    artifact serves every multiplier in the library.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (
        f"shape ({m},{k},{n}) not divisible by blocks ({block_m},{block_k},{block_n})"
    )
    assert lut.shape == (128, 128)

    grid = (m // block_m, n // block_n)
    return pl.pallas_call(
        functools.partial(_kernel, block_k=block_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, block_n), lambda i, j: (0, j)),
            pl.BlockSpec((128, 128), lambda i, j: (0, 0)),  # stationary LUT
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,  # CPU-only image; Mosaic lowering unavailable
    )(a.astype(jnp.float32), b.astype(jnp.float32), lut.astype(jnp.float32))


def pad_to(x: jnp.ndarray, mult_r: int, mult_c: int) -> jnp.ndarray:
    """Zero-pad a 2-D array so both dims are multiples of the given blocks.
    Zero rows/cols contribute exactly zero under the flush-to-zero datapath.

    Pads via the lax.pad primitive, NOT jnp.pad (lowers through an HLO
    `call`) and NOT zero-concat (materializes large zero constants): both
    corrupt the xla_extension 0.5.1 HLO-text round-trip used by the Rust
    runtime (see model._pad_same and aot.export)."""
    r, c = x.shape
    pr = (-r) % mult_r
    pc = (-c) % mult_c
    if pr == 0 and pc == 0:
        return x
    return jax.lax.pad(
        x.astype(jnp.float32), jnp.float32(0), [(0, pr, 0), (0, pc, 0)]
    )


def approx_matmul_padded(
    a: jnp.ndarray, b: jnp.ndarray, lut: jnp.ndarray, **kw
) -> jnp.ndarray:
    """approx_matmul for arbitrary shapes: pad inputs, crop the result."""
    m, k = a.shape
    _, n = b.shape
    bm = kw.get("block_m", 32)
    bn = kw.get("block_n", 32)
    bk = kw.get("block_k", 32)
    ap = pad_to(a, bm, bk)
    bp = pad_to(b, bk, bn)
    out = approx_matmul(ap, bp, lut, **kw)
    return out[:m, :n]
