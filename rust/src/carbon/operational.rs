//! Operational carbon + lifetime totals (the §II discussion around [17]:
//! embodied and operational emissions live on different scales and the
//! paper therefore optimizes embodied carbon; this module quantifies the
//! comparison for our reproduction instead of asserting it).

use crate::area::TechNode;
use crate::dataflow::arch::AccelConfig;
use crate::dataflow::energy::EnergyModel;
use crate::dataflow::mapper::NetworkMapping;
use crate::approx::Multiplier;

/// Grid carbon intensity at the *deployment* site, kgCO2/kWh (world-average
/// edge deployment; the fab's CI is a separate constant in `super`).
pub const CI_USE_KGCO2_PER_KWH: f64 = 0.4;

/// Device lifetime assumptions for edge AI (ACT-style): 3 years, duty-cycled
/// inference.
pub const LIFETIME_YEARS: f64 = 3.0;

/// Operational-carbon summary for a deployment scenario.
#[derive(Debug, Clone, Copy)]
pub struct OperationalCarbon {
    pub energy_per_inference_j: f64,
    pub inferences_per_day: f64,
    pub lifetime_kwh: f64,
    pub lifetime_gco2: f64,
}

/// Operational carbon over the device lifetime at a given inference rate.
pub fn operational_carbon(
    cfg: &AccelConfig,
    mult: &Multiplier,
    mapping: &NetworkMapping,
    inferences_per_day: f64,
) -> OperationalCarbon {
    let em = EnergyModel::for_config(cfg, mult);
    let e_inf = em.network_energy_j(mapping);
    let days = LIFETIME_YEARS * 365.0;
    let lifetime_j = e_inf * inferences_per_day * days;
    let lifetime_kwh = lifetime_j / 3.6e6;
    OperationalCarbon {
        energy_per_inference_j: e_inf,
        inferences_per_day,
        lifetime_kwh,
        lifetime_gco2: lifetime_kwh * CI_USE_KGCO2_PER_KWH * 1000.0,
    }
}

/// Embodied share of the lifetime total: the paper's edge-device motivation
/// is that this is large.
pub fn embodied_share(embodied_g: f64, operational: &OperationalCarbon) -> f64 {
    embodied_g / (embodied_g + operational.lifetime_gco2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::area::die::Integration;
    use crate::approx::{library, EXACT_ID};
    use crate::carbon::embodied_carbon;
    use crate::dataflow::mapper::map_network;
    use crate::dataflow::workloads::workload;

    fn setup() -> (AccelConfig, NetworkMapping) {
        let cfg = AccelConfig {
            px: 32,
            py: 32,
            rf_bytes: 128,
            sram_bytes: 512 << 10,
            node: TechNode::N7,
            integration: Integration::ThreeD,
            mult_id: EXACT_ID,
        };
        let w = workload("resnet50").unwrap();
        let m = map_network(&w, &cfg);
        (cfg, m)
    }

    #[test]
    fn lifetime_scales_linearly_with_rate() {
        let lib = library();
        let (cfg, m) = setup();
        let a = operational_carbon(&cfg, &lib[EXACT_ID], &m, 1000.0);
        let b = operational_carbon(&cfg, &lib[EXACT_ID], &m, 2000.0);
        assert!((b.lifetime_gco2 / a.lifetime_gco2 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn embodied_dominates_light_duty_edge_devices() {
        // The paper's §I premise: for duty-cycled edge inference, embodied
        // carbon is a significant (often dominant) share.
        let lib = library();
        let (cfg, m) = setup();
        let areas = cfg.die_areas(&lib[EXACT_ID]);
        let emb = embodied_carbon(&areas, cfg.node, cfg.integration).total_g();
        // 10k inferences/day (a few per second duty-cycled).
        let op = operational_carbon(&cfg, &lib[EXACT_ID], &m, 10_000.0);
        let share = embodied_share(emb, &op);
        assert!(share > 0.25, "embodied share {share} (emb {emb} g vs op {} g)", op.lifetime_gco2);
    }

    #[test]
    fn heavy_duty_flips_toward_operational() {
        let lib = library();
        let (cfg, m) = setup();
        let areas = cfg.die_areas(&lib[EXACT_ID]);
        let emb = embodied_carbon(&areas, cfg.node, cfg.integration).total_g();
        let light = operational_carbon(&cfg, &lib[EXACT_ID], &m, 1_000.0);
        let heavy = operational_carbon(&cfg, &lib[EXACT_ID], &m, 3_000_000.0);
        assert!(embodied_share(emb, &light) > embodied_share(emb, &heavy));
        assert!(embodied_share(emb, &heavy) < 0.5);
    }

    #[test]
    fn approx_mult_cuts_operational_energy_too() {
        let lib = library();
        let (mut cfg, m) = setup();
        let t2p3 = lib.iter().find(|x| x.name() == "T2P3").unwrap();
        let exact = operational_carbon(&cfg, &lib[EXACT_ID], &m, 10_000.0);
        cfg.mult_id = t2p3.id;
        let appx = operational_carbon(&cfg, t2p3, &m, 10_000.0);
        assert!(appx.energy_per_inference_j < exact.energy_per_inference_j);
    }
}
