//! Chromosome encoding and the bounded search space.

use crate::util::Rng;

/// Discrete search space: every gene takes values from an explicit menu, as
/// in the paper (PE dims in powers of two, buffer capacities in binary
/// steps, multipliers from the accuracy-feasible set).
#[derive(Debug, Clone)]
pub struct SearchSpace {
    pub px: Vec<usize>,
    pub py: Vec<usize>,
    pub rf_bytes: Vec<usize>,
    pub sram_bytes: Vec<usize>,
    /// Multiplier ids satisfying the accuracy constraint (Eq. 7).
    pub mult_ids: Vec<usize>,
}

impl SearchSpace {
    /// The paper-scale space: 8..64 per array dimension, 64B..1KB local
    /// buffers (Eyeriss-class register files), 256KB..8MB global SRAM.
    pub fn standard(mult_ids: Vec<usize>) -> Self {
        assert!(!mult_ids.is_empty(), "empty feasible-multiplier set");
        Self {
            px: vec![8, 16, 24, 32, 48, 64],
            py: vec![8, 16, 24, 32, 48, 64],
            rf_bytes: vec![64, 128, 256, 512, 1024],
            sram_bytes: vec![128 << 10, 256 << 10, 512 << 10, 1 << 20, 2 << 20, 4 << 20],
            mult_ids,
        }
    }

    /// Total number of configurations.
    pub fn cardinality(&self) -> usize {
        self.px.len() * self.py.len() * self.rf_bytes.len() * self.sram_bytes.len()
            * self.mult_ids.len()
    }

    /// Random chromosome.
    pub fn sample(&self, rng: &mut Rng) -> Chromosome {
        Chromosome {
            px: *rng.choice(&self.px),
            py: *rng.choice(&self.py),
            rf_bytes: *rng.choice(&self.rf_bytes),
            sram_bytes: *rng.choice(&self.sram_bytes),
            mult_id: *rng.choice(&self.mult_ids),
        }
    }

    /// Check membership (every gene on its menu).
    pub fn contains(&self, c: &Chromosome) -> bool {
        self.px.contains(&c.px)
            && self.py.contains(&c.py)
            && self.rf_bytes.contains(&c.rf_bytes)
            && self.sram_bytes.contains(&c.sram_bytes)
            && self.mult_ids.contains(&c.mult_id)
    }

    /// Mutate one random gene to a neighboring menu value (local move) or a
    /// random value (jump), 70/30.
    pub fn mutate(&self, c: &Chromosome, rng: &mut Rng) -> Chromosome {
        let mut out = c.clone();
        let gene = rng.below(5);
        let pick = |menu: &[usize], cur: usize, rng: &mut Rng| -> usize {
            let idx = menu.iter().position(|&v| v == cur).unwrap_or(0);
            if rng.chance(0.7) && menu.len() > 1 {
                // step to a neighbor
                let dir: isize = if rng.chance(0.5) { 1 } else { -1 };
                let j = (idx as isize + dir).clamp(0, menu.len() as isize - 1) as usize;
                menu[j]
            } else {
                *rng.choice(menu)
            }
        };
        match gene {
            0 => out.px = pick(&self.px, c.px, rng),
            1 => out.py = pick(&self.py, c.py, rng),
            2 => out.rf_bytes = pick(&self.rf_bytes, c.rf_bytes, rng),
            3 => out.sram_bytes = pick(&self.sram_bytes, c.sram_bytes, rng),
            _ => out.mult_id = pick(&self.mult_ids, c.mult_id, rng),
        }
        out
    }
}

/// One candidate configuration — Eq. (6) plus the multiplier gene.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Chromosome {
    pub px: usize,
    pub py: usize,
    pub rf_bytes: usize,
    pub sram_bytes: usize,
    pub mult_id: usize,
}

impl Chromosome {
    /// Uniform crossover.
    pub fn crossover(&self, other: &Chromosome, rng: &mut Rng) -> Chromosome {
        Chromosome {
            px: if rng.chance(0.5) { self.px } else { other.px },
            py: if rng.chance(0.5) { self.py } else { other.py },
            rf_bytes: if rng.chance(0.5) { self.rf_bytes } else { other.rf_bytes },
            sram_bytes: if rng.chance(0.5) { self.sram_bytes } else { other.sram_bytes },
            mult_id: if rng.chance(0.5) { self.mult_id } else { other.mult_id },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn space() -> SearchSpace {
        SearchSpace::standard(vec![0, 3, 7])
    }

    #[test]
    fn cardinality_matches_menus() {
        let s = space();
        assert_eq!(s.cardinality(), 6 * 6 * 5 * 6 * 3);
    }

    #[test]
    fn samples_stay_in_space() {
        let s = space();
        prop::check("sample-in-space", 100, |rng| {
            let c = s.sample(rng);
            assert!(s.contains(&c), "{c:?}");
        });
    }

    #[test]
    fn mutation_stays_in_space_and_changes_at_most_one_gene() {
        let s = space();
        prop::check("mutate-local", 100, |rng| {
            let c = s.sample(rng);
            let m = s.mutate(&c, rng);
            assert!(s.contains(&m), "{m:?}");
            let diffs = [
                c.px != m.px,
                c.py != m.py,
                c.rf_bytes != m.rf_bytes,
                c.sram_bytes != m.sram_bytes,
                c.mult_id != m.mult_id,
            ]
            .iter()
            .filter(|&&d| d)
            .count();
            assert!(diffs <= 1, "{c:?} -> {m:?}");
        });
    }

    #[test]
    fn crossover_genes_come_from_parents() {
        let s = space();
        prop::check("crossover-genes", 100, |rng| {
            let a = s.sample(rng);
            let b = s.sample(rng);
            let c = a.crossover(&b, rng);
            assert!(c.px == a.px || c.px == b.px);
            assert!(c.py == a.py || c.py == b.py);
            assert!(c.rf_bytes == a.rf_bytes || c.rf_bytes == b.rf_bytes);
            assert!(c.sram_bytes == a.sram_bytes || c.sram_bytes == b.sram_bytes);
            assert!(c.mult_id == a.mult_id || c.mult_id == b.mult_id);
            assert!(s.contains(&c));
        });
    }

    #[test]
    #[should_panic]
    fn empty_multiplier_set_panics() {
        SearchSpace::standard(vec![]);
    }
}
